//! ASCII line plots and tables for terminal reports.
//!
//! The paper's framework "generates plots and reports of schedule,
//! performance, throughput, and energy consumption"; in a terminal-first
//! tool those are ASCII artifacts plus CSV files for external plotting.

/// A single named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Series { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
}

/// Render multiple series as an ASCII chart (rows = y buckets).
pub fn ascii_chart(
    title: &str,
    xlabel: &str,
    ylabel: &str,
    series: &[Series],
    width: usize,
    height: usize,
) -> String {
    let marks = ['*', '+', 'o', 'x', '#', '@', '%', '&'];
    let mut pts: Vec<(f64, f64)> = Vec::new();
    for s in series {
        pts.extend(&s.points);
    }
    if pts.is_empty() {
        return format!("{title}\n  (no data)\n");
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        if x.is_finite() {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
        }
        if y.is_finite() {
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
    }
    if !xmin.is_finite() || xmax <= xmin {
        xmax = xmin + 1.0;
    }
    if !ymin.is_finite() || ymax <= ymin {
        ymax = ymin + 1.0;
    }

    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for &(x, y) in &s.points {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let col = ((x - xmin) / (xmax - xmin) * (width - 1) as f64)
                .round() as usize;
            let row = ((y - ymin) / (ymax - ymin) * (height - 1) as f64)
                .round() as usize;
            let row = height - 1 - row.min(height - 1);
            grid[row][col.min(width - 1)] = mark;
        }
    }

    let mut out = String::new();
    out.push_str(&format!("  {title}\n"));
    out.push_str(&format!("  {ylabel}\n"));
    for (i, row) in grid.iter().enumerate() {
        let yval = ymax - (ymax - ymin) * i as f64 / (height - 1) as f64;
        out.push_str(&format!("  {yval:>10.1} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!(
        "  {:>10} +{}\n",
        "",
        "-".repeat(width)
    ));
    out.push_str(&format!(
        "  {:>10}  {:<10.1}{:>width$.1}  ({xlabel})\n",
        "",
        xmin,
        xmax,
        width = width - 10
    ));
    out.push_str("  legend: ");
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("{}={}  ", marks[si % marks.len()], s.name));
    }
    out.push('\n');
    out
}

/// Render rows as an aligned ASCII table.
pub fn ascii_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        out.push('+');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('+');
        }
        out.push('\n');
    };
    sep(&mut out);
    out.push('|');
    for (h, w) in headers.iter().zip(&widths) {
        out.push_str(&format!(" {h:<w$} |"));
    }
    out.push('\n');
    sep(&mut out);
    for row in rows {
        out.push('|');
        for (i, w) in widths.iter().enumerate() {
            let empty = String::new();
            let cell = row.get(i).unwrap_or(&empty);
            out.push_str(&format!(" {cell:<w$} |"));
        }
        out.push('\n');
    }
    sep(&mut out);
    out
}

/// Serialize series to CSV: `x,<name1>,<name2>,...` with union of x values.
pub fn to_csv(xname: &str, series: &[Series]) -> String {
    let mut xs: Vec<f64> = series
        .iter()
        .flat_map(|s| s.points.iter().map(|p| p.0))
        .collect();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs.dedup();
    let mut out = String::new();
    out.push_str(xname);
    for s in series {
        out.push(',');
        out.push_str(&s.name);
    }
    out.push('\n');
    for x in xs {
        out.push_str(&format!("{x}"));
        for s in series {
            out.push(',');
            if let Some(p) = s.points.iter().find(|p| p.0 == x) {
                out.push_str(&format!("{}", p.1));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_series() -> Vec<Series> {
        let mut a = Series::new("a");
        let mut b = Series::new("b");
        for i in 0..10 {
            a.push(i as f64, (i * i) as f64);
            b.push(i as f64, (2 * i) as f64);
        }
        vec![a, b]
    }

    #[test]
    fn chart_contains_marks_and_legend() {
        let s = demo_series();
        let c = ascii_chart("t", "x", "y", &s, 40, 10);
        assert!(c.contains('*'));
        assert!(c.contains('+'));
        assert!(c.contains("legend"));
        assert!(c.contains("a"));
    }

    #[test]
    fn chart_handles_empty() {
        let c = ascii_chart("t", "x", "y", &[], 40, 10);
        assert!(c.contains("no data"));
    }

    #[test]
    fn chart_handles_single_point() {
        let mut s = Series::new("one");
        s.push(1.0, 1.0);
        let c = ascii_chart("t", "x", "y", &[s], 20, 5);
        assert!(c.contains('*'));
    }

    #[test]
    fn table_aligns() {
        let t = ascii_table(
            &["name", "value"],
            &[
                vec!["x".into(), "1".into()],
                vec!["longer".into(), "22".into()],
            ],
        );
        assert!(t.contains("| name   |"));
        assert!(t.contains("| longer |"));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let s = demo_series();
        let csv = to_csv("x", &s);
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("x,a,b"));
        assert_eq!(lines.next(), Some("0,0,0"));
        assert_eq!(csv.lines().count(), 11);
    }
}
