//! Pareto-front archive: the running set of non-dominated designs.
//!
//! All objectives are minimized.  The archive keeps every evaluated
//! design that no other evaluated design dominates; inserting a point
//! drops the entries it dominates.  A *hypervolume proxy* summarizes
//! the front's **shape**: objectives are normalized to the archive's
//! own current min/max box with a reference point 5% beyond the worst
//! corner — exact 2-D hypervolume for two objectives, a fixed-seed
//! quasi-Monte-Carlo estimate for three or more.  Because the box is
//! re-derived from the archive each call, the proxy measures how well
//! the front fills its own trade-off box (1 ≈ a dense front, small ≈ a
//! thin or degenerate one) — it is a per-generation diagnostic, **not
//! a monotone progress metric**: absolute improvements that stretch
//! the box can lower it.  Track `best_per_objective` for monotone
//! progress.

use super::eval::EvalMetrics;
use super::genome::PlatformGenome;
use crate::rng::Rng;
use crate::util::json::Json;
use crate::{Error, Result};

/// One evaluated design: genome + aggregated metrics + the objective
/// vector the search ranks on.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignPoint {
    pub genome: PlatformGenome,
    pub metrics: EvalMetrics,
    pub objectives: Vec<f64>,
}

impl DesignPoint {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("genome", self.genome.to_json())
            .set("metrics", self.metrics.to_json())
            .set(
                "objectives",
                Json::Arr(
                    self.objectives.iter().map(|&x| Json::Num(x)).collect(),
                ),
            );
        j
    }

    pub fn from_json(j: &Json) -> Result<DesignPoint> {
        Ok(DesignPoint {
            genome: PlatformGenome::from_json(j.get("genome").ok_or_else(
                || Error::Config("design point missing genome".into()),
            )?)?,
            metrics: EvalMetrics::from_json(j.get("metrics").ok_or_else(
                || Error::Config("design point missing metrics".into()),
            )?)?,
            objectives: j
                .get("objectives")
                .ok_or_else(|| {
                    Error::Config("design point missing objectives".into())
                })?
                .f64_vec()
                .map_err(|e| Error::Config(e.to_string()))?,
        })
    }
}

/// `a` Pareto-dominates `b`: no worse everywhere, strictly better
/// somewhere (minimization).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strict = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strict = true;
        }
    }
    strict
}

/// The non-dominated archive.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParetoArchive {
    entries: Vec<DesignPoint>,
}

impl ParetoArchive {
    pub fn new() -> ParetoArchive {
        ParetoArchive { entries: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> &[DesignPoint] {
        &self.entries
    }

    /// Offer a design.  Returns `true` if it entered the archive
    /// (i.e. nothing already there dominates or duplicates it);
    /// dominated incumbents are evicted.  Insertion order is
    /// deterministic, so archives built from the same evaluation
    /// sequence are bit-identical.
    pub fn insert(&mut self, point: DesignPoint) -> bool {
        for e in &self.entries {
            if dominates(&e.objectives, &point.objectives)
                || e.objectives == point.objectives
            {
                return false;
            }
        }
        self.entries
            .retain(|e| !dominates(&point.objectives, &e.objectives));
        self.entries.push(point);
        true
    }

    /// Entries sorted by the first objective — the natural order for
    /// front tables and CSV export.
    pub fn sorted_by_first_objective(&self) -> Vec<&DesignPoint> {
        let mut v: Vec<&DesignPoint> = self.entries.iter().collect();
        v.sort_by(|a, b| {
            a.objectives
                .partial_cmp(&b.objectives)
                .expect("finite objectives")
        });
        v
    }

    /// Hypervolume proxy of the current front (see module docs: a
    /// shape diagnostic normalized to the archive's own box, not a
    /// monotone progress metric).  ~0 for an empty front.
    pub fn hypervolume_proxy(&self) -> f64 {
        let n = self.entries.len();
        if n == 0 {
            return 0.0;
        }
        let dim = self.entries[0].objectives.len();
        // Normalize to the archive's bounding box.
        let mut lo = vec![f64::MAX; dim];
        let mut hi = vec![f64::MIN; dim];
        for e in &self.entries {
            for (k, &x) in e.objectives.iter().enumerate() {
                lo[k] = lo[k].min(x);
                hi[k] = hi[k].max(x);
            }
        }
        let span: Vec<f64> =
            (0..dim).map(|k| (hi[k] - lo[k]).max(1e-12)).collect();
        let norm: Vec<Vec<f64>> = self
            .entries
            .iter()
            .map(|e| {
                e.objectives
                    .iter()
                    .enumerate()
                    .map(|(k, &x)| (x - lo[k]) / span[k])
                    .collect()
            })
            .collect();
        const REF: f64 = 1.05;
        if dim == 1 {
            // Degenerate: best point's dominated interval.
            let best = norm
                .iter()
                .map(|p| p[0])
                .fold(f64::MAX, f64::min);
            return REF - best;
        }
        if dim == 2 {
            // Exact sweep: the front has strictly increasing x and
            // strictly decreasing y after sorting.
            let mut pts = norm.clone();
            pts.sort_by(|a, b| {
                a.partial_cmp(b).expect("finite objectives")
            });
            // Drop dominated points (the archive is non-dominated, but
            // normalization ties are possible).
            let mut hv = 0.0;
            let mut prev_y = REF;
            for p in pts {
                if p[1] < prev_y {
                    hv += (REF - p[0]) * (prev_y - p[1]);
                    prev_y = p[1];
                }
            }
            return hv;
        }
        // dim >= 3: fixed-seed Monte-Carlo estimate of the dominated
        // fraction of the [0, REF]^dim box.  The generator is local and
        // fixed, so the estimate is deterministic.
        const SAMPLES: usize = 8192;
        let mut rng = Rng::new(0x9E37_79B9);
        let mut dominated = 0usize;
        let mut sample = vec![0.0; dim];
        for _ in 0..SAMPLES {
            for s in sample.iter_mut() {
                *s = rng.uniform(0.0, REF);
            }
            if norm.iter().any(|p| {
                p.iter().zip(&sample).all(|(a, b)| a <= b)
            }) {
                dominated += 1;
            }
        }
        dominated as f64 / SAMPLES as f64 * REF.powi(dim as i32)
    }

    /// Best (minimum) value seen on the front per objective.
    pub fn best_per_objective(&self) -> Vec<f64> {
        if self.entries.is_empty() {
            return Vec::new();
        }
        let dim = self.entries[0].objectives.len();
        (0..dim)
            .map(|k| {
                self.entries
                    .iter()
                    .map(|e| e.objectives[k])
                    .fold(f64::MAX, f64::min)
            })
            .collect()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.entries.iter().map(DesignPoint::to_json).collect())
    }

    pub fn from_json(j: &Json) -> Result<ParetoArchive> {
        let entries = j
            .as_arr()
            .ok_or_else(|| {
                Error::Config("archive must be a JSON array".into())
            })?
            .iter()
            .map(DesignPoint::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(ParetoArchive { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(obj: &[f64]) -> DesignPoint {
        DesignPoint {
            genome: PlatformGenome {
                pe_counts: vec![obj.len()],
                opp_masks: vec![1],
                hop_latency_us: obj[0].abs() + 0.01,
                link_bandwidth: 8000.0,
                power_budget_w: None,
            },
            metrics: EvalMetrics {
                avg_latency_us: obj[0],
                p95_latency_us: 0.0,
                energy_per_job_mj: *obj.last().unwrap(),
                peak_temp_c: 0.0,
                throughput_jobs_per_ms: 0.0,
                avg_power_w: 0.0,
                completed_frac: 1.0,
                runs: 1,
            },
            objectives: obj.to_vec(),
        }
    }

    #[test]
    fn dominance_is_strict_somewhere() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 1.0]));
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 1.0]));
        assert!(!dominates(&[2.0, 1.0], &[1.0, 3.0]));
    }

    #[test]
    fn insert_keeps_only_non_dominated() {
        let mut a = ParetoArchive::new();
        assert!(a.insert(pt(&[5.0, 5.0])));
        assert!(a.insert(pt(&[3.0, 7.0])));
        assert!(a.insert(pt(&[7.0, 3.0])));
        assert_eq!(a.len(), 3);
        // Dominated offer is rejected.
        assert!(!a.insert(pt(&[6.0, 6.0])));
        // Duplicate objectives are rejected.
        assert!(!a.insert(pt(&[5.0, 5.0])));
        assert_eq!(a.len(), 3);
        // A dominating point evicts what it beats.
        assert!(a.insert(pt(&[2.0, 2.0])));
        assert_eq!(a.len(), 1);
        assert_eq!(a.entries()[0].objectives, vec![2.0, 2.0]);
    }

    #[test]
    fn hypervolume_grows_with_front_quality() {
        let mut a = ParetoArchive::new();
        a.insert(pt(&[10.0, 1.0]));
        a.insert(pt(&[1.0, 10.0]));
        let hv1 = a.hypervolume_proxy();
        // Add a knee point: strictly more dominated volume.
        a.insert(pt(&[2.0, 2.0]));
        let hv2 = a.hypervolume_proxy();
        assert!(
            hv2 > hv1,
            "knee point must grow the proxy: {hv1} -> {hv2}"
        );
    }

    #[test]
    fn hypervolume_2d_matches_hand_computation() {
        // Two points at the normalized corners: (0,1) and (1,0) with
        // REF=1.05 give 1.05*0.05 + 0.05*1.05 + 0.05*0.05 overlap-free
        // sweep = 0.05*1.05 + 1.05*... easier: sweep formula.
        let mut a = ParetoArchive::new();
        a.insert(pt(&[0.0, 1.0]));
        a.insert(pt(&[1.0, 0.0]));
        // normalized: same values. sweep sorted by x: (0,1): hv +=
        // (1.05-0)*(1.05-1)=0.0525; (1,0): hv += (1.05-1)*(1-0)=0.05.
        let hv = a.hypervolume_proxy();
        assert!((hv - 0.1025).abs() < 1e-12, "hv={hv}");
    }

    #[test]
    fn hypervolume_3d_is_deterministic_and_sane() {
        let mut a = ParetoArchive::new();
        a.insert(pt(&[1.0, 5.0, 9.0]));
        a.insert(pt(&[5.0, 1.0, 5.0]));
        a.insert(pt(&[9.0, 9.0, 1.0]));
        let hv1 = a.hypervolume_proxy();
        let hv2 = a.hypervolume_proxy();
        assert_eq!(hv1, hv2);
        assert!(hv1 > 0.0 && hv1 < 1.05f64.powi(3));
    }

    #[test]
    fn sorted_front_and_best_per_objective() {
        let mut a = ParetoArchive::new();
        a.insert(pt(&[3.0, 7.0]));
        a.insert(pt(&[7.0, 3.0]));
        a.insert(pt(&[5.0, 5.0]));
        let sorted = a.sorted_by_first_objective();
        assert_eq!(sorted[0].objectives[0], 3.0);
        assert_eq!(sorted[2].objectives[0], 7.0);
        assert_eq!(a.best_per_objective(), vec![3.0, 3.0]);
    }

    #[test]
    fn archive_json_roundtrip_is_exact() {
        let mut a = ParetoArchive::new();
        a.insert(pt(&[3.25, 7.5]));
        a.insert(pt(&[7.125, 3.0625]));
        let j = Json::parse(&a.to_json().to_string()).unwrap();
        let b = ParetoArchive::from_json(&j).unwrap();
        assert_eq!(a, b);
    }
}
