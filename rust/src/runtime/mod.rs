//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! This is the only bridge between the rust coordinator and the Layer-1/2
//! compute graphs.  Artifacts are **HLO text** (see `python/compile/aot.py`
//! for why text, not serialized protos), produced once by `make artifacts`
//! and loaded here via the `xla` crate:
//!
//! ```text
//!   PjRtClient::cpu() → HloModuleProto::from_text_file → compile → execute
//! ```
//!
//! Each artifact struct ([`DtpmArtifact`], [`EtfArtifact`]) owns a
//! compiled executable plus the fixed-shape padding/unpadding logic of
//! its AOT contract (DESIGN.md §5).  One PJRT client is shared per
//! thread (`PjRtClient` is `Rc`-internal and not `Send`).

use std::cell::RefCell;
use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// DTPM artifact contract (must match `python/compile/kernels/thermal.py`).
pub const DTPM_K: usize = 16;
pub const DTPM_N: usize = 32;
pub const DTPM_P: usize = 16;

/// ETF artifact contract (must match `python/compile/kernels/etf.py`).
pub const ETF_I: usize = 64;
pub const ETF_J: usize = 16;

/// Large finite sentinel used instead of +inf when padding (keeps the
/// device matrix finite so argmin reductions avoid NaN edge cases and
/// the values survive JSON goldens).
pub const PAD_SENTINEL: f32 = 1e30;

thread_local! {
    static CLIENT: RefCell<Option<xla::PjRtClient>> = const { RefCell::new(None) };
}

fn with_client<T>(
    f: impl FnOnce(&xla::PjRtClient) -> Result<T>,
) -> Result<T> {
    CLIENT.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            let client = xla::PjRtClient::cpu().map_err(|e| {
                Error::Runtime(format!("PjRtClient::cpu failed: {e:?}"))
            })?;
            *slot = Some(client);
        }
        f(slot.as_ref().unwrap())
    })
}

/// Resolve the artifacts directory: `$DS3R_ARTIFACTS`, else `artifacts/`
/// relative to the current directory, else relative to the crate root.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("DS3R_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if the AOT artifacts are present (tests skip gracefully if the
/// user has not run `make artifacts`).
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("dtpm_step.hlo.txt").exists()
        && dir.join("etf_matrix.hlo.txt").exists()
}

fn compile(path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    if !path.exists() {
        return Err(Error::Runtime(format!(
            "artifact {} not found — run `make artifacts` first",
            path.display()
        )));
    }
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| {
            Error::Runtime("non-utf8 artifact path".into())
        })?,
    )
    .map_err(|e| {
        Error::Runtime(format!("parse {}: {e:?}", path.display()))
    })?;
    let comp = xla::XlaComputation::from_proto(&proto);
    with_client(|client| {
        client.compile(&comp).map_err(|e| {
            Error::Runtime(format!("compile {}: {e:?}", path.display()))
        })
    })
}

fn lit_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| Error::Runtime(format!("reshape: {e:?}")))
}

fn run(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let result = exe
        .execute::<xla::Literal>(inputs)
        .map_err(|e| Error::Runtime(format!("execute: {e:?}")))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| Error::Runtime(format!("to_literal: {e:?}")))?;
    // aot.py lowers with return_tuple=True: unpack the result tuple.
    lit.to_tuple()
        .map_err(|e| Error::Runtime(format!("to_tuple: {e:?}")))
}

fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| Error::Runtime(format!("to_vec: {e:?}")))
}

// ---------------------------------------------------------------------------
// DTPM artifact
// ---------------------------------------------------------------------------

/// Outputs of one batched DTPM step (unpadded to platform dimensions).
#[derive(Debug, Clone)]
pub struct DtpmStepOut {
    /// `[k][node]` next above-ambient temperatures.
    pub t_next: Vec<Vec<f64>>,
    /// `[k][pe]` leakage power (W).
    pub p_leak: Vec<Vec<f64>>,
    /// `[k][pe]` total power (W).
    pub p_total: Vec<Vec<f64>>,
    /// `[k]` SoC power (W).
    pub p_sum: Vec<f64>,
}

/// The batched power/thermal epoch update, AOT-compiled from
/// `python/compile/model.py::dtpm_step_model`.
pub struct DtpmArtifact {
    exe: xla::PjRtLoadedExecutable,
    /// Padded constant operands (platform-dependent, set via `set_model`).
    a_pad: Vec<f32>,
    b_pad: Vec<f32>,
    pe_node_pad: Vec<f32>,
    k1_pad: Vec<f32>,
    k2_pad: Vec<f32>,
    n_nodes: usize,
    n_pes: usize,
    pub calls: u64,
}

impl DtpmArtifact {
    pub const K: usize = DTPM_K;

    /// Load + compile the artifact; `set_model` must be called before
    /// `step`.
    pub fn load(dir: &Path) -> Result<DtpmArtifact> {
        let exe = compile(&dir.join("dtpm_step.hlo.txt"))?;
        Ok(DtpmArtifact {
            exe,
            a_pad: vec![0.0; DTPM_N * DTPM_N],
            b_pad: vec![0.0; DTPM_N * DTPM_P],
            pe_node_pad: vec![0.0; DTPM_P * DTPM_N],
            k1_pad: vec![0.0; DTPM_P],
            k2_pad: vec![0.0; DTPM_P],
            n_nodes: 0,
            n_pes: 0,
            calls: 0,
        })
    }

    /// Install the platform's thermal model and leakage coefficients.
    ///
    /// `k1` must already be the *effective* k1 (ambient offset folded in,
    /// see `thermal::RcModel::leak_k1_effective`).
    pub fn set_model(
        &mut self,
        rc: &crate::thermal::RcModel,
        k1_eff: &[f64],
        k2: &[f64],
    ) -> Result<()> {
        if rc.n > DTPM_N || rc.n_pes > DTPM_P {
            return Err(Error::Runtime(format!(
                "platform ({} nodes, {} pes) exceeds artifact padding \
                 ({DTPM_N}, {DTPM_P})",
                rc.n, rc.n_pes
            )));
        }
        self.a_pad = rc.a_padded_f32(DTPM_N, DTPM_N);
        self.b_pad = rc.b_padded_f32(DTPM_N, DTPM_P);
        self.pe_node_pad = rc.pe_node_padded_f32(DTPM_P, DTPM_N);
        self.k1_pad = vec![0.0; DTPM_P];
        self.k2_pad = vec![0.0; DTPM_P];
        for i in 0..rc.n_pes {
            self.k1_pad[i] = k1_eff[i] as f32;
            self.k2_pad[i] = k2[i] as f32;
        }
        self.n_nodes = rc.n;
        self.n_pes = rc.n_pes;
        Ok(())
    }

    /// Execute one batched step for `candidates.len() <= K` DVFS
    /// candidates.  Each candidate supplies per-PE dynamic power and
    /// voltage; `theta` is the shared current state (above-ambient °C).
    pub fn step(
        &mut self,
        theta: &[f64],
        candidates: &[(Vec<f64>, Vec<f64>)], // (p_dyn, volt) per candidate
    ) -> Result<DtpmStepOut> {
        assert!(self.n_nodes > 0, "set_model not called");
        let k_used = candidates.len();
        if k_used == 0 || k_used > DTPM_K {
            return Err(Error::Runtime(format!(
                "bad candidate count {k_used} (1..={DTPM_K})"
            )));
        }
        debug_assert_eq!(theta.len(), self.n_nodes);

        let mut t = vec![0.0f32; DTPM_K * DTPM_N];
        let mut pd = vec![0.0f32; DTPM_K * DTPM_P];
        let mut v = vec![0.0f32; DTPM_K * DTPM_P];
        for k in 0..DTPM_K {
            // Unused candidate rows replicate row 0 (harmless work).
            let (pdk, vk) = candidates.get(k).unwrap_or(&candidates[0]);
            for i in 0..self.n_nodes {
                t[k * DTPM_N + i] = theta[i] as f32;
            }
            for p in 0..self.n_pes {
                pd[k * DTPM_P + p] = pdk[p] as f32;
                v[k * DTPM_P + p] = vk[p] as f32;
            }
        }

        let inputs = [
            lit_2d(&t, DTPM_K, DTPM_N)?,
            lit_2d(&self.a_pad, DTPM_N, DTPM_N)?,
            lit_2d(&self.b_pad, DTPM_N, DTPM_P)?,
            lit_2d(&pd, DTPM_K, DTPM_P)?,
            lit_2d(&v, DTPM_K, DTPM_P)?,
            lit_2d(&self.k1_pad, 1, DTPM_P)?,
            lit_2d(&self.k2_pad, 1, DTPM_P)?,
            lit_2d(&self.pe_node_pad, DTPM_P, DTPM_N)?,
        ];
        let outs = run(&self.exe, &inputs)?;
        if outs.len() != 4 {
            return Err(Error::Runtime(format!(
                "dtpm artifact returned {} outputs, want 4",
                outs.len()
            )));
        }
        self.calls += 1;
        let t_next_raw = to_f32_vec(&outs[0])?;
        let p_leak_raw = to_f32_vec(&outs[1])?;
        let p_total_raw = to_f32_vec(&outs[2])?;
        let p_sum_raw = to_f32_vec(&outs[3])?;

        let unpad = |raw: &[f32], cols_pad: usize, cols: usize| {
            (0..k_used)
                .map(|k| {
                    (0..cols)
                        .map(|c| raw[k * cols_pad + c] as f64)
                        .collect::<Vec<f64>>()
                })
                .collect::<Vec<_>>()
        };
        // p_sum from the device includes padded-PE leakage (zero k1 ⇒
        // zero), so it is exact for the real PEs.
        Ok(DtpmStepOut {
            t_next: unpad(&t_next_raw, DTPM_N, self.n_nodes),
            p_leak: unpad(&p_leak_raw, DTPM_P, self.n_pes),
            p_total: unpad(&p_total_raw, DTPM_P, self.n_pes),
            p_sum: (0..k_used).map(|k| p_sum_raw[k] as f64).collect(),
        })
    }
}

// ---------------------------------------------------------------------------
// ETF artifact
// ---------------------------------------------------------------------------

/// The ETF finish-time matrix, AOT-compiled from
/// `python/compile/model.py::etf_model`.
pub struct EtfArtifact {
    exe: xla::PjRtLoadedExecutable,
    pub calls: u64,
}

impl EtfArtifact {
    /// Max ready tasks per device call (artifact row padding).
    pub const MAX_TASKS: usize = ETF_I;
    /// Max PEs (artifact column padding).
    pub const MAX_PES: usize = ETF_J;

    pub fn load(dir: &Path) -> Result<EtfArtifact> {
        Ok(EtfArtifact {
            exe: compile(&dir.join("etf_matrix.hlo.txt"))?,
            calls: 0,
        })
    }

    /// Compute `finish[i][j] = max(avail[j], ready[i][j]) + exec[i][j]`
    /// for `n x m` real entries (row-major `ready`/`exec`).  Unsupported
    /// pairs must carry `f64::INFINITY` in `exec`; they come back as
    /// `f64::INFINITY`.
    pub fn finish_matrix(
        &mut self,
        avail: &[f64],
        ready: &[f64],
        exec: &[f64],
        n: usize,
        m: usize,
    ) -> Result<Vec<f64>> {
        if n > ETF_I || m > ETF_J {
            return Err(Error::Runtime(format!(
                "ready list {n}x{m} exceeds artifact padding {ETF_I}x{ETF_J}"
            )));
        }
        debug_assert_eq!(avail.len(), m);
        debug_assert_eq!(ready.len(), n * m);
        debug_assert_eq!(exec.len(), n * m);

        let mut av = vec![PAD_SENTINEL; ETF_J];
        for j in 0..m {
            av[j] = avail[j] as f32;
        }
        let mut rd = vec![0.0f32; ETF_I * ETF_J];
        let mut ex = vec![PAD_SENTINEL; ETF_I * ETF_J];
        for i in 0..n {
            for j in 0..m {
                rd[i * ETF_J + j] = ready[i * m + j] as f32;
                let e = exec[i * m + j];
                ex[i * ETF_J + j] =
                    if e.is_finite() { e as f32 } else { PAD_SENTINEL };
            }
        }

        let inputs = [
            lit_2d(&av, 1, ETF_J)?,
            lit_2d(&rd, ETF_I, ETF_J)?,
            lit_2d(&ex, ETF_I, ETF_J)?,
        ];
        let outs = run(&self.exe, &inputs)?;
        if outs.len() != 3 {
            return Err(Error::Runtime(format!(
                "etf artifact returned {} outputs, want 3",
                outs.len()
            )));
        }
        self.calls += 1;
        let fin_raw = to_f32_vec(&outs[0])?;
        let mut out = vec![f64::INFINITY; n * m];
        for i in 0..n {
            for j in 0..m {
                let f = fin_raw[i * ETF_J + j];
                // Anything that saturated the sentinel is "unsupported".
                out[i * m + j] = if f >= PAD_SENTINEL * 0.5 {
                    f64::INFINITY
                } else {
                    f as f64
                };
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full numeric round-trip tests against the python goldens live in
    // rust/tests/integration_runtime.rs (they need `make artifacts`).
    // Here: pure host-side helpers.

    #[test]
    fn artifacts_dir_resolution_env() {
        std::env::set_var("DS3R_ARTIFACTS", "/tmp/ds3r-test-artifacts");
        assert_eq!(
            default_artifacts_dir(),
            PathBuf::from("/tmp/ds3r-test-artifacts")
        );
        std::env::remove_var("DS3R_ARTIFACTS");
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let err = compile(Path::new("/nonexistent/foo.hlo.txt"))
            .err()
            .expect("must fail");
        let msg = format!("{err}");
        assert!(msg.contains("make artifacts"), "msg: {msg}");
    }
}
