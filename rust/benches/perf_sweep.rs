//! Batched grid-evaluation benchmark — the throughput contract of the
//! reusable-`SimWorker` engine, recorded in `BENCH_sweep.json`.
//!
//! Two grids:
//!
//! * **probe grid** — many tiny simulations (short-horizon policy
//!   probes: the regime the DSE evaluator's seeds×scenarios fan-out
//!   and the IL pipeline's DAgger probes live in, where per-point
//!   setup cost dominates).  Measured twice over the *same* points:
//!   a fresh `Simulation::build(..).run()` per point versus one
//!   `SimSetup` + a single reused `SimWorker`.  The pooled path must
//!   deliver **≥ 1.5× sims/s** — printed always, asserted in smoke
//!   mode (the CI gate).
//! * **throughput grid** — fewer, longer runs; the pooled sims/s is
//!   recorded so the JSON trajectory tracks end-to-end sweep speed,
//!   where the win is smaller (run time dominates setup).
//!
//! Run: `cargo bench --bench perf_sweep`
//!
//! Knobs:
//! * `BENCH_SMOKE=1`      — reduced grid for CI latency (and the
//!   speedup assertion)
//! * `BENCH_OUT=path`     — where to write the JSON (default
//!   `BENCH_sweep.json`)
//! * `BENCH_BASELINE=path` — compare sims/s per grid against a
//!   baseline JSON and exit non-zero on a >20% regression; missing
//!   baseline records only
//! * `-- --write-baseline` — additionally write this run's record to
//!   the baseline path (refresh-and-commit workflow; see README
//!   §Performance)
//! * `TELEMETRY_OUT=path|-` — additionally stream each grid
//!   measurement as `bench_record` telemetry events (README
//!   §Observability)

mod bench_util;

use ds3r::app::suite::{self, RadarParams, WifiParams};
use ds3r::app::AppGraph;
use ds3r::config::SimConfig;
use ds3r::platform::Platform;
use ds3r::sim::{SimSetup, SimWorker, Simulation};
use ds3r::telemetry::Event as TelEvent;
use ds3r::util::json::Json;

/// One (scheduler, rate, seed) grid point.
#[derive(Clone)]
struct Point {
    scheduler: &'static str,
    rate: f64,
    seed: u64,
}

fn grid(
    scheds: &[&'static str],
    rates: &[f64],
    seeds: u64,
) -> Vec<Point> {
    let mut out = Vec::new();
    for &scheduler in scheds {
        for &rate in rates {
            for seed in 0..seeds {
                out.push(Point { scheduler, rate, seed });
            }
        }
    }
    out
}

fn point_cfg(base: &SimConfig, p: &Point) -> SimConfig {
    let mut cfg = base.clone();
    cfg.scheduler = p.scheduler.into();
    cfg.injection_rate_per_ms = p.rate;
    cfg.seed = p.seed;
    cfg
}

/// One measured grid pass, fresh-build-per-point.
fn pass_fresh(
    platform: &Platform,
    apps: &[AppGraph],
    base: &SimConfig,
    points: &[Point],
) -> usize {
    let mut completed = 0usize;
    for p in points {
        let cfg = point_cfg(base, p);
        let r = Simulation::build(platform, apps, &cfg).unwrap().run();
        completed += r.completed_jobs;
    }
    completed
}

/// One measured grid pass through a single reused worker.
fn pass_pooled(
    setup: &SimSetup,
    base: &SimConfig,
    points: &[Point],
) -> usize {
    let mut slot: Option<SimWorker> = None;
    let mut completed = 0usize;
    for p in points {
        let cfg = point_cfg(base, p);
        let w = SimWorker::obtain(&mut slot, setup, &cfg).unwrap();
        completed += w.run(setup).completed_jobs;
    }
    completed
}

struct GridResult {
    name: String,
    points: usize,
    sims_per_s: f64,
    median_s: f64,
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE")
        .map(|v| v == "1")
        .unwrap_or(false);
    let write_baseline =
        std::env::args().any(|a| a == "--write-baseline");
    let platform = Platform::table2_soc();
    // Multi-app mix: setup cost (exec tables, templates, validation)
    // scales with the workload, exactly like DSE/IL grids.
    let apps = vec![
        suite::wifi_tx(WifiParams { symbols: 2 }),
        suite::single_carrier_tx(),
        suite::range_detection(RadarParams { pulses: 2 }),
    ];
    let mut results: Vec<GridResult> = Vec::new();

    // --- probe grid: tiny sims, setup-dominated --------------------
    // One job per point: the limiting regime of DSE/IL policy probes,
    // where per-point setup (exec tables, NoC, RC, buffers) rivals the
    // simulated work itself.
    let seeds = if smoke { 80 } else { 300 };
    let probe = grid(&["etf", "met", "heft", "rr"], &[8.0], seeds);
    let mut probe_cfg = SimConfig::default();
    probe_cfg.max_jobs = 1;
    probe_cfg.warmup_jobs = 0;
    let (warm, runs) = if smoke { (1, 3) } else { (1, 5) };

    println!(
        "=== probe grid: {} points x {} jobs (median of {runs}{}) ===",
        probe.len(),
        probe_cfg.max_jobs,
        if smoke { ", smoke mode" } else { "" }
    );
    let (fresh_jobs, fresh_st) = bench_util::bench_median(
        &format!("fresh build per point ({} pts)", probe.len()),
        warm,
        runs,
        || pass_fresh(&platform, &apps, &probe_cfg, &probe),
    );
    let setup = SimSetup::new(&platform, &apps, &probe_cfg).unwrap();
    let (pooled_jobs, pooled_st) = bench_util::bench_median(
        &format!("pooled SimWorker ({} pts)", probe.len()),
        warm,
        runs,
        || pass_pooled(&setup, &probe_cfg, &probe),
    );
    assert_eq!(
        fresh_jobs, pooled_jobs,
        "pooled pass diverged from fresh pass (jobs completed)"
    );
    let fresh_sps = probe.len() as f64 / fresh_st.median_s;
    let pooled_sps = probe.len() as f64 / pooled_st.median_s;
    let speedup = pooled_sps / fresh_sps;
    println!(
        "{:>48} {fresh_sps:>10.0} sims/s fresh | {pooled_sps:>10.0} \
         sims/s pooled | {speedup:.2}x speedup\n",
        ""
    );
    results.push(GridResult {
        name: "probe-fresh".into(),
        points: probe.len(),
        sims_per_s: fresh_sps,
        median_s: fresh_st.median_s,
    });
    results.push(GridResult {
        name: "probe-pooled".into(),
        points: probe.len(),
        sims_per_s: pooled_sps,
        median_s: pooled_st.median_s,
    });

    // --- throughput grid: longer runs, end-to-end sweep speed ------
    let jobs = if smoke { 120 } else { 400 };
    let tput = grid(&["etf", "met"], &[6.0, 9.0], 2);
    let mut tput_cfg = SimConfig::default();
    tput_cfg.max_jobs = jobs;
    tput_cfg.warmup_jobs = jobs / 20;
    println!(
        "=== throughput grid: {} points x {jobs} jobs ===",
        tput.len()
    );
    let tsetup = SimSetup::new(&platform, &apps, &tput_cfg).unwrap();
    let (_, tput_st) = bench_util::bench_median(
        &format!("pooled SimWorker ({} pts)", tput.len()),
        warm,
        runs,
        || pass_pooled(&tsetup, &tput_cfg, &tput),
    );
    let tput_sps = tput.len() as f64 / tput_st.median_s;
    println!("{:>48} {tput_sps:>10.2} sims/s pooled\n", "");
    results.push(GridResult {
        name: "throughput-pooled".into(),
        points: tput.len(),
        sims_per_s: tput_sps,
        median_s: tput_st.median_s,
    });

    let tel = bench_util::telemetry_from_env();
    for g in &results {
        tel.emit(|| TelEvent::BenchRecord {
            bench: "perf_sweep".into(),
            name: format!("grid.{}.sims_per_s", g.name),
            value: g.sims_per_s,
            unit: "sims/s".into(),
        });
    }
    tel.emit(|| TelEvent::BenchRecord {
        bench: "perf_sweep".into(),
        name: "probe.pooled_vs_fresh".into(),
        value: speedup,
        unit: "ratio".into(),
    });
    tel.flush();
    write_json(&results, speedup, smoke, write_baseline);
    if !write_baseline {
        // (In --write-baseline mode the file was just overwritten with
        // this run — comparing against it would be vacuous.)
        check_baseline(&results, smoke);
    }

    // The acceptance gate: reused workers must beat fresh builds by
    // ≥ 1.5× on the setup-dominated grid.  Asserted in smoke mode
    // (CI); printed above either way.
    if smoke && speedup < 1.5 {
        eprintln!(
            "SWEEP REGRESSION: pooled/fresh speedup {speedup:.2}x \
             < 1.5x required on the probe grid"
        );
        std::process::exit(1);
    }
}

fn write_json(
    results: &[GridResult],
    speedup: f64,
    smoke: bool,
    write_baseline: bool,
) {
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut j = Json::obj();
    j.set("schema", Json::Num(1.0))
        .set("bench", Json::Str("perf_sweep".into()))
        .set("smoke", Json::Bool(smoke))
        .set("unix_time_s", Json::Num(unix_s as f64))
        .set("probe_speedup_pooled_vs_fresh", Json::Num(speedup))
        .set(
            "grids",
            Json::Arr(
                results
                    .iter()
                    .map(|g| {
                        let mut e = Json::obj();
                        e.set("name", Json::Str(g.name.clone()))
                            .set("points", Json::Num(g.points as f64))
                            .set("sims_per_s", Json::Num(g.sims_per_s))
                            .set("median_s", Json::Num(g.median_s));
                        e
                    })
                    .collect(),
            ),
        );
    let out = std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_sweep.json".into());
    match std::fs::write(&out, j.to_string_pretty()) {
        Ok(()) => println!("bench record written to {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
    if write_baseline {
        let base = std::env::var("BENCH_BASELINE")
            .unwrap_or_else(|_| "BENCH_sweep_baseline.json".into());
        match std::fs::write(&base, j.to_string_pretty()) {
            Ok(()) => println!(
                "baseline refreshed at {base} — commit it to arm the \
                 regression gate"
            ),
            Err(e) => eprintln!("could not write baseline {base}: {e}"),
        }
    }
}

/// Compare sims/s per grid against a committed baseline (same schema),
/// exiting non-zero on a >20% regression — mirror of the
/// `perf_hotpath` gate.  Refuses to compare across smoke/full modes:
/// the grids run different job counts per sim, so cross-mode sims/s
/// ratios are meaningless (a smoke run vs a full baseline would never
/// fire, and the reverse would always fire).
fn check_baseline(results: &[GridResult], smoke: bool) {
    let Ok(base_path) = std::env::var("BENCH_BASELINE") else {
        return;
    };
    let base = match Json::parse_file(std::path::Path::new(&base_path)) {
        Ok(j) => j,
        Err(e) => {
            println!(
                "(no usable baseline at {base_path}: {e} — recording only)"
            );
            return;
        }
    };
    let base_smoke = base.get("smoke").and_then(Json::as_bool);
    if base_smoke != Some(smoke) {
        println!(
            "(baseline {base_path} was recorded with smoke={:?}, this \
             run is smoke={smoke} — modes differ, recording only; \
             refresh the baseline in the mode the gate runs in)",
            base_smoke
        );
        return;
    }
    let Some(grids) = base.get("grids").and_then(Json::as_arr) else {
        println!("(baseline {base_path} has no 'grids' — skipping)");
        return;
    };
    let mut failures = Vec::new();
    for bg in grids {
        let Some(name) = bg.get("name").and_then(Json::as_str) else {
            continue;
        };
        let Some(base_sps) = bg.get("sims_per_s").and_then(Json::as_f64)
        else {
            continue;
        };
        let Some(cur) = results.iter().find(|g| g.name == name) else {
            failures.push(format!("grid '{name}' missing from run"));
            continue;
        };
        let ratio = cur.sims_per_s / base_sps;
        println!(
            "baseline check [{name}]: {:.1} sims/s vs baseline {:.1} \
             ({:+.1}%)",
            cur.sims_per_s,
            base_sps,
            (ratio - 1.0) * 100.0
        );
        if ratio < 0.80 {
            failures.push(format!(
                "grid '{name}' regressed {:.1}% (>20% allowed)",
                (1.0 - ratio) * 100.0
            ));
        }
    }
    if !failures.is_empty() {
        eprintln!("PERF REGRESSION vs {base_path}:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
