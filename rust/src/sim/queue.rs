//! Discrete-event queue: a binary heap ordered by (time, sequence).
//!
//! The sequence number makes event ordering total and deterministic —
//! two events at the same timestamp pop in insertion order, so runs are
//! exactly reproducible from the seed.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation events.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event {
    /// A new job instance of application `app` enters the system.
    JobArrival { app: usize },
    /// Task `task` of job `job` finishes on PE `pe`.
    TaskFinish { job: usize, task: usize, pe: usize },
    /// DTPM/DVFS decision epoch boundary.
    DtpmEpoch,
    /// Scenario timeline entry `seq` fires (see [`crate::scenario`]).
    Scenario { seq: usize },
}

#[derive(Debug)]
struct Entry {
    at: f64,
    seq: u64,
    ev: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: reverse of (at, seq).  `at` is always finite.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap()
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic min-priority event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
    pub pushed: u64,
    pub popped: u64,
    /// High-water mark of the heap depth — lets the kernel's capacity
    /// regression test prove the pre-sizing covered the whole run (no
    /// mid-run reallocation).
    pub peak_len: usize,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Pre-sized queue: saturating runs keep hundreds of in-flight
    /// events, so the kernel pre-sizes the heap to avoid growth
    /// reallocations on the hot path.
    pub fn with_capacity(cap: usize) -> EventQueue {
        EventQueue {
            heap: BinaryHeap::with_capacity(cap),
            seq: 0,
            pushed: 0,
            popped: 0,
            peak_len: 0,
        }
    }

    /// Rewind to the fresh state — heap emptied, sequence and counters
    /// zeroed — growing (never shrinking) the retained allocation to at
    /// least `cap`.  Worker reuse resets instead of re-allocating.
    pub fn reset(&mut self, cap: usize) {
        self.heap.clear();
        if self.heap.capacity() < cap {
            self.heap.reserve(cap - self.heap.len());
        }
        self.seq = 0;
        self.pushed = 0;
        self.popped = 0;
        self.peak_len = 0;
    }

    pub fn capacity(&self) -> usize {
        self.heap.capacity()
    }

    pub fn push(&mut self, at: f64, ev: Event) {
        debug_assert!(at.is_finite(), "non-finite event time");
        self.heap.push(Entry { at, seq: self.seq, ev });
        self.seq += 1;
        self.pushed += 1;
        if self.heap.len() > self.peak_len {
            self.peak_len = self.heap.len();
        }
    }

    pub fn pop(&mut self) -> Option<(f64, Event)> {
        self.heap.pop().map(|e| {
            self.popped += 1;
            (e.at, e.ev)
        })
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(5.0, Event::DtpmEpoch);
        q.push(1.0, Event::JobArrival { app: 0 });
        q.push(3.0, Event::TaskFinish { job: 0, task: 0, pe: 0 });
        let times: Vec<f64> =
            std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn same_time_pops_in_insertion_order() {
        let mut q = EventQueue::new();
        for app in 0..10 {
            q.push(7.0, Event::JobArrival { app });
        }
        let apps: Vec<usize> = std::iter::from_fn(|| {
            q.pop().map(|(_, e)| match e {
                Event::JobArrival { app } => app,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(apps, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn same_time_mixed_kinds_pop_in_insertion_order() {
        // Determinism is load-bearing: scenario events share timestamps
        // with task events, and the (time, sequence) total order must
        // keep runs exactly reproducible.  Pin the tie-break across all
        // event kinds at one timestamp, twice, in different insertion
        // orders.
        let batch = [
            Event::Scenario { seq: 0 },
            Event::JobArrival { app: 1 },
            Event::TaskFinish { job: 2, task: 3, pe: 4 },
            Event::DtpmEpoch,
            Event::Scenario { seq: 1 },
        ];
        let mut q = EventQueue::new();
        q.push(9.0, Event::DtpmEpoch); // later event must not interfere
        for ev in batch {
            q.push(4.0, ev);
        }
        let popped: Vec<Event> =
            std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(&popped[..batch.len()], &batch);
        assert_eq!(popped[batch.len()], Event::DtpmEpoch);

        // Reversed insertion order pops reversed: order is insertion,
        // not kind priority.
        let mut q = EventQueue::new();
        for ev in batch.iter().rev() {
            q.push(4.0, *ev);
        }
        let popped: Vec<Event> =
            std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        let want: Vec<Event> = batch.iter().rev().copied().collect();
        assert_eq!(popped, want);
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        q.push(2.5, Event::DtpmEpoch);
        q.push(1.5, Event::DtpmEpoch);
        assert_eq!(q.peek_time(), Some(1.5));
        assert_eq!(q.pop().unwrap().0, 1.5);
        assert_eq!(q.peek_time(), Some(2.5));
    }

    #[test]
    fn counters_track_traffic() {
        let mut q = EventQueue::new();
        for i in 0..5 {
            q.push(i as f64, Event::DtpmEpoch);
        }
        q.pop();
        q.pop();
        assert_eq!(q.pushed, 5);
        assert_eq!(q.popped, 2);
        assert_eq!(q.len(), 3);
        assert!(!q.is_empty());
        assert_eq!(q.peak_len, 5);
    }

    #[test]
    fn reset_rewinds_counters_and_keeps_capacity() {
        let mut q = EventQueue::with_capacity(64);
        for i in 0..50 {
            q.push(i as f64, Event::DtpmEpoch);
        }
        q.pop();
        let cap = q.capacity();
        assert!(cap >= 64);
        q.reset(64);
        assert!(q.is_empty());
        assert_eq!((q.pushed, q.popped, q.peak_len), (0, 0, 0));
        assert_eq!(q.capacity(), cap, "reset must not shrink or grow");
        // Sequence restarted: same-timestamp events pop in the new
        // insertion order, exactly like a fresh queue.
        for app in 0..5 {
            q.push(1.0, Event::JobArrival { app });
        }
        let apps: Vec<usize> = std::iter::from_fn(|| {
            q.pop().map(|(_, e)| match e {
                Event::JobArrival { app } => app,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(apps, vec![0, 1, 2, 3, 4]);
        // Growing reset reserves at least the requested capacity.
        q.reset(4096);
        assert!(q.capacity() >= 4096);
    }
}
