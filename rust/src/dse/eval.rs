//! Candidate evaluation: decode → simulate → aggregate, fanned out over
//! OS threads with a canonical-key result cache.
//!
//! One *evaluation* of a genome runs `seeds × scenarios` independent
//! simulations (scenario presets model robustness to dynamic
//! conditions; an empty scenario list means one static run per seed)
//! and aggregates the report metrics by arithmetic mean.  Results are
//! cached keyed by the genome's canonical encoding, so designs the
//! search revisits — common once the population converges — cost
//! nothing.  Each pool thread pins one reusable
//! [`crate::sim::SimWorker`]: a genome's whole grid shares one decoded
//! [`crate::sim::SimSetup`], and the worker's buffers carry across
//! genomes.  Evaluations are deterministic functions of
//! (genome, config), which together with
//! [`crate::coordinator::parallel_map_pooled`]'s input-order result
//! placement makes a whole DSE generation bit-identical across thread
//! counts.  Larger designs (more PE instances) enter the pool first
//! via [`crate::coordinator::size_ordered_indices`] so a big decode
//! never lands last on an otherwise drained pool; results are
//! scattered back to canonical batch order.
//!
//! With an attached experiment store ([`Evaluator::set_store`]) the
//! batch additionally consults the on-disk point cache (kind
//! `dse-eval`) before simulating and records fresh evaluations back,
//! making interrupted searches resumable across processes.

use std::collections::{BTreeMap, BTreeSet};

use super::genome::{GenomeSpace, PlatformGenome};
use super::Objective;
use crate::app::AppGraph;
use crate::config::SimConfig;
use crate::coordinator::{
    parallel_map_pooled_outcomes, quarantine_guard, size_ordered_indices,
    FailPolicy, PointOutcome,
};
use crate::faultpoint;
use crate::scenario::Scenario;
use crate::sim::{SimSetup, SimWorker};
use crate::stats::FailureReport;
use crate::store::{point_key, PointEntry, StoreCtx};
use crate::telemetry::{config_hash, emit_global, Counters, Event};
use crate::util::json::Json;
use crate::{Error, Result};

/// Aggregated metrics of one genome evaluation (means over the
/// `seeds × scenarios` run grid).
#[derive(Debug, Clone, PartialEq)]
pub struct EvalMetrics {
    pub avg_latency_us: f64,
    pub p95_latency_us: f64,
    pub energy_per_job_mj: f64,
    pub peak_temp_c: f64,
    pub throughput_jobs_per_ms: f64,
    pub avg_power_w: f64,
    /// Mean completed/injected ratio — < 1 when a design saturates and
    /// hits the simulated-time wall.
    pub completed_frac: f64,
    /// Simulations aggregated into this record.
    pub runs: usize,
}

impl EvalMetrics {
    /// Objective value (lower is better).  Latency carries a completion
    /// penalty: a design that only finishes a fraction `f` of its
    /// offered load is scored `avg * (1 + 9(1-f))`, so saturated
    /// configurations rank strictly behind ones that keep up, without
    /// introducing non-finite values (which would not survive the JSON
    /// checkpoint round-trip).
    pub fn objective(&self, o: Objective) -> f64 {
        match o {
            Objective::Latency => {
                self.avg_latency_us
                    * (1.0 + 9.0 * (1.0 - self.completed_frac).max(0.0))
            }
            Objective::Energy => self.energy_per_job_mj,
            Objective::PeakTemp => self.peak_temp_c,
        }
    }

    pub fn objective_vector(&self, objectives: &[Objective]) -> Vec<f64> {
        objectives.iter().map(|&o| self.objective(o)).collect()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("avg_latency_us", Json::Num(self.avg_latency_us))
            .set("p95_latency_us", Json::Num(self.p95_latency_us))
            .set("energy_per_job_mj", Json::Num(self.energy_per_job_mj))
            .set("peak_temp_c", Json::Num(self.peak_temp_c))
            .set(
                "throughput_jobs_per_ms",
                Json::Num(self.throughput_jobs_per_ms),
            )
            .set("avg_power_w", Json::Num(self.avg_power_w))
            .set("completed_frac", Json::Num(self.completed_frac))
            .set("runs", Json::Num(self.runs as f64));
        j
    }

    pub fn from_json(j: &Json) -> Result<EvalMetrics> {
        Ok(EvalMetrics {
            avg_latency_us: j.req_f64("avg_latency_us")?,
            p95_latency_us: j.req_f64("p95_latency_us")?,
            energy_per_job_mj: j.req_f64("energy_per_job_mj")?,
            peak_temp_c: j.req_f64("peak_temp_c")?,
            throughput_jobs_per_ms: j.req_f64("throughput_jobs_per_ms")?,
            avg_power_w: j.req_f64("avg_power_w")?,
            completed_frac: j.req_f64("completed_frac")?,
            runs: j.req_f64("runs")? as usize,
        })
    }
}

/// Parallel, caching evaluator.
#[derive(Debug, Clone)]
pub struct Evaluator {
    base_cfg: SimConfig,
    seeds: Vec<u64>,
    scenarios: Vec<Scenario>,
    threads: usize,
    /// When true (the space explores the power-budget gene), the
    /// genome's `power_budget_w` fully owns the DTPM cap — `None`
    /// means *uncapped*, clearing any base-config cap.  When false the
    /// gene is pinned to `None` and the base config's cap stands.
    genome_owns_power_cap: bool,
    cache: BTreeMap<String, EvalMetrics>,
    /// Optional experiment store consulted before simulating.
    store: Option<StoreCtx>,
    /// What to do when one genome's evaluation panics, times out or
    /// errors ([`Evaluator::set_fail_policy`]; defaults to abort).
    fail_policy: FailPolicy,
    /// Genome evaluations requested (cache hits included).
    pub evals_requested: usize,
    /// Evaluations served from the cache.
    pub cache_hits: usize,
    /// Evaluations quarantined under [`FailPolicy::Quarantine`]: the
    /// design was scored with a finite worst-case surrogate (so the
    /// search dominates it away) and never written to the store.
    pub quarantined: usize,
    /// Evaluations served from the experiment store (counted neither
    /// as cache hits nor as simulations; not checkpointed — the store
    /// itself is the persistent record).
    pub store_hits: usize,
    /// Individual simulations executed.
    pub sims_run: usize,
}

impl Evaluator {
    pub fn new(
        base_cfg: SimConfig,
        seeds: Vec<u64>,
        scenarios: Vec<Scenario>,
        threads: usize,
        genome_owns_power_cap: bool,
    ) -> Result<Evaluator> {
        if seeds.is_empty() {
            return Err(Error::Config(
                "evaluator needs at least one seed".into(),
            ));
        }
        Ok(Evaluator {
            base_cfg,
            seeds,
            scenarios,
            threads: threads.max(1),
            genome_owns_power_cap,
            cache: BTreeMap::new(),
            store: None,
            fail_policy: FailPolicy::Abort,
            evals_requested: 0,
            cache_hits: 0,
            quarantined: 0,
            store_hits: 0,
            sims_run: 0,
        })
    }

    /// Choose what a failed genome evaluation does to the batch: abort
    /// the search (default) or quarantine the design behind a finite
    /// worst-case surrogate score.
    pub fn set_fail_policy(&mut self, policy: FailPolicy) {
        self.fail_policy = policy;
    }

    /// Attach (or detach) an experiment store: batch evaluation
    /// consults it before simulating and records fresh evaluations
    /// back under kind `dse-eval`.
    pub fn set_store(&mut self, store: Option<StoreCtx>) {
        self.store = store;
    }

    /// Content hash identifying one genome evaluation under this
    /// evaluator's grid — the `config_hash` component of the store
    /// point key, covering everything the metrics depend on: base
    /// config, seed/scenario grid, cap ownership and the genome's
    /// canonical encoding.
    fn eval_config_hash(&self, g: &PlatformGenome) -> String {
        let scenarios = Json::Arr(
            self.scenarios.iter().map(|s| s.to_json()).collect(),
        );
        config_hash(&format!(
            "dse-eval:{}:{:?}:{}:{}:{}",
            config_hash(&self.base_cfg.to_json().to_string()),
            self.seeds,
            scenarios.to_string(),
            self.genome_owns_power_cap,
            g.key(),
        ))
    }

    /// Simulations one (uncached) genome evaluation costs.
    pub fn runs_per_eval(&self) -> usize {
        self.seeds.len() * self.scenarios.len().max(1)
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Evaluate a batch of genomes, returning metrics in input order.
    /// Duplicate and previously seen genomes are served from the cache;
    /// the rest fan out over the evaluator's threads.
    pub fn evaluate_batch(
        &mut self,
        space: &GenomeSpace,
        apps: &[AppGraph],
        genomes: &[PlatformGenome],
    ) -> Result<Vec<EvalMetrics>> {
        let mut uncached: Vec<(String, PlatformGenome)> = Vec::new();
        let mut queued: BTreeSet<String> = BTreeSet::new();
        for g in genomes {
            let key = g.key();
            if !self.cache.contains_key(&key) && queued.insert(key.clone())
            {
                uncached.push((key, g.clone()));
            }
        }
        self.evals_requested += genomes.len();
        self.cache_hits += genomes.len() - uncached.len();

        // Consult the experiment store for designs the in-memory
        // cache misses; a hit enters the cache without costing a
        // simulation.  Lookups run serially in canonical batch order,
        // so the partition is identical across thread counts.
        if let Some(ctx) = self.store.clone() {
            let mut fresh_only = Vec::with_capacity(uncached.len());
            for (key, g) in uncached {
                let skey = point_key(
                    &self.eval_config_hash(&g),
                    &ctx.workload_digest,
                );
                let hit = ctx
                    .store
                    .lookup(&skey, "dse-eval")
                    .and_then(|e| EvalMetrics::from_json(&e.result).ok());
                match hit {
                    Some(m) => {
                        self.cache.insert(key, m);
                        self.store_hits += 1;
                    }
                    None => fresh_only.push((key, g)),
                }
            }
            uncached = fresh_only;
        }
        self.sims_run += uncached.len() * self.runs_per_eval();

        // Largest designs first (by total PE instances) so a heavy
        // decode never lands last on an otherwise drained pool; the
        // scatter below restores canonical batch order, keeping the
        // thread-count-invariance contract intact.
        let order = size_ordered_indices(&uncached, |(_, g)| {
            g.pe_counts.iter().map(|&c| c as u64).sum::<u64>()
        });
        let permuted: Vec<&(String, PlatformGenome)> =
            order.iter().map(|&i| &uncached[i]).collect();
        // One reusable SimWorker per pool thread: its buffers carry
        // across the whole seeds×scenarios grid of each genome AND
        // across the genomes the thread evaluates (the worker re-binds
        // to each genome's decoded-platform setup on reset).
        let pooled = parallel_map_pooled_outcomes(
            &permuted,
            self.threads,
            || None::<SimWorker>,
            |slot, _, entry| {
                faultpoint::fire_panic(
                    faultpoint::sites::SWEEP_POINT,
                    &entry.1.id(),
                );
                self.eval_one(space, apps, &entry.1, slot)
            },
        );
        let mut fresh: Vec<Option<PointOutcome<EvalMetrics>>> =
            uncached.iter().map(|_| None).collect();
        for (&i, r) in order.iter().zip(pooled) {
            fresh[i] = Some(r);
        }
        // Canonical-order triage.  A healthy eval enters the store and
        // the cache; a failed one either aborts the batch or — under
        // quarantine — is scored with a finite worst-case surrogate
        // (dominated by any design that actually ran) and is never
        // written to the store.
        let mut failures = FailureReport::new(uncached.len());
        for (i, ((key, g), m)) in
            uncached.iter().zip(fresh).enumerate()
        {
            let out = m.unwrap_or_else(|| {
                PointOutcome::Error(Error::Internal(format!(
                    "dse eval {i} not scattered back"
                )))
            });
            match out {
                PointOutcome::Ok(m) => {
                    if let Some(ctx) = &self.store {
                        let ch = self.eval_config_hash(g);
                        ctx.store.put_point(&PointEntry {
                            kind: "dse-eval".into(),
                            key: point_key(&ch, &ctx.workload_digest),
                            config_hash: ch,
                            workload_digest: ctx.workload_digest.clone(),
                            result: m.to_json(),
                            counters: Counters::new(),
                        })?;
                    }
                    self.cache.insert(key.clone(), m);
                }
                failure => {
                    let kind =
                        failure.failure_kind().unwrap_or("error");
                    let detail = failure.failure_detail();
                    if self.fail_policy.is_quarantine() {
                        self.quarantined += 1;
                        failures.record(i, g.id(), kind, detail);
                        self.cache.insert(
                            key.clone(),
                            self.quarantine_surrogate(),
                        );
                    } else {
                        return Err(Error::Sim(format!(
                            "evaluating design {}: {detail}",
                            g.id()
                        )));
                    }
                }
            }
        }
        quarantine_guard(&self.fail_policy, &failures)?;
        // Deterministic post-collection emission, canonical order.
        for p in &failures.failed {
            let (label, kind, detail) =
                (p.label.clone(), p.kind.clone(), p.detail.clone());
            emit_global(|| Event::PointFailed {
                what: "dse".to_string(),
                label,
                kind,
                detail,
            });
        }
        Ok(genomes
            .iter()
            .map(|g| self.cache[&g.key()].clone())
            .collect())
    }

    /// Finite worst-case metrics a quarantined design is scored with:
    /// every objective lands at (or beyond) the penalty a saturated
    /// design earns, `completed_frac` 0 engages the latency completion
    /// penalty, and every field survives the JSON checkpoint
    /// round-trip.  `runs == 0` marks the record as a surrogate.
    fn quarantine_surrogate(&self) -> EvalMetrics {
        EvalMetrics {
            avg_latency_us: self.base_cfg.max_sim_us,
            p95_latency_us: self.base_cfg.max_sim_us,
            energy_per_job_mj: 1e6,
            peak_temp_c: 1e3,
            throughput_jobs_per_ms: 0.0,
            avg_power_w: 0.0,
            completed_frac: 0.0,
            runs: 0,
        }
    }

    /// Decode and run the full `seeds × scenarios` grid for one genome
    /// on the calling thread's pinned worker (`slot`) — one setup build
    /// per genome instead of one per simulation.  Returns a
    /// [`PointOutcome`] so a step-budget timeout keeps its own verdict
    /// (a panic is caught one level up, in the pool).
    fn eval_one(
        &self,
        space: &GenomeSpace,
        apps: &[AppGraph],
        g: &PlatformGenome,
        slot: &mut Option<SimWorker>,
    ) -> PointOutcome<EvalMetrics> {
        let (platform, cap) = match space.decode(g) {
            Ok(v) => v,
            Err(e) => return PointOutcome::Error(e),
        };
        let setup = match SimSetup::with_owned_platform(
            platform,
            apps,
            &self.base_cfg,
        ) {
            Ok(s) => s,
            Err(e) => return PointOutcome::Error(e),
        };
        let mut acc = EvalMetrics {
            avg_latency_us: 0.0,
            p95_latency_us: 0.0,
            energy_per_job_mj: 0.0,
            peak_temp_c: 0.0,
            throughput_jobs_per_ms: 0.0,
            avg_power_w: 0.0,
            completed_frac: 0.0,
            runs: 0,
        };
        let scenario_slots: Vec<Option<&Scenario>> = if self
            .scenarios
            .is_empty()
        {
            vec![None]
        } else {
            self.scenarios.iter().map(Some).collect()
        };
        for &seed in &self.seeds {
            for &sc in &scenario_slots {
                let mut cfg = self.base_cfg.clone();
                cfg.seed = seed;
                // A grid scenario replaces the base config's; a `None`
                // slot (empty grid) leaves any base scenario in force.
                if sc.is_some() {
                    cfg.scenario = sc.cloned();
                }
                if self.genome_owns_power_cap {
                    // The gene is authoritative: `None` = uncapped,
                    // even when the base config carries a cap.
                    cfg.dtpm.power_cap_w = cap;
                }
                let worker = match SimWorker::obtain(slot, &setup, &cfg)
                {
                    Ok(w) => w,
                    Err(e) => return PointOutcome::Error(e),
                };
                let r = worker.run(&setup);
                if r.timed_out {
                    return PointOutcome::TimedOut {
                        steps: r.watchdog_steps,
                    };
                }
                let s = r.latency_summary();
                // A run with zero (post-warmup) completions would report
                // 0 latency / 0 energy-per-job and look falsely optimal;
                // substitute finite worst-case surrogates so such a
                // design is dominated, never preferred.
                if s.count == 0 || r.completed_jobs == 0 {
                    acc.avg_latency_us += cfg.max_sim_us;
                    acc.p95_latency_us += cfg.max_sim_us;
                    acc.energy_per_job_mj +=
                        (r.total_energy_j * 1e3).max(1e6);
                } else {
                    acc.avg_latency_us += s.mean;
                    acc.p95_latency_us += s.p95;
                    acc.energy_per_job_mj += r.energy_per_job_mj();
                }
                acc.peak_temp_c += r.peak_temp_c;
                acc.throughput_jobs_per_ms += r.throughput_jobs_per_ms();
                acc.avg_power_w += r.avg_power_w;
                debug_assert!(acc.avg_latency_us.is_finite());
                acc.completed_frac += if r.injected_jobs > 0 {
                    r.completed_jobs as f64 / r.injected_jobs as f64
                } else {
                    1.0
                };
                acc.runs += 1;
            }
        }
        let n = acc.runs.max(1) as f64;
        acc.avg_latency_us /= n;
        acc.p95_latency_us /= n;
        acc.energy_per_job_mj /= n;
        acc.peak_temp_c /= n;
        acc.throughput_jobs_per_ms /= n;
        acc.avg_power_w /= n;
        acc.completed_frac /= n;
        PointOutcome::Ok(acc)
    }

    /// Serialize the cache for checkpointing (sorted by canonical key,
    /// so the output is deterministic).
    pub fn cache_to_json(&self) -> Json {
        Json::Arr(
            self.cache
                .iter()
                .map(|(key, m)| {
                    let mut e = Json::obj();
                    // The key IS the canonical genome encoding; parse it
                    // back so checkpoints stay human-readable.
                    e.set(
                        "genome",
                        Json::parse(key).expect("cache key is valid JSON"),
                    )
                    .set("metrics", m.to_json());
                    e
                })
                .collect(),
        )
    }

    /// Restore the cache from a checkpoint (inverse of
    /// [`Self::cache_to_json`]).
    pub fn cache_from_json(&mut self, j: &Json) -> Result<()> {
        let entries = j.as_arr().ok_or_else(|| {
            Error::Config("checkpoint cache must be an array".into())
        })?;
        for e in entries {
            let g = PlatformGenome::from_json(
                e.get("genome").ok_or_else(|| {
                    Error::Config("cache entry missing genome".into())
                })?,
            )?;
            let m = EvalMetrics::from_json(
                e.get("metrics").ok_or_else(|| {
                    Error::Config("cache entry missing metrics".into())
                })?,
            )?;
            self.cache.insert(g.key(), m);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::suite::{self, WifiParams};
    use crate::platform::Platform;

    fn small_space() -> GenomeSpace {
        GenomeSpace::new(
            Platform::table2_soc(),
            1,
            6,
            (0.02, 0.2),
            (2000.0, 16000.0),
            (3.0, 10.0),
            true,
        )
        .unwrap()
    }

    fn small_cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.max_jobs = 30;
        c.warmup_jobs = 3;
        c.injection_rate_per_ms = 2.0;
        c
    }

    #[test]
    fn cache_hits_skip_simulation() {
        let space = small_space();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let mut ev =
            Evaluator::new(small_cfg(), vec![1, 2], vec![], 2, true).unwrap();
        assert_eq!(ev.runs_per_eval(), 2);
        let g = space.seed_genome();
        let batch = vec![g.clone(), g.clone()];
        let r1 = ev.evaluate_batch(&space, &apps, &batch).unwrap();
        assert_eq!(r1.len(), 2);
        assert_eq!(r1[0], r1[1]);
        assert_eq!(ev.sims_run, 2); // one unique genome x two seeds
        assert_eq!(ev.cache_hits, 1);
        let sims_before = ev.sims_run;
        let r2 = ev
            .evaluate_batch(&space, &apps, std::slice::from_ref(&g))
            .unwrap();
        assert_eq!(ev.sims_run, sims_before, "second batch fully cached");
        assert_eq!(ev.cache_hits, 2);
        assert_eq!(r2[0], r1[0]);
    }

    #[test]
    fn parallel_batch_matches_serial() {
        let space = small_space();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let mut rng = crate::rng::Rng::new(11);
        let genomes: Vec<_> =
            (0..6).map(|_| space.random(&mut rng)).collect();
        let mut serial =
            Evaluator::new(small_cfg(), vec![7], vec![], 1, true).unwrap();
        let mut par =
            Evaluator::new(small_cfg(), vec![7], vec![], 8, true).unwrap();
        let a = serial.evaluate_batch(&space, &apps, &genomes).unwrap();
        let b = par.evaluate_batch(&space, &apps, &genomes).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn scenario_presets_enter_the_grid() {
        let space = small_space();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let sc = crate::scenario::presets::pe_failure();
        let mut ev =
            Evaluator::new(small_cfg(), vec![1], vec![sc], 2, true)
                .unwrap();
        assert_eq!(ev.runs_per_eval(), 1);
        let g = space.seed_genome();
        let m = ev
            .evaluate_batch(&space, &apps, std::slice::from_ref(&g))
            .unwrap();
        assert_eq!(m[0].runs, 1);
        assert!(m[0].avg_latency_us > 0.0);
    }

    #[test]
    fn genome_power_gene_owns_the_cap() {
        let space = small_space();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let mut capped_base = small_cfg();
        capped_base.dtpm.power_cap_w = Some(1.0);

        // Gene None + owning evaluator == no cap at all: the gene
        // clears the base-config cap.
        let mut owns =
            Evaluator::new(capped_base.clone(), vec![1], vec![], 1, true)
                .unwrap();
        let mut uncapped_ref =
            Evaluator::new(small_cfg(), vec![1], vec![], 1, true).unwrap();
        let g = space.seed_genome();
        let a = owns
            .evaluate_batch(&space, &apps, std::slice::from_ref(&g))
            .unwrap();
        let b = uncapped_ref
            .evaluate_batch(&space, &apps, std::slice::from_ref(&g))
            .unwrap();
        assert_eq!(a, b, "gene None must clear the base cap");

        // Gene Some(w) == base-config cap w under a pinned space.
        let mut g_capped = space.seed_genome();
        g_capped.power_budget_w = Some(1.0);
        let x = owns
            .evaluate_batch(&space, &apps, std::slice::from_ref(&g_capped))
            .unwrap();
        let mut pinned =
            Evaluator::new(capped_base, vec![1], vec![], 1, false)
                .unwrap();
        let y = pinned
            .evaluate_batch(&space, &apps, std::slice::from_ref(&g))
            .unwrap();
        assert_eq!(x, y, "gene Some(w) must equal a base cap of w");
    }

    #[test]
    fn metrics_json_roundtrip() {
        let m = EvalMetrics {
            avg_latency_us: 123.456,
            p95_latency_us: 234.5,
            energy_per_job_mj: 1.25,
            peak_temp_c: 61.5,
            throughput_jobs_per_ms: 3.9,
            avg_power_w: 4.25,
            completed_frac: 0.975,
            runs: 4,
        };
        let j = Json::parse(&m.to_json().to_string()).unwrap();
        assert_eq!(EvalMetrics::from_json(&j).unwrap(), m);
    }

    #[test]
    fn cache_roundtrips_through_json() {
        let space = small_space();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let mut ev =
            Evaluator::new(small_cfg(), vec![3], vec![], 2, true).unwrap();
        let mut rng = crate::rng::Rng::new(13);
        let genomes: Vec<_> =
            (0..4).map(|_| space.random(&mut rng)).collect();
        let res = ev.evaluate_batch(&space, &apps, &genomes).unwrap();
        let j = Json::parse(&ev.cache_to_json().to_string()).unwrap();
        let mut ev2 =
            Evaluator::new(small_cfg(), vec![3], vec![], 2, true).unwrap();
        ev2.cache_from_json(&j).unwrap();
        assert_eq!(ev2.cache_len(), ev.cache_len());
        // Re-evaluating from the restored cache runs zero simulations.
        let res2 = ev2.evaluate_batch(&space, &apps, &genomes).unwrap();
        assert_eq!(ev2.sims_run, 0);
        assert_eq!(res, res2);
    }

    #[test]
    fn store_round_trip_skips_simulation() {
        let dir =
            std::env::temp_dir().join("ds3r_dse_eval_store_test");
        let _ = std::fs::remove_dir_all(&dir);
        let store = crate::store::ExperimentStore::open(&dir).unwrap();
        let ctx = StoreCtx {
            store,
            workload_digest: "wd-test".into(),
        };
        let space = small_space();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let mut rng = crate::rng::Rng::new(5);
        let genomes: Vec<_> =
            (0..3).map(|_| space.random(&mut rng)).collect();
        let unique: BTreeSet<String> =
            genomes.iter().map(|g| g.key()).collect();

        let mut cold =
            Evaluator::new(small_cfg(), vec![1], vec![], 2, true)
                .unwrap();
        cold.set_store(Some(ctx.clone()));
        let a = cold.evaluate_batch(&space, &apps, &genomes).unwrap();
        assert_eq!(cold.store_hits, 0);
        assert!(cold.sims_run > 0);

        // A brand-new evaluator (empty in-memory cache) over the same
        // store replays every metric without simulating a thing.
        let mut warm =
            Evaluator::new(small_cfg(), vec![1], vec![], 2, true)
                .unwrap();
        warm.set_store(Some(ctx));
        let b = warm.evaluate_batch(&space, &apps, &genomes).unwrap();
        assert_eq!(warm.sims_run, 0, "warm store must skip all sims");
        assert_eq!(warm.store_hits, unique.len());
        assert_eq!(a, b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quarantined_eval_scores_worst_case_and_skips_store() {
        let space = small_space();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let mut rng = crate::rng::Rng::new(21);
        let genomes: Vec<_> =
            (0..3).map(|_| space.random(&mut rng)).collect();
        // Arm a panic against exactly one design's id — unique enough
        // that concurrently running tests cannot trip it.
        let bad = genomes[1].id();
        let _g = faultpoint::Armed::new(
            faultpoint::sites::SWEEP_POINT,
            &bad,
            faultpoint::Fault::Panic,
        );
        let dir =
            std::env::temp_dir().join("ds3r_dse_quarantine_test");
        let _ = std::fs::remove_dir_all(&dir);
        let store = crate::store::ExperimentStore::open(&dir).unwrap();
        let ctx = StoreCtx {
            store,
            workload_digest: "wd-test".into(),
        };

        // Default (abort) policy: the injected panic fails the batch.
        let mut ev =
            Evaluator::new(small_cfg(), vec![1], vec![], 2, true)
                .unwrap();
        ev.set_store(Some(ctx.clone()));
        let err =
            ev.evaluate_batch(&space, &apps, &genomes).unwrap_err();
        assert!(
            err.to_string().contains(&bad),
            "abort error must name the design: {err}"
        );

        // Quarantine: the bad design gets the dominated surrogate.
        let mut ev2 =
            Evaluator::new(small_cfg(), vec![1], vec![], 2, true)
                .unwrap();
        ev2.set_store(Some(ctx.clone()));
        ev2.set_fail_policy(FailPolicy::Quarantine {
            max_failures: None,
        });
        let m = ev2.evaluate_batch(&space, &apps, &genomes).unwrap();
        assert_eq!(ev2.quarantined, 1);
        assert_eq!(m[1].runs, 0, "surrogate marks itself");
        assert!(
            m[1].objective(Objective::Latency)
                > m[0].objective(Objective::Latency),
            "surrogate must be dominated"
        );

        // A fresh evaluator over the same store: only the two healthy
        // designs were recorded, the quarantined one re-simulates (and
        // — still armed — quarantines again).
        let mut warm =
            Evaluator::new(small_cfg(), vec![1], vec![], 2, true)
                .unwrap();
        warm.set_store(Some(ctx));
        warm.set_fail_policy(FailPolicy::Quarantine {
            max_failures: None,
        });
        let m2 = warm.evaluate_batch(&space, &apps, &genomes).unwrap();
        assert_eq!(
            warm.store_hits, 2,
            "failed evals must never be cached"
        );
        assert_eq!(warm.quarantined, 1);
        assert_eq!(m2, m);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latency_objective_penalizes_incomplete_runs() {
        let mut m = EvalMetrics {
            avg_latency_us: 100.0,
            p95_latency_us: 0.0,
            energy_per_job_mj: 1.0,
            peak_temp_c: 50.0,
            throughput_jobs_per_ms: 1.0,
            avg_power_w: 1.0,
            completed_frac: 1.0,
            runs: 1,
        };
        assert_eq!(m.objective(Objective::Latency), 100.0);
        m.completed_frac = 0.5;
        assert!(m.objective(Objective::Latency) > 100.0);
        assert!(m.objective(Objective::Latency).is_finite());
        assert_eq!(m.objective(Objective::Energy), 1.0);
        assert_eq!(m.objective(Objective::PeakTemp), 50.0);
    }
}
