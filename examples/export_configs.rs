//! Export the built-in Table-2 platform, a Figure-3 experiment point,
//! and the scenario preset library as JSON config files (written to
//! `configs/`): the starting point for defining your own DSSoC
//! candidates and dynamic scenarios without recompiling.
//!
//! ```sh
//! cargo run --release --example export_configs
//! ds3r run --platform configs/table2_platform.json \
//!          --config configs/fig3_point.json
//! ds3r run --scenario configs/scenarios/pe-failure.json
//! ```

fn main() {
    std::fs::create_dir_all("configs/scenarios").expect("mkdir configs");

    let p = ds3r::platform::Platform::table2_soc();
    std::fs::write(
        "configs/table2_platform.json",
        p.to_json().to_string_pretty(),
    )
    .expect("write platform");

    let mut cfg = ds3r::config::SimConfig::default();
    cfg.scheduler = "etf".into();
    cfg.injection_rate_per_ms = 5.0;
    cfg.max_jobs = 1000;
    cfg.warmup_jobs = 100;
    cfg.dtpm.governor = "ondemand".into();
    cfg.save(std::path::Path::new("configs/fig3_point.json"))
        .expect("write experiment config");
    println!(
        "wrote configs/table2_platform.json and configs/fig3_point.json"
    );

    // Every scenario preset, ready to copy and edit.
    for sc in ds3r::scenario::presets::all() {
        let path = format!("configs/scenarios/{}.json", sc.name);
        sc.save(std::path::Path::new(&path)).expect("write scenario");
        println!("wrote {path}");
    }

    // A dynamic experiment point: the Figure-3 workload under a bursty
    // arrival scenario, as one self-contained config file.
    let mut dynamic = cfg.clone();
    dynamic.injection_rate_per_ms = 1.0;
    dynamic.scenario = Some(ds3r::scenario::presets::bursty_wifi());
    dynamic
        .save(std::path::Path::new("configs/bursty_point.json"))
        .expect("write dynamic experiment config");
    println!("wrote configs/bursty_point.json");
}
