//! Platform presets: the paper's evaluation SoCs.
//!
//! * [`table2_soc`] — the scheduling-case-study configuration of Table 2:
//!   4×Cortex-A15, 4×Cortex-A7, 2×Scrambler-Encoder accelerators and
//!   4×FFT accelerators (14 PEs total), mimicking an Odroid-XU3-class
//!   big.LITTLE part extended with domain accelerators.
//! * [`zcu102_soc`] — a Zynq-UltraScale-class variant (4 big cores +
//!   programmable-logic accelerators) used by the validation flow.
//!
//! OPP tables follow the Exynos 5422 (Odroid-XU3) frequency/voltage
//! ladder; power coefficients are fitted so peak powers land in the
//! published ranges (A15 ≈ 1.8-2 W/core @ 2 GHz, A7 ≈ 0.3 W/core,
//! accelerators ≈ 50-100 mW) — see DESIGN.md §Substitutions.

use super::{
    Cluster, NocParams, Opp, Pe, PeClass, PeType, Platform, ThermalFloorplan,
};

/// Exynos-5422-style big-core OPP ladder (MHz, V).
fn a15_opps() -> Vec<Opp> {
    [
        (200.0, 0.90),
        (400.0, 0.92),
        (600.0, 0.95),
        (800.0, 0.98),
        (1000.0, 1.02),
        (1200.0, 1.06),
        (1400.0, 1.10),
        (1600.0, 1.16),
        (1800.0, 1.23),
        (2000.0, 1.31),
    ]
    .iter()
    .map(|&(f, v)| Opp { freq_mhz: f, volt: v })
    .collect()
}

/// LITTLE-core ladder.
fn a7_opps() -> Vec<Opp> {
    [
        (200.0, 0.90),
        (400.0, 0.92),
        (600.0, 0.95),
        (800.0, 1.00),
        (1000.0, 1.05),
        (1200.0, 1.12),
        (1400.0, 1.20),
    ]
    .iter()
    .map(|&(f, v)| Opp { freq_mhz: f, volt: v })
    .collect()
}

fn classes() -> Vec<PeClass> {
    vec![
        PeClass {
            name: "A15".into(),
            ty: PeType::BigCore,
            nominal_mhz: 2000.0,
            opps: a15_opps(),
            // 2000 MHz * 1.31^2 V^2 * ceff = ~1.9 W  => ceff ≈ 5.5e-4
            ceff: 5.5e-4,
            leak_k1: 0.0075,
            leak_k2: 0.025,
        },
        PeClass {
            name: "A7".into(),
            ty: PeType::LittleCore,
            nominal_mhz: 1400.0,
            opps: a7_opps(),
            // 1400 MHz * 1.2^2 * ceff = ~0.3 W => ceff ≈ 1.5e-4
            ceff: 1.5e-4,
            leak_k1: 0.0020,
            leak_k2: 0.020,
        },
        PeClass {
            name: "ACC_SCR".into(),
            ty: PeType::Accelerator,
            nominal_mhz: 600.0,
            opps: vec![Opp { freq_mhz: 600.0, volt: 0.85 }],
            // 600 MHz * 0.85^2 * ceff = ~60 mW => ceff ≈ 1.4e-4
            ceff: 1.4e-4,
            leak_k1: 0.0005,
            leak_k2: 0.015,
        },
        PeClass {
            name: "ACC_FFT".into(),
            ty: PeType::Accelerator,
            nominal_mhz: 600.0,
            opps: vec![Opp { freq_mhz: 600.0, volt: 0.85 }],
            // FFT engines are larger: ~100 mW peak.
            ceff: 2.3e-4,
            leak_k1: 0.0008,
            leak_k2: 0.015,
        },
    ]
}

/// Thermal floorplan shared by both presets: one node per power island
/// plus interconnect and a memory-controller node.
fn floorplan() -> ThermalFloorplan {
    // Nodes: 0=big cluster, 1=LITTLE cluster, 2=scrambler island,
    //        3=FFT island, 4=NoC, 5=memory controller.
    let names = ["big", "LITTLE", "scr_island", "fft_island", "noc", "mem"];
    ThermalFloorplan {
        node_names: names.iter().map(|s| s.to_string()).collect(),
        // J/°C — silicon islands are small; big cluster has the largest
        // area hence the largest capacitance.
        capacitance: vec![0.35, 0.20, 0.06, 0.12, 0.08, 0.15],
        // W/°C to ambient through package/heat-spreader.
        g_amb: vec![0.12, 0.08, 0.02, 0.04, 0.03, 0.05],
        couplings: vec![
            (0, 1, 0.30), // big <-> LITTLE share the die centre
            (0, 4, 0.15),
            (1, 4, 0.12),
            (2, 4, 0.08),
            (3, 4, 0.10),
            (2, 3, 0.06),
            (4, 5, 0.10),
            (0, 3, 0.08), // big sits next to the FFT island
            (1, 2, 0.05),
        ],
    }
}

/// The Table-2 scheduling-case-study SoC: 14 PEs on a 4x4 mesh.
///
/// Mesh placement (x, y):
/// ```text
///   y=3 | A15-0  A15-1  A15-2  A15-3
///   y=2 | A7-0   A7-1   A7-2   A7-3
///   y=1 | SCR-0  SCR-1  FFT-0  FFT-1
///   y=0 | FFT-2  FFT-3  (mem)  (noc)
/// ```
pub fn table2_soc() -> Platform {
    let classes = classes();
    let mut pes = Vec::new();
    let mut clusters = Vec::new();

    let add_cluster =
        |name: &str,
         class: usize,
         thermal_node: usize,
         coords: &[(usize, usize)],
         pes: &mut Vec<Pe>,
         clusters: &mut Vec<Cluster>| {
            let id = clusters.len();
            let mut pe_ids = Vec::new();
            for (i, &(x, y)) in coords.iter().enumerate() {
                let pe_id = pes.len();
                pes.push(Pe {
                    id: pe_id,
                    class,
                    cluster: id,
                    name: format!("{name}-{i}"),
                    x,
                    y,
                });
                pe_ids.push(pe_id);
            }
            clusters.push(Cluster {
                id,
                name: name.to_string(),
                class,
                pe_ids,
                thermal_node,
            });
        };

    add_cluster(
        "A15",
        0,
        0,
        &[(0, 3), (1, 3), (2, 3), (3, 3)],
        &mut pes,
        &mut clusters,
    );
    add_cluster(
        "A7",
        1,
        1,
        &[(0, 2), (1, 2), (2, 2), (3, 2)],
        &mut pes,
        &mut clusters,
    );
    add_cluster(
        "ACC_SCR",
        2,
        2,
        &[(0, 1), (1, 1)],
        &mut pes,
        &mut clusters,
    );
    add_cluster(
        "ACC_FFT",
        3,
        3,
        &[(2, 1), (3, 1), (0, 0), (1, 0)],
        &mut pes,
        &mut clusters,
    );

    Platform::new(
        "table2-dssoc",
        classes,
        pes,
        clusters,
        NocParams::default(),
        floorplan(),
    )
    .expect("table2 preset is valid")
}

/// Zynq-ZCU102-class validation platform: 4 big cores (Cortex-A53-like,
/// modeled with the A15 class), no LITTLE cluster, and a larger
/// programmable-logic accelerator pool (2 scrambler + 6 FFT).
pub fn zcu102_soc() -> Platform {
    let classes = classes();
    let mut pes = Vec::new();
    let mut clusters = Vec::new();
    let push =
        |name: &str, class: usize, node: usize, coords: &[(usize, usize)],
         pes: &mut Vec<Pe>, clusters: &mut Vec<Cluster>| {
            let id = clusters.len();
            let mut pe_ids = Vec::new();
            for (i, &(x, y)) in coords.iter().enumerate() {
                let pe_id = pes.len();
                pes.push(Pe {
                    id: pe_id,
                    class,
                    cluster: id,
                    name: format!("{name}-{i}"),
                    x,
                    y,
                });
                pe_ids.push(pe_id);
            }
            clusters.push(Cluster {
                id,
                name: name.to_string(),
                class,
                pe_ids,
                thermal_node: node,
            });
        };

    push(
        "A53",
        0,
        0,
        &[(0, 3), (1, 3), (2, 3), (3, 3)],
        &mut pes,
        &mut clusters,
    );
    push("ACC_SCR", 2, 2, &[(0, 1), (1, 1)], &mut pes, &mut clusters);
    push(
        "ACC_FFT",
        3,
        3,
        &[(2, 1), (3, 1), (0, 0), (1, 0), (2, 0), (3, 0)],
        &mut pes,
        &mut clusters,
    );

    Platform::new(
        "zcu102-dssoc",
        classes,
        pes,
        clusters,
        NocParams::default(),
        floorplan(),
    )
    .expect("zcu102 preset is valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zcu102_has_more_fft_engines() {
        let p = zcu102_soc();
        let fft = p
            .inventory()
            .into_iter()
            .find(|(n, _, _)| n == "ACC_FFT")
            .unwrap();
        assert_eq!(fft.2, 6);
        assert_eq!(p.n_pes(), 12);
    }

    #[test]
    fn peak_powers_in_published_ranges() {
        let p = table2_soc();
        let big = &p.classes[p.class_index("A15").unwrap()];
        let peak =
            big.ceff * big.max_opp().volt.powi(2) * big.max_opp().freq_mhz;
        assert!(
            (1.5..2.5).contains(&peak),
            "A15 peak {peak} W out of range"
        );
        let little = &p.classes[p.class_index("A7").unwrap()];
        let peak_l = little.ceff
            * little.max_opp().volt.powi(2)
            * little.max_opp().freq_mhz;
        assert!(
            (0.2..0.5).contains(&peak_l),
            "A7 peak {peak_l} W out of range"
        );
        for acc in ["ACC_SCR", "ACC_FFT"] {
            let c = &p.classes[p.class_index(acc).unwrap()];
            let pk = c.ceff * c.max_opp().volt.powi(2) * c.max_opp().freq_mhz;
            assert!(pk < 0.2, "{acc} peak {pk} W too high");
        }
    }

    #[test]
    fn floorplan_is_connected() {
        // Union-find over couplings: every node must reach node 0.
        let fp = floorplan();
        let n = fp.len();
        let mut parent: Vec<usize> = (0..n).collect();
        fn find(p: &mut Vec<usize>, i: usize) -> usize {
            if p[i] != i {
                let r = find(p, p[i]);
                p[i] = r;
            }
            p[i]
        }
        for &(i, j, _) in &fp.couplings {
            let ri = find(&mut parent, i);
            let rj = find(&mut parent, j);
            parent[ri] = rj;
        }
        let root = find(&mut parent, 0);
        for i in 1..n {
            assert_eq!(find(&mut parent, i), root, "node {i} disconnected");
        }
    }

    #[test]
    fn mesh_coordinates_unique() {
        let p = table2_soc();
        let mut coords: Vec<(usize, usize)> =
            p.pes.iter().map(|pe| (pe.x, pe.y)).collect();
        coords.sort();
        let before = coords.len();
        coords.dedup();
        assert_eq!(coords.len(), before, "two PEs share a mesh tile");
    }
}
