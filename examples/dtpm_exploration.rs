//! DTPM design-space exploration: compare DVFS governors and thermal
//! policies on a radar workload, reporting the latency / energy /
//! temperature trade-off — the framework capability the paper motivates
//! beyond scheduling ("evaluating both scheduling and dynamic
//! thermal-power management algorithms").
//!
//! Set `DS3R_ARTIFACTS` (or run from the repo root after
//! `make artifacts`) to step the thermal model through the AOT
//! JAX/Pallas artifact via PJRT; otherwise the native path is used.
//!
//! ```sh
//! cargo run --release --example dtpm_exploration
//! ```

use ds3r::app::suite::{self, RadarParams};
use ds3r::config::SimConfig;
use ds3r::platform::Platform;
use ds3r::sim::Simulation;
use ds3r::util::plot;

fn main() {
    let platform = Platform::table2_soc();
    let apps = vec![
        suite::pulse_doppler(RadarParams::default()),
        suite::range_detection(RadarParams::default()),
    ];

    let use_xla = ds3r::runtime::artifacts_available(
        &ds3r::runtime::default_artifacts_dir(),
    );
    if use_xla {
        println!("thermal model: AOT JAX/Pallas artifact via PJRT\n");
    } else {
        println!("thermal model: native rust path (run `make artifacts` \
                  to use the PJRT artifact)\n");
    }

    let mut rows = Vec::new();
    for (governor, throttle) in [
        ("performance", false),
        ("performance", true),
        ("ondemand", false),
        ("ondemand", true),
        ("powersave", false),
    ] {
        let mut cfg = SimConfig::default();
        cfg.scheduler = "etf".into();
        cfg.injection_rate_per_ms = 1.2;
        cfg.max_jobs = 800;
        cfg.warmup_jobs = 80;
        cfg.dtpm.governor = governor.into();
        cfg.dtpm.thermal_throttle = throttle;
        cfg.dtpm.throttle_temp_c = 70.0;
        cfg.capture_traces = true;
        cfg.use_xla_thermal = use_xla;

        let r = Simulation::build(&platform, &apps, &cfg)
            .expect("valid config")
            .run();
        rows.push(vec![
            format!(
                "{governor}{}",
                if throttle { "+throttle@70C" } else { "" }
            ),
            format!("{:.1}", r.avg_job_latency_us()),
            format!("{:.2}", r.avg_power_w),
            format!("{:.2}", r.energy_per_job_mj()),
            format!("{:.1}", r.peak_temp_c),
            format!("{}", r.throttle_engagements),
        ]);
    }
    println!(
        "{}",
        plot::ascii_table(
            &[
                "policy",
                "avg latency us",
                "avg power W",
                "mJ/job",
                "peak temp C",
                "throttles"
            ],
            &rows
        )
    );

    // Temperature trace for the ondemand run (illustrates the RC model).
    let mut cfg = SimConfig::default();
    cfg.scheduler = "etf".into();
    cfg.injection_rate_per_ms = 1.2;
    cfg.max_jobs = 400;
    cfg.warmup_jobs = 0;
    cfg.dtpm.governor = "ondemand".into();
    cfg.capture_traces = true;
    let r = Simulation::build(&platform, &apps, &cfg).unwrap().run();
    let mut big = plot::Series::new("big-cluster C");
    let mut mhz = plot::Series::new("big MHz/100");
    for tr in &r.trace {
        big.push(tr.t_us / 1000.0, tr.temps_c[0]);
        mhz.push(tr.t_us / 1000.0, tr.cluster_mhz[0] / 100.0);
    }
    println!(
        "{}",
        plot::ascii_chart(
            "ondemand: big-cluster temperature + frequency over time",
            "ms",
            "C / (MHz/100)",
            &[big, mhz],
            72,
            16
        )
    );
}
