//! Query layer over stored manifests (`ds3r query`).
//!
//! Filters select manifests by identity (scheduler / seed / config
//! hash / command kind); aggregations reduce one named counter across
//! the selection (count / mean / p95 / worst).  Renderers emit either
//! JSONL (one full manifest per line, machine-consumable) or an ascii
//! table (human-scannable).  Everything here is a pure function of
//! store content, so query output is as deterministic as the store
//! itself.

use super::manifest::Manifest;
use crate::stats::QueryAggregate;
use crate::util::json::Json;
use crate::util::{percentile_sorted, plot};
use crate::{Error, Result};

/// Identity predicates over stored manifests; `None` fields match
/// everything.
#[derive(Debug, Clone, Default)]
pub struct QueryFilter {
    pub scheduler: Option<String>,
    pub seed: Option<u64>,
    pub config_hash: Option<String>,
    /// Campaign kind — the manifest's `cmd` (`run`, `sweep`, `fuzz`,
    /// `dse-run`, ...).
    pub kind: Option<String>,
}

impl QueryFilter {
    pub fn matches(&self, m: &Manifest) -> bool {
        self.scheduler
            .as_ref()
            .is_none_or(|s| *s == m.scheduler)
            && self.seed.is_none_or(|s| s == m.seed)
            && self
                .config_hash
                .as_ref()
                .is_none_or(|h| *h == m.config_hash)
            && self.kind.as_ref().is_none_or(|k| *k == m.cmd)
    }

    /// Apply the filter, preserving input (index) order.
    pub fn select<'a>(
        &self,
        manifests: &'a [Manifest],
    ) -> Vec<&'a Manifest> {
        manifests.iter().filter(|m| self.matches(m)).collect()
    }
}

/// Aggregation over one named counter of the selected manifests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Agg {
    /// Number of matching manifests (ignores the field).
    Count,
    /// Mean of the field across matches.
    Mean,
    /// Linear-interpolated 95th percentile of the field.
    P95,
    /// Maximum of the field across matches.
    Worst,
}

impl Agg {
    pub fn parse(s: &str) -> Result<Agg> {
        match s {
            "count" => Ok(Agg::Count),
            "mean" => Ok(Agg::Mean),
            "p95" => Ok(Agg::P95),
            "worst" => Ok(Agg::Worst),
            other => Err(Error::Config(format!(
                "unknown aggregation '{other}' (count, mean, p95, worst)"
            ))),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Agg::Count => "count",
            Agg::Mean => "mean",
            Agg::P95 => "p95",
            Agg::Worst => "worst",
        }
    }
}

/// Reduce `field` (a counter name) across the selection.
pub fn aggregate(
    selected: &[&Manifest],
    field: &str,
    agg: Agg,
) -> QueryAggregate {
    let mut xs: Vec<f64> = selected
        .iter()
        .map(|m| m.counters.get(field) as f64)
        .collect();
    xs.sort_by(f64::total_cmp);
    let value = match agg {
        Agg::Count => selected.len() as f64,
        Agg::Mean => {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        }
        Agg::P95 => percentile_sorted(&xs, 0.95),
        Agg::Worst => xs.last().copied().unwrap_or(0.0),
    };
    QueryAggregate {
        field: field.to_string(),
        agg: agg.label().to_string(),
        count: selected.len(),
        value,
    }
}

/// One compact JSON manifest per line — `ds3r query --format jsonl`.
pub fn render_jsonl(selected: &[&Manifest]) -> String {
    let mut out = String::new();
    for m in selected {
        out.push_str(&m.to_json().to_string());
        out.push('\n');
    }
    out
}

/// Human-scannable ascii table — the default `ds3r query` rendering.
pub fn render_table(selected: &[&Manifest]) -> String {
    let rows: Vec<Vec<String>> = selected
        .iter()
        .map(|m| {
            vec![
                m.key(),
                m.cmd.clone(),
                m.scheduler.clone(),
                m.seed.to_string(),
                m.config_hash.clone(),
                m.workload_digest.clone(),
                m.counters.get("runs").to_string(),
                m.counters.get("completed_jobs").to_string(),
            ]
        })
        .collect();
    plot::ascii_table(
        &[
            "key",
            "cmd",
            "scheduler",
            "seed",
            "config",
            "workload",
            "runs",
            "jobs",
        ],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Counters;

    fn manifest(
        cmd: &str,
        scheduler: &str,
        seed: u64,
        jobs: u64,
    ) -> Manifest {
        let mut counters = Counters::new();
        counters.add("runs", 1);
        counters.add("completed_jobs", jobs);
        Manifest {
            cmd: cmd.into(),
            config_hash: format!("hash-{cmd}"),
            workload_digest: "wd".into(),
            seed,
            scheduler: scheduler.into(),
            git: None,
            counters,
            point_keys: Vec::new(),
            result: Json::Null,
        }
    }

    fn corpus() -> Vec<Manifest> {
        vec![
            manifest("sweep", "etf", 1, 100),
            manifest("sweep", "met", 1, 300),
            manifest("sweep", "etf", 2, 200),
            manifest("fuzz", "etf", 1, 50),
        ]
    }

    #[test]
    fn filters_compose_and_preserve_order() {
        let ms = corpus();
        let all = QueryFilter::default().select(&ms);
        assert_eq!(all.len(), 4);
        let etf = QueryFilter {
            scheduler: Some("etf".into()),
            ..Default::default()
        }
        .select(&ms);
        assert_eq!(etf.len(), 3);
        assert_eq!(etf[0].seed, 1);
        assert_eq!(etf[1].seed, 2);
        let narrow = QueryFilter {
            scheduler: Some("etf".into()),
            seed: Some(1),
            kind: Some("sweep".into()),
            ..Default::default()
        }
        .select(&ms);
        assert_eq!(narrow.len(), 1);
        assert_eq!(narrow[0].counters.get("completed_jobs"), 100);
        let by_hash = QueryFilter {
            config_hash: Some("hash-fuzz".into()),
            ..Default::default()
        }
        .select(&ms);
        assert_eq!(by_hash.len(), 1);
        assert_eq!(by_hash[0].cmd, "fuzz");
    }

    #[test]
    fn aggregations_reduce_counters() {
        let ms = corpus();
        let sel = QueryFilter {
            kind: Some("sweep".into()),
            ..Default::default()
        }
        .select(&ms);
        let a = aggregate(&sel, "completed_jobs", Agg::Count);
        assert_eq!(a.count, 3);
        assert_eq!(a.value, 3.0);
        let a = aggregate(&sel, "completed_jobs", Agg::Mean);
        assert_eq!(a.value, 200.0);
        let a = aggregate(&sel, "completed_jobs", Agg::Worst);
        assert_eq!(a.value, 300.0);
        let a = aggregate(&sel, "completed_jobs", Agg::P95);
        assert!(a.value > 200.0 && a.value <= 300.0, "{}", a.value);
        // Empty selection is well-defined.
        let none: Vec<&Manifest> = Vec::new();
        assert_eq!(aggregate(&none, "runs", Agg::Mean).value, 0.0);
    }

    #[test]
    fn agg_parse_rejects_unknown() {
        assert_eq!(Agg::parse("p95").unwrap(), Agg::P95);
        assert!(Agg::parse("median").is_err());
    }

    #[test]
    fn renderers_cover_every_selected_manifest() {
        let ms = corpus();
        let sel = QueryFilter::default().select(&ms);
        let jsonl = render_jsonl(&sel);
        assert_eq!(jsonl.lines().count(), 4);
        for line in jsonl.lines() {
            let j = Json::parse(line).unwrap();
            assert_eq!(
                j.get("kind").and_then(Json::as_str),
                Some(super::super::MANIFEST_KIND)
            );
            assert!(j.get("key").is_some());
            assert!(j.get("counters").is_some());
        }
        let table = render_table(&sel);
        assert!(table.contains("scheduler"), "{table}");
        assert!(table.contains("hash-fuzz"), "{table}");
    }
}
