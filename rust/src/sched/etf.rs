//! Earliest Task First scheduler (Blythe et al. 2005).
//!
//! ETF repeatedly picks the (ready task, PE) pair with the globally
//! earliest *finish* time,
//!
//! ```text
//!   finish(t, p) = max(avail(p), data_ready(t, p)) + exec(t, p)
//! ```
//!
//! committing the pair and updating the PE's virtual availability, until
//! every ready task is placed.  It therefore uses both "the information
//! about the communication cost between tasks and the current status of
//! all PEs" (paper §3) — which is why it wins Figure 3.
//!
//! Two implementations share the selection logic:
//! * [`Etf`] — pure-rust inner loop (default; fastest at Table-2 scale).
//! * [`EtfXla`] — evaluates the finish-time matrix through the AOT
//!   Pallas artifact (`artifacts/etf_matrix.hlo.txt`) via PJRT: the
//!   batched-matrix path described in DESIGN.md §5.  Numerically
//!   identical decisions (asserted by integration tests); profitable
//!   only for very wide ready lists — see the `ablations` bench.

use super::{Assignment, ReadyTask, SchedBuild, SchedContext, Scheduler};
use crate::runtime::EtfArtifact;
use crate::Result;

/// Shared ETF selection over cached exec / data-ready matrices.
///
/// Semantics: repeatedly commit the (task, PE) pair with the globally
/// earliest finish `max(avail_j, ready_ij, now) + exec_ij`, updating the
/// chosen PE's virtual availability.  Ties break to the lower ready-list
/// index (FIFO) then the lower PE id — deterministic.
///
/// Complexity: the naive loop is O(I²·J).  This implementation caches
/// each task's best (finish, pe): committing to PE `j` only invalidates
/// tasks whose cached best is `j` (other columns' finish times are
/// unchanged because availability only grew for `j`), so a round is
/// O(I + k·J) with k = tasks sharing the winner's PE — a ~5× epoch
/// speedup at I=64 on the Table-2 platform (EXPERIMENTS.md §Perf).
fn select_etf(
    ready: &[ReadyTask],
    ctx: &dyn SchedContext,
    mut avail: Vec<f64>,
) -> Vec<Assignment> {
    let n = ready.len();
    let m = avail.len();
    let now = ctx.now_us();
    // Failed/hotplugged-out PEs never receive work; read the mask from
    // the snapshots in place (this path runs every decision epoch).
    let pes = ctx.pes();

    // Fast path: a single ready task (the dominant decision-epoch shape
    // below saturation) needs one scan and no matrix allocation.
    if n == 1 {
        let rt = &ready[0];
        let mut best = (f64::INFINITY, usize::MAX);
        for (j, &av) in avail.iter().enumerate() {
            if !pes[j].available {
                continue;
            }
            if let Some(e) = ctx.exec_us(rt, j) {
                let fin = av.max(ctx.data_ready_us(rt, j)).max(now) + e;
                if fin < best.0 {
                    best = (fin, j);
                }
            }
        }
        return if best.1 == usize::MAX {
            Vec::new()
        } else {
            vec![Assignment { job: rt.job, task: rt.task, pe: best.1 }]
        };
    }

    // Cache exec + data-ready: both are consulted O(n) times per round.
    let mut exec = vec![f64::INFINITY; n * m];
    let mut dready = vec![0.0f64; n * m];
    for (i, rt) in ready.iter().enumerate() {
        for j in 0..m {
            if !pes[j].available {
                continue;
            }
            if let Some(us) = ctx.exec_us(rt, j) {
                exec[i * m + j] = us;
                dready[i * m + j] = ctx.data_ready_us(rt, j);
            }
        }
    }

    // Per-task best (finish, pe) cache.
    let best_of = |i: usize, avail: &[f64]| -> (f64, usize) {
        let mut best = (f64::INFINITY, usize::MAX);
        for j in 0..m {
            let e = exec[i * m + j];
            if !e.is_finite() {
                continue;
            }
            let fin = avail[j].max(dready[i * m + j]).max(now) + e;
            if fin < best.0 {
                best = (fin, j);
            }
        }
        best
    };
    let mut cache: Vec<(f64, usize)> =
        (0..n).map(|i| best_of(i, &avail)).collect();

    let mut placed = vec![false; n];
    let mut out = Vec::with_capacity(n);
    loop {
        // Global min over cached per-task bests: O(I).
        let mut win = (f64::INFINITY, usize::MAX);
        for i in 0..n {
            if !placed[i] && cache[i].0 < win.0 {
                win = (cache[i].0, i);
            }
        }
        let (fin, i) = win;
        if i == usize::MAX {
            break; // nothing left placeable
        }
        let j = cache[i].1;
        placed[i] = true;
        avail[j] = fin;
        out.push(Assignment {
            job: ready[i].job,
            task: ready[i].task,
            pe: j,
        });
        // Only tasks whose cached best used PE j can have changed (its
        // availability grew; all other columns are untouched).
        for ii in 0..n {
            if !placed[ii] && cache[ii].1 == j {
                cache[ii] = best_of(ii, &avail);
            }
        }
    }
    out
}

/// Pure-rust ETF.
#[derive(Debug, Default)]
pub struct Etf {
    epochs: u64,
    pairs_evaluated: u64,
}

impl Etf {
    pub fn new() -> Etf {
        Etf::default()
    }
}

impl Scheduler for Etf {
    fn name(&self) -> &str {
        "etf"
    }

    fn schedule(
        &mut self,
        ready: &[ReadyTask],
        ctx: &dyn SchedContext,
    ) -> Vec<Assignment> {
        self.epochs += 1;
        self.pairs_evaluated +=
            (ready.len() * ctx.pes().len()) as u64;
        let avail: Vec<f64> =
            ctx.pes().iter().map(|p| p.avail_us).collect();
        select_etf(ready, ctx, avail)
    }

    fn report(&self) -> Vec<String> {
        vec![format!(
            "etf: {} epochs, {} (task, pe) pairs evaluated",
            self.epochs, self.pairs_evaluated
        )]
    }
}

/// XLA-accelerated ETF: the finish-time matrix (and per-task argmin) is
/// computed by the AOT-compiled Pallas kernel; selection then proceeds
/// on the returned matrix.  Falls back to chunking when the ready list
/// exceeds the artifact's padded I=64 rows.
pub struct EtfXla {
    artifact: EtfArtifact,
    epochs: u64,
    device_calls: u64,
}

impl EtfXla {
    pub fn new(build: &SchedBuild) -> Result<EtfXla> {
        let dir = build
            .artifacts_dir
            .clone()
            .unwrap_or_else(crate::runtime::default_artifacts_dir);
        Ok(EtfXla {
            artifact: EtfArtifact::load(&dir)?,
            epochs: 0,
            device_calls: 0,
        })
    }
}

impl Scheduler for EtfXla {
    fn name(&self) -> &str {
        "etf-xla"
    }

    fn schedule(
        &mut self,
        ready: &[ReadyTask],
        ctx: &dyn SchedContext,
    ) -> Vec<Assignment> {
        self.epochs += 1;
        let m = ctx.pes().len();
        let now = ctx.now_us();
        let mut avail: Vec<f64> =
            ctx.pes().iter().map(|p| p.avail_us.max(now)).collect();

        // Iteratively: evaluate the finish matrix on-device for all
        // unplaced tasks, commit the single best pair, repeat.  (The
        // artifact returns the whole matrix, so after the first call we
        // can do the remaining selection host-side against the returned
        // matrix, recomputing only the winning column's contribution —
        // identical to `select_etf` semantics.)
        let n = ready.len();
        let mut exec = vec![f64::INFINITY; n * m];
        let mut dready = vec![0.0f64; n * m];
        for (i, rt) in ready.iter().enumerate() {
            for j in 0..m {
                if !ctx.pes()[j].available {
                    continue; // failed PE: stays INFINITY everywhere
                }
                if let Some(us) = ctx.exec_us(rt, j) {
                    exec[i * m + j] = us;
                    dready[i * m + j] = ctx.data_ready_us(rt, j);
                }
            }
        }

        // One device call per chunk evaluates the full finish matrix
        // F0[i][j] = max(avail_j, ready_ij) + exec_ij for the *initial*
        // availability.  The host selection loop below consumes F0
        // directly and only recomputes entries in a column whose
        // availability it changed by committing an assignment — the
        // semantics are identical to the pure-rust `select_etf`.
        let mut fin_cache = vec![f64::INFINITY; n * m];
        let mut device_ok = true;
        let chunk_sz = EtfArtifact::MAX_TASKS.max(1);
        let chunks = n.div_ceil(chunk_sz);
        for c in 0..chunks {
            let lo = c * chunk_sz;
            let hi = ((c + 1) * chunk_sz).min(n);
            match self.artifact.finish_matrix(
                &avail,
                &dready[lo * m..hi * m],
                &exec[lo * m..hi * m],
                hi - lo,
                m,
            ) {
                Ok(matrix) => {
                    self.device_calls += 1;
                    fin_cache[lo * m..hi * m]
                        .copy_from_slice(&matrix[..(hi - lo) * m]);
                }
                Err(e) => {
                    // Device failure mid-run: degrade to the host path.
                    crate::telemetry::diag("sched.etf-xla", || {
                        format!(
                            "etf-xla: device call failed ({e}); host \
                             fallback"
                        )
                    });
                    device_ok = false;
                }
            }
        }
        if !device_ok {
            return select_etf(ready, ctx, avail);
        }

        let mut placed = vec![false; n];
        let mut out = Vec::with_capacity(n);
        loop {
            let mut best = (f64::INFINITY, usize::MAX, usize::MAX);
            for i in 0..n {
                if placed[i] {
                    continue;
                }
                let row = &fin_cache[i * m..(i + 1) * m];
                for (j, &fin) in row.iter().enumerate() {
                    if fin < best.0 {
                        best = (fin, i, j);
                    }
                }
            }
            let (fin, i, j) = best;
            if i == usize::MAX {
                break;
            }
            placed[i] = true;
            avail[j] = fin;
            out.push(Assignment {
                job: ready[i].job,
                task: ready[i].task,
                pe: j,
            });
            // Column j's availability changed: refresh its cached finish
            // times for the remaining tasks.
            for ii in 0..n {
                if placed[ii] {
                    continue;
                }
                let e = exec[ii * m + j];
                fin_cache[ii * m + j] = if e.is_finite() {
                    avail[j].max(dready[ii * m + j]).max(now) + e
                } else {
                    f64::INFINITY
                };
            }
        }
        out
    }

    fn report(&self) -> Vec<String> {
        vec![format!(
            "etf-xla: {} epochs, {} PJRT executions",
            self.epochs, self.device_calls
        )]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::{rt, MockCtx};

    #[test]
    fn prefers_earliest_finish_not_fastest_exec() {
        // PE 0: exec 10 but busy until t=100 -> finish 110.
        // PE 1: exec 40, idle -> finish 40.  ETF must pick PE 1
        // (MET would pick PE 0).
        let mut ctx = MockCtx::uniform(2, 0.0);
        ctx.set_exec(0, 0, 0, 10.0);
        ctx.set_exec(0, 0, 1, 40.0);
        ctx.pes[0].avail_us = 100.0;
        let mut etf = Etf::new();
        let a = etf.schedule(&[rt(0, 0)], &ctx);
        assert_eq!(a[0].pe, 1);
    }

    #[test]
    fn accounts_for_communication_cost() {
        // Same exec both PEs, but data lands at PE 1 much later.
        let mut ctx = MockCtx::uniform(2, 0.0);
        ctx.set_exec(0, 0, 0, 10.0);
        ctx.set_exec(0, 0, 1, 10.0);
        ctx.ready_at.insert((0, 0, 1), 500.0);
        let mut etf = Etf::new();
        let a = etf.schedule(&[rt(0, 0)], &ctx);
        assert_eq!(a[0].pe, 0);
    }

    #[test]
    fn virtual_availability_spreads_load() {
        // 4 identical tasks, 2 identical PEs -> 2 on each.
        let mut ctx = MockCtx::uniform(2, 0.0);
        for t in 0..4 {
            ctx.set_exec(0, t, 0, 10.0);
            ctx.set_exec(0, t, 1, 10.0);
        }
        let mut etf = Etf::new();
        let tasks: Vec<_> = (0..4).map(|t| rt(0, t)).collect();
        let a = etf.schedule(&tasks, &ctx);
        assert_eq!(a.iter().filter(|x| x.pe == 0).count(), 2);
        assert_eq!(a.iter().filter(|x| x.pe == 1).count(), 2);
    }

    #[test]
    fn schedules_shortest_first_on_single_pe() {
        // On one PE the ETF order is SPT: shortest task committed first.
        let mut ctx = MockCtx::uniform(1, 0.0);
        ctx.set_exec(0, 0, 0, 30.0);
        ctx.set_exec(0, 1, 0, 5.0);
        ctx.set_exec(0, 2, 0, 12.0);
        let mut etf = Etf::new();
        let tasks: Vec<_> = (0..3).map(|t| rt(0, t)).collect();
        let a = etf.schedule(&tasks, &ctx);
        let order: Vec<usize> = a.iter().map(|x| x.task).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn places_all_supported_tasks() {
        let mut ctx = MockCtx::uniform(3, 0.0);
        for t in 0..7 {
            for p in 0..3 {
                ctx.set_exec(0, t, p, 3.0 + (t + p) as f64);
            }
        }
        let mut etf = Etf::new();
        let tasks: Vec<_> = (0..7).map(|t| rt(0, t)).collect();
        assert_eq!(etf.schedule(&tasks, &ctx).len(), 7);
    }

    #[test]
    fn never_assigns_to_unavailable_pe() {
        // PE 0 is much faster but failed; ETF must route to PE 1, and
        // with both failed it must place nothing.
        let mut ctx = MockCtx::uniform(2, 0.0);
        for t in 0..3 {
            ctx.set_exec(0, t, 0, 1.0);
            ctx.set_exec(0, t, 1, 50.0);
        }
        ctx.pes[0].available = false;
        let mut etf = Etf::new();
        let tasks: Vec<_> = (0..3).map(|t| rt(0, t)).collect();
        let a = etf.schedule(&tasks, &ctx);
        assert_eq!(a.len(), 3);
        assert!(a.iter().all(|x| x.pe == 1));
        ctx.pes[1].available = false;
        assert!(etf.schedule(&tasks, &ctx).is_empty());
    }

    #[test]
    fn unsupported_tasks_left_unplaced() {
        let mut ctx = MockCtx::uniform(2, 0.0);
        ctx.set_exec(0, 0, 0, 5.0);
        // task 1 unsupported anywhere.
        let mut etf = Etf::new();
        let a = etf.schedule(&[rt(0, 0), rt(0, 1)], &ctx);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].task, 0);
    }
}
