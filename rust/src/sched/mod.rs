//! Scheduler framework: the paper's plug-and-play scheduling interface.
//!
//! "The simulation framework invokes the scheduler at every scheduling
//! decision epoch with the list of tasks ready for execution."  A
//! [`Scheduler`] maps ready tasks to PE queues; the simulation kernel
//! supplies a [`SchedContext`] exposing execution-time profiles, PE
//! availability, and communication costs.
//!
//! Built-ins (§2 of the paper):
//! * [`met::Met`] — minimum execution time (Braun et al.),
//! * [`etf::Etf`] — earliest task first (Blythe et al.), also available
//!   as an XLA-accelerated variant (`etf-xla`) that evaluates the
//!   finish-time matrix through the AOT Pallas artifact,
//! * [`table::TableSched`] — table-based scheduler storing an offline
//!   (ILP-optimal) schedule, produced by [`ilp`].
//!
//! Extensions proving plug-and-play: [`heft::Heft`], [`random::RandomSched`],
//! [`rr::RoundRobin`], and the imitation-learned
//! [`crate::learn::IlSched`] (`"il"`).  Register your own via
//! [`create`].

pub mod etf;
pub mod heft;
pub mod ilp;
pub mod met;
pub mod random;
pub mod rr;
pub mod table;

use crate::app::AppGraph;
use crate::platform::Platform;
use crate::{Error, Result};

/// A task instance eligible for scheduling (all predecessors finished).
#[derive(Debug, Clone, Copy)]
pub struct ReadyTask {
    /// Job instance id (unique over the whole run).
    pub job: usize,
    /// Task index within the job's application DAG.
    pub task: usize,
    /// Application index within the workload mix.
    pub app: usize,
    /// Job arrival time (µs) — FIFO/aging tie-breaks.
    pub arrival_us: f64,
    /// Time the task became ready (µs).
    pub ready_us: f64,
}

/// Immutable view of one PE for scheduling decisions.
#[derive(Debug, Clone, Copy)]
pub struct PeSnapshot {
    pub id: usize,
    pub class: usize,
    pub cluster: usize,
    /// Time the PE's committed queue drains (µs); `now` if idle.
    pub avail_us: f64,
    /// Committed-but-unfinished tasks (including the running one).
    pub queue_len: usize,
    /// False while the PE is failed/hotplugged out (scenario engine).
    /// Schedulers must not assign to unavailable PEs; the kernel also
    /// rejects such assignments and reports `exec_us = None` for them.
    pub available: bool,
}

/// The simulation state a scheduler may consult.
pub trait SchedContext {
    /// Current simulation time (µs).
    fn now_us(&self) -> f64;
    /// Snapshots of every PE.
    fn pes(&self) -> &[PeSnapshot];
    /// Execution time of `rt` on PE `pe` at its current DVFS state
    /// (µs), or `None` if that PE class does not support the task.
    fn exec_us(&self, rt: &ReadyTask, pe: usize) -> Option<f64>;
    /// Earliest time `rt`'s input data can be present at PE `pe`
    /// (predecessor finish + NoC transfer), in µs.
    fn data_ready_us(&self, rt: &ReadyTask, pe: usize) -> f64;
    /// Name of the task (diagnostics, table lookups).
    fn task_name(&self, rt: &ReadyTask) -> &str;
    /// Name of the application the task belongs to.
    fn app_name(&self, rt: &ReadyTask) -> &str;
    /// DVFS/thermal headroom of `cluster`, in [0, 1]: the cluster's
    /// current frequency as a fraction of its maximum, scaled down as
    /// the hottest node approaches the thermal-throttle trip point.
    /// Defaults to 1.0 for contexts that do not model DVFS/thermals
    /// (the IL featurizer treats that as "no pressure").
    fn headroom_frac(&self, _cluster: usize) -> f64 {
        1.0
    }
}

/// A scheduling decision: commit `task` of `job` to PE `pe`'s queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub job: usize,
    pub task: usize,
    pub pe: usize,
}

/// The plug-and-play scheduler interface.
///
/// `schedule` is invoked at every decision epoch with the ready list
/// (bounded by the kernel's `max_ready` window).  It may assign any
/// subset; unassigned tasks reappear at the next epoch.  Assignments to
/// unsupported PEs are rejected by the kernel (simulation error).
pub trait Scheduler {
    fn name(&self) -> &str;
    fn schedule(
        &mut self,
        ready: &[ReadyTask],
        ctx: &dyn SchedContext,
    ) -> Vec<Assignment>;
    /// Optional: scheduler-specific report lines for the run summary.
    fn report(&self) -> Vec<String> {
        Vec::new()
    }
    /// Optional: `(decisions, fallbacks)` counters surfaced as
    /// `SimReport::sched_decisions` / `sched_fallbacks`.  `fallbacks`
    /// counts decisions a guard rerouted (the IL scheduler's
    /// oracle-fallback guard); plain schedulers report 0.
    fn decision_counts(&self) -> (u64, u64) {
        (0, 0)
    }
}

/// Factory context passed to scheduler constructors: offline schedulers
/// (table/ILP, HEFT ranks) precompute against the platform + workload.
pub struct SchedBuild<'a> {
    pub platform: &'a Platform,
    pub apps: &'a [AppGraph],
    pub seed: u64,
    /// Optional path to the AOT artifacts directory (etf-xla).
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// Optional path to a trained IL policy artifact (`il`); `None`
    /// falls back to the committed pretrained preset.
    pub policy_path: Option<std::path::PathBuf>,
}

/// Registry: construct a scheduler by name.
///
/// The single source of truth for names is [`builtin_names`] —
/// `create` accepts exactly that list (`table` is the documented alias
/// of `ilp`, and both are listed), and the unknown-scheduler error is
/// generated from it, so the two can never drift apart
/// (`registry_creates_all_builtins` asserts this).
pub fn create(name: &str, build: &SchedBuild) -> Result<Box<dyn Scheduler>> {
    match name {
        "met" => Ok(Box::new(met::Met::new())),
        "met-lb" => Ok(Box::new(met::MetLb::new())),
        "etf" => Ok(Box::new(etf::Etf::new())),
        "etf-xla" => Ok(Box::new(etf::EtfXla::new(build)?)),
        "ilp" | "table" => Ok(Box::new(table::TableSched::from_ilp(build)?)),
        "heft" => Ok(Box::new(heft::Heft::new(build))),
        "il" => Ok(Box::new(crate::learn::IlSched::from_build(build)?)),
        "random" => Ok(Box::new(random::RandomSched::new(build.seed))),
        "rr" => Ok(Box::new(rr::RoundRobin::new())),
        other => Err(Error::Sched(format!(
            "unknown scheduler '{other}' (known: {})",
            builtin_names().join(", ")
        ))),
    }
}

/// All built-in scheduler names (CLI listings, sweep defaults, and the
/// exact set [`create`] accepts — aliases included).
pub fn builtin_names() -> &'static [&'static str] {
    &[
        "met", "met-lb", "etf", "etf-xla", "ilp", "table", "heft", "il",
        "random", "rr",
    ]
}

/// The built-in schedulers constructible in *this* environment:
/// [`builtin_names`] minus `etf-xla` when its on-disk AOT artifacts are
/// absent.  "Every registered scheduler" harnesses (the fuzz
/// tournament, property tests) iterate this so a fresh checkout still
/// covers the full roster it can actually build.
pub fn available_names() -> Vec<&'static str> {
    let artifacts = crate::runtime::artifacts_available(
        &crate::runtime::default_artifacts_dir(),
    );
    builtin_names()
        .iter()
        .copied()
        .filter(|&n| artifacts || n != "etf-xla")
        .collect()
}

// ---------------------------------------------------------------------------
// Test scaffolding shared by the scheduler unit tests.
// ---------------------------------------------------------------------------
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use std::collections::BTreeMap;

    /// A hand-wired context for scheduler unit tests.
    pub struct MockCtx {
        pub now: f64,
        pub pes: Vec<PeSnapshot>,
        /// (job, task, pe) -> exec µs.
        pub exec: BTreeMap<(usize, usize, usize), f64>,
        /// (job, task, pe) -> data-ready µs (default: now).
        pub ready_at: BTreeMap<(usize, usize, usize), f64>,
        pub names: BTreeMap<(usize, usize), String>,
    }

    impl MockCtx {
        pub fn uniform(n_pes: usize, now: f64) -> MockCtx {
            MockCtx {
                now,
                pes: (0..n_pes)
                    .map(|id| PeSnapshot {
                        id,
                        class: 0,
                        cluster: 0,
                        avail_us: now,
                        queue_len: 0,
                        available: true,
                    })
                    .collect(),
                exec: BTreeMap::new(),
                ready_at: BTreeMap::new(),
                names: BTreeMap::new(),
            }
        }

        pub fn set_exec(&mut self, job: usize, task: usize, pe: usize, us: f64) {
            self.exec.insert((job, task, pe), us);
        }
    }

    impl SchedContext for MockCtx {
        fn now_us(&self) -> f64 {
            self.now
        }
        fn pes(&self) -> &[PeSnapshot] {
            &self.pes
        }
        fn exec_us(&self, rt: &ReadyTask, pe: usize) -> Option<f64> {
            // Mirrors the kernel: unavailable PEs support nothing.
            if !self.pes[pe].available {
                return None;
            }
            self.exec.get(&(rt.job, rt.task, pe)).copied()
        }
        fn data_ready_us(&self, rt: &ReadyTask, pe: usize) -> f64 {
            self.ready_at
                .get(&(rt.job, rt.task, pe))
                .copied()
                .unwrap_or(self.now)
        }
        fn task_name(&self, rt: &ReadyTask) -> &str {
            self.names
                .get(&(rt.job, rt.task))
                .map(String::as_str)
                .unwrap_or("task")
        }
        fn app_name(&self, _rt: &ReadyTask) -> &str {
            "mock-app"
        }
    }

    pub fn rt(job: usize, task: usize) -> ReadyTask {
        ReadyTask { job, task, app: 0, arrival_us: 0.0, ready_us: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::suite;

    #[test]
    fn registry_creates_all_builtins() {
        // `builtin_names` is the single source of truth: `create` must
        // succeed for every listed name (etf-xla only needs its AOT
        // artifact files; without them it must fail with the artifact
        // error, not an unknown-name error).
        let platform = Platform::table2_soc();
        let apps = vec![suite::wifi_tx(suite::WifiParams { symbols: 2 })];
        let build = SchedBuild {
            platform: &platform,
            apps: &apps,
            seed: 1,
            artifacts_dir: None,
            policy_path: None,
        };
        let artifacts = crate::runtime::artifacts_available(
            &crate::runtime::default_artifacts_dir(),
        );
        for &name in builtin_names() {
            match create(name, &build) {
                Ok(s) => assert!(!s.name().is_empty(), "{name}"),
                Err(e) if name == "etf-xla" && !artifacts => {
                    let msg = format!("{e}");
                    assert!(
                        msg.contains("artifact"),
                        "{name}: unexpected failure: {msg}"
                    );
                }
                Err(e) => panic!("{name}: {e}"),
            }
        }
    }

    #[test]
    fn available_names_is_builtins_modulo_artifacts() {
        let names = available_names();
        let artifacts = crate::runtime::artifacts_available(
            &crate::runtime::default_artifacts_dir(),
        );
        for &n in builtin_names() {
            let expect = artifacts || n != "etf-xla";
            assert_eq!(names.contains(&n), expect, "{n}");
        }
        // Every available scheduler is constructible right now.
        let platform = Platform::table2_soc();
        let apps = vec![suite::wifi_tx(suite::WifiParams { symbols: 2 })];
        let build = SchedBuild {
            platform: &platform,
            apps: &apps,
            seed: 1,
            artifacts_dir: None,
            policy_path: None,
        };
        for name in names {
            create(name, &build).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn registry_rejects_unknown_and_error_lists_all_names() {
        let platform = Platform::table2_soc();
        let build = SchedBuild {
            platform: &platform,
            apps: &[],
            seed: 1,
            artifacts_dir: None,
            policy_path: None,
        };
        let msg = format!("{}", create("nope", &build).unwrap_err());
        // The error message is generated from builtin_names(), so every
        // accepted name (aliases included) appears in it.
        for name in builtin_names() {
            assert!(msg.contains(name), "error omits '{name}': {msg}");
        }
    }
}
