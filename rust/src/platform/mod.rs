//! Platform model: the paper's *resource database*.
//!
//! A [`Platform`] describes a candidate DSSoC: processing elements (PEs)
//! grouped into DVFS clusters, per-class operating performance points
//! (OPPs), power-model coefficients, mesh coordinates for the NoC model,
//! and the thermal floorplan.  Presets for the paper's evaluation SoC
//! (Table 2: 4×Cortex-A15 + 4×Cortex-A7 + 2×Scrambler-Encoder + 4×FFT)
//! live in [`presets`].

pub mod io;
pub mod presets;

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Category of a processing element.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PeType {
    /// General-purpose "big" core (e.g. Cortex-A15).
    BigCore,
    /// General-purpose "LITTLE" core (e.g. Cortex-A7).
    LittleCore,
    /// Fixed-function hardware accelerator.
    Accelerator,
}

impl PeType {
    pub fn label(&self) -> &'static str {
        match self {
            PeType::BigCore => "big",
            PeType::LittleCore => "LITTLE",
            PeType::Accelerator => "accelerator",
        }
    }
}

/// An operating performance point: frequency + the voltage it requires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Opp {
    pub freq_mhz: f64,
    pub volt: f64,
}

/// A *class* of PE: all instances share latency profiles, OPPs and power
/// coefficients.  Classes are what Table 1 columns refer to ("Odroid A7",
/// "Odroid A15", "HW Acc.").
#[derive(Debug, Clone)]
pub struct PeClass {
    /// Unique name, referenced by task profiles (e.g. "A15", "ACC_FFT").
    pub name: String,
    pub ty: PeType,
    /// Frequency at which latency profiles were measured (MHz).
    pub nominal_mhz: f64,
    /// Available OPPs, ascending frequency. Accelerators have exactly one.
    pub opps: Vec<Opp>,
    /// Effective switched capacitance: `P_dyn = ceff * V^2 * f_mhz * util`
    /// (W, with f in MHz) — [Bhat et al. 2018]-style model.
    pub ceff: f64,
    /// Leakage: `P_leak = k1 * V * exp(k2 * T)` (W, T in °C).
    pub leak_k1: f64,
    pub leak_k2: f64,
}

impl PeClass {
    pub fn max_opp(&self) -> Opp {
        *self.opps.last().expect("class has no OPPs")
    }

    pub fn min_opp(&self) -> Opp {
        *self.opps.first().expect("class has no OPPs")
    }

    /// The OPP with the lowest frequency >= `mhz` (or the max OPP).
    pub fn opp_at_least(&self, mhz: f64) -> Opp {
        for opp in &self.opps {
            if opp.freq_mhz + 1e-9 >= mhz {
                return *opp;
            }
        }
        self.max_opp()
    }
}

/// One processing element instance.
#[derive(Debug, Clone)]
pub struct Pe {
    /// Dense id, index into `Platform::pes`.
    pub id: usize,
    pub class: usize,
    pub cluster: usize,
    /// Human-readable instance name, e.g. "A15-2".
    pub name: String,
    /// Mesh coordinates for the NoC latency model.
    pub x: usize,
    pub y: usize,
}

/// A DVFS domain: all member PEs switch OPP together (matches big.LITTLE
/// cluster-level DVFS on the Odroid-XU3 the paper profiles).
#[derive(Debug, Clone)]
pub struct Cluster {
    pub id: usize,
    pub name: String,
    pub class: usize,
    pub pe_ids: Vec<usize>,
    /// Thermal floorplan node index this cluster's power flows into.
    pub thermal_node: usize,
}

/// Thermal floorplan: an RC network over named nodes.
#[derive(Debug, Clone)]
pub struct ThermalFloorplan {
    pub node_names: Vec<String>,
    /// Thermal capacitance per node (J/°C).
    pub capacitance: Vec<f64>,
    /// Conductance to ambient per node (W/°C).
    pub g_amb: Vec<f64>,
    /// Lateral couplings `(i, j, conductance W/°C)`, i < j.
    pub couplings: Vec<(usize, usize, f64)>,
}

impl ThermalFloorplan {
    pub fn len(&self) -> usize {
        self.node_names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.node_names.is_empty()
    }
}

/// NoC parameters for the analytical interconnect model.
#[derive(Debug, Clone)]
pub struct NocParams {
    /// Mesh dimensions.
    pub mesh_x: usize,
    pub mesh_y: usize,
    /// Per-hop router+link latency (µs).
    pub hop_latency_us: f64,
    /// Link bandwidth (bytes/µs).
    pub link_bandwidth: f64,
    /// Memory-access base latency (µs) for shared-memory transfers.
    pub mem_latency_us: f64,
}

impl Default for NocParams {
    fn default() -> Self {
        // Calibrated to on-chip scale: ~50 ns/hop, 8 GB/s links, and a
        // 0.5 µs shared-memory staging cost per producer→consumer move
        // (DMA descriptor setup + cache maintenance — typical for
        // core↔accelerator offload on a Zynq-class MPSoC).
        NocParams {
            mesh_x: 4,
            mesh_y: 4,
            hop_latency_us: 0.05,
            link_bandwidth: 8000.0,
            mem_latency_us: 0.5,
        }
    }
}

/// A complete DSSoC description (the resource database entry).
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: String,
    pub classes: Vec<PeClass>,
    pub pes: Vec<Pe>,
    pub clusters: Vec<Cluster>,
    pub noc: NocParams,
    pub floorplan: ThermalFloorplan,
    /// Ambient temperature (°C).
    pub t_ambient: f64,
    class_by_name: BTreeMap<String, usize>,
}

impl Platform {
    /// Assemble and validate a platform.
    pub fn new(
        name: impl Into<String>,
        classes: Vec<PeClass>,
        pes: Vec<Pe>,
        clusters: Vec<Cluster>,
        noc: NocParams,
        floorplan: ThermalFloorplan,
    ) -> Result<Platform> {
        let mut class_by_name = BTreeMap::new();
        for (i, c) in classes.iter().enumerate() {
            if c.opps.is_empty() {
                return Err(Error::Platform(format!(
                    "class '{}' has no OPPs",
                    c.name
                )));
            }
            if class_by_name.insert(c.name.clone(), i).is_some() {
                return Err(Error::Platform(format!(
                    "duplicate class '{}'",
                    c.name
                )));
            }
        }
        for (i, pe) in pes.iter().enumerate() {
            if pe.id != i {
                return Err(Error::Platform(format!(
                    "pe '{}' id {} != index {i}",
                    pe.name, pe.id
                )));
            }
            if pe.class >= classes.len() {
                return Err(Error::Platform(format!(
                    "pe '{}' references unknown class {}",
                    pe.name, pe.class
                )));
            }
            if pe.cluster >= clusters.len() {
                return Err(Error::Platform(format!(
                    "pe '{}' references unknown cluster {}",
                    pe.name, pe.cluster
                )));
            }
            if pe.x >= noc.mesh_x || pe.y >= noc.mesh_y {
                return Err(Error::Platform(format!(
                    "pe '{}' at ({}, {}) outside {}x{} mesh",
                    pe.name, pe.x, pe.y, noc.mesh_x, noc.mesh_y
                )));
            }
        }
        for (i, cl) in clusters.iter().enumerate() {
            if cl.id != i {
                return Err(Error::Platform(format!(
                    "cluster '{}' id {} != index {i}",
                    cl.name, cl.id
                )));
            }
            if cl.thermal_node >= floorplan.len() {
                return Err(Error::Platform(format!(
                    "cluster '{}' thermal node {} out of range",
                    cl.name, cl.thermal_node
                )));
            }
            for &pid in &cl.pe_ids {
                if pid >= pes.len() || pes[pid].cluster != i {
                    return Err(Error::Platform(format!(
                        "cluster '{}' membership inconsistent for pe {pid}",
                        cl.name
                    )));
                }
            }
        }
        for (i, j, g) in &floorplan.couplings {
            if *i >= floorplan.len() || *j >= floorplan.len() || i >= j {
                return Err(Error::Platform(format!(
                    "bad thermal coupling ({i}, {j})"
                )));
            }
            if *g < 0.0 {
                return Err(Error::Platform(
                    "negative thermal conductance".into(),
                ));
            }
        }
        Ok(Platform {
            name: name.into(),
            classes,
            pes,
            clusters,
            noc,
            floorplan,
            t_ambient: 25.0,
            class_by_name,
        })
    }

    /// The Table-2 evaluation SoC (see [`presets::table2_soc`]).
    pub fn table2_soc() -> Platform {
        presets::table2_soc()
    }

    pub fn n_pes(&self) -> usize {
        self.pes.len()
    }

    pub fn class_of(&self, pe_id: usize) -> &PeClass {
        &self.classes[self.pes[pe_id].class]
    }

    pub fn class_index(&self, name: &str) -> Option<usize> {
        self.class_by_name.get(name).copied()
    }

    pub fn cluster_of(&self, pe_id: usize) -> &Cluster {
        &self.clusters[self.pes[pe_id].cluster]
    }

    /// Manhattan hop distance between two PEs on the mesh.
    pub fn hops(&self, a: usize, b: usize) -> usize {
        let pa = &self.pes[a];
        let pb = &self.pes[b];
        pa.x.abs_diff(pb.x) + pa.y.abs_diff(pb.y)
    }

    /// Instance count per class name (Table-2 style inventory).
    pub fn inventory(&self) -> Vec<(String, PeType, usize)> {
        self.classes
            .iter()
            .enumerate()
            .map(|(ci, c)| {
                let n = self.pes.iter().filter(|p| p.class == ci).count();
                (c.name.clone(), c.ty, n)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_inventory_matches_paper() {
        let p = Platform::table2_soc();
        let inv: BTreeMap<String, usize> = p
            .inventory()
            .into_iter()
            .map(|(n, _, c)| (n, c))
            .collect();
        assert_eq!(inv["A15"], 4);
        assert_eq!(inv["A7"], 4);
        assert_eq!(inv["ACC_SCR"], 2);
        assert_eq!(inv["ACC_FFT"], 4);
        assert_eq!(p.n_pes(), 14); // "a total of 14 ... cores and accelerators"
    }

    #[test]
    fn validation_rejects_bad_class_ref() {
        let mut p = Platform::table2_soc();
        let classes = p.classes.clone();
        p.pes[0].class = 99;
        let r = Platform::new(
            "bad",
            classes,
            p.pes.clone(),
            p.clusters.clone(),
            p.noc.clone(),
            p.floorplan.clone(),
        );
        assert!(r.is_err());
    }

    #[test]
    fn validation_rejects_duplicate_class() {
        let p = Platform::table2_soc();
        let mut classes = p.classes.clone();
        let dup = classes[0].clone();
        classes.push(dup);
        // classes now has duplicate name "A15"
        let r = Platform::new(
            "bad",
            classes,
            p.pes.clone(),
            p.clusters.clone(),
            p.noc.clone(),
            p.floorplan.clone(),
        );
        assert!(r.is_err());
    }

    #[test]
    fn hops_are_manhattan() {
        let p = Platform::table2_soc();
        assert_eq!(p.hops(0, 0), 0);
        let h = p.hops(0, p.n_pes() - 1);
        assert!(h > 0 && h <= p.noc.mesh_x + p.noc.mesh_y);
    }

    #[test]
    fn opp_lookup() {
        let p = Platform::table2_soc();
        let big = &p.classes[p.class_index("A15").unwrap()];
        assert!(big.opps.len() > 1);
        assert_eq!(
            big.opp_at_least(big.max_opp().freq_mhz).freq_mhz,
            big.max_opp().freq_mhz
        );
        assert!(big.opp_at_least(0.0).freq_mhz <= big.opps[0].freq_mhz);
        // Monotone voltage with frequency.
        for w in big.opps.windows(2) {
            assert!(w[0].freq_mhz < w[1].freq_mhz);
            assert!(w[0].volt <= w[1].volt);
        }
    }

    #[test]
    fn accelerators_have_single_opp() {
        let p = Platform::table2_soc();
        for c in &p.classes {
            if c.ty == PeType::Accelerator {
                assert_eq!(c.opps.len(), 1, "class {}", c.name);
            }
        }
    }

    #[test]
    fn clusters_partition_pes() {
        let p = Platform::table2_soc();
        let mut seen = vec![false; p.n_pes()];
        for cl in &p.clusters {
            for &pid in &cl.pe_ids {
                assert!(!seen[pid], "pe {pid} in two clusters");
                seen[pid] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
