//! Whole-stack hot-path benchmarks — the §Perf numbers in
//! EXPERIMENTS.md and the recorded trajectory in `BENCH_hotpath.json`
//! come from this harness.
//!
//! * simulation kernel: events/second on a saturating Figure-3 workload
//!   (warmup + median-of-N, written to `BENCH_hotpath.json`)
//! * scheduler decision cost per epoch for every built-in
//! * event-queue push/pop throughput
//! * thermal RC step (native) and the k-epoch propagator
//! * PJRT artifact call overhead (when artifacts are present)
//!
//! Run: `cargo bench --bench perf_hotpath`
//!
//! Environment knobs (the CI smoke job uses all three):
//! * `BENCH_SMOKE=1`    — reduced jobs/repeats for CI latency
//! * `BENCH_OUT=path`   — where to write the JSON (default
//!   `BENCH_hotpath.json` in the working directory, i.e. the repo root
//!   under `cargo bench`)
//! * `BENCH_BASELINE=path` — compare events/s per kernel against a
//!   committed baseline JSON and **exit non-zero on a >20% regression**;
//!   a missing baseline file records only.
//! * `-- --write-baseline` — additionally write this run's record to
//!   the baseline path (`BENCH_BASELINE`, default
//!   `BENCH_baseline.json`): the one-command refresh documented in
//!   README §Performance.  Run it on a trusted machine from `main`,
//!   then commit the refreshed baseline to arm the tight gate.
//! * `TELEMETRY_OUT=path|-` — additionally stream each kernel
//!   measurement as `bench_record` telemetry events (README
//!   §Observability), so bench trajectories land in the same JSONL
//!   stream as campaign telemetry.

mod bench_util;

use ds3r::app::suite::{self, WifiParams};
use ds3r::config::SimConfig;
use ds3r::platform::Platform;
use ds3r::sim::queue::{Event, EventQueue};
use ds3r::sim::Simulation;
use ds3r::telemetry::Event as TelEvent;
use ds3r::thermal::RcModel;
use ds3r::util::json::Json;

/// One simulation-kernel measurement for the JSON record.
struct KernelResult {
    name: String,
    events_per_s: f64,
    events: u64,
    median_s: f64,
    sched_overhead_us: f64,
    /// Self-profile of the measured run's wall clock (README
    /// §Observability): fraction spent in scheduler decisions, the
    /// residual event loop, thermal/power integration, and job
    /// generation.  Fractions of the four buckets sum to 1.
    profile_fracs: [f64; 4],
}

/// Wall-clock bucket names, in `SimReport` profile order.
const PROFILE_BUCKETS: [&str; 4] = ["sched", "loop", "thermal", "jobgen"];

/// Fold a report's self-profile counters into per-bucket fractions.
fn profile_fracs(r: &ds3r::stats::SimReport) -> [f64; 4] {
    let ns = [
        r.sched_wall_ns,
        r.loop_wall_ns,
        r.thermal_wall_ns,
        r.jobgen_wall_ns,
    ];
    let total: u64 = ns.iter().sum();
    if total == 0 {
        return [0.0; 4];
    }
    ns.map(|b| b as f64 / total as f64)
}

fn main() {
    let platform = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let smoke = std::env::var("BENCH_SMOKE")
        .map(|v| v == "1")
        .unwrap_or(false);
    let (jobs, runs, warmup) = if smoke { (400, 3, 1) } else { (2000, 5, 1) };

    println!(
        "=== L3 hot path: simulation kernel (median of {runs}, \
         {jobs} jobs{}) ===",
        if smoke { ", smoke mode" } else { "" }
    );
    let mut kernels: Vec<KernelResult> = Vec::new();
    for (sched, rate) in
        [("etf", 9.0), ("met", 9.0), ("ilp", 9.0), ("heft", 9.0)]
    {
        let mut cfg = SimConfig::default();
        cfg.scheduler = sched.into();
        cfg.injection_rate_per_ms = rate;
        cfg.max_jobs = jobs;
        cfg.warmup_jobs = jobs / 20;
        cfg.max_sim_us = 30_000_000.0;
        let (r, st) = bench_util::bench_median(
            &format!("{jobs} jobs @ {rate}/ms [{sched}]"),
            warmup,
            runs,
            || Simulation::build(&platform, &apps, &cfg).unwrap().run(),
        );
        let events_per_s = r.events_processed as f64 / st.median_s;
        println!(
            "{:>48} {:>12.0} events/s  |  {:.2} us/sched-epoch  |  {} tasks\n",
            "",
            events_per_s,
            r.sched_overhead_us(),
            r.tasks_executed
        );
        kernels.push(KernelResult {
            name: sched.to_string(),
            events_per_s,
            events: r.events_processed,
            median_s: st.median_s,
            sched_overhead_us: r.sched_overhead_us(),
            profile_fracs: profile_fracs(&r),
        });
    }
    let tel = bench_util::telemetry_from_env();
    for k in &kernels {
        tel.emit(|| TelEvent::BenchRecord {
            bench: "perf_hotpath".into(),
            name: format!("kernel.{}.events_per_s", k.name),
            value: k.events_per_s,
            unit: "events/s".into(),
        });
        for (bucket, frac) in
            PROFILE_BUCKETS.iter().zip(k.profile_fracs)
        {
            tel.emit(|| TelEvent::BenchRecord {
                bench: "perf_hotpath".into(),
                name: format!("kernel.{}.profile.{bucket}", k.name),
                value: frac,
                unit: "frac".into(),
            });
        }
    }
    tel.flush();
    let record = write_bench_json(&kernels, smoke, jobs, runs);
    if std::env::args().any(|a| a == "--write-baseline") {
        let base = std::env::var("BENCH_BASELINE")
            .unwrap_or_else(|_| "BENCH_baseline.json".into());
        match std::fs::write(&base, record.to_string_pretty()) {
            Ok(()) => println!(
                "baseline refreshed at {base} — commit it to arm the \
                 regression gate against this run's hardware"
            ),
            Err(e) => eprintln!("could not write baseline {base}: {e}"),
        }
    } else {
        check_baseline(&kernels, smoke);
    }

    println!("=== scenario engine overhead guard ===");
    // Same workload twice: static vs a busy scenario timeline (an event
    // every millisecond that re-asserts the same rate — pure dispatch
    // cost, no behavioural change).  The guard: scenario event dispatch
    // must stay < 5% of wall time on a saturating run.
    {
        use ds3r::scenario::{Action, Scenario};
        let mut cfg = SimConfig::default();
        cfg.scheduler = "etf".into();
        cfg.injection_rate_per_ms = 9.0;
        cfg.max_jobs = jobs;
        cfg.warmup_jobs = jobs / 20;
        cfg.max_sim_us = 30_000_000.0;
        let (r_static, s_static) = bench_util::bench_once(
            &format!("{jobs} jobs @ 9/ms, static"),
            || Simulation::build(&platform, &apps, &cfg).unwrap().run(),
        );
        let mut churn = Scenario::new(
            "churn",
            "no-op rate re-assertions every 1 ms",
        );
        for k in 0..400 {
            churn = churn.event(
                1000.0 * (k + 1) as f64,
                Action::SetRate { per_ms: 9.0 },
            );
        }
        cfg.scenario = Some(churn);
        let (r_scen, s_scen) = bench_util::bench_once(
            &format!("{jobs} jobs @ 9/ms, 400-event scenario"),
            || Simulation::build(&platform, &apps, &cfg).unwrap().run(),
        );
        assert_eq!(r_static.completed_jobs, r_scen.completed_jobs);
        let overhead = (s_scen / s_static - 1.0) * 100.0;
        println!(
            "{:>48} {:>11.1}% wall overhead ({} scenario events, \
             {} phases) — guard: < 5%\n",
            "",
            overhead,
            r_scen.scenario_events,
            r_scen.phases.len()
        );
    }

    println!("=== telemetry overhead guard (disabled vs null sink) ===");
    // The observability contract (README §Observability): telemetry
    // must be free on the hot path.  The kernel emits no per-event
    // telemetry — only counters folded from `SimReport` afterwards —
    // so a run with the global dispatcher disabled and a run with an
    // enabled null sink must deliver the same events/s.  Interleave
    // the two configurations so thermal/cache drift hits both sides
    // equally, then compare medians; the disabled path losing more
    // than the floor vs the null-sink path fails the bench.
    {
        use ds3r::telemetry::{self, Sink, Telemetry};
        use std::sync::Arc;

        struct NullSink;
        impl Sink for NullSink {
            fn emit(&self, _ev: &TelEvent) {}
        }

        let mut cfg = SimConfig::default();
        cfg.scheduler = "etf".into();
        cfg.injection_rate_per_ms = 9.0;
        cfg.max_jobs = jobs;
        cfg.warmup_jobs = jobs / 20;
        cfg.max_sim_us = 30_000_000.0;
        let measure = || {
            let t0 = std::time::Instant::now();
            let r =
                Simulation::build(&platform, &apps, &cfg).unwrap().run();
            r.events_processed as f64 / t0.elapsed().as_secs_f64()
        };
        std::hint::black_box(measure()); // warmup
        let mut eps_dis = Vec::with_capacity(runs);
        let mut eps_null = Vec::with_capacity(runs);
        for _ in 0..runs {
            telemetry::set_global(Telemetry::disabled());
            eps_dis.push(measure());
            telemetry::set_global(Telemetry::new(Arc::new(NullSink)));
            eps_null.push(measure());
        }
        telemetry::set_global(Telemetry::disabled());
        let median = |v: &mut Vec<f64>| {
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        let d = median(&mut eps_dis);
        let n = median(&mut eps_null);
        // Short smoke runs carry more fixed cost per run, so the 1%
        // contract is checked at a relaxed floor there.
        let floor = if smoke { 0.90 } else { 0.99 };
        println!(
            "{:>48} {d:>12.0} events/s disabled | {n:>12.0} events/s \
             null sink ({:+.2}%) — guard: disabled within {:.0}%\n",
            "",
            (n / d - 1.0) * 100.0,
            (1.0 - floor) * 100.0
        );
        tel.emit(|| TelEvent::BenchRecord {
            bench: "perf_hotpath".into(),
            name: "telemetry.disabled_vs_null_sink".into(),
            value: d / n,
            unit: "ratio".into(),
        });
        tel.flush();
        if d < floor * n {
            eprintln!(
                "TELEMETRY REGRESSION: disabled dispatcher delivered \
                 {:.1}% fewer events/s than an enabled null sink \
                 (allowed: {:.0}%) — the disabled fast path is no \
                 longer free",
                (1.0 - d / n) * 100.0,
                (1.0 - floor) * 100.0
            );
            std::process::exit(1);
        }
    }

    println!("=== watchdog overhead guard (disabled vs armed budget) ===");
    // The fault-tolerance contract (README §Fault tolerance): the
    // deterministic step-budget watchdog must be free when disabled
    // and near-free when armed.  With `step_budget = 0` the loop pays
    // one u64 compare; with a budget too large to ever trip it adds an
    // increment + compare per iteration.  Interleave the two
    // configurations (same drift treatment as the telemetry guard),
    // compare medians, and fail the bench when the armed path loses
    // more than the floor.
    {
        let mut cfg = SimConfig::default();
        cfg.scheduler = "etf".into();
        cfg.injection_rate_per_ms = 9.0;
        cfg.max_jobs = jobs;
        cfg.warmup_jobs = jobs / 20;
        cfg.max_sim_us = 30_000_000.0;
        let measure = |cfg: &SimConfig| {
            let t0 = std::time::Instant::now();
            let r =
                Simulation::build(&platform, &apps, cfg).unwrap().run();
            assert!(
                !r.timed_out,
                "guard budget must never trip during the bench"
            );
            r.events_processed as f64 / t0.elapsed().as_secs_f64()
        };
        let mut armed_cfg = cfg.clone();
        armed_cfg.step_budget = u64::MAX / 2;
        std::hint::black_box(measure(&cfg)); // warmup
        let mut eps_off = Vec::with_capacity(runs);
        let mut eps_armed = Vec::with_capacity(runs);
        for _ in 0..runs {
            eps_off.push(measure(&cfg));
            eps_armed.push(measure(&armed_cfg));
        }
        let median = |v: &mut Vec<f64>| {
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        let off = median(&mut eps_off);
        let armed = median(&mut eps_armed);
        let floor = if smoke { 0.90 } else { 0.99 };
        println!(
            "{:>48} {off:>12.0} events/s disabled | {armed:>12.0} \
             events/s armed ({:+.2}%) — guard: armed within {:.0}%\n",
            "",
            (armed / off - 1.0) * 100.0,
            (1.0 - floor) * 100.0
        );
        tel.emit(|| TelEvent::BenchRecord {
            bench: "perf_hotpath".into(),
            name: "watchdog.armed_vs_disabled".into(),
            value: armed / off,
            unit: "ratio".into(),
        });
        tel.flush();
        if armed < floor * off {
            eprintln!(
                "WATCHDOG REGRESSION: an armed (never-tripping) step \
                 budget delivered {:.1}% fewer events/s than a \
                 disabled one (allowed: {:.0}%) — the watchdog guard \
                 is no longer near-free",
                (1.0 - armed / off) * 100.0,
                (1.0 - floor) * 100.0
            );
            std::process::exit(1);
        }
    }

    println!("=== event queue ===");
    let mut q = EventQueue::new();
    let mut t = 0.0;
    bench_util::bench("event queue push+pop (depth ~1k)", 1_000_000, || {
        t += 1.0;
        q.push(t, Event::DtpmEpoch);
        if q.len() > 1000 {
            std::hint::black_box(q.pop());
        }
    });

    println!("\n=== thermal model ===");
    let mut rc = RcModel::new(&platform, 10_000.0);
    let theta = vec![10.0; rc.n];
    let p = vec![1.0; rc.n_pes];
    let mut out = vec![0.0; rc.n];
    bench_util::bench("RC step (native, 6 nodes x 14 PEs)", 1_000_000, || {
        rc.step_into(&theta, &p, &mut out);
    });
    bench_util::bench("RC steady-state solve", 100_000, || {
        std::hint::black_box(rc.steady_state(&p));
    });
    // Cached k-epoch propagator vs iterating k steps.
    rc.propagator(100); // build outside the timed loop
    bench_util::bench("RC 100-epoch advance (cached propagator)", 200_000, || {
        std::hint::black_box(rc.advance_const_power(&theta, &p, 100));
    });
    bench_util::bench("RC 100-epoch advance (iterated steps)", 20_000, || {
        let mut th = theta.clone();
        for _ in 0..100 {
            rc.step_into(&th, &p, &mut out);
            std::mem::swap(&mut th, &mut out);
        }
        std::hint::black_box(&th);
    });

    let dir = ds3r::runtime::default_artifacts_dir();
    if ds3r::runtime::artifacts_available(&dir) {
        println!("\n=== PJRT artifact overhead ===");
        use ds3r::runtime::{DtpmArtifact, EtfArtifact};
        let mut art = DtpmArtifact::load(&dir).unwrap();
        let (k1, k2): (Vec<f64>, Vec<f64>) = platform
            .pes
            .iter()
            .map(|pe| {
                let c = &platform.classes[pe.class];
                (rc.leak_k1_effective(c.leak_k1, c.leak_k2), c.leak_k2)
            })
            .unzip();
        art.set_model(&rc, &k1, &k2).unwrap();
        let cand = vec![(vec![1.0; rc.n_pes], vec![1.1; rc.n_pes])];
        bench_util::bench("dtpm_step artifact (K=1 row used)", 2_000, || {
            std::hint::black_box(art.step(&theta, &cand).unwrap());
        });
        let cands16: Vec<_> = (0..16)
            .map(|_| (vec![1.0; rc.n_pes], vec![1.1; rc.n_pes]))
            .collect();
        bench_util::bench("dtpm_step artifact (K=16 batch)", 2_000, || {
            std::hint::black_box(art.step(&theta, &cands16).unwrap());
        });

        let mut etf_art = EtfArtifact::load(&dir).unwrap();
        let m = platform.n_pes();
        let avail = vec![0.0; m];
        let ready = vec![0.0; 64 * m];
        let exec: Vec<f64> =
            (0..64 * m).map(|i| 1.0 + (i % 7) as f64).collect();
        bench_util::bench("etf finish-matrix artifact (64x14)", 2_000, || {
            std::hint::black_box(
                etf_art.finish_matrix(&avail, &ready, &exec, 64, m).unwrap(),
            );
        });
        // Host equivalent for comparison.
        let mut fin = vec![0.0f64; 64 * m];
        bench_util::bench("etf finish-matrix host (64x14)", 200_000, || {
            for i in 0..64 {
                for j in 0..m {
                    fin[i * m + j] =
                        avail[j].max(ready[i * m + j]) + exec[i * m + j];
                }
            }
            std::hint::black_box(&fin);
        });
    } else {
        println!("\n(PJRT benches skipped: run `make artifacts`)");
    }

    println!("\n=== scheduler decision cost vs ready-list width ===");
    // Isolated ETF cost: synthetic context with W ready tasks.
    use ds3r::sched::{PeSnapshot, ReadyTask, SchedContext, Scheduler};
    struct SynthCtx {
        pes: Vec<PeSnapshot>,
        exec: f64,
    }
    impl SchedContext for SynthCtx {
        fn now_us(&self) -> f64 {
            0.0
        }
        fn pes(&self) -> &[PeSnapshot] {
            &self.pes
        }
        fn exec_us(&self, rt: &ReadyTask, pe: usize) -> Option<f64> {
            Some(self.exec + (rt.task * 7 + pe) as f64 % 13.0)
        }
        fn data_ready_us(&self, _rt: &ReadyTask, _pe: usize) -> f64 {
            0.0
        }
        fn task_name(&self, _rt: &ReadyTask) -> &str {
            "synthetic"
        }
        fn app_name(&self, _rt: &ReadyTask) -> &str {
            "synthetic"
        }
    }
    let ctx = SynthCtx {
        pes: (0..14)
            .map(|id| PeSnapshot {
                id,
                class: 0,
                cluster: 0,
                avail_us: 0.0,
                queue_len: 0,
                available: true,
            })
            .collect(),
        exec: 10.0,
    };
    for w in [8usize, 16, 32, 64] {
        let ready: Vec<ReadyTask> = (0..w)
            .map(|t| ReadyTask {
                job: 0,
                task: t,
                app: 0,
                arrival_us: 0.0,
                ready_us: 0.0,
            })
            .collect();
        let mut etf = ds3r::sched::etf::Etf::new();
        bench_util::bench(
            &format!("ETF decision, {w} ready x 14 PEs"),
            20_000,
            || {
                std::hint::black_box(etf.schedule(&ready, &ctx));
            },
        );
    }
}

/// Record the simulation-kernel trajectory: `BENCH_hotpath.json` at the
/// working directory (the repo root under `cargo bench`), or wherever
/// `BENCH_OUT` points.  Returns the record so `--write-baseline` can
/// copy it to the baseline path.
fn write_bench_json(
    kernels: &[KernelResult],
    smoke: bool,
    jobs: usize,
    runs: usize,
) -> Json {
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut j = Json::obj();
    j.set("schema", Json::Num(1.0))
        .set("bench", Json::Str("perf_hotpath".into()))
        .set("smoke", Json::Bool(smoke))
        .set("jobs", Json::Num(jobs as f64))
        .set("runs", Json::Num(runs as f64))
        .set("unix_time_s", Json::Num(unix_s as f64))
        .set(
            "kernels",
            Json::Arr(
                kernels
                    .iter()
                    .map(|k| {
                        let mut e = Json::obj();
                        e.set("name", Json::Str(k.name.clone()))
                            .set(
                                "events_per_s",
                                Json::Num(k.events_per_s),
                            )
                            .set("events", Json::Num(k.events as f64))
                            .set("median_s", Json::Num(k.median_s))
                            .set(
                                "sched_overhead_us",
                                Json::Num(k.sched_overhead_us),
                            );
                        let mut prof = Json::obj();
                        for (bucket, frac) in
                            PROFILE_BUCKETS.iter().zip(k.profile_fracs)
                        {
                            prof.set(bucket, Json::Num(frac));
                        }
                        e.set("profile", prof);
                        e
                    })
                    .collect(),
            ),
        );
    let path = std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_hotpath.json".into());
    match std::fs::write(&path, j.to_string_pretty()) {
        Ok(()) => println!("bench record written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    j
}

/// CI regression gate: compare events/s per kernel against a committed
/// baseline JSON (same schema as the emitted record) and exit non-zero
/// on a >20% regression.  A missing baseline records only, as does a
/// baseline recorded in the other smoke/full mode (short smoke runs
/// carry proportionally more fixed per-run cost, so cross-mode
/// events/s ratios would mis-gate in both directions).
fn check_baseline(kernels: &[KernelResult], smoke: bool) {
    let Ok(base_path) = std::env::var("BENCH_BASELINE") else {
        return;
    };
    let base = match Json::parse_file(std::path::Path::new(&base_path)) {
        Ok(j) => j,
        Err(e) => {
            println!(
                "(no usable baseline at {base_path}: {e} — recording only)"
            );
            return;
        }
    };
    let base_smoke = base.get("smoke").and_then(Json::as_bool);
    if base_smoke != Some(smoke) {
        println!(
            "(baseline {base_path} was recorded with smoke={:?}, this \
             run is smoke={smoke} — modes differ, recording only; \
             refresh the baseline in the mode the gate runs in)",
            base_smoke
        );
        return;
    }
    let Some(base_kernels) = base.get("kernels").and_then(Json::as_arr)
    else {
        println!("(baseline {base_path} has no 'kernels' — skipping)");
        return;
    };
    let mut failures = Vec::new();
    for bk in base_kernels {
        let Some(name) = bk.get("name").and_then(Json::as_str) else {
            continue;
        };
        let Some(base_eps) =
            bk.get("events_per_s").and_then(Json::as_f64)
        else {
            continue;
        };
        let Some(cur) = kernels.iter().find(|k| k.name == name) else {
            failures.push(format!("kernel '{name}' missing from run"));
            continue;
        };
        let ratio = cur.events_per_s / base_eps;
        println!(
            "baseline check [{name}]: {:.0} events/s vs baseline {:.0} \
             ({:+.1}%)",
            cur.events_per_s,
            base_eps,
            (ratio - 1.0) * 100.0
        );
        if ratio < 0.80 {
            failures.push(format!(
                "kernel '{name}' regressed {:.1}% (>{:.0}% allowed)",
                (1.0 - ratio) * 100.0,
                20.0
            ));
        }
    }
    if !failures.is_empty() {
        eprintln!("PERF REGRESSION vs {base_path}:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
