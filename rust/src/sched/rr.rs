//! Round-robin scheduler: rotate over supporting PEs.
//!
//! Simple load-spreading baseline (no latency awareness); exercises the
//! plug-and-play interface alongside [`super::random::RandomSched`].

use super::{Assignment, ReadyTask, SchedContext, Scheduler};

#[derive(Debug, Default)]
pub struct RoundRobin {
    cursor: usize,
    decisions: u64,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl Scheduler for RoundRobin {
    fn name(&self) -> &str {
        "rr"
    }

    fn schedule(
        &mut self,
        ready: &[ReadyTask],
        ctx: &dyn SchedContext,
    ) -> Vec<Assignment> {
        let n = ctx.pes().len();
        let mut out = Vec::with_capacity(ready.len());
        for rt in ready {
            // Walk at most n PEs from the cursor to find a supporting one.
            let mut pick = None;
            for k in 0..n {
                let pe = (self.cursor + k) % n;
                if ctx.pes()[pe].available
                    && ctx.exec_us(rt, pe).is_some()
                {
                    pick = Some(pe);
                    self.cursor = (pe + 1) % n;
                    break;
                }
            }
            if let Some(pe) = pick {
                out.push(Assignment { job: rt.job, task: rt.task, pe });
                self.decisions += 1;
            }
        }
        out
    }

    fn report(&self) -> Vec<String> {
        vec![format!("rr: {} decisions", self.decisions)]
    }

    fn decision_counts(&self) -> (u64, u64) {
        (self.decisions, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::{rt, MockCtx};

    #[test]
    fn rotates_over_all_pes() {
        let mut ctx = MockCtx::uniform(3, 0.0);
        for t in 0..6 {
            for p in 0..3 {
                ctx.set_exec(0, t, p, 5.0);
            }
        }
        let mut s = RoundRobin::new();
        let tasks: Vec<_> = (0..6).map(|t| rt(0, t)).collect();
        let a = s.schedule(&tasks, &ctx);
        let pes: Vec<_> = a.iter().map(|x| x.pe).collect();
        assert_eq!(pes, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn skips_unsupported_pes() {
        let mut ctx = MockCtx::uniform(3, 0.0);
        for t in 0..4 {
            ctx.set_exec(0, t, 1, 5.0); // only PE 1 supports anything
        }
        let mut s = RoundRobin::new();
        let tasks: Vec<_> = (0..4).map(|t| rt(0, t)).collect();
        let a = s.schedule(&tasks, &ctx);
        assert!(a.iter().all(|x| x.pe == 1));
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn cursor_persists_across_epochs() {
        let mut ctx = MockCtx::uniform(4, 0.0);
        for t in 0..2 {
            for p in 0..4 {
                ctx.set_exec(0, t, p, 5.0);
            }
        }
        let mut s = RoundRobin::new();
        let a1 = s.schedule(&[rt(0, 0)], &ctx);
        let a2 = s.schedule(&[rt(0, 1)], &ctx);
        assert_eq!(a1[0].pe, 0);
        assert_eq!(a2[0].pe, 1);
    }
}
