//! Content-addressed experiment store (ROADMAP item 2).
//!
//! Every campaign invoked with `--store <dir>` persists its provenance
//! into an on-disk layout keyed by content hashes:
//!
//! ```text
//! <store>/
//!   index.jsonl          append-only manifest index (one row per key)
//!   manifests/<key>.json full run manifests (config hash + workload
//!                        digest + seed + git + counters + result)
//!   points/<pkey>.json   per-point result cache (sweep / fuzz / dse)
//! ```
//!
//! The store participates in telemetry as a [`StoreSink`]: it captures
//! the `run_started` identity, and on `run_finished` finalizes a
//! [`Manifest`] from the aggregated counters plus whatever point keys
//! and result summary the campaign recorded along the way.
//!
//! ## Point cache and determinism
//!
//! Pooled campaigns ([`crate::coordinator::run_sweep_stored`], the
//! fuzz tournament, the DSE evaluator) consult [`ExperimentStore::
//! lookup`] *before* simulating and merge cached results back **in
//! input order**, so a warm rerun executes zero simulations yet
//! reproduces the cold run's report and default telemetry stream
//! byte-for-byte — and 1-vs-8-thread runs leave identical store
//! contents.  Cache-hit statistics live in store-internal atomics
//! (never in [`Counters`] or stdout reports), precisely so hits do not
//! perturb those byte-identity contracts.

pub mod index;
pub mod manifest;
pub mod query;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::app::AppGraph;
use crate::config::SimConfig;
use crate::stats::{
    StoreFsckSummary, StoreGcSummary, StoreVerifySummary,
};
use crate::telemetry::{self, Counters, Event, Sink};
use crate::util::json::Json;
use crate::{Error, Result};

pub use index::{Index, IndexRow};
pub use manifest::{manifest_key, Manifest, MANIFEST_KIND};
pub use query::{Agg, QueryFilter};

/// The `"kind"` tag of point-cache files.
pub const POINT_KIND: &str = "ds3r-point";

// ---------------------------------------------------------------------------
// Hashing
// ---------------------------------------------------------------------------

/// Incremental FNV-1a 64 over raw bytes — the byte-stream counterpart
/// of [`telemetry::config_hash`] (identical constants, identical hex
/// rendering), used where inputs are files rather than strings.
#[derive(Debug, Clone)]
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

fn fold_path(h: &mut Fnv, tag: &str, path: &Path) {
    h.update(tag.as_bytes());
    h.update(b"\0");
    match std::fs::read(path) {
        Ok(bytes) => h.update(&bytes),
        Err(_) => h.update(b"<missing>"),
    }
    h.update(b"\0");
}

/// Digest every workload input feeding a campaign: application DAGs,
/// the recorded trace file, the IL policy artifact, XLA artifacts, and
/// any command-specific extras (scenario / fuzz / DSE / learn config
/// JSON).  `run_started` carries this next to `config_hash`, making
/// store keys content-addressed: editing a trace file changes the key
/// even though the config JSON (which stores only the *path*) does
/// not.
pub fn workload_digest(
    cfg: &SimConfig,
    apps: &[AppGraph],
    extra: &[(&str, String)],
) -> String {
    let mut h = Fnv::new();
    for app in apps {
        h.update(b"app\0");
        h.update(app.name.as_bytes());
        h.update(b"\0");
        h.update(app.to_json().to_string().as_bytes());
        h.update(b"\0");
    }
    if let Some(p) = &cfg.trace_file {
        fold_path(&mut h, "trace_file", p);
    }
    if let Some(p) = &cfg.il_policy {
        fold_path(&mut h, "il_policy", p);
    }
    if let Some(dir) = &cfg.artifacts_dir {
        // `artifacts_dir` is deliberately absent from the canonical
        // config JSON, so its contents must be folded here.
        let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok().map(|e| e.path()))
                    .filter(|p| p.is_file())
                    .collect()
            })
            .unwrap_or_default();
        files.sort();
        for f in &files {
            let name = f
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_default();
            fold_path(&mut h, &format!("artifact:{name}"), f);
        }
    }
    for (k, v) in extra {
        h.update(b"extra\0");
        h.update(k.as_bytes());
        h.update(b"\0");
        h.update(v.as_bytes());
        h.update(b"\0");
    }
    h.hex()
}

/// Point-cache key: one hash over the pair (per-point config hash,
/// workload digest).  Every point entry — sweep, fuzz cell, DSE
/// evaluation — derives its key this way, which is what lets
/// `store verify` re-derive keys from entry content alone.
pub fn point_key(config_hash: &str, workload_digest: &str) -> String {
    telemetry::config_hash(&format!("{config_hash}:{workload_digest}"))
}

/// [`point_key`] for a fully-resolved per-point [`SimConfig`] (the
/// sweep / fuzz shape, where the canonical config JSON *is* the point
/// identity).
pub fn config_point_key(cfg: &SimConfig, workload_digest: &str) -> String {
    let ch = telemetry::config_hash(&cfg.to_json().to_string());
    point_key(&ch, workload_digest)
}

// ---------------------------------------------------------------------------
// Point entries
// ---------------------------------------------------------------------------

/// One cached per-point result: enough to skip the simulation and
/// still merge the report and counters back byte-identically.
#[derive(Debug, Clone, PartialEq)]
pub struct PointEntry {
    /// Which cache population this entry belongs to (`sweep`, `fuzz`,
    /// `dse-eval`) — lookups are kind-checked so populations with
    /// coincidentally equal keys can never cross-contaminate.
    pub kind: String,
    pub key: String,
    /// Hash of the fully-resolved per-point config (or evaluation
    /// identity, for DSE).
    pub config_hash: String,
    pub workload_digest: String,
    /// The point's serialized result (command-specific JSON).
    pub result: Json,
    /// The point's deterministic counter delta.
    pub counters: Counters,
}

impl PointEntry {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", Json::Str(POINT_KIND.into()))
            .set("point_kind", Json::Str(self.kind.clone()))
            .set("key", Json::Str(self.key.clone()))
            .set("config_hash", Json::Str(self.config_hash.clone()))
            .set(
                "workload_digest",
                Json::Str(self.workload_digest.clone()),
            )
            .set("result", self.result.clone())
            .set("counters", self.counters.to_json());
        j
    }

    pub fn from_json(j: &Json) -> Result<PointEntry> {
        if j.get("kind").and_then(Json::as_str) != Some(POINT_KIND) {
            return Err(Error::Json(format!(
                "not a {POINT_KIND} file (missing/foreign kind tag)"
            )));
        }
        Ok(PointEntry {
            kind: j.req_str("point_kind")?.to_string(),
            key: j.req_str("key")?.to_string(),
            config_hash: j.req_str("config_hash")?.to_string(),
            workload_digest: j.req_str("workload_digest")?.to_string(),
            result: j.get("result").cloned().unwrap_or(Json::Null),
            counters: match j.get("counters") {
                Some(c) => Counters::from_json(c)?,
                None => Counters::new(),
            },
        })
    }
}

// ---------------------------------------------------------------------------
// The store
// ---------------------------------------------------------------------------

/// Handle on one on-disk experiment store (see module docs).  Shared
/// `Arc` across the CLI, the [`StoreSink`] and pooled campaign
/// workers; all interior state is synchronized.
#[derive(Debug)]
pub struct ExperimentStore {
    root: PathBuf,
    index: Mutex<Index>,
    /// Point keys touched by the in-flight campaign, recorded by the
    /// campaign driver in canonical input order (never by `lookup` /
    /// `put_point`, whose call order is thread-dependent).
    session_points: Mutex<Vec<String>>,
    /// Result summary the campaign stashes for its manifest.
    pending_result: Mutex<Json>,
    last_manifest: Mutex<Option<String>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ExperimentStore {
    /// Open (creating if necessary) the store rooted at `dir`.
    pub fn open(dir: &Path) -> Result<Arc<ExperimentStore>> {
        std::fs::create_dir_all(dir.join("manifests"))?;
        std::fs::create_dir_all(dir.join("points"))?;
        let index = Index::open(&dir.join("index.jsonl"))?;
        Ok(Arc::new(ExperimentStore {
            root: dir.to_path_buf(),
            index: Mutex::new(index),
            session_points: Mutex::new(Vec::new()),
            pending_result: Mutex::new(Json::Null),
            last_manifest: Mutex::new(None),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }))
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn manifest_path(&self, key: &str) -> PathBuf {
        self.root.join("manifests").join(format!("{key}.json"))
    }

    fn point_path(&self, key: &str) -> PathBuf {
        self.root.join("points").join(format!("{key}.json"))
    }

    /// Atomic (write-then-rename) JSON file write, so a killed
    /// campaign never leaves a truncated entry behind.  Transient IO
    /// errors get a bounded, jitter-free retry (fixed attempt count,
    /// deterministic linear backoff): flaky NFS or an interrupted
    /// syscall doesn't abort a campaign, while a persistently failing
    /// disk still surfaces the last error.  The
    /// [`crate::faultpoint::sites::STORE_WRITE`] site (label = file
    /// name) injects synthetic failures here.
    fn write_json(&self, path: &Path, j: &Json) -> Result<()> {
        const ATTEMPTS: u32 = 3;
        let tmp = path.with_extension("json.tmp");
        let text = j.to_string_pretty();
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_default();
        let mut last = None;
        for attempt in 0..ATTEMPTS {
            if attempt > 0 {
                // Deterministic (jitter-free) linear backoff.
                std::thread::sleep(std::time::Duration::from_millis(
                    5 * attempt as u64,
                ));
            }
            let injected = crate::faultpoint::take_io_error(
                crate::faultpoint::sites::STORE_WRITE,
                &name,
            );
            let res = match injected {
                Some(e) => Err(e),
                None => std::fs::write(&tmp, &text)
                    .and_then(|()| std::fs::rename(&tmp, path)),
            };
            match res {
                Ok(()) => return Ok(()),
                Err(e) => last = Some(e),
            }
        }
        Err(last.expect("at least one write attempt ran").into())
    }

    // ---- point cache ------------------------------------------------------

    /// Consult the point cache.  A hit must carry the expected `kind`;
    /// unreadable or foreign entries count as misses.
    pub fn lookup(&self, key: &str, kind: &str) -> Option<PointEntry> {
        let hit = Json::parse_file(&self.point_path(key))
            .ok()
            .and_then(|j| PointEntry::from_json(&j).ok())
            .filter(|e| e.key == key && e.kind == kind);
        if hit.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Persist one point entry (idempotent overwrite: entries are
    /// deterministic functions of their key).
    pub fn put_point(&self, entry: &PointEntry) -> Result<()> {
        self.write_json(&self.point_path(&entry.key), &entry.to_json())
    }

    /// Record the point keys of the in-flight campaign, in canonical
    /// input order.  Drivers call this once, before the pooled grid
    /// runs, so manifests list identical keys for cold, warm and
    /// partial reruns.
    pub fn record_points(&self, keys: &[String]) {
        if let Ok(mut p) = self.session_points.lock() {
            p.extend(keys.iter().cloned());
        }
    }

    /// Stash the campaign's result summary for its manifest.
    pub fn set_result(&self, result: Json) {
        if let Ok(mut r) = self.pending_result.lock() {
            *r = result;
        }
    }

    /// Point-cache hits of this process so far.
    pub fn session_hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Point-cache misses of this process so far.
    pub fn session_misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    // ---- manifests --------------------------------------------------------

    /// Persist a manifest and index it (idempotent by key).  Returns
    /// the manifest key.
    pub fn put_manifest(&self, m: &Manifest) -> Result<String> {
        let key = m.key();
        self.write_json(&self.manifest_path(&key), &m.to_json())?;
        if let Ok(mut idx) = self.index.lock() {
            idx.append(IndexRow::from_manifest(m))?;
        }
        if let Ok(mut last) = self.last_manifest.lock() {
            *last = Some(key.clone());
        }
        Ok(key)
    }

    /// Key of the manifest most recently written by this process.
    pub fn last_manifest_key(&self) -> Option<String> {
        self.last_manifest.lock().ok().and_then(|l| l.clone())
    }

    /// Load every indexed manifest, in index (append) order.  Rows
    /// whose manifest file is missing or unreadable are skipped —
    /// `store gc` reports and prunes those.
    pub fn manifests(&self) -> Vec<Manifest> {
        let rows: Vec<IndexRow> = self
            .index
            .lock()
            .map(|idx| idx.rows().to_vec())
            .unwrap_or_default();
        rows.iter()
            .filter_map(|r| {
                Json::parse_file(&self.manifest_path(&r.key))
                    .ok()
                    .and_then(|j| Manifest::from_json(&j).ok())
            })
            .collect()
    }

    fn point_files(&self) -> Result<Vec<PathBuf>> {
        let mut files: Vec<PathBuf> = std::fs::read_dir(
            self.root.join("points"),
        )?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
        files.sort();
        Ok(files)
    }

    // ---- maintenance ------------------------------------------------------

    /// Garbage-collect the store: re-index orphaned manifest files
    /// (e.g. a kill between manifest write and index append), drop
    /// index rows whose manifest file vanished, and delete point
    /// entries no surviving manifest references.
    pub fn gc(&self) -> Result<StoreGcSummary> {
        let mut summary = StoreGcSummary::default();

        // Re-index manifest files the index does not know about.
        let mut manifest_files: Vec<PathBuf> = std::fs::read_dir(
            self.root.join("manifests"),
        )?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
        manifest_files.sort();
        if let Ok(mut idx) = self.index.lock() {
            for f in &manifest_files {
                let Ok(j) = Json::parse_file(f) else { continue };
                let Ok(m) = Manifest::from_json(&j) else { continue };
                if idx.append(IndexRow::from_manifest(&m))? {
                    summary.reindexed += 1;
                }
            }
            // Drop rows whose manifest file is gone.
            let manifests_dir = self.root.join("manifests");
            summary.dropped_rows = idx.rewrite(|r| {
                manifests_dir.join(format!("{}.json", r.key)).exists()
            })?;
        }

        // Delete point entries no surviving manifest references.
        let manifests = self.manifests();
        let referenced: std::collections::BTreeSet<&str> = manifests
            .iter()
            .flat_map(|m| m.point_keys.iter().map(String::as_str))
            .collect();
        for f in self.point_files()? {
            let stem = f
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default();
            if referenced.contains(stem.as_str()) {
                summary.kept_points += 1;
            } else {
                std::fs::remove_file(&f)?;
                summary.dropped_points += 1;
            }
        }
        summary.kept_manifests = manifests.len();
        Ok(summary)
    }

    /// Verify store integrity: re-derive every manifest key and point
    /// key from file *content* and report entries whose filename or
    /// recorded key disagrees (bit-rot, hand-edits, hash drift).
    pub fn verify(&self) -> Result<StoreVerifySummary> {
        let mut summary = StoreVerifySummary::default();
        let rows: Vec<IndexRow> = self
            .index
            .lock()
            .map(|idx| idx.rows().to_vec())
            .unwrap_or_default();
        for r in &rows {
            summary.manifests_checked += 1;
            let path = self.manifest_path(&r.key);
            let m = Json::parse_file(&path)
                .and_then(|j| Manifest::from_json(&j));
            match m {
                Ok(m) if m.key() == r.key => {}
                Ok(m) => summary.mismatches.push(format!(
                    "manifest {} re-hashes to {}",
                    r.key,
                    m.key()
                )),
                Err(e) => summary
                    .mismatches
                    .push(format!("manifest {} unreadable: {e}", r.key)),
            }
        }
        for f in self.point_files()? {
            summary.points_checked += 1;
            let stem = f
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default();
            let e = Json::parse_file(&f)
                .and_then(|j| PointEntry::from_json(&j));
            match e {
                Ok(e) => {
                    let derived =
                        point_key(&e.config_hash, &e.workload_digest);
                    if e.key != stem || derived != e.key {
                        summary.mismatches.push(format!(
                            "point {stem} re-hashes to {derived}"
                        ));
                    }
                }
                Err(e) => summary
                    .mismatches
                    .push(format!("point {stem} unreadable: {e}")),
            }
        }
        Ok(summary)
    }

    /// Crash/corruption recovery: quarantine every manifest or point
    /// file that is unparseable — or whose content re-hashes to a
    /// different key than its filename claims — into
    /// `<store>/quarantine/`, re-index orphaned manifests, and drop
    /// index rows whose manifest is gone.  Nothing is deleted: the
    /// quarantined originals stay on disk for inspection.  After
    /// `fsck`, [`ExperimentStore::verify`] passes on what remains.
    ///
    /// A torn trailing `index.jsonl` line is already salvaged by
    /// [`ExperimentStore::open`]; the summary reports whether that
    /// happened for this handle.
    pub fn fsck(&self) -> Result<StoreFsckSummary> {
        let mut summary = StoreFsckSummary::default();
        let qdir = self.root.join("quarantine");
        let quarantine = |f: &Path| -> Result<()> {
            std::fs::create_dir_all(&qdir)?;
            let name = f
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_default();
            std::fs::rename(f, qdir.join(name))?;
            Ok(())
        };

        // Manifests: quarantine undecodable / key-drifted files,
        // re-index surviving orphans.
        let mut manifest_files: Vec<PathBuf> = std::fs::read_dir(
            self.root.join("manifests"),
        )?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
        manifest_files.sort();
        if let Ok(mut idx) = self.index.lock() {
            summary.index_tail_salvaged = idx.salvaged_tail();
            for f in &manifest_files {
                let stem = f
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_default();
                let m = Json::parse_file(f)
                    .and_then(|j| Manifest::from_json(&j));
                match m {
                    Ok(m) if m.key() == stem => {
                        summary.manifests_kept += 1;
                        if idx.append(IndexRow::from_manifest(&m))? {
                            summary.reindexed += 1;
                        }
                    }
                    _ => {
                        telemetry::diag("store", || {
                            format!(
                                "fsck: quarantined manifest {stem}"
                            )
                        });
                        quarantine(f)?;
                        summary.manifests_quarantined += 1;
                    }
                }
            }
            // Drop rows whose manifest file is gone (quarantined just
            // now, or lost to a crash).
            let manifests_dir = self.root.join("manifests");
            summary.index_rows_dropped = idx.rewrite(|r| {
                manifests_dir.join(format!("{}.json", r.key)).exists()
            })?;
        }

        // Points: quarantine undecodable / key-drifted entries.
        for f in self.point_files()? {
            let stem = f
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default();
            let e = Json::parse_file(&f)
                .and_then(|j| PointEntry::from_json(&j));
            let sound = e.is_ok_and(|e| {
                e.key == stem
                    && point_key(&e.config_hash, &e.workload_digest)
                        == e.key
            });
            if sound {
                summary.points_kept += 1;
            } else {
                telemetry::diag("store", || {
                    format!("fsck: quarantined point {stem}")
                });
                quarantine(&f)?;
                summary.points_quarantined += 1;
            }
        }
        Ok(summary)
    }

    /// Drain the per-campaign state into a finalized manifest (the
    /// [`StoreSink`] `run_finished` path).
    fn finalize(&self, id: &RunIdentity, counters: &Counters) {
        let point_keys = self
            .session_points
            .lock()
            .map(|mut p| std::mem::take(&mut *p))
            .unwrap_or_default();
        let result = self
            .pending_result
            .lock()
            .map(|mut r| std::mem::replace(&mut *r, Json::Null))
            .unwrap_or(Json::Null);
        let m = Manifest {
            cmd: id.cmd.clone(),
            config_hash: id.config_hash.clone(),
            workload_digest: id.workload_digest.clone(),
            seed: id.seed,
            scheduler: id.scheduler.clone(),
            git: id.git.clone(),
            counters: counters.clone(),
            point_keys,
            result,
        };
        // Sinks cannot surface errors (and must not re-enter the
        // global telemetry dispatcher); the CLI reports the outcome
        // via `last_manifest_key`.
        let _ = self.put_manifest(&m);
    }
}

// ---------------------------------------------------------------------------
// Telemetry integration
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct RunIdentity {
    cmd: String,
    config_hash: String,
    workload_digest: String,
    seed: u64,
    scheduler: String,
    git: Option<String>,
}

/// Telemetry sink that materializes each `run_started`/`run_finished`
/// pair into a stored [`Manifest`].  Fanned out next to the JSONL and
/// progress sinks, so `--store` composes with every other
/// observability flag.
pub struct StoreSink {
    store: Arc<ExperimentStore>,
    identity: Mutex<Option<RunIdentity>>,
}

impl StoreSink {
    pub fn new(store: Arc<ExperimentStore>) -> StoreSink {
        StoreSink { store, identity: Mutex::new(None) }
    }
}

impl Sink for StoreSink {
    fn emit(&self, ev: &Event) {
        match ev {
            Event::RunStarted {
                cmd,
                config_hash,
                workload_digest,
                seed,
                scheduler,
                git,
            } => {
                if let Ok(mut id) = self.identity.lock() {
                    *id = Some(RunIdentity {
                        cmd: cmd.clone(),
                        config_hash: config_hash.clone(),
                        workload_digest: workload_digest.clone(),
                        seed: *seed,
                        scheduler: scheduler.clone(),
                        git: git.clone(),
                    });
                }
            }
            Event::RunFinished { counters, .. } => {
                let id = self
                    .identity
                    .lock()
                    .ok()
                    .and_then(|mut id| id.take());
                if let Some(id) = id {
                    self.store.finalize(&id, counters);
                }
            }
            _ => {}
        }
    }
}

/// What pooled campaign drivers need to consult the cache: the shared
/// store handle plus the campaign's workload digest.
#[derive(Debug, Clone)]
pub struct StoreCtx {
    pub store: Arc<ExperimentStore>,
    pub workload_digest: String,
}

// ---------------------------------------------------------------------------
// Global registry (CLI wiring)
// ---------------------------------------------------------------------------

static GLOBAL_STORE: Mutex<Option<Arc<ExperimentStore>>> =
    Mutex::new(None);

/// Install (or clear, with `None`) the process-global store handle —
/// `init_telemetry` does this from `--store`; tests clear it for
/// isolation.
pub fn set_global(store: Option<Arc<ExperimentStore>>) {
    if let Ok(mut g) = GLOBAL_STORE.lock() {
        *g = store;
    }
}

/// A clone of the installed global store handle, if any.
pub fn global() -> Option<Arc<ExperimentStore>> {
    GLOBAL_STORE.lock().ok().and_then(|g| g.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::suite;

    fn temp_store(tag: &str) -> (PathBuf, Arc<ExperimentStore>) {
        let dir = std::env::temp_dir()
            .join(format!("ds3r_store_{tag}_test"));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ExperimentStore::open(&dir).unwrap();
        (dir, store)
    }

    fn entry(key: &str, kind: &str) -> PointEntry {
        let mut counters = Counters::new();
        counters.add("runs", 1);
        let mut result = Json::obj();
        result.set("avg_latency_us", Json::Num(123.5));
        PointEntry {
            kind: kind.into(),
            key: key.into(),
            config_hash: "deadbeefdeadbeef".into(),
            workload_digest: "feedfacefeedface".into(),
            result,
            counters,
        }
    }

    #[test]
    fn point_cache_round_trip_and_kind_isolation() {
        let (dir, store) = temp_store("points");
        let key = point_key("deadbeefdeadbeef", "feedfacefeedface");
        let mut e = entry(&key, "sweep");
        e.key = key.clone();
        store.put_point(&e).unwrap();
        assert_eq!(store.lookup(&key, "sweep"), Some(e.clone()));
        // Foreign kind and absent key are both misses.
        assert_eq!(store.lookup(&key, "fuzz"), None);
        assert_eq!(store.lookup("0000000000000000", "sweep"), None);
        assert_eq!(store.session_hits(), 1);
        assert_eq!(store.session_misses(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn workload_digest_tracks_trace_file_content() {
        let dir =
            std::env::temp_dir().join("ds3r_store_digest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("trace.json");
        std::fs::write(&trace, b"{\"jobs\":[1,2,3]}").unwrap();

        let apps =
            vec![suite::wifi_tx(suite::WifiParams { symbols: 2 })];
        let mut cfg = SimConfig::default();
        cfg.trace_file = Some(trace.clone());

        let d1 = workload_digest(&cfg, &apps, &[]);
        // Pure function of content: same inputs, same digest.
        assert_eq!(d1, workload_digest(&cfg, &apps, &[]));
        // Editing the trace file changes the key even though the
        // config JSON (which records only the path) is unchanged.
        std::fs::write(&trace, b"{\"jobs\":[1,2,3,4]}").unwrap();
        let d2 = workload_digest(&cfg, &apps, &[]);
        assert_ne!(d1, d2);
        // Extras (scenario / fuzz config JSON) are folded in too.
        let d3 = workload_digest(
            &cfg,
            &apps,
            &[("fuzz-config", "{\"cases\":9}".into())],
        );
        assert_ne!(d2, d3);
        // The per-point cache key inherits the sensitivity.
        assert_ne!(
            config_point_key(&cfg, &d1),
            config_point_key(&cfg, &d2)
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn store_sink_materializes_manifest_from_run_pair() {
        let (dir, store) = temp_store("sink");
        store.record_points(&["k0".to_string(), "k1".to_string()]);
        let mut result = Json::obj();
        result.set("points", Json::Num(2.0));
        store.set_result(result.clone());

        let sink = StoreSink::new(store.clone());
        sink.emit(&Event::RunStarted {
            cmd: "sweep".into(),
            config_hash: "aaaaaaaaaaaaaaaa".into(),
            workload_digest: "bbbbbbbbbbbbbbbb".into(),
            seed: 42,
            scheduler: "etf".into(),
            git: None,
        });
        let mut counters = Counters::new();
        counters.add("runs", 2);
        sink.emit(&Event::RunFinished {
            cmd: "sweep".into(),
            counters: counters.clone(),
            wall_s: 0.5,
        });

        let key = store.last_manifest_key().expect("manifest written");
        let manifests = store.manifests();
        assert_eq!(manifests.len(), 1);
        let m = &manifests[0];
        assert_eq!(m.key(), key);
        assert_eq!(m.cmd, "sweep");
        assert_eq!(m.counters, counters);
        assert_eq!(m.point_keys, vec!["k0", "k1"]);
        assert_eq!(m.result, result);
        // The pair drained the session state; a second campaign in the
        // same process starts clean.
        sink.emit(&Event::RunStarted {
            cmd: "run".into(),
            config_hash: "cccccccccccccccc".into(),
            workload_digest: "bbbbbbbbbbbbbbbb".into(),
            seed: 7,
            scheduler: "met".into(),
            git: None,
        });
        sink.emit(&Event::RunFinished {
            cmd: "run".into(),
            counters: Counters::new(),
            wall_s: 0.1,
        });
        let manifests = store.manifests();
        assert_eq!(manifests.len(), 2);
        assert!(manifests[1].point_keys.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_drops_dangling_and_verify_flags_tampering() {
        let (dir, store) = temp_store("gc");
        // A referenced point, a dangling point, and one manifest.
        let ch = "deadbeefdeadbeef";
        let wd = "feedfacefeedface";
        let key = point_key(ch, wd);
        let mut good = entry(&key, "sweep");
        good.key = key.clone();
        store.put_point(&good).unwrap();
        let dangling_key = point_key("0123456789abcdef", wd);
        let mut dangling = entry(&dangling_key, "sweep");
        dangling.key = dangling_key.clone();
        store.put_point(&dangling).unwrap();

        let m = Manifest {
            cmd: "sweep".into(),
            config_hash: ch.into(),
            workload_digest: wd.into(),
            seed: 1,
            scheduler: "etf".into(),
            git: None,
            counters: Counters::new(),
            point_keys: vec![key.clone()],
            result: Json::Null,
        };
        store.put_manifest(&m).unwrap();

        let g = store.gc().unwrap();
        assert_eq!(g.kept_manifests, 1);
        assert_eq!(g.kept_points, 1);
        assert_eq!(g.dropped_points, 1);
        assert_eq!(g.dropped_rows, 0);
        assert!(store.lookup(&dangling_key, "sweep").is_none());

        let v = store.verify().unwrap();
        assert!(v.ok(), "clean store must verify: {:?}", v.mismatches);
        assert_eq!(v.manifests_checked, 1);
        assert_eq!(v.points_checked, 1);

        // Tamper with the point's identity fields on disk.
        let mut bad = good.clone();
        bad.config_hash = "0000000000000000".into();
        let path = dir.join("points").join(format!("{key}.json"));
        std::fs::write(&path, bad.to_json().to_string_pretty())
            .unwrap();
        let v = store.verify().unwrap();
        assert!(!v.ok());
        assert_eq!(v.mismatches.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fsck_quarantines_corruption_and_verify_passes_after() {
        let (dir, store) = temp_store("fsck");
        // One healthy manifest + referenced point.
        let ch = "deadbeefdeadbeef";
        let wd = "feedfacefeedface";
        let pkey = point_key(ch, wd);
        let mut good = entry(&pkey, "sweep");
        good.key = pkey.clone();
        store.put_point(&good).unwrap();
        let m1 = Manifest {
            cmd: "sweep".into(),
            config_hash: ch.into(),
            workload_digest: wd.into(),
            seed: 1,
            scheduler: "etf".into(),
            git: None,
            counters: Counters::new(),
            point_keys: vec![pkey.clone()],
            result: Json::Null,
        };
        store.put_manifest(&m1).unwrap();
        // A second manifest, then corrupt its file in place (torn
        // write / bit-rot).
        let mut m2 = m1.clone();
        m2.seed = 2;
        let k2 = store.put_manifest(&m2).unwrap();
        std::fs::write(
            dir.join("manifests").join(format!("{k2}.json")),
            "{ torn",
        )
        .unwrap();
        // And one garbage point file.
        std::fs::write(
            dir.join("points").join("0000000000000bad.json"),
            "not json at all",
        )
        .unwrap();

        let s = store.fsck().unwrap();
        assert!(!s.clean());
        assert_eq!(s.manifests_kept, 1);
        assert_eq!(s.manifests_quarantined, 1);
        assert_eq!(s.points_kept, 1);
        assert_eq!(s.points_quarantined, 1);
        assert_eq!(s.index_rows_dropped, 1);
        // Quarantined originals are preserved, not deleted.
        assert!(dir
            .join("quarantine")
            .join(format!("{k2}.json"))
            .exists());
        assert!(dir
            .join("quarantine")
            .join("0000000000000bad.json")
            .exists());
        // What remains verifies clean.
        let v = store.verify().unwrap();
        assert!(v.ok(), "post-fsck verify: {:?}", v.mismatches);
        assert_eq!(store.manifests().len(), 1);
        // A second fsck finds nothing left to repair.
        let s2 = store.fsck().unwrap();
        assert!(s2.clean(), "{s2:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn store_writes_retry_through_injected_transient_errors() {
        let (dir, store) = temp_store("retry");
        let ch = "0123456789abcdef";
        let wd = "fedcba9876543210";
        let key = point_key(ch, wd);
        let mut e = entry(&key, "sweep");
        e.key = key.clone();
        e.config_hash = ch.into();
        e.workload_digest = wd.into();
        let fname = format!("{key}.json");
        // Two transient failures: the third attempt lands the write.
        let _g = crate::faultpoint::Armed::new(
            crate::faultpoint::sites::STORE_WRITE,
            &fname,
            crate::faultpoint::Fault::IoError { times: 2 },
        );
        store.put_point(&e).unwrap();
        assert_eq!(store.lookup(&key, "sweep"), Some(e.clone()));
        // More failures than attempts: the write gives up with the
        // last error.
        crate::faultpoint::arm(
            crate::faultpoint::sites::STORE_WRITE,
            &fname,
            crate::faultpoint::Fault::IoError { times: 9 },
        );
        assert!(store.put_point(&e).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_reindexes_orphaned_manifest_files() {
        let (dir, store) = temp_store("reindex");
        let m = Manifest {
            cmd: "run".into(),
            config_hash: "aa".into(),
            workload_digest: "bb".into(),
            seed: 3,
            scheduler: "etf".into(),
            git: None,
            counters: Counters::new(),
            point_keys: Vec::new(),
            result: Json::Null,
        };
        // Simulate a kill between manifest write and index append:
        // drop the file in place without touching the index.
        let key = m.key();
        std::fs::write(
            dir.join("manifests").join(format!("{key}.json")),
            m.to_json().to_string_pretty(),
        )
        .unwrap();
        assert!(store.manifests().is_empty());
        let g = store.gc().unwrap();
        assert_eq!(g.reindexed, 1);
        assert_eq!(store.manifests().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
