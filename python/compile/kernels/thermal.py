"""L1 Pallas kernel: batched RC thermal-network step for DTPM exploration.

The DS3R framework advances an RC thermal network every DTPM epoch and,
during design-space exploration, evaluates K candidate DVFS settings at
once.  The hot-spot is the batched affine state update

    T_next = T @ A^T + P @ B^T

with a temperature-dependent leakage correction folded into P:

    P_leak[k, p] = k1[p] * V[k, p] * exp(k2[p] * T_pe[k, p])
    P_total      = P_dyn + P_leak

Hardware adaptation (paper targets embedded SoCs, we target TPU-style
execution; see DESIGN.md §Hardware-Adaptation): the HotSpot-style sparse
stencil is recast as dense MXU-shaped matmuls over a K-batch so a single
kernel invocation fills the systolic array instead of K tiny matvecs.

Shapes are the fixed AOT contract (DESIGN.md §5):
    K = 16 candidate settings, N = 32 thermal nodes, P = 16 PEs.
All operands fit in VMEM simultaneously (< 24 KiB), so the BlockSpec is
whole-operand with a single grid step; interpret=True for CPU PJRT.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed AOT contract dimensions (DESIGN.md §5).
K = 16  # candidate DVFS settings evaluated per call (batch)
N = 32  # thermal nodes (padded; real platform uses 18)
P = 16  # PEs (padded; Table-2 platform uses 14)


def _dtpm_kernel(t_ref, a_ref, b_ref, pd_ref, v_ref, k1_ref, k2_ref,
                 pe_node_ref, t_out_ref, pleak_out_ref, ptot_out_ref):
    """Fused leakage + power-injection + RC update.

    All refs are whole-operand VMEM blocks.  The two matmuls are the MXU
    work; the leakage exponential is VPU elementwise work fused in the
    same kernel so P_total never round-trips through HBM.
    """
    t = t_ref[...]                      # [K, N]
    a = a_ref[...]                      # [N, N]
    b = b_ref[...]                      # [N, P]
    pd = pd_ref[...]                    # [K, P]
    v = v_ref[...]                      # [K, P]
    k1 = k1_ref[...]                    # [1, P]
    k2 = k2_ref[...]                    # [1, P]
    pe_node = pe_node_ref[...]          # [P, N] one-hot: PE -> thermal node

    # Temperature seen by each PE: gather via one-hot matmul (MXU-friendly,
    # avoids dynamic gather which Mosaic lowers poorly).
    t_pe = t @ pe_node.T                # [K, P]

    # Leakage: k1 * V * exp(k2 * T) (subthreshold model, [Bhat 2018]).
    p_leak = k1 * v * jnp.exp(k2 * t_pe)
    p_tot = pd + p_leak

    # RC state update. A is I + dt*G/C (discretized), B is dt/C injection.
    t_next = t @ a.T + p_tot @ b.T

    t_out_ref[...] = t_next
    pleak_out_ref[...] = p_leak
    ptot_out_ref[...] = p_tot


@functools.partial(jax.jit, static_argnames=())
def dtpm_step(t, a, b, pd, v, k1, k2, pe_node):
    """Batched DTPM thermal/power step via the Pallas kernel.

    Args:
      t:  [K, N] node temperatures (°C above ambient).
      a:  [N, N] discretized thermal system matrix.
      b:  [N, P] discretized power-injection matrix.
      pd: [K, P] dynamic power per PE (W).
      v:  [K, P] PE voltages (V).
      k1: [1, P] leakage linear coefficient.
      k2: [1, P] leakage exponential coefficient (1/°C).
      pe_node: [P, N] one-hot mapping PE -> thermal node.

    Returns:
      (t_next [K, N], p_leak [K, P], p_total [K, P])
    """
    out_shapes = (
        jax.ShapeDtypeStruct((K, N), jnp.float32),
        jax.ShapeDtypeStruct((K, P), jnp.float32),
        jax.ShapeDtypeStruct((K, P), jnp.float32),
    )
    # Whole-operand blocks: total VMEM footprint is
    #   K*N + N*N + N*P + 4*K*P + 2*P + P*N  floats ≈ 5.9 K f32 ≈ 24 KiB,
    # comfortably inside VMEM; a single grid step keeps the HBM<->VMEM
    # schedule to one load/store per operand.
    return pl.pallas_call(
        _dtpm_kernel,
        out_shape=out_shapes,
        interpret=True,  # CPU-PJRT execution path; real TPU would drop this
    )(t, a, b, pd, v, k1, k2, pe_node)
