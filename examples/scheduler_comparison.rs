//! Scheduler comparison — the paper's Figure-3 experiment as a library
//! program: sweep MET / ETF / ILP-table (plus HEFT as an extension)
//! across job injection rates and plot average job execution time.
//!
//! ```sh
//! cargo run --release --example scheduler_comparison
//! ```

use ds3r::app::suite::{self, WifiParams};
use ds3r::config::SimConfig;
use ds3r::coordinator::{self};
use ds3r::platform::Platform;
use ds3r::util::plot;

fn main() {
    let platform = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];

    let mut base = SimConfig::default();
    base.max_jobs = 600;
    base.warmup_jobs = 60;
    base.max_sim_us = 5_000_000.0;

    let schedulers = ["met", "etf", "ilp", "heft"];
    let rates: Vec<f64> =
        (1..=10).map(|r| r as f64).collect();
    let points =
        coordinator::fig3_points(&schedulers, &rates, base.seed);

    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let results =
        coordinator::run_sweep(&platform, &apps, &base, &points, threads)
            .expect("sweep runs");

    let mut rows = Vec::new();
    for r in &results {
        rows.push(vec![
            r.point.scheduler.clone(),
            format!("{:.0}", r.point.rate_per_ms),
            format!("{:.1}", r.avg_latency_us),
            format!("{:.1}", r.p95_latency_us),
            format!("{:.2}", r.energy_per_job_mj),
        ]);
    }
    println!(
        "{}",
        plot::ascii_table(
            &["scheduler", "jobs/ms", "avg us", "p95 us", "mJ/job"],
            &rows
        )
    );
    let series = coordinator::latency_series(&results);
    println!(
        "{}",
        plot::ascii_chart(
            "Figure 3: avg job execution time vs injection rate",
            "jobs/ms",
            "us",
            &series,
            72,
            22
        )
    );
    println!("{}", ds3r::cli::fig3_shape_analysis(&results, &rates));
}
