//! Quickstart: simulate WiFi-TX jobs on the paper's Table-2 SoC and
//! print the standard report.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ds3r::app::suite::{self, WifiParams};
use ds3r::config::SimConfig;
use ds3r::platform::Platform;
use ds3r::sim::Simulation;

fn main() {
    // 1. A platform from the resource database: 4x A15 + 4x A7 +
    //    2x scrambler accelerator + 4x FFT accelerator (paper Table 2).
    let platform = Platform::table2_soc();

    // 2. A workload: the WiFi transmitter of Figure 2, profiled with the
    //    Table-1 execution times.
    let apps = vec![suite::wifi_tx(WifiParams::default())];

    // 3. Simulation parameters: ETF scheduler, Poisson arrivals at
    //    3 jobs/ms, 1000 jobs.
    let mut cfg = SimConfig::default();
    cfg.scheduler = "etf".into();
    cfg.injection_rate_per_ms = 3.0;
    cfg.max_jobs = 1000;
    cfg.warmup_jobs = 100;
    cfg.capture_gantt = true;

    // 4. Run and report.
    let report = Simulation::build(&platform, &apps, &cfg)
        .expect("valid configuration")
        .run();
    println!("{}", report.summary());
    println!(
        "{}",
        report.gantt_ascii(&platform, &apps, (0.0, 1500.0), 100)
    );
}
