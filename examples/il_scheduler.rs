//! Learned runtime resource management end-to-end: train an
//! imitation-learning scheduler against an ETF oracle on a mixed
//! wireless + radar workload (WiFi-TX + pulse Doppler), write the
//! deployable policy artifact, and evaluate it against the oracle and
//! the random/round-robin baselines — the "dynamic resource management"
//! pillar of the paper made learnable (DS3 journal version,
//! arXiv:2003.09016; CEDR, arXiv:2204.08962).
//!
//! ```sh
//! cargo run --release --example il_scheduler
//! ds3r run --sched il --il-policy il_policy.json   # deploy it
//! ```
//!
//! Environment knobs (the CI smoke job shrinks the budget with these,
//! mirroring the `DSE_*` knobs of `design_space.rs`):
//! * `LEARN_ROUNDS`  — collection/training rounds (default 2; 1 =
//!   behavioural cloning, more adds DAgger rounds)
//! * `LEARN_EPOCHS`  — SGD epochs per training pass (default 10)
//! * `LEARN_JOBS`    — jobs per collection/eval simulation (default 150)
//! * `LEARN_THREADS` — fan-out threads (default: all cores)
//!
//! The example exits non-zero unless the trained policy beats the
//! `random` baseline on mean latency — the same gate CI enforces.

use ds3r::app::suite::{self, RadarParams, WifiParams};
use ds3r::learn::{self, LearnConfig};
use ds3r::platform::Platform;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let platform = Platform::table2_soc();
    let apps = vec![
        suite::wifi_tx(WifiParams { symbols: 8 }),
        suite::pulse_doppler(RadarParams { pulses: 8 }),
    ];

    let mut lc = LearnConfig::default();
    lc.oracle = "etf".into();
    lc.rounds = env_usize("LEARN_ROUNDS", 2);
    lc.epochs = env_usize("LEARN_EPOCHS", 10);
    lc.threads = env_usize("LEARN_THREADS", 0);
    lc.sim.max_jobs = env_usize("LEARN_JOBS", 150);
    lc.sim.warmup_jobs = lc.sim.max_jobs / 10;

    println!(
        "Imitation learning on the Table-2 SoC — WiFi-TX + pulse-Doppler \
         mix, oracle '{}'",
        lc.oracle
    );
    println!(
        "grid: seeds {:?} x rates {:?} jobs/ms, {} round(s) x {} SGD \
         epochs\n",
        lc.seeds, lc.rates_per_ms, lc.rounds, lc.epochs
    );

    let (model, summary) = learn::train_policy(&platform, &apps, &lc)
        .expect("training pipeline completes");
    println!(
        "trained on {} demonstrations over {} round(s){}",
        summary.samples,
        summary.rounds,
        summary
            .agreement
            .map(|a| format!(
                ", last-round oracle agreement {:.1}%",
                a * 100.0
            ))
            .unwrap_or_default()
    );

    let artifact = std::path::Path::new("il_policy.json");
    model.save(artifact).expect("policy artifact written");
    println!("policy artifact -> {}\n", artifact.display());

    let report = learn::evaluate(&platform, &apps, &lc, &model)
        .expect("evaluation completes");
    println!(
        "{:<10} {:>12} {:>10} {:>12} {:>12}",
        "scheduler", "mean us", "mJ/job", "done", "fallbacks"
    );
    for row in &report.rows {
        println!(
            "{:<10} {:>12.1} {:>10.2} {:>7}/{:<6} {:>12}",
            row.scheduler,
            row.mean_latency_us,
            row.energy_per_job_mj,
            row.completed,
            row.injected,
            if row.decisions > 0 {
                format!("{}/{}", row.fallbacks, row.decisions)
            } else {
                "-".into()
            }
        );
    }
    println!(
        "\ndecision agreement with the oracle: {:.1}% over {} grid points",
        report.agreement * 100.0,
        report.grid_points
    );

    let il = report.row("il").expect("il row");
    let oracle = report.row(&lc.oracle).expect("oracle row");
    let random = report.row("random").expect("random row");
    println!(
        "il vs oracle: {:.1} vs {:.1} us ({:+.1}%)",
        il.mean_latency_us,
        oracle.mean_latency_us,
        (il.mean_latency_us / oracle.mean_latency_us - 1.0) * 100.0
    );
    // The CI gate: a learned policy must beat the random baseline.
    assert!(
        il.mean_latency_us < random.mean_latency_us,
        "learned policy ({:.1} us) does not beat random ({:.1} us)",
        il.mean_latency_us,
        random.mean_latency_us
    );
    println!("gate: il beats random on mean latency — OK");
}
