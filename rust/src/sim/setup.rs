//! Immutable shared simulation setup: everything derivable from
//! `(platform, apps)` alone — independent of any single run's
//! [`crate::config::SimConfig`] — built once and shared by every
//! [`super::SimWorker`] evaluating points of a grid.
//!
//! Grid workloads (`run_sweep`, `run_scenario_sweep`, the DSE
//! evaluator's seeds×scenarios grid, `learn collect/train/eval`) used
//! to pay full `Simulation::build` cost — exec-table, NoC, RC and
//! arrival-template construction plus a few dozen buffer allocations —
//! for every grid point.  [`SimSetup`] hoists the immutable share of
//! that cost out of the per-point loop; the mutable remainder lives in
//! a reusable [`super::SimWorker`] whose `reset` rewinds state without
//! freeing buffers.
//!
//! The platform and workload are held as [`Cow`]s: sweep-style callers
//! borrow them (zero copies), while the DSE evaluator — whose platforms
//! are decoded per genome and must outlive no one — moves an owned
//! [`Platform`] in via [`SimSetup::with_owned_platform`].

use std::borrow::Cow;

use crate::app::AppGraph;
use crate::config::SimConfig;
use crate::noc::NocModel;
use crate::platform::Platform;
use crate::sched::ilp::ExecTable;
use crate::thermal::RcModel;
use crate::{Error, Result};

/// Immutable, shareable setup for simulations of one `(platform, apps)`
/// pair.  Construction performs the platform/workload compatibility
/// validation once; workers trust it.
pub struct SimSetup<'a> {
    platform: Cow<'a, Platform>,
    apps: Cow<'a, [AppGraph]>,
    /// Per-app execution-time lookup tables (task × PE).
    pub(crate) exec_tables: Vec<ExecTable>,
    /// Per-PE cluster index (flattened from the platform).
    pub(crate) pe_cluster: Vec<usize>,
    /// Per-PE class nominal frequency (MHz).
    pub(crate) pe_nominal_mhz: Vec<f64>,
    /// Initial per-task predecessor counts per app (arrival template).
    pub(crate) app_pred_template: Vec<Vec<u16>>,
    /// Source-task indices per app.
    pub(crate) app_sources: Vec<Vec<usize>>,
    /// NoC topology template (hop table precomputed; congestion off).
    /// Workers clone it and flip congestion per their config.
    pub(crate) noc_template: NocModel,
    /// RC thermal model discretized at the *base* config's DTPM epoch
    /// (the common case across a grid).  Workers clone it when their
    /// epoch matches and rebuild — the forced eager path — when not.
    pub(crate) rc_template: RcModel,
}

impl<'a> SimSetup<'a> {
    /// Borrowing constructor: the platform and workload outlive the
    /// setup (sweeps, scenario grids, the learn pipeline).
    pub fn new(
        platform: &'a Platform,
        apps: &'a [AppGraph],
        base: &SimConfig,
    ) -> Result<SimSetup<'a>> {
        Self::build(Cow::Borrowed(platform), Cow::Borrowed(apps), base)
    }

    /// Owning-platform constructor for callers whose platform is built
    /// per evaluation (the DSE evaluator decodes one per genome) while
    /// the workload is shared.
    pub fn with_owned_platform(
        platform: Platform,
        apps: &'a [AppGraph],
        base: &SimConfig,
    ) -> Result<SimSetup<'a>> {
        Self::build(Cow::Owned(platform), Cow::Borrowed(apps), base)
    }

    fn build(
        platform: Cow<'a, Platform>,
        apps: Cow<'a, [AppGraph]>,
        base: &SimConfig,
    ) -> Result<SimSetup<'a>> {
        if apps.is_empty() {
            return Err(Error::Sim("no applications in workload".into()));
        }
        // Every app must be runnable on this platform.
        for app in apps.iter() {
            for task in &app.tasks {
                let supported = platform
                    .classes
                    .iter()
                    .any(|c| task.exec_us.contains_key(&c.name));
                if !supported {
                    return Err(Error::Sim(format!(
                        "task '{}' of app '{}' runs on no PE class of \
                         platform '{}'",
                        task.name, app.name, platform.name
                    )));
                }
            }
        }
        let p: &Platform = &platform;
        let exec_tables =
            apps.iter().map(|a| ExecTable::new(a, p)).collect();
        let pe_cluster: Vec<usize> =
            p.pes.iter().map(|pe| pe.cluster).collect();
        let pe_nominal_mhz: Vec<f64> = p
            .pes
            .iter()
            .map(|pe| p.classes[pe.class].nominal_mhz)
            .collect();
        let app_pred_template: Vec<Vec<u16>> = apps
            .iter()
            .map(|a| {
                a.tasks.iter().map(|t| t.preds.len() as u16).collect()
            })
            .collect();
        let app_sources: Vec<Vec<usize>> =
            apps.iter().map(|a| a.sources()).collect();
        let noc_template = NocModel::new(p, false);
        let rc_template = RcModel::new(p, base.dtpm.epoch_us);
        Ok(SimSetup {
            exec_tables,
            pe_cluster,
            pe_nominal_mhz,
            app_pred_template,
            app_sources,
            noc_template,
            rc_template,
            platform,
            apps,
        })
    }

    /// The platform every worker of this setup simulates.
    pub fn platform(&self) -> &Platform {
        &self.platform
    }

    /// The application mix every worker of this setup injects.
    pub fn apps(&self) -> &[AppGraph] {
        &self.apps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::suite::{self, WifiParams};

    #[test]
    fn setup_rejects_empty_and_unsupported_workloads() {
        let p = Platform::table2_soc();
        let cfg = SimConfig::default();
        assert!(SimSetup::new(&p, &[], &cfg).is_err());
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        assert!(SimSetup::new(&p, &apps, &cfg).is_ok());
    }

    #[test]
    fn owned_platform_setup_matches_borrowed() {
        let p = Platform::table2_soc();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let cfg = SimConfig::default();
        let borrowed = SimSetup::new(&p, &apps, &cfg).unwrap();
        let owned =
            SimSetup::with_owned_platform(p.clone(), &apps, &cfg).unwrap();
        assert_eq!(borrowed.pe_cluster, owned.pe_cluster);
        assert_eq!(borrowed.pe_nominal_mhz, owned.pe_nominal_mhz);
        assert_eq!(borrowed.app_pred_template, owned.app_pred_template);
        assert_eq!(borrowed.app_sources, owned.app_sources);
        assert_eq!(borrowed.platform().name, owned.platform().name);
    }
}
