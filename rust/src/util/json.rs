//! Minimal JSON parser/serializer.
//!
//! The offline build has no `serde`/`serde_json`, and DS3R needs JSON for
//! its config system, artifact manifests and golden-vector tests, so this
//! module implements the subset of RFC 8259 the framework uses: objects,
//! arrays, strings (with escapes), numbers, booleans, null.  Numbers are
//! held as `f64` (all DS3R quantities are physical scalars).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A JSON value.  Objects use `BTreeMap` for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ---------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    // ---- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0)
            .map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Typed lookup helpers with contextual error messages — the config
    /// loader uses these so a bad config names the offending key.
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key).and_then(Json::as_f64).ok_or_else(|| {
            Error::Json(format!("expected number at key '{key}'"))
        })
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key).and_then(Json::as_str).ok_or_else(|| {
            Error::Json(format!("expected string at key '{key}'"))
        })
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.get(key).and_then(Json::as_arr).ok_or_else(|| {
            Error::Json(format!("expected array at key '{key}'"))
        })
    }

    /// Array of numbers -> Vec<f64> (golden-vector loading).
    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()
            .ok_or_else(|| Error::Json("expected array".into()))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .ok_or_else(|| Error::Json("expected number".into()))
            })
            .collect()
    }

    // ---- parse -----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Json(format!(
                "trailing data at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)?;
        Json::parse(&text)
    }

    // ---- serialize --------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    v.write(out, indent, pretty);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        for _ in 0..indent + 2 {
                            out.push(' ');
                        }
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 2, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    for _ in 0..indent {
                        out.push(' ');
                    }
                }
                out.push('}');
            }
        }
    }
}

/// JSON numbers are f64, which only holds integers exactly below 2^53;
/// larger u64s serialize as decimal strings so round-trips stay exact —
/// the convention shared by DSE checkpoints and learn configs.
pub fn u64_to_json(x: u64) -> Json {
    if x < (1u64 << 53) {
        Json::Num(x as f64)
    } else {
        Json::Str(x.to_string())
    }
}

/// Inverse of [`u64_to_json`]: an exact non-negative integer number, or
/// a decimal string.
pub fn u64_from_json(v: &Json) -> Option<u64> {
    match v {
        Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
        Json::Str(s) => s.parse().ok(),
        _ => None,
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected '{}' at byte {}",
                b as char, self.pos
            )))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(Error::Json(format!(
                "unexpected byte at {}",
                self.pos
            ))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => {
                    return Err(Error::Json(format!(
                        "expected ',' or '}}' at byte {}",
                        self.pos
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => {
                    return Err(Error::Json(format!(
                        "expected ',' or ']' at byte {}",
                        self.pos
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(Error::Json(
                                    "bad \\u escape".into(),
                                ));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| {
                                Error::Json("bad \\u escape".into())
                            })?;
                            let cp = u32::from_str_radix(hex, 16).map_err(
                                |_| Error::Json("bad \\u escape".into()),
                            )?;
                            // BMP only (sufficient for our configs).
                            s.push(
                                char::from_u32(cp).unwrap_or('\u{FFFD}'),
                            );
                            self.pos += 4;
                        }
                        _ => {
                            return Err(Error::Json(format!(
                                "bad escape at byte {}",
                                self.pos
                            )))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let chunk =
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| {
                                Error::Json("invalid utf-8".into())
                            })?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| {
                c.is_ascii_digit()
                    || c == b'.'
                    || c == b'e'
                    || c == b'E'
                    || c == b'+'
                    || c == b'-'
            })
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| Error::Json("bad number".into()))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("bad number '{text}'")))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(
            Json::parse("\"hi\"").unwrap(),
            Json::Str("hi".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(
            r#"{"a": [1, 2, {"b": "x"}], "c": null, "d": {"e": true}}"#,
        )
        .unwrap();
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
        assert_eq!(
            j.get("d").unwrap().get("e").unwrap().as_bool(),
            Some(true)
        );
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\"A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\"A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ✓"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":"x\ny"},"d":true,"e":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn typed_lookups() {
        let j = Json::parse(r#"{"n": 3, "s": "x", "a": [1]}"#).unwrap();
        assert_eq!(j.req_f64("n").unwrap(), 3.0);
        assert_eq!(j.req_str("s").unwrap(), "x");
        assert_eq!(j.req_arr("a").unwrap().len(), 1);
        assert!(j.req_f64("s").is_err());
        assert!(j.req_f64("missing").is_err());
    }

    #[test]
    fn f64_vec_roundtrip() {
        let j = Json::parse("[1, 2.5, 3]").unwrap();
        assert_eq!(j.f64_vec().unwrap(), vec![1.0, 2.5, 3.0]);
        assert!(Json::parse("[1, \"x\"]").unwrap().f64_vec().is_err());
    }

    #[test]
    fn builder_api() {
        let mut j = Json::obj();
        j.set("x", Json::Num(1.0))
            .set("y", Json::Arr(vec![Json::Bool(false)]));
        assert_eq!(j.get("x").unwrap().as_f64(), Some(1.0));
    }
}
