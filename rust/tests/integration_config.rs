//! Config-system integration: file round-trips, CLI plumbing, and
//! config-driven custom applications running end to end.

use ds3r::app::AppGraph;
use ds3r::cli::{self, Args};
use ds3r::config::SimConfig;
use ds3r::platform::Platform;
use ds3r::sim::Simulation;
use ds3r::util::json::Json;

fn args(s: &str) -> Args {
    Args::parse(s.split_whitespace().map(String::from))
}

#[test]
fn config_file_roundtrip_drives_simulation() {
    let dir = std::env::temp_dir().join("ds3r-test-config");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.json");

    let mut cfg = SimConfig::default();
    cfg.scheduler = "heft".into();
    cfg.injection_rate_per_ms = 2.5;
    cfg.max_jobs = 40;
    cfg.warmup_jobs = 4;
    cfg.dtpm.governor = "ondemand".into();
    cfg.save(&path).unwrap();

    let loaded = SimConfig::load(&path).unwrap();
    assert_eq!(loaded.scheduler, "heft");
    assert_eq!(loaded.injection_rate_per_ms, 2.5);
    assert_eq!(loaded.dtpm.governor, "ondemand");

    let p = Platform::table2_soc();
    let apps =
        vec![ds3r::app::suite::wifi_tx(Default::default())];
    let r = Simulation::build(&p, &apps, &loaded).unwrap().run();
    assert_eq!(r.completed_jobs, 40);
    assert_eq!(r.scheduler, "heft");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_config_flag_plus_overrides() {
    let dir = std::env::temp_dir().join("ds3r-test-config2");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("base.json");
    let mut cfg = SimConfig::default();
    cfg.scheduler = "met".into();
    cfg.max_jobs = 77;
    cfg.save(&path).unwrap();

    // --rate overrides, scheduler comes from file.
    let a = args(&format!("run --config {} --rate 6", path.display()));
    let merged = cli::config_from_args(&a).unwrap();
    assert_eq!(merged.scheduler, "met");
    assert_eq!(merged.max_jobs, 77);
    assert_eq!(merged.injection_rate_per_ms, 6.0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn custom_app_from_json_runs() {
    // A user-defined application loaded from JSON must simulate cleanly:
    // the "plug your own DAG" path.
    let j = Json::parse(
        r#"{
          "name": "custom-dsp",
          "tasks": [
            {"name": "src",  "exec_us": {"A15": 5, "A7": 12},
             "preds": [], "out_bytes": 256},
            {"name": "fir",  "exec_us": {"A15": 40, "A7": 100},
             "preds": [0], "out_bytes": 512},
            {"name": "fft",  "exec_us": {"ACC_FFT": 16, "A15": 118,
             "A7": 296}, "preds": [0], "out_bytes": 512},
            {"name": "mix",  "exec_us": {"A15": 9, "A7": 21},
             "preds": [1, 2], "out_bytes": 128}
          ]
        }"#,
    )
    .unwrap();
    let app = AppGraph::from_json(&j).unwrap();
    assert_eq!(app.len(), 4);
    assert_eq!(app.sinks(), vec![3]);

    let p = Platform::table2_soc();
    let apps = vec![app];
    let mut cfg = SimConfig::default();
    cfg.max_jobs = 50;
    cfg.warmup_jobs = 5;
    cfg.injection_rate_per_ms = 2.0;
    let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
    assert_eq!(r.completed_jobs, 50);
    // Critical path: src(5) + fft(16) + mix(9) = 30 plus NoC.
    assert!(r.avg_job_latency_us() >= 30.0);
    assert!(r.avg_job_latency_us() < 60.0);
}

#[test]
fn malformed_configs_are_rejected_with_context() {
    for (text, needle) in [
        (r#"{"max_ready": 0}"#, "max_ready"),
        (r#"{"injection_rate_per_ms": -1}"#, "injection_rate"),
        (r#"{"arrival": "fractal"}"#, "arrival"),
        (r#"{"exec_jitter_frac": 0.9}"#, "jitter"),
    ] {
        let j = Json::parse(text).unwrap();
        let err = SimConfig::from_json(&j).unwrap_err();
        let msg = format!("{err}");
        assert!(
            msg.contains(needle),
            "error for {text} lacks '{needle}': {msg}"
        );
    }
}

#[test]
fn app_json_rejects_malformed_graphs() {
    for text in [
        // cycle
        r#"{"name":"x","tasks":[
            {"name":"a","exec_us":{"A15":1},"preds":[1],"out_bytes":0},
            {"name":"b","exec_us":{"A15":1},"preds":[0],"out_bytes":0}]}"#,
        // missing exec_us
        r#"{"name":"x","tasks":[{"name":"a","preds":[]}]}"#,
        // bad pred index
        r#"{"name":"x","tasks":[
            {"name":"a","exec_us":{"A15":1},"preds":[9],"out_bytes":0}]}"#,
    ] {
        let j = Json::parse(text).unwrap();
        assert!(AppGraph::from_json(&j).is_err(), "accepted: {text}");
    }
}

#[test]
fn cli_reproduce_table_commands() {
    let out = cli::cmd_reproduce(&args("reproduce table1")).unwrap();
    assert!(out.contains("Inverse-FFT") || out.contains("ifft"));
    let out = cli::cmd_reproduce(&args("reproduce table2")).unwrap();
    assert!(out.contains("total PEs: 14"));
    let out = cli::cmd_reproduce(&args("reproduce fig2")).unwrap();
    assert!(out.contains("->"));
    assert!(cli::cmd_reproduce(&args("reproduce fig9")).is_err());
}

#[test]
fn saved_config_parses_as_strict_json() {
    // Our serializer must emit strictly-parseable JSON (self-host test).
    let cfg = SimConfig::default();
    let text = cfg.to_json().to_string_pretty();
    let re = Json::parse(&text).unwrap();
    assert!(re.get("scheduler").is_some());
}
