"""L2 model + AOT lowering tests: shapes, clamping, HLO-text round-trip."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref, thermal
from .test_kernels import make_etf_inputs, make_thermal_inputs


class TestDtpmModel:
    def test_shapes_and_psum(self):
        rng = np.random.default_rng(0)
        args = make_thermal_inputs(rng)
        t_next, p_leak, p_tot, p_sum = model.dtpm_step_model(*args)
        assert t_next.shape == (thermal.K, thermal.N)
        assert p_sum.shape == (thermal.K, 1)
        np.testing.assert_allclose(
            np.asarray(p_sum)[:, 0], np.asarray(p_tot).sum(axis=1),
            rtol=1e-5)

    def test_clamps_to_physical_range(self):
        rng = np.random.default_rng(1)
        t, a, b, pd, v, k1, k2, pe_node = make_thermal_inputs(rng)
        hot = jnp.full_like(t, 104.0)
        big = jnp.full_like(pd, 100.0)
        t_next, _, _, _ = model.dtpm_step_model(
            hot, a, b, big, v, k1, k2, pe_node)
        assert float(jnp.max(t_next)) <= model.T_MAX
        assert float(jnp.min(t_next)) >= model.T_MIN

    def test_matches_kernel_plus_clip(self):
        rng = np.random.default_rng(2)
        args = make_thermal_inputs(rng)
        t_next, p_leak, p_tot, _ = model.dtpm_step_model(*args)
        w_t, w_leak, w_tot = ref.dtpm_step_ref(*args)
        np.testing.assert_allclose(
            t_next, jnp.clip(w_t, model.T_MIN, model.T_MAX),
            rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(p_leak, w_leak, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(p_tot, w_tot, rtol=1e-5, atol=1e-5)


class TestEtfModel:
    def test_delegates_to_kernel(self):
        rng = np.random.default_rng(3)
        args = make_etf_inputs(rng, 10, 14)
        got = model.etf_model(*args)
        want = ref.etf_matrix_ref(*args)
        for g, w in zip(got, want):
            g, w = np.asarray(g), np.asarray(w)
            mask = np.isfinite(w)
            np.testing.assert_allclose(g[mask], w[mask], rtol=1e-5)


class TestAot:
    def test_dtpm_hlo_text_nonempty_and_parseable_header(self):
        text = aot.lower_dtpm_step()
        assert "HloModule" in text
        assert len(text) > 1000

    def test_etf_hlo_text(self):
        text = aot.lower_etf()
        assert "HloModule" in text

    def test_manifest_written(self):
        with tempfile.TemporaryDirectory() as d:
            import sys
            argv = sys.argv
            sys.argv = ["aot", "--out-dir", d]
            try:
                aot.main()
            finally:
                sys.argv = argv
            assert os.path.exists(os.path.join(d, "dtpm_step.hlo.txt"))
            assert os.path.exists(os.path.join(d, "etf_matrix.hlo.txt"))
            assert os.path.exists(os.path.join(d, "manifest.json"))

    def test_lowered_compile_matches_eager(self):
        """The AOT-lowered computation, compiled, matches eager execution.

        (The HLO-text -> xla-crate -> PJRT round-trip itself is covered on
        the rust side by rust/tests/integration_runtime.rs, which loads the
        same artifact and cross-checks numerics against values produced by
        ref.py; see python/tests/golden generation in conftest.)
        """
        rng = np.random.default_rng(5)
        args = make_thermal_inputs(rng)
        lowered = jax.jit(model.dtpm_step_model).lower(
            *[jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args])
        out = lowered.compile()(*args)
        want = model.dtpm_step_model(*args)
        for g, w in zip(out, want):
            np.testing.assert_allclose(np.asarray(g), w, rtol=1e-5,
                                       atol=1e-5)

    def test_hlo_has_expected_entry_shapes(self):
        text = aot.lower_dtpm_step()
        # Entry computation signature must carry the fixed AOT contract.
        assert "f32[16,32]" in text   # t
        assert "f32[32,32]" in text   # a
        assert "f32[16,16]" in text   # pd/v
        text2 = aot.lower_etf()
        assert "f32[64,16]" in text2  # ready/exec
        assert "f32[1,16]" in text2   # avail
