//! Plug-and-play scheduling: implement your own scheduler against the
//! `Scheduler` trait and run it inside the framework — "the framework
//! enables a plug-and-play interface ... developers can implement their
//! own algorithms and integrate them easily" (paper §2).
//!
//! The example implements a *queue-aware MET* hybrid: pick the fastest
//! class, but spill to the second-fastest class whenever the fastest
//! one's shortest queue exceeds a threshold.
//!
//! ```sh
//! cargo run --release --example custom_scheduler
//! ```

use ds3r::app::suite::{self, WifiParams};
use ds3r::config::SimConfig;
use ds3r::platform::Platform;
use ds3r::sched::{Assignment, ReadyTask, SchedContext, Scheduler};
use ds3r::sim::Simulation;
use ds3r::util::plot;

/// MET that spills to slower classes when the fast class queues up.
struct SpillingMet {
    spill_threshold: usize,
    spills: u64,
}

impl Scheduler for SpillingMet {
    fn name(&self) -> &str {
        "spilling-met"
    }

    fn schedule(
        &mut self,
        ready: &[ReadyTask],
        ctx: &dyn SchedContext,
    ) -> Vec<Assignment> {
        let mut out = Vec::with_capacity(ready.len());
        let mut queue_len: Vec<usize> =
            ctx.pes().iter().map(|p| p.queue_len).collect();
        for rt in ready {
            // Rank supporting PEs by (exec, queue length).
            let mut cands: Vec<(f64, usize, usize)> = ctx
                .pes()
                .iter()
                .filter_map(|p| {
                    ctx.exec_us(rt, p.id)
                        .map(|e| (e, queue_len[p.id], p.id))
                })
                .collect();
            if cands.is_empty() {
                continue;
            }
            cands.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let fastest = cands[0].0;
            // Shortest queue among fastest-class PEs.
            let best_fast = cands
                .iter()
                .filter(|c| c.0 == fastest)
                .min_by_key(|c| c.1)
                .copied()
                .unwrap();
            let pick = if best_fast.1 > self.spill_threshold {
                // Spill: best finish-ish among remaining classes.
                self.spills += 1;
                cands
                    .iter()
                    .copied()
                    .min_by(|a, b| {
                        let fa = a.0 * (a.1 as f64 + 1.0);
                        let fb = b.0 * (b.1 as f64 + 1.0);
                        fa.partial_cmp(&fb).unwrap()
                    })
                    .unwrap()
            } else {
                best_fast
            };
            queue_len[pick.2] += 1;
            out.push(Assignment { job: rt.job, task: rt.task, pe: pick.2 });
        }
        out
    }

    fn report(&self) -> Vec<String> {
        vec![format!("spilling-met: {} spills", self.spills)]
    }
}

fn main() {
    let platform = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];

    println!("custom scheduler vs built-ins at 6 jobs/ms:\n");
    let mut rows = Vec::new();

    // Built-ins through the registry...
    for name in ["met", "etf"] {
        let mut cfg = SimConfig::default();
        cfg.scheduler = name.into();
        cfg.injection_rate_per_ms = 6.0;
        cfg.max_jobs = 600;
        cfg.warmup_jobs = 60;
        cfg.max_sim_us = 4_000_000.0;
        let r = Simulation::build(&platform, &apps, &cfg).unwrap().run();
        rows.push(vec![
            name.to_string(),
            format!("{:.1}", r.avg_job_latency_us()),
            format!("{:.1}", r.latency_summary().p95),
        ]);
    }

    // ...and the custom one through the plug-in hook.
    let mut cfg = SimConfig::default();
    cfg.injection_rate_per_ms = 6.0;
    cfg.max_jobs = 600;
    cfg.warmup_jobs = 60;
    cfg.max_sim_us = 4_000_000.0;
    let custom = SpillingMet { spill_threshold: 2, spills: 0 };
    let r = Simulation::build_with_scheduler(
        &platform,
        &apps,
        &cfg,
        Box::new(custom),
    )
    .unwrap()
    .run();
    rows.push(vec![
        "spilling-met (custom)".into(),
        format!("{:.1}", r.avg_job_latency_us()),
        format!("{:.1}", r.latency_summary().p95),
    ]);
    for line in &r.scheduler_report {
        println!("  {line}");
    }

    println!(
        "{}",
        plot::ascii_table(&["scheduler", "avg us", "p95 us"], &rows)
    );
    println!(
        "The custom hybrid fixes MET's instance pinning while keeping\n\
         its O(1) decision cost — implemented entirely outside the\n\
         framework through the Scheduler trait."
    );
}
