"""L2: JAX compute graphs the rust coordinator executes via PJRT.

Two exported entry points, both jitted and AOT-lowered by aot.py into
fixed-shape HLO-text artifacts (contracts in DESIGN.md §5):

  * ``dtpm_step_model``  — the per-epoch power/thermal update, batched over
    K candidate DVFS settings.  Wraps the L1 Pallas kernel
    (kernels.thermal) and adds the model-level plumbing the framework
    needs around it: clamping to the physical temperature range and a
    per-candidate total-power reduction used by the power-cap governor.
  * ``etf_model``        — the ETF finish-time matrix (kernels.etf).

Python runs ONCE at build time (``make artifacts``); the rust hot loop
only ever touches the lowered HLO.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels import etf as etf_kernel
from compile.kernels import thermal as thermal_kernel

# Physical clamp range for node temperatures, °C above ambient.  The RC
# discretization is stable for the time steps we use, but a scheduler
# exploring aggressive DVFS candidates can inject transient power spikes;
# clamping mirrors what the firmware thermal driver reports.
T_MIN = 0.0
T_MAX = 105.0


def dtpm_step_model(t, a, b, pd, v, k1, k2, pe_node):
    """Per-epoch DTPM update over K candidate DVFS settings.

    Returns (t_next [K,N], p_leak [K,P], p_total [K,P], p_sum [K, 1]).
    ``p_sum`` is the SoC-level power per candidate, consumed by the
    power-cap governor without a second device round-trip.
    """
    t_next, p_leak, p_tot = thermal_kernel.dtpm_step(
        t, a, b, pd, v, k1, k2, pe_node)
    t_next = jnp.clip(t_next, T_MIN, T_MAX)
    p_sum = jnp.sum(p_tot, axis=1, keepdims=True)
    return t_next, p_leak, p_tot, p_sum


def etf_model(avail, ready, exec_):
    """ETF finish-time matrix + per-task best PE (see kernels.etf)."""
    return etf_kernel.etf_matrix(avail, ready, exec_)
