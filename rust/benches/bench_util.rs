//! Tiny shared timing harness for the `harness = false` benches (the
//! offline build has no criterion).  Reports median / mean / min over
//! repeated runs with a measured-overhead warmup.

use std::time::Instant;

/// Time `f` for `iters` iterations, returning ns/iter statistics.
pub fn bench<F: FnMut()>(name: &str, iters: u64, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..(iters / 10).max(1) {
        f();
    }
    let mut samples = Vec::with_capacity(5);
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    println!(
        "{name:<48} {median:>12.1} ns/iter   (min {:.1}, max {:.1}, {iters} iters x5)",
        samples[0],
        samples[samples.len() - 1]
    );
    median
}

/// Time a single long-running closure, printing seconds.
pub fn bench_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let s = t0.elapsed().as_secs_f64();
    println!("{name:<48} {:>12.3} s", s);
    (out, s)
}
