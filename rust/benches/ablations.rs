//! Ablation benches for the design decisions called out in DESIGN.md:
//!
//! * MET tie-breaking (naive pinning vs least-loaded) — how much of the
//!   Figure-3 collapse is instance pinning.
//! * NoC model on/off/congestion — what interconnect awareness buys.
//! * Serial vs parallel WiFi-TX frame — DAG-width sensitivity.
//! * Scheduler window (`max_ready`) sizing.
//! * ETF host vs ETF-XLA (AOT artifact) decision cost.
//!
//! Run: `cargo bench --bench ablations`

mod bench_util;

use ds3r::app::suite::{self, WifiParams};
use ds3r::app::AppGraph;
use ds3r::config::SimConfig;
use ds3r::platform::Platform;
use ds3r::sim::Simulation;
use ds3r::util::plot;

fn run(
    platform: &Platform,
    apps: &[AppGraph],
    f: impl FnOnce(&mut SimConfig),
) -> ds3r::stats::SimReport {
    let mut cfg = SimConfig::default();
    cfg.max_jobs = 400;
    cfg.warmup_jobs = 40;
    cfg.injection_rate_per_ms = 6.0;
    cfg.max_sim_us = 4_000_000.0;
    f(&mut cfg);
    Simulation::build(platform, apps, &cfg).unwrap().run()
}

fn main() {
    let platform = Platform::table2_soc();
    let serial = vec![suite::wifi_tx(WifiParams::default())];
    let parallel = vec![suite::wifi_tx_parallel(WifiParams::default())];

    // ----- 1. MET tie-breaking -----
    println!("=== ablation: MET instance tie-breaking (6 jobs/ms) ===");
    let met = run(&platform, &serial, |c| c.scheduler = "met".into());
    let met_lb =
        run(&platform, &serial, |c| c.scheduler = "met-lb".into());
    let etf = run(&platform, &serial, |c| c.scheduler = "etf".into());
    println!(
        "{}",
        plot::ascii_table(
            &["variant", "avg us", "p95 us"],
            &[
                vec![
                    "met (paper/DS3: pin to first)".into(),
                    format!("{:.1}", met.avg_job_latency_us()),
                    format!("{:.1}", met.latency_summary().p95)
                ],
                vec![
                    "met-lb (least-loaded ties)".into(),
                    format!("{:.1}", met_lb.avg_job_latency_us()),
                    format!("{:.1}", met_lb.latency_summary().p95)
                ],
                vec![
                    "etf (reference)".into(),
                    format!("{:.1}", etf.avg_job_latency_us()),
                    format!("{:.1}", etf.latency_summary().p95)
                ],
            ]
        )
    );

    // ----- 2. NoC model -----
    println!("=== ablation: interconnect model (etf, 6 jobs/ms) ===");
    let base = run(&platform, &serial, |c| c.scheduler = "etf".into());
    let congested = run(&platform, &serial, |c| {
        c.scheduler = "etf".into();
        c.noc_congestion = true;
    });
    let mut free_noc_platform = platform.clone();
    free_noc_platform.noc.hop_latency_us = 0.0;
    free_noc_platform.noc.mem_latency_us = 0.0;
    let free = run(&free_noc_platform, &serial, |c| {
        c.scheduler = "etf".into()
    });
    println!(
        "{}",
        plot::ascii_table(
            &["NoC model", "avg us"],
            &[
                vec![
                    "analytical (default)".into(),
                    format!("{:.1}", base.avg_job_latency_us())
                ],
                vec![
                    "analytical + congestion".into(),
                    format!("{:.1}", congested.avg_job_latency_us())
                ],
                vec![
                    "free interconnect".into(),
                    format!("{:.1}", free.avg_job_latency_us())
                ],
            ]
        )
    );

    // ----- 3. DAG width -----
    println!("=== ablation: frame structure (etf) ===");
    let ser = run(&platform, &serial, |c| c.scheduler = "etf".into());
    let par = run(&platform, &parallel, |c| c.scheduler = "etf".into());
    println!(
        "{}",
        plot::ascii_table(
            &["wifi-tx frame", "avg us", "width"],
            &[
                vec![
                    "serial pipeline (paper Fig 2)".into(),
                    format!("{:.1}", ser.avg_job_latency_us()),
                    "1".into()
                ],
                vec![
                    "parallel symbol fan-out".into(),
                    format!("{:.1}", par.avg_job_latency_us()),
                    format!("{}", WifiParams::default().symbols)
                ],
            ]
        )
    );

    // ----- 4. scheduler window -----
    println!("=== ablation: max_ready window (etf, 9 jobs/ms) ===");
    let mut rows = Vec::new();
    for w in [4usize, 16, 64, 256] {
        let r = run(&platform, &serial, |c| {
            c.scheduler = "etf".into();
            c.injection_rate_per_ms = 9.0;
            c.max_ready = w;
        });
        rows.push(vec![
            format!("{w}"),
            format!("{:.1}", r.avg_job_latency_us()),
            format!("{:.2}", r.sched_overhead_us()),
        ]);
    }
    println!(
        "{}",
        plot::ascii_table(
            &["window", "avg us", "sched us/epoch"],
            &rows
        )
    );

    // ----- 5. ETF host vs XLA artifact -----
    println!("=== ablation: ETF host vs AOT-XLA finish matrix ===");
    let dir = ds3r::runtime::default_artifacts_dir();
    if ds3r::runtime::artifacts_available(&dir) {
        let host = run(&platform, &serial, |c| {
            c.scheduler = "etf".into();
            c.injection_rate_per_ms = 8.0;
        });
        let xla = run(&platform, &serial, |c| {
            c.scheduler = "etf-xla".into();
            c.injection_rate_per_ms = 8.0;
        });
        println!(
            "{}",
            plot::ascii_table(
                &["variant", "avg us", "sched us/epoch"],
                &[
                    vec![
                        "etf (host)".into(),
                        format!("{:.1}", host.avg_job_latency_us()),
                        format!("{:.2}", host.sched_overhead_us())
                    ],
                    vec![
                        "etf-xla (PJRT artifact)".into(),
                        format!("{:.1}", xla.avg_job_latency_us()),
                        format!("{:.2}", xla.sched_overhead_us())
                    ],
                ]
            )
        );
        println!(
            "note: at Table-2 scale (14 PEs) the per-call PJRT overhead \
             dominates;\nthe artifact path pays off only for much wider \
             ready lists / PE counts\n(see EXPERIMENTS.md §Perf)."
        );
    } else {
        println!("(skipped: run `make artifacts` first)");
    }
}
