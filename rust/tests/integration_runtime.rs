//! End-to-end PJRT runtime tests: the python-AOT → HLO-text → rust-load
//! loop, cross-checked against goldens produced by the pure-jnp oracle
//! (`python/compile/kernels/ref.py`, dumped by `compile.aot`).
//!
//! These tests require `make artifacts`; they skip (pass vacuously, with
//! a note on stderr) when the artifacts directory is absent so `cargo
//! test` works on a fresh checkout.

use std::path::PathBuf;

use ds3r::platform::Platform;
use ds3r::runtime::{
    artifacts_available, default_artifacts_dir, DtpmArtifact, EtfArtifact,
    DTPM_K, DTPM_N, DTPM_P, ETF_I, ETF_J,
};
use ds3r::thermal::RcModel;
use ds3r::util::json::Json;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = default_artifacts_dir();
    if artifacts_available(&dir) {
        Some(dir)
    } else {
        eprintln!(
            "SKIP: artifacts not found at {} — run `make artifacts`",
            dir.display()
        );
        None
    }
}

fn golden(dir: &PathBuf, name: &str) -> Json {
    Json::parse_file(&dir.join(name)).expect("golden parses")
}

fn vec_of(j: &Json, section: &str, key: &str) -> Vec<f64> {
    j.get(section)
        .and_then(|s| s.get(key))
        .expect("golden key")
        .f64_vec()
        .expect("numeric golden")
}

#[test]
fn dtpm_artifact_matches_python_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let g = golden(&dir, "golden_dtpm.json");

    let t = vec_of(&g, "inputs", "t");
    let a = vec_of(&g, "inputs", "a");
    let b = vec_of(&g, "inputs", "b");
    let pd = vec_of(&g, "inputs", "pd");
    let v = vec_of(&g, "inputs", "v");
    let k1 = vec_of(&g, "inputs", "k1");
    let k2 = vec_of(&g, "inputs", "k2");
    let pe_node = vec_of(&g, "inputs", "pe_node");

    // Inject the golden matrices through a matrices-only RcModel (full
    // N x P shapes, so padding is the identity).
    let rc = RcModel::from_matrices(
        a,
        b,
        pe_node
            .chunks(DTPM_N)
            .map(|row| row.iter().position(|&x| x == 1.0).unwrap_or(0))
            .collect(),
        10_000.0,
        25.0,
    );
    let mut art = DtpmArtifact::load(&dir).expect("artifact compiles");
    art.set_model(&rc, &k1, &k2).unwrap();

    // The golden batch varies theta per row; our API replicates one
    // theta across rows, so compare row 0 (full-batch parity of the same
    // HLO is covered by the python tests).
    let theta: Vec<f64> = t[..DTPM_N].to_vec();
    let cand = vec![(pd[..DTPM_P].to_vec(), v[..DTPM_P].to_vec())];
    let out = art.step(&theta, &cand).expect("device step");

    let want_t = vec_of(&g, "outputs", "t_next");
    let want_leak = vec_of(&g, "outputs", "p_leak");
    let want_tot = vec_of(&g, "outputs", "p_total");
    let want_sum = vec_of(&g, "outputs", "p_sum");

    for i in 0..DTPM_N {
        assert!(
            (out.t_next[0][i] - want_t[i]).abs() < 1e-3,
            "t_next[{i}]: {} vs {}",
            out.t_next[0][i],
            want_t[i]
        );
    }
    for p in 0..DTPM_P {
        assert!((out.p_leak[0][p] - want_leak[p]).abs() < 1e-4);
        assert!((out.p_total[0][p] - want_tot[p]).abs() < 1e-4);
    }
    assert!((out.p_sum[0] - want_sum[0]).abs() < 1e-3);
}

#[test]
fn etf_artifact_matches_python_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let g = golden(&dir, "golden_etf.json");
    let avail = vec_of(&g, "inputs", "avail");
    let ready = vec_of(&g, "inputs", "ready");
    let exec = vec_of(&g, "inputs", "exec");
    let want_fin = vec_of(&g, "outputs", "finish");

    // Goldens use 1e30 as the pad sentinel; convert to inf for the API.
    let exec_inf: Vec<f64> = exec
        .iter()
        .map(|&e| if e >= 1e29 { f64::INFINITY } else { e })
        .collect();

    let mut art = EtfArtifact::load(&dir).expect("artifact compiles");
    let fin = art
        .finish_matrix(&avail, &ready, &exec_inf, ETF_I, ETF_J)
        .expect("device call");

    for i in 0..ETF_I {
        for j in 0..ETF_J {
            let got = fin[i * ETF_J + j];
            let want = want_fin[i * ETF_J + j];
            if want >= 1e29 {
                assert!(
                    got.is_infinite(),
                    "({i},{j}): expected padded, got {got}"
                );
            } else {
                assert!(
                    (got - want).abs() <= want.abs() * 1e-5 + 1e-2,
                    "({i},{j}): {got} vs {want}"
                );
            }
        }
    }
    assert_eq!(art.calls, 1);
}

#[test]
fn dtpm_artifact_agrees_with_native_thermal_model() {
    let Some(dir) = artifacts_dir() else { return };
    let platform = Platform::table2_soc();
    let rc = RcModel::new(&platform, 10_000.0);
    let (k1, k2): (Vec<f64>, Vec<f64>) = platform
        .pes
        .iter()
        .map(|pe| {
            let c = &platform.classes[pe.class];
            (rc.leak_k1_effective(c.leak_k1, c.leak_k2), c.leak_k2)
        })
        .unzip();
    let mut art = DtpmArtifact::load(&dir).unwrap();
    art.set_model(&rc, &k1, &k2).unwrap();

    // Several epochs of a plausible trajectory: native f64 vs device f32.
    let mut theta = vec![0.0f64; rc.n];
    let p_dyn: Vec<f64> =
        (0..rc.n_pes).map(|i| 0.3 + 0.1 * i as f64).collect();
    let volts: Vec<f64> = vec![1.1; rc.n_pes];
    for epoch in 0..50 {
        let p_total: Vec<f64> = (0..rc.n_pes)
            .map(|i| {
                let t_pe = theta[rc.pe_node[i]];
                p_dyn[i] + k1[i] * volts[i] * (k2[i] * t_pe).exp()
            })
            .collect();
        let native_next = rc.step(&theta, &p_total);

        let out = art
            .step(&theta, &[(p_dyn.clone(), volts.clone())])
            .expect("device step");
        for i in 0..rc.n {
            assert!(
                (out.t_next[0][i] - native_next[i]).abs() < 1e-3,
                "epoch {epoch} node {i}: device {} vs native {}",
                out.t_next[0][i],
                native_next[i]
            );
        }
        theta = native_next;
    }
}

#[test]
fn dtpm_artifact_batched_candidates() {
    let Some(dir) = artifacts_dir() else { return };
    let platform = Platform::table2_soc();
    let rc = RcModel::new(&platform, 10_000.0);
    let (k1, k2): (Vec<f64>, Vec<f64>) = platform
        .pes
        .iter()
        .map(|pe| {
            let c = &platform.classes[pe.class];
            (rc.leak_k1_effective(c.leak_k1, c.leak_k2), c.leak_k2)
        })
        .unzip();
    let mut art = DtpmArtifact::load(&dir).unwrap();
    art.set_model(&rc, &k1, &k2).unwrap();

    let theta = vec![10.0; rc.n];
    // K candidates with increasing dynamic power: hotter candidates must
    // produce hotter next-states and larger p_sum (DSE ordering).
    let cands: Vec<(Vec<f64>, Vec<f64>)> = (0..DTPM_K)
        .map(|k| {
            (vec![0.2 * (k + 1) as f64; rc.n_pes], vec![1.0; rc.n_pes])
        })
        .collect();
    let out = art.step(&theta, &cands).expect("batched step");
    assert_eq!(out.p_sum.len(), DTPM_K);
    for k in 1..DTPM_K {
        assert!(out.p_sum[k] > out.p_sum[k - 1]);
        let hot: f64 = out.t_next[k].iter().sum();
        let cold: f64 = out.t_next[k - 1].iter().sum();
        assert!(hot > cold, "candidate {k} not hotter");
    }
}

#[test]
fn etf_xla_scheduler_matches_native_etf_end_to_end() {
    let Some(_dir) = artifacts_dir() else { return };
    use ds3r::app::suite::{self, WifiParams};
    use ds3r::config::SimConfig;
    use ds3r::sim::Simulation;

    let platform = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams { symbols: 4 })];
    let mut cfg = SimConfig::default();
    cfg.max_jobs = 60;
    cfg.warmup_jobs = 6;
    cfg.injection_rate_per_ms = 3.0;

    cfg.scheduler = "etf".into();
    let native = Simulation::build(&platform, &apps, &cfg).unwrap().run();
    cfg.scheduler = "etf-xla".into();
    let xla = Simulation::build(&platform, &apps, &cfg).unwrap().run();

    assert_eq!(native.completed_jobs, xla.completed_jobs);
    // f32 device matrix can flip exact ties, so allow a small drift in
    // the mean but require close agreement.
    let a = native.avg_job_latency_us();
    let b = xla.avg_job_latency_us();
    assert!(
        (a - b).abs() / a < 0.02,
        "etf {a} vs etf-xla {b} diverge > 2%"
    );
}

#[test]
fn xla_thermal_simulation_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    use ds3r::app::suite::{self, WifiParams};
    use ds3r::config::SimConfig;
    use ds3r::sim::Simulation;

    let platform = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams { symbols: 4 })];
    let mut cfg = SimConfig::default();
    cfg.max_jobs = 80;
    cfg.warmup_jobs = 8;
    cfg.injection_rate_per_ms = 4.0;
    cfg.capture_traces = true;

    let native = Simulation::build(&platform, &apps, &cfg).unwrap().run();
    cfg.use_xla_thermal = true;
    cfg.artifacts_dir = Some(dir);
    let xla = Simulation::build(&platform, &apps, &cfg).unwrap().run();

    assert_eq!(native.completed_jobs, xla.completed_jobs);
    assert!(xla.device_calls > 0, "xla thermal path never used");
    // Same schedule; energy and peak temperature agree to f32 tolerance.
    assert!(
        (native.total_energy_j - xla.total_energy_j).abs()
            / native.total_energy_j
            < 1e-3,
        "energy: native {} vs xla {}",
        native.total_energy_j,
        xla.total_energy_j
    );
    assert!(
        (native.peak_temp_c - xla.peak_temp_c).abs() < 0.05,
        "peak temp: native {} vs xla {}",
        native.peak_temp_c,
        xla.peak_temp_c
    );
    // Latencies identical: the thermal path does not affect scheduling
    // here (performance governor pins frequencies).
    assert_eq!(native.job_latencies_us, xla.job_latencies_us);
}
