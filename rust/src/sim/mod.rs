//! The discrete-event simulation kernel.
//!
//! "the simulation kernel simulates task execution on the corresponding
//! PE using execution time profiles obtained from our reference hardware
//! implementations ... After each scheduling decision, the simulation
//! kernel updates the state of the simulation, which is used in
//! subsequent decision epochs" (paper §2).
//!
//! The engine is split for batched grid evaluation:
//!
//! * [`SimSetup`] — immutable shared setup derived from
//!   `(platform, apps)`: exec tables, NoC topology, RC model, arrival
//!   templates.  Built once per grid, shared by every worker.
//! * [`SimWorker`] — the event-loop engine owning all per-run mutable
//!   state.  A worker is *reusable*: [`SimWorker::reset`] rewinds it
//!   for the next grid point without freeing its buffers, so
//!   steady-state grid evaluation stops allocating after warmup.
//!   Reset is bit-identical to a fresh build by construction (one
//!   shared constructor, `fresh`, serves both paths) and by test
//!   (`rust/tests/integration_worker.rs`, `prop_invariants.rs`).
//! * [`Simulation`] — the classic one-shot facade: build, run once,
//!   take the report.  It wires a private setup to a private worker
//!   and is what single runs and the existing examples use.
//!
//! The worker wires together every subsystem: the job generator
//! injects DAG instances; ready tasks are handed to the pluggable
//! [`crate::sched::Scheduler`] at every decision epoch; task execution
//! uses the profile database scaled by the cluster's DVFS state; NoC
//! transfers delay data readiness; at every DTPM epoch the governor and
//! throttle policies pick OPPs and the power/thermal models advance
//! (natively or through the AOT PJRT artifact).

pub mod queue;
pub mod setup;

pub use setup::SimSetup;

use std::collections::VecDeque;
use std::time::Instant;

pub use crate::stats::SimReport;

use crate::app::AppGraph;
use crate::config::SimConfig;
use crate::dtpm::{self, ExploreDse, Governor, PowerCap, ThermalThrottle};
use crate::jobgen::JobGen;
use crate::noc::NocModel;
use crate::platform::{Opp, Platform};
use crate::power::{self, EnergyMeter};
use crate::rng::Rng;
use crate::runtime::DtpmArtifact;
use crate::scenario::{Action, CompiledEvent};
use crate::sched::{
    Assignment, PeSnapshot, ReadyTask, SchedBuild, SchedContext, Scheduler,
};
use crate::stats::{EpochTrace, GanttEntry, PhaseStats};
use crate::thermal::RcModel;
use crate::{Error, Result};
use queue::{Event, EventQueue};

/// Upper bound on the lazy lane's deferred-epoch backlog.  Flushing
/// early is always valid (the replay is exact), so this only bounds
/// memory — at 10 ms epochs it is ~10 s of simulated time per flush.
const MAX_PENDING_EPOCHS: usize = 1024;

/// Cap on the free-list of recycled per-job task buffers (`job_pool`).
/// Bounds worker memory on unbounded runs; reached only when > this
/// many jobs are ever concurrently live.
const JOB_POOL_CAP: usize = 1024;

/// Runtime state of one job instance.
#[derive(Debug)]
struct Job {
    app: usize,
    arrival_us: f64,
    /// Unfinished predecessor count per task.
    pred_remaining: Vec<u16>,
    /// Finish time per task (NaN = not finished).
    finish_us: Vec<f64>,
    /// Committed PE per task (usize::MAX = unassigned).
    assigned_pe: Vec<usize>,
    tasks_done: usize,
    done: bool,
}

/// Runtime state of one PE.
#[derive(Debug, Clone)]
struct PeState {
    /// Committed FIFO queue (excluding the running task).
    queue: VecDeque<(usize, usize)>,
    /// Sum of execution estimates of queued tasks (avail heuristic).
    pending_est_us: f64,
    running: Option<(usize, usize)>,
    /// Start/end of the running task.
    run_start_us: f64,
    busy_until_us: f64,
    /// Busy time accounted so far for the running task.
    accounted_us: f64,
    /// Busy time inside the current DTPM epoch.
    epoch_busy_us: f64,
    /// Total busy time over the run.
    total_busy_us: f64,
}

impl PeState {
    fn new() -> PeState {
        PeState {
            queue: VecDeque::new(),
            pending_est_us: 0.0,
            running: None,
            run_start_us: 0.0,
            busy_until_us: 0.0,
            accounted_us: 0.0,
            epoch_busy_us: 0.0,
            total_busy_us: 0.0,
        }
    }

    fn avail_us(&self, now: f64) -> f64 {
        let base = if self.running.is_some() {
            self.busy_until_us
        } else {
            now
        };
        base.max(now) + self.pending_est_us
    }

    /// Rewind to the fresh-build state, keeping the queue's allocation.
    fn reset(&mut self) {
        self.queue.clear();
        self.pending_est_us = 0.0;
        self.running = None;
        self.run_start_us = 0.0;
        self.busy_until_us = 0.0;
        self.accounted_us = 0.0;
        self.epoch_busy_us = 0.0;
        self.total_busy_us = 0.0;
    }
}

/// Recyclable per-task buffers of one job.  Completed jobs hand their
/// buffers back to the simulation's free-list (`job_pool`) so steady
/// arrivals stop allocating — the third leg of the hot-path overhaul.
#[derive(Debug, Default)]
struct JobBufs {
    pred_remaining: Vec<u16>,
    finish_us: Vec<f64>,
    assigned_pe: Vec<usize>,
}

/// One closed DTPM epoch awaiting power/thermal integration.
///
/// The lazy integration lane accumulates these piecewise-constant
/// segments (per-PE utilization and busy time, plus the OPP indices in
/// force) and replays them — in order, with arithmetic identical to the
/// eager path — at the next observation point: a DTPM epoch a policy or
/// trace observes, a scenario phase boundary, an ambient or power-cap
/// change, or finalize.  See `SimWorker::flush_thermal`.
#[derive(Debug, Default)]
struct EpochSeg {
    dt_us: f64,
    /// Per-PE utilization over the epoch, in [0, 1].
    util: Vec<f64>,
    /// Per-PE busy time over the epoch (µs).
    busy: Vec<f64>,
    /// OPP index per cluster in force during the epoch.
    opp_idx: Vec<usize>,
}

/// The recyclable buffers a [`SimWorker`] hands back to `fresh` on
/// reset: the worker is rebuilt through the *same* constructor as a
/// fresh build — bit-identity by construction — but every heap
/// allocation survives, so steady-state grid evaluation allocates
/// (almost) nothing per point.
#[derive(Default)]
struct SimSpares {
    events: EventQueue,
    jobs: Vec<Job>,
    job_pool: Vec<JobBufs>,
    pes: Vec<PeState>,
    pe_available: Vec<bool>,
    ready: VecDeque<ReadyTask>,
    cluster_opp_idx: Vec<usize>,
    cluster_mhz: Vec<f64>,
    dvfs_clusters: Vec<usize>,
    theta: Vec<f64>,
    theta_scratch: Vec<f64>,
    energy: EnergyMeter,
    ready_scratch: Vec<ReadyTask>,
    snap_scratch: Vec<PeSnapshot>,
    assigned_scratch: Vec<(usize, usize)>,
    kept_scratch: Vec<ReadyTask>,
    pending: Vec<EpochSeg>,
    seg_pool: Vec<EpochSeg>,
    util_scratch: Vec<f64>,
    busy_scratch: Vec<f64>,
    power_scratch: Vec<f64>,
    t_pe_scratch: Vec<f64>,
    opps_scratch: Vec<Opp>,
    phase_lats: Vec<f64>,
    report: SimReport,
}

/// A reusable simulation engine: all per-run mutable state for one
/// grid point, built against a shared [`SimSetup`].
///
/// Lifecycle: [`build`](SimWorker::build) →
/// [`run`](SimWorker::run) → [`reset`](SimWorker::reset) →
/// `run` → … — the worker owns no borrow of the setup, so one worker
/// can even be re-targeted at a *different* setup (the DSE evaluator
/// reuses workers across genomes this way); its buffers re-size and
/// carry over.  A reused worker is bit-identical to a fresh build.
pub struct SimWorker {
    cfg: SimConfig,

    noc: NocModel,
    rc: RcModel,
    scheduler: Box<dyn Scheduler>,
    governor: Box<dyn Governor>,
    /// Predictive DSE governor (batched artifact path), when selected.
    explore: Option<ExploreDse>,
    /// DVFS-capable cluster ids the explore grid spans (max 2).
    dvfs_clusters: Vec<usize>,
    throttle: Option<ThermalThrottle>,
    power_cap: Option<PowerCap>,
    dtpm_xla: Option<DtpmArtifact>,

    // --- dynamic state ---
    now: f64,
    events: EventQueue,
    jobgen: JobGen,
    jobs: Vec<Job>,
    pes: Vec<PeState>,
    /// Scenario timeline (ramps pre-expanded); empty for static runs.
    timeline: Vec<CompiledEvent>,
    /// Per-PE availability mask (false while failed/hotplugged out).
    pe_available: Vec<bool>,
    /// Ambient temperature (°C) — starts at the platform's value,
    /// steppable by scenario events.
    t_ambient_c: f64,
    ready: VecDeque<ReadyTask>,
    /// Current OPP index per cluster.
    cluster_opp_idx: Vec<usize>,
    /// Above-ambient node temperatures.
    theta: Vec<f64>,
    theta_scratch: Vec<f64>,
    energy: EnergyMeter,
    last_epoch_t: f64,
    last_epoch_power_w: f64,
    jitter_rng: Rng,

    // --- hot-path caches & scratch (golden-trace-guarded overhaul;
    // the platform-derived immutable caches live in `SimSetup`) ---
    /// Current frequency (MHz) per cluster; mirrors `cluster_opp_idx`.
    cluster_mhz: Vec<f64>,
    /// Free-list of per-task buffers reclaimed from completed jobs.
    job_pool: Vec<JobBufs>,
    /// Scratch buffers reused across scheduler invocations.
    ready_scratch: Vec<ReadyTask>,
    snap_scratch: Vec<PeSnapshot>,
    assigned_scratch: Vec<(usize, usize)>,
    kept_scratch: Vec<ReadyTask>,
    /// Lazy power/thermal lane: closed-but-unintegrated DTPM epochs.
    pending: Vec<EpochSeg>,
    seg_pool: Vec<EpochSeg>,
    util_scratch: Vec<f64>,
    busy_scratch: Vec<f64>,
    power_scratch: Vec<f64>,
    t_pe_scratch: Vec<f64>,
    opps_scratch: Vec<Opp>,
    /// Hottest absolute temperature after the last integrated epoch.
    last_t_max_abs: f64,

    // --- accounting ---
    injected: usize,
    completed: usize,
    arrivals_done: bool,
    report: SimReport,
    sched_dirty: bool,
    /// Attached time-series probe ([`crate::probe`]); `None` on the
    /// unprobed hot path, where each hook costs one branch.  Dropped
    /// by reset — a probe records exactly one run.
    probe: Option<Box<crate::probe::ProbeRecorder>>,

    // --- per-phase accounting (scenario runs) ---
    phase_lats: Vec<f64>,
    phase_energy0_j: f64,
    phase_peak_temp_c: f64,

    /// Set by `run`; cleared by `reset` — guards against re-running a
    /// finished worker without rewinding it first.
    ran: bool,
}

impl SimWorker {
    /// Build a worker against `setup` for one run of `cfg`.
    pub fn build(setup: &SimSetup, cfg: &SimConfig) -> Result<SimWorker> {
        Self::fresh(setup, cfg, None, SimSpares::default())
    }

    /// Build with a user-supplied scheduler instead of resolving
    /// `cfg.scheduler` through the registry — the plug-and-play hook
    /// (`examples/custom_scheduler.rs`).
    pub fn build_with_scheduler(
        setup: &SimSetup,
        cfg: &SimConfig,
        scheduler: Box<dyn Scheduler>,
    ) -> Result<SimWorker> {
        Self::fresh(setup, cfg, Some(scheduler), SimSpares::default())
    }

    /// Rewind this worker for another run of `cfg` against `setup`
    /// (the same setup, or a different one — buffers re-size).  The
    /// rewound worker is bit-identical to a freshly built one: both go
    /// through the same constructor; reset only recycles allocations.
    ///
    /// On error the worker is left hollow (its buffers recycled but
    /// unconfigured); a later successful `reset` fully recovers it.
    pub fn reset(
        &mut self,
        setup: &SimSetup,
        cfg: &SimConfig,
    ) -> Result<()> {
        self.reset_inner(setup, cfg, None)
    }

    /// [`reset`](SimWorker::reset) with a user-supplied scheduler
    /// (the pooled counterpart of `build_with_scheduler`).
    pub fn reset_with_scheduler(
        &mut self,
        setup: &SimSetup,
        cfg: &SimConfig,
        scheduler: Box<dyn Scheduler>,
    ) -> Result<()> {
        self.reset_inner(setup, cfg, Some(scheduler))
    }

    fn reset_inner(
        &mut self,
        setup: &SimSetup,
        cfg: &SimConfig,
        scheduler_override: Option<Box<dyn Scheduler>>,
    ) -> Result<()> {
        let spares = self.take_spares();
        *self = Self::fresh(setup, cfg, scheduler_override, spares)?;
        // Label the build span: this engine came from a recycled reset,
        // not a from-scratch build (wall-clock metadata only — the run
        // itself is bit-identical either way).
        self.report.build_reused = true;
        Ok(())
    }

    /// Fetch the thread-pinned worker out of `slot`, building it on
    /// first use and resetting it on every later one — the idiom every
    /// pooled grid loop (`run_sweep`, the DSE evaluator, the learn
    /// pipeline) uses inside
    /// [`crate::coordinator::parallel_map_pooled`].
    pub fn obtain<'w>(
        slot: &'w mut Option<SimWorker>,
        setup: &SimSetup,
        cfg: &SimConfig,
    ) -> Result<&'w mut SimWorker> {
        match slot {
            Some(w) => w.reset(setup, cfg)?,
            None => *slot = Some(SimWorker::build(setup, cfg)?),
        }
        Ok(slot.as_mut().expect("worker installed above"))
    }

    /// [`obtain`](SimWorker::obtain) with a user-supplied scheduler.
    pub fn obtain_with_scheduler<'w>(
        slot: &'w mut Option<SimWorker>,
        setup: &SimSetup,
        cfg: &SimConfig,
        scheduler: Box<dyn Scheduler>,
    ) -> Result<&'w mut SimWorker> {
        match slot {
            Some(w) => w.reset_with_scheduler(setup, cfg, scheduler)?,
            None => {
                *slot = Some(SimWorker::build_with_scheduler(
                    setup, cfg, scheduler,
                )?)
            }
        }
        Ok(slot.as_mut().expect("worker installed above"))
    }

    /// Move every recyclable buffer out, leaving the worker hollow.
    fn take_spares(&mut self) -> SimSpares {
        SimSpares {
            events: std::mem::take(&mut self.events),
            jobs: std::mem::take(&mut self.jobs),
            job_pool: std::mem::take(&mut self.job_pool),
            pes: std::mem::take(&mut self.pes),
            pe_available: std::mem::take(&mut self.pe_available),
            ready: std::mem::take(&mut self.ready),
            cluster_opp_idx: std::mem::take(&mut self.cluster_opp_idx),
            cluster_mhz: std::mem::take(&mut self.cluster_mhz),
            dvfs_clusters: std::mem::take(&mut self.dvfs_clusters),
            theta: std::mem::take(&mut self.theta),
            theta_scratch: std::mem::take(&mut self.theta_scratch),
            energy: std::mem::take(&mut self.energy),
            ready_scratch: std::mem::take(&mut self.ready_scratch),
            snap_scratch: std::mem::take(&mut self.snap_scratch),
            assigned_scratch: std::mem::take(&mut self.assigned_scratch),
            kept_scratch: std::mem::take(&mut self.kept_scratch),
            pending: std::mem::take(&mut self.pending),
            seg_pool: std::mem::take(&mut self.seg_pool),
            util_scratch: std::mem::take(&mut self.util_scratch),
            busy_scratch: std::mem::take(&mut self.busy_scratch),
            power_scratch: std::mem::take(&mut self.power_scratch),
            t_pe_scratch: std::mem::take(&mut self.t_pe_scratch),
            opps_scratch: std::mem::take(&mut self.opps_scratch),
            phase_lats: std::mem::take(&mut self.phase_lats),
            report: std::mem::take(&mut self.report),
        }
    }

    /// The one constructor behind both `build` (empty spares) and
    /// `reset` (recycled spares).  Per-run state depends only on
    /// `(setup, cfg)`; the spares contribute capacity, never values —
    /// which is what makes reset bit-identical to a fresh build.
    fn fresh(
        setup: &SimSetup,
        cfg: &SimConfig,
        scheduler_override: Option<Box<dyn Scheduler>>,
        mut spares: SimSpares,
    ) -> Result<SimWorker> {
        let build_t0 = crate::telemetry::SpanTimer::start();
        cfg.validate()?;
        let platform = setup.platform();
        let apps = setup.apps();

        let scheduler = match scheduler_override {
            Some(s) => s,
            None => {
                let build = SchedBuild {
                    platform,
                    apps,
                    seed: cfg.seed,
                    artifacts_dir: cfg.artifacts_dir.clone(),
                    policy_path: cfg.il_policy.clone(),
                };
                crate::sched::create(&cfg.scheduler, &build)?
            }
        };

        // Scenario: validate against this platform/workload, dry-run any
        // hot-swap scheduler names through the registry so a typo fails
        // at build time, and expand the timeline into executable form.
        let timeline = match &cfg.scenario {
            Some(sc) => {
                sc.validate()?;
                sc.validate_for(platform, apps.len())?;
                let build = SchedBuild {
                    platform,
                    apps,
                    seed: cfg.seed,
                    artifacts_dir: cfg.artifacts_dir.clone(),
                    policy_path: cfg.il_policy.clone(),
                };
                for name in sc.scheduler_names() {
                    crate::sched::create(name, &build).map_err(|e| {
                        Error::Config(format!(
                            "scenario '{}' hot-swaps to an unusable \
                             scheduler: {e}",
                            sc.name
                        ))
                    })?;
                }
                sc.compile(cfg.injection_rate_per_ms)
            }
            None => Vec::new(),
        };
        let governor = dtpm::create_governor(&cfg.dtpm)?;
        // RC model: clone the setup's template when this run's DTPM
        // epoch matches the one it was discretized at (the common case
        // across a grid); a differing epoch forces an eager rebuild.
        let rc = if setup.rc_template.dt_us == cfg.dtpm.epoch_us {
            setup.rc_template.clone()
        } else {
            RcModel::new(platform, cfg.dtpm.epoch_us)
        };

        let explore_requested = cfg.dtpm.governor == "explore-xla";
        let dtpm_xla = if cfg.use_xla_thermal || explore_requested {
            let dir = cfg
                .artifacts_dir
                .clone()
                .unwrap_or_else(crate::runtime::default_artifacts_dir);
            let mut art = DtpmArtifact::load(&dir)?;
            let (k1, k2): (Vec<f64>, Vec<f64>) = platform
                .pes
                .iter()
                .map(|pe| {
                    let c = &platform.classes[pe.class];
                    (rc.leak_k1_effective(c.leak_k1, c.leak_k2), c.leak_k2)
                })
                .unzip();
            art.set_model(&rc, &k1, &k2)?;
            Some(art)
        } else {
            None
        };

        let jobgen = match &cfg.trace_file {
            Some(path) => {
                let j = crate::util::json::Json::parse_file(path)?;
                let gen = JobGen::from_trace_json(&j, cfg.max_jobs)?;
                gen
            }
            None => JobGen::new(
                cfg.arrival,
                cfg.injection_rate_per_ms,
                apps.len(),
                &cfg.app_weights,
                cfg.max_jobs,
                cfg.seed,
            ),
        };
        // The explore-xla governor spans the first two DVFS-capable
        // clusters (big + LITTLE on the Table-2 SoC).
        spares.dvfs_clusters.clear();
        spares.dvfs_clusters.extend(
            platform
                .clusters
                .iter()
                .filter(|c| platform.classes[c.class].opps.len() > 1)
                .map(|c| c.id)
                .take(2),
        );
        let explore = if explore_requested {
            if spares.dvfs_clusters.is_empty() {
                return Err(Error::Config(
                    "explore-xla governor needs a DVFS-capable cluster"
                        .into(),
                ));
            }
            let n_big = platform.classes
                [platform.clusters[spares.dvfs_clusters[0]].class]
                .opps
                .len();
            let n_little = spares
                .dvfs_clusters
                .get(1)
                .map(|&c| platform.classes[platform.clusters[c].class].opps.len())
                .unwrap_or(1);
            Some(ExploreDse::new(n_big, n_little, cfg.dtpm.throttle_temp_c))
        } else {
            None
        };

        // Governors start at max frequency (Linux boot default).
        spares.cluster_opp_idx.clear();
        spares.cluster_opp_idx.extend(
            platform
                .clusters
                .iter()
                .map(|c| platform.classes[c.class].opps.len() - 1),
        );
        spares.cluster_mhz.clear();
        {
            let opp_idx = &spares.cluster_opp_idx;
            spares.cluster_mhz.extend(
                platform.clusters.iter().enumerate().map(|(c, cl)| {
                    platform.classes[cl.class].opps[opp_idx[c]].freq_mhz
                }),
            );
        }

        // NoC: the hop table comes precomputed from the setup; only the
        // congestion mode is per-run.
        let mut noc = setup.noc_template.clone();
        noc.set_congestion(cfg.noc_congestion);

        let n_nodes = platform.floorplan.len();
        let n_pes = platform.n_pes();

        let mut report = spares.report.recycle();
        report.scheduler = scheduler.name().to_string();
        report.injection_rate_per_ms = cfg.injection_rate_per_ms;
        report.seed = cfg.seed;
        report.per_app_latencies_us.resize(apps.len(), Vec::new());
        if let Some(sc) = &cfg.scenario {
            report.scenario = sc.name.clone();
        }

        // Right-size the event heap from the run's shape: the queue
        // holds at most one pending arrival, one DTPM epoch, one
        // in-flight finish per PE, and the (up-front) scenario
        // timeline.  `EventQueue::peak_len` plus the capacity
        // regression test in `sim::tests` pin this bound.
        let ev_cap = (timeline.len() + n_pes + 64).clamp(256, 65_536);
        spares.events.reset(ev_cap);

        // Job-table capacity from the offered load: `max_jobs` when
        // bounded, else the expected arrivals over the simulated-time
        // wall at the configured rate (+25% headroom).
        let expect_jobs = if cfg.max_jobs > 0 {
            cfg.max_jobs
        } else {
            (cfg.max_sim_us / 1000.0 * cfg.injection_rate_per_ms * 1.25)
                as usize
        };
        let jobs_cap = expect_jobs.clamp(16, 65_536);
        // Reclaim the per-task buffers of jobs the previous run left
        // behind (incomplete jobs of saturated/aborted runs — completed
        // jobs donated theirs at completion) before clearing the table.
        for job in spares.jobs.drain(..) {
            if spares.job_pool.len() >= JOB_POOL_CAP {
                break;
            }
            if job.finish_us.capacity() > 0 {
                spares.job_pool.push(JobBufs {
                    pred_remaining: job.pred_remaining,
                    finish_us: job.finish_us,
                    assigned_pe: job.assigned_pe,
                });
            }
        }
        if spares.jobs.capacity() < jobs_cap {
            // len is 0 here (just drained), so this guarantees
            // capacity >= jobs_cap.
            spares.jobs.reserve(jobs_cap);
        }

        spares.pes.truncate(n_pes);
        for pe in &mut spares.pes {
            pe.reset();
        }
        while spares.pes.len() < n_pes {
            spares.pes.push(PeState::new());
        }
        spares.pe_available.clear();
        spares.pe_available.resize(n_pes, true);
        spares.ready.clear();
        if spares.ready.capacity() < 256 {
            spares.ready.reserve(256 - spares.ready.len());
        }
        spares.theta.clear();
        spares.theta.resize(n_nodes, 0.0);
        spares.theta_scratch.clear();
        spares.theta_scratch.resize(n_nodes, 0.0);
        spares.energy.reset(n_pes);
        // Deferred segments of an aborted previous run go back to the
        // segment pool.
        spares.seg_pool.append(&mut spares.pending);
        spares.phase_lats.clear();

        // The reset-vs-fresh build span (`build_reused` is set by
        // `reset_inner` after this returns).
        report.build_wall_ns = build_t0.elapsed_ns();

        Ok(SimWorker {
            cfg: cfg.clone(),
            noc,
            rc,
            scheduler,
            governor,
            explore,
            dvfs_clusters: spares.dvfs_clusters,
            throttle: cfg
                .dtpm
                .thermal_throttle
                .then(|| ThermalThrottle::new(cfg.dtpm.throttle_temp_c)),
            power_cap: cfg.dtpm.power_cap_w.map(PowerCap::new),
            dtpm_xla,
            now: 0.0,
            events: spares.events,
            jobgen,
            jobs: spares.jobs,
            pes: spares.pes,
            timeline,
            pe_available: spares.pe_available,
            t_ambient_c: platform.t_ambient,
            ready: spares.ready,
            cluster_opp_idx: spares.cluster_opp_idx,
            theta: spares.theta,
            theta_scratch: spares.theta_scratch,
            energy: spares.energy,
            last_epoch_t: 0.0,
            last_epoch_power_w: 0.0,
            jitter_rng: Rng::new(cfg.seed ^ 0x7177_E44E_0C5A_11AA),
            cluster_mhz: spares.cluster_mhz,
            job_pool: spares.job_pool,
            ready_scratch: spares.ready_scratch,
            snap_scratch: spares.snap_scratch,
            assigned_scratch: spares.assigned_scratch,
            kept_scratch: spares.kept_scratch,
            pending: spares.pending,
            seg_pool: spares.seg_pool,
            util_scratch: spares.util_scratch,
            busy_scratch: spares.busy_scratch,
            power_scratch: spares.power_scratch,
            t_pe_scratch: spares.t_pe_scratch,
            opps_scratch: spares.opps_scratch,
            last_t_max_abs: platform.t_ambient,
            injected: 0,
            completed: 0,
            arrivals_done: false,
            report,
            sched_dirty: false,
            probe: None,
            phase_lats: spares.phase_lats,
            phase_energy0_j: 0.0,
            phase_peak_temp_c: 0.0,
            ran: false,
        })
    }

    /// Execution time of (app, task) on `pe` at current DVFS (no jitter).
    ///
    /// This is the single hottest probe in the kernel (every scheduler
    /// consults it O(ready × PEs) per decision epoch), so the
    /// PE→cluster→class→OPP pointer chain is flattened into the
    /// `pe_nominal_mhz` / `cluster_mhz` caches — the arithmetic (and
    /// therefore every golden trace) is unchanged.
    #[inline]
    fn exec_base_us(
        &self,
        setup: &SimSetup,
        app: usize,
        task: usize,
        pe: usize,
    ) -> f64 {
        let base = setup.exec_tables[app].us(task, pe);
        if !base.is_finite() {
            return f64::INFINITY;
        }
        base * setup.pe_nominal_mhz[pe]
            / self.cluster_mhz[setup.pe_cluster[pe]]
    }

    /// Re-derive the per-cluster frequency cache after OPP changes
    /// (end of every DTPM epoch — the only writer of `cluster_opp_idx`).
    fn refresh_cluster_mhz(&mut self, setup: &SimSetup) {
        for (c, cl) in setup.platform().clusters.iter().enumerate() {
            self.cluster_mhz[c] = setup.platform().classes[cl.class].opps
                [self.cluster_opp_idx[c]]
                .freq_mhz;
        }
    }

    /// Earliest time the inputs of (job, task) can be at `pe`.
    fn data_ready(
        &self,
        setup: &SimSetup,
        job: usize,
        task: usize,
        pe: usize,
    ) -> f64 {
        let j = &self.jobs[job];
        let app = &setup.apps()[j.app];
        let mut t = j.arrival_us;
        for &p in &app.tasks[task].preds {
            let fin = j.finish_us[p];
            debug_assert!(fin.is_finite(), "pred not finished");
            let src = j.assigned_pe[p];
            let arr = fin
                + self.noc.transfer_us(src, pe, app.tasks[p].out_bytes);
            if arr > t {
                t = arr;
            }
        }
        t
    }

    // -------------------------------------------------------------------
    // Main loop
    // -------------------------------------------------------------------

    /// Run to completion, finalizing the report in place (borrow it
    /// here, or move it out with [`take_report`](SimWorker::take_report)).
    /// A finished worker must be [`reset`](SimWorker::reset) before it
    /// can run again.
    pub fn run(&mut self, setup: &SimSetup) -> &SimReport {
        assert!(
            !self.ran,
            "SimWorker::run called twice without reset between runs"
        );
        self.ran = true;
        let wall0 = Instant::now();
        // Prime the event queue: the scenario timeline first (so
        // same-timestamp scenario events apply before task events — the
        // queue's (time, sequence) order makes this deterministic), then
        // the first arrival and the first DTPM epoch.
        if !self.timeline.is_empty() {
            self.begin_phase(setup, "baseline".to_string());
            for (seq, ev) in self.timeline.iter().enumerate() {
                self.events.push(ev.at_us, Event::Scenario { seq });
            }
        }
        self.schedule_next_arrival();
        self.events.push(self.cfg.dtpm.epoch_us, Event::DtpmEpoch);

        // Deterministic watchdog: count event-loop iterations (never
        // wall clock) against the configured step budget, so an
        // over-budget verdict is bit-reproducible across machines and
        // thread counts.  Disabled (budget 0) costs one u64 compare
        // per iteration.  An armed SlowLoop fault pre-charges the
        // counter, simulating a runaway point without actually looping.
        let budget = self.cfg.step_budget;
        let mut steps: u64 = if budget != 0 {
            crate::faultpoint::slow_penalty(
                crate::faultpoint::sites::SIM_LOOP,
                &self.cfg.scheduler,
            )
        } else {
            0
        };

        while let Some((at, ev)) = self.events.pop() {
            debug_assert!(at + 1e-9 >= self.now, "time went backwards");
            self.now = at;
            if self.now > self.cfg.max_sim_us {
                break;
            }
            if budget != 0 {
                steps += 1;
                if steps >= budget {
                    self.report.timed_out = true;
                    self.report.watchdog_steps = steps;
                    break;
                }
            }
            match ev {
                Event::JobArrival { app } => {
                    // Arrivals are job-scale (orders of magnitude
                    // rarer than task events), so one Instant pair
                    // per arrival prices the jobgen bucket at noise
                    // level — same rationale as the flush span.
                    let span = crate::telemetry::SpanTimer::start();
                    self.on_job_arrival(setup, app);
                    self.report.jobgen_wall_ns += span.elapsed_ns();
                }
                Event::TaskFinish { job, task, pe } => {
                    self.on_task_finish(setup, job, task, pe)
                }
                Event::DtpmEpoch => self.on_dtpm_epoch(setup),
                Event::Scenario { seq } => self.on_scenario(setup, seq),
            }
            // Decision epoch: a task finished or a job arrived.
            if self.sched_dirty && !self.ready.is_empty() {
                self.invoke_scheduler(setup);
            }
            if self.finished() {
                break;
            }
        }

        self.finalize(setup, wall0);
        &self.report
    }

    /// Move the finished run's report out (leaving a default in its
    /// place; the buffers return on the next reset's recycle).
    pub fn take_report(&mut self) -> SimReport {
        std::mem::take(&mut self.report)
    }

    /// Borrow the report of the last finished run.
    pub fn report(&self) -> &SimReport {
        &self.report
    }

    /// Attach a time-series probe recording the next run (see
    /// [`crate::probe`]).  A probe records exactly one run: `reset`
    /// drops it, so pooled grids re-attach per point.
    pub fn attach_probe(&mut self, cfg: crate::probe::ProbeConfig) {
        self.probe = Some(Box::new(crate::probe::ProbeRecorder::new(
            cfg,
            self.pes.len(),
            self.theta.len(),
        )));
    }

    /// Detach the probe of a finished run as a sealed
    /// [`crate::probe::TraceSeries`] artifact (`None` if no probe was
    /// attached).
    pub fn take_probe_trace(
        &mut self,
    ) -> Option<crate::probe::TraceSeries> {
        self.probe.take().map(|p| {
            p.into_trace(
                &self.report.scheduler,
                &self.report.scenario,
                self.report.seed,
            )
        })
    }

    /// Move the scheduler out (a [`NullSched`] takes its slot until the
    /// next reset).  Callers that wrapped shared state in a custom
    /// scheduler — the learn pipeline's recording `Collector` — use
    /// this to get their wrapper back after the run.
    pub fn take_scheduler(&mut self) -> Box<dyn Scheduler> {
        std::mem::replace(&mut self.scheduler, Box::new(NullSched))
    }

    fn finished(&self) -> bool {
        self.arrivals_done
            && self.completed == self.injected
            && self.ready.is_empty()
    }

    fn schedule_next_arrival(&mut self) {
        match self.jobgen.next() {
            Some(a) => {
                self.events.push(a.at_us, Event::JobArrival { app: a.app })
            }
            None => self.arrivals_done = true,
        }
    }

    fn on_job_arrival(&mut self, setup: &SimSetup, app_idx: usize) {
        assert!(
            app_idx < setup.apps().len(),
            "trace references app index {app_idx}, workload has {}",
            setup.apps().len()
        );
        let n = setup.apps()[app_idx].len();
        let job_id = self.jobs.len();
        // Per-task state comes from the free-list of completed jobs
        // (allocation-free at steady state) and is stamped from the
        // precomputed per-app templates.
        let mut bufs = self.job_pool.pop().unwrap_or_default();
        bufs.pred_remaining.clear();
        bufs.pred_remaining
            .extend_from_slice(&setup.app_pred_template[app_idx]);
        bufs.finish_us.clear();
        bufs.finish_us.resize(n, f64::NAN);
        bufs.assigned_pe.clear();
        bufs.assigned_pe.resize(n, usize::MAX);
        self.jobs.push(Job {
            app: app_idx,
            arrival_us: self.now,
            pred_remaining: bufs.pred_remaining,
            finish_us: bufs.finish_us,
            assigned_pe: bufs.assigned_pe,
            tasks_done: 0,
            done: false,
        });
        // Sources are immediately ready.
        for &s in &setup.app_sources[app_idx] {
            self.ready.push_back(ReadyTask {
                job: job_id,
                task: s,
                app: app_idx,
                arrival_us: self.now,
                ready_us: self.now,
            });
        }
        self.injected += 1;
        self.sched_dirty = true;
        self.schedule_next_arrival();
    }

    fn on_task_finish(
        &mut self,
        setup: &SimSetup,
        job_id: usize,
        task: usize,
        pe_id: usize,
    ) {
        // --- PE bookkeeping ---
        let end;
        {
            let pe = &mut self.pes[pe_id];
            debug_assert_eq!(pe.running, Some((job_id, task)));
            end = pe.busy_until_us;
            let add = (end - pe.accounted_us).max(0.0);
            pe.epoch_busy_us += add;
            pe.total_busy_us += end - pe.run_start_us;
            pe.running = None;
        }
        self.report.tasks_executed += 1;

        // --- job bookkeeping ---
        {
            let job = &mut self.jobs[job_id];
            job.finish_us[task] = end;
            job.tasks_done += 1;
        }
        let app_idx = self.jobs[job_id].app;
        let app = &setup.apps()[app_idx];
        // Propagate readiness.
        for &succ in app.succs(task) {
            let job = &mut self.jobs[job_id];
            job.pred_remaining[succ] -= 1;
            if job.pred_remaining[succ] == 0 {
                let arrival_us = job.arrival_us;
                self.ready.push_back(ReadyTask {
                    job: job_id,
                    task: succ,
                    app: app_idx,
                    arrival_us,
                    ready_us: self.now,
                });
            }
        }
        // Job completion.
        if self.jobs[job_id].tasks_done == app.len() {
            let job = &mut self.jobs[job_id];
            job.done = true;
            let latency = self.now - job.arrival_us;
            // Reclaim the per-task buffers into the free-list — no task
            // of a done job is ever consulted again (commit() rejects
            // stale assignments for done jobs before indexing).  Past
            // the in-run cap the pool is already as deep as a reset
            // could ever reuse, so extra buffers are freed eagerly —
            // this keeps completed-job memory bounded on one-shot
            // unbounded runs exactly like the pre-worker kernel.
            if self.job_pool.len() < JOB_POOL_CAP {
                self.job_pool.push(JobBufs {
                    pred_remaining: std::mem::take(
                        &mut job.pred_remaining,
                    ),
                    finish_us: std::mem::take(&mut job.finish_us),
                    assigned_pe: std::mem::take(&mut job.assigned_pe),
                });
            } else {
                job.pred_remaining = Vec::new();
                job.finish_us = Vec::new();
                job.assigned_pe = Vec::new();
            }
            self.completed += 1;
            if !self.timeline.is_empty() {
                // Scenario run: attribute the job to the current phase.
                self.phase_lats.push(latency);
            }
            if job_id >= self.cfg.warmup_jobs {
                self.report.job_latencies_us.push(latency);
                self.report.per_app_latencies_us[app_idx].push(latency);
            }
        }
        self.sched_dirty = true;
        self.try_start_next(setup, pe_id);
    }

    /// Start the next queued task on an idle PE, if any.
    fn try_start_next(&mut self, setup: &SimSetup, pe_id: usize) {
        if self.pes[pe_id].running.is_some() {
            return;
        }
        let Some((job_id, task)) = self.pes[pe_id].queue.pop_front() else {
            return;
        };
        let app_idx = self.jobs[job_id].app;
        let est = self.exec_base_us(setup, app_idx, task, pe_id);
        self.pes[pe_id].pending_est_us =
            (self.pes[pe_id].pending_est_us - est).max(0.0);

        let data_at = self.data_ready(setup, job_id, task, pe_id);
        let start = data_at.max(self.now);
        let mut exec = est;
        if self.cfg.exec_jitter_frac > 0.0 {
            let f = self
                .jitter_rng
                .normal(1.0, self.cfg.exec_jitter_frac)
                .clamp(0.5, 1.5);
            exec *= f;
        }
        debug_assert!(exec.is_finite(), "dispatch to unsupported PE");
        let end = start + exec;
        // NoC congestion tracking (first-order: flows start at dispatch).
        if self.noc.models_congestion() {
            self.noc.flow_started();
            self.noc.flow_finished();
        }
        {
            let pe = &mut self.pes[pe_id];
            pe.running = Some((job_id, task));
            pe.run_start_us = start;
            pe.busy_until_us = end;
            pe.accounted_us = start;
        }
        if self.cfg.capture_gantt
            && self.report.gantt.len() < self.cfg.gantt_limit
        {
            self.report.gantt.push(GanttEntry {
                pe: pe_id,
                job: job_id,
                app: app_idx,
                task,
                start_us: start,
                end_us: end,
            });
        }
        self.events
            .push(end, Event::TaskFinish { job: job_id, task, pe: pe_id });
    }

    // -------------------------------------------------------------------
    // Scheduling
    // -------------------------------------------------------------------

    /// Refresh the scheduler's PE view in place.  `avail_us` depends on
    /// `now`, so values are recomputed every epoch — but into the same
    /// reused buffer, so the per-event snapshot allocation of the old
    /// kernel is gone.
    fn fill_snapshots(&self, setup: &SimSetup, out: &mut Vec<PeSnapshot>) {
        out.clear();
        out.extend(setup.platform().pes.iter().map(|pe| PeSnapshot {
            id: pe.id,
            class: pe.class,
            cluster: pe.cluster,
            avail_us: self.pes[pe.id].avail_us(self.now),
            queue_len: self.pes[pe.id].queue.len()
                + self.pes[pe.id].running.is_some() as usize,
            available: self.pe_available[pe.id],
        }));
    }

    fn invoke_scheduler(&mut self, setup: &SimSetup) {
        self.sched_dirty = false;
        let window = self.ready.len().min(self.cfg.max_ready);
        // Scratch buffers are moved out of `self` for the duration of
        // the call (cheap pointer moves) so the context can borrow the
        // simulation immutably; their capacity survives across epochs.
        let mut ready_vec = std::mem::take(&mut self.ready_scratch);
        ready_vec.clear();
        ready_vec.extend(self.ready.iter().take(window).copied());
        let mut snapshots = std::mem::take(&mut self.snap_scratch);
        self.fill_snapshots(setup, &mut snapshots);

        // Temporarily lift the scheduler out of `self` so the context can
        // borrow the rest of the simulation immutably.
        let mut scheduler =
            std::mem::replace(&mut self.scheduler, Box::new(NullSched));
        let t0 = Instant::now();
        let assignments = {
            let ctx = CtxView { setup, w: self, snapshots: &snapshots };
            scheduler.schedule(&ready_vec, &ctx)
        };
        self.report.sched_wall_ns += t0.elapsed().as_nanos() as u64;
        self.scheduler = scheduler;
        self.report.sched_invocations += 1;
        self.snap_scratch = snapshots;
        self.ready_scratch = ready_vec;

        if assignments.is_empty() {
            return;
        }
        // Commit.
        let mut assigned = std::mem::take(&mut self.assigned_scratch);
        assigned.clear();
        for a in &assignments {
            if self.commit(setup, a) {
                assigned.push((a.job, a.task));
            }
        }
        // Remove committed tasks from the ready deque.  Assignments can
        // only reference the first `window` entries, so pop that prefix
        // and push back the unassigned ones in order — O(window) rather
        // than O(backlog) (the backlog can be thousands of tasks deep on
        // saturated sweeps; see EXPERIMENTS.md §Perf).
        if !assigned.is_empty() {
            let mut kept = std::mem::take(&mut self.kept_scratch);
            kept.clear();
            kept.extend(
                self.ready
                    .drain(..window)
                    .filter(|rt| !assigned.contains(&(rt.job, rt.task))),
            );
            for rt in kept.drain(..).rev() {
                self.ready.push_front(rt);
            }
            self.kept_scratch = kept;
        }
        self.assigned_scratch = assigned;
    }

    /// Validate and enqueue one assignment.  Returns false if rejected.
    fn commit(&mut self, setup: &SimSetup, a: &Assignment) -> bool {
        if a.pe >= self.pes.len() || a.job >= self.jobs.len() {
            return false;
        }
        if !self.pe_available[a.pe] {
            // Failed/hotplugged-out PE (scenario engine): reject; the
            // task stays ready for the next decision epoch.
            return false;
        }
        // A done job's per-task buffers live in the free-list: reject
        // stale assignments before indexing into them, and out-of-range
        // task ids from misbehaving schedulers outright.
        if self.jobs[a.job].done
            || a.task >= self.jobs[a.job].assigned_pe.len()
        {
            return false;
        }
        let app_idx = self.jobs[a.job].app;
        let est = self.exec_base_us(setup, app_idx, a.task, a.pe);
        if !est.is_finite() {
            // Scheduler picked an unsupported PE: reject (task stays
            // ready; a scheduler bug surfaces as starvation, not UB).
            return false;
        }
        if self.jobs[a.job].assigned_pe[a.task] != usize::MAX {
            return false; // duplicate assignment
        }
        self.jobs[a.job].assigned_pe[a.task] = a.pe;
        self.pes[a.pe].queue.push_back((a.job, a.task));
        self.pes[a.pe].pending_est_us += est;
        self.try_start_next(setup, a.pe);
        true
    }

    // -------------------------------------------------------------------
    // Scenario engine
    // -------------------------------------------------------------------

    /// Execute one scenario timeline entry.
    fn on_scenario(&mut self, setup: &SimSetup, seq: usize) {
        let ev = self.timeline[seq].clone();
        self.report.scenario_events += 1;
        if let Some(label) = ev.phase_label {
            self.begin_phase(setup, label);
        }
        match ev.action {
            Action::SetRate { per_ms } => self.jobgen.set_rate(per_ms),
            // compile() expands ramps to steps; handle a raw ramp from a
            // hand-built timeline as a step to its target.
            Action::RampRate { to_per_ms, .. } => {
                self.jobgen.set_rate(to_per_ms)
            }
            Action::SetAppWeights { weights } => {
                self.jobgen.set_weights(&weights)
            }
            Action::SetAmbient { t_c } => self.set_ambient(setup, t_c),
            Action::PeFail { pe } => self.pe_fail(pe),
            Action::PeRestore { pe } => {
                self.pe_available[pe] = true;
                self.sched_dirty = true;
            }
            Action::SetPowerCap { watts } => {
                // Epochs deferred under the old budget integrate before
                // the policy changes (the cap observes epoch power).
                self.flush_thermal(setup);
                match watts {
                    // Keep the cap's backoff state across budget changes.
                    Some(w) => match self.power_cap.as_mut() {
                        Some(cap) => cap.cap_w = w,
                        None => self.power_cap = Some(PowerCap::new(w)),
                    },
                    None => self.power_cap = None,
                }
            }
            Action::SetScheduler { name } => self.swap_scheduler(setup, &name),
        }
    }

    /// PE fault: the in-flight task (if any) runs to completion, the
    /// committed-but-unstarted queue is handed back to the scheduler,
    /// and the PE accepts no work until restored.
    fn pe_fail(&mut self, pe_id: usize) {
        if !self.pe_available[pe_id] {
            return;
        }
        self.pe_available[pe_id] = false;
        let queued: Vec<(usize, usize)> =
            self.pes[pe_id].queue.drain(..).collect();
        self.pes[pe_id].pending_est_us = 0.0;
        for (job_id, task) in queued {
            let job = &mut self.jobs[job_id];
            job.assigned_pe[task] = usize::MAX;
            let app = job.app;
            let arrival_us = job.arrival_us;
            self.ready.push_back(ReadyTask {
                job: job_id,
                task,
                app,
                arrival_us,
                ready_us: self.now,
            });
        }
        self.sched_dirty = true;
    }

    /// Ambient temperature step: absolute temperatures shift; the
    /// above-ambient thermal state is preserved and relaxes toward the
    /// new environment through the RC dynamics.
    fn set_ambient(&mut self, setup: &SimSetup, t_c: f64) {
        // Deferred epochs ran under the old ambient: integrate them
        // before the RC model and offsets change.
        self.flush_thermal(setup);
        self.t_ambient_c = t_c;
        self.rc.t_ambient = t_c;
        if let Some(art) = self.dtpm_xla.as_mut() {
            // Re-fold the ambient offset into the artifact's leakage
            // coefficients (k1_eff depends on ambient).
            let (k1, k2): (Vec<f64>, Vec<f64>) = setup
                .platform()
                .pes
                .iter()
                .map(|pe| {
                    let c = &setup.platform().classes[pe.class];
                    (
                        self.rc.leak_k1_effective(c.leak_k1, c.leak_k2),
                        c.leak_k2,
                    )
                })
                .unzip();
            if let Err(e) = art.set_model(&self.rc, &k1, &k2) {
                crate::telemetry::diag("sim.scenario", || {
                    format!(
                        "scenario ambient step: artifact refresh failed \
                         ({e}); native fallback"
                    )
                });
                self.dtpm_xla = None;
            }
        }
    }

    /// Scheduler hot-swap through the registry.  Names are dry-run at
    /// build time, so failures here only happen on registry state that
    /// changed mid-run (e.g. artifacts disappearing); the old scheduler
    /// is kept in that case.
    fn swap_scheduler(&mut self, setup: &SimSetup, name: &str) {
        let build = SchedBuild {
            platform: setup.platform(),
            apps: setup.apps(),
            seed: self.cfg.seed,
            artifacts_dir: self.cfg.artifacts_dir.clone(),
            policy_path: self.cfg.il_policy.clone(),
        };
        match crate::sched::create(name, &build) {
            Ok(s) => {
                self.scheduler = s;
                if !self.report.scheduler.ends_with(name) {
                    self.report.scheduler.push_str(&format!("+{name}"));
                }
                self.sched_dirty = true;
            }
            Err(e) => crate::telemetry::diag("sim.scenario", || {
                format!("scenario scheduler swap to '{name}' failed: {e}")
            }),
        }
    }

    /// Close the current stats phase (if any) and open a new one.  A
    /// phase that would close at zero length (e.g. "baseline" displaced
    /// by a t=0 timeline event) is taken over instead of recorded empty.
    fn begin_phase(&mut self, setup: &SimSetup, label: String) {
        if let Some(last) = self.report.phases.last_mut() {
            if last.start_us == self.now {
                if let Some(p) = self.probe.as_deref_mut() {
                    p.relabel_last_marker(&label);
                }
                last.label = label;
                return;
            }
        }
        self.close_phase(setup);
        if let Some(p) = self.probe.as_deref_mut() {
            p.phase_marker(self.now, &label);
        }
        self.phase_lats.clear();
        self.phase_energy0_j = self.energy.total_energy_j();
        self.phase_peak_temp_c = 0.0;
        self.report.phases.push(PhaseStats {
            label,
            start_us: self.now,
            ..Default::default()
        });
    }

    /// Seal the open phase's accumulators into its [`PhaseStats`].
    /// Energy integrates at DTPM-epoch granularity, so an epoch spanning
    /// a boundary is attributed to the phase it *ends* in.
    fn close_phase(&mut self, setup: &SimSetup) {
        // Deferred epochs belong to the closing phase: integrate them
        // before reading the energy/peak accumulators.  (Also covers
        // finalize for static runs — close_phase is its first step.)
        self.flush_thermal(setup);
        let Some(p) = self.report.phases.last_mut() else { return };
        p.end_us = self.now;
        p.jobs_completed = self.phase_lats.len();
        let s = crate::util::Summary::of(&self.phase_lats);
        p.avg_latency_us = s.mean;
        p.p95_latency_us = s.p95;
        p.energy_j = self.energy.total_energy_j() - self.phase_energy0_j;
        let dur_s = (p.end_us - p.start_us).max(0.0) * 1e-6;
        p.avg_power_w =
            if dur_s > 0.0 { p.energy_j / dur_s } else { 0.0 };
        p.peak_temp_c = self.phase_peak_temp_c;
    }

    // -------------------------------------------------------------------
    // DTPM epoch
    // -------------------------------------------------------------------

    /// Whether the epoch closing now can be integrated later: nothing
    /// in the decision path (throttle, power cap, predictive DSE,
    /// traces) observes power or temperature this epoch.
    fn can_defer(&self) -> bool {
        !self.cfg.eager_integration
            && !self.cfg.capture_traces
            && self.throttle.is_none()
            && self.power_cap.is_none()
            && self.explore.is_none()
    }

    /// Integrate every pending power/thermal segment, replaying the
    /// exact per-epoch arithmetic of the eager path (power from
    /// pre-step temperatures, RC step, energy, peak tracking) so lazy
    /// and eager integration are bit-identical — asserted by
    /// `tests/golden_traces.rs`.
    fn flush_thermal(&mut self, setup: &SimSetup) {
        if self.pending.is_empty() {
            return;
        }
        self.report.thermal_flushes += 1;
        let span = crate::telemetry::SpanTimer::start();
        let mut segs = std::mem::take(&mut self.pending);
        let mut powers = std::mem::take(&mut self.power_scratch);
        let mut t_pe = std::mem::take(&mut self.t_pe_scratch);
        let mut opps = std::mem::take(&mut self.opps_scratch);
        for seg in segs.drain(..) {
            // OPPs that were in force during the segment's epoch.
            opps.clear();
            for (c, cl) in setup.platform().clusters.iter().enumerate() {
                opps.push(
                    setup.platform().classes[cl.class].opps[seg.opp_idx[c]],
                );
            }
            // Power from pre-step temperatures, then the RC step.
            t_pe.clear();
            t_pe.extend(
                self.rc
                    .pe_node
                    .iter()
                    .map(|&nd| self.theta[nd] + self.t_ambient_c),
            );
            power::epoch_power_into(
                setup.platform(),
                &opps,
                &seg.util,
                &t_pe,
                &mut powers,
            );
            self.rc.step_into(
                &self.theta,
                &powers,
                &mut self.theta_scratch,
            );
            std::mem::swap(&mut self.theta, &mut self.theta_scratch);
            self.account_epoch(&powers, &seg.busy, seg.dt_us);
            self.seg_pool.push(seg);
        }
        self.pending = segs;
        self.power_scratch = powers;
        self.t_pe_scratch = t_pe;
        self.opps_scratch = opps;
        // Flushes happen at observation-point scale (epochs, not
        // events), so one Instant pair per flush is noise-level cost.
        self.report.thermal_wall_ns += span.elapsed_ns();
    }

    /// Energy + peak-temperature accounting for one integrated epoch
    /// (`theta` already stepped).  Shared by the lazy flush and the
    /// device path so the two can never drift apart.
    fn account_epoch(&mut self, powers: &[f64], busy: &[f64], dt: f64) {
        self.energy.add_epoch(powers, busy, dt);
        let p_total_w: f64 = powers.iter().sum();
        self.last_epoch_power_w = p_total_w;
        let t_max_abs = self.theta.iter().copied().fold(0.0, f64::max)
            + self.t_ambient_c;
        self.last_t_max_abs = t_max_abs;
        if t_max_abs > self.report.peak_temp_c {
            self.report.peak_temp_c = t_max_abs;
        }
        if !self.timeline.is_empty() && t_max_abs > self.phase_peak_temp_c
        {
            self.phase_peak_temp_c = t_max_abs;
        }
        // Probe hook: integration channels.  `account_epoch` is the
        // one accounting point shared by the lazy flush, the eager
        // path, and the device lane, and the lazy flush replays
        // epochs in order — so the probe's cumulative-dt cursor
        // reconstructs identical epoch-end timestamps on every lane.
        if let Some(p) = self.probe.as_deref_mut() {
            p.sample_thermal(dt, &self.theta, self.t_ambient_c, p_total_w);
        }
    }

    /// One eager power/thermal epoch through the PJRT artifact (single
    /// candidate row).  Returns false if the device call failed — the
    /// artifact is dropped and the caller integrates this (and every
    /// later) epoch through the native segment lane instead.
    fn epoch_step_xla(
        &mut self,
        setup: &SimSetup,
        dt: f64,
        util: &[f64],
        busy: &[f64],
    ) -> bool {
        let span = crate::telemetry::SpanTimer::start();
        let cluster_opps: Vec<Opp> = (0..setup.platform().clusters.len())
            .map(|c| {
                let class = setup.platform().clusters[c].class;
                setup.platform().classes[class].opps[self.cluster_opp_idx[c]]
            })
            .collect();
        // Dynamic power host-side, leakage + thermal step on-device.
        let p_dyn: Vec<f64> = setup
            .platform()
            .pes
            .iter()
            .map(|pe| {
                power::p_dynamic(
                    &setup.platform().classes[pe.class],
                    cluster_opps[pe.cluster],
                    util[pe.id],
                )
            })
            .collect();
        let volts: Vec<f64> = setup
            .platform()
            .pes
            .iter()
            .map(|pe| cluster_opps[pe.cluster].volt)
            .collect();
        let Some(art) = self.dtpm_xla.as_mut() else { return false };
        let powers = match art.step(&self.theta, &[(p_dyn.clone(), volts)])
        {
            Ok(out) => {
                self.theta.copy_from_slice(&out.t_next[0]);
                self.report.device_calls = art.calls;
                out.p_total[0].clone()
            }
            Err(e) => {
                // Degrade to the native lane mid-run.
                crate::telemetry::diag("sim.dtpm-xla", || {
                    format!("dtpm-xla failed ({e}); native fallback")
                });
                self.dtpm_xla = None;
                return false;
            }
        };
        self.account_epoch(&powers, busy, dt);
        self.report.thermal_flushes += 1;
        self.report.thermal_wall_ns += span.elapsed_ns();
        true
    }

    fn on_dtpm_epoch(&mut self, setup: &SimSetup) {
        let dt = self.now - self.last_epoch_t;
        if dt <= 0.0 {
            self.events
                .push(self.now + self.cfg.dtpm.epoch_us, Event::DtpmEpoch);
            return;
        }
        // 1. Utilization over the closing epoch (reused scratch).
        let mut util = std::mem::take(&mut self.util_scratch);
        let mut busy = std::mem::take(&mut self.busy_scratch);
        util.clear();
        busy.clear();
        for pe in self.pes.iter_mut() {
            if pe.running.is_some() {
                let upto = self.now.min(pe.busy_until_us);
                let add = (upto - pe.accounted_us).max(0.0);
                pe.epoch_busy_us += add;
                pe.accounted_us = pe.accounted_us.max(upto);
            }
            busy.push(pe.epoch_busy_us);
            util.push((pe.epoch_busy_us / dt).clamp(0.0, 1.0));
            pe.epoch_busy_us = 0.0;
        }

        // 2+3. Power, thermal step, energy.  The device path is always
        // eager (stateful artifact); the native path accumulates a
        // piecewise-constant segment and integrates lazily unless a
        // policy or trace observes this epoch.  A failed device call
        // also lands in the segment lane (this epoch onwards).
        let device_done = self.dtpm_xla.is_some()
            && self.epoch_step_xla(setup, dt, &util, &busy);
        if !device_done {
            let mut seg = self.seg_pool.pop().unwrap_or_default();
            seg.dt_us = dt;
            seg.util.clear();
            seg.util.extend_from_slice(&util);
            seg.busy.clear();
            seg.busy.extend_from_slice(&busy);
            seg.opp_idx.clear();
            seg.opp_idx.extend_from_slice(&self.cluster_opp_idx);
            self.pending.push(seg);
            // Bound the deferred backlog: flushing early is always
            // valid (replay is exact), so very long runs hold at most
            // MAX_PENDING_EPOCHS segments instead of O(#epochs).
            if !self.can_defer()
                || self.pending.len() >= MAX_PENDING_EPOCHS
            {
                self.flush_thermal(setup);
            } else {
                self.report.deferred_epochs += 1;
            }
        }
        // Valid whenever a policy below consumes them: any policy
        // forces eager integration, which refreshes both every epoch.
        let t_max_abs = self.last_t_max_abs;
        let p_total_w = self.last_epoch_power_w;

        // 4. Governor + DTPM policies pick OPPs for the next epoch.
        //
        // 4a. Predictive DSE ("explore-xla"): one batched artifact call
        // scores the whole candidate grid; fall through to the classic
        // governor only on device failure.
        let mut explored = false;
        if self.explore.is_some() && self.dtpm_xla.is_some() {
            explored = self.explore_epoch(setup, &util, t_max_abs);
        }
        for c in 0..setup.platform().clusters.len() {
            if explored && self.dvfs_clusters.contains(&c) {
                // OPPs already set by the DSE pick; policies still cap.
                let class_idx = setup.platform().clusters[c].class;
                let n_opps =
                    setup.platform().classes[class_idx].opps.len();
                let mut idx = self.cluster_opp_idx[c];
                if let Some(th) = self.throttle.as_mut() {
                    idx = th.apply(idx, t_max_abs);
                }
                if let Some(cap) = self.power_cap.as_mut() {
                    idx = cap.apply(idx, p_total_w);
                }
                self.cluster_opp_idx[c] = idx.min(n_opps - 1);
                continue;
            }
            let class_idx = setup.platform().clusters[c].class;
            let class = &setup.platform().classes[class_idx];
            if class.opps.len() == 1 {
                continue; // accelerators: fixed OPP
            }
            // Linux-style: cluster utilization = max over member PEs.
            let u = setup.platform().clusters[c]
                .pe_ids
                .iter()
                .map(|&p| util[p])
                .fold(0.0, f64::max);
            let mut idx = self.governor.decide(
                c,
                u,
                self.cluster_opp_idx[c],
                &class.opps,
            );
            if let Some(th) = self.throttle.as_mut() {
                idx = th.apply(idx, t_max_abs);
            }
            if let Some(cap) = self.power_cap.as_mut() {
                idx = cap.apply(idx, p_total_w);
            }
            self.cluster_opp_idx[c] = idx.min(class.opps.len() - 1);
        }
        self.refresh_cluster_mhz(setup);
        // Probe hook: epoch-boundary channels.  Nothing here reads
        // integrated power/thermal state, so the samples are identical
        // on the lazy and eager lanes.
        if let Some(p) = self.probe.as_deref_mut() {
            p.sample_epoch(
                self.now,
                &util,
                &self.pe_available,
                &self.cluster_mhz,
                &setup.pe_cluster,
                self.ready.len(),
                self.report.sched_invocations,
            );
        }
        self.util_scratch = util;
        self.busy_scratch = busy;

        // 5. Trace (capture forces eager integration, so `theta` and
        // the last epoch power are current here).
        if self.cfg.capture_traces {
            self.report.trace.push(EpochTrace {
                t_us: self.now,
                temps_c: self
                    .theta
                    .iter()
                    .map(|t| t + self.t_ambient_c)
                    .collect(),
                power_w: p_total_w,
                cluster_mhz: self.cluster_mhz.clone(),
            });
        }

        self.last_epoch_t = self.now;
        // Keep epochs coming while the system is active.
        if !(self.arrivals_done && self.completed == self.injected) {
            self.events
                .push(self.now + self.cfg.dtpm.epoch_us, Event::DtpmEpoch);
        }
    }

    /// One predictive-DSE decision: build the candidate grid, evaluate
    /// it in a single batched artifact call, commit the best candidate's
    /// OPP indices.  Returns false on device failure (callers then use
    /// the classic governor for this epoch).
    fn explore_epoch(
        &mut self,
        setup: &SimSetup,
        util: &[f64],
        _t_max_abs: f64,
    ) -> bool {
        let Some(expl) = self.explore.as_mut() else { return false };
        let Some(art) = self.dtpm_xla.as_mut() else { return false };
        let n_pes = setup.platform().n_pes();
        let grid = expl.grid.clone();

        // Current frequency per cluster (for utilization rescaling).
        let cur_mhz: Vec<f64> = (0..setup.platform().clusters.len())
            .map(|c| {
                let cl = setup.platform().clusters[c].class;
                setup.platform().classes[cl].opps[self.cluster_opp_idx[c]]
                    .freq_mhz
            })
            .collect();

        let mut cands: Vec<(Vec<f64>, Vec<f64>)> =
            Vec::with_capacity(grid.len());
        let mut feasible = vec![true; grid.len()];
        for (k, &(bi, li)) in grid.iter().enumerate() {
            let mut p_dyn = vec![0.0f64; n_pes];
            let mut volts = vec![0.0f64; n_pes];
            for pe in &setup.platform().pes {
                let cluster = pe.cluster;
                let class = &setup.platform().classes[pe.class];
                let opp = if Some(&cluster) == self.dvfs_clusters.first()
                {
                    class.opps[bi.min(class.opps.len() - 1)]
                } else if Some(&cluster) == self.dvfs_clusters.get(1) {
                    class.opps[li.min(class.opps.len() - 1)]
                } else {
                    class.opps[self.cluster_opp_idx[cluster]]
                };
                // Same work at lower frequency -> higher utilization.
                let u = (util[pe.id] * cur_mhz[cluster] / opp.freq_mhz)
                    .min(1.0);
                if self.dvfs_clusters.contains(&cluster)
                    && util[pe.id] * cur_mhz[cluster] / opp.freq_mhz
                        > 0.95
                {
                    feasible[k] = false;
                }
                p_dyn[pe.id] = power::p_dynamic(class, opp, u);
                volts[pe.id] = opp.volt;
            }
            cands.push((p_dyn, volts));
        }

        let out = match art.step(&self.theta, &cands) {
            Ok(o) => o,
            Err(e) => {
                crate::telemetry::diag("sim.explore-xla", || {
                    format!(
                        "explore-xla device failure ({e}); governor \
                         fallback"
                    )
                });
                return false;
            }
        };
        self.report.device_calls = art.calls;
        let t_peak_next: Vec<f64> = out
            .t_next
            .iter()
            .map(|row| {
                row.iter().copied().fold(0.0, f64::max)
                    + self.t_ambient_c
            })
            .collect();
        let k = expl.choose(&out.p_sum, &t_peak_next, &feasible);
        let (bi, li) = grid[k];
        let b_cluster = self.dvfs_clusters[0];
        let b_class = setup.platform().clusters[b_cluster].class;
        self.cluster_opp_idx[b_cluster] =
            bi.min(setup.platform().classes[b_class].opps.len() - 1);
        if let Some(&l_cluster) = self.dvfs_clusters.get(1) {
            let l_class = setup.platform().clusters[l_cluster].class;
            self.cluster_opp_idx[l_cluster] =
                li.min(setup.platform().classes[l_class].opps.len() - 1);
        }
        true
    }

    fn finalize(&mut self, setup: &SimSetup, wall0: Instant) {
        // Seal the last scenario phase at the final simulation time.
        self.close_phase(setup);
        self.report.injected_jobs = self.injected;
        self.report.completed_jobs = self.completed;
        self.report.sim_time_us = self.now;
        self.report.events_processed = self.events.popped;
        self.report.total_energy_j = self.energy.total_energy_j();
        self.report.avg_power_w = self.energy.avg_power_w();
        // In-place (the recycled buffer survives worker reuse).
        self.report.pe_utilization.clear();
        let now = self.now;
        self.report.pe_utilization.extend(self.pes.iter().map(|pe| {
            if now > 0.0 {
                (pe.total_busy_us / now).min(1.0)
            } else {
                0.0
            }
        }));
        if let Some(th) = &self.throttle {
            self.report.throttle_engagements = th.engagements;
        }
        self.report.scheduler_report = self.scheduler.report();
        let (decisions, fallbacks) = self.scheduler.decision_counts();
        self.report.sched_decisions = decisions;
        self.report.sched_fallbacks = fallbacks;
        self.report.wall_s = wall0.elapsed().as_secs_f64();
        // Event-loop bucket: whatever the instrumented stages
        // (scheduler, thermal flushes, jobgen) don't account for —
        // dispatch, queue ops, task bookkeeping.
        let total_ns = (self.report.wall_s * 1e9) as u64;
        self.report.loop_wall_ns = total_ns.saturating_sub(
            self.report.sched_wall_ns
                + self.report.thermal_wall_ns
                + self.report.jobgen_wall_ns,
        );
    }
}

/// Placeholder scheduler occupying the slot during an invocation.
struct NullSched;

impl Scheduler for NullSched {
    fn name(&self) -> &str {
        "null"
    }
    fn schedule(
        &mut self,
        _ready: &[ReadyTask],
        _ctx: &dyn SchedContext,
    ) -> Vec<Assignment> {
        Vec::new()
    }
}

/// Borrowed scheduler view of the simulation.
struct CtxView<'s, 'a> {
    setup: &'s SimSetup<'a>,
    w: &'s SimWorker,
    snapshots: &'s [PeSnapshot],
}

impl SchedContext for CtxView<'_, '_> {
    fn now_us(&self) -> f64 {
        self.w.now
    }
    fn pes(&self) -> &[PeSnapshot] {
        self.snapshots
    }
    fn exec_us(&self, rt: &ReadyTask, pe: usize) -> Option<f64> {
        // Out-of-range probes (instance tables can carry arbitrary ids)
        // and failed/hotplugged-out PEs support nothing.
        if !self.w.pe_available.get(pe).copied().unwrap_or(false) {
            return None;
        }
        let us = self.w.exec_base_us(self.setup, rt.app, rt.task, pe);
        us.is_finite().then_some(us)
    }
    fn data_ready_us(&self, rt: &ReadyTask, pe: usize) -> f64 {
        self.w.data_ready(self.setup, rt.job, rt.task, pe)
    }
    fn task_name(&self, rt: &ReadyTask) -> &str {
        &self.setup.apps()[rt.app].tasks[rt.task].name
    }
    fn app_name(&self, rt: &ReadyTask) -> &str {
        &self.setup.apps()[rt.app].name
    }
    fn headroom_frac(&self, cluster: usize) -> f64 {
        // DVFS headroom: current / max cluster frequency ...
        let Some(cl) = self.setup.platform().clusters.get(cluster)
        else {
            return 1.0;
        };
        let max_mhz =
            self.setup.platform().classes[cl.class].max_opp().freq_mhz;
        let dvfs = if max_mhz > 0.0 {
            (self.w.cluster_mhz[cluster] / max_mhz).clamp(0.0, 1.0)
        } else {
            1.0
        };
        // ... scaled by thermal headroom to the throttle trip point
        // (only when a throttle polices temperature; readings are from
        // the last integrated epoch, which is exact under any policy
        // because policies force eager integration).
        let thermal = if self.w.cfg.dtpm.thermal_throttle {
            let trip = self.w.cfg.dtpm.throttle_temp_c;
            let span = (trip - self.w.t_ambient_c).max(1e-9);
            ((trip - self.w.last_t_max_abs) / span).clamp(0.0, 1.0)
        } else {
            1.0
        };
        dvfs * thermal
    }
}

/// A one-shot simulation: the classic build → run facade over a
/// private [`SimSetup`] + [`SimWorker`] pair.  Grid evaluators that
/// run many points should share one setup and reuse workers instead
/// (see [`crate::coordinator::parallel_map_pooled`]).
pub struct Simulation<'a> {
    setup: SimSetup<'a>,
    worker: SimWorker,
}

impl<'a> Simulation<'a> {
    /// Build a simulation for `platform` running the `apps` workload mix.
    pub fn build(
        platform: &'a Platform,
        apps: &'a [AppGraph],
        cfg: &SimConfig,
    ) -> Result<Simulation<'a>> {
        let setup = SimSetup::new(platform, apps, cfg)?;
        let worker = SimWorker::build(&setup, cfg)?;
        Ok(Simulation { setup, worker })
    }

    /// Build with a user-supplied scheduler instead of resolving
    /// `cfg.scheduler` through the registry — the plug-and-play hook
    /// (`examples/custom_scheduler.rs`).
    pub fn build_with_scheduler(
        platform: &'a Platform,
        apps: &'a [AppGraph],
        cfg: &SimConfig,
        scheduler: Box<dyn Scheduler>,
    ) -> Result<Simulation<'a>> {
        let setup = SimSetup::new(platform, apps, cfg)?;
        let worker =
            SimWorker::build_with_scheduler(&setup, cfg, scheduler)?;
        Ok(Simulation { setup, worker })
    }

    /// Run to completion and produce the report.
    pub fn run(mut self) -> SimReport {
        self.worker.run(&self.setup);
        self.worker.take_report()
    }

    /// Attach a time-series probe ([`crate::probe`]) recorded by
    /// [`run_with_trace`](Simulation::run_with_trace).
    pub fn attach_probe(&mut self, cfg: crate::probe::ProbeConfig) {
        self.worker.attach_probe(cfg);
    }

    /// Run to completion; returns the report plus the sealed probe
    /// trace when one was attached.
    pub fn run_with_trace(
        mut self,
    ) -> (SimReport, Option<crate::probe::TraceSeries>) {
        self.worker.run(&self.setup);
        let trace = self.worker.take_probe_trace();
        (self.worker.take_report(), trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::suite::{self, WifiParams};

    fn quick_cfg(sched: &str, rate: f64, jobs: usize) -> SimConfig {
        let mut c = SimConfig::default();
        c.scheduler = sched.into();
        c.injection_rate_per_ms = rate;
        c.max_jobs = jobs;
        c.warmup_jobs = (jobs / 10).min(20);
        c
    }

    fn wifi1() -> Vec<AppGraph> {
        vec![suite::wifi_tx(WifiParams { symbols: 4 })]
    }

    #[test]
    fn completes_all_jobs_at_low_rate() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        let cfg = quick_cfg("etf", 0.5, 50);
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r.injected_jobs, 50);
        assert_eq!(r.completed_jobs, 50);
        assert!(r.avg_job_latency_us() > 0.0);
        assert!(r.tasks_executed as usize >= 50 * apps[0].len());
    }

    #[test]
    fn latency_lower_bounded_by_critical_path() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        let cp = apps[0].critical_path_us();
        let cfg = quick_cfg("etf", 0.2, 30);
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        let min = r
            .job_latencies_us
            .iter()
            .copied()
            .fold(f64::INFINITY, f64::min);
        assert!(
            min >= cp - 1e-6,
            "min latency {min} below critical path {cp}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        let cfg = quick_cfg("etf", 2.0, 60);
        let a = Simulation::build(&p, &apps, &cfg).unwrap().run();
        let b = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(a.job_latencies_us, b.job_latencies_us);
        assert_eq!(a.events_processed, b.events_processed);
        assert!((a.total_energy_j - b.total_energy_j).abs() < 1e-12);
    }

    #[test]
    fn different_seeds_differ() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        let mut cfg = quick_cfg("etf", 2.0, 60);
        let a = Simulation::build(&p, &apps, &cfg).unwrap().run();
        cfg.seed = 1234;
        let b = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_ne!(a.job_latencies_us, b.job_latencies_us);
    }

    #[test]
    fn all_schedulers_run_clean() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        for s in ["met", "etf", "ilp", "heft", "random", "rr"] {
            let cfg = quick_cfg(s, 1.0, 40);
            let r = Simulation::build(&p, &apps, &cfg)
                .unwrap_or_else(|e| panic!("{s}: {e}"))
                .run();
            assert_eq!(r.completed_jobs, 40, "{s} lost jobs");
        }
    }

    #[test]
    fn energy_and_power_are_positive() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        let cfg = quick_cfg("etf", 2.0, 100);
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert!(r.total_energy_j > 0.0);
        assert!(r.avg_power_w > 0.0);
        assert!(r.peak_temp_c > p.t_ambient);
        // Idle-ish platform must not overheat.
        assert!(r.peak_temp_c < 105.0);
    }

    #[test]
    fn utilization_grows_with_rate() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        let lo = Simulation::build(&p, &apps, &quick_cfg("etf", 0.5, 80))
            .unwrap()
            .run();
        let hi = Simulation::build(&p, &apps, &quick_cfg("etf", 8.0, 80))
            .unwrap()
            .run();
        let sum = |r: &SimReport| -> f64 { r.pe_utilization.iter().sum() };
        assert!(
            sum(&hi) > sum(&lo),
            "hi {:?} !> lo {:?}",
            sum(&hi),
            sum(&lo)
        );
    }

    #[test]
    fn gantt_capture_respects_limit() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        let mut cfg = quick_cfg("etf", 1.0, 30);
        cfg.capture_gantt = true;
        cfg.gantt_limit = 25;
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r.gantt.len(), 25);
        // Entries are well-formed.
        for e in &r.gantt {
            assert!(e.end_us > e.start_us);
            assert!(e.pe < p.n_pes());
        }
    }

    #[test]
    fn traces_captured_when_enabled() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        let mut cfg = quick_cfg("etf", 1.0, 50);
        cfg.capture_traces = true;
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert!(!r.trace.is_empty());
        for tr in &r.trace {
            assert_eq!(tr.temps_c.len(), p.floorplan.len());
            assert!(tr.power_w >= 0.0);
        }
    }

    #[test]
    fn multi_app_mix_completes() {
        let p = Platform::table2_soc();
        let apps = vec![
            suite::wifi_tx(WifiParams { symbols: 2 }),
            suite::single_carrier_tx(),
            suite::range_detection(suite::RadarParams { pulses: 2 }),
        ];
        let mut cfg = quick_cfg("etf", 2.0, 90);
        cfg.app_weights = vec![1.0, 2.0, 1.0];
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r.completed_jobs, 90);
        // All three apps contributed measured jobs.
        for (i, lats) in r.per_app_latencies_us.iter().enumerate() {
            assert!(!lats.is_empty(), "app {i} has no completions");
        }
    }

    #[test]
    fn rejects_empty_workload() {
        let p = Platform::table2_soc();
        let cfg = SimConfig::default();
        assert!(Simulation::build(&p, &[], &cfg).is_err());
    }

    #[test]
    fn ondemand_tracks_load() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        let mut cfg = quick_cfg("etf", 6.0, 200);
        cfg.dtpm.governor = "ondemand".into();
        cfg.capture_traces = true;
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r.completed_jobs, 200);
        // Under load, ondemand must have raised the big cluster's
        // frequency above min in at least one epoch.
        let raised = r
            .trace
            .iter()
            .any(|tr| tr.cluster_mhz[0] > 200.0);
        assert!(raised);
    }

    #[test]
    fn scenario_rate_step_shifts_per_phase_throughput() {
        use crate::scenario::Scenario;
        let p = Platform::table2_soc();
        let apps = wifi1();
        let mut cfg = quick_cfg("etf", 1.0, 300);
        cfg.scenario = Some(
            Scenario::new("step", "")
                .event(50_000.0, Action::SetRate { per_ms: 8.0 }),
        );
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r.completed_jobs, 300);
        assert_eq!(r.scenario, "step");
        assert_eq!(r.phases.len(), 2, "{:?}", r.phases);
        let rate = |ph: &crate::stats::PhaseStats| {
            ph.jobs_completed as f64 / (ph.duration_us() / 1000.0)
        };
        assert!(r.phases.iter().all(|ph| ph.jobs_completed > 0));
        assert!(
            rate(&r.phases[1]) > 3.0 * rate(&r.phases[0]),
            "phase rates: {} vs {}",
            rate(&r.phases[0]),
            rate(&r.phases[1])
        );
    }

    #[test]
    fn scenario_pe_failure_requeues_and_completes() {
        use crate::scenario::Scenario;
        let p = Platform::table2_soc();
        let apps = wifi1();
        let mut cfg = quick_cfg("etf", 2.0, 300);
        cfg.capture_gantt = true;
        cfg.gantt_limit = usize::MAX >> 1;
        let mut sc = Scenario::new("fft-out", "");
        for pe in 10..14 {
            sc = sc.event(30_000.0, Action::PeFail { pe });
        }
        for pe in 10..14 {
            sc = sc.event(90_000.0, Action::PeRestore { pe });
        }
        cfg.scenario = Some(sc);
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        // Nothing is lost to the fault: every queued task was re-queued
        // and re-placed on the surviving PEs.
        assert_eq!(r.completed_jobs, 300);
        // No execution may *start* on a failed PE inside the outage.
        // Small slack past the fault time: a task dispatched just before
        // the fault counts as in-flight even while its input data is
        // still crossing the NoC.
        for e in &r.gantt {
            if (10..14).contains(&e.pe) {
                assert!(
                    e.start_us < 30_010.0 || e.start_us >= 90_000.0,
                    "task started on failed pe {} at {}",
                    e.pe,
                    e.start_us
                );
            }
        }
        // The accelerators are used again after restore.
        assert!(
            r.gantt
                .iter()
                .any(|e| (10..14).contains(&e.pe)
                    && e.start_us >= 90_000.0),
            "FFT engines never used after hotplug"
        );
        // Per-phase latency shows the fault: FFT work fell back to the
        // cores, so the outage phase is visibly slower.
        assert_eq!(r.phases.len(), 3);
        assert!(
            r.phases[1].avg_latency_us > 1.5 * r.phases[0].avg_latency_us,
            "outage {} vs baseline {}",
            r.phases[1].avg_latency_us,
            r.phases[0].avg_latency_us
        );
    }

    #[test]
    fn scenario_runs_are_deterministic() {
        use crate::scenario::presets;
        let p = Platform::table2_soc();
        let apps = wifi1();
        let mut cfg = quick_cfg("etf", 2.0, 200);
        cfg.scenario = Some(presets::pe_failure());
        let a = Simulation::build(&p, &apps, &cfg).unwrap().run();
        let b = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(a.job_latencies_us, b.job_latencies_us);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.scenario_events, b.scenario_events);
        // The fault events (t = 50 ms) fire before the 200-job run
        // drains; the restores (t = 150 ms) may fall past the end.
        assert!(a.scenario_events >= 4);
    }

    #[test]
    fn scenario_scheduler_hot_swap_completes() {
        use crate::scenario::Scenario;
        let p = Platform::table2_soc();
        let apps = wifi1();
        let mut cfg = quick_cfg("etf", 2.0, 200);
        cfg.scenario = Some(
            Scenario::new("swap", "")
                .event(
                    30_000.0,
                    Action::SetScheduler { name: "met-lb".into() },
                )
                .event(
                    60_000.0,
                    Action::SetScheduler { name: "etf".into() },
                ),
        );
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r.completed_jobs, 200);
        assert!(r.scheduler.contains("met-lb"), "{}", r.scheduler);
        assert_eq!(r.phases.len(), 3);
    }

    #[test]
    fn scenario_ambient_step_shifts_absolute_temperature() {
        use crate::scenario::Scenario;
        let p = Platform::table2_soc();
        let apps = wifi1();
        let mut cfg = quick_cfg("etf", 1.0, 120);
        cfg.scenario = Some(
            Scenario::new("hot-room", "")
                .event(20_000.0, Action::SetAmbient { t_c: 60.0 }),
        );
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r.completed_jobs, 120);
        // Absolute temperatures ride on the new ambient.
        assert!(r.peak_temp_c > 60.0, "peak {}", r.peak_temp_c);
        assert_eq!(r.phases.len(), 2);
        assert!(
            r.phases[1].peak_temp_c > r.phases[0].peak_temp_c + 20.0,
            "phases: {:?}",
            r.phases
        );
    }

    #[test]
    fn scenario_build_rejects_unknown_pe_and_scheduler() {
        use crate::scenario::Scenario;
        let p = Platform::table2_soc();
        let apps = wifi1();
        let mut cfg = quick_cfg("etf", 1.0, 50);
        cfg.scenario = Some(
            Scenario::new("bad-pe", "")
                .event(0.0, Action::PeFail { pe: 99 }),
        );
        assert!(Simulation::build(&p, &apps, &cfg).is_err());
        cfg.scenario = Some(Scenario::new("bad-sched", "").event(
            0.0,
            Action::SetScheduler { name: "warp-speed".into() },
        ));
        assert!(Simulation::build(&p, &apps, &cfg).is_err());
    }

    #[test]
    fn lazy_integration_is_bit_identical_to_eager() {
        // The lazy power/thermal lane replays deferred epochs with the
        // exact arithmetic of the eager path — every observable must
        // match to the bit, not just within tolerance.
        let p = Platform::table2_soc();
        let apps = wifi1();
        for sched in ["etf", "met", "rr"] {
            let lazy_cfg = quick_cfg(sched, 3.0, 80);
            let mut eager_cfg = lazy_cfg.clone();
            eager_cfg.eager_integration = true;
            let a = Simulation::build(&p, &apps, &lazy_cfg).unwrap().run();
            let b =
                Simulation::build(&p, &apps, &eager_cfg).unwrap().run();
            assert_eq!(a.job_latencies_us, b.job_latencies_us, "{sched}");
            assert_eq!(a.events_processed, b.events_processed, "{sched}");
            assert_eq!(
                a.total_energy_j.to_bits(),
                b.total_energy_j.to_bits(),
                "{sched}: energy diverged"
            );
            assert_eq!(
                a.peak_temp_c.to_bits(),
                b.peak_temp_c.to_bits(),
                "{sched}: peak temp diverged"
            );
            // The lazy run actually deferred work; the eager run didn't.
            assert!(a.deferred_epochs > 0, "{sched}: nothing deferred");
            assert_eq!(b.deferred_epochs, 0);
        }
    }

    #[test]
    fn lazy_integration_matches_eager_under_scenario_phases() {
        use crate::scenario::presets;
        let p = Platform::table2_soc();
        let apps = wifi1();
        let mut lazy_cfg = quick_cfg("etf", 2.0, 150);
        lazy_cfg.dtpm.governor = "ondemand".into();
        lazy_cfg.scenario = Some(presets::pe_failure());
        let mut eager_cfg = lazy_cfg.clone();
        eager_cfg.eager_integration = true;
        let a = Simulation::build(&p, &apps, &lazy_cfg).unwrap().run();
        let b = Simulation::build(&p, &apps, &eager_cfg).unwrap().run();
        assert_eq!(a.job_latencies_us, b.job_latencies_us);
        assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
        assert_eq!(a.phases.len(), b.phases.len());
        for (pa, pb) in a.phases.iter().zip(&b.phases) {
            assert_eq!(pa.energy_j.to_bits(), pb.energy_j.to_bits());
            assert_eq!(pa.peak_temp_c.to_bits(), pb.peak_temp_c.to_bits());
            assert_eq!(pa.jobs_completed, pb.jobs_completed);
        }
    }

    #[test]
    fn throttle_and_caps_force_eager_integration() {
        // Policies observe per-epoch temperature/power, so runs with a
        // throttle or power cap must never defer.
        let p = Platform::table2_soc();
        let apps = wifi1();
        let mut cfg = quick_cfg("etf", 4.0, 80);
        cfg.dtpm.thermal_throttle = true;
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r.deferred_epochs, 0);
        assert!(r.thermal_flushes > 0);

        let mut cfg = quick_cfg("etf", 4.0, 80);
        cfg.dtpm.power_cap_w = Some(4.0);
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r.deferred_epochs, 0);
    }

    #[test]
    fn job_buffer_pool_reuses_across_arrivals() {
        // Many sequential jobs at a low rate: the pool keeps the run
        // behaviourally identical to the allocating implementation.
        let p = Platform::table2_soc();
        let apps = wifi1();
        let cfg = quick_cfg("etf", 0.5, 200);
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r.completed_jobs, 200);
        let again = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r.job_latencies_us, again.job_latencies_us);
    }

    fn reports_bit_identical(a: &SimReport, b: &SimReport) {
        assert_eq!(a.job_latencies_us, b.job_latencies_us);
        assert_eq!(a.per_app_latencies_us, b.per_app_latencies_us);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.tasks_executed, b.tasks_executed);
        assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
        assert_eq!(a.peak_temp_c.to_bits(), b.peak_temp_c.to_bits());
        assert_eq!(a.completed_jobs, b.completed_jobs);
        assert_eq!(a.injected_jobs, b.injected_jobs);
        assert_eq!(a.sched_invocations, b.sched_invocations);
    }

    #[test]
    fn worker_reset_is_bit_identical_to_fresh_build() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        let cfg_a = quick_cfg("etf", 3.0, 60);
        let cfg_b = quick_cfg("met", 6.0, 80);
        let setup = SimSetup::new(&p, &apps, &cfg_a).unwrap();
        let mut w = SimWorker::build(&setup, &cfg_a).unwrap();
        w.run(&setup);
        let a1 = w.take_report();
        // Reuse with a different config, then come back to the first:
        // history must not leak through the reset.
        w.reset(&setup, &cfg_b).unwrap();
        w.run(&setup);
        let b1 = w.take_report();
        w.reset(&setup, &cfg_a).unwrap();
        w.run(&setup);
        let a2 = w.take_report();
        let fresh_a = Simulation::build(&p, &apps, &cfg_a).unwrap().run();
        let fresh_b = Simulation::build(&p, &apps, &cfg_b).unwrap().run();
        reports_bit_identical(&a1, &fresh_a);
        reports_bit_identical(&a2, &fresh_a);
        reports_bit_identical(&b1, &fresh_b);
    }

    #[test]
    fn worker_reuse_keeps_job_pool_warm() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        let cfg = quick_cfg("etf", 2.0, 100);
        let setup = SimSetup::new(&p, &apps, &cfg).unwrap();
        let mut w = SimWorker::build(&setup, &cfg).unwrap();
        w.run(&setup);
        let first = w.take_report();
        w.reset(&setup, &cfg).unwrap();
        // The pool carried recycled per-job buffers across the reset.
        assert!(
            !w.job_pool.is_empty(),
            "reset dropped the job-buffer pool"
        );
        w.run(&setup);
        let second = w.take_report();
        reports_bit_identical(&first, &second);
    }

    #[test]
    fn event_queue_is_right_sized_and_never_grows() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        let cfg = quick_cfg("etf", 9.0, 300);
        let setup = SimSetup::new(&p, &apps, &cfg).unwrap();
        let mut w = SimWorker::build(&setup, &cfg).unwrap();
        let cap0 = w.events.capacity();
        assert!(cap0 >= 256, "queue under-sized: {cap0}");
        assert!(
            w.jobs.capacity() >= 300,
            "job table under-sized: {}",
            w.jobs.capacity()
        );
        w.run(&setup);
        assert!(
            w.events.peak_len <= cap0,
            "event heap outgrew its pre-sized capacity: peak {} > {}",
            w.events.peak_len,
            cap0
        );
        assert_eq!(
            w.events.capacity(),
            cap0,
            "event heap reallocated mid-run"
        );
    }

    #[test]
    fn worker_rebind_across_setups_matches_fresh() {
        let p1 = Platform::table2_soc();
        let mut p2 = Platform::table2_soc();
        p2.t_ambient = 45.0;
        let apps = wifi1();
        let cfg = quick_cfg("etf", 2.0, 50);
        let s1 = SimSetup::new(&p1, &apps, &cfg).unwrap();
        let s2 = SimSetup::new(&p2, &apps, &cfg).unwrap();
        let mut w = SimWorker::build(&s1, &cfg).unwrap();
        w.run(&s1);
        let _ = w.take_report();
        // Re-target the same worker at a different platform setup (the
        // DSE evaluator's cross-genome reuse).
        w.reset(&s2, &cfg).unwrap();
        w.run(&s2);
        let hot = w.take_report();
        let fresh = Simulation::build(&p2, &apps, &cfg).unwrap().run();
        reports_bit_identical(&hot, &fresh);
        assert!(hot.peak_temp_c > 45.0, "new ambient not in force");
    }

    #[test]
    #[should_panic(expected = "without reset")]
    fn rerunning_without_reset_panics() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        let cfg = quick_cfg("etf", 1.0, 20);
        let setup = SimSetup::new(&p, &apps, &cfg).unwrap();
        let mut w = SimWorker::build(&setup, &cfg).unwrap();
        w.run(&setup);
        w.run(&setup);
    }

    #[test]
    fn jitter_changes_latencies_but_not_stability() {
        let p = Platform::table2_soc();
        let apps = wifi1();
        let mut cfg = quick_cfg("etf", 1.0, 60);
        cfg.exec_jitter_frac = 0.1;
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r.completed_jobs, 60);
        let base_cfg = quick_cfg("etf", 1.0, 60);
        let base = Simulation::build(&p, &apps, &base_cfg).unwrap().run();
        assert_ne!(r.job_latencies_us, base.job_latencies_us);
    }
}
