//! Minimum Execution Time scheduler (Braun et al. 2001).
//!
//! MET assigns each ready task to the PE offering the lowest execution
//! time, "by only considering PEs with best execution times" (paper §3),
//! ignoring queue state and data locality entirely.  Matching the DS3
//! reference implementation (`np.argmin` over the per-resource execution
//! times), ties among equally-fast instances resolve to the **lowest PE
//! id** — so MET keeps piling work onto the first instance of the
//! fastest class.  This naïve view of system state is exactly why MET
//! degrades first and worst in Figure 3.
//!
//! [`MetLb`] (name `met-lb`) is an ablation variant that breaks ties by
//! earliest availability instead; the `ablations` bench quantifies how
//! much of MET's collapse is instance pinning vs class blindness.

use super::{Assignment, ReadyTask, SchedContext, Scheduler};

#[derive(Debug, Default)]
pub struct Met {
    decisions: u64,
}

impl Met {
    pub fn new() -> Met {
        Met { decisions: 0 }
    }
}

/// Tie-break policy shared by [`Met`] / [`MetLb`].
fn met_schedule(
    ready: &[ReadyTask],
    ctx: &dyn SchedContext,
    least_loaded: bool,
    decisions: &mut u64,
) -> Vec<Assignment> {
    let mut out = Vec::with_capacity(ready.len());
    // Virtual availability, used only by the least-loaded variant.
    let mut avail: Vec<f64> = ctx.pes().iter().map(|p| p.avail_us).collect();
    for rt in ready {
        let mut best_exec = f64::INFINITY;
        for pe in ctx.pes() {
            if !pe.available {
                continue; // failed/hotplugged-out (scenario engine)
            }
            if let Some(us) = ctx.exec_us(rt, pe.id) {
                if us < best_exec {
                    best_exec = us;
                }
            }
        }
        if !best_exec.is_finite() {
            continue; // unsupported everywhere; kernel will flag it
        }
        let mut best_pe = usize::MAX;
        if least_loaded {
            let mut best_avail = f64::INFINITY;
            for pe in ctx.pes() {
                if pe.available
                    && ctx.exec_us(rt, pe.id) == Some(best_exec)
                    && avail[pe.id] < best_avail
                {
                    best_avail = avail[pe.id];
                    best_pe = pe.id;
                }
            }
        } else {
            // DS3-faithful: first (lowest-id) PE achieving the minimum.
            for pe in ctx.pes() {
                if pe.available
                    && ctx.exec_us(rt, pe.id) == Some(best_exec)
                {
                    best_pe = pe.id;
                    break;
                }
            }
        }
        debug_assert_ne!(best_pe, usize::MAX);
        avail[best_pe] = avail[best_pe].max(ctx.now_us()) + best_exec;
        out.push(Assignment { job: rt.job, task: rt.task, pe: best_pe });
        *decisions += 1;
    }
    out
}

impl Scheduler for Met {
    fn name(&self) -> &str {
        "met"
    }

    fn schedule(
        &mut self,
        ready: &[ReadyTask],
        ctx: &dyn SchedContext,
    ) -> Vec<Assignment> {
        met_schedule(ready, ctx, false, &mut self.decisions)
    }

    fn report(&self) -> Vec<String> {
        vec![format!("met: {} decisions", self.decisions)]
    }

    fn decision_counts(&self) -> (u64, u64) {
        (self.decisions, 0)
    }
}

/// MET with least-available tie-breaking among equal-best instances
/// (ablation variant `met-lb`).
#[derive(Debug, Default)]
pub struct MetLb {
    decisions: u64,
}

impl MetLb {
    pub fn new() -> MetLb {
        MetLb { decisions: 0 }
    }
}

impl Scheduler for MetLb {
    fn name(&self) -> &str {
        "met-lb"
    }

    fn schedule(
        &mut self,
        ready: &[ReadyTask],
        ctx: &dyn SchedContext,
    ) -> Vec<Assignment> {
        met_schedule(ready, ctx, true, &mut self.decisions)
    }

    fn report(&self) -> Vec<String> {
        vec![format!("met-lb: {} decisions", self.decisions)]
    }

    fn decision_counts(&self) -> (u64, u64) {
        (self.decisions, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::{rt, MockCtx};

    #[test]
    fn picks_fastest_pe_class() {
        let mut ctx = MockCtx::uniform(3, 0.0);
        ctx.set_exec(0, 0, 0, 50.0);
        ctx.set_exec(0, 0, 1, 10.0); // fastest
        ctx.set_exec(0, 0, 2, 30.0);
        let mut met = Met::new();
        let a = met.schedule(&[rt(0, 0)], &ctx);
        assert_eq!(a, vec![Assignment { job: 0, task: 0, pe: 1 }]);
    }

    #[test]
    fn ignores_queue_on_slower_pes() {
        // PE 1 is fastest but heavily queued; MET must still pick it
        // (that is its defining pathology).
        let mut ctx = MockCtx::uniform(2, 0.0);
        ctx.set_exec(0, 0, 0, 12.0);
        ctx.set_exec(0, 0, 1, 10.0);
        ctx.pes[1].avail_us = 10_000.0;
        ctx.pes[1].queue_len = 40;
        let mut met = Met::new();
        let a = met.schedule(&[rt(0, 0)], &ctx);
        assert_eq!(a[0].pe, 1);
    }

    #[test]
    fn pins_to_first_equal_best_instance() {
        // Two identical accelerators: DS3-faithful MET piles everything
        // onto instance 0 (the Figure-3 collapse mechanism).
        let mut ctx = MockCtx::uniform(2, 0.0);
        for t in 0..4 {
            ctx.set_exec(0, t, 0, 16.0);
            ctx.set_exec(0, t, 1, 16.0);
        }
        let mut met = Met::new();
        let tasks: Vec<_> = (0..4).map(|t| rt(0, t)).collect();
        let a = met.schedule(&tasks, &ctx);
        assert!(a.iter().all(|x| x.pe == 0));
    }

    #[test]
    fn met_lb_spreads_across_equal_best_instances() {
        // The ablation variant alternates over equally-fast instances.
        let mut ctx = MockCtx::uniform(2, 0.0);
        for t in 0..4 {
            ctx.set_exec(0, t, 0, 16.0);
            ctx.set_exec(0, t, 1, 16.0);
        }
        let mut met = MetLb::new();
        let tasks: Vec<_> = (0..4).map(|t| rt(0, t)).collect();
        let a = met.schedule(&tasks, &ctx);
        let on0 = a.iter().filter(|x| x.pe == 0).count();
        let on1 = a.iter().filter(|x| x.pe == 1).count();
        assert_eq!((on0, on1), (2, 2));
    }

    #[test]
    fn met_lb_still_ignores_other_classes() {
        // Even met-lb must pick the fastest class when it is saturated.
        let mut ctx = MockCtx::uniform(2, 0.0);
        ctx.set_exec(0, 0, 0, 10.0); // fast, busy
        ctx.set_exec(0, 0, 1, 12.0); // slower, idle
        ctx.pes[0].avail_us = 1e6;
        let mut met = MetLb::new();
        assert_eq!(met.schedule(&[rt(0, 0)], &ctx)[0].pe, 0);
    }

    #[test]
    fn failed_instance_falls_back_to_next_best() {
        // Fastest class on PE 0 is failed: MET must take the next-best
        // available PE instead of pinning to the failed one.
        let mut ctx = MockCtx::uniform(2, 0.0);
        ctx.set_exec(0, 0, 0, 10.0);
        ctx.set_exec(0, 0, 1, 25.0);
        ctx.pes[0].available = false;
        let mut met = Met::new();
        assert_eq!(met.schedule(&[rt(0, 0)], &ctx)[0].pe, 1);
        // All PEs failed: nothing placed.
        ctx.pes[1].available = false;
        assert!(met.schedule(&[rt(0, 0)], &ctx).is_empty());
    }

    #[test]
    fn skips_unsupported_tasks() {
        let ctx = MockCtx::uniform(2, 0.0); // no exec entries at all
        let mut met = Met::new();
        assert!(met.schedule(&[rt(0, 0)], &ctx).is_empty());
    }

    #[test]
    fn assigns_every_supported_task() {
        let mut ctx = MockCtx::uniform(4, 0.0);
        for t in 0..10 {
            ctx.set_exec(0, t, t % 4, 5.0);
        }
        let mut met = Met::new();
        let tasks: Vec<_> = (0..10).map(|t| rt(0, t)).collect();
        assert_eq!(met.schedule(&tasks, &ctx).len(), 10);
    }
}
