//! Integration tests for the telemetry subsystem's determinism
//! contract (README §Observability): the default (non-timing) event
//! stream of a fixed-seed campaign is byte-identical regardless of
//! worker thread count, and the aggregated counters equal the sum of
//! the per-run reports.
//!
//! Every test uses a *local* `Telemetry` handle (a `MemSink` passed
//! through the `_with` entry points) rather than the process-global
//! dispatcher — cargo runs integration tests in parallel and the
//! global is shared process state.

use ds3r::app::suite::{self, WifiParams};
use ds3r::app::AppGraph;
use ds3r::config::SimConfig;
use ds3r::coordinator::{
    run_scenario_sweep_with, run_sweep_with, SweepPoint,
};
use ds3r::dse::{DseConfig, DseEngine};
use ds3r::platform::Platform;
use ds3r::scenario::{Action, Scenario};
use ds3r::telemetry::{MemSink, Telemetry};
use ds3r::util::json::Json;
use std::sync::Arc;

fn apps() -> Vec<AppGraph> {
    vec![suite::wifi_tx(WifiParams { symbols: 2 })]
}

fn base_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.max_jobs = 40;
    cfg.warmup_jobs = 4;
    cfg.max_sim_us = 5_000_000.0;
    cfg
}

fn grid() -> Vec<SweepPoint> {
    let mut pts = Vec::new();
    for sched in ["etf", "met"] {
        for rate in [2.0, 4.0] {
            for seed in 0..2u64 {
                pts.push(SweepPoint {
                    scheduler: sched.into(),
                    rate_per_ms: rate,
                    seed,
                });
            }
        }
    }
    pts
}

/// Run the sweep grid with a fresh MemSink, returning the captured
/// stream and the aggregated counters.
fn sweep_stream(
    threads: usize,
) -> (String, ds3r::telemetry::Counters, Vec<usize>) {
    let platform = Platform::table2_soc();
    let apps = apps();
    let sink = Arc::new(MemSink::new());
    let tel = Telemetry::new(sink.clone());
    let (results, counters) = run_sweep_with(
        &platform,
        &apps,
        &base_cfg(),
        &grid(),
        threads,
        &tel,
    )
    .unwrap();
    let completed: Vec<usize> =
        results.iter().map(|r| r.completed_jobs).collect();
    (sink.dump(), counters, completed)
}

#[test]
fn sweep_telemetry_is_byte_identical_across_1_vs_8_threads() {
    let (s1, c1, done1) = sweep_stream(1);
    let (s8, c8, done8) = sweep_stream(8);
    assert_eq!(done1, done8, "sweep results depend on thread count");
    assert_eq!(
        s1, s8,
        "non-timing telemetry stream depends on thread count"
    );
    assert_eq!(c1, c8, "aggregated counters depend on thread count");
    assert!(!c1.is_empty());
}

#[test]
fn sweep_telemetry_is_repeatable_run_to_run() {
    let (a, ca, _) = sweep_stream(4);
    let (b, cb, _) = sweep_stream(4);
    assert_eq!(a, b, "same-seed reruns emitted different bytes");
    assert_eq!(ca, cb);
}

#[test]
fn sweep_counters_equal_sum_of_per_point_reports() {
    let (_, counters, completed) = sweep_stream(2);
    let n = grid().len() as u64;
    assert_eq!(counters.get("runs"), n);
    assert_eq!(
        counters.get("completed_jobs"),
        completed.iter().map(|&c| c as u64).sum::<u64>(),
        "aggregated completed_jobs disagrees with the result rows"
    );
    // The kernel counters every run contributes at least one of.
    for key in ["injected_jobs", "events_processed", "tasks_executed"] {
        assert!(
            counters.get(key) >= n,
            "counter '{key}' missing contributions: {}",
            counters.get(key)
        );
    }
}

#[test]
fn sweep_telemetry_lines_are_wellformed_jsonl() {
    let (stream, _, _) = sweep_stream(2);
    let mut kinds = std::collections::BTreeSet::new();
    for line in stream.lines() {
        let j = Json::parse(line)
            .unwrap_or_else(|e| panic!("bad JSONL line '{line}': {e}"));
        let kind = j
            .get("event")
            .and_then(Json::as_str)
            .expect("every event carries an 'event' kind")
            .to_string();
        kinds.insert(kind);
    }
    // A plain sweep through a non-timing sink emits no wall-clock
    // progress events and no per-run lifecycle events (those come
    // from the CLI layer) — the stream may legitimately be empty of
    // some kinds, but must never contain nondeterministic ones.
    assert!(
        !kinds.contains("sweep_progress"),
        "non-timing sink leaked a wall-clock event: {kinds:?}"
    );
}

#[test]
fn scenario_sweep_emits_phases_deterministically() {
    let platform = Platform::table2_soc();
    let apps = apps();
    let scenarios = vec![
        Scenario::new("steady", "constant rate")
            .event(500.0, Action::SetRate { per_ms: 3.0 }),
        Scenario::new("burst", "rate step up then down")
            .event(500.0, Action::SetRate { per_ms: 6.0 })
            .event(1500.0, Action::SetRate { per_ms: 2.0 }),
    ];
    let run = |threads: usize| {
        let sink = Arc::new(MemSink::new());
        let tel = Telemetry::new(sink.clone());
        let (results, counters) = run_scenario_sweep_with(
            &platform,
            &apps,
            &base_cfg(),
            &scenarios,
            threads,
            &tel,
        )
        .unwrap();
        let phases: Vec<usize> =
            results.iter().map(|r| r.phases.len()).collect();
        (sink.dump(), counters, phases)
    };
    let (s1, c1, p1) = run(1);
    let (s8, c8, p8) = run(8);
    assert_eq!(p1, p8);
    assert_eq!(s1, s8, "scenario_phase stream depends on thread count");
    assert_eq!(c1, c8);
    // Phase events stream in scenario input order: every scenario's
    // phases appear, grouped, in declaration order.
    let names: Vec<String> = s1
        .lines()
        .filter_map(|l| Json::parse(l).ok())
        .filter(|j| {
            j.get("event").and_then(Json::as_str)
                == Some("scenario_phase")
        })
        .filter_map(|j| {
            j.get("scenario").and_then(Json::as_str).map(String::from)
        })
        .collect();
    assert!(!names.is_empty(), "no scenario_phase events captured");
    let first_burst =
        names.iter().position(|n| n == "burst").unwrap();
    assert!(
        names[..first_burst].iter().all(|n| n == "steady"),
        "phase events not grouped in scenario input order: {names:?}"
    );
}

fn dse_cfg(threads: usize) -> DseConfig {
    let mut cfg = DseConfig::default();
    cfg.population = 6;
    cfg.generations = 3;
    cfg.search_seed = 42;
    cfg.seeds = vec![1];
    cfg.threads = threads;
    cfg.sim.injection_rate_per_ms = 2.0;
    cfg.sim.max_jobs = 30;
    cfg.sim.warmup_jobs = 3;
    cfg.sim.max_sim_us = 2_000_000.0;
    cfg
}

fn dse_stream(threads: usize) -> String {
    let sink = Arc::new(MemSink::new());
    let mut engine =
        DseEngine::new(Platform::table2_soc(), dse_cfg(threads))
            .unwrap();
    engine.set_telemetry(Telemetry::new(sink.clone()));
    engine.run(&apps(), None, |_| {}).unwrap();
    sink.dump()
}

#[test]
fn dse_generation_stream_is_byte_identical_across_thread_counts() {
    let s1 = dse_stream(1);
    let s8 = dse_stream(8);
    assert_eq!(
        s1, s8,
        "dse_generation stream depends on evaluation thread count"
    );
    let gens = s1
        .lines()
        .filter(|l| l.contains("\"dse_generation\""))
        .count();
    // Generation 0 (the seeded population) plus 3 evolutionary rounds.
    assert_eq!(gens, 4, "one dse_generation event per generation");
    for line in s1.lines() {
        Json::parse(line)
            .unwrap_or_else(|e| panic!("bad JSONL line '{line}': {e}"));
    }
}
