//! Scenario-engine integration tests: time-scripted runtime events
//! executing end-to-end through the simulation kernel, with per-phase
//! statistics that expose each timeline step's effect.

use ds3r::app::suite::{self, WifiParams};
use ds3r::config::SimConfig;
use ds3r::platform::Platform;
use ds3r::scenario::{presets, Action, Scenario};
use ds3r::sim::Simulation;

fn cfg(rate: f64, jobs: usize) -> SimConfig {
    let mut c = SimConfig::default();
    c.scheduler = "etf".into();
    c.injection_rate_per_ms = rate;
    c.max_jobs = jobs;
    c.warmup_jobs = jobs / 10;
    c
}

/// The acceptance-criterion run: the `pe-failure` preset executes
/// end-to-end and the report's per-phase stats differ across phases.
#[test]
fn pe_failure_preset_end_to_end_with_distinct_phases() {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let mut c = cfg(2.0, 500); // arrivals span ~250 ms
    c.scenario = Some(presets::pe_failure());
    let r = Simulation::build(&p, &apps, &c).unwrap().run();

    // Nothing is lost to the fault.
    assert_eq!(r.completed_jobs, 500);
    assert_eq!(r.scenario, "pe-failure");

    // Three phases: baseline, FFT outage (50-150 ms), after hotplug.
    assert_eq!(r.phases.len(), 3, "{:?}", r.phases);
    let (base, outage, restored) =
        (&r.phases[0], &r.phases[1], &r.phases[2]);
    assert!(base.label.contains("baseline"));
    assert!(outage.label.contains("pe10-fail"));
    assert!(restored.label.contains("pe10-restore"));
    for ph in &r.phases {
        assert!(ph.jobs_completed > 0, "empty phase {:?}", ph);
        assert!(ph.end_us > ph.start_us);
        assert!(ph.energy_j > 0.0);
    }

    // The outage visibly hurts: IFFTs fall back from the 16 µs FFT
    // engines to 118 µs A15 cores, so per-phase latency jumps, then
    // recovers after the hotplug.
    assert!(
        outage.avg_latency_us > 1.5 * base.avg_latency_us,
        "outage {} vs baseline {}",
        outage.avg_latency_us,
        base.avg_latency_us
    );
    assert!(
        restored.avg_latency_us < outage.avg_latency_us,
        "restored {} vs outage {}",
        restored.avg_latency_us,
        outage.avg_latency_us
    );
}

#[test]
fn scenario_run_is_deterministic_and_seed_sensitive() {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams { symbols: 6 })];
    let mut c = cfg(2.0, 200);
    c.scenario = Some(presets::bursty_wifi());
    let a = Simulation::build(&p, &apps, &c).unwrap().run();
    let b = Simulation::build(&p, &apps, &c).unwrap().run();
    assert_eq!(a.job_latencies_us, b.job_latencies_us);
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.scenario_events, b.scenario_events);
    c.seed = 777;
    let d = Simulation::build(&p, &apps, &c).unwrap().run();
    assert_ne!(a.job_latencies_us, d.job_latencies_us);
}

#[test]
fn bursty_wifi_ramp_raises_mid_run_pressure() {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let mut c = cfg(1.0, 600);
    c.scenario = Some(presets::bursty_wifi());
    let r = Simulation::build(&p, &apps, &c).unwrap().run();
    assert_eq!(r.completed_jobs, 600);
    // Ramp sub-steps execute on top of the listed events.
    assert!(r.scenario_events > 4, "{}", r.scenario_events);
    // The burst phase (opened by the first ramp) completes jobs at a
    // much higher rate than the quiet baseline.  The t=0 set-rate event
    // takes over the baseline phase, so match phases by label (the
    // trailing events may fall past the end of the 600-job run).
    assert!(r.phases.len() >= 2, "{:?}", r.phases);
    let per_ms = |ph: &ds3r::stats::PhaseStats| {
        ph.jobs_completed as f64 / (ph.duration_us() / 1000.0)
    };
    let quiet = r
        .phases
        .iter()
        .find(|ph| ph.label.contains("rate=1"))
        .expect("quiet phase");
    let burst = r
        .phases
        .iter()
        .find(|ph| ph.label.contains("ramp->8"))
        .expect("burst phase");
    assert!(
        per_ms(burst) > 2.0 * per_ms(quiet),
        "burst {} vs quiet {} jobs/ms",
        per_ms(burst),
        per_ms(quiet)
    );
}

#[test]
fn budget_throttle_scenario_engages_power_cap() {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let mut c = cfg(8.0, 1500); // hot enough to exceed 3.5 W
    c.scenario = Some(presets::budget_throttle());
    let r = Simulation::build(&p, &apps, &c).unwrap().run();
    assert_eq!(r.completed_jobs, 1500);
    assert!(r.phases.len() >= 3, "{:?}", r.phases);
    // The tightened-budget phase draws less average power than the
    // uncapped baseline phase.
    let base = &r.phases[0];
    let tight = r
        .phases
        .iter()
        .find(|ph| ph.label.contains("cap=3.5"))
        .expect("tight-budget phase present");
    assert!(
        tight.avg_power_w < base.avg_power_w,
        "capped {} W vs baseline {} W",
        tight.avg_power_w,
        base.avg_power_w
    );
}

#[test]
fn scheduler_shootout_swaps_policies_in_one_run() {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let mut c = cfg(2.0, 800); // arrivals span ~400 ms: all swaps fire
    c.scenario = Some(presets::scheduler_shootout());
    let r = Simulation::build(&p, &apps, &c).unwrap().run();
    assert_eq!(r.completed_jobs, 800);
    assert_eq!(r.phases.len(), 4);
    for needle in ["heft", "met-lb", "etf"] {
        assert!(
            r.scheduler.contains(needle),
            "'{}' missing swap to {needle}",
            r.scheduler
        );
    }
}

#[test]
fn thermal_soak_scenario_tracks_ambient() {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let mut c = cfg(2.0, 800);
    c.scenario = Some(presets::thermal_soak());
    c.capture_traces = true;
    let r = Simulation::build(&p, &apps, &c).unwrap().run();
    assert_eq!(r.completed_jobs, 800);
    // Phase peak temperatures follow the 25 -> 45 -> 60 -> 25 staircase.
    assert_eq!(r.phases.len(), 4);
    assert!(r.phases[1].peak_temp_c > r.phases[0].peak_temp_c + 10.0);
    assert!(r.phases[2].peak_temp_c > r.phases[1].peak_temp_c + 5.0);
    assert!(r.phases[3].peak_temp_c < r.phases[2].peak_temp_c);
    assert!(r.peak_temp_c >= 60.0, "peak {}", r.peak_temp_c);
}

#[test]
fn scenario_json_file_drives_a_run() {
    // The full file path: write a scenario JSON, load it through the
    // config layer, run it.
    let dir = std::env::temp_dir().join("ds3r-scenario-integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("burst.json");
    // 200 jobs: ~20 arrive before the burst, ~120 during it, and the
    // rest after the rate drops back — every event fires mid-stream.
    let sc = Scenario::new("file-burst", "from disk")
        .event(20_000.0, Action::SetRate { per_ms: 6.0 })
        .event(40_000.0, Action::SetRate { per_ms: 1.0 });
    sc.save(&path).unwrap();

    let j = ds3r::util::json::Json::parse(&format!(
        r#"{{"max_jobs": 200, "warmup_jobs": 10,
            "scenario": "{}"}}"#,
        path.display()
    ))
    .unwrap();
    let c = SimConfig::from_json(&j).unwrap();
    assert_eq!(c.scenario.as_ref().unwrap().name, "file-burst");

    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams { symbols: 4 })];
    let r = Simulation::build(&p, &apps, &c).unwrap().run();
    assert_eq!(r.completed_jobs, 200);
    assert_eq!(r.scenario, "file-burst");
    assert_eq!(r.phases.len(), 3);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn static_runs_are_untouched_by_the_scenario_engine() {
    // No scenario => no phases, no scenario events, and identical
    // results to the seed behaviour (guard against accidental coupling).
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams { symbols: 4 })];
    let r = Simulation::build(&p, &apps, &cfg(2.0, 100)).unwrap().run();
    assert_eq!(r.completed_jobs, 100);
    assert!(r.phases.is_empty());
    assert_eq!(r.scenario_events, 0);
    assert!(r.scenario.is_empty());
}
