//! Probe-subsystem integration tests: the determinism contract of the
//! trace artifact (README §Observability) checked end-to-end through
//! the real simulation kernel.
//!
//! * a fixed-seed probed sweep serializes to a **byte-identical**
//!   artifact for any `--threads` value and across reruns,
//! * lazy and eager power/thermal integration record identical
//!   samples (the probe rides `account_epoch`, the one accounting
//!   point both lanes share),
//! * stride-doubling downsampling never exceeds the budget, keeps
//!   timestamps strictly increasing, preserves both endpoints, and
//!   selects a subset of the raw samples,
//! * attaching a probe does not perturb the run it observes.

use ds3r::app::suite::{self, WifiParams};
use ds3r::config::SimConfig;
use ds3r::coordinator::run_scenario_sweep_probed;
use ds3r::platform::Platform;
use ds3r::probe::{traces_to_json, ProbeConfig, TraceSeries};
use ds3r::scenario::presets;
use ds3r::sim::Simulation;
use ds3r::telemetry::Telemetry;

fn cfg(jobs: usize) -> SimConfig {
    let mut c = SimConfig::default();
    c.scheduler = "etf".into();
    c.injection_rate_per_ms = 2.0;
    c.max_jobs = jobs;
    c.warmup_jobs = 0;
    c
}

fn probed_soak(budget: usize, eager: bool) -> TraceSeries {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let mut c = cfg(400);
    c.eager_integration = eager;
    c.scenario = Some(presets::thermal_soak());
    let mut sim = Simulation::build(&p, &apps, &c).unwrap();
    sim.attach_probe(ProbeConfig::with_budget(budget));
    let (r, trace) = sim.run_with_trace();
    assert_eq!(r.completed_jobs, 400);
    trace.expect("probe was attached")
}

#[test]
fn probed_sweep_is_byte_identical_across_threads_and_reruns() {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let base = cfg(250);
    let scenarios = vec![presets::thermal_soak(), presets::pe_failure()];
    let tel = Telemetry::disabled();
    let pc = ProbeConfig::default();
    let artifact = |threads: usize| {
        let (_, _, traces) = run_scenario_sweep_probed(
            &p, &apps, &base, &scenarios, threads, &tel, &pc,
        )
        .unwrap();
        assert_eq!(traces.len(), scenarios.len());
        traces_to_json(&traces).to_string()
    };
    let one = artifact(1);
    assert_eq!(one, artifact(8), "1-thread vs 8-thread artifact");
    assert_eq!(one, artifact(1), "rerun artifact");
}

#[test]
fn lazy_and_eager_integration_record_identical_traces() {
    let lazy = probed_soak(256, false);
    let eager = probed_soak(256, true);
    assert_eq!(
        lazy.to_json().to_string(),
        eager.to_json().to_string(),
        "lazy and eager lanes must sample identically"
    );
}

#[test]
fn downsampling_respects_budget_monotonicity_and_endpoints() {
    // A budget large enough to keep every raw sample (stride 1) gives
    // the ground truth the downsampled run must be a subset of.
    let full = probed_soak(1 << 20, false);
    let small = probed_soak(16, false);
    assert_eq!(full.channels.len(), small.channels.len());
    assert_eq!(
        full.channels.len(),
        3 * full.n_pes + full.n_nodes + 3,
        "per-PE util/mhz/avail + per-node temp + power/depth/invocations"
    );
    for (f, s) in full.channels.iter().zip(&small.channels) {
        assert_eq!(f.name, s.name);
        assert_eq!(f.stride, 1, "{}: ground truth downsampled", f.name);
        assert_eq!(f.raw_count, s.raw_count, "{}", s.name);
        assert!(s.v.len() <= 16, "{}: budget exceeded", s.name);
        assert!(
            s.stride.is_power_of_two(),
            "{}: stride {} not a power of two",
            s.name,
            s.stride
        );
        assert!(
            s.t_us.windows(2).all(|w| w[0] < w[1]),
            "{}: timestamps not strictly increasing",
            s.name
        );
        // Both endpoints survive downsampling.
        assert_eq!(f.t_us.first(), s.t_us.first(), "{}", s.name);
        assert_eq!(f.t_us.last(), s.t_us.last(), "{}", s.name);
        // Every kept sample is one of the raw samples, bit-exact.
        for (t, v) in s.t_us.iter().zip(&s.v) {
            assert!(
                f.t_us
                    .iter()
                    .zip(&f.v)
                    .any(|(ft, fv)| ft == t && fv == v),
                "{}: kept sample ({t}, {v}) not in the raw series",
                s.name
            );
        }
    }
    // The thermal-soak timeline steps ambient three times -> phase
    // markers, identical at both budgets (markers are never dropped).
    assert!(!full.markers.is_empty());
    assert_eq!(full.markers, small.markers);
    assert!(full
        .markers
        .windows(2)
        .all(|w| w[0].t_us <= w[1].t_us));
}

#[test]
fn attaching_a_probe_does_not_perturb_the_run() {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let mut c = cfg(300);
    c.scenario = Some(presets::thermal_soak());
    let bare = Simulation::build(&p, &apps, &c).unwrap().run();
    let mut sim = Simulation::build(&p, &apps, &c).unwrap();
    sim.attach_probe(ProbeConfig::default());
    let (probed, trace) = sim.run_with_trace();
    assert_eq!(bare.job_latencies_us, probed.job_latencies_us);
    assert_eq!(bare.events_processed, probed.events_processed);
    assert_eq!(bare.total_energy_j, probed.total_energy_j);
    let trace = trace.unwrap();
    assert_eq!(trace.scheduler, "etf");
    assert_eq!(trace.scenario, "thermal-soak");
    // Artifact JSON roundtrips losslessly.
    let j = trace.to_json();
    let back = TraceSeries::from_json(
        &ds3r::util::json::Json::parse(&j.to_string()).unwrap(),
    )
    .unwrap();
    assert_eq!(j.to_string(), back.to_json().to_string());
}
