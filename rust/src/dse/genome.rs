//! Platform genome: a compact, serializable encoding of the mutable
//! hardware parameters of a DSSoC, with validated decode back into a
//! [`Platform`] and the variation operators (mutation, crossover) the
//! evolutionary search applies.
//!
//! A genome is always interpreted relative to a *base platform* (held by
//! [`GenomeSpace`]), which contributes everything the search does not
//! touch: PE classes with their latency/power coefficients, the thermal
//! floorplan, cluster→thermal-node wiring, and the memory latency.  The
//! genes are:
//!
//! * `pe_counts[c]`   — PE instances in cluster `c` (the Table-2
//!   provisioning question: how many FFT engines? how many big cores?)
//! * `opp_masks[c]`   — bitmask of enabled OPPs for cluster `c`'s class
//!   (bit *i* = i-th entry of the class ladder; DVFS-domain pruning à la
//!   Montanaro et al., arXiv:2411.15574)
//! * `hop_latency_us` / `link_bandwidth` — NoC fabric speed grade
//! * `power_budget_w` — optional DTPM SoC power cap applied at runtime
//!
//! Decoding re-derives the mesh (row-major placement on a near-square
//! grid) and re-instantiates per-cluster PEs; everything else is carried
//! over from the base platform unchanged.

use std::path::Path;

use crate::platform::{Cluster, NocParams, Pe, PeClass, Platform};
use crate::rng::Rng;
use crate::util::json::Json;
use crate::{Error, Result};

/// A candidate hardware configuration in genome form.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformGenome {
    /// PE instances per base cluster (same order as the base platform's
    /// cluster list).
    pub pe_counts: Vec<usize>,
    /// Enabled-OPP bitmask per cluster; bit `i` enables the i-th OPP of
    /// the cluster's class ladder.  At least one bit per cluster.
    pub opp_masks: Vec<u64>,
    /// Per-hop router+link latency (µs).
    pub hop_latency_us: f64,
    /// Link bandwidth (bytes/µs).
    pub link_bandwidth: f64,
    /// DTPM SoC power budget (W); `None` = uncapped.
    pub power_budget_w: Option<f64>,
}

impl PlatformGenome {
    /// Stable 64-bit identity (FNV-1a over the canonical encoding).
    /// Used for design ids and checkpoint bookkeeping; the evaluation
    /// cache keys on the full canonical encoding ([`Self::key`]) so hash
    /// collisions can never alias two designs.
    pub fn hash64(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf29ce484222325;
        const FNV_PRIME: u64 = 0x100000001b3;
        let mut h = FNV_OFFSET;
        let mut eat = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        };
        for &c in &self.pe_counts {
            eat(c as u64);
        }
        for &m in &self.opp_masks {
            eat(m);
        }
        eat(self.hop_latency_us.to_bits());
        eat(self.link_bandwidth.to_bits());
        match self.power_budget_w {
            None => eat(0),
            Some(w) => {
                eat(1);
                eat(w.to_bits());
            }
        }
        h
    }

    /// Canonical compact encoding — the evaluation-cache key.
    pub fn key(&self) -> String {
        self.to_json().to_string()
    }

    /// Short printable design id, e.g. `g3f2a90c1`.
    pub fn id(&self) -> String {
        format!("g{:08x}", self.hash64() as u32)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set(
            "pe_counts",
            Json::Arr(
                self.pe_counts.iter().map(|&c| Json::Num(c as f64)).collect(),
            ),
        )
        .set(
            "opp_masks",
            Json::Arr(
                self.opp_masks.iter().map(|&m| Json::Num(m as f64)).collect(),
            ),
        )
        .set("hop_latency_us", Json::Num(self.hop_latency_us))
        .set("link_bandwidth", Json::Num(self.link_bandwidth));
        if let Some(w) = self.power_budget_w {
            j.set("power_budget_w", Json::Num(w));
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<PlatformGenome> {
        let pe_counts = j
            .req_arr("pe_counts")?
            .iter()
            .map(|v| {
                v.as_usize().ok_or_else(|| {
                    Error::Config("genome pe_counts: bad count".into())
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let opp_masks = j
            .req_arr("opp_masks")?
            .iter()
            .map(|v| {
                v.as_f64()
                    .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                    .map(|x| x as u64)
                    .ok_or_else(|| {
                        Error::Config("genome opp_masks: bad mask".into())
                    })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(PlatformGenome {
            pe_counts,
            opp_masks,
            hop_latency_us: j.req_f64("hop_latency_us")?,
            link_bandwidth: j.req_f64("link_bandwidth")?,
            power_budget_w: j.get("power_budget_w").and_then(Json::as_f64),
        })
    }
}

/// Bounds of the searchable space plus the base platform genomes are
/// decoded against.  Construction validates that the base platform is
/// *genome-compatible*: every cluster must own a distinct PE class
/// (true of both presets), because decode specializes each cluster's
/// OPP ladder independently while class names must stay unique.
#[derive(Debug, Clone)]
pub struct GenomeSpace {
    base: Platform,
    /// Per-cluster instance-count bounds (inclusive).
    pub min_pes: usize,
    pub max_pes: usize,
    /// NoC gene bounds (inclusive).
    pub hop_latency_range: (f64, f64),
    pub link_bandwidth_range: (f64, f64),
    /// Power-budget gene bounds; `explore_power_budget = false` pins the
    /// gene to `None` (uncapped).
    pub power_budget_range: (f64, f64),
    pub explore_power_budget: bool,
}

impl GenomeSpace {
    pub fn new(
        base: Platform,
        min_pes: usize,
        max_pes: usize,
        hop_latency_range: (f64, f64),
        link_bandwidth_range: (f64, f64),
        power_budget_range: (f64, f64),
        explore_power_budget: bool,
    ) -> Result<GenomeSpace> {
        if min_pes == 0 || max_pes < min_pes {
            return Err(Error::Config(format!(
                "bad PE-count bounds [{min_pes}, {max_pes}]"
            )));
        }
        for (lo, hi, name) in [
            (hop_latency_range.0, hop_latency_range.1, "hop_latency"),
            (
                link_bandwidth_range.0,
                link_bandwidth_range.1,
                "link_bandwidth",
            ),
            (power_budget_range.0, power_budget_range.1, "power_budget"),
        ] {
            if !(lo > 0.0 && hi >= lo) {
                return Err(Error::Config(format!(
                    "bad {name} range [{lo}, {hi}]"
                )));
            }
        }
        let mut seen = vec![false; base.classes.len()];
        for cl in &base.clusters {
            if seen[cl.class] {
                return Err(Error::Config(format!(
                    "base platform '{}' is not genome-compatible: class \
                     '{}' is shared by two clusters",
                    base.name, base.classes[cl.class].name
                )));
            }
            seen[cl.class] = true;
        }
        if base.clusters.is_empty() {
            return Err(Error::Config(
                "base platform has no clusters".into(),
            ));
        }
        Ok(GenomeSpace {
            base,
            min_pes,
            max_pes,
            hop_latency_range,
            link_bandwidth_range,
            power_budget_range,
            explore_power_budget,
        })
    }

    pub fn base(&self) -> &Platform {
        &self.base
    }

    pub fn n_clusters(&self) -> usize {
        self.base.clusters.len()
    }

    fn class_of_cluster(&self, c: usize) -> &PeClass {
        &self.base.classes[self.base.clusters[c].class]
    }

    /// Full-ladder mask for cluster `c`'s class.
    fn full_mask(&self, c: usize) -> u64 {
        let n = self.class_of_cluster(c).opps.len().min(63);
        (1u64 << n) - 1
    }

    /// The genome that reproduces the base platform (modulo mesh
    /// re-placement): base PE counts, full OPP ladders, base NoC genes,
    /// no power cap.
    pub fn seed_genome(&self) -> PlatformGenome {
        PlatformGenome {
            pe_counts: self
                .base
                .clusters
                .iter()
                .map(|cl| cl.pe_ids.len().clamp(self.min_pes, self.max_pes))
                .collect(),
            opp_masks: (0..self.n_clusters())
                .map(|c| self.full_mask(c))
                .collect(),
            hop_latency_us: self.base.noc.hop_latency_us.clamp(
                self.hop_latency_range.0,
                self.hop_latency_range.1,
            ),
            link_bandwidth: self.base.noc.link_bandwidth.clamp(
                self.link_bandwidth_range.0,
                self.link_bandwidth_range.1,
            ),
            power_budget_w: None,
        }
    }

    /// Sample a uniform-random genome.
    pub fn random(&self, rng: &mut Rng) -> PlatformGenome {
        let pe_counts = (0..self.n_clusters())
            .map(|_| {
                self.min_pes
                    + rng.below((self.max_pes - self.min_pes + 1) as u64)
                        as usize
            })
            .collect();
        let opp_masks = (0..self.n_clusters())
            .map(|c| self.random_mask(c, rng))
            .collect();
        let power_budget_w = if self.explore_power_budget && rng.f64() < 0.5
        {
            Some(rng.uniform(
                self.power_budget_range.0,
                self.power_budget_range.1,
            ))
        } else {
            None
        };
        PlatformGenome {
            pe_counts,
            opp_masks,
            hop_latency_us: rng.uniform(
                self.hop_latency_range.0,
                self.hop_latency_range.1,
            ),
            link_bandwidth: rng.uniform(
                self.link_bandwidth_range.0,
                self.link_bandwidth_range.1,
            ),
            power_budget_w,
        }
    }

    /// Random non-empty OPP subset that always keeps the top OPP (so
    /// `nominal_mhz`-relative scaling stays bounded and the performance
    /// governor has a ceiling to grant).
    fn random_mask(&self, c: usize, rng: &mut Rng) -> u64 {
        let n = self.class_of_cluster(c).opps.len().min(63);
        let top = 1u64 << (n - 1);
        if n == 1 {
            return top;
        }
        (rng.next_u64() & self.full_mask(c)) | top
    }

    /// Mutate: each gene flips with probability `rate`.  A gene flip
    /// that turns out to be a no-op (a single-OPP accelerator ladder, a
    /// continuous gene pinned at its bound) does not count, and at
    /// least one gene is always genuinely perturbed — offspring never
    /// silently equal their parent (as long as the space has more than
    /// one PE-count value, i.e. `min_pes < max_pes`).
    pub fn mutate(
        &self,
        g: &PlatformGenome,
        rate: f64,
        rng: &mut Rng,
    ) -> PlatformGenome {
        let mut out = g.clone();
        let mut touched = false;
        for c in 0..self.n_clusters() {
            if rng.f64() < rate {
                let next = self.step_count(out.pe_counts[c], rng);
                touched |= next != out.pe_counts[c];
                out.pe_counts[c] = next;
            }
            if rng.f64() < rate {
                let next = self.toggle_opp(c, out.opp_masks[c], rng);
                touched |= next != out.opp_masks[c];
                out.opp_masks[c] = next;
            }
        }
        if rng.f64() < rate {
            let next = scale_clamped(
                out.hop_latency_us,
                self.hop_latency_range,
                rng,
            );
            touched |= next != out.hop_latency_us;
            out.hop_latency_us = next;
        }
        if rng.f64() < rate {
            let next = scale_clamped(
                out.link_bandwidth,
                self.link_bandwidth_range,
                rng,
            );
            touched |= next != out.link_bandwidth;
            out.link_bandwidth = next;
        }
        if self.explore_power_budget && rng.f64() < rate {
            let next = match out.power_budget_w {
                None => Some(rng.uniform(
                    self.power_budget_range.0,
                    self.power_budget_range.1,
                )),
                Some(w) => {
                    if rng.f64() < 0.25 {
                        None
                    } else {
                        Some(scale_clamped(
                            w,
                            self.power_budget_range,
                            rng,
                        ))
                    }
                }
            };
            touched |= next != out.power_budget_w;
            out.power_budget_w = next;
        }
        if !touched {
            // Force one PE-count step: the cheapest always-legal move.
            let c = rng.below(self.n_clusters() as u64) as usize;
            out.pe_counts[c] = self.step_count(out.pe_counts[c], rng);
        }
        out
    }

    fn step_count(&self, cur: usize, rng: &mut Rng) -> usize {
        let up = rng.f64() < 0.5;
        let next = if up { cur + 1 } else { cur.saturating_sub(1) };
        let next = next.clamp(self.min_pes, self.max_pes);
        if next == cur {
            // At a bound: step the other way (bounds span >= 1 value).
            if up {
                cur.saturating_sub(1).clamp(self.min_pes, self.max_pes)
            } else {
                (cur + 1).clamp(self.min_pes, self.max_pes)
            }
        } else {
            next
        }
    }

    /// Toggle one non-top OPP bit; the top OPP stays enabled.
    fn toggle_opp(&self, c: usize, mask: u64, rng: &mut Rng) -> u64 {
        let n = self.class_of_cluster(c).opps.len().min(63);
        if n <= 1 {
            return mask;
        }
        let bit = 1u64 << rng.below((n - 1) as u64);
        let top = 1u64 << (n - 1);
        (mask ^ bit) | top
    }

    /// Uniform crossover: each gene comes from either parent with equal
    /// probability.
    pub fn crossover(
        &self,
        a: &PlatformGenome,
        b: &PlatformGenome,
        rng: &mut Rng,
    ) -> PlatformGenome {
        let pick = |rng: &mut Rng| rng.f64() < 0.5;
        PlatformGenome {
            pe_counts: (0..self.n_clusters())
                .map(|c| {
                    if pick(rng) {
                        a.pe_counts[c]
                    } else {
                        b.pe_counts[c]
                    }
                })
                .collect(),
            opp_masks: (0..self.n_clusters())
                .map(|c| {
                    if pick(rng) {
                        a.opp_masks[c]
                    } else {
                        b.opp_masks[c]
                    }
                })
                .collect(),
            hop_latency_us: if pick(rng) {
                a.hop_latency_us
            } else {
                b.hop_latency_us
            },
            link_bandwidth: if pick(rng) {
                a.link_bandwidth
            } else {
                b.link_bandwidth
            },
            power_budget_w: if pick(rng) {
                a.power_budget_w
            } else {
                b.power_budget_w
            },
        }
    }

    /// Validate a genome against this space (shape and bounds).  Decode
    /// calls this, so a corrupt checkpoint fails loudly, not silently.
    pub fn validate(&self, g: &PlatformGenome) -> Result<()> {
        let n = self.n_clusters();
        if g.pe_counts.len() != n || g.opp_masks.len() != n {
            return Err(Error::Config(format!(
                "genome shape mismatch: {} counts / {} masks for {} \
                 clusters",
                g.pe_counts.len(),
                g.opp_masks.len(),
                n
            )));
        }
        for (c, &cnt) in g.pe_counts.iter().enumerate() {
            if !(self.min_pes..=self.max_pes).contains(&cnt) {
                return Err(Error::Config(format!(
                    "cluster {c}: PE count {cnt} outside [{}, {}]",
                    self.min_pes, self.max_pes
                )));
            }
        }
        for (c, &mask) in g.opp_masks.iter().enumerate() {
            let full = self.full_mask(c);
            if mask & full == 0 {
                return Err(Error::Config(format!(
                    "cluster {c}: empty OPP subset"
                )));
            }
            if mask & !full != 0 {
                return Err(Error::Config(format!(
                    "cluster {c}: OPP mask {mask:#x} has bits beyond the \
                     {}-entry ladder",
                    self.class_of_cluster(c).opps.len()
                )));
            }
        }
        let in_range = |x: f64, (lo, hi): (f64, f64)| x >= lo && x <= hi;
        if !in_range(g.hop_latency_us, self.hop_latency_range) {
            return Err(Error::Config(format!(
                "genome hop latency {} outside [{}, {}]",
                g.hop_latency_us,
                self.hop_latency_range.0,
                self.hop_latency_range.1
            )));
        }
        if !in_range(g.link_bandwidth, self.link_bandwidth_range) {
            return Err(Error::Config(format!(
                "genome link bandwidth {} outside [{}, {}]",
                g.link_bandwidth,
                self.link_bandwidth_range.0,
                self.link_bandwidth_range.1
            )));
        }
        if let Some(w) = g.power_budget_w {
            if !self.explore_power_budget {
                return Err(Error::Config(
                    "genome carries a power budget but the space does \
                     not explore one"
                        .into(),
                ));
            }
            if !in_range(w, self.power_budget_range) {
                return Err(Error::Config(format!(
                    "genome power budget {w} W outside [{}, {}]",
                    self.power_budget_range.0, self.power_budget_range.1
                )));
            }
        }
        Ok(())
    }

    /// Decode a genome into a runnable platform plus the DTPM power-cap
    /// override the evaluation layer applies to its `SimConfig`.
    ///
    /// PEs are re-placed row-major on a near-square mesh sized to the
    /// total instance count; per-cluster classes are cloned from the
    /// base with their OPP ladder filtered by the genome's mask.
    pub fn decode(
        &self,
        g: &PlatformGenome,
    ) -> Result<(Platform, Option<f64>)> {
        self.validate(g)?;
        let total: usize = g.pe_counts.iter().sum();
        let mesh_x = ((total as f64).sqrt().ceil() as usize).max(1);
        let mesh_y = total.div_ceil(mesh_x).max(1);

        let mut classes: Vec<PeClass> = Vec::with_capacity(self.n_clusters());
        let mut pes: Vec<Pe> = Vec::with_capacity(total);
        let mut clusters: Vec<Cluster> = Vec::with_capacity(self.n_clusters());
        for (c, base_cl) in self.base.clusters.iter().enumerate() {
            let base_class = &self.base.classes[base_cl.class];
            let opps = base_class
                .opps
                .iter()
                .enumerate()
                .filter(|(i, _)| g.opp_masks[c] >> i & 1 == 1)
                .map(|(_, o)| *o)
                .collect::<Vec<_>>();
            classes.push(PeClass { opps, ..base_class.clone() });
            let mut pe_ids = Vec::with_capacity(g.pe_counts[c]);
            for i in 0..g.pe_counts[c] {
                let id = pes.len();
                pes.push(Pe {
                    id,
                    class: c,
                    cluster: c,
                    name: format!("{}-{i}", base_cl.name),
                    x: id % mesh_x,
                    y: id / mesh_x,
                });
                pe_ids.push(id);
            }
            clusters.push(Cluster {
                id: c,
                name: base_cl.name.clone(),
                class: c,
                pe_ids,
                thermal_node: base_cl.thermal_node,
            });
        }
        let noc = NocParams {
            mesh_x,
            mesh_y,
            hop_latency_us: g.hop_latency_us,
            link_bandwidth: g.link_bandwidth,
            mem_latency_us: self.base.noc.mem_latency_us,
        };
        let floorplan = self.base.floorplan.clone();
        let mut platform = Platform::new(
            format!("dse-{}", g.id()),
            classes,
            pes,
            clusters,
            noc,
            floorplan,
        )?;
        platform.t_ambient = self.base.t_ambient;
        Ok((platform, g.power_budget_w))
    }

    /// Convenience: decode and write the platform JSON (`dse export`).
    pub fn export_platform(
        &self,
        g: &PlatformGenome,
        path: &Path,
    ) -> Result<()> {
        let (platform, _) = self.decode(g)?;
        std::fs::write(path, platform.to_json().to_string_pretty())?;
        Ok(())
    }
}

/// Multiply by a uniform factor in [0.75, 1.3), clamped to the range —
/// the continuous-gene mutation kernel.
fn scale_clamped(x: f64, range: (f64, f64), rng: &mut Rng) -> f64 {
    (x * rng.uniform(0.75, 1.3)).clamp(range.0, range.1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> GenomeSpace {
        GenomeSpace::new(
            Platform::table2_soc(),
            1,
            8,
            (0.02, 0.2),
            (2000.0, 16000.0),
            (3.0, 10.0),
            true,
        )
        .unwrap()
    }

    #[test]
    fn seed_genome_decodes_to_base_inventory() {
        let s = space();
        let g = s.seed_genome();
        let (p, cap) = s.decode(&g).unwrap();
        assert_eq!(cap, None);
        assert_eq!(p.n_pes(), s.base().n_pes());
        // Same per-class instance counts as the base platform.
        let inv = |p: &Platform| {
            let mut v: Vec<(String, usize)> = p
                .inventory()
                .into_iter()
                .map(|(n, _, c)| (n, c))
                .collect();
            v.sort();
            v
        };
        assert_eq!(inv(&p), inv(s.base()));
        // Full OPP ladders survive.
        for (c, cl) in p.clusters.iter().enumerate() {
            assert_eq!(
                p.classes[cl.class].opps.len(),
                s.base().classes[s.base().clusters[c].class].opps.len()
            );
        }
    }

    #[test]
    fn decode_respects_counts_masks_and_noc_genes() {
        let s = space();
        let mut g = s.seed_genome();
        g.pe_counts = vec![2, 1, 3, 6];
        // Keep only the top OPP of cluster 0 (A15), top two of cluster 1.
        let n0 = s.base().classes[0].opps.len();
        let n1 = s.base().classes[1].opps.len();
        g.opp_masks[0] = 1 << (n0 - 1);
        g.opp_masks[1] = (1 << (n1 - 1)) | (1 << (n1 - 2));
        g.hop_latency_us = 0.1;
        g.link_bandwidth = 4000.0;
        g.power_budget_w = Some(5.0);
        let (p, cap) = s.decode(&g).unwrap();
        assert_eq!(cap, Some(5.0));
        assert_eq!(p.n_pes(), 12);
        assert_eq!(p.clusters[0].pe_ids.len(), 2);
        assert_eq!(p.clusters[3].pe_ids.len(), 6);
        assert_eq!(p.classes[p.clusters[0].class].opps.len(), 1);
        assert_eq!(p.classes[p.clusters[1].class].opps.len(), 2);
        // The filtered ladder keeps the max OPP.
        assert_eq!(
            p.classes[p.clusters[0].class].max_opp().freq_mhz,
            s.base().classes[0].max_opp().freq_mhz
        );
        assert_eq!(p.noc.hop_latency_us, 0.1);
        assert_eq!(p.noc.link_bandwidth, 4000.0);
        // Mesh fits every PE (Platform::new re-validates coordinates).
        assert!(p.noc.mesh_x * p.noc.mesh_y >= 12);
    }

    #[test]
    fn validate_rejects_out_of_space_genomes() {
        let s = space();
        let mut g = s.seed_genome();
        g.pe_counts[2] = 0;
        assert!(s.decode(&g).is_err());

        let mut g = s.seed_genome();
        g.pe_counts[2] = 99;
        assert!(s.decode(&g).is_err());

        let mut g = s.seed_genome();
        g.opp_masks[0] = 0;
        assert!(s.decode(&g).is_err());

        let mut g = s.seed_genome();
        g.opp_masks[0] = u64::MAX;
        assert!(s.decode(&g).is_err());

        let mut g = s.seed_genome();
        g.pe_counts.pop();
        assert!(s.decode(&g).is_err());

        // Continuous genes outside the space bounds fail loudly too
        // (corrupt or foreign-config checkpoints must not evaluate).
        let mut g = s.seed_genome();
        g.hop_latency_us = 5.0;
        assert!(s.decode(&g).is_err());

        let mut g = s.seed_genome();
        g.link_bandwidth = 1.0;
        assert!(s.decode(&g).is_err());

        let mut g = s.seed_genome();
        g.power_budget_w = Some(99.0);
        assert!(s.decode(&g).is_err());
    }

    #[test]
    fn mutation_stays_in_space_and_changes_something() {
        let s = space();
        let mut rng = Rng::new(5);
        let mut g = s.seed_genome();
        for _ in 0..200 {
            let m = s.mutate(&g, 0.3, &mut rng);
            assert_ne!(m, g, "mutation must perturb at least one gene");
            s.validate(&m).unwrap();
            g = m;
        }
    }

    #[test]
    fn crossover_mixes_parent_genes() {
        let s = space();
        let mut rng = Rng::new(6);
        let a = s.seed_genome();
        let mut b = s.seed_genome();
        b.pe_counts = vec![1, 1, 1, 1];
        b.hop_latency_us = 0.19;
        for _ in 0..50 {
            let child = s.crossover(&a, &b, &mut rng);
            s.validate(&child).unwrap();
            for c in 0..s.n_clusters() {
                assert!(
                    child.pe_counts[c] == a.pe_counts[c]
                        || child.pe_counts[c] == b.pe_counts[c]
                );
            }
        }
    }

    #[test]
    fn random_genomes_are_valid_and_diverse() {
        let s = space();
        let mut rng = Rng::new(7);
        let gs: Vec<PlatformGenome> =
            (0..64).map(|_| s.random(&mut rng)).collect();
        for g in &gs {
            s.validate(g).unwrap();
            s.decode(g).unwrap();
        }
        let distinct: std::collections::BTreeSet<String> =
            gs.iter().map(|g| g.key()).collect();
        assert!(distinct.len() > 32, "only {} distinct", distinct.len());
    }

    #[test]
    fn genome_json_roundtrip_is_exact() {
        let s = space();
        let mut rng = Rng::new(8);
        for _ in 0..32 {
            let g = s.random(&mut rng);
            let j = Json::parse(&g.to_json().to_string()).unwrap();
            let g2 = PlatformGenome::from_json(&j).unwrap();
            assert_eq!(g, g2);
            assert_eq!(g.key(), g2.key());
            assert_eq!(g.hash64(), g2.hash64());
        }
    }

    #[test]
    fn hash_is_stable_and_discriminating() {
        let s = space();
        let g = s.seed_genome();
        assert_eq!(g.hash64(), g.clone().hash64());
        let mut h = g.clone();
        h.pe_counts[0] += 1;
        assert_ne!(g.hash64(), h.hash64());
    }

    #[test]
    fn decoded_platform_simulates() {
        use crate::app::suite::{self, WifiParams};
        use crate::config::SimConfig;
        use crate::sim::Simulation;
        let s = space();
        let mut rng = Rng::new(9);
        let g = s.random(&mut rng);
        let (p, cap) = s.decode(&g).unwrap();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let mut cfg = SimConfig::default();
        cfg.max_jobs = 20;
        cfg.warmup_jobs = 2;
        cfg.dtpm.power_cap_w = cap;
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r.completed_jobs, 20);
    }

    #[test]
    fn rejects_class_sharing_base() {
        // Build a base where two clusters share one class.
        let base = Platform::table2_soc();
        let mut pes = base.pes.clone();
        let mut clusters = base.clusters.clone();
        // Point cluster 1's PEs at class 0 — now class 0 backs both
        // cluster 0 and cluster 1.
        clusters[1].class = 0;
        for &pid in &clusters[1].pe_ids.clone() {
            pes[pid].class = 0;
        }
        let shared = Platform::new(
            "shared",
            base.classes.clone(),
            pes,
            clusters,
            base.noc.clone(),
            base.floorplan.clone(),
        )
        .unwrap();
        assert!(GenomeSpace::new(
            shared,
            1,
            8,
            (0.02, 0.2),
            (2000.0, 16000.0),
            (3.0, 10.0),
            true,
        )
        .is_err());
    }
}
