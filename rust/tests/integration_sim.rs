//! End-to-end simulation-kernel integration tests: queueing behaviour,
//! conservation laws, and cross-subsystem consistency.

use ds3r::app::suite::{self, RadarParams, WifiParams};
use ds3r::config::{ArrivalKind, SimConfig};
use ds3r::platform::Platform;
use ds3r::sim::Simulation;

fn cfg(sched: &str, rate: f64, jobs: usize) -> SimConfig {
    let mut c = SimConfig::default();
    c.scheduler = sched.into();
    c.injection_rate_per_ms = rate;
    c.max_jobs = jobs;
    c.warmup_jobs = jobs / 10;
    c
}

#[test]
fn latency_is_monotone_in_rate() {
    // Mean job execution time must not decrease with injection rate
    // (Figure 3's x-axis direction) for every scheduler.
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    for sched in ["met", "etf", "ilp", "heft"] {
        let mut last = 0.0;
        for rate in [0.5, 2.0, 5.0, 8.0] {
            let r = Simulation::build(&p, &apps, &cfg(sched, rate, 300))
                .unwrap()
                .run();
            let avg = r.avg_job_latency_us();
            assert!(
                avg >= last * 0.98, // tolerate sampling wiggle
                "{sched}: latency fell from {last} to {avg} at rate {rate}"
            );
            last = avg;
        }
    }
}

#[test]
fn throughput_tracks_injection_below_saturation() {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    for rate in [1.0, 2.0, 4.0] {
        let r = Simulation::build(&p, &apps, &cfg("etf", rate, 500))
            .unwrap()
            .run();
        let thru = r.throughput_jobs_per_ms();
        assert!(
            (thru - rate).abs() / rate < 0.1,
            "rate {rate}: throughput {thru}"
        );
    }
}

#[test]
fn unloaded_latency_matches_ilp_makespan() {
    // At near-zero load with the table scheduler, every job should take
    // about the offline single-job makespan (plus NoC effects already
    // included in the makespan model).
    let p = Platform::table2_soc();
    let app = suite::wifi_tx(WifiParams { symbols: 6 });
    let sched = ds3r::sched::ilp::optimize(&app, &p, 2_000_000);
    let apps = vec![app];
    let r = Simulation::build(&p, &apps, &cfg("ilp", 0.05, 40))
        .unwrap()
        .run();
    let avg = r.avg_job_latency_us();
    assert!(
        (avg - sched.makespan_us).abs() / sched.makespan_us < 0.10,
        "sim {avg} vs ilp makespan {}",
        sched.makespan_us
    );
}

#[test]
fn energy_scales_with_work() {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let r1 = Simulation::build(&p, &apps, &cfg("etf", 2.0, 200))
        .unwrap()
        .run();
    let r2 = Simulation::build(&p, &apps, &cfg("etf", 2.0, 400))
        .unwrap()
        .run();
    // Twice the jobs over ~twice the time: energy roughly doubles.
    let ratio = r2.total_energy_j / r1.total_energy_j;
    assert!((1.6..2.4).contains(&ratio), "energy ratio {ratio}");
}

#[test]
fn busy_time_never_exceeds_elapsed() {
    let p = Platform::table2_soc();
    let apps = vec![suite::pulse_doppler(RadarParams { pulses: 8 })];
    let r = Simulation::build(&p, &apps, &cfg("etf", 1.0, 120))
        .unwrap()
        .run();
    for (i, &u) in r.pe_utilization.iter().enumerate() {
        assert!((0.0..=1.0).contains(&u), "pe {i} utilization {u}");
    }
}

#[test]
fn gantt_trace_is_consistent() {
    // No PE overlap; every execution window respects its DAG deps.
    let p = Platform::table2_soc();
    let apps = vec![suite::range_detection(RadarParams { pulses: 4 })];
    let mut c = cfg("etf", 2.0, 60);
    c.capture_gantt = true;
    c.gantt_limit = 100_000;
    let r = Simulation::build(&p, &apps, &c).unwrap().run();
    assert!(!r.gantt.is_empty());

    // Per-PE non-overlap.
    let mut by_pe: Vec<Vec<(f64, f64)>> = vec![Vec::new(); p.n_pes()];
    for e in &r.gantt {
        by_pe[e.pe].push((e.start_us, e.end_us));
    }
    for (pe, windows) in by_pe.iter_mut().enumerate() {
        windows.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in windows.windows(2) {
            assert!(
                w[1].0 >= w[0].1 - 1e-9,
                "pe {pe}: overlapping executions {w:?}"
            );
        }
    }

    // Dependency order within each job.
    let app = &apps[0];
    let mut finish: std::collections::BTreeMap<(usize, usize), f64> =
        Default::default();
    for e in &r.gantt {
        finish.insert((e.job, e.task), e.end_us);
    }
    for e in &r.gantt {
        for &pred in &app.tasks[e.task].preds {
            if let Some(&pf) = finish.get(&(e.job, pred)) {
                assert!(
                    e.start_us >= pf - 1e-9,
                    "job {} task {} started {} before pred {} finished {}",
                    e.job,
                    e.task,
                    e.start_us,
                    pred,
                    pf
                );
            }
        }
    }
}

#[test]
fn arrival_processes_have_distinct_signatures() {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams { symbols: 4 })];
    let mut results = Vec::new();
    for kind in
        [ArrivalKind::Poisson, ArrivalKind::Periodic, ArrivalKind::Uniform]
    {
        let mut c = cfg("etf", 5.0, 300);
        c.arrival = kind;
        let r = Simulation::build(&p, &apps, &c).unwrap().run();
        results.push(r.latency_summary());
    }
    // Poisson has the heaviest tail; periodic the lightest (identical
    // spacing -> near-constant latency).
    let (poisson, periodic, _uniform) =
        (&results[0], &results[1], &results[2]);
    assert!(poisson.p99 >= periodic.p99);
    assert!(poisson.std > periodic.std);
}

#[test]
fn saturated_run_terminates_via_time_guard() {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let mut c = cfg("met", 20.0, 0); // unbounded jobs
    c.max_sim_us = 50_000.0; // 50 ms guard
    let r = Simulation::build(&p, &apps, &c).unwrap().run();
    assert!(r.sim_time_us <= 51_000.0);
    assert!(r.injected_jobs > 0);
}

#[test]
fn zcu102_platform_runs_the_suite() {
    let p = ds3r::platform::presets::zcu102_soc();
    // zcu102 has no LITTLE cluster; every suite task also lists A15, so
    // the workload remains schedulable.
    let apps = vec![
        suite::wifi_tx(WifiParams { symbols: 6 }),
        suite::range_detection(RadarParams { pulses: 6 }),
    ];
    let r = Simulation::build(&p, &apps, &cfg("etf", 2.0, 100))
        .unwrap()
        .run();
    assert_eq!(r.completed_jobs, 100);
}

#[test]
fn per_app_latencies_partition_total() {
    let p = Platform::table2_soc();
    let apps = vec![
        suite::wifi_tx(WifiParams { symbols: 4 }),
        suite::single_carrier_rx(),
    ];
    let r = Simulation::build(&p, &apps, &cfg("etf", 2.0, 200))
        .unwrap()
        .run();
    let n: usize = r.per_app_latencies_us.iter().map(Vec::len).sum();
    assert_eq!(n, r.job_latencies_us.len());
}
