//! Configuration system: simulation, DTPM and workload parameters.
//!
//! Every knob the framework exposes lives in [`SimConfig`] and is
//! (de)serializable as JSON so experiments are reproducible from a
//! config file (`ds3r run --config exp.json`).  Defaults mirror the
//! paper's scheduling case study (§3).
//!
//! Design-space exploration runs are configured by [`DseConfig`]
//! (re-exported from [`crate::dse`]) and imitation-learning runs by
//! [`LearnConfig`] (re-exported from [`crate::learn`]); both embed a
//! base `SimConfig` for their evaluations and follow the same
//! JSON-with-defaults and validate-on-parse conventions.

use std::path::PathBuf;

use crate::scenario::Scenario;
use crate::util::json::Json;
use crate::{Error, Result};

pub use crate::dse::DseConfig;
pub use crate::learn::LearnConfig;

/// Job inter-arrival process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Poisson process: exponential inter-arrival times (paper default:
    /// "injects instances of an application ... following a given
    /// probability distribution").
    Poisson,
    /// Fixed-period injection.
    Periodic,
    /// Uniform inter-arrival in `[0.5, 1.5] x mean`.
    Uniform,
}

impl ArrivalKind {
    pub fn parse(s: &str) -> Result<ArrivalKind> {
        match s {
            "poisson" => Ok(ArrivalKind::Poisson),
            "periodic" => Ok(ArrivalKind::Periodic),
            "uniform" => Ok(ArrivalKind::Uniform),
            other => Err(Error::Config(format!(
                "unknown arrival process '{other}' \
                 (poisson, periodic, uniform)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Periodic => "periodic",
            ArrivalKind::Uniform => "uniform",
        }
    }
}

/// Dynamic thermal-power management configuration.
#[derive(Debug, Clone)]
pub struct DtpmConfig {
    /// DTPM/DVFS decision epoch (µs).  Power is integrated and the
    /// thermal model stepped at this period.
    pub epoch_us: f64,
    /// Governor: `performance`, `powersave`, `ondemand`, `userspace`.
    pub governor: String,
    /// Target frequency for the userspace governor (MHz).
    pub userspace_mhz: f64,
    /// Enable thermal throttling.
    pub thermal_throttle: bool,
    /// Throttle trip point, absolute °C.
    pub throttle_temp_c: f64,
    /// Optional SoC power cap (W): the power-cap policy lowers OPPs
    /// while the last epoch's average power exceeds this.
    pub power_cap_w: Option<f64>,
}

impl Default for DtpmConfig {
    fn default() -> Self {
        DtpmConfig {
            epoch_us: 10_000.0, // 10 ms, Linux ondemand-style sampling
            governor: "performance".into(),
            userspace_mhz: 1000.0,
            thermal_throttle: false,
            throttle_temp_c: 85.0,
            power_cap_w: None,
        }
    }
}

/// Full simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Scheduler name (see `sched::builtin_names`).
    pub scheduler: String,
    /// Mean job injection rate (jobs per millisecond) across all apps.
    pub injection_rate_per_ms: f64,
    pub arrival: ArrivalKind,
    /// Total jobs to inject (0 = unbounded, stop on `max_sim_us`).
    pub max_jobs: usize,
    /// Jobs excluded from steady-state statistics (transient warmup).
    pub warmup_jobs: usize,
    pub seed: u64,
    /// Scheduler window: max ready tasks passed per decision epoch.
    pub max_ready: usize,
    /// Fractional execution-time jitter (std of a truncated normal);
    /// 0 disables. Models run-to-run hardware variance.
    pub exec_jitter_frac: f64,
    /// Model NoC contention.
    pub noc_congestion: bool,
    /// Relative injection weight per application in the workload mix
    /// (empty = uniform).
    pub app_weights: Vec<f64>,
    pub dtpm: DtpmConfig,
    /// Record a Gantt trace (first `gantt_limit` task executions).
    pub capture_gantt: bool,
    pub gantt_limit: usize,
    /// Record per-epoch temperature/power traces.
    pub capture_traces: bool,
    /// Hard wall on simulated time (µs); guards saturated runs.
    pub max_sim_us: f64,
    /// Deterministic watchdog: maximum event-loop iterations before a
    /// run is declared timed out (0 = disabled).  The budget counts
    /// *simulation steps*, never wall clock, so a "timed out" verdict
    /// is bit-reproducible across machines and thread counts; a run
    /// that trips it finalizes normally with
    /// [`crate::stats::SimReport::timed_out`] set, which grid drivers
    /// turn into a `PointOutcome::TimedOut` quarantine verdict.
    pub step_budget: u64,
    /// Replay job arrivals from this JSON trace file instead of the
    /// stochastic generator (see `jobgen::JobGen::from_trace_json`).
    pub trace_file: Option<PathBuf>,
    /// Artifacts directory override (etf-xla / XLA thermal path).
    pub artifacts_dir: Option<PathBuf>,
    /// Trained IL policy artifact for the `il` scheduler (JSON, see
    /// [`crate::learn`]).  `None` uses the committed pretrained preset
    /// baked into the binary, so `--sched il` works without training.
    pub il_policy: Option<PathBuf>,
    /// Step the thermal model through the AOT PJRT artifact instead of
    /// the native rust path (bit-compatible to ~1e-4; see DESIGN.md).
    pub use_xla_thermal: bool,
    /// Force power/thermal integration at every DTPM epoch instead of
    /// the lazy batched lane.  This is the reference path the golden
    /// tests compare against — lazy and eager must be bit-identical
    /// (see `rust/tests/golden_traces.rs` and README §Performance).
    pub eager_integration: bool,
    /// Scenario: a time-scripted timeline of runtime events (rate
    /// ramps, app-mix switches, ambient steps, PE fault/hotplug, power
    /// budgets, scheduler hot-swap) executed alongside task events.  In
    /// JSON either an inline scenario object or a string naming a
    /// preset / `.json` file (see [`crate::scenario`]).
    pub scenario: Option<Scenario>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            scheduler: "etf".into(),
            injection_rate_per_ms: 1.0,
            arrival: ArrivalKind::Poisson,
            max_jobs: 500,
            warmup_jobs: 50,
            seed: 42,
            max_ready: 64,
            exec_jitter_frac: 0.0,
            noc_congestion: false,
            app_weights: Vec::new(),
            dtpm: DtpmConfig::default(),
            capture_gantt: false,
            gantt_limit: 10_000,
            capture_traces: false,
            max_sim_us: 60_000_000.0, // 60 s simulated
            step_budget: 0,
            trace_file: None,
            artifacts_dir: None,
            il_policy: None,
            use_xla_thermal: false,
            eager_integration: false,
            scenario: None,
        }
    }
}

impl SimConfig {
    pub fn validate(&self) -> Result<()> {
        if self.injection_rate_per_ms <= 0.0 {
            return Err(Error::Config(
                "injection_rate_per_ms must be > 0".into(),
            ));
        }
        if self.max_ready == 0 {
            return Err(Error::Config("max_ready must be >= 1".into()));
        }
        if self.warmup_jobs >= self.max_jobs && self.max_jobs > 0 {
            return Err(Error::Config(format!(
                "warmup_jobs ({}) must be < max_jobs ({})",
                self.warmup_jobs, self.max_jobs
            )));
        }
        if self.dtpm.epoch_us <= 0.0 {
            return Err(Error::Config("dtpm.epoch_us must be > 0".into()));
        }
        if !(0.0..0.5).contains(&self.exec_jitter_frac) {
            return Err(Error::Config(
                "exec_jitter_frac must be in [0, 0.5)".into(),
            ));
        }
        if let Some(sc) = &self.scenario {
            sc.validate()?;
        }
        Ok(())
    }

    // ---- JSON ------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut d = Json::obj();
        d.set("epoch_us", Json::Num(self.dtpm.epoch_us))
            .set("governor", Json::Str(self.dtpm.governor.clone()))
            .set("userspace_mhz", Json::Num(self.dtpm.userspace_mhz))
            .set(
                "thermal_throttle",
                Json::Bool(self.dtpm.thermal_throttle),
            )
            .set("throttle_temp_c", Json::Num(self.dtpm.throttle_temp_c));
        if let Some(cap) = self.dtpm.power_cap_w {
            d.set("power_cap_w", Json::Num(cap));
        }
        let mut j = Json::obj();
        j.set("scheduler", Json::Str(self.scheduler.clone()))
            .set(
                "injection_rate_per_ms",
                Json::Num(self.injection_rate_per_ms),
            )
            .set("arrival", Json::Str(self.arrival.name().into()))
            .set("max_jobs", Json::Num(self.max_jobs as f64))
            .set("warmup_jobs", Json::Num(self.warmup_jobs as f64))
            .set("seed", Json::Num(self.seed as f64))
            .set("max_ready", Json::Num(self.max_ready as f64))
            .set("exec_jitter_frac", Json::Num(self.exec_jitter_frac))
            .set("noc_congestion", Json::Bool(self.noc_congestion))
            .set(
                "app_weights",
                Json::Arr(
                    self.app_weights.iter().map(|&w| Json::Num(w)).collect(),
                ),
            )
            .set("dtpm", d)
            .set("capture_gantt", Json::Bool(self.capture_gantt))
            .set("capture_traces", Json::Bool(self.capture_traces))
            .set("max_sim_us", Json::Num(self.max_sim_us))
            .set("use_xla_thermal", Json::Bool(self.use_xla_thermal))
            .set(
                "eager_integration",
                Json::Bool(self.eager_integration),
            );
        // Emitted only when set, like the other optional knobs — so
        // config hashes (and store point keys) of budget-less runs are
        // unchanged by the watchdog's existence.
        if self.step_budget > 0 {
            j.set(
                "step_budget",
                crate::util::json::u64_to_json(self.step_budget),
            );
        }
        if let Some(tf) = &self.trace_file {
            j.set(
                "trace_file",
                Json::Str(tf.to_string_lossy().into_owned()),
            );
        }
        if let Some(p) = &self.il_policy {
            j.set(
                "il_policy",
                Json::Str(p.to_string_lossy().into_owned()),
            );
        }
        if let Some(sc) = &self.scenario {
            j.set("scenario", sc.to_json());
        }
        j
    }

    /// Parse from JSON; missing keys keep their defaults (so configs
    /// only state what they change).
    pub fn from_json(j: &Json) -> Result<SimConfig> {
        let mut c = SimConfig::default();
        if let Some(s) = j.get("scheduler").and_then(Json::as_str) {
            c.scheduler = s.to_string();
        }
        if let Some(x) = j.get("injection_rate_per_ms").and_then(Json::as_f64)
        {
            c.injection_rate_per_ms = x;
        }
        if let Some(s) = j.get("arrival").and_then(Json::as_str) {
            c.arrival = ArrivalKind::parse(s)?;
        }
        if let Some(x) = j.get("max_jobs").and_then(Json::as_usize) {
            c.max_jobs = x;
        }
        if let Some(x) = j.get("warmup_jobs").and_then(Json::as_usize) {
            c.warmup_jobs = x;
        }
        if let Some(x) = j.get("seed").and_then(Json::as_f64) {
            c.seed = x as u64;
        }
        if let Some(x) = j.get("max_ready").and_then(Json::as_usize) {
            c.max_ready = x;
        }
        if let Some(x) = j.get("exec_jitter_frac").and_then(Json::as_f64) {
            c.exec_jitter_frac = x;
        }
        if let Some(b) = j.get("noc_congestion").and_then(Json::as_bool) {
            c.noc_congestion = b;
        }
        if let Some(a) = j.get("app_weights").and_then(Json::as_arr) {
            c.app_weights = a.iter().filter_map(Json::as_f64).collect();
        }
        if let Some(b) = j.get("capture_gantt").and_then(Json::as_bool) {
            c.capture_gantt = b;
        }
        if let Some(b) = j.get("capture_traces").and_then(Json::as_bool) {
            c.capture_traces = b;
        }
        if let Some(x) = j.get("max_sim_us").and_then(Json::as_f64) {
            c.max_sim_us = x;
        }
        if let Some(b) = j.get("use_xla_thermal").and_then(Json::as_bool) {
            c.use_xla_thermal = b;
        }
        if let Some(b) =
            j.get("eager_integration").and_then(Json::as_bool)
        {
            c.eager_integration = b;
        }
        if let Some(x) =
            j.get("step_budget").and_then(crate::util::json::u64_from_json)
        {
            c.step_budget = x;
        }
        if let Some(tf) = j.get("trace_file").and_then(Json::as_str) {
            c.trace_file = Some(PathBuf::from(tf));
        }
        if let Some(p) = j.get("il_policy").and_then(Json::as_str) {
            c.il_policy = Some(PathBuf::from(p));
        }
        match j.get("scenario") {
            None => {}
            // A string names a preset or a scenario .json file.
            Some(Json::Str(s)) => {
                c.scenario = Some(crate::scenario::resolve(s)?);
            }
            Some(obj) => {
                c.scenario = Some(Scenario::from_json(obj)?);
            }
        }
        if let Some(d) = j.get("dtpm") {
            if let Some(x) = d.get("epoch_us").and_then(Json::as_f64) {
                c.dtpm.epoch_us = x;
            }
            if let Some(s) = d.get("governor").and_then(Json::as_str) {
                c.dtpm.governor = s.to_string();
            }
            if let Some(x) = d.get("userspace_mhz").and_then(Json::as_f64) {
                c.dtpm.userspace_mhz = x;
            }
            if let Some(b) =
                d.get("thermal_throttle").and_then(Json::as_bool)
            {
                c.dtpm.thermal_throttle = b;
            }
            if let Some(x) = d.get("throttle_temp_c").and_then(Json::as_f64)
            {
                c.dtpm.throttle_temp_c = x;
            }
            if let Some(x) = d.get("power_cap_w").and_then(Json::as_f64) {
                c.dtpm.power_cap_w = Some(x);
            }
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &std::path::Path) -> Result<SimConfig> {
        SimConfig::from_json(&Json::parse_file(path)?)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        SimConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let mut c = SimConfig::default();
        c.scheduler = "met".into();
        c.injection_rate_per_ms = 7.5;
        c.arrival = ArrivalKind::Periodic;
        c.max_jobs = 1234;
        c.warmup_jobs = 100;
        c.seed = 99;
        c.max_ready = 32;
        c.exec_jitter_frac = 0.05;
        c.noc_congestion = true;
        c.app_weights = vec![2.0, 1.0];
        c.dtpm.governor = "ondemand".into();
        c.dtpm.epoch_us = 5000.0;
        c.dtpm.thermal_throttle = true;
        c.dtpm.power_cap_w = Some(6.5);
        c.use_xla_thermal = true;
        c.eager_integration = true;
        c.trace_file = Some(PathBuf::from("/tmp/trace.json"));
        c.il_policy = Some(PathBuf::from("/tmp/policy.json"));
        let j = c.to_json();
        let c2 = SimConfig::from_json(&j).unwrap();
        assert_eq!(c2.scheduler, "met");
        assert_eq!(c2.injection_rate_per_ms, 7.5);
        assert_eq!(c2.arrival, ArrivalKind::Periodic);
        assert_eq!(c2.max_jobs, 1234);
        assert_eq!(c2.warmup_jobs, 100);
        assert_eq!(c2.seed, 99);
        assert_eq!(c2.max_ready, 32);
        assert_eq!(c2.exec_jitter_frac, 0.05);
        assert!(c2.noc_congestion);
        assert_eq!(c2.app_weights, vec![2.0, 1.0]);
        assert_eq!(c2.dtpm.governor, "ondemand");
        assert_eq!(c2.dtpm.epoch_us, 5000.0);
        assert!(c2.dtpm.thermal_throttle);
        assert_eq!(c2.dtpm.power_cap_w, Some(6.5));
        assert!(c2.use_xla_thermal);
        assert!(c2.eager_integration);
        assert_eq!(c2.trace_file, Some(PathBuf::from("/tmp/trace.json")));
        assert_eq!(c2.il_policy, Some(PathBuf::from("/tmp/policy.json")));
    }

    #[test]
    fn scenario_roundtrips_through_config_json() {
        use crate::scenario::{presets, Action, Scenario};
        let mut c = SimConfig::default();
        c.scenario = Some(
            Scenario::new("inline", "")
                .event(1000.0, Action::SetRate { per_ms: 4.0 })
                .event(2000.0, Action::PeFail { pe: 3 }),
        );
        let c2 = SimConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.scenario, c.scenario);

        // A string resolves through the preset registry.
        let j = Json::parse(r#"{"scenario": "pe-failure"}"#).unwrap();
        let c3 = SimConfig::from_json(&j).unwrap();
        assert_eq!(c3.scenario, Some(presets::pe_failure()));

        // Unknown names are rejected with the preset list.
        let j = Json::parse(r#"{"scenario": "fractal"}"#).unwrap();
        assert!(SimConfig::from_json(&j).is_err());

        // Invalid inline scenarios are rejected by validate().
        let mut bad = SimConfig::default();
        bad.scenario = Some(
            Scenario::new("bad", "")
                .event(-5.0, Action::SetRate { per_ms: 1.0 }),
        );
        assert!(bad.validate().is_err());
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let j = Json::parse(r#"{"scheduler": "heft"}"#).unwrap();
        let c = SimConfig::from_json(&j).unwrap();
        assert_eq!(c.scheduler, "heft");
        assert_eq!(c.max_jobs, SimConfig::default().max_jobs);
    }

    #[test]
    fn rejects_bad_values() {
        let mut c = SimConfig::default();
        c.injection_rate_per_ms = 0.0;
        assert!(c.validate().is_err());

        let mut c = SimConfig::default();
        c.max_ready = 0;
        assert!(c.validate().is_err());

        let mut c = SimConfig::default();
        c.warmup_jobs = c.max_jobs;
        assert!(c.validate().is_err());

        let mut c = SimConfig::default();
        c.exec_jitter_frac = 0.9;
        assert!(c.validate().is_err());
    }

    #[test]
    fn step_budget_roundtrips_and_stays_off_budgetless_json() {
        // Disabled budget leaves the canonical JSON unchanged, so
        // pre-watchdog config hashes and store point keys survive.
        let c = SimConfig::default();
        assert_eq!(c.step_budget, 0);
        assert!(!c.to_json().to_string().contains("step_budget"));

        let mut c = SimConfig::default();
        c.step_budget = 250_000;
        let j = c.to_json();
        assert!(j.to_string().contains("step_budget"));
        let c2 = SimConfig::from_json(&j).unwrap();
        assert_eq!(c2.step_budget, 250_000);
    }

    #[test]
    fn arrival_parse() {
        assert_eq!(
            ArrivalKind::parse("poisson").unwrap(),
            ArrivalKind::Poisson
        );
        assert!(ArrivalKind::parse("gaussian").is_err());
    }
}
