//! Scenario engine demo: run the `pe-failure` preset — all four FFT
//! accelerators drop out at 50 ms and hotplug back at 150 ms — and show
//! the per-phase latency/energy/temperature breakdown the report adds
//! for scenario runs.
//!
//! ```sh
//! cargo run --release --example scenario_run
//! # equivalent CLI:  ds3r run --scenario pe-failure --rate 2 --jobs 500
//! ```

use ds3r::app::suite::{self, WifiParams};
use ds3r::config::SimConfig;
use ds3r::platform::Platform;
use ds3r::scenario::presets;
use ds3r::sim::Simulation;
use ds3r::util::plot;

fn main() {
    let platform = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];

    let scenario = presets::pe_failure();
    println!("scenario '{}': {}\n", scenario.name, scenario.description);

    let mut cfg = SimConfig::default();
    cfg.scheduler = "etf".into();
    cfg.injection_rate_per_ms = 2.0;
    cfg.max_jobs = 500;
    cfg.warmup_jobs = 50;
    cfg.scenario = Some(scenario);

    let report = Simulation::build(&platform, &apps, &cfg)
        .expect("valid configuration")
        .run();
    println!("{}", report.summary());

    let rows: Vec<Vec<String>> = report
        .phases
        .iter()
        .map(|p| {
            vec![
                p.label.clone(),
                format!("{:.0}..{:.0}", p.start_us / 1000.0, p.end_us / 1000.0),
                p.jobs_completed.to_string(),
                format!("{:.1}", p.avg_latency_us),
                format!("{:.1}", p.p95_latency_us),
                format!("{:.3}", p.energy_j),
                format!("{:.2}", p.avg_power_w),
                format!("{:.1}", p.peak_temp_c),
            ]
        })
        .collect();
    println!(
        "{}",
        plot::ascii_table(
            &[
                "phase", "ms", "jobs", "avg us", "p95 us", "J", "W",
                "peak C"
            ],
            &rows
        )
    );

    // The whole point: the timeline is visible in the numbers.
    let base = &report.phases[0];
    let outage = &report.phases[1];
    assert!(
        outage.avg_latency_us > base.avg_latency_us,
        "outage phase should be slower than baseline"
    );
    println!(
        "FFT outage costs {:.1}x in mean job latency ({:.0} -> {:.0} us); \
         the hotplug phase recovers.",
        outage.avg_latency_us / base.avg_latency_us,
        base.avg_latency_us,
        outage.avg_latency_us
    );
}
