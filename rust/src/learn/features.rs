//! Feature extraction for the imitation-learning scheduler.
//!
//! Every scheduling decision is cast as a choice among *candidate PEs*
//! for one ready task; each (ready-task, candidate-PE) pair is described
//! by a fixed, documented vector of [`N_FEATURES`] values derived
//! entirely from the [`SchedContext`] / [`ReadyTask`] / [`PeSnapshot`]
//! API — the same view every hand-written scheduler sees, so a learned
//! policy is deployable wherever ETF is.
//!
//! ## Feature schema (index — name — meaning)
//!
//! | # | name                  | meaning                                      |
//! |---|-----------------------|----------------------------------------------|
//! | 0 | `bias`                | constant 1.0                                 |
//! | 1 | `log_exec_us`         | ln(1 + exec estimate on this PE, µs)         |
//! | 2 | `exec_ratio`          | exec / best exec among candidates (≥ 1)      |
//! | 3 | `log_queue_wait_us`   | ln(1 + time until the PE's queue drains)     |
//! | 4 | `log_data_wait_us`    | ln(1 + time until inputs arrive via the NoC) |
//! | 5 | `log_finish_us`       | ln(1 + projected finish delta from now)      |
//! | 6 | `queue_depth`         | committed tasks on this PE, capped /16       |
//! | 7 | `cluster_queue_depth` | mean queue depth over the PE's cluster, /16  |
//! | 8 | `cluster_busy_frac`   | fraction of busy PEs in the PE's cluster     |
//! | 9 | `is_fastest_class`    | 1.0 iff this PE achieves the best exec       |
//! | 10| `headroom`            | DVFS × thermal headroom of the cluster [0,1] |
//! | 11| `log_task_age_us`     | ln(1 + time the task has been ready)         |
//!
//! All features are finite by construction — degenerate states (zero
//! live PEs of a class, saturated queues, failed PEs) either remove the
//! candidate or clamp the value, never produce NaN/inf (unit-tested on
//! `sched::testutil::MockCtx`).  Log compression keeps microsecond
//! quantities spanning six orders of magnitude in a range SGD handles.

use crate::sched::{PeSnapshot, ReadyTask, SchedContext};

/// Length of the per-(task, PE) feature vector.  Policy artifacts pin
/// this value; loading an artifact with a different `n_features` fails.
pub const N_FEATURES: usize = 12;

/// Documentation names for the feature slots (serialized into policy
/// artifacts so a saved model is self-describing).
pub const FEATURE_NAMES: [&str; N_FEATURES] = [
    "bias",
    "log_exec_us",
    "exec_ratio",
    "log_queue_wait_us",
    "log_data_wait_us",
    "log_finish_us",
    "queue_depth",
    "cluster_queue_depth",
    "cluster_busy_frac",
    "is_fastest_class",
    "headroom",
    "log_task_age_us",
];

/// Queue depths are capped at this many tasks before normalization.
const QUEUE_NORM: f64 = 16.0;

/// Exec ratios are capped here (a 64×-slower PE and a 1000×-slower PE
/// are equally hopeless; unbounded ratios destabilize SGD).
const RATIO_CAP: f64 = 64.0;

/// Per-decision-epoch cluster aggregates, computed once from the PE
/// snapshots and shared by every candidate's feature vector.
/// Long-lived schedulers keep one instance and [`refresh`] it per
/// epoch, so the hot path never reallocates.
///
/// [`refresh`]: FeatureCtx::refresh
#[derive(Debug, Clone, Default)]
pub struct FeatureCtx {
    /// Mean committed-queue depth per cluster.
    pub mean_queue: Vec<f64>,
    /// Fraction of cluster PEs with a non-empty queue.
    pub busy_frac: Vec<f64>,
    /// Scratch: live PEs per cluster.
    counts: Vec<f64>,
}

impl FeatureCtx {
    pub fn new(ctx: &dyn SchedContext) -> FeatureCtx {
        let mut fc = FeatureCtx::default();
        fc.refresh(ctx);
        fc
    }

    /// Clear and refill the aggregates from the current snapshots,
    /// reusing the buffers' capacity across epochs.
    pub fn refresh(&mut self, ctx: &dyn SchedContext) {
        let pes = ctx.pes();
        let n_clusters =
            pes.iter().map(|p| p.cluster + 1).max().unwrap_or(0);
        self.counts.clear();
        self.counts.resize(n_clusters, 0.0);
        self.mean_queue.clear();
        self.mean_queue.resize(n_clusters, 0.0);
        self.busy_frac.clear();
        self.busy_frac.resize(n_clusters, 0.0);
        for p in pes {
            self.counts[p.cluster] += 1.0;
            self.mean_queue[p.cluster] += p.queue_len as f64;
            if p.queue_len > 0 {
                self.busy_frac[p.cluster] += 1.0;
            }
        }
        for c in 0..n_clusters {
            if self.counts[c] > 0.0 {
                self.mean_queue[c] /= self.counts[c];
                self.busy_frac[c] /= self.counts[c];
            }
        }
    }
}

/// Collect the available, supporting PEs for `rt` into `out` as
/// `(pe id, exec µs)` pairs, and return the best (minimum) execution
/// estimate among them — `f64::INFINITY` when the task is currently
/// unplaceable (e.g. every PE of its supporting classes is failed).
pub fn candidates(
    rt: &ReadyTask,
    ctx: &dyn SchedContext,
    out: &mut Vec<(usize, f64)>,
) -> f64 {
    out.clear();
    let mut best = f64::INFINITY;
    for pe in ctx.pes() {
        if !pe.available {
            continue;
        }
        if let Some(us) = ctx.exec_us(rt, pe.id) {
            out.push((pe.id, us));
            if us < best {
                best = us;
            }
        }
    }
    best
}

#[inline]
fn ln1p_us(x: f64) -> f64 {
    x.max(0.0).ln_1p()
}

/// Fill `out` (length [`N_FEATURES`]) with the feature vector of one
/// (ready-task, candidate-PE) pair.
///
/// `avail_us` is passed explicitly (rather than read from the snapshot)
/// so callers committing several tasks per epoch can feed the
/// *virtually updated* availability — the same convention ETF uses.
/// `best_exec_us` is the minimum over the task's candidates (see
/// [`candidates`]); non-finite or non-positive values degrade to a
/// ratio of 1 rather than NaN.
#[allow(clippy::too_many_arguments)]
pub fn features_into(
    rt: &ReadyTask,
    ctx: &dyn SchedContext,
    pe: &PeSnapshot,
    avail_us: f64,
    exec_us: f64,
    best_exec_us: f64,
    fc: &FeatureCtx,
    out: &mut [f64],
) {
    debug_assert_eq!(out.len(), N_FEATURES);
    let now = ctx.now_us();
    let data_at = ctx.data_ready_us(rt, pe.id);
    let queue_wait = (avail_us - now).max(0.0);
    let data_wait = (data_at - now).max(0.0);
    let start = avail_us.max(data_at).max(now);
    let finish = (start - now).max(0.0) + exec_us;
    let ratio = if best_exec_us.is_finite() && best_exec_us > 0.0 {
        (exec_us / best_exec_us).min(RATIO_CAP)
    } else {
        1.0
    };
    out[0] = 1.0;
    out[1] = ln1p_us(exec_us);
    out[2] = ratio;
    out[3] = ln1p_us(queue_wait);
    out[4] = ln1p_us(data_wait);
    out[5] = ln1p_us(finish);
    out[6] = (pe.queue_len as f64 / QUEUE_NORM).min(1.0);
    out[7] = (fc.mean_queue.get(pe.cluster).copied().unwrap_or(0.0)
        / QUEUE_NORM)
        .min(1.0);
    out[8] = fc.busy_frac.get(pe.cluster).copied().unwrap_or(0.0);
    out[9] = if exec_us <= best_exec_us { 1.0 } else { 0.0 };
    out[10] = ctx.headroom_frac(pe.cluster).clamp(0.0, 1.0);
    out[11] = ln1p_us(now - rt.ready_us);
    debug_assert!(
        out.iter().all(|v| v.is_finite()),
        "non-finite feature: {out:?}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::{rt, MockCtx};

    fn assert_all_finite(v: &[f64]) {
        assert!(v.iter().all(|x| x.is_finite()), "{v:?}");
    }

    #[test]
    fn features_are_finite_and_schema_sized() {
        let mut ctx = MockCtx::uniform(3, 100.0);
        ctx.set_exec(0, 0, 0, 10.0);
        ctx.set_exec(0, 0, 1, 40.0);
        let fc = FeatureCtx::new(&ctx);
        let mut cands = Vec::new();
        let best = candidates(&rt(0, 0), &ctx, &mut cands);
        assert_eq!(best, 10.0);
        assert_eq!(cands, vec![(0, 10.0), (1, 40.0)]);
        let mut out = [0.0; N_FEATURES];
        for &(pe, exec) in &cands {
            features_into(
                &rt(0, 0),
                &ctx,
                &ctx.pes[pe],
                ctx.pes[pe].avail_us,
                exec,
                best,
                &fc,
                &mut out,
            );
            assert_all_finite(&out);
            assert_eq!(out[0], 1.0);
        }
        assert_eq!(FEATURE_NAMES.len(), N_FEATURES);
    }

    #[test]
    fn zero_pes_of_a_class_means_no_candidates() {
        // Task 7 is supported nowhere (models "zero live PEs of the
        // supporting class"): the candidate list must come back empty
        // with an infinite best exec, never a NaN feature.
        let ctx = MockCtx::uniform(4, 0.0);
        let mut cands = Vec::new();
        let best = candidates(&rt(0, 7), &ctx, &mut cands);
        assert!(cands.is_empty());
        assert!(best.is_infinite());
    }

    #[test]
    fn failed_pes_are_not_candidates() {
        let mut ctx = MockCtx::uniform(2, 0.0);
        ctx.set_exec(0, 0, 0, 5.0);
        ctx.set_exec(0, 0, 1, 5.0);
        ctx.pes[0].available = false;
        let mut cands = Vec::new();
        let best = candidates(&rt(0, 0), &ctx, &mut cands);
        assert_eq!(cands, vec![(1, 5.0)]);
        assert_eq!(best, 5.0);
        ctx.pes[1].available = false;
        assert!(candidates(&rt(0, 0), &ctx, &mut cands).is_infinite());
        assert!(cands.is_empty());
    }

    #[test]
    fn saturated_queues_do_not_nan() {
        let mut ctx = MockCtx::uniform(2, 1000.0);
        ctx.set_exec(0, 0, 0, 10.0);
        ctx.pes[0].avail_us = 1e12; // queue drains in ~12 days
        ctx.pes[0].queue_len = 100_000;
        let fc = FeatureCtx::new(&ctx);
        let mut out = [0.0; N_FEATURES];
        features_into(
            &rt(0, 0),
            &ctx,
            &ctx.pes[0],
            ctx.pes[0].avail_us,
            10.0,
            10.0,
            &fc,
            &mut out,
        );
        assert_all_finite(&out);
        assert_eq!(out[6], 1.0, "queue depth must cap at 1");
        assert!(out[3] > 0.0, "queue wait must register");
    }

    #[test]
    fn exec_ratio_and_fastest_flag() {
        let mut ctx = MockCtx::uniform(2, 0.0);
        ctx.set_exec(0, 0, 0, 10.0);
        ctx.set_exec(0, 0, 1, 40.0);
        let fc = FeatureCtx::new(&ctx);
        let mut a = [0.0; N_FEATURES];
        let mut b = [0.0; N_FEATURES];
        features_into(&rt(0, 0), &ctx, &ctx.pes[0], 0.0, 10.0, 10.0, &fc, &mut a);
        features_into(&rt(0, 0), &ctx, &ctx.pes[1], 0.0, 40.0, 10.0, &fc, &mut b);
        assert_eq!(a[2], 1.0);
        assert_eq!(b[2], 4.0);
        assert_eq!(a[9], 1.0);
        assert_eq!(b[9], 0.0);
        // Degenerate best-exec inputs fall back to ratio 1, not NaN.
        let mut c = [0.0; N_FEATURES];
        features_into(
            &rt(0, 0),
            &ctx,
            &ctx.pes[0],
            0.0,
            10.0,
            f64::INFINITY,
            &fc,
            &mut c,
        );
        assert_eq!(c[2], 1.0);
        assert_all_finite(&c);
    }

    #[test]
    fn cluster_aggregates_follow_snapshots() {
        let mut ctx = MockCtx::uniform(4, 0.0);
        ctx.pes[2].cluster = 1;
        ctx.pes[3].cluster = 1;
        ctx.pes[0].queue_len = 4;
        ctx.pes[2].queue_len = 2;
        let fc = FeatureCtx::new(&ctx);
        assert_eq!(fc.mean_queue, vec![2.0, 1.0]);
        assert_eq!(fc.busy_frac, vec![0.5, 0.5]);
    }
}
