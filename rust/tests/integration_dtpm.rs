//! DTPM integration tests: governors, thermal throttling and power caps
//! acting on the full simulation loop.

use ds3r::app::suite::{self, WifiParams};
use ds3r::config::SimConfig;
use ds3r::platform::Platform;
use ds3r::sim::Simulation;
use ds3r::stats::SimReport;

fn run_with(f: impl FnOnce(&mut SimConfig)) -> SimReport {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let mut c = SimConfig::default();
    c.max_jobs = 300;
    c.warmup_jobs = 30;
    c.injection_rate_per_ms = 3.0;
    c.capture_traces = true;
    f(&mut c);
    Simulation::build(&p, &apps, &c).unwrap().run()
}

#[test]
fn powersave_is_slower_but_lower_power_than_performance() {
    let perf = run_with(|c| c.dtpm.governor = "performance".into());
    let save = run_with(|c| c.dtpm.governor = "powersave".into());
    assert!(
        save.avg_job_latency_us() > 2.0 * perf.avg_job_latency_us(),
        "powersave {} vs performance {}",
        save.avg_job_latency_us(),
        perf.avg_job_latency_us()
    );
    assert!(
        save.avg_power_w < perf.avg_power_w,
        "powersave power {} vs performance {}",
        save.avg_power_w,
        perf.avg_power_w
    );
}

#[test]
fn ondemand_sits_between_powersave_and_performance() {
    let perf = run_with(|c| c.dtpm.governor = "performance".into());
    let save = run_with(|c| c.dtpm.governor = "powersave".into());
    let onde = run_with(|c| c.dtpm.governor = "ondemand".into());
    let l = |r: &SimReport| r.avg_job_latency_us();
    assert!(
        l(&perf) <= l(&onde) && l(&onde) <= l(&save),
        "latency ordering: perf {} ondemand {} powersave {}",
        l(&perf),
        l(&onde),
        l(&save)
    );
    // Ondemand saves energy per job relative to performance at moderate
    // load (clusters idle at low frequency between bursts).
    assert!(
        onde.avg_power_w <= perf.avg_power_w * 1.05,
        "ondemand power {} vs perf {}",
        onde.avg_power_w,
        perf.avg_power_w
    );
}

#[test]
fn userspace_pins_frequency() {
    let r = run_with(|c| {
        c.dtpm.governor = "userspace".into();
        c.dtpm.userspace_mhz = 600.0;
    });
    for tr in &r.trace {
        // Cluster 0 (A15) must stay at the requested 600 MHz OPP.
        assert_eq!(tr.cluster_mhz[0], 600.0);
        assert_eq!(tr.cluster_mhz[1], 600.0);
    }
}

#[test]
fn thermal_throttle_caps_temperature() {
    // Force a hot platform: saturating load, then compare peak temps
    // with and without the throttle.
    let hot = run_with(|c| {
        c.injection_rate_per_ms = 10.0;
        c.max_jobs = 2000;
        c.dtpm.thermal_throttle = false;
    });
    let trip = hot.peak_temp_c - 2.0; // trip just below observed peak
    let cooled = run_with(|c| {
        c.injection_rate_per_ms = 10.0;
        c.max_jobs = 2000;
        c.dtpm.thermal_throttle = true;
        c.dtpm.throttle_temp_c = trip;
    });
    assert!(cooled.throttle_engagements > 0, "throttle never engaged");
    assert!(
        cooled.peak_temp_c <= hot.peak_temp_c,
        "throttled peak {} vs free {}",
        cooled.peak_temp_c,
        hot.peak_temp_c
    );
}

#[test]
fn power_cap_reduces_average_power() {
    let free = run_with(|c| c.injection_rate_per_ms = 8.0);
    let cap_w = free.avg_power_w * 0.7;
    let capped = run_with(|c| {
        c.injection_rate_per_ms = 8.0;
        c.dtpm.power_cap_w = Some(cap_w);
    });
    assert!(
        capped.avg_power_w < free.avg_power_w,
        "capped {} vs free {}",
        capped.avg_power_w,
        free.avg_power_w
    );
}

#[test]
fn temperature_rises_with_load_and_stays_physical() {
    let idle = run_with(|c| c.injection_rate_per_ms = 0.2);
    let busy = run_with(|c| {
        c.injection_rate_per_ms = 10.0;
        c.max_jobs = 2000;
    });
    assert!(busy.peak_temp_c > idle.peak_temp_c);
    let p = Platform::table2_soc();
    assert!(idle.peak_temp_c >= p.t_ambient);
    assert!(busy.peak_temp_c < 105.0, "melted: {}", busy.peak_temp_c);
}

#[test]
fn dtpm_epoch_length_changes_trace_resolution() {
    let coarse = run_with(|c| c.dtpm.epoch_us = 20_000.0);
    let fine = run_with(|c| c.dtpm.epoch_us = 2_000.0);
    assert!(fine.trace.len() > 5 * coarse.trace.len());
    // Energy should agree regardless of sampling (same workload):
    let ratio = fine.total_energy_j / coarse.total_energy_j;
    assert!((0.9..1.1).contains(&ratio), "energy ratio {ratio}");
}

#[test]
fn explore_xla_governor_saves_energy_within_thermal_limit() {
    let dir = ds3r::runtime::default_artifacts_dir();
    if !ds3r::runtime::artifacts_available(&dir) {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let perf = run_with(|c| {
        c.injection_rate_per_ms = 0.8;
        c.dtpm.governor = "performance".into();
    });
    let explore = run_with(|c| {
        c.injection_rate_per_ms = 0.8;
        c.dtpm.governor = "explore-xla".into();
        c.dtpm.throttle_temp_c = 80.0;
    });
    assert_eq!(explore.completed_jobs, perf.completed_jobs);
    assert!(explore.device_calls > 0, "DSE path never used");
    assert!(
        explore.energy_per_job_mj() < perf.energy_per_job_mj(),
        "explore {} mJ vs performance {} mJ",
        explore.energy_per_job_mj(),
        perf.energy_per_job_mj()
    );
    assert!(explore.peak_temp_c <= 80.0 + 1.0);
}

#[test]
fn energy_per_job_lower_with_ondemand_at_low_load() {
    let perf = run_with(|c| {
        c.dtpm.governor = "performance".into();
        c.injection_rate_per_ms = 0.5;
    });
    let onde = run_with(|c| {
        c.dtpm.governor = "ondemand".into();
        c.injection_rate_per_ms = 0.5;
    });
    // At 0.5 job/ms the platform is mostly idle: ondemand drops cluster
    // voltage/frequency and leakage+dynamic energy per job falls.
    assert!(
        onde.energy_per_job_mj() < perf.energy_per_job_mj(),
        "ondemand {} mJ vs performance {} mJ",
        onde.energy_per_job_mj(),
        perf.energy_per_job_mj()
    );
}
