//! Metrics, traces, and report generation.
//!
//! "the framework generates plots and reports of schedule, performance,
//! throughput, and energy consumption to aid users in analyzing the
//! behaviour of various algorithms" (paper §2).
//!
//! [`SimReport`] is the structured output of a simulation run; it renders
//! to an ASCII summary, a Gantt chart, CSV series, or JSON.

use crate::app::AppGraph;
use crate::platform::Platform;
use crate::util::json::Json;
use crate::util::{plot, Summary};

/// One executed task instance (schedule/Gantt trace).
#[derive(Debug, Clone, Copy)]
pub struct GanttEntry {
    pub pe: usize,
    pub job: usize,
    pub app: usize,
    pub task: usize,
    pub start_us: f64,
    pub end_us: f64,
}

/// One DTPM epoch snapshot.
#[derive(Debug, Clone)]
pub struct EpochTrace {
    pub t_us: f64,
    /// Absolute node temperatures (°C).
    pub temps_c: Vec<f64>,
    /// Average SoC power over the epoch (W).
    pub power_w: f64,
    /// Granted frequency per cluster (MHz).
    pub cluster_mhz: Vec<f64>,
}

/// Per-phase statistics of a scenario run.  A phase spans the interval
/// between two scenario timeline steps (the first phase, "baseline",
/// starts at t=0); jobs are attributed to the phase they *complete* in.
#[derive(Debug, Clone, Default)]
pub struct PhaseStats {
    /// Label of the scenario step that opened the phase.
    pub label: String,
    pub start_us: f64,
    pub end_us: f64,
    /// Jobs completed inside the phase (warmup included — phases are the
    /// measurement structure of a scenario run).
    pub jobs_completed: usize,
    pub avg_latency_us: f64,
    pub p95_latency_us: f64,
    /// Energy dissipated during the phase (J).
    pub energy_j: f64,
    /// Mean SoC power over the phase (W).
    pub avg_power_w: f64,
    /// Hottest absolute node temperature observed in the phase (°C).
    pub peak_temp_c: f64,
}

impl PhaseStats {
    pub fn duration_us(&self) -> f64 {
        (self.end_us - self.start_us).max(0.0)
    }
}

/// Structured result of one simulation run.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub scheduler: String,
    pub injection_rate_per_ms: f64,
    pub seed: u64,
    /// Scenario name (empty when the run was static).
    pub scenario: String,
    /// Scenario timeline entries executed (ramp sub-steps included).
    pub scenario_events: u64,

    /// Jobs injected / completed (all, including warmup).
    pub injected_jobs: usize,
    pub completed_jobs: usize,
    /// Post-warmup job execution times (finish - arrival, µs).
    pub job_latencies_us: Vec<f64>,
    /// Same, split per application index.
    pub per_app_latencies_us: Vec<Vec<f64>>,
    /// Simulated timespan (µs).
    pub sim_time_us: f64,

    /// Kernel counters.
    pub events_processed: u64,
    pub sched_invocations: u64,
    pub tasks_executed: u64,
    /// Decisions reported by the scheduler active at run end (see
    /// `Scheduler::decision_counts`; 0 for schedulers that don't
    /// count).  After a scenario hot-swap these describe the scheduler
    /// in force at the end of the run.
    pub sched_decisions: u64,
    /// Decisions a guard rerouted — the IL scheduler's oracle-fallback
    /// guard engaging (0 elsewhere).
    pub sched_fallbacks: u64,
    /// Wall-clock time spent inside `Scheduler::schedule` (ns).
    pub sched_wall_ns: u64,
    /// Wall-clock time spent injecting jobs (the `JobArrival`
    /// handler: jobgen sampling + task admission) (ns).
    pub jobgen_wall_ns: u64,
    /// Event-loop remainder (ns): run wall time not attributed to the
    /// scheduler / thermal-flush / jobgen buckets — dispatch, queue
    /// ops, task bookkeeping.  Derived at finalize, so the four
    /// buckets plus `build_wall_ns` tile the invocation's wall clock.
    pub loop_wall_ns: u64,
    /// Total wall-clock for the run (s).
    pub wall_s: f64,

    /// Energy / power / thermal.
    pub total_energy_j: f64,
    pub avg_power_w: f64,
    pub pe_utilization: Vec<f64>,
    pub peak_temp_c: f64,
    pub throttle_engagements: u64,
    /// PJRT device invocations (0 on the pure-rust paths).
    pub device_calls: u64,
    /// DTPM epochs whose power/thermal integration was deferred to a
    /// batched flush (the lazy lane; 0 when a policy or trace forces
    /// eager integration every epoch).
    pub deferred_epochs: u64,
    /// Power/thermal integration flushes (eager runs: one per epoch;
    /// lazy runs: one per observation point).
    pub thermal_flushes: u64,
    /// Wall-clock time spent inside power/thermal flushes (ns) — the
    /// timing span over the stage `thermal_flushes` counts.
    pub thermal_wall_ns: u64,
    /// Wall-clock time building (or resetting) the engine for this run
    /// (ns): the `SimWorker::fresh` span.
    pub build_wall_ns: u64,
    /// Whether this run's engine came from a recycled worker reset
    /// (`true`) or a from-scratch build (`false`) — splits
    /// `build_wall_ns` into the reset-vs-fresh comparison without
    /// affecting simulated behaviour.
    pub build_reused: bool,

    /// Watchdog verdict: true iff the run's deterministic step budget
    /// (`SimConfig::step_budget`) was exhausted and the event loop
    /// stopped early.  Depends only on the event sequence, never on
    /// wall clock, so the verdict is bit-reproducible.
    pub timed_out: bool,
    /// Steps the watchdog had counted when it tripped (0 otherwise).
    pub watchdog_steps: u64,

    pub scheduler_report: Vec<String>,
    pub gantt: Vec<GanttEntry>,
    pub trace: Vec<EpochTrace>,
    /// Per-phase breakdown (scenario runs only; empty otherwise).
    pub phases: Vec<PhaseStats>,
}

impl SimReport {
    /// Recycle this report's heap buffers into a fresh zeroed report,
    /// leaving `self` hollow.  Every scalar of the returned report is
    /// the `Default` value — including the wall-clock profile buckets
    /// (`sched_wall_ns`, `jobgen_wall_ns`, `loop_wall_ns`,
    /// `thermal_wall_ns`), so a reused worker's profile never bleeds
    /// into the next run and fresh-vs-reset stays bit-identical (wall
    /// fields are excluded from deterministic streams regardless).
    /// Every collection is an emptied (`clear`ed, capacity-retaining)
    /// version of `self`'s — the reusable `SimWorker`'s reset path
    /// calls this so steady-state grid evaluation stops re-allocating
    /// report buffers.
    pub fn recycle(&mut self) -> SimReport {
        let mut fresh = SimReport::default();
        std::mem::swap(
            &mut fresh.job_latencies_us,
            &mut self.job_latencies_us,
        );
        fresh.job_latencies_us.clear();
        std::mem::swap(
            &mut fresh.per_app_latencies_us,
            &mut self.per_app_latencies_us,
        );
        for lats in &mut fresh.per_app_latencies_us {
            lats.clear();
        }
        std::mem::swap(
            &mut fresh.pe_utilization,
            &mut self.pe_utilization,
        );
        fresh.pe_utilization.clear();
        std::mem::swap(
            &mut fresh.scheduler_report,
            &mut self.scheduler_report,
        );
        fresh.scheduler_report.clear();
        std::mem::swap(&mut fresh.gantt, &mut self.gantt);
        fresh.gantt.clear();
        std::mem::swap(&mut fresh.trace, &mut self.trace);
        fresh.trace.clear();
        std::mem::swap(&mut fresh.phases, &mut self.phases);
        fresh.phases.clear();
        fresh
    }

    /// Mean job execution time (µs) over post-warmup completions —
    /// the Figure-3 y-axis.
    pub fn avg_job_latency_us(&self) -> f64 {
        Summary::of(&self.job_latencies_us).mean
    }

    pub fn latency_summary(&self) -> Summary {
        Summary::of(&self.job_latencies_us)
    }

    /// Completed jobs per simulated millisecond.
    pub fn throughput_jobs_per_ms(&self) -> f64 {
        if self.sim_time_us <= 0.0 {
            return 0.0;
        }
        self.completed_jobs as f64 / (self.sim_time_us / 1000.0)
    }

    /// Energy per completed job (mJ).
    pub fn energy_per_job_mj(&self) -> f64 {
        if self.completed_jobs == 0 {
            return 0.0;
        }
        self.total_energy_j * 1000.0 / self.completed_jobs as f64
    }

    /// Average scheduler decision latency (µs of wall time per
    /// invocation) — the framework-overhead metric in §Perf.
    pub fn sched_overhead_us(&self) -> f64 {
        if self.sched_invocations == 0 {
            return 0.0;
        }
        self.sched_wall_ns as f64 / 1000.0 / self.sched_invocations as f64
    }

    /// Multi-line ASCII summary.
    pub fn summary(&self) -> String {
        let lat = self.latency_summary();
        let mut s = String::new();
        s.push_str(&format!(
            "scheduler={} rate={}/ms seed={}\n",
            self.scheduler, self.injection_rate_per_ms, self.seed
        ));
        s.push_str(&format!(
            "  jobs: injected={} completed={} measured={}\n",
            self.injected_jobs,
            self.completed_jobs,
            lat.count
        ));
        s.push_str(&format!(
            "  job exec time: mean={:.1} us  p50={:.1}  p95={:.1}  p99={:.1}  max={:.1}\n",
            lat.mean, lat.p50, lat.p95, lat.p99, lat.max
        ));
        s.push_str(&format!(
            "  throughput={:.3} jobs/ms  sim_time={:.1} ms  wall={:.2} s\n",
            self.throughput_jobs_per_ms(),
            self.sim_time_us / 1000.0,
            self.wall_s
        ));
        s.push_str(&format!(
            "  energy={:.3} J  avg_power={:.2} W  {:.2} mJ/job  peak_temp={:.1} C  throttles={}\n",
            self.total_energy_j,
            self.avg_power_w,
            self.energy_per_job_mj(),
            self.peak_temp_c,
            self.throttle_engagements
        ));
        s.push_str(&format!(
            "  kernel: {} events, {} sched epochs ({:.2} us/epoch wall), {} tasks, {} device calls\n",
            self.events_processed,
            self.sched_invocations,
            self.sched_overhead_us(),
            self.tasks_executed,
            self.device_calls
        ));
        s.push_str(&format!(
            "  thermal: {} epochs deferred across {} flushes\n",
            self.deferred_epochs, self.thermal_flushes
        ));
        let prof_ns = self.sched_wall_ns
            + self.thermal_wall_ns
            + self.jobgen_wall_ns
            + self.loop_wall_ns;
        if prof_ns > 0 {
            let pct = |ns: u64| 100.0 * ns as f64 / prof_ns as f64;
            s.push_str(&format!(
                "  profile: sched={:.1}%  loop={:.1}%  thermal={:.1}%  jobgen={:.1}%  (+{:.2} ms build)\n",
                pct(self.sched_wall_ns),
                pct(self.loop_wall_ns),
                pct(self.thermal_wall_ns),
                pct(self.jobgen_wall_ns),
                self.build_wall_ns as f64 / 1e6,
            ));
        }
        if self.sched_decisions > 0 {
            s.push_str(&format!(
                "  scheduler decisions: {} ({} guard fallbacks)\n",
                self.sched_decisions, self.sched_fallbacks
            ));
        }
        if self.timed_out {
            s.push_str(&format!(
                "  WATCHDOG: step budget exhausted after {} steps\n",
                self.watchdog_steps
            ));
        }
        for line in &self.scheduler_report {
            s.push_str(&format!("  {line}\n"));
        }
        if !self.phases.is_empty() {
            s.push_str(&format!(
                "  scenario '{}': {} events, {} phases\n",
                self.scenario,
                self.scenario_events,
                self.phases.len()
            ));
            for p in &self.phases {
                s.push_str(&format!(
                    "    [{:>9.1}..{:>9.1} ms] {:<24} jobs={:<5} \
                     avg={:>8.1} us  p95={:>8.1} us  {:>7.3} J  \
                     {:>5.2} W  peak={:>5.1} C\n",
                    p.start_us / 1000.0,
                    p.end_us / 1000.0,
                    p.label,
                    p.jobs_completed,
                    p.avg_latency_us,
                    p.p95_latency_us,
                    p.energy_j,
                    p.avg_power_w,
                    p.peak_temp_c
                ));
            }
        }
        s
    }

    /// ASCII Gantt chart of the first `max_rows` PEs over a window.
    pub fn gantt_ascii(
        &self,
        platform: &Platform,
        apps: &[AppGraph],
        window_us: (f64, f64),
        width: usize,
    ) -> String {
        if self.gantt.is_empty() {
            return "  (no gantt trace captured — set capture_gantt)\n"
                .into();
        }
        let (lo, hi) = window_us;
        let span = (hi - lo).max(1e-9);
        let mut out = String::new();
        out.push_str(&format!(
            "  Gantt [{:.0}..{:.0} us], one row per PE:\n",
            lo, hi
        ));
        for pe in 0..platform.n_pes() {
            let mut row = vec!['.'; width];
            for e in self.gantt.iter().filter(|e| e.pe == pe) {
                if e.end_us < lo || e.start_us > hi {
                    continue;
                }
                let c0 = (((e.start_us - lo) / span) * width as f64)
                    .max(0.0) as usize;
                let c1 = (((e.end_us - lo) / span) * width as f64)
                    .min(width as f64 - 1.0) as usize;
                // Mark with the first letter of the task name.
                let name = &apps[e.app].tasks[e.task].name;
                let ch = name.chars().next().unwrap_or('#');
                for cell in row.iter_mut().take(c1 + 1).skip(c0) {
                    *cell = ch;
                }
            }
            out.push_str(&format!(
                "  {:>8} |{}|\n",
                platform.pes[pe].name,
                row.into_iter().collect::<String>()
            ));
        }
        out
    }

    /// Temperature trace as CSV (`t_us, node0, node1, ...`).
    pub fn thermal_csv(&self, platform: &Platform) -> String {
        let mut out = String::from("t_us");
        for n in &platform.floorplan.node_names {
            out.push(',');
            out.push_str(n);
        }
        out.push_str(",power_w\n");
        for e in &self.trace {
            out.push_str(&format!("{}", e.t_us));
            for t in &e.temps_c {
                out.push_str(&format!(",{t:.3}"));
            }
            out.push_str(&format!(",{:.3}\n", e.power_w));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let lat = self.latency_summary();
        let mut j = Json::obj();
        j.set("scheduler", Json::Str(self.scheduler.clone()))
            .set(
                "injection_rate_per_ms",
                Json::Num(self.injection_rate_per_ms),
            )
            .set("seed", Json::Num(self.seed as f64))
            .set("injected_jobs", Json::Num(self.injected_jobs as f64))
            .set("completed_jobs", Json::Num(self.completed_jobs as f64))
            .set("avg_job_latency_us", Json::Num(lat.mean))
            .set("p95_job_latency_us", Json::Num(lat.p95))
            .set(
                "throughput_jobs_per_ms",
                Json::Num(self.throughput_jobs_per_ms()),
            )
            .set("total_energy_j", Json::Num(self.total_energy_j))
            .set("avg_power_w", Json::Num(self.avg_power_w))
            .set("energy_per_job_mj", Json::Num(self.energy_per_job_mj()))
            .set("peak_temp_c", Json::Num(self.peak_temp_c))
            .set("sim_time_us", Json::Num(self.sim_time_us))
            .set(
                "events_processed",
                Json::Num(self.events_processed as f64),
            )
            .set(
                "sched_overhead_us",
                Json::Num(self.sched_overhead_us()),
            )
            .set(
                "sched_decisions",
                Json::Num(self.sched_decisions as f64),
            )
            .set(
                "sched_fallbacks",
                Json::Num(self.sched_fallbacks as f64),
            )
            .set(
                "pe_utilization",
                Json::Arr(
                    self.pe_utilization
                        .iter()
                        .map(|&u| Json::Num(u))
                        .collect(),
                ),
            );
        // Emitted only when tripped so budget-less reports (and their
        // golden fixtures) are unchanged.
        if self.timed_out {
            j.set("timed_out", Json::Bool(true));
            j.set(
                "watchdog_steps",
                Json::Num(self.watchdog_steps as f64),
            );
        }
        if !self.phases.is_empty() {
            j.set("scenario", Json::Str(self.scenario.clone()));
            j.set(
                "phases",
                Json::Arr(
                    self.phases
                        .iter()
                        .map(|p| {
                            let mut jp = Json::obj();
                            jp.set("label", Json::Str(p.label.clone()))
                                .set("start_us", Json::Num(p.start_us))
                                .set("end_us", Json::Num(p.end_us))
                                .set(
                                    "jobs_completed",
                                    Json::Num(p.jobs_completed as f64),
                                )
                                .set(
                                    "avg_latency_us",
                                    Json::Num(p.avg_latency_us),
                                )
                                .set(
                                    "p95_latency_us",
                                    Json::Num(p.p95_latency_us),
                                )
                                .set("energy_j", Json::Num(p.energy_j))
                                .set(
                                    "avg_power_w",
                                    Json::Num(p.avg_power_w),
                                )
                                .set(
                                    "peak_temp_c",
                                    Json::Num(p.peak_temp_c),
                                );
                            jp
                        })
                        .collect(),
                ),
            );
        }
        j
    }
}

/// Per-generation summary of a guided design-space exploration run
/// ([`crate::dse`]): search progress (front size, hypervolume proxy,
/// best objective values) and evaluation economics (evaluations, cache
/// hits, simulations executed).  Checkpoints carry the whole history,
/// so a resumed search reports the same trajectory as an uninterrupted
/// one.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DseGenStats {
    /// Generation index (0 = the seeded initial population).
    pub generation: usize,
    /// Genome evaluations requested this generation (cache hits
    /// included).
    pub evals: usize,
    /// Evaluations served from the result cache this generation.
    pub cache_hits: usize,
    /// Simulations actually executed this generation.
    pub sims: usize,
    /// Non-dominated designs in the archive after this generation.
    pub front_size: usize,
    /// Hypervolume proxy of the archive — a front-*shape* diagnostic
    /// normalized to the archive's own bounding box, not a monotone
    /// progress metric (see `dse::ParetoArchive::hypervolume_proxy`).
    pub hypervolume: f64,
    /// Best (minimum) value per objective on the front so far — the
    /// monotone progress signal.
    pub best: Vec<f64>,
}

impl DseGenStats {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("generation", Json::Num(self.generation as f64))
            .set("evals", Json::Num(self.evals as f64))
            .set("cache_hits", Json::Num(self.cache_hits as f64))
            .set("sims", Json::Num(self.sims as f64))
            .set("front_size", Json::Num(self.front_size as f64))
            .set("hypervolume", Json::Num(self.hypervolume))
            .set(
                "best",
                Json::Arr(
                    self.best.iter().map(|&x| Json::Num(x)).collect(),
                ),
            );
        j
    }

    pub fn from_json(j: &Json) -> crate::Result<DseGenStats> {
        Ok(DseGenStats {
            generation: j.req_f64("generation")? as usize,
            evals: j.req_f64("evals")? as usize,
            cache_hits: j.req_f64("cache_hits")? as usize,
            sims: j.req_f64("sims")? as usize,
            front_size: j.req_f64("front_size")? as usize,
            hypervolume: j.req_f64("hypervolume")?,
            best: j
                .get("best")
                .ok_or_else(|| {
                    crate::Error::Config(
                        "DseGenStats missing 'best'".into(),
                    )
                })?
                .f64_vec()?,
        })
    }
}

/// One scheduler × generated-scenario cell of a fuzz tournament
/// ([`crate::fuzz::tournament`]): robustness metrics plus any oracle
/// violations the run triggered.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CellScore {
    pub scheduler: String,
    /// Index of the generated scenario (`fuzz::gen::generate` case).
    pub case_idx: usize,
    pub scenario: String,
    /// Scenario timeline length (events), the cell's size signal.
    pub events: usize,
    pub mean_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    /// Jobs whose latency exceeded the configured soft deadline.
    pub deadline_misses: usize,
    pub energy_j: f64,
    /// `sched_fallbacks / sched_decisions` (0 when no decisions).
    pub fallback_rate: f64,
    /// `(oracle, detail)` pairs from [`crate::fuzz::oracle::check`].
    pub violations: Vec<(String, String)>,
}

impl CellScore {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("scheduler", Json::Str(self.scheduler.clone()))
            .set("case", Json::Num(self.case_idx as f64))
            .set("scenario", Json::Str(self.scenario.clone()))
            .set("events", Json::Num(self.events as f64))
            .set("mean_us", Json::Num(self.mean_us))
            .set("p95_us", Json::Num(self.p95_us))
            .set("p99_us", Json::Num(self.p99_us))
            .set("max_us", Json::Num(self.max_us))
            .set(
                "deadline_misses",
                Json::Num(self.deadline_misses as f64),
            )
            .set("energy_j", Json::Num(self.energy_j))
            .set("fallback_rate", Json::Num(self.fallback_rate))
            .set(
                "violations",
                Json::Arr(
                    self.violations
                        .iter()
                        .map(|(oracle, detail)| {
                            let mut v = Json::obj();
                            v.set("oracle", Json::Str(oracle.clone()))
                                .set("detail", Json::Str(detail.clone()));
                            v
                        })
                        .collect(),
                ),
            );
        j
    }

    pub fn from_json(j: &Json) -> crate::Result<CellScore> {
        let violations = match j.get("violations") {
            Some(Json::Arr(xs)) => xs
                .iter()
                .map(|v| {
                    Ok((
                        v.req_str("oracle")?.to_string(),
                        v.req_str("detail")?.to_string(),
                    ))
                })
                .collect::<crate::Result<Vec<_>>>()?,
            _ => Vec::new(),
        };
        Ok(CellScore {
            scheduler: j.req_str("scheduler")?.to_string(),
            case_idx: j.req_f64("case")? as usize,
            scenario: j.req_str("scenario")?.to_string(),
            events: j.req_f64("events")? as usize,
            mean_us: j.req_f64("mean_us")?,
            p95_us: j.req_f64("p95_us")?,
            p99_us: j.req_f64("p99_us")?,
            max_us: j.req_f64("max_us")?,
            deadline_misses: j.req_f64("deadline_misses")? as usize,
            energy_j: j.req_f64("energy_j")?,
            fallback_rate: j.req_f64("fallback_rate")?,
            violations,
        })
    }
}

/// Per-scheduler aggregate over every tournament case, ranked by
/// `rank_score` (sum of per-metric ranks; lower is better).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SchedStanding {
    pub scheduler: String,
    /// Worst job latency across every case (robustness headline).
    pub worst_max_us: f64,
    pub mean_p95_us: f64,
    pub mean_p99_us: f64,
    pub deadline_misses: usize,
    pub energy_j: f64,
    pub fallback_rate: f64,
    pub violations: usize,
    pub rank_score: f64,
}

impl SchedStanding {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("scheduler", Json::Str(self.scheduler.clone()))
            .set("worst_max_us", Json::Num(self.worst_max_us))
            .set("mean_p95_us", Json::Num(self.mean_p95_us))
            .set("mean_p99_us", Json::Num(self.mean_p99_us))
            .set(
                "deadline_misses",
                Json::Num(self.deadline_misses as f64),
            )
            .set("energy_j", Json::Num(self.energy_j))
            .set("fallback_rate", Json::Num(self.fallback_rate))
            .set("violations", Json::Num(self.violations as f64))
            .set("rank_score", Json::Num(self.rank_score));
        j
    }

    pub fn from_json(j: &Json) -> crate::Result<SchedStanding> {
        Ok(SchedStanding {
            scheduler: j.req_str("scheduler")?.to_string(),
            worst_max_us: j.req_f64("worst_max_us")?,
            mean_p95_us: j.req_f64("mean_p95_us")?,
            mean_p99_us: j.req_f64("mean_p99_us")?,
            deadline_misses: j.req_f64("deadline_misses")? as usize,
            energy_j: j.req_f64("energy_j")?,
            fallback_rate: j.req_f64("fallback_rate")?,
            violations: j.req_f64("violations")? as usize,
            rank_score: j.req_f64("rank_score")?,
        })
    }
}

/// Full result of one fuzz tournament: every cell in canonical
/// (scheduler-major, case-minor) order, the ranked standings, and the
/// paths of any minimized repro files written.  Byte-deterministic in
/// `(fuzz config, scheduler roster)` — thread count never changes the
/// serialized report (`rust/tests/fuzz_props.rs` pins this).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TournamentReport {
    pub fuzz_seed: u64,
    pub cases: usize,
    pub jobs: usize,
    pub schedulers: Vec<String>,
    pub cells: Vec<CellScore>,
    pub standings: Vec<SchedStanding>,
    /// Total oracle violations across every cell.
    pub violations: usize,
    /// Minimized repro JSON files, in cell order.
    pub repros: Vec<String>,
}

impl TournamentReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", Json::Str("ds3r-tournament-report".into()))
            .set("fuzz_seed", crate::util::json::u64_to_json(self.fuzz_seed))
            .set("cases", Json::Num(self.cases as f64))
            .set("jobs", Json::Num(self.jobs as f64))
            .set(
                "schedulers",
                Json::Arr(
                    self.schedulers
                        .iter()
                        .map(|s| Json::Str(s.clone()))
                        .collect(),
                ),
            )
            .set(
                "cells",
                Json::Arr(self.cells.iter().map(CellScore::to_json).collect()),
            )
            .set(
                "standings",
                Json::Arr(
                    self.standings
                        .iter()
                        .map(SchedStanding::to_json)
                        .collect(),
                ),
            )
            .set("violations", Json::Num(self.violations as f64))
            .set(
                "repros",
                Json::Arr(
                    self.repros
                        .iter()
                        .map(|s| Json::Str(s.clone()))
                        .collect(),
                ),
            );
        j
    }

    pub fn from_json(j: &Json) -> crate::Result<TournamentReport> {
        if j.get("kind").and_then(Json::as_str)
            != Some("ds3r-tournament-report")
        {
            return Err(crate::Error::Config(
                "not a ds3r-tournament-report file".into(),
            ));
        }
        let strings = |key: &str| -> crate::Result<Vec<String>> {
            j.req_arr(key)?
                .iter()
                .map(|x| {
                    x.as_str().map(str::to_string).ok_or_else(|| {
                        crate::Error::Config(format!(
                            "TournamentReport '{key}' entries must be \
                             strings"
                        ))
                    })
                })
                .collect()
        };
        Ok(TournamentReport {
            fuzz_seed: j.req_f64("fuzz_seed")? as u64,
            cases: j.req_f64("cases")? as usize,
            jobs: j.req_f64("jobs")? as usize,
            schedulers: strings("schedulers")?,
            cells: j
                .req_arr("cells")?
                .iter()
                .map(CellScore::from_json)
                .collect::<crate::Result<Vec<_>>>()?,
            standings: j
                .req_arr("standings")?
                .iter()
                .map(SchedStanding::from_json)
                .collect::<crate::Result<Vec<_>>>()?,
            violations: j.req_f64("violations")? as usize,
            repros: strings("repros")?,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> crate::Result<TournamentReport> {
        TournamentReport::from_json(&Json::parse_file(path)?)
    }
}

/// Collect a Figure-3-style series: mean latency per injection rate.
pub fn latency_series(
    name: &str,
    points: &[(f64, f64)],
) -> plot::Series {
    let mut s = plot::Series::new(name);
    for &(x, y) in points {
        s.push(x, y);
    }
    s
}

/// One reduction over stored manifests (`ds3r query --agg`): the
/// counter field reduced, the aggregation applied, how many manifests
/// matched, and the resulting value.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct QueryAggregate {
    pub field: String,
    /// Aggregation label (`count`, `mean`, `p95`, `worst`).
    pub agg: String,
    /// Manifests the filter selected.
    pub count: usize,
    pub value: f64,
}

impl QueryAggregate {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("field", Json::Str(self.field.clone()))
            .set("agg", Json::Str(self.agg.clone()))
            .set("count", Json::Num(self.count as f64))
            .set("value", Json::Num(self.value));
        j
    }
}

/// Outcome of `ds3r store gc`: what survived and what was dropped.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StoreGcSummary {
    /// Manifests still reachable through the index.
    pub kept_manifests: usize,
    /// Point files referenced by at least one manifest.
    pub kept_points: usize,
    /// Unreferenced point files deleted.
    pub dropped_points: usize,
    /// Index rows whose manifest file was missing, dropped.
    pub dropped_rows: usize,
    /// Orphaned manifest files (written but never indexed — e.g. a
    /// kill between the file write and the index append) re-indexed.
    pub reindexed: usize,
}

impl StoreGcSummary {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set(
            "kept_manifests",
            Json::Num(self.kept_manifests as f64),
        )
        .set("kept_points", Json::Num(self.kept_points as f64))
        .set("dropped_points", Json::Num(self.dropped_points as f64))
        .set("dropped_rows", Json::Num(self.dropped_rows as f64))
        .set("reindexed", Json::Num(self.reindexed as f64));
        j
    }
}

/// Outcome of `ds3r store verify`: every manifest re-hashed from its
/// content and every point key re-derived; `mismatches` lists anything
/// whose stored key disagrees with its content.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StoreVerifySummary {
    pub manifests_checked: usize,
    pub points_checked: usize,
    /// Human-readable descriptions of every key/content disagreement.
    pub mismatches: Vec<String>,
}

impl StoreVerifySummary {
    pub fn ok(&self) -> bool {
        self.mismatches.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set(
            "manifests_checked",
            Json::Num(self.manifests_checked as f64),
        )
        .set(
            "points_checked",
            Json::Num(self.points_checked as f64),
        )
        .set("ok", Json::Bool(self.ok()))
        .set(
            "mismatches",
            Json::Arr(
                self.mismatches
                    .iter()
                    .map(|s| Json::Str(s.clone()))
                    .collect(),
            ),
        );
        j
    }
}

/// Outcome of `ds3r store fsck`: crash-damage triage.  Unparseable
/// manifest/point files are moved (never deleted) into
/// `<store>/quarantine/`, a torn trailing index append is dropped, and
/// index rows pointing at quarantined or missing manifests are removed
/// — so a subsequent `store verify` passes on what remains.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StoreFsckSummary {
    /// Manifest files that parsed and kept their place.
    pub manifests_kept: usize,
    /// Manifest files moved to `quarantine/` (unparseable JSON).
    pub manifests_quarantined: usize,
    /// Point files that parsed and kept their place.
    pub points_kept: usize,
    /// Point files moved to `quarantine/` (unparseable JSON).
    pub points_quarantined: usize,
    /// Index rows dropped (manifest quarantined or file missing).
    pub index_rows_dropped: usize,
    /// Orphaned manifest files (written but never indexed) re-indexed.
    pub reindexed: usize,
    /// Whether a torn trailing `index.jsonl` line was salvaged away.
    pub index_tail_salvaged: bool,
}

impl StoreFsckSummary {
    /// True when fsck found nothing to repair.
    pub fn clean(&self) -> bool {
        self.manifests_quarantined == 0
            && self.points_quarantined == 0
            && self.index_rows_dropped == 0
            && self.reindexed == 0
            && !self.index_tail_salvaged
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set(
            "manifests_kept",
            Json::Num(self.manifests_kept as f64),
        )
        .set(
            "manifests_quarantined",
            Json::Num(self.manifests_quarantined as f64),
        )
        .set("points_kept", Json::Num(self.points_kept as f64))
        .set(
            "points_quarantined",
            Json::Num(self.points_quarantined as f64),
        )
        .set(
            "index_rows_dropped",
            Json::Num(self.index_rows_dropped as f64),
        )
        .set("reindexed", Json::Num(self.reindexed as f64))
        .set(
            "index_tail_salvaged",
            Json::Bool(self.index_tail_salvaged),
        )
        .set("clean", Json::Bool(self.clean()));
        j
    }
}

/// One grid point quarantined under a degraded-mode fail policy.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FailedPoint {
    /// Canonical input-order index of the point in its grid.
    pub index: usize,
    /// Point label (`"{scheduler}@{rate}"`, scenario name, cell id).
    pub label: String,
    /// Failure class: `panic`, `timeout` or `error`.
    pub kind: String,
    /// Panic message, watchdog step count, or error text.
    pub detail: String,
}

/// Degraded-mode summary of a quarantined campaign: how many points
/// the grid attempted and exactly which ones failed, in canonical
/// input order — a deterministic function of (config, seed), identical
/// for any thread count (`rust/tests/integration_fault.rs` pins this).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FailureReport {
    /// Grid points attempted (healthy + quarantined).
    pub total: usize,
    /// Quarantined points, in input order.
    pub failed: Vec<FailedPoint>,
}

impl FailureReport {
    pub fn new(total: usize) -> FailureReport {
        FailureReport { total, failed: Vec::new() }
    }

    pub fn record(
        &mut self,
        index: usize,
        label: String,
        kind: &str,
        detail: String,
    ) {
        self.failed.push(FailedPoint {
            index,
            label,
            kind: kind.to_string(),
            detail,
        });
    }

    /// True when every point succeeded.
    pub fn is_clean(&self) -> bool {
        self.failed.is_empty()
    }

    pub fn quarantined(&self) -> usize {
        self.failed.len()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("total", Json::Num(self.total as f64))
            .set("quarantined", Json::Num(self.quarantined() as f64))
            .set(
                "failed",
                Json::Arr(
                    self.failed
                        .iter()
                        .map(|p| {
                            let mut jp = Json::obj();
                            jp.set("index", Json::Num(p.index as f64))
                                .set("label", Json::Str(p.label.clone()))
                                .set("kind", Json::Str(p.kind.clone()))
                                .set(
                                    "detail",
                                    Json::Str(p.detail.clone()),
                                );
                            jp
                        })
                        .collect(),
                ),
            );
        j
    }

    /// Human rendering for the CLI's degraded-mode footer.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "quarantined {}/{} points:\n",
            self.quarantined(),
            self.total
        );
        for p in &self.failed {
            s.push_str(&format!(
                "  [{}] {} ({}): {}\n",
                p.index, p.label, p.kind, p.detail
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_report() -> SimReport {
        SimReport {
            scheduler: "etf".into(),
            injection_rate_per_ms: 5.0,
            completed_jobs: 100,
            injected_jobs: 110,
            job_latencies_us: (0..100).map(|i| 50.0 + i as f64).collect(),
            sim_time_us: 20_000.0,
            sched_invocations: 200,
            sched_wall_ns: 400_000,
            total_energy_j: 0.5,
            ..Default::default()
        }
    }

    #[test]
    fn dse_gen_stats_json_roundtrip() {
        let s = DseGenStats {
            generation: 7,
            evals: 16,
            cache_hits: 3,
            sims: 26,
            front_size: 9,
            hypervolume: 0.8125,
            best: vec![123.5, 1.75, 61.0],
        };
        let j = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(DseGenStats::from_json(&j).unwrap(), s);
    }

    #[test]
    fn recycle_zeroes_everything_but_keeps_capacity() {
        let mut r = demo_report();
        r.per_app_latencies_us = vec![vec![1.0, 2.0], vec![3.0]];
        r.pe_utilization = vec![0.5; 14];
        r.peak_temp_c = 61.0;
        let lat_cap = r.job_latencies_us.capacity();
        let fresh = r.recycle();
        // `r` is hollow; `fresh` is field-for-field a default report…
        assert_eq!(fresh.scheduler, "");
        assert_eq!(fresh.completed_jobs, 0);
        assert_eq!(fresh.total_energy_j, 0.0);
        assert_eq!(fresh.peak_temp_c, 0.0);
        assert!(fresh.job_latencies_us.is_empty());
        assert!(fresh.pe_utilization.is_empty());
        assert!(fresh.phases.is_empty());
        assert!(fresh
            .per_app_latencies_us
            .iter()
            .all(|v| v.is_empty()));
        // …except that the big buffers kept their allocations.
        assert!(fresh.job_latencies_us.capacity() >= lat_cap);
        assert!(fresh.pe_utilization.capacity() >= 14);
    }

    #[test]
    fn latency_and_throughput() {
        let r = demo_report();
        assert!((r.avg_job_latency_us() - 99.5).abs() < 1e-9);
        assert!((r.throughput_jobs_per_ms() - 5.0).abs() < 1e-9);
        assert!((r.energy_per_job_mj() - 5.0).abs() < 1e-9);
        assert!((r.sched_overhead_us() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_report_is_safe() {
        let r = SimReport::default();
        assert_eq!(r.avg_job_latency_us(), 0.0);
        assert_eq!(r.throughput_jobs_per_ms(), 0.0);
        assert_eq!(r.energy_per_job_mj(), 0.0);
        assert_eq!(r.sched_overhead_us(), 0.0);
        assert!(!r.summary().is_empty());
    }

    #[test]
    fn summary_mentions_key_metrics() {
        let s = demo_report().summary();
        assert!(s.contains("scheduler=etf"));
        assert!(s.contains("throughput"));
        assert!(s.contains("energy"));
        // The decisions line only appears for counting schedulers.
        assert!(!s.contains("guard fallbacks"));
        let mut r = demo_report();
        r.sched_decisions = 42;
        r.sched_fallbacks = 3;
        let s = r.summary();
        assert!(s.contains("42 (3 guard fallbacks)"), "{s}");
        let j = r.to_json();
        assert_eq!(j.get("sched_decisions").unwrap().as_f64(), Some(42.0));
        assert_eq!(j.get("sched_fallbacks").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn json_contains_fig3_fields() {
        let j = demo_report().to_json();
        assert!(j.get("avg_job_latency_us").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            j.get("injection_rate_per_ms").unwrap().as_f64(),
            Some(5.0)
        );
    }

    #[test]
    fn phase_stats_render_in_summary_and_json() {
        let mut r = demo_report();
        r.scenario = "pe-failure".into();
        r.scenario_events = 8;
        r.phases = vec![
            PhaseStats {
                label: "baseline".into(),
                start_us: 0.0,
                end_us: 50_000.0,
                jobs_completed: 40,
                avg_latency_us: 100.0,
                p95_latency_us: 150.0,
                energy_j: 0.2,
                avg_power_w: 4.0,
                peak_temp_c: 55.0,
            },
            PhaseStats {
                label: "pe10-fail".into(),
                start_us: 50_000.0,
                end_us: 150_000.0,
                jobs_completed: 60,
                avg_latency_us: 400.0,
                p95_latency_us: 600.0,
                energy_j: 0.5,
                avg_power_w: 5.0,
                peak_temp_c: 60.0,
            },
        ];
        assert_eq!(r.phases[1].duration_us(), 100_000.0);
        let s = r.summary();
        assert!(s.contains("pe-failure"));
        assert!(s.contains("baseline"));
        assert!(s.contains("pe10-fail"));
        let j = r.to_json();
        let phases = j.get("phases").unwrap().as_arr().unwrap();
        assert_eq!(phases.len(), 2);
        assert_eq!(
            phases[1].get("label").unwrap().as_str(),
            Some("pe10-fail")
        );
        assert_eq!(
            phases[1].get("avg_latency_us").unwrap().as_f64(),
            Some(400.0)
        );
    }

    #[test]
    fn gantt_without_trace_degrades() {
        let r = SimReport::default();
        let p = Platform::table2_soc();
        let out = r.gantt_ascii(&p, &[], (0.0, 100.0), 60);
        assert!(out.contains("no gantt"));
    }

    #[test]
    fn failure_report_records_and_serializes_in_order() {
        let mut fr = FailureReport::new(10);
        assert!(fr.is_clean());
        fr.record(3, "etf@6".into(), "panic", "boom".into());
        fr.record(7, "met@2".into(), "timeout", "5000 steps".into());
        assert!(!fr.is_clean());
        assert_eq!(fr.quarantined(), 2);
        let j = fr.to_json();
        assert_eq!(j.get("total").unwrap().as_f64(), Some(10.0));
        assert_eq!(j.get("quarantined").unwrap().as_f64(), Some(2.0));
        let failed = j.get("failed").unwrap().as_arr().unwrap();
        assert_eq!(failed[0].get("index").unwrap().as_f64(), Some(3.0));
        assert_eq!(
            failed[1].get("kind").unwrap().as_str(),
            Some("timeout")
        );
        let s = fr.summary();
        assert!(s.contains("2/10"), "{s}");
        assert!(s.contains("etf@6"), "{s}");
    }

    #[test]
    fn fsck_summary_clean_flag() {
        let mut f = StoreFsckSummary::default();
        assert!(f.clean());
        f.index_tail_salvaged = true;
        assert!(!f.clean());
        let j = f.to_json();
        assert_eq!(j.get("clean"), Some(&Json::Bool(false)));
        assert_eq!(
            j.get("index_tail_salvaged"),
            Some(&Json::Bool(true))
        );
    }
}
