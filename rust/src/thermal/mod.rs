//! RC thermal-network model.
//!
//! The floorplan ([`crate::platform::ThermalFloorplan`]) defines a
//! HotSpot-style RC network: nodes with thermal capacitance, conductance
//! to ambient, and lateral couplings.  This module discretizes it into
//! the affine update
//!
//! ```text
//!   Θ' = A Θ + B P          (Θ = temperature above ambient, °C)
//!   A  = I - dt C⁻¹ G        B = dt C⁻¹ M
//! ```
//!
//! where `G` is the conductance Laplacian (+ ambient leg on the diagonal)
//! and `M` maps per-PE power onto floorplan nodes.  The same matrices are
//! exported (zero-padded) to the AOT Pallas artifact, which evaluates the
//! update batched over candidate DVFS settings (see
//! [`crate::dtpm::XlaDtpmStep`]); [`RcModel::step`] is the scalar
//! reference the artifact must agree with.
//!
//! Working in above-ambient coordinates folds the ambient offset out of
//! the linear system; the leakage model's `exp(k2·T_abs)` is preserved by
//! rescaling `k1 ← k1·exp(k2·T_amb)` (see [`RcModel::leak_k1_effective`]).

use crate::platform::Platform;

/// Discretized RC network for one platform.
#[derive(Debug, Clone)]
pub struct RcModel {
    /// Number of floorplan nodes.
    pub n: usize,
    /// Number of PEs (columns of B).
    pub n_pes: usize,
    /// `n x n` state matrix, row-major.
    pub a: Vec<f64>,
    /// `n x n_pes` input matrix, row-major.
    pub b: Vec<f64>,
    /// Node index each PE's power flows into (its cluster's node).
    pub pe_node: Vec<usize>,
    /// Discretization step (µs).
    pub dt_us: f64,
    /// Ambient temperature (°C), for absolute-temperature conversions.
    pub t_ambient: f64,
    /// Dense conductance matrix `G` (kept for steady-state solves).
    g: Vec<f64>,
    /// Node capacitances (kept for diagnostics / future variable-dt).
    #[allow(dead_code)]
    c: Vec<f64>,
    /// Cached k-epoch propagators (`A^k`, `(Σ_{i<k} A^i)·B`), keyed by
    /// step count `k`.  The discretization step `dt` is fixed per
    /// model, so `k` indexes repeated-`dt` batches; each propagator is
    /// built once (O(k·n³)) and reused (O(n²) per advance).
    props: Vec<(usize, Propagator)>,
}

/// A cached k-epoch constant-power propagator (see
/// [`RcModel::advance_const_power`]).
#[derive(Debug, Clone)]
pub struct Propagator {
    /// `A^k`, row-major `n × n`.
    pub a_k: Vec<f64>,
    /// `(Σ_{i<k} A^i)·B`, row-major `n × n_pes`.
    pub s_k_b: Vec<f64>,
}

impl RcModel {
    /// Build a model directly from discretized matrices (testing /
    /// externally calibrated models).  `a` is `n x n`, `b` is
    /// `n x n_pes`, row-major; `pe_node[p]` is the node PE `p` heats.
    /// Steady-state solves are unavailable (no conductance matrix):
    /// `steady_state` panics for such models.
    pub fn from_matrices(
        a: Vec<f64>,
        b: Vec<f64>,
        pe_node: Vec<usize>,
        dt_us: f64,
        t_ambient: f64,
    ) -> RcModel {
        let n = (a.len() as f64).sqrt() as usize;
        assert_eq!(n * n, a.len(), "A must be square");
        let n_pes = pe_node.len();
        assert_eq!(b.len(), n * n_pes, "B must be n x n_pes");
        RcModel {
            n,
            n_pes,
            a,
            b,
            pe_node,
            dt_us,
            t_ambient,
            g: vec![0.0; n * n],
            c: vec![1.0; n],
            props: Vec::new(),
        }
    }

    /// Build the discretized model with step `dt_us`.
    ///
    /// Panics (debug) if the discretization would be unstable
    /// (`dt * g_total / C >= 1` for some node) — callers should keep the
    /// DTPM epoch well below the smallest node time constant.
    pub fn new(platform: &Platform, dt_us: f64) -> RcModel {
        let fp = &platform.floorplan;
        let n = fp.len();
        let n_pes = platform.n_pes();
        let dt_s = dt_us * 1e-6;

        // Conductance Laplacian with ambient legs on the diagonal.
        let mut g = vec![0.0f64; n * n];
        for i in 0..n {
            g[i * n + i] = fp.g_amb[i];
        }
        for &(i, j, gij) in &fp.couplings {
            g[i * n + i] += gij;
            g[j * n + j] += gij;
            g[i * n + j] -= gij;
            g[j * n + i] -= gij;
        }

        // A = I - dt C^-1 G.
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let delta = if i == j { 1.0 } else { 0.0 };
                a[i * n + j] = delta - dt_s * g[i * n + j] / fp.capacitance[i];
            }
            debug_assert!(
                a[i * n + i] > 0.0,
                "unstable thermal discretization at node {i}: \
                 dt too large for capacitance {}",
                fp.capacitance[i]
            );
        }

        // B maps PE power into its cluster's node.
        let mut pe_node = Vec::with_capacity(n_pes);
        let mut b = vec![0.0f64; n * n_pes];
        for pe in &platform.pes {
            let node = platform.clusters[pe.cluster].thermal_node;
            pe_node.push(node);
            b[node * n_pes + pe.id] = dt_s / fp.capacitance[node];
        }

        RcModel {
            n,
            n_pes,
            a,
            b,
            pe_node,
            dt_us,
            t_ambient: platform.t_ambient,
            g,
            c: fp.capacitance.clone(),
            props: Vec::new(),
        }
    }

    /// One epoch: `theta' = A theta + B p`.  `theta` is above-ambient °C,
    /// `p` is per-PE power in W.
    pub fn step(&self, theta: &[f64], p: &[f64]) -> Vec<f64> {
        debug_assert_eq!(theta.len(), self.n);
        debug_assert_eq!(p.len(), self.n_pes);
        let mut out = vec![0.0; self.n];
        for i in 0..self.n {
            let mut acc = 0.0;
            let row = &self.a[i * self.n..(i + 1) * self.n];
            for (aij, th) in row.iter().zip(theta) {
                acc += aij * th;
            }
            let brow = &self.b[i * self.n_pes..(i + 1) * self.n_pes];
            for (bij, pw) in brow.iter().zip(p) {
                acc += bij * pw;
            }
            out[i] = acc;
        }
        out
    }

    /// In-place variant used on the simulation hot path (no allocation).
    pub fn step_into(&self, theta: &[f64], p: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.n);
        for i in 0..self.n {
            let mut acc = 0.0;
            let row = &self.a[i * self.n..(i + 1) * self.n];
            for (aij, th) in row.iter().zip(theta) {
                acc += aij * th;
            }
            let brow = &self.b[i * self.n_pes..(i + 1) * self.n_pes];
            for (bij, pw) in brow.iter().zip(p) {
                acc += bij * pw;
            }
            out[i] = acc;
        }
    }

    /// Above-ambient temperature seen by each PE.
    pub fn t_pe(&self, theta: &[f64]) -> Vec<f64> {
        self.pe_node.iter().map(|&nd| theta[nd]).collect()
    }

    /// The cached `k`-epoch propagator, building (and memoizing) it on
    /// first use.
    pub fn propagator(&mut self, k: usize) -> &Propagator {
        assert!(k >= 1, "propagator needs k >= 1 epochs");
        if let Some(pos) =
            self.props.iter().position(|(kk, _)| *kk == k)
        {
            return &self.props[pos].1;
        }
        let n = self.n;
        // a_k starts at I and is left-multiplied by A k times; s
        // accumulates Σ_{i<k} A^i along the way.
        let mut a_k = vec![0.0f64; n * n];
        for i in 0..n {
            a_k[i * n + i] = 1.0;
        }
        let mut s = vec![0.0f64; n * n];
        for _ in 0..k {
            for (si, ai) in s.iter_mut().zip(&a_k) {
                *si += ai;
            }
            let mut next = vec![0.0f64; n * n];
            for i in 0..n {
                for l in 0..n {
                    let aij = self.a[i * n + l];
                    if aij == 0.0 {
                        continue;
                    }
                    for j in 0..n {
                        next[i * n + j] += aij * a_k[l * n + j];
                    }
                }
            }
            a_k = next;
        }
        // s_k_b = (Σ A^i) · B.
        let n_pes = self.n_pes;
        let mut s_k_b = vec![0.0f64; n * n_pes];
        for i in 0..n {
            for l in 0..n {
                let sil = s[i * n + l];
                if sil == 0.0 {
                    continue;
                }
                for j in 0..n_pes {
                    s_k_b[i * n_pes + j] += sil * self.b[l * n_pes + j];
                }
            }
        }
        self.props.push((k, Propagator { a_k, s_k_b }));
        &self.props.last().unwrap().1
    }

    /// Fast-forward `k` epochs under constant per-PE power:
    /// `Θ' = A^k Θ + (Σ_{i<k} A^i) B p`.
    ///
    /// Algebraically identical to `k` repeated [`RcModel::step`]s but a
    /// single O(n²) evaluation after the propagator is cached.
    /// Floating-point results differ from iterated stepping at rounding
    /// level (~1e-12 per step), so golden-guarded paths (the simulation
    /// kernel's lazy lane) replay per-epoch instead; this API serves
    /// DSE "what settles where" probes and long idle fast-forwards
    /// where that tolerance is acceptable.
    pub fn advance_const_power(
        &mut self,
        theta: &[f64],
        p: &[f64],
        k: usize,
    ) -> Vec<f64> {
        if k == 0 {
            return theta.to_vec();
        }
        debug_assert_eq!(theta.len(), self.n);
        debug_assert_eq!(p.len(), self.n_pes);
        let n = self.n;
        let n_pes = self.n_pes;
        let prop = self.propagator(k);
        let mut out = vec![0.0; n];
        for i in 0..n {
            let mut acc = 0.0;
            for (aij, th) in
                prop.a_k[i * n..(i + 1) * n].iter().zip(theta)
            {
                acc += aij * th;
            }
            for (bij, pw) in prop.s_k_b
                [i * n_pes..(i + 1) * n_pes]
                .iter()
                .zip(p)
            {
                acc += bij * pw;
            }
            out[i] = acc;
        }
        out
    }

    /// Steady-state above-ambient temperatures for constant power `p`:
    /// solves `G theta = M p` by Gaussian elimination with partial
    /// pivoting (the system is small: n <= a few dozen nodes).
    pub fn steady_state(&self, p: &[f64]) -> Vec<f64> {
        let n = self.n;
        // rhs = M p (inject PE powers into nodes).
        let mut rhs = vec![0.0f64; n];
        for (pe, &node) in self.pe_node.iter().enumerate() {
            rhs[node] += p[pe];
        }
        let mut m = self.g.clone();
        // Gaussian elimination.
        for col in 0..n {
            // Pivot.
            let mut piv = col;
            for r in col + 1..n {
                if m[r * n + col].abs() > m[piv * n + col].abs() {
                    piv = r;
                }
            }
            if piv != col {
                for c in 0..n {
                    m.swap(col * n + c, piv * n + c);
                }
                rhs.swap(col, piv);
            }
            let d = m[col * n + col];
            assert!(
                d.abs() > 1e-12,
                "singular thermal conductance matrix (node {col} floating?)"
            );
            for r in col + 1..n {
                let f = m[r * n + col] / d;
                if f == 0.0 {
                    continue;
                }
                for c in col..n {
                    m[r * n + c] -= f * m[col * n + c];
                }
                rhs[r] -= f * rhs[col];
            }
        }
        // Back substitution.
        let mut theta = vec![0.0f64; n];
        for row in (0..n).rev() {
            let mut acc = rhs[row];
            for c in row + 1..n {
                acc -= m[row * n + c] * theta[c];
            }
            theta[row] = acc / m[row * n + row];
        }
        theta
    }

    /// Effective `k1` folding the ambient offset into the leakage model
    /// (state is above-ambient): `k1_eff = k1 * exp(k2 * t_ambient)`.
    pub fn leak_k1_effective(&self, k1: f64, k2: f64) -> f64 {
        k1 * (k2 * self.t_ambient).exp()
    }

    /// Pad `A` to `rows x cols` (f32, row-major) for the AOT artifact:
    /// identity on padded diagonal entries so padded state stays inert.
    pub fn a_padded_f32(&self, rows: usize, cols: usize) -> Vec<f32> {
        assert!(rows >= self.n && cols >= self.n);
        let mut out = vec![0.0f32; rows * cols];
        for i in 0..self.n {
            for j in 0..self.n {
                out[i * cols + j] = self.a[i * self.n + j] as f32;
            }
        }
        for i in self.n..rows.min(cols) {
            out[i * cols + i] = 1.0;
        }
        out
    }

    /// Pad `B` to `rows x cols` (f32, row-major) for the AOT artifact.
    pub fn b_padded_f32(&self, rows: usize, cols: usize) -> Vec<f32> {
        assert!(rows >= self.n && cols >= self.n_pes);
        let mut out = vec![0.0f32; rows * cols];
        for i in 0..self.n {
            for j in 0..self.n_pes {
                out[i * cols + j] = self.b[i * self.n_pes + j] as f32;
            }
        }
        out
    }

    /// One-hot PE→node map padded to `rows x cols` (f32) for the artifact.
    pub fn pe_node_padded_f32(&self, rows: usize, cols: usize) -> Vec<f32> {
        assert!(rows >= self.n_pes && cols >= self.n);
        let mut out = vec![0.0f32; rows * cols];
        for (pe, &node) in self.pe_node.iter().enumerate() {
            out[pe * cols + node] = 1.0;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Platform;

    fn model() -> RcModel {
        RcModel::new(&Platform::table2_soc(), 10_000.0) // 10 ms epochs
    }

    #[test]
    fn zero_power_decays_to_ambient() {
        let m = model();
        let mut theta = vec![30.0; m.n];
        let p = vec![0.0; m.n_pes];
        for _ in 0..10_000 {
            theta = m.step(&theta, &p);
        }
        for &t in &theta {
            assert!(t.abs() < 0.1, "residual {t}");
        }
    }

    #[test]
    fn step_converges_to_steady_state() {
        let m = model();
        let p: Vec<f64> =
            (0..m.n_pes).map(|i| 0.5 + 0.1 * i as f64).collect();
        let ss = m.steady_state(&p);
        let mut theta = vec![0.0; m.n];
        for _ in 0..200_000 {
            theta = m.step(&theta, &p);
        }
        for (a, b) in theta.iter().zip(&ss) {
            assert!((a - b).abs() < 0.05, "step={a} ss={b}");
        }
    }

    #[test]
    fn steady_state_is_fixed_point() {
        let m = model();
        let p = vec![1.0; m.n_pes];
        let ss = m.steady_state(&p);
        let next = m.step(&ss, &p);
        for (a, b) in ss.iter().zip(&next) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn more_power_means_hotter() {
        let m = model();
        let lo = m.steady_state(&vec![0.5; m.n_pes]);
        let hi = m.steady_state(&vec![2.0; m.n_pes]);
        for (l, h) in lo.iter().zip(&hi) {
            assert!(h > l);
        }
        // Linearity: 4x power = 4x above-ambient temperature.
        for (l, h) in lo.iter().zip(&hi) {
            assert!((h / l - 4.0).abs() < 1e-9);
        }
    }

    #[test]
    fn heat_spreads_to_coupled_nodes() {
        let m = model();
        // Power only on PE 0 (big cluster, node 0).
        let mut p = vec![0.0; m.n_pes];
        p[0] = 3.0;
        let ss = m.steady_state(&p);
        assert!(ss[0] > ss[1]); // source hottest
        for (i, &t) in ss.iter().enumerate() {
            assert!(t > 0.0, "node {i} stayed cold");
        }
    }

    #[test]
    fn realistic_load_stays_sub_throttle() {
        // Full-tilt Table-2 SoC: the package must settle below ~60 °C
        // above ambient (i.e. < 90 °C absolute) — matches Odroid-XU3
        // behaviour without throttling at full fan.
        let m = model();
        let platform = Platform::table2_soc();
        let opps: Vec<_> = platform
            .clusters
            .iter()
            .map(|c| platform.classes[c.class].max_opp())
            .collect();
        let util = vec![1.0; m.n_pes];
        let temps = vec![60.0; m.n_pes];
        let p = crate::power::epoch_power(&platform, &opps, &util, &temps);
        let ss = m.steady_state(&p);
        let peak = ss.iter().copied().fold(0.0, f64::max);
        assert!(
            (20.0..70.0).contains(&peak),
            "peak above-ambient {peak} °C implausible"
        );
    }

    #[test]
    fn t_pe_maps_cluster_nodes() {
        let m = model();
        let platform = Platform::table2_soc();
        let theta: Vec<f64> = (0..m.n).map(|i| i as f64 * 10.0).collect();
        let t = m.t_pe(&theta);
        for pe in &platform.pes {
            let node = platform.clusters[pe.cluster].thermal_node;
            assert_eq!(t[pe.id], theta[node]);
        }
    }

    #[test]
    fn padded_matrices_embed_originals() {
        let m = model();
        let a = m.a_padded_f32(32, 32);
        for i in 0..m.n {
            for j in 0..m.n {
                assert!(
                    (a[i * 32 + j] as f64 - m.a[i * m.n + j]).abs() < 1e-6
                );
            }
        }
        // Padded diagonal is identity.
        for i in m.n..32 {
            assert_eq!(a[i * 32 + i], 1.0);
        }
        let b = m.b_padded_f32(32, 16);
        for i in 0..m.n {
            for j in 0..m.n_pes {
                assert!(
                    (b[i * 16 + j] as f64 - m.b[i * m.n_pes + j]).abs()
                        < 1e-6
                );
            }
        }
        let pn = m.pe_node_padded_f32(16, 32);
        for (pe, &node) in m.pe_node.iter().enumerate() {
            assert_eq!(pn[pe * 32 + node], 1.0);
            let row_sum: f32 = pn[pe * 32..(pe + 1) * 32].iter().sum();
            assert_eq!(row_sum, 1.0);
        }
    }

    #[test]
    fn step_into_matches_step() {
        let m = model();
        let theta: Vec<f64> = (0..m.n).map(|i| 5.0 + i as f64).collect();
        let p: Vec<f64> = (0..m.n_pes).map(|i| 0.2 * i as f64).collect();
        let a = m.step(&theta, &p);
        let mut b = vec![0.0; m.n];
        m.step_into(&theta, &p, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn propagator_advance_matches_iterated_steps() {
        let mut m = model();
        let theta0: Vec<f64> = (0..m.n).map(|i| 3.0 * i as f64).collect();
        let p: Vec<f64> =
            (0..m.n_pes).map(|i| 0.3 + 0.05 * i as f64).collect();
        for k in [1usize, 2, 7, 50] {
            let mut iter = theta0.clone();
            for _ in 0..k {
                iter = m.step(&iter, &p);
            }
            let fast = m.advance_const_power(&theta0, &p, k);
            for (a, b) in fast.iter().zip(&iter) {
                assert!(
                    (a - b).abs() < 1e-9,
                    "k={k}: fast={a} iter={b}"
                );
            }
        }
        // k = 0 is the identity.
        assert_eq!(m.advance_const_power(&theta0, &p, 0), theta0);
    }

    #[test]
    fn propagator_is_cached_per_step_count() {
        let mut m = model();
        let theta = vec![5.0; m.n];
        let p = vec![1.0; m.n_pes];
        let a = m.advance_const_power(&theta, &p, 12);
        // Second call hits the cache and must return identical bits.
        let b = m.advance_const_power(&theta, &p, 12);
        assert_eq!(a, b);
        assert_eq!(
            m.props.iter().filter(|(k, _)| *k == 12).count(),
            1,
            "duplicate cache entries"
        );
    }

    #[test]
    fn leak_k1_effective_folds_ambient() {
        let m = model();
        let k1 = 0.01;
        let k2 = 0.02;
        let eff = m.leak_k1_effective(k1, k2);
        // k1_eff * exp(k2 * theta) == k1 * exp(k2 * (theta + t_amb))
        let theta: f64 = 40.0;
        let lhs = eff * (k2 * theta).exp();
        let rhs = k1 * (k2 * (theta + m.t_ambient)).exp();
        assert!((lhs - rhs).abs() < 1e-12);
    }
}
