//! The deployable learned scheduler: [`IlSched`] (registry name `"il"`).
//!
//! Wraps a trained [`SoftmaxModel`] in the plug-and-play [`Scheduler`]
//! trait: per ready task it enumerates the candidate PEs, extracts the
//! documented feature vector for each, and commits the model's argmax —
//! protected by an **oracle-fallback guard**: any pick whose projected
//! finish time exceeds `guard_ratio ×` the best achievable finish is
//! overridden by the earliest-finish (oracle-style) choice and counted
//! as a fallback in [`crate::stats::SimReport::sched_fallbacks`].  The
//! guard bounds how badly a mistrained model can behave without ever
//! blocking a well-trained one.
//!
//! `sched::create("il", build)` loads the trained weights from the JSON
//! artifact at `SchedBuild::policy_path` (the `il_policy` config key /
//! `--il-policy` flag); with no path it falls back to the committed
//! pretrained preset baked into the binary from
//! `rust/data/il_policy.json`, so `--sched il` works out of the box.

use crate::sched::{
    Assignment, ReadyTask, SchedBuild, SchedContext, Scheduler,
};
use crate::util::json::Json;
use crate::Result;

use super::features::{candidates, features_into, FeatureCtx, N_FEATURES};
use super::model::SoftmaxModel;

/// The committed pretrained policy (see `rust/data/il_policy.json`):
/// hand-verified weights that reduce to the earliest-finish rule, so the
/// out-of-the-box `--sched il` behaves sanely on any platform.
pub const PRESET_POLICY: &str = include_str!("../../data/il_policy.json");

/// The decision rule shared by [`IlSched`] and the DAgger collector:
/// model argmax with the earliest-finish guard.  `fins` carries each
/// candidate's projected finish time; returns `(candidate index,
/// guard_fired)`.
pub fn choose_guarded(
    model: &SoftmaxModel,
    classes: &[u16],
    feats: &[f64],
    fins: &[f64],
) -> (usize, bool) {
    let mut best = (f64::INFINITY, 0usize);
    for (i, &f) in fins.iter().enumerate() {
        if f < best.0 {
            best = (f, i);
        }
    }
    let pick = model.predict(classes, feats);
    let f = fins[pick];
    if !f.is_finite() || f > model.guard_ratio * best.0 + 1e-9 {
        (best.1, true)
    } else {
        (pick, false)
    }
}

/// Imitation-learned scheduler (registry name `"il"`).
pub struct IlSched {
    model: SoftmaxModel,
    epochs: u64,
    decisions: u64,
    fallbacks: u64,
    // Reused per-epoch scratch.
    fc: FeatureCtx,
    cands: Vec<(usize, f64)>,
    fins: Vec<f64>,
    avail: Vec<f64>,
    classes: Vec<u16>,
    feats: Vec<f64>,
}

impl IlSched {
    pub fn new(model: SoftmaxModel) -> IlSched {
        IlSched {
            model,
            epochs: 0,
            decisions: 0,
            fallbacks: 0,
            fc: FeatureCtx::default(),
            cands: Vec::new(),
            fins: Vec::new(),
            avail: Vec::new(),
            classes: Vec::new(),
            feats: Vec::new(),
        }
    }

    /// Registry constructor: load the artifact at
    /// `build.policy_path`, or the committed preset when unset.
    pub fn from_build(build: &SchedBuild) -> Result<IlSched> {
        let model = match &build.policy_path {
            Some(p) => SoftmaxModel::load(p)?,
            None => SoftmaxModel::from_json(&Json::parse(PRESET_POLICY)?)?,
        };
        Ok(IlSched::new(model))
    }

    pub fn model(&self) -> &SoftmaxModel {
        &self.model
    }
}

impl Scheduler for IlSched {
    fn name(&self) -> &str {
        "il"
    }

    fn schedule(
        &mut self,
        ready: &[ReadyTask],
        ctx: &dyn SchedContext,
    ) -> Vec<Assignment> {
        self.epochs += 1;
        self.fc.refresh(ctx);
        let pes = ctx.pes();
        let now = ctx.now_us();
        self.avail.clear();
        self.avail.extend(pes.iter().map(|p| p.avail_us));
        let mut out = Vec::with_capacity(ready.len());
        for rt in ready {
            let best_exec = candidates(rt, ctx, &mut self.cands);
            if self.cands.is_empty() {
                continue; // currently unplaceable; retry next epoch
            }
            let k = self.cands.len();
            self.classes.clear();
            self.fins.clear();
            self.feats.clear();
            self.feats.resize(k * N_FEATURES, 0.0);
            for (i, &(pe_id, exec)) in self.cands.iter().enumerate() {
                let snap = &pes[pe_id];
                features_into(
                    rt,
                    ctx,
                    snap,
                    self.avail[pe_id],
                    exec,
                    best_exec,
                    &self.fc,
                    &mut self.feats[i * N_FEATURES..(i + 1) * N_FEATURES],
                );
                self.classes.push(snap.class as u16);
                self.fins.push(
                    self.avail[pe_id]
                        .max(ctx.data_ready_us(rt, pe_id))
                        .max(now)
                        + exec,
                );
            }
            let (pick, guarded) = choose_guarded(
                &self.model,
                &self.classes,
                &self.feats,
                &self.fins,
            );
            self.decisions += 1;
            if guarded {
                self.fallbacks += 1;
            }
            let (pe_id, _) = self.cands[pick];
            // Virtual availability advances to the projected finish
            // (data-ready wait included) so several same-epoch tasks
            // spread — the same convention ETF/HEFT use.
            self.avail[pe_id] = self.fins[pick];
            out.push(Assignment { job: rt.job, task: rt.task, pe: pe_id });
        }
        out
    }

    fn report(&self) -> Vec<String> {
        vec![format!(
            "il: {} epochs, {} decisions, {} guard fallbacks \
             (oracle '{}', guard {:.2})",
            self.epochs,
            self.decisions,
            self.fallbacks,
            self.model.oracle,
            self.model.guard_ratio
        )]
    }

    fn decision_counts(&self) -> (u64, u64) {
        (self.decisions, self.fallbacks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::{rt, MockCtx};

    fn preset() -> SoftmaxModel {
        SoftmaxModel::from_json(&Json::parse(PRESET_POLICY).unwrap())
            .unwrap()
    }

    #[test]
    fn committed_preset_parses_and_roundtrips() {
        let m = preset();
        assert!(m.n_classes >= 1);
        assert!(m.guard_ratio >= 1.0);
        let back = SoftmaxModel::from_json(
            &Json::parse(&m.to_json().to_string_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn preset_prefers_earliest_finish() {
        // PE 0: exec 10 but busy until t=100 -> finish 110.
        // PE 1: exec 40, idle -> finish 40.  The preset must pick PE 1
        // (it encodes the earliest-finish rule).
        let mut ctx = MockCtx::uniform(2, 0.0);
        ctx.set_exec(0, 0, 0, 10.0);
        ctx.set_exec(0, 0, 1, 40.0);
        ctx.pes[0].avail_us = 100.0;
        let mut s = IlSched::new(preset());
        let a = s.schedule(&[rt(0, 0)], &ctx);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].pe, 1);
        assert_eq!(s.decision_counts().0, 1);
    }

    #[test]
    fn guard_overrides_a_bad_model() {
        // A model that *prefers* late finishes (positive weight on the
        // finish feature) with a tight guard: every decision falls back
        // to the earliest-finish choice.
        let mut m = SoftmaxModel::zeros(1, "etf");
        m.weights[5] = 1.0; // log_finish_us
        m.guard_ratio = 1.0;
        let mut ctx = MockCtx::uniform(2, 0.0);
        ctx.set_exec(0, 0, 0, 10.0);
        ctx.set_exec(0, 0, 1, 500.0);
        let mut s = IlSched::new(m);
        let a = s.schedule(&[rt(0, 0)], &ctx);
        assert_eq!(a[0].pe, 0, "guard must reroute to earliest finish");
        let (dec, fb) = s.decision_counts();
        assert_eq!((dec, fb), (1, 1));
    }

    #[test]
    fn never_assigns_to_unavailable_pes_and_spreads_batches() {
        let mut ctx = MockCtx::uniform(2, 0.0);
        for t in 0..4 {
            ctx.set_exec(0, t, 0, 10.0);
            ctx.set_exec(0, t, 1, 10.0);
        }
        let mut s = IlSched::new(preset());
        let tasks: Vec<_> = (0..4).map(|t| rt(0, t)).collect();
        let a = s.schedule(&tasks, &ctx);
        assert_eq!(a.len(), 4);
        // Virtual availability spreads equal work over equal PEs.
        assert_eq!(a.iter().filter(|x| x.pe == 0).count(), 2);
        assert_eq!(a.iter().filter(|x| x.pe == 1).count(), 2);

        ctx.pes[0].available = false;
        let mut s = IlSched::new(preset());
        let a = s.schedule(&tasks, &ctx);
        assert!(a.iter().all(|x| x.pe == 1));
        ctx.pes[1].available = false;
        let mut s = IlSched::new(preset());
        assert!(s.schedule(&tasks, &ctx).is_empty());
    }

    #[test]
    fn unsupported_tasks_are_skipped() {
        let mut ctx = MockCtx::uniform(2, 0.0);
        ctx.set_exec(0, 0, 0, 5.0);
        let mut s = IlSched::new(preset());
        let a = s.schedule(&[rt(0, 0), rt(0, 1)], &ctx);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].task, 0);
    }
}
