//! Guided design-space exploration: multi-objective search over the
//! hardware configuration space.
//!
//! The paper positions DS3 as enabling "both design space exploration
//! and dynamic resource management"; the journal version (Arda et al.,
//! arXiv:2003.09016) demonstrates DSE over PE counts and frequency
//! domains as a first-class use case.  This module turns the simulator
//! into that search engine:
//!
//! * [`genome`] — a **platform genome**: per-cluster PE counts, enabled
//!   OPP subsets, NoC hop latency / link bandwidth, and an optional
//!   DTPM power budget, with validated decode into a
//!   [`crate::platform::Platform`] and mutation/crossover operators.
//! * [`eval`] — a parallel, caching **evaluation layer**: each genome
//!   runs a `seeds × scenarios` simulation grid fanned out over OS
//!   threads (via [`crate::coordinator::parallel_map`]), with results
//!   cached by canonical genome encoding so revisited designs are free.
//! * [`archive`] — a **Pareto-front archive** of non-dominated designs
//!   plus a hypervolume proxy (a front-shape diagnostic; see
//!   [`archive`] docs) and monotone best-per-objective tracking.
//! * [`search`] — the **search loop**: NSGA-II-style evolutionary
//!   optimization (non-dominated sorting, crowding distance, binary
//!   tournaments) or pure random search, with JSON
//!   **checkpoint/resume** that round-trips the archive, population,
//!   evaluation cache, and RNG state — a resumed search continues
//!   bit-identically to an uninterrupted one.
//!
//! Objectives (all minimized): average job latency (with a completion
//! penalty for saturated designs), energy per job, and peak
//! temperature.  Drive it from the CLI (`ds3r dse run|resume|front|
//! export`) or programmatically (`examples/design_space.rs`):
//!
//! ```no_run
//! use ds3r::dse::{DseConfig, DseEngine};
//! use ds3r::platform::Platform;
//! use ds3r::telemetry::{JsonlSink, Telemetry};
//! use std::sync::Arc;
//!
//! let mut cfg = DseConfig::default();
//! cfg.population = 16;
//! cfg.generations = 13;           // 16 + 13x16 = 224 evaluations
//! let apps = vec![ds3r::app::suite::wifi_tx(Default::default())];
//! let mut engine = DseEngine::new(Platform::table2_soc(), cfg).unwrap();
//! // Per-generation progress is a telemetry stream, not print lines:
//! // each generation emits a deterministic `dse_generation` JSONL
//! // record (archive size, hypervolume proxy, cache hits).
//! engine.set_telemetry(Telemetry::new(Arc::new(JsonlSink::stderr())));
//! engine.run(&apps, None, |_| ()).unwrap();
//! let best = engine.archive().entries().len();
//! assert!(best > 0);
//! ```

pub mod archive;
pub mod eval;
pub mod genome;
pub mod search;

pub use archive::{dominates, DesignPoint, ParetoArchive};
pub use eval::{EvalMetrics, Evaluator};
pub use genome::{GenomeSpace, PlatformGenome};
pub use search::DseEngine;

use crate::config::SimConfig;
use crate::util::json::{u64_from_json, u64_to_json, Json};
use crate::{Error, Result};

/// An optimization objective (minimized).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Mean job latency (µs), penalized for incomplete offered load —
    /// see `EvalMetrics::objective`.
    Latency,
    /// Energy per completed job (mJ).
    Energy,
    /// Peak absolute node temperature (°C).
    PeakTemp,
}

impl Objective {
    pub fn parse(s: &str) -> Result<Objective> {
        match s {
            "latency" => Ok(Objective::Latency),
            "energy" => Ok(Objective::Energy),
            "peak_temp" | "peak-temp" | "temp" => Ok(Objective::PeakTemp),
            other => Err(Error::Config(format!(
                "unknown objective '{other}' (latency, energy, peak_temp)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Energy => "energy",
            Objective::PeakTemp => "peak_temp",
        }
    }

    /// Column header for front tables.
    pub fn unit(&self) -> &'static str {
        match self {
            Objective::Latency => "us",
            Objective::Energy => "mJ/job",
            Objective::PeakTemp => "C",
        }
    }
}

/// Full configuration of a DSE run: search budget and operators, genome
/// bounds, evaluation grid, and the base `SimConfig` every evaluation
/// inherits.  JSON round-trips (`ds3r dse run --dse-config file.json`);
/// missing keys keep their defaults, and [`DseConfig::from_json`]
/// validates on the way in via [`Error::Config`].
#[derive(Debug, Clone)]
pub struct DseConfig {
    /// `nsga2` (guided evolutionary search) or `random` (baseline).
    pub algorithm: String,
    /// 1-3 distinct objectives; the Pareto front lives in this space.
    pub objectives: Vec<Objective>,
    /// Candidate designs per generation.
    pub population: usize,
    /// Evolutionary generations after the seeded initial population
    /// (total evaluations = `population * (generations + 1)`).
    pub generations: usize,
    /// Workload seeds each design is evaluated under (aggregated by
    /// mean — robustness across stochastic arrivals).
    pub seeds: Vec<u64>,
    /// Scenario presets / files each design is additionally evaluated
    /// under (empty = one static run per seed).
    pub scenarios: Vec<String>,
    /// Per-gene mutation probability.
    pub mutation_rate: f64,
    /// Probability an offspring is produced by crossover.
    pub crossover_rate: f64,
    /// Seed of the search's own RNG stream (genome sampling, variation
    /// operators) — independent from workload seeds.
    pub search_seed: u64,
    /// Genome bounds: PE instances per cluster.
    pub min_pes_per_cluster: usize,
    pub max_pes_per_cluster: usize,
    /// Genome bounds: NoC genes.
    pub hop_latency_range: (f64, f64),
    pub link_bandwidth_range: (f64, f64),
    /// Genome bounds: DTPM power budget (W); `explore_power_budget =
    /// false` pins the gene to "uncapped".
    pub power_budget_range: (f64, f64),
    pub explore_power_budget: bool,
    /// Base simulation config for every evaluation (scheduler, rate,
    /// jobs, DTPM policy...).  `seed` and `scenario` are overridden per
    /// grid point; `dtpm.power_cap_w` is overridden when the genome
    /// carries a budget.
    pub sim: SimConfig,
    /// Evaluation threads (0 = all available cores).
    pub threads: usize,
}

impl Default for DseConfig {
    fn default() -> Self {
        let mut sim = SimConfig::default();
        // DSE evaluations favour many medium-length runs: enough jobs
        // for stable steady-state means, a hard sim-time wall so
        // saturated designs terminate quickly (they pay the completion
        // penalty instead of burning wall clock).
        sim.injection_rate_per_ms = 4.0;
        sim.max_jobs = 300;
        sim.warmup_jobs = 30;
        sim.max_sim_us = 4_000_000.0;
        DseConfig {
            algorithm: "nsga2".into(),
            objectives: vec![Objective::Latency, Objective::Energy],
            population: 16,
            generations: 13,
            seeds: vec![1],
            scenarios: Vec::new(),
            mutation_rate: 0.35,
            crossover_rate: 0.9,
            search_seed: 7,
            min_pes_per_cluster: 1,
            max_pes_per_cluster: 8,
            hop_latency_range: (0.02, 0.2),
            link_bandwidth_range: (2000.0, 16000.0),
            power_budget_range: (3.0, 10.0),
            explore_power_budget: true,
            sim,
            threads: 0,
        }
    }
}

impl DseConfig {
    /// Resolved evaluation thread count.
    pub fn eval_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            crate::util::default_threads()
        }
    }

    /// Total genome evaluations the configured budget requests.
    pub fn budget_evals(&self) -> usize {
        self.population * (self.generations + 1)
    }

    pub fn validate(&self) -> Result<()> {
        if !matches!(self.algorithm.as_str(), "nsga2" | "random") {
            return Err(Error::Config(format!(
                "unknown DSE algorithm '{}' (nsga2, random)",
                self.algorithm
            )));
        }
        if self.objectives.is_empty() || self.objectives.len() > 3 {
            return Err(Error::Config(
                "objectives must list 1-3 of latency, energy, peak_temp"
                    .into(),
            ));
        }
        for (i, a) in self.objectives.iter().enumerate() {
            if self.objectives[i + 1..].contains(a) {
                return Err(Error::Config(format!(
                    "duplicate objective '{}'",
                    a.name()
                )));
            }
        }
        if self.population < 2 {
            return Err(Error::Config(
                "population must be >= 2".into(),
            ));
        }
        if !(0.0..=1.0).contains(&self.mutation_rate)
            || self.mutation_rate == 0.0
        {
            return Err(Error::Config(
                "mutation_rate must be in (0, 1]".into(),
            ));
        }
        if !(0.0..=1.0).contains(&self.crossover_rate) {
            return Err(Error::Config(
                "crossover_rate must be in [0, 1]".into(),
            ));
        }
        if self.seeds.is_empty() {
            return Err(Error::Config(
                "seeds must list at least one workload seed".into(),
            ));
        }
        if self.min_pes_per_cluster == 0
            || self.max_pes_per_cluster < self.min_pes_per_cluster
        {
            return Err(Error::Config(format!(
                "bad PE-count bounds [{}, {}]",
                self.min_pes_per_cluster, self.max_pes_per_cluster
            )));
        }
        for ((lo, hi), name) in [
            (self.hop_latency_range, "hop_latency_range"),
            (self.link_bandwidth_range, "link_bandwidth_range"),
            (self.power_budget_range, "power_budget_range"),
        ] {
            if !(lo > 0.0 && hi >= lo) {
                return Err(Error::Config(format!(
                    "bad {name} [{lo}, {hi}]"
                )));
            }
        }
        self.sim.validate()
    }

    // ---- JSON ------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let range = |(lo, hi): (f64, f64)| {
            Json::Arr(vec![Json::Num(lo), Json::Num(hi)])
        };
        let mut j = Json::obj();
        j.set("algorithm", Json::Str(self.algorithm.clone()))
            .set(
                "objectives",
                Json::Arr(
                    self.objectives
                        .iter()
                        .map(|o| Json::Str(o.name().into()))
                        .collect(),
                ),
            )
            .set("population", Json::Num(self.population as f64))
            .set("generations", Json::Num(self.generations as f64))
            .set(
                "seeds",
                Json::Arr(self.seeds.iter().map(|&s| u64_to_json(s)).collect()),
            )
            .set(
                "scenarios",
                Json::Arr(
                    self.scenarios
                        .iter()
                        .map(|s| Json::Str(s.clone()))
                        .collect(),
                ),
            )
            .set("mutation_rate", Json::Num(self.mutation_rate))
            .set("crossover_rate", Json::Num(self.crossover_rate))
            .set("search_seed", u64_to_json(self.search_seed))
            .set(
                "min_pes_per_cluster",
                Json::Num(self.min_pes_per_cluster as f64),
            )
            .set(
                "max_pes_per_cluster",
                Json::Num(self.max_pes_per_cluster as f64),
            )
            .set("hop_latency_range", range(self.hop_latency_range))
            .set("link_bandwidth_range", range(self.link_bandwidth_range))
            .set("power_budget_range", range(self.power_budget_range))
            .set(
                "explore_power_budget",
                Json::Bool(self.explore_power_budget),
            )
            .set("sim", self.sim.to_json())
            .set("threads", Json::Num(self.threads as f64));
        j
    }

    /// Parse from JSON; missing keys keep their defaults.  Validates.
    pub fn from_json(j: &Json) -> Result<DseConfig> {
        let mut c = DseConfig::default();
        if let Some(s) = j.get("algorithm").and_then(Json::as_str) {
            c.algorithm = s.to_string();
        }
        if let Some(a) = j.get("objectives").and_then(Json::as_arr) {
            c.objectives = a
                .iter()
                .map(|v| {
                    v.as_str()
                        .ok_or_else(|| {
                            Error::Config(
                                "objectives must be strings".into(),
                            )
                        })
                        .and_then(Objective::parse)
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(x) = j.get("population").and_then(Json::as_usize) {
            c.population = x;
        }
        if let Some(x) = j.get("generations").and_then(Json::as_usize) {
            c.generations = x;
        }
        if let Some(a) = j.get("seeds").and_then(Json::as_arr) {
            c.seeds = a
                .iter()
                .map(|v| {
                    u64_from_json(v).ok_or_else(|| {
                        Error::Config(format!(
                            "seeds: bad entry {}",
                            v.to_string()
                        ))
                    })
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(a) = j.get("scenarios").and_then(Json::as_arr) {
            c.scenarios = a
                .iter()
                .map(|v| {
                    v.as_str().map(String::from).ok_or_else(|| {
                        Error::Config(
                            "scenarios entries must be strings".into(),
                        )
                    })
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(x) = j.get("mutation_rate").and_then(Json::as_f64) {
            c.mutation_rate = x;
        }
        if let Some(x) = j.get("crossover_rate").and_then(Json::as_f64) {
            c.crossover_rate = x;
        }
        if let Some(v) = j.get("search_seed") {
            c.search_seed = u64_from_json(v).ok_or_else(|| {
                Error::Config("search_seed must be a non-negative integer \
                               (number or decimal string)".into())
            })?;
        }
        if let Some(x) =
            j.get("min_pes_per_cluster").and_then(Json::as_usize)
        {
            c.min_pes_per_cluster = x;
        }
        if let Some(x) =
            j.get("max_pes_per_cluster").and_then(Json::as_usize)
        {
            c.max_pes_per_cluster = x;
        }
        let parse_range = |key: &str, default: (f64, f64)| -> Result<(f64, f64)> {
            match j.get(key) {
                None => Ok(default),
                Some(v) => {
                    let xs = v.f64_vec()?;
                    if xs.len() != 2 {
                        return Err(Error::Config(format!(
                            "{key} must be [lo, hi]"
                        )));
                    }
                    Ok((xs[0], xs[1]))
                }
            }
        };
        c.hop_latency_range =
            parse_range("hop_latency_range", c.hop_latency_range)?;
        c.link_bandwidth_range =
            parse_range("link_bandwidth_range", c.link_bandwidth_range)?;
        c.power_budget_range =
            parse_range("power_budget_range", c.power_budget_range)?;
        if let Some(b) =
            j.get("explore_power_budget").and_then(Json::as_bool)
        {
            c.explore_power_budget = b;
        }
        if let Some(sim) = j.get("sim") {
            c.sim = SimConfig::from_json(sim)?;
        }
        if let Some(x) = j.get("threads").and_then(Json::as_usize) {
            c.threads = x;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &std::path::Path) -> Result<DseConfig> {
        DseConfig::from_json(&Json::parse_file(path)?)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid_and_meets_the_budget_floor() {
        let c = DseConfig::default();
        c.validate().unwrap();
        assert!(c.budget_evals() >= 200, "{}", c.budget_evals());
        assert!(c.eval_threads() >= 1);
    }

    #[test]
    fn objective_parse_and_names() {
        assert_eq!(Objective::parse("latency").unwrap(), Objective::Latency);
        assert_eq!(Objective::parse("energy").unwrap(), Objective::Energy);
        assert_eq!(
            Objective::parse("peak_temp").unwrap(),
            Objective::PeakTemp
        );
        assert_eq!(
            Objective::parse("peak-temp").unwrap(),
            Objective::PeakTemp
        );
        assert!(Objective::parse("carbon").is_err());
        for o in [Objective::Latency, Objective::Energy, Objective::PeakTemp]
        {
            assert_eq!(Objective::parse(o.name()).unwrap(), o);
            assert!(!o.unit().is_empty());
        }
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let mut c = DseConfig::default();
        c.algorithm = "random".into();
        c.objectives =
            vec![Objective::Energy, Objective::PeakTemp, Objective::Latency];
        c.population = 10;
        c.generations = 4;
        c.seeds = vec![3, 5, u64::MAX]; // u64::MAX exercises the string path
        c.scenarios = vec!["bursty-wifi".into()];
        c.mutation_rate = 0.5;
        c.crossover_rate = 0.75;
        c.search_seed = (1u64 << 53) + 3; // exercises the string path
        c.min_pes_per_cluster = 2;
        c.max_pes_per_cluster = 6;
        c.hop_latency_range = (0.03, 0.15);
        c.link_bandwidth_range = (4000.0, 12000.0);
        c.power_budget_range = (4.0, 8.0);
        c.explore_power_budget = false;
        c.sim.scheduler = "met".into();
        c.sim.max_jobs = 123;
        c.sim.warmup_jobs = 12;
        c.threads = 3;
        let j = Json::parse(&c.to_json().to_string()).unwrap();
        let c2 = DseConfig::from_json(&j).unwrap();
        assert_eq!(c2.algorithm, c.algorithm);
        assert_eq!(c2.objectives, c.objectives);
        assert_eq!(c2.population, c.population);
        assert_eq!(c2.generations, c.generations);
        assert_eq!(c2.seeds, c.seeds);
        assert_eq!(c2.scenarios, c.scenarios);
        assert_eq!(c2.mutation_rate, c.mutation_rate);
        assert_eq!(c2.crossover_rate, c.crossover_rate);
        assert_eq!(c2.search_seed, c.search_seed);
        assert_eq!(c2.min_pes_per_cluster, c.min_pes_per_cluster);
        assert_eq!(c2.max_pes_per_cluster, c.max_pes_per_cluster);
        assert_eq!(c2.hop_latency_range, c.hop_latency_range);
        assert_eq!(c2.link_bandwidth_range, c.link_bandwidth_range);
        assert_eq!(c2.power_budget_range, c.power_budget_range);
        assert_eq!(c2.explore_power_budget, c.explore_power_budget);
        assert_eq!(c2.sim.scheduler, "met");
        assert_eq!(c2.sim.max_jobs, 123);
        assert_eq!(c2.threads, 3);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let j = Json::parse(r#"{"population": 8}"#).unwrap();
        let c = DseConfig::from_json(&j).unwrap();
        assert_eq!(c.population, 8);
        assert_eq!(c.generations, DseConfig::default().generations);
        assert_eq!(c.objectives, DseConfig::default().objectives);
    }

    #[test]
    fn rejects_bad_configs() {
        let mut c = DseConfig::default();
        c.algorithm = "annealing".into();
        assert!(c.validate().is_err());

        let mut c = DseConfig::default();
        c.objectives = vec![];
        assert!(c.validate().is_err());

        let mut c = DseConfig::default();
        c.objectives = vec![Objective::Latency, Objective::Latency];
        assert!(c.validate().is_err());

        let mut c = DseConfig::default();
        c.population = 1;
        assert!(c.validate().is_err());

        let mut c = DseConfig::default();
        c.mutation_rate = 0.0;
        assert!(c.validate().is_err());

        let mut c = DseConfig::default();
        c.crossover_rate = 1.5;
        assert!(c.validate().is_err());

        let mut c = DseConfig::default();
        c.seeds = vec![];
        assert!(c.validate().is_err());

        let mut c = DseConfig::default();
        c.min_pes_per_cluster = 5;
        c.max_pes_per_cluster = 2;
        assert!(c.validate().is_err());

        let mut c = DseConfig::default();
        c.hop_latency_range = (0.2, 0.02);
        assert!(c.validate().is_err());

        // Bad range shape in JSON.
        let j = Json::parse(r#"{"hop_latency_range": [1]}"#).unwrap();
        assert!(DseConfig::from_json(&j).is_err());
    }
}
