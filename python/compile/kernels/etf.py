"""L1 Pallas kernel: ETF earliest-finish-time matrix.

The ETF scheduler (Blythe et al., the paper's best performer in Fig. 3)
evaluates, for every (ready task i, PE j) pair,

    finish[i, j] = max(avail[j], ready[i, j]) + exec[i, j]

and picks the global minimum.  For large ready lists this I×J sweep is the
scheduling hot-spot; DS3R offers an XLA-accelerated variant (`etf-xla`)
that evaluates the whole matrix plus the per-task argmin reduction in one
AOT-compiled call.

Fixed AOT contract (DESIGN.md §5): I = 64 ready-task slots, J = 16 PE
slots; rust pads unsupported (task, PE) pairs with +inf exec so they never
win the argmin.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I = 64  # max ready tasks per scheduler invocation (padded)
J = 16  # max PEs (padded; Table-2 platform uses 14)


def _etf_kernel(avail_ref, ready_ref, exec_ref, fin_ref, best_pe_ref,
                best_fin_ref):
    avail = avail_ref[...]          # [1, J]
    ready = ready_ref[...]          # [I, J]
    exe = exec_ref[...]             # [I, J]

    fin = jnp.maximum(avail, ready) + exe          # [I, J] broadcast on rows
    fin_ref[...] = fin

    # Per-task argmin over PEs. Keep everything 2-D: Mosaic vectorizes
    # lane-dimension reductions; iota over the lane dim gives the index.
    best = jnp.min(fin, axis=1, keepdims=True)                    # [I, 1]
    idx = jax.lax.broadcasted_iota(jnp.float32, (I, J), 1)        # [I, J]
    # First PE achieving the min (ties -> lowest index, matching rust ETF).
    masked = jnp.where(fin <= best, idx, jnp.float32(J))
    best_pe_ref[...] = jnp.min(masked, axis=1, keepdims=True)     # [I, 1]
    best_fin_ref[...] = best


@functools.partial(jax.jit, static_argnames=())
def etf_matrix(avail, ready, exec_):
    """Earliest-finish-time matrix + per-task best PE.

    Args:
      avail: [1, J] earliest time each PE becomes free (µs).
      ready: [I, J] time task i's input data is available at PE j (µs).
      exec_: [I, J] execution latency of task i on PE j (µs; +inf if
        task i cannot run on PE j).

    Returns:
      (finish [I, J], best_pe [I, 1] (f32 index), best_finish [I, 1])
    """
    out_shapes = (
        jax.ShapeDtypeStruct((I, J), jnp.float32),
        jax.ShapeDtypeStruct((I, 1), jnp.float32),
        jax.ShapeDtypeStruct((I, 1), jnp.float32),
    )
    return pl.pallas_call(
        _etf_kernel,
        out_shape=out_shapes,
        interpret=True,
    )(avail, ready, exec_)
