//! Design-space exploration coordinator.
//!
//! "It allows the end user to evaluate workload scenarios exhaustively by
//! sweeping the configuration space to determine the most suitable
//! scheduling algorithm for a given SoC architecture" (paper §3).
//!
//! [`run_sweep`] fans simulation points (scheduler × injection rate ×
//! seed) out over OS threads — each point is an independent
//! [`Simulation`], so the sweep scales linearly with cores.  Helpers
//! assemble the Figure-3 experiment and the hardware-validation
//! comparison from sweep results.
//!
//! The underlying fan-out primitive, [`parallel_map`], is shared with
//! the guided design-space exploration engine ([`crate::dse`]): results
//! land in input order regardless of thread interleaving, which is what
//! makes parallel sweeps and DSE generations bit-identical to their
//! serial counterparts.
//!
//! Telemetry: the `*_with` sweep variants take a
//! [`Telemetry`](crate::telemetry::Telemetry) handle, stream
//! [`SweepProgress`](crate::telemetry::Event::SweepProgress) while the
//! grid runs, and aggregate per-run
//! [`Counters`](crate::telemetry::Counters) through
//! [`parallel_map_pooled_counted`], whose input-order fold makes the
//! aggregate independent of thread count.
//!
//! Experiment store: [`run_sweep_stored`] adds the cache-consult hook
//! — with a [`StoreCtx`](crate::store::StoreCtx) it loads
//! already-computed points from the on-disk point cache, simulates
//! only the missing subset, and merges everything back in input
//! order, preserving both byte-identity contracts (report bytes and
//! aggregated counters) for warm, partial and cold runs alike.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, PoisonError};

use crate::app::AppGraph;
use crate::config::SimConfig;
use crate::platform::Platform;
use crate::scenario::Scenario;
use crate::sim::{SimSetup, SimWorker, Simulation};
use crate::stats::{FailureReport, PhaseStats, SimReport};
use crate::store::{PointEntry, StoreCtx};
use crate::telemetry::{Counters, Event, SpanTimer, Telemetry};
use crate::util::json::{u64_from_json, u64_to_json, Json};
use crate::util::plot::Series;
use crate::{Error, Result};

/// Verdict of one pooled grid point.  [`parallel_map_pooled_outcomes`]
/// produces these in input order: a panicking point is contained as
/// [`PointOutcome::Panicked`] (never a process abort), a point whose
/// simulation tripped its deterministic step budget comes back
/// [`PointOutcome::TimedOut`], and ordinary failures stay
/// [`PointOutcome::Error`].  Campaign drivers either convert failures
/// to hard errors ([`FailPolicy::Abort`]) or quarantine them into a
/// [`FailureReport`] and keep the healthy points
/// ([`FailPolicy::Quarantine`]).
#[derive(Debug)]
pub enum PointOutcome<R> {
    Ok(R),
    /// The point's closure panicked; the worker that ran it was
    /// discarded and rebuilt before the pool continued.
    Panicked { msg: String },
    /// The simulation exhausted its deterministic step budget
    /// ([`SimConfig::step_budget`]).
    TimedOut { steps: u64 },
    Error(Error),
}

impl<R> PointOutcome<R> {
    pub fn from_result(r: Result<R>) -> PointOutcome<R> {
        match r {
            Ok(v) => PointOutcome::Ok(v),
            Err(e) => PointOutcome::Error(e),
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, PointOutcome::Ok(_))
    }

    pub fn ok(self) -> Option<R> {
        match self {
            PointOutcome::Ok(v) => Some(v),
            _ => None,
        }
    }

    /// Map the success value, preserving failure verdicts.
    pub fn map<S>(self, f: impl FnOnce(R) -> S) -> PointOutcome<S> {
        match self {
            PointOutcome::Ok(v) => PointOutcome::Ok(f(v)),
            PointOutcome::Panicked { msg } => {
                PointOutcome::Panicked { msg }
            }
            PointOutcome::TimedOut { steps } => {
                PointOutcome::TimedOut { steps }
            }
            PointOutcome::Error(e) => PointOutcome::Error(e),
        }
    }

    /// Collapse to a plain [`Result`] (the abort-policy view).
    pub fn into_result(self) -> Result<R> {
        match self {
            PointOutcome::Ok(v) => Ok(v),
            PointOutcome::Panicked { msg } => {
                Err(Error::Sim(format!("worker panicked: {msg}")))
            }
            PointOutcome::TimedOut { steps } => Err(Error::Sim(format!(
                "watchdog: step budget exhausted after {steps} steps"
            ))),
            PointOutcome::Error(e) => Err(e),
        }
    }

    /// Failure class for [`FailureReport`] rows (`None` for `Ok`).
    pub fn failure_kind(&self) -> Option<&'static str> {
        match self {
            PointOutcome::Ok(_) => None,
            PointOutcome::Panicked { .. } => Some("panic"),
            PointOutcome::TimedOut { .. } => Some("timeout"),
            PointOutcome::Error(_) => Some("error"),
        }
    }

    /// Human detail for [`FailureReport`] rows (empty for `Ok`).
    pub fn failure_detail(&self) -> String {
        match self {
            PointOutcome::Ok(_) => String::new(),
            PointOutcome::Panicked { msg } => msg.clone(),
            PointOutcome::TimedOut { steps } => {
                format!("step budget exhausted after {steps} steps")
            }
            PointOutcome::Error(e) => e.to_string(),
        }
    }
}

/// What a campaign does with failed grid points.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailPolicy {
    /// The first failure aborts the whole campaign with a hard error
    /// (the pre-fault-isolation behaviour, and still the default).
    Abort,
    /// Failed points are dropped from the results and recorded in a
    /// [`FailureReport`]; healthy points complete normally.  With
    /// `max_failures` set, exceeding that budget aborts after all —
    /// the guard against a systematically broken campaign silently
    /// quarantining everything.
    Quarantine { max_failures: Option<usize> },
}

impl FailPolicy {
    /// Parse the `--fail-policy` grammar:
    /// `abort | quarantine | quarantine:N`.
    pub fn parse(s: &str) -> Result<FailPolicy> {
        match s {
            "abort" => Ok(FailPolicy::Abort),
            "quarantine" => {
                Ok(FailPolicy::Quarantine { max_failures: None })
            }
            _ => s
                .strip_prefix("quarantine:")
                .and_then(|n| n.parse::<usize>().ok())
                .map(|n| FailPolicy::Quarantine {
                    max_failures: Some(n),
                })
                .ok_or_else(|| {
                    Error::Config(format!(
                        "bad fail policy '{s}' (expected abort, \
                         quarantine or quarantine:N)"
                    ))
                }),
        }
    }

    pub fn is_quarantine(&self) -> bool {
        matches!(self, FailPolicy::Quarantine { .. })
    }
}

/// Render a caught panic payload (the `&str`/`String` cases cover
/// `panic!` literals and formatted messages).
fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Lock the shared slot table, shrugging off poisoning: panics in `f`
/// are already contained by `catch_unwind`, and the table is plain
/// data with no invariant a stray unwind could break — recovering via
/// [`PoisonError::into_inner`] keeps one panic from cascading into a
/// second opaque panic at join time.
fn lock_slots<R>(
    m: &Mutex<Vec<Option<PointOutcome<R>>>>,
) -> std::sync::MutexGuard<'_, Vec<Option<PointOutcome<R>>>> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Fault-isolated worker-pool fan-out — the core primitive under
/// [`parallel_map_pooled`].  Every `f` call runs under
/// `catch_unwind`: a panicking point is recorded as
/// [`PointOutcome::Panicked`] in its input slot, the thread's pinned
/// state is **discarded and rebuilt via `init`** (a panicked
/// [`SimWorker`] may hold arbitrarily corrupt simulation state and is
/// never reused), and the pool moves on to the next item — one bad
/// point can no longer take down a multi-hour campaign.
///
/// Determinism contract: outcomes land in input slots, and every
/// verdict (including which points failed and with what message) is a
/// function of `(index, item)` alone, so a degraded 1-thread run is
/// bit-identical to a degraded 8-thread run.
pub fn parallel_map_pooled_outcomes<T, R, W, I, F>(
    items: &[T],
    threads: usize,
    init: I,
    f: F,
) -> Vec<PointOutcome<R>>
where
    T: Sync,
    R: Send,
    I: Fn() -> W + Sync,
    F: Fn(&mut W, usize, &T) -> PointOutcome<R> + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<PointOutcome<R>>>> =
        Mutex::new((0..items.len()).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let out = match catch_unwind(AssertUnwindSafe(
                        || f(&mut state, i, &items[i]),
                    )) {
                        Ok(out) => out,
                        Err(payload) => {
                            // Poisoned-worker replacement: whatever
                            // the panic left behind is untrusted.
                            state = init();
                            PointOutcome::Panicked {
                                msg: panic_message(payload),
                            }
                        }
                    };
                    lock_slots(&results)[i] = Some(out);
                }
            });
        }
    });
    results
        .into_inner()
        .unwrap_or_else(PoisonError::into_inner)
        .into_iter()
        .map(|r| {
            r.unwrap_or_else(|| {
                PointOutcome::Error(Error::Internal(
                    "fan-out slot left unfilled".into(),
                ))
            })
        })
        .collect()
}

/// Worker-pool fan-out: run `f` over `items` on up to `threads` OS
/// threads, returning results in input order.  Each spawned thread
/// calls `init` exactly once and *pins* the returned state for its
/// whole lifetime, threading it into every `f` call it executes — the
/// primitive behind reusable-[`SimWorker`](crate::sim::SimWorker)
/// grids (`run_sweep`, `run_scenario_sweep`, the DSE evaluator, the
/// learn pipeline), where the pinned state is an `Option<SimWorker>`
/// reset per item instead of rebuilt.
///
/// Determinism contract: an atomic work index hands items to threads
/// and each result lands in its input slot, so the output is
/// independent of thread interleaving — and because a reset worker is
/// bit-identical to a freshly built one, a 1-thread run is
/// bit-identical to an 8-thread run whenever `f` itself is a
/// deterministic function of `(index, item)` (asserted for the whole
/// stack by `rust/tests/integration_worker.rs`).
///
/// Built on [`parallel_map_pooled_outcomes`], so a panicking item
/// comes back as `Err` (with the panic message) instead of aborting
/// the process.
///
/// The per-thread state needs no `Send`/`Sync`: it is created and
/// dropped on its owning thread.
pub fn parallel_map_pooled<T, R, W, I, F>(
    items: &[T],
    threads: usize,
    init: I,
    f: F,
) -> Vec<Result<R>>
where
    T: Sync,
    R: Send,
    I: Fn() -> W + Sync,
    F: Fn(&mut W, usize, &T) -> Result<R> + Sync,
{
    parallel_map_pooled_outcomes(items, threads, init, |state, i, t| {
        PointOutcome::from_result(f(state, i, t))
    })
    .into_iter()
    .map(PointOutcome::into_result)
    .collect()
}

/// [`parallel_map_pooled`] plus deterministic telemetry counters: `f`
/// additionally receives a per-item [`Counters`] registry, and the
/// per-item registries are folded **in input order** into one
/// aggregate.  Counter addition is commutative, but pinning the fold
/// order makes the aggregate independent of thread interleaving by
/// construction — a 1-thread and an 8-thread grid emit byte-identical
/// aggregated telemetry (asserted by
/// `rust/tests/integration_telemetry.rs`) and the contract survives
/// future non-commutative merges (e.g. "last value wins" gauges).
///
/// Items that fail contribute no counters (their `f` call returned
/// `Err` before finishing its run).
pub fn parallel_map_pooled_counted<T, R, W, I, F>(
    items: &[T],
    threads: usize,
    init: I,
    f: F,
) -> (Vec<Result<R>>, Counters)
where
    T: Sync,
    R: Send,
    I: Fn() -> W + Sync,
    F: Fn(&mut W, &mut Counters, usize, &T) -> Result<R> + Sync,
{
    let (outcomes, total) = parallel_map_pooled_counted_outcomes(
        items,
        threads,
        init,
        |state, c, i, t| {
            PointOutcome::from_result(f(state, c, i, t))
        },
    );
    (
        outcomes.into_iter().map(PointOutcome::into_result).collect(),
        total,
    )
}

/// [`parallel_map_pooled_outcomes`] plus deterministic counters (the
/// outcome-typed sibling of [`parallel_map_pooled_counted`]): failed
/// points — panicked, timed out or errored — contribute no counters,
/// so a quarantined degraded run aggregates exactly its healthy
/// subset, folded in input order.
pub fn parallel_map_pooled_counted_outcomes<T, R, W, I, F>(
    items: &[T],
    threads: usize,
    init: I,
    f: F,
) -> (Vec<PointOutcome<R>>, Counters)
where
    T: Sync,
    R: Send,
    I: Fn() -> W + Sync,
    F: Fn(&mut W, &mut Counters, usize, &T) -> PointOutcome<R> + Sync,
{
    let results = parallel_map_pooled_outcomes(
        items,
        threads,
        init,
        |state, i, t| {
            let mut c = Counters::new();
            f(state, &mut c, i, t).map(|v| (v, c))
        },
    );
    let mut total = Counters::new();
    let mut out = Vec::with_capacity(results.len());
    for r in results {
        out.push(r.map(|(v, c)| {
            total.merge(&c);
            v
        }));
    }
    (out, total)
}

/// Largest-first schedule for a *heterogeneous* grid: indices of
/// `items` sorted by non-increasing `cost`, ties broken by input index
/// (deterministic).  The pooled fan-out hands items out in list order,
/// so feeding it `idx.map(|i| items[i])` keeps the expensive items off
/// the tail of the run — a big item picked up last would otherwise
/// idle every other worker while it finishes.  Callers re-scatter the
/// permuted results through the same index vector to recover canonical
/// input order (see `fuzz::tournament` for the idiom).
pub fn size_ordered_indices<T>(
    items: &[T],
    cost: impl Fn(&T) -> u64,
) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..items.len()).collect();
    idx.sort_by_key(|&i| (std::cmp::Reverse(cost(&items[i])), i));
    idx
}

/// Stateless fan-out over `items` (see [`parallel_map_pooled`] for the
/// ordering/determinism contract).  Kept for map jobs with no
/// per-thread state worth pinning.
pub fn parallel_map<T, R, F>(
    items: &[T],
    threads: usize,
    f: F,
) -> Vec<Result<R>>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> Result<R> + Sync,
{
    parallel_map_pooled(items, threads, || (), |_, i, t| f(i, t))
}

/// One sweep point: a scheduler at an injection rate (and seed).
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub scheduler: String,
    pub rate_per_ms: f64,
    pub seed: u64,
}

impl SweepPoint {
    /// The fully-resolved per-point config: `base` with this point's
    /// scheduler/rate/seed applied.  Its canonical JSON is the point's
    /// store identity.
    pub fn resolve(&self, base: &SimConfig) -> SimConfig {
        let mut cfg = base.clone();
        cfg.scheduler = self.scheduler.clone();
        cfg.injection_rate_per_ms = self.rate_per_ms;
        cfg.seed = self.seed;
        cfg
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("scheduler", Json::Str(self.scheduler.clone()))
            .set("rate_per_ms", Json::Num(self.rate_per_ms))
            .set("seed", u64_to_json(self.seed));
        j
    }

    pub fn from_json(j: &Json) -> Result<SweepPoint> {
        Ok(SweepPoint {
            scheduler: j.req_str("scheduler")?.to_string(),
            rate_per_ms: j.req_f64("rate_per_ms")?,
            seed: j.get("seed").and_then(u64_from_json).ok_or_else(
                || Error::Json("sweep point: bad seed".into()),
            )?,
        })
    }
}

/// Condensed result of one sweep point.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub point: SweepPoint,
    pub avg_latency_us: f64,
    pub p95_latency_us: f64,
    pub throughput_jobs_per_ms: f64,
    pub energy_per_job_mj: f64,
    pub avg_power_w: f64,
    pub completed_jobs: usize,
    pub injected_jobs: usize,
    pub sched_overhead_us: f64,
    pub peak_temp_c: f64,
}

impl SweepResult {
    fn from_report(point: SweepPoint, r: &SimReport) -> SweepResult {
        let s = r.latency_summary();
        SweepResult {
            point,
            avg_latency_us: s.mean,
            p95_latency_us: s.p95,
            throughput_jobs_per_ms: r.throughput_jobs_per_ms(),
            energy_per_job_mj: r.energy_per_job_mj(),
            avg_power_w: r.avg_power_w,
            completed_jobs: r.completed_jobs,
            injected_jobs: r.injected_jobs,
            sched_overhead_us: r.sched_overhead_us(),
            peak_temp_c: r.peak_temp_c,
        }
    }

    /// Serialize for the experiment-store point cache.  `f64` fields
    /// round-trip bit-exactly (shortest-form printing, correctly
    /// rounded parsing), which is what lets a warm-store rerun
    /// reproduce the cold run's report byte-for-byte.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("point", self.point.to_json())
            .set("avg_latency_us", Json::Num(self.avg_latency_us))
            .set("p95_latency_us", Json::Num(self.p95_latency_us))
            .set(
                "throughput_jobs_per_ms",
                Json::Num(self.throughput_jobs_per_ms),
            )
            .set(
                "energy_per_job_mj",
                Json::Num(self.energy_per_job_mj),
            )
            .set("avg_power_w", Json::Num(self.avg_power_w))
            .set(
                "completed_jobs",
                Json::Num(self.completed_jobs as f64),
            )
            .set("injected_jobs", Json::Num(self.injected_jobs as f64))
            .set(
                "sched_overhead_us",
                Json::Num(self.sched_overhead_us),
            )
            .set("peak_temp_c", Json::Num(self.peak_temp_c));
        j
    }

    pub fn from_json(j: &Json) -> Result<SweepResult> {
        let usize_at = |key: &str| -> Result<usize> {
            j.get(key).and_then(Json::as_usize).ok_or_else(|| {
                Error::Json(format!(
                    "sweep result: expected integer at key '{key}'"
                ))
            })
        };
        Ok(SweepResult {
            point: SweepPoint::from_json(j.get("point").ok_or_else(
                || Error::Json("sweep result: missing point".into()),
            )?)?,
            avg_latency_us: j.req_f64("avg_latency_us")?,
            p95_latency_us: j.req_f64("p95_latency_us")?,
            throughput_jobs_per_ms: j
                .req_f64("throughput_jobs_per_ms")?,
            energy_per_job_mj: j.req_f64("energy_per_job_mj")?,
            avg_power_w: j.req_f64("avg_power_w")?,
            completed_jobs: usize_at("completed_jobs")?,
            injected_jobs: usize_at("injected_jobs")?,
            sched_overhead_us: j.req_f64("sched_overhead_us")?,
            peak_temp_c: j.req_f64("peak_temp_c")?,
        })
    }
}

/// Run every (scheduler, rate) combination, `threads`-wide.
///
/// The base config supplies everything except scheduler/rate/seed.
/// Results come back in deterministic (scheduler, rate) input order.
pub fn run_sweep(
    platform: &Platform,
    apps: &[AppGraph],
    base: &SimConfig,
    points: &[SweepPoint],
    threads: usize,
) -> Result<Vec<SweepResult>> {
    run_sweep_with(
        platform,
        apps,
        base,
        points,
        threads,
        &Telemetry::disabled(),
    )
    .map(|(res, _)| res)
}

/// [`run_sweep`] with telemetry: streams
/// [`Event::SweepProgress`] (completed/total, sims/s, ETA) as points
/// finish and returns the grid's aggregated deterministic [`Counters`]
/// alongside the results.  Progress events are wall-clock (emitted from
/// whichever pool thread finishes a point); the returned counters are
/// folded in input order and independent of `threads`.
pub fn run_sweep_with(
    platform: &Platform,
    apps: &[AppGraph],
    base: &SimConfig,
    points: &[SweepPoint],
    threads: usize,
    tel: &Telemetry,
) -> Result<(Vec<SweepResult>, Counters)> {
    run_sweep_stored(platform, apps, base, points, threads, tel, None)
}

/// [`run_sweep_with`] plus the experiment-store cache-consult hook.
///
/// With a [`StoreCtx`], every point's cache key is resolved up front
/// (in input order, so the run manifest lists identical keys for
/// cold, warm and partial reruns), cached points are loaded instead
/// of simulated, and only the *missing* subset goes through the
/// pooled grid — a fully warm rerun performs **zero** simulations and
/// never even builds the [`SimSetup`].  Cached and fresh results are
/// merged back in input order, and the final counter fold walks the
/// full grid in input order mixing stored and fresh per-point deltas,
/// so the report and the aggregated counters are byte-identical to a
/// cold run's for any thread count.
pub fn run_sweep_stored(
    platform: &Platform,
    apps: &[AppGraph],
    base: &SimConfig,
    points: &[SweepPoint],
    threads: usize,
    tel: &Telemetry,
    store: Option<&StoreCtx>,
) -> Result<(Vec<SweepResult>, Counters)> {
    run_sweep_quarantined(
        platform,
        apps,
        base,
        points,
        threads,
        tel,
        store,
        FailPolicy::Abort,
    )
    .map(|(res, counters, _)| (res, counters))
}

/// Enforce a quarantine budget: `quarantine:N` aborts once more than
/// `N` points have failed.  Shared with the fuzz tournament and the
/// DSE evaluator.
pub(crate) fn quarantine_guard(
    policy: &FailPolicy,
    failures: &FailureReport,
) -> Result<()> {
    if let FailPolicy::Quarantine { max_failures: Some(max) } = policy {
        if failures.quarantined() > *max {
            return Err(Error::Sim(format!(
                "quarantine budget exceeded: {}/{} points failed \
                 (max {max})",
                failures.quarantined(),
                failures.total
            )));
        }
    }
    Ok(())
}

/// Emit one deterministic [`Event::PointFailed`] per quarantined
/// point — post-collection, in input order, from the calling thread.
fn emit_point_failures(
    tel: &Telemetry,
    what: &str,
    failures: &FailureReport,
) {
    for p in &failures.failed {
        tel.emit(|| Event::PointFailed {
            what: what.to_string(),
            label: p.label.clone(),
            kind: p.kind.clone(),
            detail: p.detail.clone(),
        });
    }
}

/// [`run_sweep_stored`] with an explicit [`FailPolicy`] — the full
/// fault-isolated sweep driver.  Under
/// [`FailPolicy::Quarantine`], a panicking, timed-out or erroring
/// point is dropped from the results (and **never** written to the
/// store), recorded in the returned [`FailureReport`], and reported
/// through one deterministic [`Event::PointFailed`] per failure; all
/// healthy points complete normally.  The quarantine set, the
/// surviving results, the aggregated counters and the telemetry
/// stream are all byte-identical across thread counts
/// (`rust/tests/integration_fault.rs`).
#[allow(clippy::too_many_arguments)]
pub fn run_sweep_quarantined(
    platform: &Platform,
    apps: &[AppGraph],
    base: &SimConfig,
    points: &[SweepPoint],
    threads: usize,
    tel: &Telemetry,
    store: Option<&StoreCtx>,
    policy: FailPolicy,
) -> Result<(Vec<SweepResult>, Counters, FailureReport)> {
    // Per-point identity, resolved in canonical input order.
    let keys: Vec<(String, String)> = match store {
        Some(ctx) => points
            .iter()
            .map(|p| {
                let ch = crate::telemetry::config_hash(
                    &p.resolve(base).to_json().to_string(),
                );
                let key =
                    crate::store::point_key(&ch, &ctx.workload_digest);
                (ch, key)
            })
            .collect(),
        None => Vec::new(),
    };
    if let Some(ctx) = store {
        ctx.store
            .record_points(&keys.iter().map(|(_, k)| k.clone()).collect::<Vec<_>>());
    }

    // Partition cached vs fresh (input order).
    let mut slots: Vec<Option<(SweepResult, Counters)>> =
        (0..points.len()).map(|_| None).collect();
    let mut fresh: Vec<(usize, SweepPoint)> = Vec::new();
    for (i, p) in points.iter().enumerate() {
        let cached = store
            .and_then(|ctx| ctx.store.lookup(&keys[i].1, "sweep"))
            .and_then(|e| {
                SweepResult::from_json(&e.result)
                    .ok()
                    .map(|r| (r, e.counters))
            });
        match cached {
            Some(rc) => slots[i] = Some(rc),
            None => fresh.push((i, p.clone())),
        }
    }

    let mut failures = FailureReport::new(points.len());
    if !fresh.is_empty() {
        // One immutable setup for the whole grid; one reusable worker
        // per pool thread (reset per point — no per-point rebuild).
        let setup = SimSetup::new(platform, apps, base)?;
        let setup = &setup;
        let progress = GridProgress::start(fresh.len());
        let outcomes = parallel_map_pooled_outcomes(
            &fresh,
            threads,
            || None::<SimWorker>,
            |slot, _, (_, p)| {
                let label =
                    format!("{}@{}", p.scheduler, p.rate_per_ms);
                crate::faultpoint::fire_panic(
                    crate::faultpoint::sites::SWEEP_POINT,
                    &label,
                );
                let cfg = p.resolve(base);
                let worker = match SimWorker::obtain(slot, setup, &cfg)
                {
                    Ok(w) => w,
                    Err(e) => return PointOutcome::Error(e),
                };
                let report = worker.run(setup);
                progress.emit_done(tel);
                if report.timed_out {
                    return PointOutcome::TimedOut {
                        steps: report.watchdog_steps,
                    };
                }
                let counters = Counters::from_report(report);
                PointOutcome::Ok((
                    SweepResult::from_report(p.clone(), report),
                    counters,
                ))
            },
        );
        // Triage outcomes from the calling thread, in input (filtered)
        // order: healthy points persist to the store and land in their
        // slots; failed points are quarantined — and never cached — or
        // abort the campaign, per policy.
        let mut errs = Vec::new();
        for ((i, p), out) in fresh.iter().zip(outcomes) {
            let label = format!("{}@{}", p.scheduler, p.rate_per_ms);
            match out {
                PointOutcome::Ok(rc) => {
                    if let Some(ctx) = store {
                        ctx.store.put_point(&PointEntry {
                            kind: "sweep".into(),
                            key: keys[*i].1.clone(),
                            config_hash: keys[*i].0.clone(),
                            workload_digest: ctx
                                .workload_digest
                                .clone(),
                            result: rc.0.to_json(),
                            counters: rc.1.clone(),
                        })?;
                    }
                    slots[*i] = Some(rc);
                }
                out => {
                    let kind = out.failure_kind().unwrap_or("error");
                    let detail = out.failure_detail();
                    if policy.is_quarantine() {
                        failures.record(*i, label, kind, detail);
                    } else {
                        errs.push(format!("{label}: {detail}"));
                    }
                }
            }
        }
        if !errs.is_empty() {
            return Err(Error::Sim(format!(
                "sweep failures: {}",
                errs.join("; ")
            )));
        }
        quarantine_guard(&policy, &failures)?;
    }

    // point_failed events are deterministic: emitted post-collection,
    // in input order, from the calling thread.
    emit_point_failures(tel, "sweep", &failures);

    // Final merge: walk the full grid in input order, mixing cached
    // and fresh per-point deltas — byte-identical to a cold run.  An
    // empty slot is legal only for a quarantined point.
    let failed_idx: std::collections::BTreeSet<usize> =
        failures.failed.iter().map(|p| p.index).collect();
    let mut results = Vec::with_capacity(points.len());
    let mut counters = Counters::new();
    for (i, s) in slots.into_iter().enumerate() {
        match s {
            Some((r, c)) => {
                counters.merge(&c);
                results.push(r);
            }
            None if failed_idx.contains(&i) => {}
            None => {
                return Err(Error::Internal(format!(
                    "sweep point {i} neither resolved nor quarantined"
                )))
            }
        }
    }
    Ok((results, counters, failures))
}

/// Shared completion tracker behind [`Event::SweepProgress`]: an atomic
/// done-count plus the grid's start instant, emitting one progress
/// event per finished item from whichever pool thread finished it.
struct GridProgress {
    total: usize,
    done: AtomicUsize,
    t0: SpanTimer,
}

impl GridProgress {
    fn start(total: usize) -> GridProgress {
        GridProgress {
            total,
            done: AtomicUsize::new(0),
            t0: SpanTimer::start(),
        }
    }

    fn emit_done(&self, tel: &Telemetry) {
        if !tel.enabled() {
            return;
        }
        let done = self.done.fetch_add(1, Ordering::Relaxed) + 1;
        let elapsed = self.t0.elapsed_s();
        let rate = if elapsed > 0.0 { done as f64 / elapsed } else { 0.0 };
        let eta_s = if rate > 0.0 {
            (self.total.saturating_sub(done)) as f64 / rate
        } else {
            0.0
        };
        tel.emit(|| Event::SweepProgress {
            completed: done,
            total: self.total,
            sims_per_s: rate,
            eta_s,
        });
    }
}

/// Condensed result of one scenario sweep point.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub scenario: String,
    pub avg_latency_us: f64,
    pub p95_latency_us: f64,
    pub completed_jobs: usize,
    pub injected_jobs: usize,
    pub energy_per_job_mj: f64,
    pub avg_power_w: f64,
    pub peak_temp_c: f64,
    /// Per-phase breakdown of the run.
    pub phases: Vec<PhaseStats>,
}

/// Run the same workload under every scenario, `threads`-wide — the
/// scenario-file axis of the design space ("as many scenarios as you
/// can imagine").  `base` supplies everything except the scenario;
/// results come back in input order.
pub fn run_scenario_sweep(
    platform: &Platform,
    apps: &[AppGraph],
    base: &SimConfig,
    scenarios: &[Scenario],
    threads: usize,
) -> Result<Vec<ScenarioResult>> {
    run_scenario_sweep_with(
        platform,
        apps,
        base,
        scenarios,
        threads,
        &Telemetry::disabled(),
    )
    .map(|(res, _)| res)
}

/// [`run_scenario_sweep`] with telemetry: streams
/// [`Event::SweepProgress`] while the grid runs, then emits one
/// deterministic [`Event::ScenarioPhase`] per phase **in input order**
/// after collection, and returns the aggregated [`Counters`].
pub fn run_scenario_sweep_with(
    platform: &Platform,
    apps: &[AppGraph],
    base: &SimConfig,
    scenarios: &[Scenario],
    threads: usize,
    tel: &Telemetry,
) -> Result<(Vec<ScenarioResult>, Counters)> {
    run_scenario_sweep_inner(
        platform,
        apps,
        base,
        scenarios,
        threads,
        tel,
        None,
        FailPolicy::Abort,
    )
    .map(|(res, counters, _, _)| (res, counters))
}

/// [`run_scenario_sweep_with`] with an explicit [`FailPolicy`]: under
/// quarantine, failed scenario points are dropped from the results
/// (which keep input order over the survivors) and recorded in the
/// returned [`FailureReport`].
#[allow(clippy::too_many_arguments)]
pub fn run_scenario_sweep_quarantined(
    platform: &Platform,
    apps: &[AppGraph],
    base: &SimConfig,
    scenarios: &[Scenario],
    threads: usize,
    tel: &Telemetry,
    policy: FailPolicy,
) -> Result<(Vec<ScenarioResult>, Counters, FailureReport)> {
    run_scenario_sweep_inner(
        platform, apps, base, scenarios, threads, tel, None, policy,
    )
    .map(|(res, counters, _, failures)| (res, counters, failures))
}

/// [`run_scenario_sweep_with`] with a time-series probe attached to
/// every point: returns one sealed [`crate::probe::TraceSeries`] per
/// scenario **in input order**, so the artifact is byte-identical
/// across thread counts.
pub fn run_scenario_sweep_probed(
    platform: &Platform,
    apps: &[AppGraph],
    base: &SimConfig,
    scenarios: &[Scenario],
    threads: usize,
    tel: &Telemetry,
    probe: &crate::probe::ProbeConfig,
) -> Result<(Vec<ScenarioResult>, Counters, Vec<crate::probe::TraceSeries>)>
{
    let (res, counters, traces, _) = run_scenario_sweep_inner(
        platform,
        apps,
        base,
        scenarios,
        threads,
        tel,
        Some(probe),
        FailPolicy::Abort,
    )?;
    Ok((res, counters, traces.into_iter().flatten().collect()))
}

#[allow(clippy::too_many_arguments)]
fn run_scenario_sweep_inner(
    platform: &Platform,
    apps: &[AppGraph],
    base: &SimConfig,
    scenarios: &[Scenario],
    threads: usize,
    tel: &Telemetry,
    probe: Option<&crate::probe::ProbeConfig>,
    policy: FailPolicy,
) -> Result<(
    Vec<ScenarioResult>,
    Counters,
    Vec<Option<crate::probe::TraceSeries>>,
    FailureReport,
)> {
    let setup = SimSetup::new(platform, apps, base)?;
    let setup = &setup;
    let progress = GridProgress::start(scenarios.len());
    let (outcomes, counters) = parallel_map_pooled_counted_outcomes(
        scenarios,
        threads,
        || None::<SimWorker>,
        |slot, counters, _, sc| {
            crate::faultpoint::fire_panic(
                crate::faultpoint::sites::SWEEP_POINT,
                &sc.name,
            );
            let mut cfg = base.clone();
            cfg.scenario = Some(sc.clone());
            let worker = match SimWorker::obtain(slot, setup, &cfg) {
                Ok(w) => w,
                Err(e) => return PointOutcome::Error(e),
            };
            // A probe records exactly one run (reset drops it), so
            // each point re-attaches after obtaining its worker.
            if let Some(pc) = probe {
                worker.attach_probe(pc.clone());
            }
            // Borrow the report in place: cloning `phases` into the
            // result lets the worker keep its buffers (latency vectors,
            // phase list) for capacity-retaining recycle on the next
            // reset, instead of `take_report` stealing them every run.
            let r = worker.run(setup);
            progress.emit_done(tel);
            if r.timed_out {
                return PointOutcome::TimedOut {
                    steps: r.watchdog_steps,
                };
            }
            counters.merge(&Counters::from_report(r));
            let s = r.latency_summary();
            let res = ScenarioResult {
                scenario: sc.name.clone(),
                avg_latency_us: s.mean,
                p95_latency_us: s.p95,
                completed_jobs: r.completed_jobs,
                injected_jobs: r.injected_jobs,
                energy_per_job_mj: r.energy_per_job_mj(),
                avg_power_w: r.avg_power_w,
                peak_temp_c: r.peak_temp_c,
                phases: r.phases.clone(),
            };
            let trace = worker.take_probe_trace();
            PointOutcome::Ok((res, trace))
        },
    );
    let mut failures = FailureReport::new(scenarios.len());
    let mut errs = Vec::new();
    let mut results = Vec::with_capacity(scenarios.len());
    let mut traces = Vec::with_capacity(scenarios.len());
    for (i, out) in outcomes.into_iter().enumerate() {
        match out {
            PointOutcome::Ok((res, trace)) => {
                results.push(res);
                traces.push(trace);
            }
            out => {
                let kind = out.failure_kind().unwrap_or("error");
                let detail = out.failure_detail();
                if policy.is_quarantine() {
                    failures.record(
                        i,
                        scenarios[i].name.clone(),
                        kind,
                        detail,
                    );
                } else {
                    errs.push(format!(
                        "{}: {detail}",
                        scenarios[i].name
                    ));
                }
            }
        }
    }
    if !errs.is_empty() {
        return Err(Error::Sim(format!(
            "scenario sweep failures: {}",
            errs.join("; ")
        )));
    }
    quarantine_guard(&policy, &failures)?;
    emit_point_failures(tel, "scenario", &failures);
    // Per-phase events are deterministic, so they are emitted here —
    // post-collection, in input order, from the calling thread — never
    // concurrently from the pool.
    for res in &results {
        for phase in &res.phases {
            tel.emit(|| Event::ScenarioPhase {
                scenario: res.scenario.clone(),
                phase: phase.clone(),
            });
        }
    }
    Ok((results, counters, traces, failures))
}

/// Build the Figure-3 point grid: every scheduler at every rate.
pub fn fig3_points(
    schedulers: &[&str],
    rates: &[f64],
    seed: u64,
) -> Vec<SweepPoint> {
    let mut out = Vec::with_capacity(schedulers.len() * rates.len());
    for s in schedulers {
        for &r in rates {
            out.push(SweepPoint {
                scheduler: s.to_string(),
                rate_per_ms: r,
                seed,
            });
        }
    }
    out
}

/// Convert sweep results into per-scheduler latency-vs-rate series
/// (the Figure-3 plot).
pub fn latency_series(results: &[SweepResult]) -> Vec<Series> {
    let mut order: Vec<String> = Vec::new();
    for r in results {
        if !order.contains(&r.point.scheduler) {
            order.push(r.point.scheduler.clone());
        }
    }
    order
        .into_iter()
        .map(|name| {
            let mut s = Series::new(name.clone());
            for r in results.iter().filter(|r| r.point.scheduler == name) {
                s.push(r.point.rate_per_ms, r.avg_latency_us);
            }
            s
        })
        .collect()
}

/// Hardware-validation comparison (paper §3: "we also implemented a
/// subset of the scheduling algorithms on the Xilinx Zynq FPGA and then
/// compared the results ... with hardware measurements").
///
/// With no FPGA in this environment, the "measurement" reference is a
/// fine-grained simulation variant — execution-time jitter from profile
/// variance plus NoC contention — against which the deterministic
/// analytical model is validated (DESIGN.md §Substitutions item 2).
#[derive(Debug, Clone)]
pub struct ValidationRow {
    pub app: String,
    pub scheduler: String,
    pub model_us: f64,
    pub reference_us: f64,
    pub error_pct: f64,
}

pub fn validate(
    platform: &Platform,
    apps: &[AppGraph],
    schedulers: &[&str],
    jobs: usize,
    seed: u64,
) -> Result<Vec<ValidationRow>> {
    let mut rows = Vec::new();
    for (ai, app) in apps.iter().enumerate() {
        let single = std::slice::from_ref(app);
        for s in schedulers {
            let mut cfg = SimConfig::default();
            cfg.scheduler = s.to_string();
            cfg.injection_rate_per_ms = 1.0;
            cfg.max_jobs = jobs;
            cfg.warmup_jobs = jobs / 10;
            cfg.seed = seed + ai as u64;
            let model =
                Simulation::build(platform, single, &cfg)?.run();

            let mut href = cfg.clone();
            href.exec_jitter_frac = 0.08; // profiled run-to-run variance
            href.noc_congestion = true;
            let reference =
                Simulation::build(platform, single, &href)?.run();

            let m = model.avg_job_latency_us();
            let h = reference.avg_job_latency_us();
            rows.push(ValidationRow {
                app: app.name.clone(),
                scheduler: s.to_string(),
                model_us: m,
                reference_us: h,
                error_pct: if h > 0.0 {
                    (m - h).abs() / h * 100.0
                } else {
                    0.0
                },
            });
        }
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::suite::{self, WifiParams};

    fn small_base() -> SimConfig {
        let mut c = SimConfig::default();
        c.max_jobs = 40;
        c.warmup_jobs = 5;
        c
    }

    #[test]
    fn parallel_map_preserves_order_and_aggregates_errors() {
        let items: Vec<usize> = (0..64).collect();
        let out = parallel_map(&items, 8, |i, &x| {
            assert_eq!(i, x);
            if x % 13 == 5 {
                Err(crate::Error::Sim(format!("boom{x}")))
            } else {
                Ok(x * 2)
            }
        });
        assert_eq!(out.len(), 64);
        for (i, r) in out.iter().enumerate() {
            if i % 13 == 5 {
                let msg = r.as_ref().unwrap_err().to_string();
                assert!(msg.contains(&format!("boom{i}")), "{msg}");
            } else {
                assert_eq!(*r.as_ref().unwrap(), i * 2);
            }
        }
        let all_ok = parallel_map(&items, 3, |_, &x| Ok(x + 1));
        let vals: Vec<usize> =
            all_ok.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (1..=64).collect::<Vec<_>>());
    }

    #[test]
    fn size_ordered_indices_sorts_descending_with_stable_ties() {
        let costs = [3u64, 9, 1, 9, 7, 1];
        let idx = size_ordered_indices(&costs, |&c| c);
        assert_eq!(idx, vec![1, 3, 4, 0, 2, 5]);
        // Non-increasing along the schedule; a permutation of 0..n.
        for w in idx.windows(2) {
            assert!(costs[w[0]] >= costs[w[1]]);
        }
        let mut seen = idx.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..costs.len()).collect::<Vec<_>>());
        let empty: [u64; 0] = [];
        assert!(size_ordered_indices(&empty, |&c| c).is_empty());
    }

    #[test]
    fn pooled_map_initializes_once_per_thread_and_reuses_state() {
        let items: Vec<usize> = (0..32).collect();
        let out = parallel_map_pooled(
            &items,
            4,
            || 0usize,
            |count, _, &x| {
                *count += 1;
                Ok((x, *count))
            },
        );
        let mut deepest = 0;
        for (i, r) in out.iter().enumerate() {
            let (x, nth) = *r.as_ref().unwrap();
            assert_eq!(x, i, "result out of input order");
            assert!(nth >= 1);
            deepest = deepest.max(nth);
        }
        // 32 items over ≤ 4 threads: some thread must have processed
        // ≥ 8 items through its pinned state (pigeonhole) — the state
        // visibly persisted across items.
        assert!(deepest >= 8, "state not reused: max depth {deepest}");
    }

    #[test]
    fn counted_map_aggregates_in_input_order_across_thread_counts() {
        let items: Vec<u64> = (0..40).collect();
        let run = |threads: usize| {
            parallel_map_pooled_counted(
                &items,
                threads,
                || (),
                |_, c, _, &x| {
                    c.add("sum", x);
                    c.add("items", 1);
                    if x == 11 {
                        return Err(crate::Error::Sim("skip".into()));
                    }
                    Ok(x)
                },
            )
        };
        let (res1, c1) = run(1);
        let (res8, c8) = run(8);
        assert_eq!(res1.len(), 40);
        assert_eq!(c1, c8, "aggregate must not depend on thread count");
        assert_eq!(
            c1.to_json().to_string(),
            c8.to_json().to_string(),
            "serialized counters must be byte-identical"
        );
        // The failing item (x == 11) contributes nothing.
        assert_eq!(c1.get("items"), 39);
        assert_eq!(c1.get("sum"), (0..40).sum::<u64>() - 11);
        assert!(res8[11].is_err());
    }

    #[test]
    fn pooled_outcomes_contain_panics_and_rebuild_state() {
        let items: Vec<usize> = (0..24).collect();
        let built = AtomicUsize::new(0);
        let out = parallel_map_pooled_outcomes(
            &items,
            4,
            || {
                built.fetch_add(1, Ordering::Relaxed);
                0usize
            },
            |depth, _, &x| {
                *depth += 1;
                if x % 7 == 3 {
                    panic!("boom{x}");
                }
                PointOutcome::Ok((x, *depth))
            },
        );
        assert_eq!(out.len(), 24);
        let mut panics = 0;
        for (i, o) in out.iter().enumerate() {
            if i % 7 == 3 {
                panics += 1;
                match o {
                    PointOutcome::Panicked { msg } => {
                        assert_eq!(msg, &format!("boom{i}"));
                    }
                    other => panic!("expected Panicked: {other:?}"),
                }
            } else {
                assert!(o.is_ok(), "item {i}: {o:?}");
            }
        }
        assert_eq!(panics, 3);
        // Poisoned-state replacement: each panic discarded the pinned
        // state, so `init` ran once per pool thread (≤ 4) plus once
        // per panic.
        let inits = built.load(Ordering::Relaxed);
        assert!(
            inits >= 1 + panics && inits <= 4 + panics,
            "unexpected init count {inits}"
        );
    }

    #[test]
    fn fail_policy_parse_grammar() {
        assert_eq!(
            FailPolicy::parse("abort").unwrap(),
            FailPolicy::Abort
        );
        assert_eq!(
            FailPolicy::parse("quarantine").unwrap(),
            FailPolicy::Quarantine { max_failures: None }
        );
        assert_eq!(
            FailPolicy::parse("quarantine:5").unwrap(),
            FailPolicy::Quarantine { max_failures: Some(5) }
        );
        assert!(FailPolicy::parse("retry").is_err());
        assert!(FailPolicy::parse("quarantine:x").is_err());
        assert!(FailPolicy::parse("quarantine:").is_err());
    }

    #[test]
    fn sweep_quarantines_injected_panic() {
        // Unique rate → unique "met@2.125" label, so the armed fault
        // cannot leak into concurrently running sweep tests.
        let p = Platform::table2_soc();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let pts = fig3_points(&["etf", "met"], &[0.5, 2.125], 3);
        let _g = crate::faultpoint::Armed::new(
            crate::faultpoint::sites::SWEEP_POINT,
            "met@2.125",
            crate::faultpoint::Fault::Panic,
        );
        let (res, counters, fr) = run_sweep_quarantined(
            &p,
            &apps,
            &small_base(),
            &pts,
            2,
            &Telemetry::disabled(),
            None,
            FailPolicy::Quarantine { max_failures: None },
        )
        .unwrap();
        assert_eq!(res.len(), 3, "healthy points survive");
        assert_eq!(fr.quarantined(), 1);
        assert_eq!(fr.failed[0].label, "met@2.125");
        assert_eq!(fr.failed[0].kind, "panic");
        assert_eq!(fr.failed[0].index, 3);
        // Failed point contributes no counters.
        assert_eq!(counters.get("runs"), 3);
        // A zero quarantine budget aborts on the same fault…
        assert!(run_sweep_quarantined(
            &p,
            &apps,
            &small_base(),
            &pts,
            2,
            &Telemetry::disabled(),
            None,
            FailPolicy::Quarantine { max_failures: Some(0) },
        )
        .is_err());
        // …and so does the abort policy (as an error, not a crash).
        let err =
            run_sweep(&p, &apps, &small_base(), &pts, 2).unwrap_err();
        assert!(
            err.to_string().contains("met@2.125"),
            "{err}"
        );
    }

    #[test]
    fn sweep_with_streams_progress_and_counters() {
        use crate::telemetry::MemSink;
        use std::sync::Arc;
        let p = Platform::table2_soc();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let pts = fig3_points(&["etf", "met"], &[0.5, 2.0], 3);
        let sink = Arc::new(MemSink::new().with_timing(true));
        let tel = Telemetry::new(sink.clone());
        let (res, counters) =
            run_sweep_with(&p, &apps, &small_base(), &pts, 2, &tel)
                .unwrap();
        assert_eq!(res.len(), 4);
        // One progress event per point, last one reporting 4/4.
        let lines = sink.lines();
        let progress: Vec<&String> = lines
            .iter()
            .filter(|l| l.contains("\"sweep_progress\""))
            .collect();
        assert_eq!(progress.len(), 4, "{lines:?}");
        assert!(
            progress.iter().any(|l| l.contains("\"completed\": 4")),
            "{progress:?}"
        );
        // Aggregated counters match the per-point reports.
        assert_eq!(counters.get("runs"), 4);
        assert_eq!(
            counters.get("completed_jobs"),
            res.iter().map(|r| r.completed_jobs as u64).sum::<u64>()
        );
    }

    #[test]
    fn sweep_worker_reuse_matches_fresh_builds_per_point() {
        // The pooled run_sweep (workers reset per point) against a
        // hand-rolled fresh-build-per-point loop: every metric must be
        // bit-identical, regardless of which thread ran which point.
        let p = Platform::table2_soc();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let base = small_base();
        let pts = fig3_points(&["etf", "met", "rr"], &[0.5, 2.0, 6.0], 9);
        assert_eq!(pts.len(), 9);
        // 2 threads × 9 points forces several resets per worker.
        let pooled = run_sweep(&p, &apps, &base, &pts, 2).unwrap();
        for (r, pt) in pooled.iter().zip(&pts) {
            let mut cfg = base.clone();
            cfg.scheduler = pt.scheduler.clone();
            cfg.injection_rate_per_ms = pt.rate_per_ms;
            cfg.seed = pt.seed;
            let fresh = Simulation::build(&p, &apps, &cfg).unwrap().run();
            let s = fresh.latency_summary();
            let ctx = format!("{}@{}", pt.scheduler, pt.rate_per_ms);
            assert_eq!(r.avg_latency_us.to_bits(), s.mean.to_bits(), "{ctx}");
            assert_eq!(r.p95_latency_us.to_bits(), s.p95.to_bits(), "{ctx}");
            assert_eq!(r.completed_jobs, fresh.completed_jobs, "{ctx}");
            assert_eq!(
                r.energy_per_job_mj.to_bits(),
                fresh.energy_per_job_mj().to_bits(),
                "{ctx}"
            );
            assert_eq!(
                r.peak_temp_c.to_bits(),
                fresh.peak_temp_c.to_bits(),
                "{ctx}"
            );
        }
    }

    #[test]
    fn sweep_result_json_round_trip_is_bit_exact() {
        let p = Platform::table2_soc();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let pts = fig3_points(&["etf"], &[2.0], 3);
        let res = run_sweep(&p, &apps, &small_base(), &pts, 1).unwrap();
        let r = &res[0];
        let back = SweepResult::from_json(
            &Json::parse(&r.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(
            r.avg_latency_us.to_bits(),
            back.avg_latency_us.to_bits()
        );
        assert_eq!(
            r.p95_latency_us.to_bits(),
            back.p95_latency_us.to_bits()
        );
        assert_eq!(
            r.energy_per_job_mj.to_bits(),
            back.energy_per_job_mj.to_bits()
        );
        assert_eq!(r.completed_jobs, back.completed_jobs);
        assert_eq!(r.point.scheduler, back.point.scheduler);
        assert_eq!(r.point.seed, back.point.seed);
        // And the re-serialization is byte-identical.
        assert_eq!(
            r.to_json().to_string(),
            back.to_json().to_string()
        );
    }

    #[test]
    fn sweep_covers_grid_in_order() {
        let p = Platform::table2_soc();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let pts = fig3_points(&["met", "etf"], &[0.5, 1.0], 7);
        assert_eq!(pts.len(), 4);
        let res = run_sweep(&p, &apps, &small_base(), &pts, 4).unwrap();
        assert_eq!(res.len(), 4);
        for (r, pt) in res.iter().zip(&pts) {
            assert_eq!(r.point.scheduler, pt.scheduler);
            assert_eq!(r.point.rate_per_ms, pt.rate_per_ms);
            assert_eq!(r.completed_jobs, 40);
            assert!(r.avg_latency_us > 0.0);
        }
    }

    #[test]
    fn sweep_parallel_matches_serial() {
        let p = Platform::table2_soc();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let pts = fig3_points(&["etf"], &[0.5, 2.0, 4.0], 3);
        let serial = run_sweep(&p, &apps, &small_base(), &pts, 1).unwrap();
        let par = run_sweep(&p, &apps, &small_base(), &pts, 8).unwrap();
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.avg_latency_us, b.avg_latency_us);
            assert_eq!(a.completed_jobs, b.completed_jobs);
        }
    }

    #[test]
    fn sweep_propagates_bad_scheduler() {
        let p = Platform::table2_soc();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let pts = vec![SweepPoint {
            scheduler: "bogus".into(),
            rate_per_ms: 1.0,
            seed: 1,
        }];
        assert!(run_sweep(&p, &apps, &small_base(), &pts, 2).is_err());
    }

    #[test]
    fn series_grouping() {
        let mk = |s: &str, r: f64, l: f64| SweepResult {
            point: SweepPoint {
                scheduler: s.into(),
                rate_per_ms: r,
                seed: 0,
            },
            avg_latency_us: l,
            p95_latency_us: l,
            throughput_jobs_per_ms: 0.0,
            energy_per_job_mj: 0.0,
            avg_power_w: 0.0,
            completed_jobs: 0,
            injected_jobs: 0,
            sched_overhead_us: 0.0,
            peak_temp_c: 0.0,
        };
        let res = vec![
            mk("met", 1.0, 10.0),
            mk("met", 2.0, 20.0),
            mk("etf", 1.0, 8.0),
        ];
        let series = latency_series(&res);
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].name, "met");
        assert_eq!(series[0].points, vec![(1.0, 10.0), (2.0, 20.0)]);
        assert_eq!(series[1].points, vec![(1.0, 8.0)]);
    }

    #[test]
    fn scenario_sweep_covers_inputs_in_order() {
        use crate::scenario::{presets, Action, Scenario};
        let p = Platform::table2_soc();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 3 })];
        let mut base = small_base();
        base.max_jobs = 120;
        base.injection_rate_per_ms = 2.0;
        let scenarios = vec![
            presets::pe_failure(),
            Scenario::new("quiet", "")
                .event(10_000.0, Action::SetRate { per_ms: 1.0 }),
        ];
        let res =
            run_scenario_sweep(&p, &apps, &base, &scenarios, 4).unwrap();
        assert_eq!(res.len(), 2);
        assert_eq!(res[0].scenario, "pe-failure");
        assert_eq!(res[1].scenario, "quiet");
        for r in &res {
            assert_eq!(r.completed_jobs, 120, "{} lost jobs", r.scenario);
            assert!(!r.phases.is_empty());
        }
    }

    #[test]
    fn scenario_sweep_propagates_build_errors() {
        use crate::scenario::{Action, Scenario};
        let p = Platform::table2_soc();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let bad = vec![Scenario::new("bad", "")
            .event(0.0, Action::PeFail { pe: 999 })];
        assert!(
            run_scenario_sweep(&p, &apps, &small_base(), &bad, 2).is_err()
        );
    }

    #[test]
    fn validation_errors_are_bounded() {
        let p = Platform::table2_soc();
        let apps = vec![suite::single_carrier_tx()];
        let rows = validate(&p, &apps, &["etf"], 60, 5).unwrap();
        assert_eq!(rows.len(), 1);
        // Model vs jittered reference should agree within ~15%.
        assert!(
            rows[0].error_pct < 15.0,
            "validation error {}%",
            rows[0].error_pct
        );
    }
}
