//! Fault-injection test hooks — a process-global registry of *armed*
//! faults that production code consults at named sites.
//!
//! This generalizes the fuzz tournament's `inject_label` hook into a
//! reusable primitive: tests (and the CLI's `--inject-fault` flag) arm
//! a fault against a `(site, label-prefix)` pair, and the instrumented
//! code paths — the coordinator's pooled sweep points, the simulation
//! event loop, and the store's write path — fire it when they process
//! a matching label.  Three fault kinds cover the failure modes the
//! quarantine machinery must contain:
//!
//! * [`Fault::Panic`] — the site panics, exercising `catch_unwind`
//!   quarantine and poisoned-worker replacement.
//! * [`Fault::SlowLoop`] — the simulation's watchdog step counter is
//!   pre-charged by `steps`, so a configured step budget trips
//!   deterministically without wall-clock dependence.
//! * [`Fault::IoError`] — the store's write path sees a synthetic
//!   transient IO error for the next `times` attempts, exercising the
//!   bounded retry schedule.
//!
//! The registry is **zero-cost when disarmed**: every check starts with
//! one relaxed atomic load (the same guard discipline as
//! [`crate::telemetry`]), and the map lock is only taken while a fault
//! is armed.  Injection is deterministic — whether a fault fires
//! depends only on the armed table and the label at the site, never on
//! thread identity or timing — so degraded runs stay bit-reproducible
//! across thread counts.
//!
//! Tests that arm faults share process state; use distinct site names
//! (or the scoped [`Armed`] guard plus a per-test label prefix) so
//! parallel tests cannot observe each other's faults.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// One injectable fault.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Panic at the site (message carries site + label).
    Panic,
    /// Pre-charge the simulation watchdog by this many steps.
    SlowLoop { steps: u64 },
    /// Fail the next `times` IO attempts at the site, then succeed.
    IoError { times: u64 },
}

/// Armed faults keyed by `(site, label_prefix)`.  A site fires the
/// first entry (in key order, deterministically) whose site matches
/// and whose prefix starts the label.
static ARMED: Mutex<BTreeMap<(String, String), Fault>> =
    Mutex::new(BTreeMap::new());

/// Fast-path guard: true iff any fault is armed anywhere.
static ANY_ARMED: AtomicBool = AtomicBool::new(false);

fn table() -> std::sync::MutexGuard<'static, BTreeMap<(String, String), Fault>>
{
    // A panic *while armed* is expected (that is the point of
    // `Fault::Panic`), so recover from poisoning instead of cascading.
    ARMED.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Arm `fault` at `site` for labels starting with `label_prefix`.
/// Re-arming the same `(site, prefix)` replaces the previous fault.
pub fn arm(site: &str, label_prefix: &str, fault: Fault) {
    let mut t = table();
    t.insert((site.to_string(), label_prefix.to_string()), fault);
    ANY_ARMED.store(true, Ordering::Release);
}

/// Disarm one `(site, prefix)` entry.
pub fn disarm(site: &str, label_prefix: &str) {
    let mut t = table();
    t.remove(&(site.to_string(), label_prefix.to_string()));
    ANY_ARMED.store(!t.is_empty(), Ordering::Release);
}

/// Disarm everything.
pub fn clear() {
    let mut t = table();
    t.clear();
    ANY_ARMED.store(false, Ordering::Release);
}

/// True iff any fault is armed (one relaxed load — the hot-path guard).
#[inline]
pub fn any_armed() -> bool {
    ANY_ARMED.load(Ordering::Relaxed)
}

/// The fault armed at `site` for `label`, if any.
fn lookup(site: &str, label: &str) -> Option<Fault> {
    if !any_armed() {
        return None;
    }
    let t = table();
    t.iter()
        .find(|((s, prefix), _)| s == site && label.starts_with(prefix.as_str()))
        .map(|(_, f)| f.clone())
}

/// Panic iff a [`Fault::Panic`] is armed at `(site, label)`.  Call at
/// the top of a quarantinable unit of work.
#[inline]
pub fn fire_panic(site: &str, label: &str) {
    if !any_armed() {
        return;
    }
    if let Some(Fault::Panic) = lookup(site, label) {
        panic!("injected panic at {site}: {label}");
    }
}

/// Steps to pre-charge a watchdog counter with, when a
/// [`Fault::SlowLoop`] is armed at `(site, label)` (0 otherwise).
#[inline]
pub fn slow_penalty(site: &str, label: &str) -> u64 {
    if !any_armed() {
        return 0;
    }
    match lookup(site, label) {
        Some(Fault::SlowLoop { steps }) => steps,
        _ => 0,
    }
}

/// Take one synthetic IO error if a [`Fault::IoError`] with remaining
/// charges is armed at `(site, label)`; decrements the charge count.
#[inline]
pub fn take_io_error(site: &str, label: &str) -> Option<std::io::Error> {
    if !any_armed() {
        return None;
    }
    let mut t = table();
    let hit = t
        .iter_mut()
        .find(|((s, prefix), f)| {
            s == site
                && label.starts_with(prefix.as_str())
                && matches!(f, Fault::IoError { times } if *times > 0)
        })
        .map(|(_, f)| f);
    if let Some(Fault::IoError { times }) = hit {
        *times -= 1;
        return Some(std::io::Error::new(
            std::io::ErrorKind::Interrupted,
            format!("injected io error at {site}: {label}"),
        ));
    }
    None
}

/// Does any label in `labels` start with `prefix`?  Shared helper for
/// label-prefix hooks (the fuzz tournament's injected-violation check
/// uses it against scenario event labels).
pub fn prefix_hit<'a, I>(prefix: &str, labels: I) -> bool
where
    I: IntoIterator<Item = &'a str>,
{
    labels.into_iter().any(|l| l.starts_with(prefix))
}

/// RAII guard: arms a fault on construction, disarms it on drop, so a
/// panicking test cannot leave the process armed.
pub struct Armed {
    site: String,
    prefix: String,
}

impl Armed {
    pub fn new(site: &str, label_prefix: &str, fault: Fault) -> Armed {
        arm(site, label_prefix, fault);
        Armed {
            site: site.to_string(),
            prefix: label_prefix.to_string(),
        }
    }
}

impl Drop for Armed {
    fn drop(&mut self) {
        disarm(&self.site, &self.prefix);
    }
}

/// Instrumented site names (one per consulted code path, so tests and
/// `--inject-fault` target exactly one layer).
pub mod sites {
    /// Pooled sweep points ([`crate::coordinator`]); labels are
    /// `"{scheduler}@{rate}"`.
    pub const SWEEP_POINT: &str = "coordinator.sweep_point";
    /// The simulation event loop's watchdog counter
    /// ([`crate::sim::SimWorker::run`]); labels are the scheduler name.
    pub const SIM_LOOP: &str = "sim.run_loop";
    /// The store's atomic JSON writes ([`crate::store`]); labels are
    /// the destination file name.
    pub const STORE_WRITE: &str = "store.write_json";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_registry_is_inert() {
        // Distinct site: other tests may be armed concurrently.
        let site = "test.inert";
        assert_eq!(slow_penalty(site, "anything"), 0);
        assert!(take_io_error(site, "anything").is_none());
        fire_panic(site, "anything"); // must not panic
    }

    #[test]
    fn panic_fires_only_on_matching_prefix() {
        let site = "test.panic_site";
        let _g = Armed::new(site, "bad-", Fault::Panic);
        fire_panic(site, "good-point"); // no match, no panic
        let err = std::panic::catch_unwind(|| {
            fire_panic(site, "bad-point");
        });
        assert!(err.is_err(), "matching label must panic");
    }

    #[test]
    fn slow_loop_reports_penalty_and_io_error_counts_down() {
        let site = "test.slow_site";
        let _g = Armed::new(site, "x", Fault::SlowLoop { steps: 500 });
        assert_eq!(slow_penalty(site, "x1"), 500);
        assert_eq!(slow_penalty(site, "y1"), 0);

        let io_site = "test.io_site";
        let _g2 = Armed::new(io_site, "f", Fault::IoError { times: 2 });
        assert!(take_io_error(io_site, "file.json").is_some());
        assert!(take_io_error(io_site, "file.json").is_some());
        assert!(
            take_io_error(io_site, "file.json").is_none(),
            "charges exhausted"
        );
    }

    #[test]
    fn armed_guard_disarms_on_drop() {
        let site = "test.guard_site";
        {
            let _g = Armed::new(site, "", Fault::SlowLoop { steps: 1 });
            assert_eq!(slow_penalty(site, "any"), 1);
        }
        assert_eq!(slow_penalty(site, "any"), 0);
    }

    #[test]
    fn prefix_hit_matches_any_label() {
        assert!(prefix_hit("rate=", ["x", "rate=2"].into_iter()));
        assert!(!prefix_hit("rate=", ["x", "y"].into_iter()));
        assert!(!prefix_hit("rate=", std::iter::empty()));
    }
}
