//! Scheduler-behaviour integration tests: the Figure-3 orderings and the
//! qualitative claims of the paper's §3, verified end-to-end.

use ds3r::app::suite::{self, WifiParams};
use ds3r::config::SimConfig;
use ds3r::coordinator::{self, SweepPoint};
use ds3r::platform::Platform;
use ds3r::sim::Simulation;

fn base(jobs: usize) -> SimConfig {
    let mut c = SimConfig::default();
    c.max_jobs = jobs;
    c.warmup_jobs = jobs / 10;
    c
}

fn run_at(sched: &str, rate: f64, jobs: usize) -> f64 {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let mut c = base(jobs);
    c.scheduler = sched.into();
    c.injection_rate_per_ms = rate;
    Simulation::build(&p, &apps, &c)
        .unwrap()
        .run()
        .avg_job_latency_us()
}

#[test]
fn fig3_low_rate_schedulers_perform_similar() {
    // "All schedulers perform similar at low job injection rates
    //  (less than 5 job/ms)."
    let met = run_at("met", 1.0, 300);
    let etf = run_at("etf", 1.0, 300);
    let ilp = run_at("ilp", 1.0, 300);
    let max = met.max(etf).max(ilp);
    let min = met.min(etf).min(ilp);
    assert!(
        (max - min) / min < 0.15,
        "low-rate spread too wide: met={met} etf={etf} ilp={ilp}"
    );
}

#[test]
fn fig3_met_degrades_past_5_jobs_per_ms() {
    // "as the job injection rates increases, the schedule from MET
    //  results in higher execution time"
    let at4 = run_at("met", 4.0, 300);
    let at7 = run_at("met", 7.0, 300);
    assert!(
        at7 > 3.0 * at4,
        "MET did not collapse: {at4} -> {at7}"
    );
}

#[test]
fn fig3_high_rate_ordering_etf_ilp_met() {
    // "The performance of ETF is superior in comparison to the others."
    for rate in [6.0, 8.0, 10.0] {
        let met = run_at("met", rate, 300);
        let etf = run_at("etf", rate, 300);
        let ilp = run_at("ilp", rate, 300);
        assert!(etf <= ilp, "rate {rate}: etf {etf} > ilp {ilp}");
        assert!(ilp < met, "rate {rate}: ilp {ilp} >= met {met}");
    }
}

#[test]
fn etf_beats_random_and_rr_under_load() {
    let etf = run_at("etf", 6.0, 300);
    let random = run_at("random", 6.0, 300);
    let rr = run_at("rr", 6.0, 300);
    assert!(etf < random, "etf {etf} vs random {random}");
    assert!(etf < rr, "etf {etf} vs rr {rr}");
}

#[test]
fn heft_is_competitive_with_etf() {
    // HEFT and ETF should be within ~2x of each other below saturation.
    let etf = run_at("etf", 4.0, 300);
    let heft = run_at("heft", 4.0, 300);
    assert!(heft < 2.0 * etf, "heft {heft} vs etf {etf}");
}

#[test]
fn met_lb_ablation_outperforms_naive_met_under_load() {
    // Instance pinning is most of MET's collapse (see sched::met docs).
    let met = run_at("met", 7.0, 300);
    let met_lb = run_at("met-lb", 7.0, 300);
    assert!(
        met_lb < met / 2.0,
        "met-lb {met_lb} should be far below met {met}"
    );
}

#[test]
fn sweep_reproduces_fig3_shape_summary() {
    // The same check the CLI prints, as a test: run the actual sweep
    // machinery end to end.
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let rates = [1.0, 7.0];
    let pts: Vec<SweepPoint> =
        coordinator::fig3_points(&["met", "etf", "ilp"], &rates, 42);
    let res =
        coordinator::run_sweep(&p, &apps, &base(250), &pts, 6).unwrap();
    let text = ds3r::cli::fig3_shape_analysis(&res, &rates);
    assert!(
        text.contains("HOLDS"),
        "fig3 ordering violated:\n{text}"
    );
}

#[test]
fn scheduler_decisions_respect_support_constraints() {
    // Running every scheduler on the mixed suite must never starve:
    // all jobs complete, which implies no assignment to unsupported PEs
    // was committed (those are rejected by the kernel).
    let p = Platform::table2_soc();
    let apps = vec![
        suite::wifi_tx(WifiParams { symbols: 3 }),
        suite::wifi_rx(WifiParams { symbols: 2 }),
        suite::pulse_doppler(suite::RadarParams { pulses: 4 }),
    ];
    for sched in ["met", "met-lb", "etf", "ilp", "heft", "random", "rr"] {
        let mut c = base(60);
        c.scheduler = sched.into();
        c.injection_rate_per_ms = 0.5;
        let r = Simulation::build(&p, &apps, &c).unwrap().run();
        assert_eq!(r.completed_jobs, 60, "{sched} starved");
    }
}

#[test]
fn max_ready_window_does_not_lose_tasks() {
    // Tiny scheduler window under burst load: everything still finishes.
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let mut c = base(150);
    c.scheduler = "etf".into();
    c.injection_rate_per_ms = 8.0;
    c.max_ready = 4;
    let r = Simulation::build(&p, &apps, &c).unwrap().run();
    assert_eq!(r.completed_jobs, 150);
}
