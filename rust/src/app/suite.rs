//! The paper's five-application benchmark suite.
//!
//! "The framework includes five reference applications from wireless
//! communication and radar processing domains" (§1): WiFi transmitter,
//! WiFi receiver, low-power single-carrier TX/RX, range detection, and
//! pulse Doppler.
//!
//! **Profiling provenance** (DESIGN.md §Substitutions): WiFi-TX latencies
//! are Table 1 of the paper, verbatim (µs on HW accelerator / Odroid A7 /
//! Odroid A15).  The remaining applications are not tabulated in the WiP
//! paper; their profiles are synthesized to be consistent with Table 1's
//! measured ratios (accelerator ≈ 7-18× faster than A15 for FFT-class
//! kernels, A15 ≈ 2.5× faster than A7 for control-dominated kernels).
//!
//! Class names used by profiles: `A15`, `A7`, `ACC_SCR` (scrambler-
//! encoder engine), `ACC_FFT` (FFT engine).  A task lacking an entry for
//! a class cannot execute there (Table 1's empty cells).

use super::{AppGraph, DagBuilder};

/// Parameters for the WiFi transmitter/receiver frame structure.
#[derive(Debug, Clone, Copy)]
pub struct WifiParams {
    /// OFDM symbols per frame.  The frame traverses the Figure-2
    /// pipeline symbol by symbol: `scrambler-encoder` → S sequential
    /// `interleaver→qpsk→pilot→ifft` segments → `crc` (a transmitter
    /// processes the frame in stream order, so segments are serial
    /// within one job; parallelism comes from job interleaving).  The
    /// default of 12 calibrates the Table-2 platform so the MET
    /// scheduler saturates just above 5 jobs/ms, reproducing the
    /// Figure-3 knee position.
    pub symbols: usize,
}

/// Same frame, but with per-symbol chains fanned out in parallel between
/// scrambler and CRC (a batch-processing transmitter).  Used by the
/// ablation benches to study how DAG width shifts the Figure-3 curves.
pub fn wifi_tx_parallel(p: WifiParams) -> AppGraph {
    let s = p.symbols.max(1);
    let mut b = DagBuilder::new("wifi-tx-par");
    let scr = b.task(
        "scrambler-encoder",
        &[("ACC_SCR", 8.0), ("A7", 22.0), ("A15", 10.0)],
        &[],
        1024,
    );
    let mut ifft_ids = Vec::with_capacity(s);
    for i in 0..s {
        let il = b.task(
            format!("interleaver-{i}"),
            &[("A7", 10.0), ("A15", 4.0)],
            &[scr],
            192,
        );
        let q = b.task(
            format!("qpsk-{i}"),
            &[("A7", 15.0), ("A15", 8.0)],
            &[il],
            384,
        );
        let pi = b.task(
            format!("pilot-{i}"),
            &[("A7", 5.0), ("A15", 3.0)],
            &[q],
            512,
        );
        let f = b.task(
            format!("ifft-{i}"),
            &[("ACC_FFT", 16.0), ("A7", 296.0), ("A15", 118.0)],
            &[pi],
            512,
        );
        ifft_ids.push(f);
    }
    b.task("crc", &[("A7", 5.0), ("A15", 3.0)], &ifft_ids, 64);
    b.build().expect("wifi-tx-par DAG is valid")
}

impl Default for WifiParams {
    fn default() -> Self {
        WifiParams { symbols: 12 }
    }
}

/// WiFi transmitter (Figure 2 + Table 1).
///
/// DAG: `scrambler-encoder` → sequential per-symbol segments
/// (`interleaver_i` → `qpsk_i` → `pilot_i` → `ifft_i`) → `crc`,
/// i.e. the Figure-2 pipeline traversed symbol by symbol.
pub fn wifi_tx(p: WifiParams) -> AppGraph {
    let s = p.symbols.max(1);
    let mut b = DagBuilder::new("wifi-tx");
    // Table 1, row "Scrambler Enc.": 8 / 22 / 10 µs.
    let scr = b.task(
        "scrambler-encoder",
        &[("ACC_SCR", 8.0), ("A7", 22.0), ("A15", 10.0)],
        &[],
        1024,
    );
    let mut prev = scr;
    for i in 0..s {
        // Table 1: Interleaver 10/4, QPSK 15/8, Pilot 5/3, IFFT 16/296/118.
        let il = b.task(
            format!("interleaver-{i}"),
            &[("A7", 10.0), ("A15", 4.0)],
            &[prev],
            192,
        );
        let q = b.task(
            format!("qpsk-{i}"),
            &[("A7", 15.0), ("A15", 8.0)],
            &[il],
            384,
        );
        let pi = b.task(
            format!("pilot-{i}"),
            &[("A7", 5.0), ("A15", 3.0)],
            &[q],
            512,
        );
        prev = b.task(
            format!("ifft-{i}"),
            &[("ACC_FFT", 16.0), ("A7", 296.0), ("A15", 118.0)],
            &[pi],
            512,
        );
    }
    // Table 1, row "CRC": 5 / 3 µs.
    b.task("crc", &[("A7", 5.0), ("A15", 3.0)], &[prev], 64);
    b.build().expect("wifi-tx DAG is valid")
}

/// WiFi receiver: the inverse pipeline plus a Viterbi decoder, the
/// dominant compute stage (decoder is core-only on the Table-2 SoC).
pub fn wifi_rx(p: WifiParams) -> AppGraph {
    let s = p.symbols.max(1);
    let mut b = DagBuilder::new("wifi-rx");
    let mf = b.task(
        "match-filter",
        &[("A7", 80.0), ("A15", 32.0)],
        &[],
        2048,
    );
    let pay = b.task(
        "payload-extract",
        &[("A7", 12.0), ("A15", 5.0)],
        &[mf],
        2048,
    );
    let mut dec_ids = Vec::with_capacity(s);
    for i in 0..s {
        let fft = b.task(
            format!("fft-{i}"),
            &[("ACC_FFT", 16.0), ("A7", 296.0), ("A15", 118.0)],
            &[pay],
            512,
        );
        let pe = b.task(
            format!("pilot-extract-{i}"),
            &[("A7", 7.0), ("A15", 3.0)],
            &[fft],
            448,
        );
        let dq = b.task(
            format!("qpsk-demod-{i}"),
            &[("A7", 18.0), ("A15", 9.0)],
            &[pe],
            384,
        );
        let di = b.task(
            format!("deinterleaver-{i}"),
            &[("A7", 11.0), ("A15", 5.0)],
            &[dq],
            192,
        );
        let vd = b.task(
            format!("viterbi-{i}"),
            &[("A7", 570.0), ("A15", 190.0)],
            &[di],
            96,
        );
        dec_ids.push(vd);
    }
    let desc = b.task(
        "descrambler",
        &[("ACC_SCR", 8.0), ("A7", 22.0), ("A15", 10.0)],
        &dec_ids,
        1024,
    );
    b.task("crc-check", &[("A7", 5.0), ("A15", 3.0)], &[desc], 16);
    b.build().expect("wifi-rx DAG is valid")
}

/// Low-power single-carrier transmitter: short control-dominated chain
/// (the paper's "low-power single-carrier" reference application).
pub fn single_carrier_tx() -> AppGraph {
    let mut b = DagBuilder::new("sc-tx");
    let scr = b.task(
        "scrambler",
        &[("ACC_SCR", 8.0), ("A7", 22.0), ("A15", 10.0)],
        &[],
        256,
    );
    let m = b.task(
        "bpsk-mod",
        &[("A7", 14.0), ("A15", 6.0)],
        &[scr],
        512,
    );
    let ps = b.task(
        "pulse-shape-fir",
        &[("A7", 90.0), ("A15", 35.0)],
        &[m],
        1024,
    );
    b.task("crc", &[("A7", 5.0), ("A15", 3.0)], &[ps], 64);
    b.build().expect("sc-tx DAG is valid")
}

/// Low-power single-carrier receiver.
pub fn single_carrier_rx() -> AppGraph {
    let mut b = DagBuilder::new("sc-rx");
    let mf = b.task(
        "match-filter",
        &[("A7", 105.0), ("A15", 40.0)],
        &[],
        1024,
    );
    let d = b.task(
        "bpsk-demod",
        &[("A7", 18.0), ("A15", 8.0)],
        &[mf],
        512,
    );
    let ds = b.task(
        "descrambler",
        &[("ACC_SCR", 8.0), ("A7", 22.0), ("A15", 10.0)],
        &[d],
        256,
    );
    b.task("crc-check", &[("A7", 5.0), ("A15", 3.0)], &[ds], 16);
    b.build().expect("sc-rx DAG is valid")
}

/// Parameters for the radar applications.
#[derive(Debug, Clone, Copy)]
pub struct RadarParams {
    /// Pulses per coherent processing interval (pulse Doppler) or
    /// chirp segments (range detection).
    pub pulses: usize,
}

impl Default for RadarParams {
    fn default() -> Self {
        RadarParams { pulses: 16 }
    }
}

/// Range detection: pulse compression by FFT → conjugate multiply with
/// the reference chirp → IFFT → magnitude → peak detection.
pub fn range_detection(p: RadarParams) -> AppGraph {
    let seg = p.pulses.max(1);
    let mut b = DagBuilder::new("range-detection");
    let src = b.task(
        "adc-capture",
        &[("A7", 9.0), ("A15", 4.0)],
        &[],
        4096,
    );
    let mut peaks = Vec::with_capacity(seg);
    for i in 0..seg {
        let f = b.task(
            format!("fft-{i}"),
            &[("ACC_FFT", 16.0), ("A7", 296.0), ("A15", 118.0)],
            &[src],
            512,
        );
        let m = b.task(
            format!("ref-multiply-{i}"),
            &[("A7", 30.0), ("A15", 12.0)],
            &[f],
            512,
        );
        let inv = b.task(
            format!("ifft-{i}"),
            &[("ACC_FFT", 16.0), ("A7", 296.0), ("A15", 118.0)],
            &[m],
            512,
        );
        let a = b.task(
            format!("magnitude-{i}"),
            &[("A7", 20.0), ("A15", 8.0)],
            &[inv],
            256,
        );
        peaks.push(a);
    }
    b.task(
        "peak-detect",
        &[("A7", 26.0), ("A15", 10.0)],
        &peaks,
        32,
    );
    b.build().expect("range-detection DAG is valid")
}

/// Pulse Doppler: per-pulse range FFTs, corner turn, per-bin Doppler
/// FFTs, then CFAR detection — the FFT-heaviest app in the suite.
pub fn pulse_doppler(p: RadarParams) -> AppGraph {
    let pulses = p.pulses.max(1);
    let doppler_bins = (pulses / 2).max(1);
    let mut b = DagBuilder::new("pulse-doppler");
    let src = b.task(
        "adc-capture",
        &[("A7", 9.0), ("A15", 4.0)],
        &[],
        8192,
    );
    let mut range_ffts = Vec::with_capacity(pulses);
    for i in 0..pulses {
        let f = b.task(
            format!("range-fft-{i}"),
            &[("ACC_FFT", 16.0), ("A7", 296.0), ("A15", 118.0)],
            &[src],
            512,
        );
        range_ffts.push(f);
    }
    let ct = b.task(
        "corner-turn",
        &[("A7", 46.0), ("A15", 18.0)],
        &range_ffts,
        8192,
    );
    let mut dops = Vec::with_capacity(doppler_bins);
    for i in 0..doppler_bins {
        let f = b.task(
            format!("doppler-fft-{i}"),
            &[("ACC_FFT", 16.0), ("A7", 296.0), ("A15", 118.0)],
            &[ct],
            512,
        );
        dops.push(f);
    }
    b.task(
        "cfar-detect",
        &[("A7", 120.0), ("A15", 45.0)],
        &dops,
        64,
    );
    b.build().expect("pulse-doppler DAG is valid")
}

/// All five reference applications at their default parameters.
pub fn all_default() -> Vec<AppGraph> {
    vec![
        wifi_tx(WifiParams::default()),
        wifi_rx(WifiParams::default()),
        single_carrier_tx(),
        single_carrier_rx(),
        range_detection(RadarParams::default()),
        pulse_doppler(RadarParams::default()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wifi_tx_single_symbol_is_fig2_pipeline() {
        // With one symbol the DAG is exactly the Figure-2 chain:
        // scrambler -> interleaver -> qpsk -> pilot -> ifft -> crc.
        let g = wifi_tx(WifiParams { symbols: 1 });
        assert_eq!(g.len(), 6);
        assert_eq!(g.sources(), vec![0]);
        assert_eq!(g.sinks(), vec![5]);
        for i in 1..6 {
            assert_eq!(g.tasks[i].preds, vec![i - 1]);
        }
    }

    #[test]
    fn wifi_tx_table1_values_verbatim() {
        let g = wifi_tx(WifiParams { symbols: 1 });
        let by_name = |n: &str| {
            g.tasks.iter().find(|t| t.name.starts_with(n)).unwrap()
        };
        let scr = by_name("scrambler-encoder");
        assert_eq!(scr.exec_us["ACC_SCR"], 8.0);
        assert_eq!(scr.exec_us["A7"], 22.0);
        assert_eq!(scr.exec_us["A15"], 10.0);
        let il = by_name("interleaver");
        assert_eq!(il.exec_us["A7"], 10.0);
        assert_eq!(il.exec_us["A15"], 4.0);
        assert!(!il.exec_us.contains_key("ACC_FFT"));
        let q = by_name("qpsk");
        assert_eq!(q.exec_us["A7"], 15.0);
        assert_eq!(q.exec_us["A15"], 8.0);
        let pi = by_name("pilot");
        assert_eq!(pi.exec_us["A7"], 5.0);
        assert_eq!(pi.exec_us["A15"], 3.0);
        let f = by_name("ifft");
        assert_eq!(f.exec_us["ACC_FFT"], 16.0);
        assert_eq!(f.exec_us["A7"], 296.0);
        assert_eq!(f.exec_us["A15"], 118.0);
        let crc = by_name("crc");
        assert_eq!(crc.exec_us["A7"], 5.0);
        assert_eq!(crc.exec_us["A15"], 3.0);
    }

    #[test]
    fn wifi_tx_frame_structure() {
        let s = 12;
        let g = wifi_tx(WifiParams { symbols: s });
        assert_eq!(g.len(), 2 + 4 * s);
        assert_eq!(g.sources().len(), 1);
        assert_eq!(g.sinks().len(), 1);
        // Serial pipeline: width 1 — all schedulers coincide unloaded.
        assert_eq!(g.max_width(), 1);
        // Critical path: scr(8) + s*(4+8+3+16) + crc(3) = 11 + 31 s.
        assert!(
            (g.critical_path_us() - (11.0 + 31.0 * s as f64)).abs() < 1e-9
        );
        // Parallel ablation variant keeps the same work, width s.
        let gp = wifi_tx_parallel(WifiParams { symbols: s });
        assert_eq!(gp.max_width(), s);
        assert!((gp.total_work_us() - g.total_work_us()).abs() < 1e-9);
        assert!((gp.critical_path_us() - 42.0).abs() < 1e-9);
    }

    #[test]
    fn all_apps_valid_and_nontrivial() {
        for g in all_default() {
            assert!(g.len() >= 4, "{} too small", g.name);
            assert!(!g.sources().is_empty());
            assert!(!g.sinks().is_empty());
            assert!(g.critical_path_us() > 0.0);
            // Every task must be reachable: sum of level sizes == n is
            // implied by construction; check total work sane instead.
            assert!(g.total_work_us() > g.critical_path_us() * 0.5);
        }
    }

    #[test]
    fn accelerator_ratios_consistent_with_table1() {
        // FFT-class tasks must keep the measured acc/A15/A7 ratios
        // everywhere in the suite (DESIGN.md substitution rule).
        for g in all_default() {
            for t in &g.tasks {
                if let Some(&acc) = t.exec_us.get("ACC_FFT") {
                    let a15 = t.exec_us["A15"];
                    let a7 = t.exec_us["A7"];
                    assert!((a15 / acc - 118.0 / 16.0).abs() < 1e-9);
                    assert!((a7 / a15 - 296.0 / 118.0).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn pulse_doppler_is_fft_heavy() {
        let g = pulse_doppler(RadarParams { pulses: 16 });
        let ffts = g
            .tasks
            .iter()
            .filter(|t| t.exec_us.contains_key("ACC_FFT"))
            .count();
        assert_eq!(ffts, 16 + 8);
    }

    #[test]
    fn param_floors() {
        // Degenerate params are clamped, not panicking.
        assert!(wifi_tx(WifiParams { symbols: 0 }).len() >= 6);
        assert!(range_detection(RadarParams { pulses: 0 }).len() >= 4);
    }
}
