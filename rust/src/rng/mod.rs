//! Deterministic pseudo-random numbers and distributions.
//!
//! The simulator must be exactly reproducible from a seed (the paper's
//! experiments sweep stochastic workloads), and the offline build has no
//! `rand` crate, so DS3R ships its own small, well-tested generator:
//! **xoshiro256++** seeded through **SplitMix64** (the reference seeding
//! procedure recommended by the xoshiro authors), plus the distributions
//! the framework needs — uniform, exponential (Poisson arrivals), normal
//! (profile jitter) and discrete choice.

/// SplitMix64: used to expand a 64-bit seed into xoshiro state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = sm.next_u64();
        }
        // All-zero state is invalid for xoshiro; SplitMix64 cannot emit
        // four zeros in a row, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    /// Derive an independent stream (used to give each subsystem its own
    /// generator so adding draws in one place never perturbs another).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Snapshot the 256-bit internal state — the DSE engine checkpoints
    /// this so a resumed search continues the exact random stream.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.  An all-zero
    /// state is invalid for xoshiro and is mapped to a fixed non-zero
    /// word (matching the constructor's guard).
    pub fn from_state(s: [u64; 4]) -> Rng {
        if s == [0, 0, 0, 0] {
            return Rng::new(0);
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's bounded rejection method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).  Drives Poisson
    /// job-arrival processes: inter-arrival times are Exp(lambda).
    #[inline]
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        // 1 - f64() is in (0, 1], so ln() is finite.
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Standard normal via Box-Muller (used for execution-time jitter).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64(); // (0, 1]
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        mean + std * r * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick an index according to non-negative `weights`.
    pub fn choose_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "choose_weighted: all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vectors() {
        // Reference values for seed 1234567 (from the public-domain
        // splitmix64.c reference implementation).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.uniform(-3.0, 9.0);
            assert!((-3.0..9.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            // Expected 10_000; allow 5 sigma (~±475).
            assert!((c as i64 - 10_000).abs() < 600, "counts={counts:?}");
        }
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(13);
        let lambda = 0.2; // mean 5
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| r.exp(lambda)).sum();
        let mean = sum / n as f64;
        assert!((mean - 5.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn exp_is_positive_and_finite() {
        let mut r = Rng::new(17);
        for _ in 0..100_000 {
            let x = r.exp(3.0);
            assert!(x.is_finite() && x >= 0.0);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(19);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(10.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!((mean - 10.0).abs() < 0.03, "mean={mean}");
        assert!((var - 4.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn choose_weighted_respects_weights() {
        let mut r = Rng::new(23);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.choose_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut a = Rng::new(97);
        for _ in 0..37 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let resumed: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        assert_eq!(tail, resumed);
        // All-zero state maps to a usable generator, not a stuck one.
        let mut z = Rng::from_state([0, 0, 0, 0]);
        assert_ne!(z.next_u64(), z.next_u64());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(31);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
