//! Dynamic thermal-power management: DVFS governors and DTPM policies.
//!
//! "the proposed framework also features built-in DVFS governors deployed
//! on commercial SoCs" (paper §1): `performance`, `powersave`,
//! `ondemand` (Linux-style utilization ramp) and `userspace` are
//! provided, plus two DTPM policies layered on top of the governor
//! decision: a thermal-throttle trip (cap OPP while a trip temperature
//! is exceeded, with hysteresis) and an SoC power cap.
//!
//! The per-epoch flow inside the simulation kernel:
//!
//! ```text
//!   utilization -> governor -> requested OPP
//!              -> thermal throttle / power cap -> granted OPP
//!              -> power model -> thermal step (rust or XLA artifact)
//! ```

use crate::config::DtpmConfig;
use crate::platform::Opp;
#[cfg(test)]
use crate::platform::Platform;
use crate::{Error, Result};

/// Per-cluster DVFS governor interface.
pub trait Governor {
    fn name(&self) -> &str;
    /// Choose the OPP *index* for a cluster given its utilization over
    /// the last epoch (max over member PEs, Linux-style) and the current
    /// index.  `opps` is ascending in frequency.
    fn decide(
        &mut self,
        cluster: usize,
        utilization: f64,
        current_idx: usize,
        opps: &[Opp],
    ) -> usize;
}

/// Always the highest OPP (Linux `performance`).
#[derive(Debug, Default)]
pub struct Performance;

impl Governor for Performance {
    fn name(&self) -> &str {
        "performance"
    }
    fn decide(&mut self, _c: usize, _u: f64, _i: usize, opps: &[Opp]) -> usize {
        opps.len() - 1
    }
}

/// Always the lowest OPP (Linux `powersave`).
#[derive(Debug, Default)]
pub struct Powersave;

impl Governor for Powersave {
    fn name(&self) -> &str {
        "powersave"
    }
    fn decide(&mut self, _c: usize, _u: f64, _i: usize, _o: &[Opp]) -> usize {
        0
    }
}

/// Linux `ondemand`: jump to max above `up_threshold`, otherwise scale
/// frequency proportionally to utilization (then snap to the lowest OPP
/// that covers the target).
#[derive(Debug)]
pub struct Ondemand {
    pub up_threshold: f64,
}

impl Default for Ondemand {
    fn default() -> Self {
        Ondemand { up_threshold: 0.80 }
    }
}

impl Governor for Ondemand {
    fn name(&self) -> &str {
        "ondemand"
    }
    fn decide(
        &mut self,
        _c: usize,
        util: f64,
        _current: usize,
        opps: &[Opp],
    ) -> usize {
        if util >= self.up_threshold {
            return opps.len() - 1;
        }
        // next_freq = max_freq * util / up_threshold  (kernel formula).
        let target = opps[opps.len() - 1].freq_mhz * util / self.up_threshold;
        opps.iter()
            .position(|o| o.freq_mhz + 1e-9 >= target)
            .unwrap_or(opps.len() - 1)
    }
}

/// Fixed user-selected frequency (Linux `userspace`).
#[derive(Debug)]
pub struct Userspace {
    pub target_mhz: f64,
}

impl Governor for Userspace {
    fn name(&self) -> &str {
        "userspace"
    }
    fn decide(&mut self, _c: usize, _u: f64, _i: usize, opps: &[Opp]) -> usize {
        opps.iter()
            .position(|o| o.freq_mhz + 1e-9 >= self.target_mhz)
            .unwrap_or(opps.len() - 1)
    }
}

/// Construct a governor by name.
///
/// `explore-xla` is resolved by the simulation kernel itself (it needs
/// the batched PJRT artifact); the registry returns its fallback
/// behaviour (performance) for the epochs before the artifact is ready.
pub fn create_governor(cfg: &DtpmConfig) -> Result<Box<dyn Governor>> {
    match cfg.governor.as_str() {
        "performance" | "explore-xla" => Ok(Box::new(Performance)),
        "powersave" => Ok(Box::new(Powersave)),
        "ondemand" => Ok(Box::new(Ondemand::default())),
        "userspace" => {
            Ok(Box::new(Userspace { target_mhz: cfg.userspace_mhz }))
        }
        other => Err(Error::Config(format!(
            "unknown governor '{other}' \
             (performance, powersave, ondemand, userspace, explore-xla)"
        ))),
    }
}

/// Predictive DSE governor ("explore-xla"): every epoch, evaluate a grid
/// of candidate (big, LITTLE) OPP pairs through the **batched** DTPM
/// artifact (one PJRT call scores all K=16 candidates: predicted next
/// temperature + SoC power) and pick the lowest-power candidate that
/// (a) keeps the predicted hottest node below `t_limit_c` and (b) keeps
/// the predicted utilization of every DVFS cluster below ~95% so
/// throughput is not sacrificed.  This is the paper's "design space
/// exploration of DTPM techniques" running *inside* the loop, powered by
/// the Layer-1 Pallas kernel.
#[derive(Debug)]
pub struct ExploreDse {
    pub t_limit_c: f64,
    /// OPP-index candidates per (big, LITTLE) pair, filled at build time.
    pub grid: Vec<(usize, usize)>,
    pub picks: u64,
}

impl ExploreDse {
    /// A 4x4 subsample of the (big, LITTLE) OPP ladder = K=16 candidates.
    pub fn new(n_big_opps: usize, n_little_opps: usize, t_limit_c: f64) -> Self {
        let pick4 = |n: usize| -> Vec<usize> {
            if n <= 4 {
                (0..n).collect()
            } else {
                vec![0, n / 3, 2 * n / 3, n - 1]
            }
        };
        let mut grid = Vec::with_capacity(16);
        for &b in &pick4(n_big_opps) {
            for &l in &pick4(n_little_opps) {
                grid.push((b, l));
            }
        }
        grid.truncate(16);
        ExploreDse { t_limit_c, grid, picks: 0 }
    }

    /// Choose the candidate index given per-candidate predictions.
    /// `feasible[k]` = utilization guard; returns the feasible candidate
    /// with minimal predicted power, falling back to the highest-
    /// frequency candidate (last in the grid) if none is feasible.
    pub fn choose(
        &mut self,
        p_sum: &[f64],
        t_peak_next_c: &[f64],
        feasible: &[bool],
    ) -> usize {
        self.picks += 1;
        let mut best = (f64::INFINITY, usize::MAX);
        for k in 0..self.grid.len().min(p_sum.len()) {
            if !feasible[k] || t_peak_next_c[k] > self.t_limit_c {
                continue;
            }
            if p_sum[k] < best.0 {
                best = (p_sum[k], k);
            }
        }
        if best.1 == usize::MAX {
            self.grid.len().min(p_sum.len()) - 1
        } else {
            best.1
        }
    }
}

/// Thermal-throttle policy with hysteresis: while any PE temperature is
/// above `trip_c`, cap the OPP index; release only below
/// `trip_c - hysteresis_c`.
#[derive(Debug)]
pub struct ThermalThrottle {
    pub trip_c: f64,
    pub hysteresis_c: f64,
    /// Max OPP index while throttled (0 = force minimum).
    pub capped_idx: usize,
    engaged: bool,
    pub engagements: u64,
}

impl ThermalThrottle {
    pub fn new(trip_c: f64) -> ThermalThrottle {
        ThermalThrottle {
            trip_c,
            hysteresis_c: 5.0,
            capped_idx: 0,
            engaged: false,
            engagements: 0,
        }
    }

    /// Apply the policy to a requested OPP index given the hottest PE
    /// temperature (absolute °C).  Runs per cluster per DTPM epoch;
    /// enabling it forces eager power/thermal integration (the lazy
    /// lane cannot defer epochs a policy observes).
    #[inline]
    pub fn apply(&mut self, requested_idx: usize, t_max_c: f64) -> usize {
        if self.engaged {
            if t_max_c < self.trip_c - self.hysteresis_c {
                self.engaged = false;
            }
        } else if t_max_c >= self.trip_c {
            self.engaged = true;
            self.engagements += 1;
        }
        if self.engaged {
            requested_idx.min(self.capped_idx)
        } else {
            requested_idx
        }
    }

    pub fn is_engaged(&self) -> bool {
        self.engaged
    }
}

/// SoC power cap: steps OPPs down one notch per epoch while the last
/// epoch's average power exceeded the cap, and back up when there is
/// at least 20% headroom.
#[derive(Debug)]
pub struct PowerCap {
    pub cap_w: f64,
    /// Current number of notches removed from the requested index.
    backoff: usize,
    pub violations: u64,
}

impl PowerCap {
    pub fn new(cap_w: f64) -> PowerCap {
        PowerCap { cap_w, backoff: 0, violations: 0 }
    }

    /// Runs per cluster per DTPM epoch; like the thermal throttle, an
    /// active cap forces eager power/thermal integration.
    #[inline]
    pub fn apply(&mut self, requested_idx: usize, last_power_w: f64) -> usize {
        if last_power_w > self.cap_w {
            self.backoff = (self.backoff + 1).min(16);
            self.violations += 1;
        } else if last_power_w < 0.8 * self.cap_w && self.backoff > 0 {
            self.backoff -= 1;
        }
        requested_idx.saturating_sub(self.backoff)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Platform;

    fn big_opps() -> Vec<Opp> {
        let p = Platform::table2_soc();
        p.classes[p.class_index("A15").unwrap()].opps.clone()
    }

    #[test]
    fn performance_always_max() {
        let opps = big_opps();
        let mut g = Performance;
        for u in [0.0, 0.3, 1.0] {
            assert_eq!(g.decide(0, u, 0, &opps), opps.len() - 1);
        }
    }

    #[test]
    fn powersave_always_min() {
        let opps = big_opps();
        let mut g = Powersave;
        assert_eq!(g.decide(0, 1.0, 5, &opps), 0);
    }

    #[test]
    fn ondemand_jumps_to_max_above_threshold() {
        let opps = big_opps();
        let mut g = Ondemand::default();
        assert_eq!(g.decide(0, 0.85, 0, &opps), opps.len() - 1);
        assert_eq!(g.decide(0, 1.0, 0, &opps), opps.len() - 1);
    }

    #[test]
    fn ondemand_scales_proportionally_below_threshold() {
        let opps = big_opps();
        let mut g = Ondemand::default();
        // util 0.4 / 0.8 threshold * 2000 MHz = 1000 MHz target.
        let idx = g.decide(0, 0.4, 0, &opps);
        assert!(opps[idx].freq_mhz >= 1000.0);
        assert!(idx < opps.len() - 1);
        // idle -> min.
        assert_eq!(g.decide(0, 0.0, 3, &opps), 0);
        // Monotone in utilization.
        let mut last = 0;
        for u in [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7] {
            let i = g.decide(0, u, 0, &opps);
            assert!(i >= last, "non-monotone at {u}");
            last = i;
        }
    }

    #[test]
    fn userspace_snaps_to_requested() {
        let opps = big_opps();
        let mut g = Userspace { target_mhz: 1000.0 };
        let idx = g.decide(0, 0.0, 0, &opps);
        assert_eq!(opps[idx].freq_mhz, 1000.0);
        let mut g = Userspace { target_mhz: 999999.0 };
        assert_eq!(g.decide(0, 0.0, 0, &opps), opps.len() - 1);
    }

    #[test]
    fn governor_registry() {
        let mut cfg = DtpmConfig::default();
        for name in ["performance", "powersave", "ondemand", "userspace"] {
            cfg.governor = name.into();
            assert_eq!(create_governor(&cfg).unwrap().name(), name);
        }
        cfg.governor = "warp-speed".into();
        assert!(create_governor(&cfg).is_err());
    }

    #[test]
    fn throttle_engages_and_releases_with_hysteresis() {
        let mut t = ThermalThrottle::new(85.0);
        assert_eq!(t.apply(9, 70.0), 9);
        assert!(!t.is_engaged());
        // Trip.
        assert_eq!(t.apply(9, 86.0), 0);
        assert!(t.is_engaged());
        // Still above release point (80): stays engaged.
        assert_eq!(t.apply(9, 82.0), 0);
        // Below release: free again.
        assert_eq!(t.apply(9, 79.0), 9);
        assert!(!t.is_engaged());
        assert_eq!(t.engagements, 1);
    }

    #[test]
    fn explore_grid_is_k16_for_table2() {
        let p = Platform::table2_soc();
        let n_big = p.classes[p.class_index("A15").unwrap()].opps.len();
        let n_little = p.classes[p.class_index("A7").unwrap()].opps.len();
        let e = ExploreDse::new(n_big, n_little, 85.0);
        assert_eq!(e.grid.len(), 16);
        // Grid spans the ladder ends.
        assert!(e.grid.contains(&(0, 0)));
        assert!(e.grid.contains(&(n_big - 1, n_little - 1)));
    }

    #[test]
    fn explore_choose_prefers_lowest_feasible_power() {
        let mut e = ExploreDse::new(10, 7, 85.0);
        let k = e.grid.len();
        let p_sum: Vec<f64> = (0..k).map(|i| 10.0 - i as f64 * 0.5).collect();
        let mut t_next = vec![50.0; k];
        let mut feasible = vec![true; k];
        // Lowest power is the last candidate.
        assert_eq!(e.choose(&p_sum, &t_next, &feasible), k - 1);
        // Thermal violation knocks it out.
        t_next[k - 1] = 90.0;
        assert_eq!(e.choose(&p_sum, &t_next, &feasible), k - 2);
        // Infeasible utilization knocks the next out too.
        feasible[k - 2] = false;
        assert_eq!(e.choose(&p_sum, &t_next, &feasible), k - 3);
        // Nothing feasible -> fall back to max-frequency candidate.
        let none = vec![false; k];
        assert_eq!(e.choose(&p_sum, &vec![50.0; k], &none), k - 1);
        assert_eq!(e.picks, 4);
    }

    #[test]
    fn power_cap_backs_off_and_recovers() {
        let mut c = PowerCap::new(5.0);
        assert_eq!(c.apply(9, 4.0), 9);
        assert_eq!(c.apply(9, 6.0), 8); // one notch
        assert_eq!(c.apply(9, 6.0), 7); // two
        assert_eq!(c.apply(9, 4.5), 7); // within cap but <20% headroom
        assert_eq!(c.apply(9, 3.0), 8); // recovering
        assert_eq!(c.apply(9, 3.0), 9);
        assert_eq!(c.violations, 2);
    }
}
