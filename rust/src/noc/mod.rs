//! Analytical interconnect and memory latency model.
//!
//! The paper: "the framework employs analytical latency models to
//! estimate interconnect delays on the SoC" and "the memory access and
//! on-chip interconnect latency are modeled by the proposed framework".
//!
//! DS3R models a 2-D mesh NoC with X-Y routing.  A producer→consumer
//! transfer of `bytes` between PEs `a` and `b` costs
//!
//! ```text
//!   latency = hops(a, b) * hop_latency + bytes / link_bandwidth
//!             + mem_latency                 (shared-memory staging)
//! ```
//!
//! plus an optional congestion factor that grows with tracked concurrent
//! flows (first-order contention model, can be disabled for ablations).
//! Same-PE transfers are free (data stays in local memory).

use crate::platform::Platform;

/// Interconnect model state.
#[derive(Debug, Clone)]
pub struct NocModel {
    hop_latency_us: f64,
    link_bandwidth: f64,
    mem_latency_us: f64,
    /// Precomputed Manhattan hop counts, `n_pes x n_pes` row-major.
    hops: Vec<u8>,
    n_pes: usize,
    /// Congestion modelling (None = contention-free).
    congestion: Option<CongestionState>,
}

#[derive(Debug, Clone)]
struct CongestionState {
    /// Exponential moving average of concurrent flows.
    ema_flows: f64,
    /// Flows currently in flight.
    active_flows: usize,
    /// Latency multiplier per concurrent flow beyond the first.
    alpha: f64,
}

impl NocModel {
    pub fn new(platform: &Platform, model_congestion: bool) -> NocModel {
        let n = platform.n_pes();
        let mut hops = vec![0u8; n * n];
        for a in 0..n {
            for b in 0..n {
                hops[a * n + b] = platform.hops(a, b) as u8;
            }
        }
        let mut m = NocModel {
            hop_latency_us: platform.noc.hop_latency_us,
            link_bandwidth: platform.noc.link_bandwidth,
            mem_latency_us: platform.noc.mem_latency_us,
            hops,
            n_pes: n,
            congestion: None,
        };
        // Single source of truth for the fresh congestion state — the
        // worker-reset path's `set_congestion(true)` must stay
        // bit-identical to `NocModel::new(p, true)`.
        m.set_congestion(model_congestion);
        m
    }

    #[inline]
    pub fn hops(&self, a: usize, b: usize) -> usize {
        self.hops[a * self.n_pes + b] as usize
    }

    /// Latency (µs) to move `bytes` from PE `src` to PE `dst`.
    #[inline]
    pub fn transfer_us(&self, src: usize, dst: usize, bytes: u64) -> f64 {
        if src == dst || bytes == 0 {
            return 0.0;
        }
        let base = self.hops(src, dst) as f64 * self.hop_latency_us
            + bytes as f64 / self.link_bandwidth
            + self.mem_latency_us;
        match &self.congestion {
            Some(c) => {
                let extra = (c.ema_flows - 1.0).max(0.0);
                base * (1.0 + c.alpha * extra)
            }
            None => base,
        }
    }

    /// Enable or disable congestion modelling, resetting its state to
    /// the fresh-model values either way.  Reused simulation workers
    /// flip this per run instead of rebuilding the hop table.
    pub fn set_congestion(&mut self, model_congestion: bool) {
        self.congestion = model_congestion.then(|| CongestionState {
            ema_flows: 0.0,
            active_flows: 0,
            alpha: 0.15,
        });
    }

    /// Record the start/end of a transfer (congestion tracking).  The
    /// simulation kernel calls these around each NoC transfer event.
    pub fn flow_started(&mut self) {
        if let Some(c) = &mut self.congestion {
            c.active_flows += 1;
            c.ema_flows =
                0.9 * c.ema_flows + 0.1 * c.active_flows as f64;
        }
    }

    pub fn flow_finished(&mut self) {
        if let Some(c) = &mut self.congestion {
            c.active_flows = c.active_flows.saturating_sub(1);
            c.ema_flows =
                0.9 * c.ema_flows + 0.1 * c.active_flows as f64;
        }
    }

    pub fn models_congestion(&self) -> bool {
        self.congestion.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Platform;

    fn model() -> NocModel {
        NocModel::new(&Platform::table2_soc(), false)
    }

    #[test]
    fn same_pe_is_free() {
        let m = model();
        assert_eq!(m.transfer_us(3, 3, 100_000), 0.0);
        assert_eq!(m.transfer_us(0, 1, 0), 0.0);
    }

    #[test]
    fn latency_grows_with_distance_and_bytes() {
        let p = Platform::table2_soc();
        let m = model();
        // Find a far pair and a near pair.
        let near = (0usize, 1usize);
        let mut far = (0usize, 0usize);
        let mut best = 0;
        for a in 0..p.n_pes() {
            for b in 0..p.n_pes() {
                if p.hops(a, b) > best {
                    best = p.hops(a, b);
                    far = (a, b);
                }
            }
        }
        assert!(
            m.transfer_us(far.0, far.1, 512)
                > m.transfer_us(near.0, near.1, 512)
        );
        assert!(
            m.transfer_us(0, 1, 8192) > m.transfer_us(0, 1, 64)
        );
    }

    #[test]
    fn hops_match_platform() {
        let p = Platform::table2_soc();
        let m = model();
        for a in 0..p.n_pes() {
            for b in 0..p.n_pes() {
                assert_eq!(m.hops(a, b), p.hops(a, b));
            }
        }
    }

    #[test]
    fn congestion_increases_latency() {
        let mut m = NocModel::new(&Platform::table2_soc(), true);
        let quiet = m.transfer_us(0, 5, 1024);
        for _ in 0..50 {
            m.flow_started();
        }
        let busy = m.transfer_us(0, 5, 1024);
        assert!(busy > quiet, "busy={busy} quiet={quiet}");
        for _ in 0..50 {
            m.flow_finished();
        }
        // EMA decays back toward quiet.
        let after = m.transfer_us(0, 5, 1024);
        assert!(after < busy);
    }

    #[test]
    fn latency_is_monotonic_in_active_flows() {
        let mut m = NocModel::new(&Platform::table2_soc(), true);
        let mut last = m.transfer_us(0, 5, 2048);
        let mut grew = false;
        for _ in 0..40 {
            m.flow_started();
            let cur = m.transfer_us(0, 5, 2048);
            assert!(
                cur >= last,
                "latency dropped while flows only started: {cur} < {last}"
            );
            grew |= cur > last;
            last = cur;
        }
        assert!(grew, "40 concurrent flows never raised latency");
        // Draining relaxes the model back toward quiet.  (The EMA lags
        // the instantaneous flow count, so the decay need not be
        // step-monotonic — only the end state is pinned.)
        let peak = last;
        for _ in 0..40 {
            m.flow_finished();
        }
        for _ in 0..60 {
            // Idle-tick the EMA down with zero active flows.
            m.flow_finished();
        }
        assert!(m.transfer_us(0, 5, 2048) < peak);
    }

    #[test]
    fn contention_free_matches_closed_form() {
        let p = Platform::table2_soc();
        let m = model();
        for (src, dst, bytes) in
            [(0usize, 1usize, 64u64), (0, 9, 2048), (3, 12, 777), (5, 6, 1)]
        {
            let expected = p.hops(src, dst) as f64 * p.noc.hop_latency_us
                + bytes as f64 / p.noc.link_bandwidth
                + p.noc.mem_latency_us;
            assert_eq!(
                m.transfer_us(src, dst, bytes),
                expected,
                "{src}->{dst} x{bytes}"
            );
        }
    }

    #[test]
    fn congestion_state_resets_between_simulations() {
        // Direct: a fresh model has no residual congestion.
        let p = Platform::table2_soc();
        let mut m1 = NocModel::new(&p, true);
        let quiet = m1.transfer_us(0, 5, 1024);
        for _ in 0..100 {
            m1.flow_started();
        }
        assert!(m1.transfer_us(0, 5, 1024) > quiet);
        let m2 = NocModel::new(&p, true);
        assert_eq!(m2.transfer_us(0, 5, 1024), quiet);

        // End-to-end: each Simulation builds its own NocModel, so two
        // identical congested runs are bit-identical — run 2 cannot see
        // run 1's flow history.
        use crate::app::suite::{self, WifiParams};
        use crate::config::SimConfig;
        use crate::sim::Simulation;
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 3 })];
        let mut cfg = SimConfig::default();
        cfg.max_jobs = 60;
        cfg.warmup_jobs = 6;
        cfg.injection_rate_per_ms = 4.0;
        cfg.noc_congestion = true;
        let r1 = Simulation::build(&p, &apps, &cfg).unwrap().run();
        let r2 = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r1.job_latencies_us, r2.job_latencies_us);
        assert_eq!(r1.total_energy_j, r2.total_energy_j);
    }

    #[test]
    fn set_congestion_resets_state_like_a_fresh_model() {
        let p = Platform::table2_soc();
        let mut m = NocModel::new(&p, true);
        let quiet = m.transfer_us(0, 5, 1024);
        for _ in 0..100 {
            m.flow_started();
        }
        assert!(m.transfer_us(0, 5, 1024) > quiet);
        // Re-enabling clears the EMA/active-flow state exactly like
        // `NocModel::new(&p, true)` — reused workers rely on this.
        m.set_congestion(true);
        assert_eq!(m.transfer_us(0, 5, 1024), quiet);
        assert!(m.models_congestion());
        // Disabling matches the contention-free model.
        m.set_congestion(false);
        assert!(!m.models_congestion());
        let reference = NocModel::new(&p, false);
        assert_eq!(
            m.transfer_us(0, 9, 2048),
            reference.transfer_us(0, 9, 2048)
        );
    }

    #[test]
    fn contention_free_is_deterministic() {
        let mut m = model();
        let x = m.transfer_us(0, 9, 2048);
        m.flow_started(); // no-op without congestion state
        assert_eq!(m.transfer_us(0, 9, 2048), x);
    }
}
