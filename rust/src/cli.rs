//! Command-line interface and experiment reproduction drivers.
//!
//! Subcommands:
//! * `run`        — one simulation, full report.
//! * `sweep`      — scheduler × injection-rate grid, multithreaded.
//! * `scenario`   — scenario preset library + scenario sweeps.
//! * `dse`        — guided design-space exploration: `run` a
//!   multi-objective hardware search, `resume` it from a checkpoint,
//!   print or `export` the Pareto `front` (see [`crate::dse`]).
//! * `learn`      — imitation-learned scheduling: `collect` oracle
//!   demonstrations, `train` the deployable `il` policy, `eval` it
//!   against the oracle and baselines (see [`crate::learn`]).
//! * `fuzz`       — seeded scenario fuzzing: `run` the
//!   scheduler-robustness tournament with invariant oracles, `replay`
//!   a minimized repro, render a saved `report` (see [`crate::fuzz`]).
//! * `trace`      — render (`show`) or compare (`diff`) time-series
//!   trace artifacts recorded with `--probe` (see [`crate::probe`]).
//! * `reproduce`  — regenerate the paper's tables/figures
//!   (`table1`, `table2`, `fig2`, `fig3`, `all`).
//! * `validate`   — analytical model vs fine-grained reference
//!   (the paper's FPGA validation, simulated — DESIGN.md §Substitutions).
//! * `list`       — available schedulers, governors, applications.
//!
//! Observability flags shared by every subcommand: `--telemetry
//! <path|->` streams structured JSONL events ([`crate::telemetry`]),
//! `--telemetry-timing` adds wall-clock fields/events to that stream,
//! `--progress` renders live progress lines on stderr, `--store <dir>`
//! opens the content-addressed experiment store ([`crate::store`]) —
//! every campaign writes a manifest there and sweep/fuzz/dse points
//! are served from its cache on re-runs — and
//! `--log-format json|text` picks how library diagnostics are rendered.
//! `ds3r query` and `ds3r store gc|verify|fsck` operate on a store
//! offline.  Grid campaigns additionally share the fault-tolerance
//! flags `--fail-policy abort|quarantine[:N]`, `--step-budget <n>`
//! (deterministic watchdog), and `--inject-fault` (test hook); a
//! campaign that quarantined points exits with code 2.
//! The CLI is the only layer that turns events into print lines — CI
//! denies `print_stdout`/`print_stderr` everywhere else in `rust/src/`,
//! hence the file-level allow below.

// The one module (with main.rs) where rendering text to the terminal
// is the job.
#![allow(clippy::print_stdout, clippy::print_stderr)]

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::app::{suite, AppGraph};
use crate::config::SimConfig;
use crate::coordinator;
use crate::platform::Platform;
use crate::sim::Simulation;
use crate::telemetry::{
    self, Counters, Event, FanoutSink, JsonlSink, Sink, SpanTimer,
    Telemetry,
};
use crate::util::plot;
use crate::{Error, Result};

/// Minimal argument parser: `--key value`, `--key=value`, bare `--flag`.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    values: BTreeMap<String, String>,
    flags: BTreeSet<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.values.insert(k.to_string(), v.to_string());
                } else {
                    // Value flag if the next token does not look like a
                    // flag; boolean otherwise.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.values.insert(rest.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(rest.to_string());
                        }
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains(key) || self.values.contains_key(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.values
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("--{key}: bad number '{v}'"))
            }),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("--{key}: bad integer '{v}'"))
            }),
        }
    }

    /// Comma-separated list (`--scheds met,etf,ilp`).
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.values.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }

    /// Rate range `lo:hi:step` or comma list.
    pub fn rates_or(&self, key: &str, default: &[f64]) -> Result<Vec<f64>> {
        let Some(v) = self.values.get(key) else {
            return Ok(default.to_vec());
        };
        if let Some((lo, rest)) = v.split_once(':') {
            let (hi, step) = rest.split_once(':').ok_or_else(|| {
                Error::Config(format!("--{key}: want lo:hi:step, got '{v}'"))
            })?;
            let (lo, hi, step): (f64, f64, f64) = (
                lo.parse().map_err(|_| bad_num(key, lo))?,
                hi.parse().map_err(|_| bad_num(key, hi))?,
                step.parse().map_err(|_| bad_num(key, step))?,
            );
            if step <= 0.0 || hi < lo {
                return Err(Error::Config(format!(
                    "--{key}: bad range {lo}:{hi}:{step}"
                )));
            }
            let mut out = Vec::new();
            let mut x = lo;
            while x <= hi + 1e-9 {
                out.push(x);
                x += step;
            }
            Ok(out)
        } else {
            v.split(',')
                .map(|s| s.trim().parse().map_err(|_| bad_num(key, s)))
                .collect()
        }
    }
}

fn bad_num(key: &str, v: &str) -> Error {
    Error::Config(format!("--{key}: bad number '{v}'"))
}

/// Resolve an application by name with optional size parameters.
pub fn app_by_name(
    name: &str,
    symbols: usize,
    pulses: usize,
) -> Result<AppGraph> {
    let wp = suite::WifiParams { symbols };
    let rp = suite::RadarParams { pulses };
    match name {
        "wifi-tx" => Ok(suite::wifi_tx(wp)),
        "wifi-rx" => Ok(suite::wifi_rx(wp)),
        "sc-tx" => Ok(suite::single_carrier_tx()),
        "sc-rx" => Ok(suite::single_carrier_rx()),
        "range-detection" => Ok(suite::range_detection(rp)),
        "pulse-doppler" => Ok(suite::pulse_doppler(rp)),
        other => Err(Error::Config(format!(
            "unknown app '{other}' (wifi-tx, wifi-rx, sc-tx, sc-rx, \
             range-detection, pulse-doppler)"
        ))),
    }
}

/// Resolve a platform preset by name, or load a JSON platform file
/// (anything containing a path separator or ending in `.json`).
pub fn platform_by_name(name: &str) -> Result<Platform> {
    match name {
        "table2" => Ok(Platform::table2_soc()),
        "zcu102" => Ok(crate::platform::presets::zcu102_soc()),
        other if other.ends_with(".json") || other.contains('/') => {
            Platform::from_json_file(std::path::Path::new(other))
        }
        other => Err(Error::Config(format!(
            "unknown platform '{other}' (table2, zcu102, or a .json file)"
        ))),
    }
}

/// Build a `SimConfig` from common CLI flags.
pub fn config_from_args(args: &Args) -> Result<SimConfig> {
    let mut cfg = if args.has("config") {
        SimConfig::load(std::path::Path::new(&args.str_or("config", "")))?
    } else {
        SimConfig::default()
    };
    apply_sim_flags(args, &mut cfg)?;
    cfg.validate()?;
    Ok(cfg)
}

/// Overlay the common simulation flags onto an existing config — shared
/// between `config_from_args` and the `dse` subcommand (whose base
/// `SimConfig` may come from a DSE config file instead).
pub fn apply_sim_flags(args: &Args, cfg: &mut SimConfig) -> Result<()> {
    if args.has("sched") {
        cfg.scheduler = args.str_or("sched", "etf");
    }
    cfg.injection_rate_per_ms =
        args.f64_or("rate", cfg.injection_rate_per_ms)?;
    cfg.max_jobs = args.usize_or("jobs", cfg.max_jobs)?;
    cfg.warmup_jobs = args.usize_or("warmup", cfg.warmup_jobs)?;
    cfg.seed = args.usize_or("seed", cfg.seed as usize)? as u64;
    cfg.max_ready = args.usize_or("max-ready", cfg.max_ready)?;
    cfg.step_budget =
        args.usize_or("step-budget", cfg.step_budget as usize)? as u64;
    cfg.exec_jitter_frac = args.f64_or("jitter", cfg.exec_jitter_frac)?;
    if args.has("governor") {
        cfg.dtpm.governor = args.str_or("governor", "performance");
    }
    cfg.dtpm.epoch_us = args.f64_or("epoch-us", cfg.dtpm.epoch_us)?;
    if args.has("throttle") {
        cfg.dtpm.thermal_throttle = true;
        cfg.dtpm.throttle_temp_c =
            args.f64_or("throttle", cfg.dtpm.throttle_temp_c)?;
    }
    if args.has("power-cap") {
        cfg.dtpm.power_cap_w = Some(args.f64_or("power-cap", 5.0)?);
    }
    if args.has("gantt") {
        cfg.capture_gantt = true;
    }
    if args.has("traces") {
        cfg.capture_traces = true;
    }
    if args.has("noc-congestion") {
        cfg.noc_congestion = true;
    }
    if args.has("xla-thermal") {
        cfg.use_xla_thermal = true;
    }
    if args.has("trace-file") {
        cfg.trace_file =
            Some(std::path::PathBuf::from(args.str_or("trace-file", "")));
    }
    if args.has("artifacts") {
        cfg.artifacts_dir =
            Some(std::path::PathBuf::from(args.str_or("artifacts", "")));
    }
    if args.has("il-policy") {
        cfg.il_policy =
            Some(std::path::PathBuf::from(args.str_or("il-policy", "")));
    }
    if args.has("scenario") {
        cfg.scenario = Some(crate::scenario::resolve(
            &args.str_or("scenario", ""),
        )?);
    }
    Ok(())
}

/// Parse `--fail-policy abort|quarantine[:N]` (default `abort`) — how
/// grid campaigns treat a panicking, timed-out, or erroring point
/// (see [`crate::coordinator::FailPolicy`]).
fn fail_policy_from_args(args: &Args) -> Result<coordinator::FailPolicy> {
    coordinator::FailPolicy::parse(&args.str_or("fail-policy", "abort"))
}

/// Whether the command that just ran quarantined any grid points —
/// `main` turns this into exit code 2 (partial success) after the
/// degraded report has printed.  Reset by [`init_telemetry`], so
/// processes that drive several commands (tests) never leak a stale
/// verdict into the next campaign.
static PARTIAL_FAILURE: AtomicBool = AtomicBool::new(false);

/// True when the last campaign completed in degraded mode.
pub fn partial_failure() -> bool {
    PARTIAL_FAILURE.load(Ordering::Acquire)
}

/// Render a campaign's degraded-mode footer and raise the process
/// partial-failure flag; a clean report renders nothing.
fn failure_footer(failures: &crate::stats::FailureReport) -> String {
    if failures.is_clean() {
        return String::new();
    }
    PARTIAL_FAILURE.store(true, Ordering::Release);
    failures.summary()
}

/// Arm the process fault-injection registry from `--inject-fault
/// panic=<label-prefix>|hang=<label-prefix>` — the CLI face of
/// [`crate::faultpoint`], for exercising quarantine and watchdog
/// plumbing on a healthy build.  `panic` fires at pooled grid points
/// whose label (`{scheduler}@{rate}`, `{scheduler}@{scenario}`, or a
/// design id) starts with the prefix; `hang` pre-charges the
/// simulation watchdog for matching scheduler names, so it only trips
/// when `--step-budget` is set.
fn apply_inject_fault(args: &Args) -> Result<()> {
    if !args.has("inject-fault") {
        return Ok(());
    }
    let spec = args.str_or("inject-fault", "");
    let (kind, prefix) = spec.split_once('=').ok_or_else(|| {
        Error::Config(format!(
            "--inject-fault: want panic=<label-prefix> or \
             hang=<label-prefix>, got '{spec}'"
        ))
    })?;
    use crate::faultpoint::{self, sites, Fault};
    match kind {
        "panic" => {
            faultpoint::arm(sites::SWEEP_POINT, prefix, Fault::Panic)
        }
        "hang" => faultpoint::arm(
            sites::SIM_LOOP,
            prefix,
            // Large enough to exhaust any sane --step-budget on the
            // first loop iteration, without risking counter overflow.
            Fault::SlowLoop { steps: u64::MAX / 2 },
        ),
        other => {
            return Err(Error::Config(format!(
                "--inject-fault: unknown fault kind '{other}' \
                 (panic, hang)"
            )))
        }
    }
    Ok(())
}

/// The workload triple behind `--apps` / `--symbols` / `--pulses`.
fn workload_from_args(args: &Args) -> Result<(Vec<String>, usize, usize)> {
    Ok((
        args.list_or("apps", &["wifi-tx"]),
        args.usize_or("symbols", 12)?,
        args.usize_or("pulses", 16)?,
    ))
}

/// Build the workload from `--apps` / `--symbols` / `--pulses`.
pub fn apps_from_args(args: &Args) -> Result<Vec<AppGraph>> {
    let (names, symbols, pulses) = workload_from_args(args)?;
    names
        .iter()
        .map(|n| app_by_name(n, symbols, pulses))
        .collect()
}

// ---------------------------------------------------------------------------
// Telemetry wiring (--telemetry / --telemetry-timing / --progress /
// --log-format)
// ---------------------------------------------------------------------------

/// Render selected events as human text on stderr — the only place in
/// the library where telemetry becomes print lines.  Diagnostics are
/// always rendered (`--log-format` picks text vs JSONL); progress-class
/// events only under `--progress`.
struct StderrRenderSink {
    progress: bool,
    json_logs: bool,
}

impl Sink for StderrRenderSink {
    fn emit(&self, ev: &Event) {
        match ev {
            Event::Diagnostic { component, message } => {
                if self.json_logs {
                    eprintln!("{}", ev.to_json(true).to_string());
                } else {
                    eprintln!("{component}: {message}");
                }
            }
            Event::SweepProgress {
                completed,
                total,
                sims_per_s,
                eta_s,
            } if self.progress => {
                eprintln!(
                    "progress: {completed}/{total} sims \
                     ({sims_per_s:.1}/s, eta {eta_s:.0}s)"
                );
            }
            Event::DseGeneration { stats } if self.progress => {
                eprintln!(
                    "dse gen {:>3}: evals {:>3} (cache {:>2}) front \
                     {:>3} hv {:.4}",
                    stats.generation,
                    stats.evals,
                    stats.cache_hits,
                    stats.front_size,
                    stats.hypervolume
                );
            }
            Event::LearnRound { round, samples, agreement }
                if self.progress =>
            {
                let agree = agreement
                    .map(|a| format!(" agreement {:.1}%", a * 100.0))
                    .unwrap_or_default();
                eprintln!("learn round {round}: {samples} samples{agree}");
            }
            // Cache economics render unconditionally (not
            // progress-gated): CI's store smoke greps this exact line,
            // and it must not enter deterministic JSONL streams (warm
            // and cold reruns differ), hence event + render split.
            Event::StoreStats { hits, misses, .. } => {
                let total = hits + misses;
                if total > 0 {
                    eprintln!(
                        "store: {hits}/{total} points served from cache"
                    );
                }
            }
            _ => {}
        }
    }
}

/// Build the process telemetry handle from the shared observability
/// flags and install it as the global dispatcher (library diagnostics
/// route through it).  Returns the handle for explicit threading into
/// grid workloads.
///
/// * `--telemetry <path|->` — JSONL event stream to a file, or to
///   stderr for `-`.  Deterministic by default: wall-clock events and
///   fields are excluded, so fixed-seed streams are byte-identical
///   across thread counts.
/// * `--telemetry-timing` — include wall-clock events/fields (progress
///   rates, spans, run wall time) in the JSONL stream.
/// * `--progress` — live progress lines on stderr.
/// * `--store <dir>` — open (creating if needed) the experiment store:
///   installs a manifest-writing sink and the process-global store
///   handle that sweep/fuzz/dse drivers consult for cached points.
/// * `--log-format json|text` — diagnostics as JSONL or plain text
///   (default `text`, matching the pre-telemetry `eprintln!` output).
pub fn init_telemetry(args: &Args) -> Result<Telemetry> {
    // Process campaign state: the partial-failure verdict belongs to
    // the command about to run, and any requested fault injection must
    // be armed before the drivers fan out.
    PARTIAL_FAILURE.store(false, Ordering::Release);
    apply_inject_fault(args)?;
    let log_format = args.str_or("log-format", "text");
    if log_format != "text" && log_format != "json" {
        return Err(Error::Config(format!(
            "--log-format: want json|text, got '{log_format}'"
        )));
    }
    let mut sinks: Vec<Arc<dyn Sink>> = Vec::new();
    if args.has("telemetry") {
        let target = args.str_or("telemetry", "-");
        let sink = if target == "-" {
            JsonlSink::stderr()
        } else {
            JsonlSink::create(std::path::Path::new(&target))?
        };
        sinks.push(Arc::new(
            sink.with_timing(args.has("telemetry-timing")),
        ));
    }
    if args.has("store") {
        let dir = args.str_or("store", "experiment_store");
        let store = crate::store::ExperimentStore::open(
            std::path::Path::new(&dir),
        )?;
        sinks.push(Arc::new(crate::store::StoreSink::new(store.clone())));
        crate::store::set_global(Some(store));
    } else {
        // A handle left over from a previous init (tests drive several
        // commands per process) must not leak into this campaign.
        crate::store::set_global(None);
    }
    sinks.push(Arc::new(StderrRenderSink {
        progress: args.has("progress"),
        json_logs: log_format == "json",
    }));
    let tel = if sinks.len() == 1 {
        Telemetry::new(sinks.pop().expect("one sink"))
    } else {
        Telemetry::new(Arc::new(FanoutSink::new(sinks)))
    };
    telemetry::set_global(tel.clone());
    Ok(tel)
}

/// Emit the campaign-opening [`Event::RunStarted`] manifest: canonical
/// config hash, workload digest, seed, scheduler, and `git describe`
/// environment stamp.
fn emit_run_started(
    tel: &Telemetry,
    cmd: &'static str,
    cfg: &SimConfig,
    workload_digest: &str,
) {
    tel.emit(|| Event::RunStarted {
        cmd: cmd.to_string(),
        config_hash: telemetry::config_hash(&cfg.to_json().to_string()),
        seed: cfg.seed,
        scheduler: cfg.scheduler.clone(),
        workload_digest: workload_digest.to_string(),
        git: telemetry::git_describe(),
    });
}

/// Emit the closing [`Event::RunFinished`] with the campaign's
/// aggregated deterministic counters (wall time is a timing-gated
/// field).
fn emit_run_finished(
    tel: &Telemetry,
    cmd: &'static str,
    counters: Counters,
    t0: SpanTimer,
) {
    tel.emit(|| Event::RunFinished {
        cmd: cmd.to_string(),
        counters,
        wall_s: t0.elapsed_s(),
    });
    tel.flush();
}

/// The campaign workload digest: canonical config JSON, resolved app
/// graphs, and any trace-file bytes (see
/// [`crate::store::workload_digest`]).
fn store_digest(cfg: &SimConfig, apps: &[AppGraph]) -> String {
    crate::store::workload_digest(cfg, apps, &[])
}

/// Point-cache context when `--store` is active: the open store plus
/// the campaign workload digest that scopes its point keys.
fn store_ctx(workload_digest: &str) -> Option<crate::store::StoreCtx> {
    crate::store::global().map(|store| crate::store::StoreCtx {
        store,
        workload_digest: workload_digest.to_string(),
    })
}

/// Stash a compact numeric result summary on the pending manifest
/// (drained by the store sink when the run-finished event lands).
fn store_result(pairs: &[(&str, f64)]) {
    if let Some(store) = crate::store::global() {
        let mut r = crate::util::json::Json::obj();
        for (k, v) in pairs {
            r.set(k, crate::util::json::Json::Num(*v));
        }
        store.set_result(r);
    }
}

/// Post-run store bookkeeping: emit the cache-economics
/// [`Event::StoreStats`] (rendered to stderr by the CLI sink; captured
/// in JSONL only under `--telemetry-timing`, since hit/miss rates
/// depend on prior store state) and [`Event::ManifestWritten`] once
/// the sink has finalized the manifest — call after
/// [`emit_run_finished`].
fn finish_store(tel: &Telemetry, cmd: &'static str) {
    let Some(store) = crate::store::global() else {
        return;
    };
    let (hits, misses) = (store.session_hits(), store.session_misses());
    if hits + misses > 0 {
        tel.emit(|| Event::StoreStats {
            cmd: cmd.to_string(),
            hits,
            misses,
        });
    }
    if let Some(key) = store.last_manifest_key() {
        tel.emit(|| Event::ManifestWritten {
            cmd: cmd.to_string(),
            key,
        });
    }
    tel.flush();
}

// ---------------------------------------------------------------------------
// Probe wiring (--probe / --probe-budget) and self-profiling
// ---------------------------------------------------------------------------

/// The `--probe <path|->` target, when probing was requested.
fn probe_target(args: &Args) -> Option<String> {
    args.has("probe").then(|| args.str_or("probe", "-"))
}

/// Probe recorder configuration from `--probe-budget`.
fn probe_config(args: &Args) -> Result<crate::probe::ProbeConfig> {
    Ok(crate::probe::ProbeConfig::with_budget(
        args.usize_or("probe-budget", crate::probe::DEFAULT_BUDGET)?,
    ))
}

/// Write (or inline, for `-`) one probe artifact; returns the text to
/// append to stdout.
fn write_trace_json(
    target: &str,
    j: &crate::util::json::Json,
) -> Result<String> {
    if target == "-" {
        Ok(format!("{}\n", j.to_string_pretty()))
    } else {
        std::fs::write(target, j.to_string_pretty())?;
        Ok(format!("wrote probe trace to {target}\n"))
    }
}

/// Link a probe trace into the experiment store as a content-addressed
/// `trace` point: keyed like every point (`point_key(config_hash,
/// workload_digest)`) but under a `trace:`-prefixed config identity so
/// it can never collide with the run's result point, and recorded on
/// the pending manifest so `store gc` keeps it and `store verify`
/// re-derives its key.  Call before [`emit_run_finished`] (the store
/// sink drains session points when the run-finished event lands).
fn store_trace_point(
    label: &str,
    cfg: &SimConfig,
    workload_digest: &str,
    trace: &crate::probe::TraceSeries,
) {
    let Some(store) = crate::store::global() else {
        return;
    };
    let ch = telemetry::config_hash(&format!(
        "trace:{label}:{}",
        cfg.to_json().to_string()
    ));
    let key = crate::store::point_key(&ch, workload_digest);
    let entry = crate::store::PointEntry {
        kind: "trace".into(),
        key: key.clone(),
        config_hash: ch,
        workload_digest: workload_digest.to_string(),
        result: trace.to_json(),
        counters: Counters::new(),
    };
    if let Err(e) = store.put_point(&entry) {
        telemetry::diag("cli.probe", || {
            format!("failed to store trace point {key}: {e}")
        });
        return;
    }
    store.record_points(&[key]);
}

/// Emit the wall-clock self-profile of one finished run (a
/// timing-gated event: never part of deterministic streams).
fn emit_profile(
    tel: &Telemetry,
    cmd: &'static str,
    r: &crate::stats::SimReport,
) {
    tel.emit(|| Event::Profile {
        cmd: cmd.to_string(),
        build_wall_ns: r.build_wall_ns,
        sched_wall_ns: r.sched_wall_ns,
        thermal_wall_ns: r.thermal_wall_ns,
        jobgen_wall_ns: r.jobgen_wall_ns,
        loop_wall_ns: r.loop_wall_ns,
    });
}

// ---------------------------------------------------------------------------
// Subcommand drivers (each returns the text it printed, for testability)
// ---------------------------------------------------------------------------

pub fn cmd_run(args: &Args) -> Result<String> {
    let platform = platform_by_name(&args.str_or("platform", "table2"))?;
    let apps = apps_from_args(args)?;
    let cfg = config_from_args(args)?;
    if args.has("record-trace") {
        // Record the arrival stream this config would generate and exit:
        // replay later with --trace-file for exact cross-scheduler runs.
        let out = args.str_or("record-trace", "trace.json");
        let trace = crate::jobgen::JobGen::new(
            cfg.arrival,
            cfg.injection_rate_per_ms,
            apps.len(),
            &cfg.app_weights,
            cfg.max_jobs,
            cfg.seed,
        )
        .record_trace();
        std::fs::write(
            &out,
            crate::jobgen::JobGen::trace_to_json(&trace)
                .to_string_pretty(),
        )?;
        return Ok(format!("recorded {} arrivals to {out}\n", trace.len()));
    }
    let tel = telemetry::global();
    let t0 = SpanTimer::start();
    let wd = store_digest(&cfg, &apps);
    emit_run_started(&tel, "run", &cfg, &wd);
    let mut sim = Simulation::build(&platform, &apps, &cfg)?;
    let probe_out = probe_target(args);
    if probe_out.is_some() {
        sim.attach_probe(probe_config(args)?);
    }
    let (report, trace) = sim.run_with_trace();
    emit_profile(&tel, "run", &report);
    let mut probe_text = String::new();
    if let (Some(target), Some(trace)) = (&probe_out, &trace) {
        probe_text = write_trace_json(target, &trace.to_json())?;
        store_trace_point("", &cfg, &wd, trace);
    }
    store_result(&[
        ("completed_jobs", report.completed_jobs as f64),
        ("injected_jobs", report.injected_jobs as f64),
    ]);
    emit_run_finished(&tel, "run", Counters::from_report(&report), t0);
    finish_store(&tel, "run");
    let mut out = report.summary();
    out.push_str(&probe_text);
    if cfg.capture_gantt {
        let hi = report
            .gantt
            .iter()
            .map(|e| e.end_us)
            .fold(0.0, f64::max)
            .min(2000.0);
        out.push_str(&report.gantt_ascii(&platform, &apps, (0.0, hi), 100));
    }
    if args.has("json") {
        out.push_str(&report.to_json().to_string_pretty());
    }
    Ok(out)
}

pub fn cmd_sweep(args: &Args) -> Result<String> {
    let platform = platform_by_name(&args.str_or("platform", "table2"))?;
    let apps = apps_from_args(args)?;
    let cfg = config_from_args(args)?;
    let scheds = args.list_or("scheds", &["met", "etf", "ilp"]);
    let sched_refs: Vec<&str> = scheds.iter().map(String::as_str).collect();
    let rates =
        args.rates_or("rates", &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0])?;
    let threads = args.usize_or("threads", default_threads())?;
    let policy = fail_policy_from_args(args)?;

    let points = coordinator::fig3_points(&sched_refs, &rates, cfg.seed);
    let tel = telemetry::global();
    let t0 = SpanTimer::start();
    let wd = store_digest(&cfg, &apps);
    emit_run_started(&tel, "sweep", &cfg, &wd);
    let ctx = store_ctx(&wd);
    let (results, counters, failures) =
        coordinator::run_sweep_quarantined(
            &platform, &apps, &cfg, &points, threads, &tel,
            ctx.as_ref(), policy,
        )?;
    store_result(&[("points", results.len() as f64)]);
    emit_run_finished(&tel, "sweep", counters, t0);
    finish_store(&tel, "sweep");

    let mut rows = Vec::new();
    for r in &results {
        rows.push(vec![
            r.point.scheduler.clone(),
            format!("{:.1}", r.point.rate_per_ms),
            format!("{:.1}", r.avg_latency_us),
            format!("{:.1}", r.p95_latency_us),
            format!("{:.3}", r.throughput_jobs_per_ms),
            format!("{:.2}", r.energy_per_job_mj),
            format!("{}/{}", r.completed_jobs, r.injected_jobs),
        ]);
    }
    let mut out = plot::ascii_table(
        &[
            "scheduler",
            "rate/ms",
            "avg exec us",
            "p95 us",
            "thru/ms",
            "mJ/job",
            "done",
        ],
        &rows,
    );
    let series = coordinator::latency_series(&results);
    out.push_str(&plot::ascii_chart(
        "avg job execution time vs injection rate",
        "jobs/ms",
        "us",
        &series,
        72,
        20,
    ));
    if args.has("csv") {
        let path = args.str_or("csv", "sweep.csv");
        std::fs::write(&path, plot::to_csv("rate_per_ms", &series))?;
        out.push_str(&format!("wrote {path}\n"));
    }
    out.push_str(&failure_footer(&failures));
    Ok(out)
}

pub fn cmd_validate(args: &Args) -> Result<String> {
    let platform = platform_by_name(&args.str_or("platform", "table2"))?;
    let symbols = args.usize_or("symbols", 8)?;
    let pulses = args.usize_or("pulses", 8)?;
    let apps = vec![
        suite::wifi_tx(suite::WifiParams { symbols }),
        suite::single_carrier_tx(),
        suite::single_carrier_rx(),
        suite::range_detection(suite::RadarParams { pulses }),
    ];
    let jobs = args.usize_or("jobs", 200)?;
    let rows = coordinator::validate(
        &platform,
        &apps,
        &["met", "etf"],
        jobs,
        args.usize_or("seed", 42)? as u64,
    )?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.app.clone(),
                r.scheduler.clone(),
                format!("{:.1}", r.model_us),
                format!("{:.1}", r.reference_us),
                format!("{:.1}%", r.error_pct),
            ]
        })
        .collect();
    Ok(plot::ascii_table(
        &["app", "scheduler", "model us", "reference us", "error"],
        &table,
    ))
}

pub fn cmd_list() -> String {
    let mut out = String::new();
    out.push_str("schedulers: ");
    out.push_str(&crate::sched::builtin_names().join(", "));
    out.push_str("\ngovernors:  performance, powersave, ondemand, userspace, explore-xla\n");
    out.push_str("platforms:  table2 (paper Table 2), zcu102, or a platform .json file\n");
    out.push_str(
        "apps:       wifi-tx, wifi-rx, sc-tx, sc-rx, range-detection, \
         pulse-doppler\n",
    );
    out.push_str("scenarios:  ");
    out.push_str(&crate::scenario::presets::names().join(", "));
    out.push_str(", or a scenario .json file\n");
    out.push_str(
        "objectives: latency, energy, peak_temp (dse subcommand)\n",
    );
    out
}

// ---------------------------------------------------------------------------
// scenario: preset library + scenario sweeps
// ---------------------------------------------------------------------------

/// `ds3r scenario <list|show|export|sweep>` driver.
pub fn cmd_scenario(args: &Args) -> Result<String> {
    let sub = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("list");
    match sub {
        "list" => {
            let mut rows = Vec::new();
            for sc in crate::scenario::presets::all() {
                rows.push(vec![
                    sc.name.clone(),
                    sc.events.len().to_string(),
                    sc.description.clone(),
                ]);
            }
            Ok(plot::ascii_table(
                &["scenario", "events", "description"],
                &rows,
            ))
        }
        "show" => {
            let name = args.positional.get(2).ok_or_else(|| {
                Error::Config("scenario show <name-or-file>".into())
            })?;
            let sc = crate::scenario::resolve(name)?;
            Ok(sc.to_json().to_string_pretty())
        }
        "export" => {
            // Write every preset as a JSON file, ready to edit.
            let dir = args.str_or("out", "scenarios");
            std::fs::create_dir_all(&dir)?;
            let mut out = String::new();
            for sc in crate::scenario::presets::all() {
                let path = format!("{dir}/{}.json", sc.name);
                sc.save(std::path::Path::new(&path))?;
                out.push_str(&format!("wrote {path}\n"));
            }
            Ok(out)
        }
        "sweep" => cmd_scenario_sweep(args),
        other => Err(Error::Config(format!(
            "unknown scenario subcommand '{other}' \
             (list, show, export, sweep)"
        ))),
    }
}

/// Run the configured workload under several scenarios and compare.
fn cmd_scenario_sweep(args: &Args) -> Result<String> {
    let platform = platform_by_name(&args.str_or("platform", "table2"))?;
    let apps = apps_from_args(args)?;
    let mut cfg = config_from_args(args)?;
    cfg.scenario = None; // set per sweep point
    let sel = args.str_or("scenarios", "all");
    let names: Vec<String> = if sel == "all" {
        crate::scenario::presets::names()
            .iter()
            .map(|s| s.to_string())
            .collect()
    } else {
        sel.split(',').map(|s| s.trim().to_string()).collect()
    };
    let scenarios = names
        .iter()
        .map(|n| crate::scenario::resolve(n))
        .collect::<Result<Vec<_>>>()?;
    let threads = args.usize_or("threads", default_threads())?;
    let tel = telemetry::global();
    let t0 = SpanTimer::start();
    let wd = store_digest(&cfg, &apps);
    emit_run_started(&tel, "scenario-sweep", &cfg, &wd);
    let probe_out = probe_target(args);
    let policy = fail_policy_from_args(args)?;
    let mut footer = String::new();
    let (results, counters, traces) = if probe_out.is_some() {
        // The probed path records one trace per scenario; a partially
        // populated trace set would silently lie about coverage.
        if policy != coordinator::FailPolicy::Abort {
            return Err(Error::Config(
                "--fail-policy quarantine is not supported together \
                 with --probe (trace sets must cover every scenario)"
                    .into(),
            ));
        }
        coordinator::run_scenario_sweep_probed(
            &platform,
            &apps,
            &cfg,
            &scenarios,
            threads,
            &tel,
            &probe_config(args)?,
        )?
    } else {
        let (results, counters, failures) =
            coordinator::run_scenario_sweep_quarantined(
                &platform, &apps, &cfg, &scenarios, threads, &tel,
                policy,
            )?;
        footer = failure_footer(&failures);
        (results, counters, Vec::new())
    };
    let mut probe_text = String::new();
    if let Some(target) = &probe_out {
        probe_text = write_trace_json(
            target,
            &crate::probe::traces_to_json(&traces),
        )?;
        for t in &traces {
            store_trace_point(&t.scenario, &cfg, &wd, t);
        }
    }
    store_result(&[("scenarios", results.len() as f64)]);
    emit_run_finished(&tel, "scenario-sweep", counters, t0);
    finish_store(&tel, "scenario-sweep");

    let mut out = probe_text;
    let mut rows = Vec::new();
    for r in &results {
        rows.push(vec![
            r.scenario.clone(),
            format!("{}/{}", r.completed_jobs, r.injected_jobs),
            format!("{:.1}", r.avg_latency_us),
            format!("{:.1}", r.p95_latency_us),
            format!("{:.2}", r.energy_per_job_mj),
            format!("{:.1}", r.peak_temp_c),
            r.phases.len().to_string(),
        ]);
    }
    out.push_str(&plot::ascii_table(
        &[
            "scenario",
            "done",
            "avg us",
            "p95 us",
            "mJ/job",
            "peak C",
            "phases",
        ],
        &rows,
    ));
    for r in &results {
        out.push_str(&format!("\n{}:\n", r.scenario));
        for p in &r.phases {
            out.push_str(&format!(
                "  [{:>9.1}..{:>9.1} ms] {:<24} jobs={:<5} \
                 avg={:>8.1} us  {:>5.2} W  peak={:>5.1} C\n",
                p.start_us / 1000.0,
                p.end_us / 1000.0,
                p.label,
                p.jobs_completed,
                p.avg_latency_us,
                p.avg_power_w,
                p.peak_temp_c
            ));
        }
    }
    out.push_str(&footer);
    Ok(out)
}

pub fn default_threads() -> usize {
    crate::util::default_threads()
}

// ---------------------------------------------------------------------------
// dse: guided design-space exploration
// ---------------------------------------------------------------------------

/// `ds3r dse <run|resume|front|export>` driver.
pub fn cmd_dse(args: &Args) -> Result<String> {
    let sub = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("run");
    match sub {
        "run" => cmd_dse_run(args),
        "resume" => cmd_dse_resume(args),
        "front" => cmd_dse_front(args),
        "export" => cmd_dse_export(args),
        other => Err(Error::Config(format!(
            "unknown dse subcommand '{other}' (run, resume, front, export)"
        ))),
    }
}

/// Assemble a `DseConfig` from `--dse-config` plus flag overrides.
fn dse_config_from_args(args: &Args) -> Result<crate::dse::DseConfig> {
    use crate::dse::{DseConfig, Objective};
    let mut cfg = if args.has("dse-config") {
        DseConfig::load(std::path::Path::new(
            &args.str_or("dse-config", ""),
        ))?
    } else {
        DseConfig::default()
    };
    if args.has("objectives") {
        cfg.objectives = args
            .list_or("objectives", &[])
            .iter()
            .map(|s| Objective::parse(s))
            .collect::<Result<Vec<_>>>()?;
    }
    if args.has("algorithm") {
        cfg.algorithm = args.str_or("algorithm", "nsga2");
    }
    cfg.population = args.usize_or("population", cfg.population)?;
    cfg.generations = args.usize_or("generations", cfg.generations)?;
    cfg.search_seed =
        args.usize_or("search-seed", cfg.search_seed as usize)? as u64;
    cfg.mutation_rate = args.f64_or("mutation", cfg.mutation_rate)?;
    cfg.crossover_rate = args.f64_or("crossover", cfg.crossover_rate)?;
    cfg.min_pes_per_cluster =
        args.usize_or("min-pes", cfg.min_pes_per_cluster)?;
    cfg.max_pes_per_cluster =
        args.usize_or("max-pes", cfg.max_pes_per_cluster)?;
    cfg.threads = args.usize_or("threads", cfg.threads)?;
    if args.has("eval-seeds") {
        cfg.seeds = args
            .list_or("eval-seeds", &[])
            .iter()
            .map(|s| {
                s.parse::<u64>().map_err(|_| {
                    Error::Config(format!("--eval-seeds: bad seed '{s}'"))
                })
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if args.has("eval-scenarios") {
        cfg.scenarios = args.list_or("eval-scenarios", &[]);
    }
    // Base-simulation flags (--sched, --rate, --jobs, ...) overlay the
    // embedded SimConfig.
    apply_sim_flags(args, &mut cfg.sim)?;
    cfg.validate()?;
    Ok(cfg)
}

/// Emit the `dse run`/`dse resume` opening manifest (the DSE analogue
/// of [`emit_run_started`]: the hash covers the whole search config).
fn emit_dse_started(
    tel: &Telemetry,
    cmd: &'static str,
    cfg: &crate::dse::DseConfig,
    workload_digest: &str,
) {
    tel.emit(|| Event::RunStarted {
        cmd: cmd.to_string(),
        config_hash: telemetry::config_hash(&cfg.to_json().to_string()),
        seed: cfg.search_seed,
        scheduler: cfg.sim.scheduler.clone(),
        workload_digest: workload_digest.to_string(),
        git: telemetry::git_describe(),
    });
}

/// Aggregate a search's generation history into deterministic run
/// counters for [`Event::RunFinished`].
fn dse_counters(history: &[crate::stats::DseGenStats]) -> Counters {
    let mut c = Counters::new();
    for s in history {
        c.add("generations", 1);
        c.add("evals", s.evals as u64);
        c.add("cache_hits", s.cache_hits as u64);
        c.add("sims", s.sims as u64);
    }
    c
}

fn dse_progress_line(s: &crate::stats::DseGenStats) -> String {
    let best = s
        .best
        .iter()
        .map(|b| format!("{b:.1}"))
        .collect::<Vec<_>>()
        .join("/");
    format!(
        "gen {:>3}: evals {:>3} (cache {:>2}) sims {:>3}  front {:>3}  \
         hv {:.4}  best {}\n",
        s.generation, s.evals, s.cache_hits, s.sims, s.front_size, best
    )
}

/// Render the Pareto front as a table (sorted by the first objective).
fn dse_front_table(engine: &crate::dse::DseEngine) -> String {
    let objectives = &engine.config().objectives;
    let mut headers: Vec<String> = vec!["design".into()];
    for o in objectives {
        headers.push(format!("{} ({})", o.name(), o.unit()));
    }
    headers.push("PEs".into());
    headers.push("opps".into());
    headers.push("hop us".into());
    headers.push("BW B/us".into());
    headers.push("cap W".into());
    let header_refs: Vec<&str> =
        headers.iter().map(String::as_str).collect();
    let base = engine.space().base();
    let rows: Vec<Vec<String>> = engine
        .archive()
        .sorted_by_first_objective()
        .into_iter()
        .map(|p| {
            let mut row = vec![p.genome.id()];
            for v in &p.objectives {
                row.push(format!("{v:.2}"));
            }
            row.push(
                p.genome
                    .pe_counts
                    .iter()
                    .map(|c| c.to_string())
                    .collect::<Vec<_>>()
                    .join("/"),
            );
            row.push(
                p.genome
                    .opp_masks
                    .iter()
                    .map(|m| m.count_ones().to_string())
                    .collect::<Vec<_>>()
                    .join("/"),
            );
            row.push(format!("{:.3}", p.genome.hop_latency_us));
            row.push(format!("{:.0}", p.genome.link_bandwidth));
            row.push(
                p.genome
                    .power_budget_w
                    .map(|w| format!("{w:.1}"))
                    .unwrap_or_else(|| "-".into()),
            );
            row
        })
        .collect();
    let mut out = format!(
        "Pareto front over {} (base platform '{}', clusters {}):\n",
        objectives
            .iter()
            .map(|o| o.name())
            .collect::<Vec<_>>()
            .join(" x "),
        base.name,
        base.clusters
            .iter()
            .map(|c| c.name.as_str())
            .collect::<Vec<_>>()
            .join("/"),
    );
    out.push_str(&plot::ascii_table(&header_refs, &rows));
    out
}

/// Degraded-mode footer for a DSE search that quarantined design
/// evaluations (raises the process partial-failure flag like
/// [`failure_footer`]).
fn dse_failure_footer(engine: &crate::dse::DseEngine) -> String {
    if engine.quarantined() == 0 {
        return String::new();
    }
    PARTIAL_FAILURE.store(true, Ordering::Release);
    format!(
        "quarantined {} design evaluation(s): scored worst-case, \
         dominated away, never cached\n",
        engine.quarantined()
    )
}

/// Encode the CLI workload flags as checkpoint metadata.
fn dse_workload_meta(
    names: &[String],
    symbols: usize,
    pulses: usize,
) -> crate::util::json::Json {
    use crate::util::json::Json;
    let mut m = Json::obj();
    m.set(
        "apps",
        Json::Arr(names.iter().map(|n| Json::Str(n.clone())).collect()),
    )
    .set("symbols", Json::Num(symbols as f64))
    .set("pulses", Json::Num(pulses as f64));
    m
}

fn cmd_dse_run(args: &Args) -> Result<String> {
    let platform = platform_by_name(&args.str_or("platform", "table2"))?;
    let (names, symbols, pulses) = workload_from_args(args)?;
    let apps = names
        .iter()
        .map(|n| app_by_name(n, symbols, pulses))
        .collect::<Result<Vec<_>>>()?;
    let cfg = dse_config_from_args(args)?;
    let checkpoint = args.str_or("checkpoint", "dse_checkpoint.json");
    let budget = cfg.budget_evals();
    let mut engine = crate::dse::DseEngine::new(platform, cfg)?;
    engine.set_workload_meta(dse_workload_meta(&names, symbols, pulses));
    let tel = telemetry::global();
    let t0 = SpanTimer::start();
    let wd = store_digest(&engine.config().sim, &apps);
    emit_dse_started(&tel, "dse-run", engine.config(), &wd);
    engine.set_telemetry(tel.clone());
    engine.set_store(store_ctx(&wd));
    engine.set_fail_policy(fail_policy_from_args(args)?);
    let mut out = format!(
        "DSE: {} search, budget {} evaluations ({} x {} designs)\n",
        engine.config().algorithm,
        budget,
        engine.target_generations(),
        engine.config().population,
    );
    engine.run(
        &apps,
        Some(std::path::Path::new(&checkpoint)),
        |s| out.push_str(&dse_progress_line(s)),
    )?;
    let front = engine
        .history()
        .last()
        .map(|s| s.front_size as f64)
        .unwrap_or(0.0);
    store_result(&[
        ("generations", engine.history().len() as f64),
        ("front_size", front),
    ]);
    emit_run_finished(&tel, "dse-run", dse_counters(engine.history()), t0);
    finish_store(&tel, "dse-run");
    out.push('\n');
    out.push_str(&dse_front_table(&engine));
    out.push_str(&dse_failure_footer(&engine));
    out.push_str(&format!(
        "\ncheckpoint written to {checkpoint} — `ds3r dse front \
         --checkpoint {checkpoint}` to revisit, `ds3r dse resume \
         --checkpoint {checkpoint} --generations N` to extend\n"
    ));
    Ok(out)
}

fn cmd_dse_resume(args: &Args) -> Result<String> {
    if !args.has("checkpoint") {
        return Err(Error::Config(
            "dse resume requires --checkpoint <file>".into(),
        ));
    }
    let checkpoint = args.str_or("checkpoint", "");
    let mut engine = crate::dse::DseEngine::from_checkpoint_file(
        std::path::Path::new(&checkpoint),
    )?;
    // Rebuild the workload the checkpoint pins; refuse a silent switch
    // (cached metrics and the archive would mix incomparable
    // workloads).  The metadata is treated as usable only when the
    // full apps/symbols/pulses schema is present — a partial or
    // foreign meta blob must not be patched up with defaults.
    let meta_workload = engine.workload_meta().and_then(|meta| {
        use crate::util::json::Json;
        let apps: Vec<String> = meta
            .get("apps")?
            .as_arr()?
            .iter()
            .map(|v| v.as_str().map(String::from))
            .collect::<Option<Vec<_>>>()?;
        if apps.is_empty() {
            return None;
        }
        let symbols = meta.get("symbols").and_then(Json::as_usize)?;
        let pulses = meta.get("pulses").and_then(Json::as_usize)?;
        Some((apps, symbols, pulses))
    });
    let apps = match meta_workload {
        Some((stored, symbols, pulses)) => {
            let (names, fsym, fpul) = workload_from_args(args)?;
            if args.has("apps") && names != stored {
                return Err(Error::Config(format!(
                    "checkpoint pins workload --apps {} (got {})",
                    stored.join(","),
                    names.join(",")
                )));
            }
            if args.has("symbols") && fsym != symbols {
                return Err(Error::Config(format!(
                    "checkpoint pins --symbols {symbols} (got {fsym})"
                )));
            }
            if args.has("pulses") && fpul != pulses {
                return Err(Error::Config(format!(
                    "checkpoint pins --pulses {pulses} (got {fpul})"
                )));
            }
            stored
                .iter()
                .map(|n| app_by_name(n, symbols, pulses))
                .collect::<Result<Vec<_>>>()?
        }
        // Library-written checkpoints may omit (or carry a foreign)
        // workload metadata blob; never guess a default workload
        // silently — demand explicit flags.
        None => {
            if !args.has("apps") {
                return Err(Error::Config(
                    "checkpoint carries no usable workload metadata; \
                     pass the original workload explicitly (--apps, and \
                     --symbols/--pulses if not default)"
                        .into(),
                ));
            }
            apps_from_args(args)?
        }
    };
    if args.has("generations") {
        engine.set_generations(args.usize_or("generations", 0)?);
    }
    if engine.is_done() {
        let done = engine.completed_generations() - 1;
        return Ok(format!(
            "search already complete at generation {done}; pass \
             --generations N (> {done}) to extend\n{}",
            dse_front_table(&engine)
        ));
    }
    let tel = telemetry::global();
    let t0 = SpanTimer::start();
    let wd = store_digest(&engine.config().sim, &apps);
    emit_dse_started(&tel, "dse-resume", engine.config(), &wd);
    engine.set_telemetry(tel.clone());
    engine.set_store(store_ctx(&wd));
    engine.set_fail_policy(fail_policy_from_args(args)?);
    let resumed_at = engine.completed_generations();
    let mut out = format!(
        "resuming from {checkpoint} at generation {resumed_at} \
         (target {})\n",
        engine.target_generations(),
    );
    engine.run(
        &apps,
        Some(std::path::Path::new(&checkpoint)),
        |s| out.push_str(&dse_progress_line(s)),
    )?;
    let front = engine
        .history()
        .last()
        .map(|s| s.front_size as f64)
        .unwrap_or(0.0);
    store_result(&[
        ("generations", engine.history().len() as f64),
        ("front_size", front),
    ]);
    emit_run_finished(
        &tel,
        "dse-resume",
        dse_counters(&engine.history()[resumed_at..]),
        t0,
    );
    finish_store(&tel, "dse-resume");
    out.push('\n');
    out.push_str(&dse_front_table(&engine));
    out.push_str(&dse_failure_footer(&engine));
    Ok(out)
}

fn cmd_dse_front(args: &Args) -> Result<String> {
    if !args.has("checkpoint") {
        return Err(Error::Config(
            "dse front requires --checkpoint <file>".into(),
        ));
    }
    let engine = crate::dse::DseEngine::from_checkpoint_file(
        std::path::Path::new(&args.str_or("checkpoint", "")),
    )?;
    if args.has("json") {
        return Ok(engine.archive().to_json().to_string_pretty());
    }
    let mut out = dse_front_table(&engine);
    if let Some(last) = engine.history().last() {
        out.push_str(&format!(
            "after generation {}: {} designs on the front, hypervolume \
             proxy {:.4}\n",
            last.generation, last.front_size, last.hypervolume
        ));
    }
    Ok(out)
}

fn cmd_dse_export(args: &Args) -> Result<String> {
    if !args.has("checkpoint") {
        return Err(Error::Config(
            "dse export requires --checkpoint <file>".into(),
        ));
    }
    let engine = crate::dse::DseEngine::from_checkpoint_file(
        std::path::Path::new(&args.str_or("checkpoint", "")),
    )?;
    let dir = args.str_or("out", "dse_designs");
    std::fs::create_dir_all(&dir)?;
    let mut out = String::new();
    for p in engine.archive().sorted_by_first_objective() {
        let path = format!("{dir}/{}.json", p.genome.id());
        engine
            .space()
            .export_platform(&p.genome, std::path::Path::new(&path))?;
        // The power budget is a runtime (SimConfig) knob, not a
        // platform property — ship it as a companion config so the
        // exported design reproduces its evaluated behaviour.
        if let Some(w) = p.genome.power_budget_w {
            let mut sim = engine.config().sim.clone();
            sim.dtpm.power_cap_w = Some(w);
            let cfg_path = format!("{dir}/{}.config.json", p.genome.id());
            sim.save(std::path::Path::new(&cfg_path))?;
            out.push_str(&format!(
                "wrote {path} (+ {cfg_path}: {w:.1} W power cap)\n"
            ));
        } else {
            out.push_str(&format!("wrote {path}\n"));
        }
    }
    let front_path = format!("{dir}/front.json");
    std::fs::write(
        &front_path,
        engine.archive().to_json().to_string_pretty(),
    )?;
    out.push_str(&format!(
        "wrote {front_path} ({} designs) — run a design with `ds3r run \
         --platform <file>`, adding `--config <id>.config.json` for \
         power-capped designs\n",
        engine.archive().len()
    ));
    Ok(out)
}

// ---------------------------------------------------------------------------
// learn: imitation-learned scheduling
// ---------------------------------------------------------------------------

/// Assemble a `LearnConfig` from `--learn-config` plus flag overrides.
fn learn_config_from_args(args: &Args) -> Result<crate::learn::LearnConfig> {
    use crate::learn::LearnConfig;
    let mut lc = if args.has("learn-config") {
        LearnConfig::load(std::path::Path::new(
            &args.str_or("learn-config", ""),
        ))?
    } else {
        LearnConfig::default()
    };
    if args.has("oracle") {
        lc.oracle = args.str_or("oracle", "etf");
    }
    lc.rounds = args.usize_or("rounds", lc.rounds)?;
    lc.epochs = args.usize_or("epochs", lc.epochs)?;
    lc.learning_rate = args.f64_or("lr", lc.learning_rate)?;
    lc.l2 = args.f64_or("l2", lc.l2)?;
    lc.train_seed =
        args.usize_or("train-seed", lc.train_seed as usize)? as u64;
    lc.guard_ratio = args.f64_or("guard", lc.guard_ratio)?;
    if args.has("learn-seeds") {
        lc.seeds = args
            .list_or("learn-seeds", &[])
            .iter()
            .map(|s| {
                s.parse::<u64>().map_err(|_| {
                    Error::Config(format!("--learn-seeds: bad seed '{s}'"))
                })
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if args.has("rates") {
        lc.rates_per_ms = args.rates_or("rates", &[])?;
    }
    if args.has("baselines") {
        lc.baselines = args.list_or("baselines", &[]);
    }
    lc.max_samples_per_run =
        args.usize_or("max-samples", lc.max_samples_per_run)?;
    lc.threads = args.usize_or("threads", lc.threads)?;
    // Base-simulation flags (--jobs, --warmup, --governor, ...) overlay
    // the embedded SimConfig; --rate/--seed stay per-grid-point knobs.
    apply_sim_flags(args, &mut lc.sim)?;
    lc.validate()?;
    Ok(lc)
}

/// Render an eval report as an ASCII table + agreement line.
fn learn_eval_text(report: &crate::learn::EvalReport) -> String {
    let rows: Vec<Vec<String>> = report
        .rows
        .iter()
        .map(|r| {
            vec![
                r.scheduler.clone(),
                format!("{:.1}", r.mean_latency_us),
                format!("{:.2}", r.energy_per_job_mj),
                format!("{}/{}", r.completed, r.injected),
                if r.decisions > 0 {
                    format!("{}/{}", r.fallbacks, r.decisions)
                } else {
                    "-".into()
                },
            ]
        })
        .collect();
    let mut out = plot::ascii_table(
        &["scheduler", "mean us", "mJ/job", "done", "fallbacks"],
        &rows,
    );
    out.push_str(&format!(
        "decision agreement with the oracle: {:.1}% over {} grid \
         points\n",
        report.agreement * 100.0,
        report.grid_points
    ));
    out
}

/// `ds3r learn <collect|train|eval>` driver.
pub fn cmd_learn(args: &Args) -> Result<String> {
    let sub = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("train");
    let platform = platform_by_name(&args.str_or("platform", "table2"))?;
    let apps = apps_from_args(args)?;
    let mut lc = learn_config_from_args(args)?;
    match sub {
        "collect" => {
            let out = args.str_or("out", "il_dataset.json");
            let (data, _, _) =
                crate::learn::collect_round(&platform, &apps, &lc, None)?;
            data.save(std::path::Path::new(&out))?;
            Ok(format!(
                "collected {} demonstrations from oracle '{}' over a \
                 {}x{} seeds x rates grid -> {out}\n",
                data.len(),
                lc.oracle,
                lc.seeds.len(),
                lc.rates_per_ms.len()
            ))
        }
        "train" => {
            let out = args.str_or("out", "il_policy.json");
            let (model, text) = if args.has("data") {
                // Train on a previously collected dataset.
                let data = crate::learn::Dataset::load(
                    std::path::Path::new(&args.str_or("data", "")),
                )?;
                let params = crate::learn::TrainParams {
                    epochs: lc.epochs,
                    learning_rate: lc.learning_rate,
                    l2: lc.l2,
                    seed: lc.train_seed,
                };
                // The dataset records which oracle labelled it; stamp
                // the artifact with that unless --oracle overrides.
                let oracle = if args.has("oracle") || data.oracle.is_empty()
                {
                    lc.oracle.clone()
                } else {
                    data.oracle.clone()
                };
                let model = crate::learn::SoftmaxModel::train(
                    &data,
                    platform.classes.len().max(1),
                    &oracle,
                    &params,
                    lc.guard_ratio,
                );
                (
                    model,
                    format!(
                        "trained on {} stored demonstrations\n",
                        data.len()
                    ),
                )
            } else {
                // Full DAgger pipeline: collect -> train, lc.rounds x.
                let tel = telemetry::global();
                let t0 = SpanTimer::start();
                let wd = store_digest(&lc.sim, &apps);
                tel.emit(|| Event::RunStarted {
                    cmd: "learn-train".to_string(),
                    config_hash: telemetry::config_hash(
                        &lc.to_json().to_string(),
                    ),
                    seed: lc.train_seed,
                    scheduler: lc.oracle.clone(),
                    workload_digest: wd,
                    git: telemetry::git_describe(),
                });
                let (model, summary) = crate::learn::train_policy_with(
                    &platform, &apps, &lc, &tel,
                )?;
                store_result(&[
                    ("rounds", summary.rounds as f64),
                    ("samples", summary.samples as f64),
                ]);
                let mut counters = Counters::new();
                counters.add("rounds", summary.rounds as u64);
                counters.add("samples", summary.samples as u64);
                emit_run_finished(&tel, "learn-train", counters, t0);
                finish_store(&tel, "learn-train");
                let agree = summary
                    .agreement
                    .map(|a| format!(", last-round agreement {:.1}%", a * 100.0))
                    .unwrap_or_default();
                (
                    model,
                    format!(
                        "trained on {} demonstrations over {} round(s){}\n",
                        summary.samples, summary.rounds, agree
                    ),
                )
            };
            model.save(std::path::Path::new(&out))?;
            Ok(format!(
                "{text}policy artifact -> {out}  (run it: ds3r run \
                 --sched il --il-policy {out}; evaluate: ds3r learn \
                 eval --policy {out})\n"
            ))
        }
        "eval" => {
            let path = args.str_or("policy", "il_policy.json");
            let p = std::path::Path::new(&path);
            let (model, note) = if p.exists() {
                (crate::learn::SoftmaxModel::load(p)?, String::new())
            } else if args.has("policy") {
                return Err(Error::Config(format!(
                    "policy artifact '{path}' not found"
                )));
            } else {
                (
                    crate::learn::SoftmaxModel::from_json(
                        &crate::util::json::Json::parse(
                            crate::learn::PRESET_POLICY,
                        )?,
                    )?,
                    format!(
                        "(no {path}; evaluating the committed pretrained \
                         preset)\n"
                    ),
                )
            };
            // The artifact records which oracle it imitates; compare
            // and label against that one unless --oracle overrides.
            if !args.has("oracle") && lc.oracle != model.oracle {
                lc.oracle = model.oracle.clone();
                lc.validate()?;
            }
            let report =
                crate::learn::evaluate(&platform, &apps, &lc, &model)?;
            Ok(format!("{note}{}", learn_eval_text(&report)))
        }
        other => Err(Error::Config(format!(
            "unknown learn subcommand '{other}' (collect, train, eval)"
        ))),
    }
}

// ---------------------------------------------------------------------------
// reproduce: the paper's tables and figures
// ---------------------------------------------------------------------------

/// Table 1: WiFi-TX execution profiles. Regenerated from the resource
/// database so any drift from the paper's numbers fails visibly.
pub fn reproduce_table1() -> String {
    let app = suite::wifi_tx(suite::WifiParams { symbols: 1 });
    let mut rows = Vec::new();
    for t in &app.tasks {
        let cell = |k: &str| {
            t.exec_us
                .get(k)
                .map(|v| format!("{v:.0}"))
                .unwrap_or_default()
        };
        let acc = if t.exec_us.contains_key("ACC_SCR") {
            cell("ACC_SCR")
        } else {
            cell("ACC_FFT")
        };
        rows.push(vec![t.name.clone(), acc, cell("A7"), cell("A15")]);
    }
    let mut out = String::from(
        "Table 1: Execution profiles of WiFi-TX (latency in us)\n",
    );
    out.push_str(&plot::ascii_table(
        &["Task", "HW Acc.", "Odroid A7", "Odroid A15"],
        &rows,
    ));
    out.push_str(
        "paper: Scrambler 8/22/10, Interleaver -/10/4, QPSK -/15/8, \
         Pilot -/5/3, IFFT 16/296/118, CRC -/5/3\n",
    );
    out
}

/// Table 2: the SoC configuration used in the scheduling case study.
pub fn reproduce_table2() -> String {
    let p = Platform::table2_soc();
    let rows: Vec<Vec<String>> = p
        .inventory()
        .into_iter()
        .map(|(name, ty, n)| {
            vec![name, ty.label().to_string(), n.to_string()]
        })
        .collect();
    let mut out =
        String::from("Table 2: SoC configuration for scheduling case studies\n");
    out.push_str(&plot::ascii_table(
        &["Resource", "Type", "# of Instances"],
        &rows,
    ));
    out.push_str(&format!(
        "total PEs: {} (paper: 14 general purpose cores and hardware \
         accelerators)\n",
        p.n_pes()
    ));
    out
}

/// Figure 2: the WiFi-TX application DAG.
pub fn reproduce_fig2() -> String {
    let app = suite::wifi_tx(suite::WifiParams { symbols: 1 });
    let mut out = String::from(
        "Figure 2: WiFi transmitter block diagram (single-symbol chain)\n  ",
    );
    for (i, &t) in app.topo_order().iter().enumerate() {
        if i > 0 {
            out.push_str(" -> ");
        }
        out.push_str(&app.tasks[t].name);
    }
    out.push('\n');
    let frame = suite::wifi_tx(suite::WifiParams::default());
    out.push_str(&format!(
        "frame DAG at default {} symbols: {} tasks, width {}, \
         critical path {:.0} us, total work {:.0} us\n",
        suite::WifiParams::default().symbols,
        frame.len(),
        frame.max_width(),
        frame.critical_path_us(),
        frame.total_work_us(),
    ));
    out
}

/// Figure 3: average job execution time vs injection rate for
/// MET / ETF / ILP-table on the Table-2 SoC with WiFi-TX jobs.
pub fn reproduce_fig3(args: &Args) -> Result<String> {
    let quick = args.has("quick");
    let platform = Platform::table2_soc();
    let symbols = args.usize_or("symbols", 12)?;
    let apps = vec![suite::wifi_tx(suite::WifiParams { symbols })];

    let mut base = SimConfig::default();
    base.max_jobs = args.usize_or("jobs", if quick { 200 } else { 1000 })?;
    base.warmup_jobs = base.max_jobs / 10;
    base.seed = args.usize_or("seed", 42)? as u64;
    base.max_sim_us = 10_000_000.0; // cap deeply saturated points

    let rates = args.rates_or(
        "rates",
        if quick {
            &[1.0, 3.0, 5.0, 6.0, 7.0, 9.0]
        } else {
            &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0]
        },
    )?;
    let scheds = args.list_or("scheds", &["met", "etf", "ilp"]);
    let sched_refs: Vec<&str> = scheds.iter().map(String::as_str).collect();
    let threads = args.usize_or("threads", default_threads())?;

    let points = coordinator::fig3_points(&sched_refs, &rates, base.seed);
    let results =
        coordinator::run_sweep(&platform, &apps, &base, &points, threads)?;
    let series = coordinator::latency_series(&results);

    let mut out = String::from(
        "Figure 3: results from different schedulers, WiFi-TX workload\n",
    );
    let mut rows = Vec::new();
    for r in &results {
        rows.push(vec![
            r.point.scheduler.clone(),
            format!("{:.1}", r.point.rate_per_ms),
            format!("{:.1}", r.avg_latency_us),
            format!("{:.3}", r.throughput_jobs_per_ms),
            format!("{}/{}", r.completed_jobs, r.injected_jobs),
        ]);
    }
    out.push_str(&plot::ascii_table(
        &["scheduler", "jobs/ms", "avg exec us", "thru/ms", "done"],
        &rows,
    ));
    out.push_str(&plot::ascii_chart(
        "avg job execution time vs job injection rate",
        "jobs/ms",
        "us",
        &series,
        72,
        20,
    ));

    // Shape assertions from the paper's discussion.
    out.push_str(&fig3_shape_analysis(&results, &rates));

    let csv_path = args.str_or("csv", "fig3.csv");
    std::fs::write(&csv_path, plot::to_csv("rate_per_ms", &series))?;
    out.push_str(&format!("wrote {csv_path}\n"));
    Ok(out)
}

/// Check the qualitative claims of Figure 3 against sweep results.
pub fn fig3_shape_analysis(
    results: &[coordinator::SweepResult],
    rates: &[f64],
) -> String {
    let get = |s: &str, r: f64| {
        results
            .iter()
            .find(|x| {
                x.point.scheduler == s
                    && (x.point.rate_per_ms - r).abs() < 1e-9
            })
            .map(|x| x.avg_latency_us)
    };
    let lo = rates[0];
    let hi = rates[rates.len() - 1];
    let mut out = String::from("shape vs paper:\n");
    if let (Some(m), Some(e), Some(i)) =
        (get("met", lo), get("etf", lo), get("ilp", lo))
    {
        let spread = (m.max(e).max(i) - m.min(e).min(i))
            / m.min(e).min(i).max(1e-9);
        out.push_str(&format!(
            "  low rate ({lo}/ms): met={m:.0} etf={e:.0} ilp={i:.0} us \
             (spread {:.0}% — paper: 'all schedulers perform similar')\n",
            spread * 100.0
        ));
    }
    if let (Some(m), Some(e), Some(i)) =
        (get("met", hi), get("etf", hi), get("ilp", hi))
    {
        let order_ok = e <= i && i <= m;
        out.push_str(&format!(
            "  high rate ({hi}/ms): met={m:.0} etf={e:.0} ilp={i:.0} us — \
             ordering etf <= ilp <= met: {}\n",
            if order_ok { "HOLDS (matches paper)" } else { "VIOLATED" }
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// fuzz: seeded scenario fuzzing + scheduler-robustness tournament
// ---------------------------------------------------------------------------

/// Build the generator config from `--fuzz-config` (JSON file) plus
/// flag overrides (`--seed`, `--cases`, `--jobs`, `--deadline-us`).
fn fuzz_config_from_args(args: &Args) -> Result<crate::fuzz::FuzzConfig> {
    let mut fc = if args.has("fuzz-config") {
        crate::fuzz::FuzzConfig::load(std::path::Path::new(
            &args.str_or("fuzz-config", ""),
        ))?
    } else {
        crate::fuzz::FuzzConfig::default()
    };
    fc.seed = args.usize_or("seed", fc.seed as usize)? as u64;
    fc.cases = args.usize_or("cases", fc.cases)?;
    fc.jobs = args.usize_or("jobs", fc.jobs)?;
    fc.deadline_us = args.f64_or("deadline-us", fc.deadline_us)?;
    fc.validate()?;
    Ok(fc)
}

/// `ds3r fuzz <run|replay|report>` driver (see [`crate::fuzz`]).
pub fn cmd_fuzz(args: &Args) -> Result<String> {
    let sub = args.positional.get(1).map(String::as_str).unwrap_or("run");
    match sub {
        "run" => cmd_fuzz_run(args),
        "replay" => cmd_fuzz_replay(args),
        "report" => cmd_fuzz_report(args),
        other => Err(Error::Config(format!(
            "unknown fuzz subcommand '{other}' (run, replay, report)"
        ))),
    }
}

fn cmd_fuzz_run(args: &Args) -> Result<String> {
    let platform = platform_by_name(&args.str_or("platform", "table2"))?;
    let apps = apps_from_args(args)?;
    let fuzz = fuzz_config_from_args(args)?;
    let mut opts = crate::fuzz::TournamentOpts::default();
    let roster = args.list_or("scheds", &[]);
    if !roster.is_empty() && roster != ["all"] {
        opts.schedulers = roster;
    }
    opts.threads = args.usize_or("threads", default_threads())?;
    if args.has("repro-dir") {
        opts.repro_dir = Some(std::path::PathBuf::from(
            args.str_or("repro-dir", "fuzz_repros"),
        ));
    }
    if args.has("inject") {
        // Test hook: flag an artificial violation on every scenario
        // containing an event whose label starts with this prefix —
        // exercises the shrink + repro pipeline on a healthy simulator.
        opts.inject_label = Some(args.str_or("inject", "rate="));
    }
    // Campaign manifest: a representative cell config (first scheduler,
    // tournament seed) so run_started carries a meaningful hash.
    let mut cfg0 = config_from_args(args)?;
    cfg0.scheduler =
        opts.schedulers.first().cloned().unwrap_or_default();
    cfg0.seed = fuzz.seed;
    let tel = telemetry::global();
    let t0 = SpanTimer::start();
    let wd = store_digest(&cfg0, &apps);
    emit_run_started(&tel, "fuzz", &cfg0, &wd);
    opts.store = store_ctx(&wd);
    let policy = fail_policy_from_args(args)?;
    let (report, counters, failures) =
        crate::fuzz::run_tournament_with_policy(
            &platform, &apps, &fuzz, &opts, &policy,
        )?;
    let violations: usize =
        report.cells.iter().map(|c| c.violations.len()).sum();
    store_result(&[
        ("cells", report.cells.len() as f64),
        ("violations", violations as f64),
    ]);
    emit_run_finished(&tel, "fuzz", counters, t0);
    finish_store(&tel, "fuzz");
    if args.has("out") {
        let out = args.str_or("out", "tournament.json");
        report.save(std::path::Path::new(&out))?;
    }
    let mut out = render_tournament(&report);
    out.push_str(&failure_footer(&failures));
    Ok(out)
}

/// Re-execute a minimized repro written by `fuzz run` and compare the
/// fresh oracle verdict with the recorded one.  Pass the same workload
/// flags (`--apps`/`--symbols`/`--pulses`/`--platform`) the tournament
/// ran with — the repro pins the simulation config, not the workload.
fn cmd_fuzz_replay(args: &Args) -> Result<String> {
    let path = args.positional.get(2).ok_or_else(|| {
        Error::Config("fuzz replay <repro.json>".into())
    })?;
    let platform = platform_by_name(&args.str_or("platform", "table2"))?;
    let apps = apps_from_args(args)?;
    let repro = crate::fuzz::Repro::load(std::path::Path::new(path))?;
    let fresh = crate::fuzz::replay(&repro, &platform, &apps)?;
    let mut out = format!(
        "repro {path}: scheduler {}, case {}, {} event(s), oracle \
         '{}', {} recorded violation(s)\n",
        repro.scheduler,
        repro.case_idx,
        repro.scenario.events.len(),
        repro.oracle,
        repro.violations.len(),
    );
    let fresh: Vec<(String, String)> = fresh
        .into_iter()
        .map(|v| (v.oracle, v.detail))
        .collect();
    for (oracle, detail) in &fresh {
        out.push_str(&format!("  {oracle}: {detail}\n"));
    }
    if fresh == repro.violations {
        out.push_str("verdict: reproduced bit-identically\n");
    } else if fresh.is_empty() {
        out.push_str("verdict: no longer reproduces (bug fixed?)\n");
    } else {
        out.push_str("verdict: DIVERGED from the recorded violations\n");
    }
    // Render what the failing run looked like, when the tournament
    // attached a probe trace to the repro.
    if let Some(trace) = &repro.trace {
        out.push_str("recorded failing-run trace:\n");
        out.push_str(&crate::probe::render(
            trace,
            args.usize_or("width", 72)?,
        ));
    }
    Ok(out)
}

/// Render a saved [`crate::stats::TournamentReport`] JSON file.
fn cmd_fuzz_report(args: &Args) -> Result<String> {
    let path = args.str_or("out", "tournament.json");
    let report = crate::stats::TournamentReport::load(
        std::path::Path::new(&path),
    )?;
    let mut out = render_tournament(&report);
    if args.has("json") {
        out.push_str(&report.to_json().to_string_pretty());
    }
    Ok(out)
}

fn render_tournament(report: &crate::stats::TournamentReport) -> String {
    let mut out = format!(
        "fuzz tournament: seed {} — {} schedulers × {} cases \
         ({} cells), {} oracle violation(s)\n",
        report.fuzz_seed,
        report.schedulers.len(),
        report.cases,
        report.cells.len(),
        report.violations,
    );
    let mut rows = Vec::new();
    for s in &report.standings {
        rows.push(vec![
            s.scheduler.clone(),
            format!("{:.0}", s.rank_score),
            format!("{:.1}", s.worst_max_us),
            format!("{:.1}", s.mean_p95_us),
            format!("{:.1}", s.mean_p99_us),
            s.deadline_misses.to_string(),
            format!("{:.3}", s.energy_j),
            format!("{:.3}", s.fallback_rate),
            s.violations.to_string(),
        ]);
    }
    out.push_str(&plot::ascii_table(
        &[
            "scheduler",
            "score",
            "worst us",
            "p95 us",
            "p99 us",
            "misses",
            "J",
            "fallback",
            "viol",
        ],
        &rows,
    ));
    if !report.repros.is_empty() {
        out.push_str("minimized repros:\n");
        for r in &report.repros {
            out.push_str(&format!("  {r}\n"));
        }
    }
    out
}

pub fn cmd_reproduce(args: &Args) -> Result<String> {
    let what = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let mut out = String::new();
    match what {
        "table1" => out.push_str(&reproduce_table1()),
        "table2" => out.push_str(&reproduce_table2()),
        "fig2" => out.push_str(&reproduce_fig2()),
        "fig3" => out.push_str(&reproduce_fig3(args)?),
        "all" => {
            out.push_str(&reproduce_table1());
            out.push('\n');
            out.push_str(&reproduce_table2());
            out.push('\n');
            out.push_str(&reproduce_fig2());
            out.push('\n');
            out.push_str(&reproduce_fig3(args)?);
        }
        other => {
            return Err(Error::Config(format!(
                "unknown experiment '{other}' (table1, table2, fig2, fig3, all)"
            )))
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// query + store: offline drivers over the experiment store
// ---------------------------------------------------------------------------

/// `ds3r query` — filter stored run manifests by identity and render
/// them (table/JSONL) or aggregate one counter across the selection.
pub fn cmd_query(args: &Args) -> Result<String> {
    let store = crate::store::global().ok_or_else(|| {
        Error::Config("query requires --store <dir>".into())
    })?;
    let manifests = store.manifests();
    let mut filter = crate::store::QueryFilter::default();
    if args.has("sched") {
        filter.scheduler = Some(args.str_or("sched", ""));
    }
    if args.has("seed") {
        filter.seed = Some(args.usize_or("seed", 0)? as u64);
    }
    if args.has("config-hash") {
        filter.config_hash = Some(args.str_or("config-hash", ""));
    }
    if args.has("kind") {
        filter.kind = Some(args.str_or("kind", ""));
    }
    let sel = filter.select(&manifests);
    if args.has("agg") || args.has("field") {
        let agg = crate::store::Agg::parse(&args.str_or("agg", "mean"))?;
        let field = args.str_or("field", "completed_jobs");
        let a = crate::store::query::aggregate(&sel, &field, agg);
        return Ok(format!("{}\n", a.to_json().to_string()));
    }
    match args.str_or("format", "table").as_str() {
        "jsonl" => Ok(crate::store::query::render_jsonl(&sel)),
        "table" => Ok(crate::store::query::render_table(&sel)),
        other => Err(Error::Config(format!(
            "--format: want table|jsonl, got '{other}'"
        ))),
    }
}

/// `ds3r store <gc|verify|fsck>` — maintain an on-disk experiment
/// store: `gc` drops dangling index rows and unreferenced points
/// (re-indexing orphaned manifests), `verify` checks every key
/// against the content it addresses and fails loudly on a mismatch,
/// `fsck` quarantines unparseable manifests/points into
/// `<store>/quarantine/` and heals the index so the surviving store
/// passes `verify` again.
pub fn cmd_store(args: &Args) -> Result<String> {
    let store = crate::store::global().ok_or_else(|| {
        Error::Config(
            "store gc|verify|fsck requires --store <dir>".into(),
        )
    })?;
    let sub = args.positional.get(1).map(String::as_str).unwrap_or("");
    match sub {
        "gc" => {
            let s = store.gc()?;
            if args.has("json") {
                return Ok(s.to_json().to_string_pretty());
            }
            Ok(format!(
                "gc: kept {} manifests, {} points; dropped {} \
                 unreferenced points, {} stale index rows; re-indexed \
                 {} manifests\n",
                s.kept_manifests,
                s.kept_points,
                s.dropped_points,
                s.dropped_rows,
                s.reindexed,
            ))
        }
        "verify" => {
            let s = store.verify()?;
            if s.ok() {
                if args.has("json") {
                    return Ok(s.to_json().to_string_pretty());
                }
                return Ok(format!(
                    "verify: {} manifests, {} points checked — store \
                     is consistent\n",
                    s.manifests_checked, s.points_checked,
                ));
            }
            let mut detail = String::new();
            for m in &s.mismatches {
                detail.push_str(&format!("  {m}\n"));
            }
            Err(Error::Config(format!(
                "store verify failed ({} mismatches):\n{detail}",
                s.mismatches.len()
            )))
        }
        "fsck" => {
            let s = store.fsck()?;
            if args.has("json") {
                return Ok(s.to_json().to_string_pretty());
            }
            let mut out = format!(
                "fsck: kept {} manifests, {} points; quarantined {} \
                 manifests, {} points; dropped {} index rows; \
                 re-indexed {} manifests\n",
                s.manifests_kept,
                s.points_kept,
                s.manifests_quarantined,
                s.points_quarantined,
                s.index_rows_dropped,
                s.reindexed,
            );
            if s.index_tail_salvaged {
                out.push_str(
                    "fsck: salvaged a torn trailing index line (crash \
                     mid-append)\n",
                );
            }
            if s.clean() {
                out.push_str("fsck: store is clean\n");
            }
            Ok(out)
        }
        other => Err(Error::Config(format!(
            "unknown store subcommand '{other}' (gc, verify, fsck)"
        ))),
    }
}

// ---------------------------------------------------------------------------
// trace: probe-trace viewer and differ
// ---------------------------------------------------------------------------

/// `ds3r trace <show|diff>` — render or compare probe trace artifacts
/// (plain [`crate::probe::TRACE_KIND`] files or
/// [`crate::probe::TRACE_SET_KIND`] bundles from scenario sweeps).
pub fn cmd_trace(args: &Args) -> Result<String> {
    let sub = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("show");
    let width = args.usize_or("width", 72)?;
    let load = |pos: usize,
                usage: &str|
     -> Result<Vec<crate::probe::TraceSeries>> {
        let path = args.positional.get(pos).ok_or_else(|| {
            Error::Config(format!("trace {usage}"))
        })?;
        crate::probe::traces_from_json(
            &crate::util::json::Json::parse_file(std::path::Path::new(
                path,
            ))?,
        )
    };
    match sub {
        "show" => {
            let traces = load(2, "show <trace.json>")?;
            let mut out = String::new();
            for t in &traces {
                out.push_str(&crate::probe::render(t, width));
            }
            Ok(out)
        }
        "diff" => {
            let a = load(2, "diff <a.json> <b.json>")?;
            let b = load(3, "diff <a.json> <b.json>")?;
            let mut out = String::new();
            if a.len() != b.len() {
                out.push_str(&format!(
                    "trace count differs: {} vs {}\n",
                    a.len(),
                    b.len()
                ));
            }
            for (ta, tb) in a.iter().zip(&b) {
                if a.len() > 1 {
                    out.push_str(&format!(
                        "[{} vs {}]\n",
                        if ta.scenario.is_empty() { "-" } else { &ta.scenario },
                        if tb.scenario.is_empty() { "-" } else { &tb.scenario },
                    ));
                }
                let (txt, _differing) = crate::probe::diff(ta, tb);
                out.push_str(&txt);
            }
            Ok(out)
        }
        other => Err(Error::Config(format!(
            "unknown trace subcommand '{other}' (show, diff)"
        ))),
    }
}

pub const USAGE: &str = "\
ds3r — DSSoC simulation framework (DS3 reproduction)

USAGE:
  ds3r run       [--sched etf] [--rate 3.0] [--jobs 500] [--apps wifi-tx]
                 [--symbols 12] [--governor ondemand] [--throttle 85]
                 [--power-cap 6] [--gantt] [--traces] [--xla-thermal]
                 [--record-trace out.json] [--trace-file in.json]
                 [--il-policy policy.json] [--scenario pe-failure|file.json]
                 [--platform table2|zcu102] [--config file.json] [--json]
                 [--probe trace.json|-] [--probe-budget 512]
  ds3r sweep     [--scheds met,etf,ilp] [--rates 1:8:1] [--threads N]
                 [--csv out.csv] (+ run flags)
  ds3r scenario  list | show <name> | export [--out dir] |
                 sweep [--scenarios all|a,b] [--probe traces.json|-]
                 (+ run flags)
  ds3r dse       run    [--dse-config file.json] [--objectives latency,energy]
                        [--population 16] [--generations 13]
                        [--algorithm nsga2|random] [--search-seed 7]
                        [--mutation 0.35] [--crossover 0.9]
                        [--min-pes 1] [--max-pes 8] [--eval-seeds 1,2]
                        [--eval-scenarios bursty-wifi] [--threads N]
                        [--checkpoint dse_checkpoint.json] (+ run flags)
                 resume --checkpoint file [--generations N]
                 front  --checkpoint file [--json]
                 export --checkpoint file [--out dse_designs]
  ds3r learn     collect [--out il_dataset.json] |
                 train   [--data il_dataset.json] [--out il_policy.json] |
                 eval    [--policy il_policy.json]
                 [--oracle etf] [--rounds 2] [--epochs 10] [--lr 0.05]
                 [--l2 0.0001] [--train-seed 7] [--guard 1.25]
                 [--learn-seeds 1,2] [--rates 1.5,3] [--baselines random,rr]
                 [--learn-config file.json] [--threads N] (+ run flags)
  ds3r fuzz      run    [--seed 42] [--cases 200] [--jobs 80]
                        [--scheds all|a,b] [--threads N]
                        [--fuzz-config file.json] [--deadline-us 20000]
                        [--out tournament.json] [--repro-dir dir]
                        [--inject <label-prefix>] (+ run flags)
                 replay <repro.json> (+ workload flags)
                 report [--out tournament.json] [--json]
  ds3r reproduce [table1|table2|fig2|fig3|all] [--quick] [--jobs N]
                 [--rates lo:hi:step] [--csv fig3.csv]
  ds3r validate  [--jobs 200]
  ds3r query     --store dir [--sched etf] [--seed 42] [--kind sweep]
                 [--config-hash h] [--format table|jsonl]
                 [--agg count|mean|p95|worst] [--field completed_jobs]
  ds3r store     gc | verify | fsck  --store dir [--json]
  ds3r trace     show <trace.json> [--width 72] |
                 diff <a.json> <b.json>
  ds3r list

OBSERVABILITY (any subcommand):
  --telemetry <path|->   stream structured JSONL events to a file, or
                         stderr for '-' (run_started/run_finished with
                         config hash + seed + git describe, per-phase
                         scenario stats, dse_generation, learn_round,
                         diagnostics).  Deterministic by default: same
                         config + seed give byte-identical streams for
                         any --threads value.
  --telemetry-timing     include wall-clock events/fields (sweep
                         progress rates, ETAs, spans, run wall time)
  --progress             live progress lines on stderr (completed/total
                         + sims/s for sweeps, per-generation DSE stats,
                         per-round learn agreement)
  --store <dir>          content-addressed experiment store: every
                         campaign writes a manifest (config hash +
                         workload digest + seed + git describe +
                         counters + result summary); sweep, fuzz and
                         dse consult the per-point cache and skip
                         already-simulated points, merging cached
                         results back in input order so reports and
                         the default telemetry stream stay
                         byte-identical with a cold run
  --log-format json|text render library diagnostics as JSONL or text
                         (default text)
  --probe <path|->       (run, scenario sweep) record bounded in-sim
                         time series — per-PE util/frequency/
                         availability, per-node temperature, SoC power,
                         ready-queue depth, scheduler invocations,
                         phase markers — as a schema-versioned trace
                         artifact ('-' prints it).  Deterministic:
                         byte-identical for any --threads value; with
                         --store the trace is linked into the manifest
                         as a content-addressed 'trace' point.  Render
                         or compare with 'ds3r trace show|diff'.
  --probe-budget <n>     max kept samples per probe channel (default
                         512); longer runs downsample by stride
                         doubling, always preserving both endpoints

FAULT TOLERANCE (sweep, scenario sweep, fuzz run, dse run/resume):
  --fail-policy abort|quarantine[:N]
                         abort (default): the first panicking,
                         timed-out, or erroring grid point fails the
                         whole campaign (exit 1).  quarantine: failed
                         points are dropped from the report, each
                         emits a deterministic point_failed event and
                         a summary footer, failed points are never
                         cached, and the process exits 2 (partial
                         success).  Quarantined sets are identical for
                         any --threads value.  :N caps the budget —
                         more than N failures aborts after all.
  --step-budget <n>      deterministic watchdog: cap every simulation
                         at n event-loop iterations (never wall
                         clock); a tripped run reports 'timed out'
                         bit-identically on every host and counts as
                         a failed point under --fail-policy
  --inject-fault panic=<prefix>|hang=<prefix>
                         test hook: 'panic' panics in pooled grid
                         points whose label starts with the prefix
                         ('{scheduler}@{rate}',
                         '{scheduler}@{scenario}', or a design id);
                         'hang' pre-charges the watchdog for matching
                         scheduler names (trips only with
                         --step-budget).  Exercises the quarantine
                         machinery on a healthy build.
  ds3r store fsck        quarantine unparseable manifests/points into
                         <store>/quarantine/, heal a torn index tail,
                         drop dangling rows — 'store verify' passes on
                         what remains
";

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_values_flags_positionals() {
        let a = args("run --sched etf --jobs=100 --gantt --rate 2.5 pos2");
        assert_eq!(a.positional, vec!["run", "pos2"]);
        assert_eq!(a.str_or("sched", "x"), "etf");
        assert_eq!(a.usize_or("jobs", 0).unwrap(), 100);
        assert_eq!(a.f64_or("rate", 0.0).unwrap(), 2.5);
        assert!(a.has("gantt"));
        assert!(!a.has("traces"));
    }

    #[test]
    fn rate_range_expansion() {
        let a = args("sweep --rates 1:3:0.5");
        assert_eq!(
            a.rates_or("rates", &[]).unwrap(),
            vec![1.0, 1.5, 2.0, 2.5, 3.0]
        );
        let a = args("sweep --rates 1,4,9");
        assert_eq!(a.rates_or("rates", &[]).unwrap(), vec![1.0, 4.0, 9.0]);
        let a = args("sweep");
        assert_eq!(a.rates_or("rates", &[7.0]).unwrap(), vec![7.0]);
        assert!(args("x --rates 5:1:1").rates_or("rates", &[]).is_err());
        assert!(args("x --rates a:b:c").rates_or("rates", &[]).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = args("sweep --scheds met,etf");
        assert_eq!(a.list_or("scheds", &["x"]), vec!["met", "etf"]);
        assert_eq!(args("sweep").list_or("scheds", &["x"]), vec!["x"]);
    }

    #[test]
    fn config_from_args_applies_flags() {
        let a = args(
            "run --sched met --rate 4 --jobs 80 --warmup 8 --governor \
             ondemand --throttle 80 --power-cap 5.5 --traces \
             --step-budget 5000",
        );
        let c = config_from_args(&a).unwrap();
        assert_eq!(c.scheduler, "met");
        assert_eq!(c.injection_rate_per_ms, 4.0);
        assert_eq!(c.max_jobs, 80);
        assert_eq!(c.dtpm.governor, "ondemand");
        assert!(c.dtpm.thermal_throttle);
        assert_eq!(c.dtpm.throttle_temp_c, 80.0);
        assert_eq!(c.dtpm.power_cap_w, Some(5.5));
        assert!(c.capture_traces);
        assert_eq!(c.step_budget, 5000);
    }

    #[test]
    fn fail_policy_flag_parses() {
        use coordinator::FailPolicy;
        assert_eq!(
            fail_policy_from_args(&args("sweep")).unwrap(),
            FailPolicy::Abort
        );
        assert_eq!(
            fail_policy_from_args(&args("sweep --fail-policy quarantine"))
                .unwrap(),
            FailPolicy::Quarantine { max_failures: None }
        );
        assert_eq!(
            fail_policy_from_args(&args(
                "sweep --fail-policy quarantine:3"
            ))
            .unwrap(),
            FailPolicy::Quarantine { max_failures: Some(3) }
        );
        assert!(
            fail_policy_from_args(&args("sweep --fail-policy retry"))
                .is_err()
        );
    }

    /// Serializes the tests that install the process-global telemetry
    /// dispatcher (cargo runs tests in parallel threads).
    static TEL_GLOBAL_LOCK: std::sync::Mutex<()> =
        std::sync::Mutex::new(());

    #[test]
    fn telemetry_flags_stream_wellformed_jsonl() {
        let _g = TEL_GLOBAL_LOCK.lock().unwrap();
        let dir = std::env::temp_dir().join("ds3r_cli_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        let a = args(&format!(
            "sweep --scheds etf --rates 1,2 --jobs 30 --warmup 3 \
             --threads 2 --telemetry {}",
            path.display()
        ));
        init_telemetry(&a).unwrap();
        cmd_sweep(&a).unwrap();
        telemetry::set_global(Telemetry::disabled());
        let text = std::fs::read_to_string(&path).unwrap();
        // Assert on parsed structure, not serialized spelling.
        let mut kinds = Vec::new();
        for line in text.lines() {
            let j = crate::util::json::Json::parse(line)
                .unwrap_or_else(|e| {
                    panic!("malformed JSONL line '{line}': {e}")
                });
            if let Some(k) =
                j.get("event").and_then(crate::util::json::Json::as_str)
            {
                kinds.push(k.to_string());
            }
        }
        assert!(kinds.iter().any(|k| k == "run_started"), "{text}");
        assert!(kinds.iter().any(|k| k == "run_finished"), "{text}");
        assert!(text.contains("config_hash"), "{text}");
        assert!(text.contains("workload_digest"), "{text}");
        // Default stream is deterministic: wall-clock progress events
        // and wall_s are excluded.
        assert!(!text.contains("sweep_progress"), "{text}");
        assert!(!text.contains("wall_s"), "{text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn warm_store_sweep_is_byte_identical_and_fully_cached() {
        let _g = TEL_GLOBAL_LOCK.lock().unwrap();
        let dir = std::env::temp_dir().join("ds3r_cli_store_sweep_test");
        let _ = std::fs::remove_dir_all(&dir);
        let a = args(&format!(
            "sweep --scheds etf,met --rates 1,2 --jobs 25 --warmup 3 \
             --threads 2 --store {}",
            dir.display()
        ));
        init_telemetry(&a).unwrap();
        let cold = cmd_sweep(&a).unwrap();
        let store = crate::store::global().unwrap();
        assert_eq!(store.session_hits(), 0);
        assert_eq!(store.session_misses(), 4);
        assert!(store.last_manifest_key().is_some());
        // Re-init opens a fresh handle over the same directory: every
        // point must now come from the cache, and the rendered report
        // must not change by a byte.
        init_telemetry(&a).unwrap();
        let warm = cmd_sweep(&a).unwrap();
        let store = crate::store::global().unwrap();
        assert_eq!(store.session_misses(), 0);
        assert_eq!(store.session_hits(), 4);
        assert_eq!(cold, warm);
        telemetry::set_global(Telemetry::disabled());
        crate::store::set_global(None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn query_filters_aggregate_and_store_maintenance() {
        let _g = TEL_GLOBAL_LOCK.lock().unwrap();
        let dir = std::env::temp_dir().join("ds3r_cli_store_query_test");
        let _ = std::fs::remove_dir_all(&dir);
        let a = args(&format!(
            "sweep --scheds etf --rates 1 --jobs 20 --warmup 2 \
             --threads 1 --store {}",
            dir.display()
        ));
        init_telemetry(&a).unwrap();
        cmd_sweep(&a).unwrap();
        let q = |cmd: &str| {
            let qa = args(cmd);
            init_telemetry(&qa).unwrap();
            qa
        };
        let jsonl = cmd_query(&q(&format!(
            "query --store {} --format jsonl",
            dir.display()
        )))
        .unwrap();
        assert_eq!(jsonl.lines().count(), 1);
        let j = crate::util::json::Json::parse(
            jsonl.lines().next().unwrap(),
        )
        .unwrap();
        assert_eq!(
            j.get("cmd").and_then(crate::util::json::Json::as_str),
            Some("sweep")
        );
        let agg = cmd_query(&q(&format!(
            "query --store {} --agg count --field completed_jobs",
            dir.display()
        )))
        .unwrap();
        let j = crate::util::json::Json::parse(agg.trim()).unwrap();
        assert_eq!(
            j.get("count")
                .and_then(crate::util::json::Json::as_usize),
            Some(1)
        );
        // A filter matching nothing selects nothing.
        let none = cmd_query(&q(&format!(
            "query --store {} --sched nosuch --format jsonl",
            dir.display()
        )))
        .unwrap();
        assert_eq!(none, "");
        // Maintenance drivers: a freshly written store is consistent
        // and gc keeps everything.
        let verify =
            cmd_store(&q(&format!("store verify --store {}", dir.display())))
                .unwrap();
        assert!(verify.contains("consistent"), "{verify}");
        let gc =
            cmd_store(&q(&format!("store gc --store {}", dir.display())))
                .unwrap();
        assert!(gc.contains("dropped 0 unreferenced points"), "{gc}");
        let fsck = cmd_store(&q(&format!(
            "store fsck --store {}",
            dir.display()
        )))
        .unwrap();
        assert!(fsck.contains("store is clean"), "{fsck}");
        telemetry::set_global(Telemetry::disabled());
        crate::store::set_global(None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_log_format_is_rejected_before_installing() {
        let _g = TEL_GLOBAL_LOCK.lock().unwrap();
        assert!(init_telemetry(&args("run --log-format yaml")).is_err());
        assert!(init_telemetry(&args("run --log-format json")).is_ok());
        telemetry::set_global(Telemetry::disabled());
    }

    #[test]
    fn bad_inject_fault_specs_are_rejected() {
        let _g = TEL_GLOBAL_LOCK.lock().unwrap();
        // No '=' separator, and an unknown fault kind.
        assert!(init_telemetry(&args("run --inject-fault panic"))
            .is_err());
        assert!(init_telemetry(&args("run --inject-fault explode=met"))
            .is_err());
        telemetry::set_global(Telemetry::disabled());
    }

    #[test]
    fn sweep_quarantine_drops_points_and_flags_partial_success() {
        let _g = TEL_GLOBAL_LOCK.lock().unwrap();
        // Unique injection label: no other test sweeps rate 2.75.
        let a = args(
            "sweep --scheds met,etf --rates 2.75 --jobs 25 --warmup 3 \
             --threads 2 --fail-policy quarantine \
             --inject-fault panic=met@2.75",
        );
        init_telemetry(&a).unwrap();
        let out = cmd_sweep(&a);
        crate::faultpoint::disarm(
            crate::faultpoint::sites::SWEEP_POINT,
            "met@2.75",
        );
        telemetry::set_global(Telemetry::disabled());
        let out = out.unwrap();
        // The failed point is gone from the table, the footer names
        // it, and main's exit-2 flag is raised.
        assert!(partial_failure());
        assert!(out.contains("quarantined 1/2 points"), "{out}");
        assert!(out.contains("met@2.75 (panic)"), "{out}");
        // The surviving scheduler still reports normally.
        assert!(out.contains("etf"), "{out}");
        // The next campaign starts with a clean verdict.
        init_telemetry(&args("run")).unwrap();
        telemetry::set_global(Telemetry::disabled());
        assert!(!partial_failure());
    }

    #[test]
    fn app_and_platform_lookup() {
        assert!(app_by_name("wifi-tx", 4, 4).is_ok());
        assert!(app_by_name("pulse-doppler", 4, 4).is_ok());
        assert!(app_by_name("tetris", 4, 4).is_err());
        assert!(platform_by_name("table2").is_ok());
        assert!(platform_by_name("zcu102").is_ok());
        assert!(platform_by_name("m1-max").is_err());
    }

    #[test]
    fn table1_matches_paper_values() {
        let t = reproduce_table1();
        for needle in ["scrambler-encoder", "296", "118", "16", "22"] {
            assert!(t.contains(needle), "missing {needle} in:\n{t}");
        }
    }

    #[test]
    fn table2_shows_14_pes() {
        let t = reproduce_table2();
        assert!(t.contains("total PEs: 14"));
        assert!(t.contains("A15"));
        assert!(t.contains("ACC_FFT"));
    }

    #[test]
    fn fig2_shows_pipeline() {
        let t = reproduce_fig2();
        assert!(t.contains("scrambler-encoder -> interleaver-0"));
        assert!(t.contains("crc"));
    }

    #[test]
    fn list_covers_everything() {
        let t = cmd_list();
        for s in ["met", "etf", "ilp", "ondemand", "wifi-tx", "zcu102"] {
            assert!(t.contains(s));
        }
    }

    #[test]
    fn run_quick_smoke() {
        let a = args("run --rate 0.5 --jobs 20 --warmup 2 --symbols 2");
        let out = cmd_run(&a).unwrap();
        assert!(out.contains("scheduler=etf"));
        assert!(out.contains("completed=20"));
    }

    #[test]
    fn scenario_flag_resolves_presets() {
        let a = args("run --scenario pe-failure");
        let c = config_from_args(&a).unwrap();
        assert_eq!(c.scenario.as_ref().unwrap().name, "pe-failure");
        let a = args("run --scenario no-such-scenario");
        assert!(config_from_args(&a).is_err());
    }

    #[test]
    fn scenario_subcommand_list_and_show() {
        let out = cmd_scenario(&args("scenario list")).unwrap();
        for name in crate::scenario::presets::names() {
            assert!(out.contains(name), "missing {name}:\n{out}");
        }
        let out = cmd_scenario(&args("scenario show pe-failure")).unwrap();
        assert!(out.contains("pe-fail"));
        assert!(out.contains("\"at_us\""));
        assert!(cmd_scenario(&args("scenario frobnicate")).is_err());
        assert!(cmd_scenario(&args("scenario show")).is_err());
    }

    #[test]
    fn dse_run_front_resume_export_cycle() {
        let dir = std::env::temp_dir().join("ds3r_cli_dse_test");
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt = dir.join("ckpt.json");
        let ckpt_s = ckpt.to_string_lossy().into_owned();

        let out = cmd_dse(&args(&format!(
            "dse run --population 4 --generations 1 --jobs 25 --warmup 2 \
             --rate 2 --symbols 2 --threads 2 --search-seed 11 \
             --checkpoint {ckpt_s}"
        )))
        .unwrap();
        assert!(out.contains("Pareto front"), "{out}");
        assert!(out.contains("gen   0"), "{out}");
        assert!(ckpt.exists());

        let out = cmd_dse(&args(&format!(
            "dse front --checkpoint {ckpt_s}"
        )))
        .unwrap();
        assert!(out.contains("design"), "{out}");

        // Budget exhausted: resume reports completion...
        let out = cmd_dse(&args(&format!(
            "dse resume --symbols 2 --checkpoint {ckpt_s}"
        )))
        .unwrap();
        assert!(out.contains("already complete"), "{out}");
        // ...the checkpoint pins the workload against silent switches...
        let err = cmd_dse(&args(&format!(
            "dse resume --apps wifi-rx --checkpoint {ckpt_s}"
        )));
        assert!(err.is_err(), "conflicting --apps must be rejected");
        let err = cmd_dse(&args(&format!(
            "dse resume --symbols 9 --checkpoint {ckpt_s}"
        )));
        assert!(err.is_err(), "conflicting --symbols must be rejected");
        // ...and --generations extends the run.
        let out = cmd_dse(&args(&format!(
            "dse resume --symbols 2 --generations 2 --checkpoint {ckpt_s}"
        )))
        .unwrap();
        assert!(out.contains("gen   2"), "{out}");

        let export_dir = dir.join("designs");
        let out = cmd_dse(&args(&format!(
            "dse export --checkpoint {ckpt_s} --out {}",
            export_dir.to_string_lossy()
        )))
        .unwrap();
        assert!(out.contains("front.json"), "{out}");
        // Every exported design is a loadable platform.
        let front = std::fs::read_dir(&export_dir).unwrap().count();
        assert!(front >= 2, "expected front.json + >=1 design");

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dse_flag_validation() {
        assert!(cmd_dse(&args("dse frobnicate")).is_err());
        assert!(cmd_dse(&args("dse resume")).is_err());
        assert!(cmd_dse(&args("dse front")).is_err());
        assert!(cmd_dse(&args("dse export")).is_err());
        assert!(dse_config_from_args(&args(
            "dse run --objectives latency,carbon"
        ))
        .is_err());
        assert!(dse_config_from_args(&args(
            "dse run --algorithm annealing"
        ))
        .is_err());
        let c = dse_config_from_args(&args(
            "dse run --objectives energy,peak_temp --population 6 \
             --eval-seeds 3,4 --sched met",
        ))
        .unwrap();
        assert_eq!(c.population, 6);
        assert_eq!(c.seeds, vec![3, 4]);
        assert_eq!(c.sim.scheduler, "met");
        assert_eq!(
            c.objectives,
            vec![
                crate::dse::Objective::Energy,
                crate::dse::Objective::PeakTemp
            ]
        );
    }

    #[test]
    fn learn_config_from_args_applies_flags() {
        let lc = learn_config_from_args(&args(
            "learn train --oracle heft --rounds 3 --epochs 4 --lr 0.1 \
             --l2 0.01 --train-seed 11 --guard 1.5 --learn-seeds 9,10 \
             --rates 1,2 --baselines rr --max-samples 500 --jobs 80 \
             --warmup 8",
        ))
        .unwrap();
        assert_eq!(lc.oracle, "heft");
        assert_eq!(lc.rounds, 3);
        assert_eq!(lc.epochs, 4);
        assert_eq!(lc.learning_rate, 0.1);
        assert_eq!(lc.l2, 0.01);
        assert_eq!(lc.train_seed, 11);
        assert_eq!(lc.guard_ratio, 1.5);
        assert_eq!(lc.seeds, vec![9, 10]);
        assert_eq!(lc.rates_per_ms, vec![1.0, 2.0]);
        assert_eq!(lc.baselines, vec!["rr"]);
        assert_eq!(lc.max_samples_per_run, 500);
        assert_eq!(lc.sim.max_jobs, 80);
        // Validation flows through.
        assert!(learn_config_from_args(&args("learn --guard 0.5"))
            .is_err());
        assert!(learn_config_from_args(&args("learn --oracle il"))
            .is_err());
    }

    #[test]
    fn learn_cli_collect_train_eval_cycle() {
        let dir = std::env::temp_dir().join("ds3r_cli_learn_test");
        std::fs::create_dir_all(&dir).unwrap();
        let data = dir.join("data.json");
        let policy = dir.join("policy.json");
        let base = "--learn-seeds 1 --rates 2 --jobs 30 --warmup 3 \
                    --symbols 2 --rounds 1 --epochs 2 --threads 2";
        let out = cmd_learn(&args(&format!(
            "learn collect --out {} {base}",
            data.display()
        )))
        .unwrap();
        assert!(out.contains("demonstrations"), "{out}");
        assert!(data.exists());
        let out = cmd_learn(&args(&format!(
            "learn train --data {} --out {} {base}",
            data.display(),
            policy.display()
        )))
        .unwrap();
        assert!(out.contains("policy artifact"), "{out}");
        assert!(policy.exists());
        let out = cmd_learn(&args(&format!(
            "learn eval --policy {} {base}",
            policy.display()
        )))
        .unwrap();
        assert!(out.contains("agreement"), "{out}");
        assert!(out.contains("il"), "{out}");
        assert!(cmd_learn(&args("learn frobnicate")).is_err());
        assert!(cmd_learn(&args(
            "learn eval --policy /nonexistent/policy.json"
        ))
        .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_with_scenario_reports_phases() {
        // Acceptance path: `run --scenario pe-failure` end-to-end with
        // per-phase stats in the printed report.
        let a = args(
            "run --scenario pe-failure --rate 2 --jobs 250 --warmup 10 \
             --symbols 4",
        );
        let out = cmd_run(&a).unwrap();
        assert!(out.contains("scenario 'pe-failure'"), "{out}");
        assert!(out.contains("baseline"), "{out}");
        assert!(out.contains("pe10-fail"), "{out}");
    }
}
