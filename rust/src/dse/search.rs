//! The guided search loop: NSGA-II-style evolutionary multi-objective
//! optimization (plus a pure random-search baseline) with JSON
//! checkpoint/resume.
//!
//! One *generation* evaluates `population` candidate genomes (fanned out
//! over OS threads, cache-deduplicated), folds every result into the
//! Pareto archive, and — under `nsga2` — selects the next parent
//! population by non-dominated rank and crowding distance.  All
//! randomness flows from one [`Rng`] stream whose state is part of the
//! checkpoint, and evaluation results are deterministic per genome, so:
//!
//! * the same config + seed give a bit-identical archive for any thread
//!   count, and
//! * a search resumed from a checkpoint continues bit-identically to an
//!   uninterrupted run (`rust/tests/integration_dse.rs` pins both).

use std::path::Path;

use super::archive::{dominates, DesignPoint, ParetoArchive};
use super::eval::Evaluator;
use super::genome::{GenomeSpace, PlatformGenome};
use super::DseConfig;
use crate::app::AppGraph;
use crate::platform::Platform;
use crate::rng::Rng;
use crate::scenario::{Action, Scenario};
use crate::stats::DseGenStats;
use crate::telemetry::{Event, Telemetry};
use crate::util::json::Json;
use crate::{Error, Result};

/// Checkpoint format version.
const CHECKPOINT_SCHEMA: f64 = 1.0;
const CHECKPOINT_KIND: &str = "ds3r-dse-checkpoint";

/// The design-space exploration engine.
#[derive(Debug, Clone)]
pub struct DseEngine {
    cfg: DseConfig,
    space: GenomeSpace,
    evaluator: Evaluator,
    rng: Rng,
    population: Vec<DesignPoint>,
    archive: ParetoArchive,
    history: Vec<DseGenStats>,
    /// Opaque caller-provided description of the workload the search
    /// ran under (the CLI stores its `--apps`/`--symbols`/`--pulses`
    /// here).  Persisted in the checkpoint so `resume` can rebuild —
    /// and refuse to silently change — the workload.
    workload: Option<Json>,
    /// Event stream for per-generation summaries
    /// ([`Event::DseGeneration`]).  Not part of the checkpoint:
    /// telemetry is an environment concern, re-attached after resume
    /// (`from_checkpoint` builds the engine with it disabled).
    telemetry: Telemetry,
}

impl DseEngine {
    /// Build a fresh engine around `base` (the platform whose clusters,
    /// classes and floorplan anchor the genome space).  Fails with
    /// [`Error::Config`] on invalid configuration — including scenario
    /// presets that reference PE ids the smallest decodable design
    /// cannot have.
    pub fn new(base: Platform, cfg: DseConfig) -> Result<DseEngine> {
        cfg.validate()?;
        let space = GenomeSpace::new(
            base,
            cfg.min_pes_per_cluster,
            cfg.max_pes_per_cluster,
            cfg.hop_latency_range,
            cfg.link_bandwidth_range,
            cfg.power_budget_range,
            cfg.explore_power_budget,
        )?;
        let scenarios = cfg
            .scenarios
            .iter()
            .map(|n| crate::scenario::resolve(n))
            .collect::<Result<Vec<_>>>()?;
        let min_total = cfg.min_pes_per_cluster * space.n_clusters();
        for sc in &scenarios {
            check_scenario_pe_refs(sc, min_total)?;
        }
        let evaluator = Evaluator::new(
            cfg.sim.clone(),
            cfg.seeds.clone(),
            scenarios,
            cfg.eval_threads(),
            cfg.explore_power_budget,
        )?;
        let rng = Rng::new(cfg.search_seed);
        Ok(DseEngine {
            cfg,
            space,
            evaluator,
            rng,
            population: Vec::new(),
            archive: ParetoArchive::new(),
            history: Vec::new(),
            workload: None,
            telemetry: Telemetry::disabled(),
        })
    }

    /// Attach a telemetry handle: every later [`Self::step`] emits one
    /// deterministic [`Event::DseGeneration`] (archive size,
    /// hypervolume proxy, cache hits, sims) after the generation
    /// completes.  Fixed-seed searches emit byte-identical streams
    /// regardless of `eval_threads` — asserted by
    /// `rust/tests/integration_telemetry.rs`.
    pub fn set_telemetry(&mut self, tel: Telemetry) {
        self.telemetry = tel;
    }

    /// Attach an experiment store: the evaluator consults its
    /// `dse-eval` point cache before simulating and records fresh
    /// evaluations back, so an interrupted search resumes across
    /// processes even without a checkpoint (see
    /// [`Evaluator::set_store`]).
    pub fn set_store(&mut self, store: Option<crate::store::StoreCtx>) {
        self.evaluator.set_store(store);
    }

    /// Evaluations served from the attached experiment store.
    pub fn store_hits(&self) -> usize {
        self.evaluator.store_hits
    }

    /// Route evaluation failures (panics, watchdog trips, per-design
    /// errors) per `policy` instead of aborting the search (see
    /// [`Evaluator::set_fail_policy`]).  Quarantined designs score the
    /// finite worst-case surrogate and are dominated away.
    pub fn set_fail_policy(
        &mut self,
        policy: crate::coordinator::FailPolicy,
    ) {
        self.evaluator.set_fail_policy(policy);
    }

    /// Evaluations quarantined under the active fail policy.
    pub fn quarantined(&self) -> usize {
        self.evaluator.quarantined
    }

    /// Attach an opaque workload description persisted with every
    /// checkpoint (see the `workload` field).
    pub fn set_workload_meta(&mut self, meta: Json) {
        self.workload = Some(meta);
    }

    pub fn workload_meta(&self) -> Option<&Json> {
        self.workload.as_ref()
    }

    pub fn config(&self) -> &DseConfig {
        &self.cfg
    }

    pub fn space(&self) -> &GenomeSpace {
        &self.space
    }

    pub fn archive(&self) -> &ParetoArchive {
        &self.archive
    }

    pub fn history(&self) -> &[DseGenStats] {
        &self.history
    }

    /// Generations completed so far (the seeded generation 0 counts).
    pub fn completed_generations(&self) -> usize {
        self.history.len()
    }

    /// Total generations this engine will run: the initial population
    /// plus `cfg.generations` evolutionary rounds.
    pub fn target_generations(&self) -> usize {
        self.cfg.generations + 1
    }

    pub fn is_done(&self) -> bool {
        self.completed_generations() >= self.target_generations()
    }

    /// Extend (or shrink) the evolutionary budget — used by
    /// `dse resume --generations N`.
    pub fn set_generations(&mut self, generations: usize) {
        self.cfg.generations = generations;
    }

    /// Run one generation: the seeded initial population first, then
    /// evolutionary (or random) rounds.  Returns that generation's
    /// summary (also appended to [`Self::history`]).
    pub fn step(&mut self, apps: &[AppGraph]) -> Result<DseGenStats> {
        if self.is_done() {
            return Err(Error::Config(format!(
                "search already ran {} generations; raise the budget to \
                 continue",
                self.completed_generations()
            )));
        }
        let evals0 = self.evaluator.evals_requested;
        let hits0 = self.evaluator.cache_hits;
        let sims0 = self.evaluator.sims_run;

        let genomes: Vec<PlatformGenome> = if self.history.is_empty() {
            // Generation 0: the base design plus random exploration.
            let mut g = vec![self.space.seed_genome()];
            while g.len() < self.cfg.population {
                g.push(self.space.random(&mut self.rng));
            }
            g
        } else if self.cfg.algorithm == "random" {
            (0..self.cfg.population)
                .map(|_| self.space.random(&mut self.rng))
                .collect()
        } else {
            self.make_offspring()
        };

        let metrics =
            self.evaluator.evaluate_batch(&self.space, apps, &genomes)?;
        let points: Vec<DesignPoint> = genomes
            .into_iter()
            .zip(metrics)
            .map(|(genome, m)| {
                let objectives =
                    m.objective_vector(&self.cfg.objectives);
                DesignPoint { genome, metrics: m, objectives }
            })
            .collect();
        for p in &points {
            self.archive.insert(p.clone());
        }

        self.population = if self.history.is_empty()
            || self.cfg.algorithm == "random"
        {
            points
        } else {
            // µ+λ environmental selection over parents ∪ offspring.
            let mut combined = std::mem::take(&mut self.population);
            combined.extend(points);
            select_nsga2(combined, self.cfg.population)
        };

        let stats = DseGenStats {
            generation: self.history.len(),
            evals: self.evaluator.evals_requested - evals0,
            cache_hits: self.evaluator.cache_hits - hits0,
            sims: self.evaluator.sims_run - sims0,
            front_size: self.archive.len(),
            hypervolume: self.archive.hypervolume_proxy(),
            best: self.archive.best_per_objective(),
        };
        self.history.push(stats.clone());
        // Emitted from the search thread after the generation's grid
        // has fully collected, so the stream order is deterministic
        // (`DseGenStats` itself carries no wall-clock fields).
        self.telemetry.emit(|| Event::DseGeneration {
            stats: stats.clone(),
        });
        Ok(stats)
    }

    /// Run to the configured budget.  `on_gen` fires after every
    /// generation (progress reporting); `checkpoint` — when given — is
    /// rewritten after every generation, so an interrupted search loses
    /// at most one generation of work.
    pub fn run(
        &mut self,
        apps: &[AppGraph],
        checkpoint: Option<&Path>,
        mut on_gen: impl FnMut(&DseGenStats),
    ) -> Result<()> {
        while !self.is_done() {
            let stats = self.step(apps)?;
            if let Some(path) = checkpoint {
                self.save_checkpoint(path)?;
            }
            on_gen(&stats);
        }
        Ok(())
    }

    /// Binary-tournament parent selection + crossover + mutation.
    fn make_offspring(&mut self) -> Vec<PlatformGenome> {
        let objs: Vec<&[f64]> = self
            .population
            .iter()
            .map(|p| p.objectives.as_slice())
            .collect();
        let rank = rank_of(&nondominated_sort(&objs), objs.len());
        let crowd = crowding_all(&objs, &rank);
        let n = self.population.len();
        let mut tournament = |rng: &mut Rng| -> usize {
            let i = rng.below(n as u64) as usize;
            let j = rng.below(n as u64) as usize;
            if better(rank[i], crowd[i], i, rank[j], crowd[j], j) {
                i
            } else {
                j
            }
        };
        (0..self.cfg.population)
            .map(|_| {
                let a = tournament(&mut self.rng);
                let child = if self.rng.f64() < self.cfg.crossover_rate {
                    let b = tournament(&mut self.rng);
                    self.space.crossover(
                        &self.population[a].genome,
                        &self.population[b].genome,
                        &mut self.rng,
                    )
                } else {
                    self.population[a].genome.clone()
                };
                self.space.mutate(
                    &child,
                    self.cfg.mutation_rate,
                    &mut self.rng,
                )
            })
            .collect()
    }

    // ---- checkpointing ---------------------------------------------------

    pub fn checkpoint_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("schema", Json::Num(CHECKPOINT_SCHEMA))
            .set("kind", Json::Str(CHECKPOINT_KIND.into()))
            .set("config", self.cfg.to_json())
            .set("platform", self.space.base().to_json())
            .set(
                "rng",
                Json::Arr(
                    self.rng
                        .state()
                        .iter()
                        .map(|&w| Json::Str(format!("{w:#018x}")))
                        .collect(),
                ),
            )
            .set(
                "population",
                Json::Arr(
                    self.population
                        .iter()
                        .map(DesignPoint::to_json)
                        .collect(),
                ),
            )
            .set("archive", self.archive.to_json())
            .set("cache", self.evaluator.cache_to_json())
            .set(
                "history",
                Json::Arr(
                    self.history.iter().map(DseGenStats::to_json).collect(),
                ),
            )
            .set(
                "evals_requested",
                Json::Num(self.evaluator.evals_requested as f64),
            )
            .set(
                "cache_hits",
                Json::Num(self.evaluator.cache_hits as f64),
            )
            .set("sims_run", Json::Num(self.evaluator.sims_run as f64));
        if let Some(w) = &self.workload {
            j.set("workload", w.clone());
        }
        j
    }

    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.checkpoint_json().to_string_pretty())?;
        Ok(())
    }

    /// Rebuild an engine from a checkpoint.  The base platform travels
    /// inside the checkpoint; applications are code-built graphs, so
    /// the same workload must be passed to [`Self::run`] /
    /// [`Self::step`] for the continuation to be meaningful — callers
    /// should rebuild it from [`Self::workload_meta`] (the CLI does,
    /// and rejects conflicting flags).
    pub fn from_checkpoint(j: &Json) -> Result<DseEngine> {
        if j.get("kind").and_then(Json::as_str) != Some(CHECKPOINT_KIND) {
            return Err(Error::Config(
                "not a ds3r DSE checkpoint (missing kind)".into(),
            ));
        }
        let cfg = DseConfig::from_json(j.get("config").ok_or_else(|| {
            Error::Config("checkpoint missing config".into())
        })?)?;
        let base = Platform::from_json(j.get("platform").ok_or_else(
            || Error::Config("checkpoint missing platform".into()),
        )?)?;
        let mut engine = DseEngine::new(base, cfg)?;

        let rng_words = j
            .get("rng")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Config("checkpoint missing rng".into()))?;
        if rng_words.len() != 4 {
            return Err(Error::Config(
                "checkpoint rng must have 4 words".into(),
            ));
        }
        let mut state = [0u64; 4];
        for (slot, w) in state.iter_mut().zip(rng_words) {
            let s = w.as_str().ok_or_else(|| {
                Error::Config("checkpoint rng word must be a string".into())
            })?;
            let hex = s.strip_prefix("0x").unwrap_or(s);
            *slot = u64::from_str_radix(hex, 16).map_err(|_| {
                Error::Config(format!("bad rng word '{s}'"))
            })?;
        }
        engine.rng = Rng::from_state(state);

        engine.population = j
            .get("population")
            .and_then(Json::as_arr)
            .ok_or_else(|| {
                Error::Config("checkpoint missing population".into())
            })?
            .iter()
            .map(DesignPoint::from_json)
            .collect::<Result<Vec<_>>>()?;
        engine.archive = ParetoArchive::from_json(
            j.get("archive").ok_or_else(|| {
                Error::Config("checkpoint missing archive".into())
            })?,
        )?;
        if let Some(cache) = j.get("cache") {
            engine.evaluator.cache_from_json(cache)?;
        }
        engine.history = j
            .get("history")
            .and_then(Json::as_arr)
            .ok_or_else(|| {
                Error::Config("checkpoint missing history".into())
            })?
            .iter()
            .map(DseGenStats::from_json)
            .collect::<Result<Vec<_>>>()?;
        engine.evaluator.evals_requested =
            j.get("evals_requested").and_then(Json::as_f64).unwrap_or(0.0)
                as usize;
        engine.evaluator.cache_hits =
            j.get("cache_hits").and_then(Json::as_f64).unwrap_or(0.0)
                as usize;
        engine.evaluator.sims_run =
            j.get("sims_run").and_then(Json::as_f64).unwrap_or(0.0)
                as usize;
        engine.workload = j.get("workload").cloned();
        Ok(engine)
    }

    pub fn from_checkpoint_file(path: &Path) -> Result<DseEngine> {
        DseEngine::from_checkpoint(&Json::parse_file(path)?)
    }
}

/// Scenario presets that fail/restore PEs constrain the genome space:
/// the smallest decodable design must still contain the referenced PE.
fn check_scenario_pe_refs(sc: &Scenario, min_total: usize) -> Result<()> {
    for e in &sc.events {
        let pe = match e.action {
            Action::PeFail { pe } | Action::PeRestore { pe } => pe,
            _ => continue,
        };
        if pe >= min_total {
            return Err(Error::Config(format!(
                "scenario '{}' references PE {pe}, but the smallest \
                 decodable design has only {min_total} PEs; raise \
                 min_pes_per_cluster or drop the scenario",
                sc.name
            )));
        }
    }
    Ok(())
}

/// `(rank, crowding, index)` lexicographic "better" for tournaments and
/// truncation: lower rank, then larger crowding, then lower index (the
/// final tie-break keeps every comparison deterministic).
fn better(
    ra: usize,
    ca: f64,
    ia: usize,
    rb: usize,
    cb: f64,
    ib: usize,
) -> bool {
    if ra != rb {
        return ra < rb;
    }
    if ca != cb {
        return ca > cb;
    }
    ia < ib
}

/// Fast non-dominated sort: partition indices into fronts (front 0 =
/// non-dominated).  O(n²·m) — fine at population scale.
pub fn nondominated_sort(objs: &[&[f64]]) -> Vec<Vec<usize>> {
    let n = objs.len();
    let mut dominated_by: Vec<usize> = vec![0; n];
    let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for k in (i + 1)..n {
            if dominates(objs[i], objs[k]) {
                dominates_list[i].push(k);
                dominated_by[k] += 1;
            } else if dominates(objs[k], objs[i]) {
                dominates_list[k].push(i);
                dominated_by[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> =
        (0..n).filter(|&i| dominated_by[i] == 0).collect();
    while !current.is_empty() {
        let mut next: Vec<usize> = Vec::new();
        for &i in &current {
            for &k in &dominates_list[i] {
                dominated_by[k] -= 1;
                if dominated_by[k] == 0 {
                    next.push(k);
                }
            }
        }
        next.sort_unstable();
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Per-index front rank from a front partition.
fn rank_of(fronts: &[Vec<usize>], n: usize) -> Vec<usize> {
    let mut rank = vec![0usize; n];
    for (r, front) in fronts.iter().enumerate() {
        for &i in front {
            rank[i] = r;
        }
    }
    rank
}

/// Crowding distance of one front (objective-wise normalized gap to the
/// nearest neighbours; boundary points get `f64::INFINITY`).
pub fn crowding_distance(
    objs: &[&[f64]],
    front: &[usize],
) -> Vec<f64> {
    let m = front.len();
    let mut dist = vec![0.0f64; m];
    if m == 0 {
        return dist;
    }
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    let dims = objs[front[0]].len();
    for k in 0..dims {
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| {
            objs[front[a]][k]
                .partial_cmp(&objs[front[b]][k])
                .expect("finite objectives")
                .then(front[a].cmp(&front[b]))
        });
        let lo = objs[front[order[0]]][k];
        let hi = objs[front[order[m - 1]]][k];
        let span = (hi - lo).max(1e-12);
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        for w in 1..m - 1 {
            let gap = objs[front[order[w + 1]]][k]
                - objs[front[order[w - 1]]][k];
            dist[order[w]] += gap / span;
        }
    }
    dist
}

/// Crowding distance for every index given its front partition.
fn crowding_all(objs: &[&[f64]], rank: &[usize]) -> Vec<f64> {
    let n = objs.len();
    let n_fronts = rank.iter().copied().max().map_or(0, |r| r + 1);
    let mut crowd = vec![0.0f64; n];
    for r in 0..n_fronts {
        let front: Vec<usize> =
            (0..n).filter(|&i| rank[i] == r).collect();
        let d = crowding_distance(objs, &front);
        for (slot, &i) in d.iter().zip(&front) {
            crowd[i] = *slot;
        }
    }
    crowd
}

/// NSGA-II environmental selection: fill the next population front by
/// front, truncating the splitting front by crowding distance.  Output
/// order is deterministic (front order, then crowding-desc with index
/// tie-break).
pub fn select_nsga2(
    combined: Vec<DesignPoint>,
    target: usize,
) -> Vec<DesignPoint> {
    if combined.len() <= target {
        return combined;
    }
    let objs: Vec<&[f64]> =
        combined.iter().map(|p| p.objectives.as_slice()).collect();
    let fronts = nondominated_sort(&objs);
    let mut chosen: Vec<usize> = Vec::with_capacity(target);
    for front in &fronts {
        if chosen.len() + front.len() <= target {
            chosen.extend_from_slice(front);
            if chosen.len() == target {
                break;
            }
        } else {
            let d = crowding_distance(&objs, front);
            let mut order: Vec<usize> = (0..front.len()).collect();
            order.sort_by(|&a, &b| {
                d[b].partial_cmp(&d[a])
                    .expect("crowding is comparable")
                    .then(front[a].cmp(&front[b]))
            });
            for &w in order.iter().take(target - chosen.len()) {
                chosen.push(front[w]);
            }
            break;
        }
    }
    // Materialize in chosen order without cloning the points.
    let mut slots: Vec<Option<DesignPoint>> =
        combined.into_iter().map(Some).collect();
    chosen
        .into_iter()
        .map(|i| slots[i].take().expect("indices are unique"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::suite::{self, WifiParams};
    use crate::config::SimConfig;
    use crate::dse::Objective;

    fn tiny_cfg() -> DseConfig {
        let mut sim = SimConfig::default();
        sim.max_jobs = 25;
        sim.warmup_jobs = 2;
        sim.injection_rate_per_ms = 2.0;
        sim.max_sim_us = 2_000_000.0;
        let mut cfg = DseConfig::default();
        cfg.population = 6;
        cfg.generations = 2;
        cfg.seeds = vec![1];
        cfg.sim = sim;
        cfg.threads = 2;
        cfg
    }

    fn apps() -> Vec<AppGraph> {
        vec![suite::wifi_tx(WifiParams { symbols: 2 })]
    }

    #[test]
    fn nondominated_sort_partitions_correctly() {
        let o: Vec<Vec<f64>> = vec![
            vec![1.0, 1.0], // front 0
            vec![2.0, 2.0], // front 1 (dominated by 0)
            vec![0.5, 3.0], // front 0
            vec![3.0, 3.0], // front 2
            vec![2.5, 0.5], // front 0
        ];
        let refs: Vec<&[f64]> = o.iter().map(|v| v.as_slice()).collect();
        let fronts = nondominated_sort(&refs);
        assert_eq!(fronts[0], vec![0, 2, 4]);
        assert_eq!(fronts[1], vec![1]);
        assert_eq!(fronts[2], vec![3]);
        let rank = rank_of(&fronts, 5);
        assert_eq!(rank, vec![0, 1, 0, 2, 0]);
    }

    #[test]
    fn crowding_rewards_boundary_and_spread() {
        let o: Vec<Vec<f64>> = vec![
            vec![0.0, 10.0],
            vec![1.0, 5.0],  // close to 0 and 2
            vec![2.0, 4.0],
            vec![10.0, 0.0],
        ];
        let refs: Vec<&[f64]> = o.iter().map(|v| v.as_slice()).collect();
        let front: Vec<usize> = vec![0, 1, 2, 3];
        let d = crowding_distance(&refs, &front);
        assert!(d[0].is_infinite() && d[3].is_infinite());
        assert!(d[1].is_finite() && d[2].is_finite());
        // Point 2 spans a wider neighbour gap on objective 0.
        assert!(d[2] > d[1]);
    }

    #[test]
    fn engine_runs_and_archive_is_nontrivial() {
        let mut e =
            DseEngine::new(Platform::table2_soc(), tiny_cfg()).unwrap();
        let mut gens = 0;
        e.run(&apps(), None, |s| {
            gens += 1;
            assert!(s.front_size >= 1);
            assert_eq!(s.best.len(), 2);
        })
        .unwrap();
        assert_eq!(gens, 3);
        assert_eq!(e.completed_generations(), 3);
        assert!(e.is_done());
        assert!(!e.archive().is_empty());
        assert!(e.history()[2].hypervolume >= 0.0);
        // Archive invariant: no entry dominates another.
        let pts = e.archive().entries();
        for a in pts {
            for b in pts {
                if !std::ptr::eq(a, b) {
                    assert!(!dominates(&a.objectives, &b.objectives));
                }
            }
        }
    }

    #[test]
    fn random_algorithm_also_runs() {
        let mut cfg = tiny_cfg();
        cfg.algorithm = "random".into();
        cfg.generations = 1;
        let mut e =
            DseEngine::new(Platform::table2_soc(), cfg).unwrap();
        e.run(&apps(), None, |_| {}).unwrap();
        assert_eq!(e.completed_generations(), 2);
        assert!(!e.archive().is_empty());
    }

    #[test]
    fn checkpoint_roundtrip_preserves_engine_state() {
        let mut e =
            DseEngine::new(Platform::table2_soc(), tiny_cfg()).unwrap();
        e.step(&apps()).unwrap();
        e.step(&apps()).unwrap();
        let j = Json::parse(&e.checkpoint_json().to_string()).unwrap();
        let e2 = DseEngine::from_checkpoint(&j).unwrap();
        assert_eq!(e2.completed_generations(), 2);
        assert_eq!(e2.rng.state(), e.rng.state());
        assert_eq!(e2.archive(), e.archive());
        assert_eq!(e2.population, e.population);
        assert_eq!(e2.history(), e.history());
    }

    #[test]
    fn rejects_scenarios_referencing_impossible_pes() {
        // pe-failure fails PEs 10-13; with min 1 PE/cluster the smallest
        // design has only 4 PEs, so the combination must be rejected.
        let mut cfg = tiny_cfg();
        cfg.scenarios = vec!["pe-failure".into()];
        cfg.min_pes_per_cluster = 1;
        assert!(DseEngine::new(Platform::table2_soc(), cfg).is_err());

        // With >= 4 PEs/cluster every design has >= 16 PEs: PE 13 always
        // exists and the scenario is accepted.
        let mut cfg = tiny_cfg();
        cfg.scenarios = vec!["pe-failure".into()];
        cfg.min_pes_per_cluster = 4;
        assert!(DseEngine::new(Platform::table2_soc(), cfg).is_ok());
    }

    #[test]
    fn step_past_budget_errors() {
        let mut cfg = tiny_cfg();
        cfg.generations = 0;
        let mut e =
            DseEngine::new(Platform::table2_soc(), cfg).unwrap();
        e.step(&apps()).unwrap();
        assert!(e.step(&apps()).is_err());
        e.set_generations(1);
        assert!(e.step(&apps()).is_ok());
    }

    #[test]
    fn objectives_drive_the_archive_dimension() {
        let mut cfg = tiny_cfg();
        cfg.objectives =
            vec![Objective::Latency, Objective::Energy, Objective::PeakTemp];
        cfg.generations = 0;
        let mut e =
            DseEngine::new(Platform::table2_soc(), cfg).unwrap();
        e.step(&apps()).unwrap();
        for p in e.archive().entries() {
            assert_eq!(p.objectives.len(), 3);
        }
    }
}
