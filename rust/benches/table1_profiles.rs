//! Table 1 bench: regenerates the WiFi-TX execution-profile table from
//! the resource database and measures profile-lookup cost (the
//! operation every scheduling decision performs).
//!
//! Run: `cargo bench --bench table1_profiles`

mod bench_util;

use ds3r::app::suite::{self, WifiParams};
use ds3r::platform::Platform;
use ds3r::sched::ilp::ExecTable;

fn main() {
    println!("=== Table 1 regeneration ===\n");
    println!("{}", ds3r::cli::reproduce_table1());

    let platform = Platform::table2_soc();
    let app = suite::wifi_tx(WifiParams::default());
    let exec = ExecTable::new(&app, &platform);

    println!("--- resource-database microbenchmarks ---");
    bench_util::bench("ExecTable::new (50-task app, 14 PEs)", 20_000, || {
        std::hint::black_box(ExecTable::new(&app, &platform));
    });

    let mut acc = 0.0f64;
    let n_tasks = app.len();
    let n_pes = platform.n_pes();
    bench_util::bench("profile lookup (task, pe) -> us", 1_000_000, || {
        // Touch a pseudo-random entry to defeat caching of one cell.
        let t = (acc as usize * 7 + 3) % n_tasks;
        let p = (acc as usize * 13 + 1) % n_pes;
        acc += exec.us(t, p).min(1.0);
    });
    std::hint::black_box(acc);

    // Latency scaling at a DVFS point: the full per-decision cost.
    let class = &platform.classes[0];
    let opp = class.opps[3];
    bench_util::bench("DVFS-scaled latency (mul + div)", 1_000_000, || {
        let base = exec.us(5 % n_tasks, 0);
        std::hint::black_box(base * class.nominal_mhz / opp.freq_mhz);
    });

    // Verify against the paper's values once more, loudly.
    let t1 = [
        ("scrambler-encoder", Some(8.0), 22.0, 10.0),
        ("interleaver-0", None, 10.0, 4.0),
        ("qpsk-0", None, 15.0, 8.0),
        ("pilot-0", None, 5.0, 3.0),
        ("ifft-0", Some(16.0), 296.0, 118.0),
        ("crc", None, 5.0, 3.0),
    ];
    let mut ok = true;
    for (name, acc_us, a7, a15) in t1 {
        let task = app.tasks.iter().find(|t| t.name == name).unwrap();
        let got_acc = task
            .exec_us
            .get("ACC_SCR")
            .or_else(|| task.exec_us.get("ACC_FFT"))
            .copied();
        if got_acc != acc_us
            || task.exec_us["A7"] != a7
            || task.exec_us["A15"] != a15
        {
            ok = false;
            println!("MISMATCH vs paper Table 1 at {name}");
        }
    }
    println!(
        "\nTable 1 values vs paper: {}",
        if ok { "EXACT MATCH" } else { "MISMATCH (see above)" }
    );
}
