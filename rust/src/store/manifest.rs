//! Run manifests: the durable provenance record of one CLI invocation.
//!
//! A [`Manifest`] is the persisted form of the `run_started` /
//! `run_finished` event pair — canonical config hash, workload digest,
//! seed, scheduler, git describe, the aggregated deterministic
//! [`Counters`], the per-point cache keys the campaign touched, and a
//! free-form result summary.  Its [`Manifest::key`] is a pure function
//! of the identity fields (environment metadata like `git` is stored
//! but never hashed), so re-running the identical campaign lands on
//! the same manifest file.

use crate::telemetry::{self, Counters};
use crate::util::json::{u64_from_json, u64_to_json, Json};
use crate::{Error, Result};

/// The `"kind"` tag guarding manifest JSON files against accidental
/// cross-loading (same convention as `ds3r-tournament-report`).
pub const MANIFEST_KIND: &str = "ds3r-manifest";

/// Content-addressed key of one campaign invocation.  Hashes only the
/// fields that determine simulated behaviour: command, canonical
/// config hash, workload digest, seed and scheduler.
pub fn manifest_key(
    cmd: &str,
    config_hash: &str,
    workload_digest: &str,
    seed: u64,
    scheduler: &str,
) -> String {
    telemetry::config_hash(&format!(
        "{cmd}:{config_hash}:{workload_digest}:{seed}:{scheduler}"
    ))
}

/// One campaign's provenance record (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Subcommand / campaign label (`run`, `sweep`, `fuzz`, ...).
    pub cmd: String,
    /// FNV-1a hash of the canonical config JSON.
    pub config_hash: String,
    /// FNV-1a digest over every workload input (app DAGs, trace files,
    /// XLA artifacts, scenario/fuzz JSON, IL policy).
    pub workload_digest: String,
    pub seed: u64,
    pub scheduler: String,
    /// `git describe --always --dirty`, when available.  Environment
    /// metadata: stored, never hashed into [`Manifest::key`].
    pub git: Option<String>,
    /// Aggregated deterministic counters of the whole invocation.
    pub counters: Counters,
    /// Point-cache keys this campaign consulted or wrote, in canonical
    /// input order (identical for cold, warm and partial reruns).
    pub point_keys: Vec<String>,
    /// Free-form result summary (command-specific JSON).
    pub result: Json,
}

impl Manifest {
    /// The content-addressed key this manifest files under.
    pub fn key(&self) -> String {
        manifest_key(
            &self.cmd,
            &self.config_hash,
            &self.workload_digest,
            self.seed,
            &self.scheduler,
        )
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", Json::Str(MANIFEST_KIND.into()))
            .set("key", Json::Str(self.key()))
            .set("cmd", Json::Str(self.cmd.clone()))
            .set("config_hash", Json::Str(self.config_hash.clone()))
            .set(
                "workload_digest",
                Json::Str(self.workload_digest.clone()),
            )
            .set("seed", u64_to_json(self.seed))
            .set("scheduler", Json::Str(self.scheduler.clone()))
            .set(
                "git",
                match &self.git {
                    Some(g) => Json::Str(g.clone()),
                    None => Json::Null,
                },
            )
            .set("counters", self.counters.to_json())
            .set(
                "point_keys",
                Json::Arr(
                    self.point_keys
                        .iter()
                        .map(|k| Json::Str(k.clone()))
                        .collect(),
                ),
            )
            .set("result", self.result.clone());
        j
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        if j.get("kind").and_then(Json::as_str) != Some(MANIFEST_KIND) {
            return Err(Error::Json(format!(
                "not a {MANIFEST_KIND} file (missing/foreign kind tag)"
            )));
        }
        let seed = j
            .get("seed")
            .and_then(u64_from_json)
            .ok_or_else(|| Error::Json("manifest: bad seed".into()))?;
        let mut point_keys = Vec::new();
        for v in j.req_arr("point_keys")? {
            point_keys.push(
                v.as_str()
                    .ok_or_else(|| {
                        Error::Json("manifest: non-string point key".into())
                    })?
                    .to_string(),
            );
        }
        let counters = match j.get("counters") {
            Some(c) => Counters::from_json(c)?,
            None => Counters::new(),
        };
        Ok(Manifest {
            cmd: j.req_str("cmd")?.to_string(),
            config_hash: j.req_str("config_hash")?.to_string(),
            workload_digest: j.req_str("workload_digest")?.to_string(),
            seed,
            scheduler: j.req_str("scheduler")?.to_string(),
            git: j
                .get("git")
                .and_then(Json::as_str)
                .map(str::to_string),
            counters,
            point_keys,
            result: j.get("result").cloned().unwrap_or(Json::Null),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        let mut counters = Counters::new();
        counters.add("runs", 8);
        counters.add("completed_jobs", 320);
        let mut result = Json::obj();
        result.set("points", Json::Num(8.0));
        Manifest {
            cmd: "sweep".into(),
            config_hash: telemetry::config_hash("{}"),
            workload_digest: telemetry::config_hash("workload"),
            seed: 42,
            scheduler: "etf".into(),
            git: Some("abc1234".into()),
            counters,
            point_keys: vec!["k0".into(), "k1".into()],
            result,
        }
    }

    #[test]
    fn manifest_json_round_trip_is_exact() {
        let m = sample();
        let j = m.to_json();
        let back = Manifest::from_json(
            &Json::parse(&j.to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(m, back);
        assert_eq!(j.to_string(), back.to_json().to_string());
    }

    #[test]
    fn key_ignores_environment_metadata() {
        let mut a = sample();
        let mut b = sample();
        a.git = Some("dirty".into());
        b.git = None;
        b.counters = Counters::new();
        assert_eq!(a.key(), b.key());
        b.seed = 43;
        assert_ne!(a.key(), b.key());
    }

    #[test]
    fn from_json_rejects_foreign_kinds() {
        let j = Json::parse(r#"{"kind":"ds3r-point"}"#).unwrap();
        assert!(Manifest::from_json(&j).is_err());
        assert!(Manifest::from_json(&Json::obj()).is_err());
    }
}
