//! Integration tests for the guided design-space exploration engine:
//! end-to-end search behaviour, thread-count determinism (the same
//! pattern as the sweep bit-identity test in `prop_invariants.rs`),
//! and checkpoint/resume bit-identity.

use ds3r::app::suite::{self, RadarParams, WifiParams};
use ds3r::app::AppGraph;
use ds3r::dse::{DseConfig, DseEngine, Objective};
use ds3r::platform::Platform;
use ds3r::util::json::Json;

fn tiny_cfg(threads: usize) -> DseConfig {
    let mut cfg = DseConfig::default();
    cfg.population = 6;
    cfg.generations = 3;
    cfg.search_seed = 42;
    cfg.seeds = vec![1];
    cfg.threads = threads;
    cfg.sim.injection_rate_per_ms = 2.0;
    cfg.sim.max_jobs = 30;
    cfg.sim.warmup_jobs = 3;
    cfg.sim.max_sim_us = 2_000_000.0;
    cfg
}

fn apps() -> Vec<AppGraph> {
    vec![suite::wifi_tx(WifiParams { symbols: 2 })]
}

/// Serialize the parts of engine state that must be reproducible
/// (everything except the config, which legitimately differs in
/// `threads` between the compared runs).
fn state_fingerprint(e: &DseEngine) -> (String, String) {
    let archive = e.archive().to_json().to_string();
    let history = Json::Arr(
        e.history().iter().map(|h| h.to_json()).collect::<Vec<_>>(),
    )
    .to_string();
    (archive, history)
}

#[test]
fn dse_archive_bit_identical_across_1_vs_8_threads() {
    let apps = apps();
    let mut serial =
        DseEngine::new(Platform::table2_soc(), tiny_cfg(1)).unwrap();
    serial.run(&apps, None, |_| {}).unwrap();
    let mut parallel =
        DseEngine::new(Platform::table2_soc(), tiny_cfg(8)).unwrap();
    parallel.run(&apps, None, |_| {}).unwrap();

    let (a_archive, a_history) = state_fingerprint(&serial);
    let (b_archive, b_history) = state_fingerprint(&parallel);
    assert_eq!(
        a_archive, b_archive,
        "Pareto archive depends on evaluation thread count"
    );
    assert_eq!(
        a_history, b_history,
        "per-generation stats depend on evaluation thread count"
    );
    assert!(!serial.archive().is_empty());
}

#[test]
fn dse_resume_continues_bit_identically() {
    let apps = apps();

    // Reference: one uninterrupted 1+5-generation run.
    let mut straight_cfg = tiny_cfg(2);
    straight_cfg.generations = 5;
    let mut straight =
        DseEngine::new(Platform::table2_soc(), straight_cfg).unwrap();
    straight.run(&apps, None, |_| {}).unwrap();

    // Interrupted: stop after 1+2 generations, checkpoint to disk,
    // rebuild from the file, extend the budget, continue.
    let mut short_cfg = tiny_cfg(2);
    short_cfg.generations = 2;
    let mut interrupted =
        DseEngine::new(Platform::table2_soc(), short_cfg).unwrap();
    interrupted.run(&apps, None, |_| {}).unwrap();

    let dir = std::env::temp_dir().join("ds3r_dse_resume_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("checkpoint.json");
    interrupted.save_checkpoint(&ckpt).unwrap();

    let mut resumed = DseEngine::from_checkpoint_file(&ckpt).unwrap();
    assert_eq!(resumed.completed_generations(), 3);
    resumed.set_generations(5);
    resumed.run(&apps, None, |_| {}).unwrap();
    std::fs::remove_dir_all(&dir).ok();

    let (a_archive, a_history) = state_fingerprint(&straight);
    let (b_archive, b_history) = state_fingerprint(&resumed);
    assert_eq!(
        a_archive, b_archive,
        "resumed archive diverged from the uninterrupted run"
    );
    assert_eq!(
        a_history, b_history,
        "resumed per-generation stats diverged"
    );
}

#[test]
fn dse_checkpoint_file_roundtrip_is_exact() {
    let apps = apps();
    let mut e =
        DseEngine::new(Platform::table2_soc(), tiny_cfg(2)).unwrap();
    e.step(&apps).unwrap();
    e.step(&apps).unwrap();

    let dir = std::env::temp_dir().join("ds3r_dse_roundtrip_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("checkpoint.json");
    e.save_checkpoint(&ckpt).unwrap();
    let e2 = DseEngine::from_checkpoint_file(&ckpt).unwrap();
    // Writing the restored engine's checkpoint reproduces the file
    // byte-for-byte — nothing drifts through the f64/JSON round-trip.
    let ckpt2 = dir.join("checkpoint2.json");
    e2.save_checkpoint(&ckpt2).unwrap();
    let a = std::fs::read_to_string(&ckpt).unwrap();
    let b = std::fs::read_to_string(&ckpt2).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(a, b);
}

/// A meaningful (if small-budget) two-objective search on the Table-2
/// SoC with the WiFi-TX + pulse-Doppler mix: the acceptance-criteria
/// workload at test scale.  The full-budget path (>= 200 evaluations)
/// runs through `ds3r dse run` defaults and the design_space example.
#[test]
fn dse_finds_a_nontrivial_front_on_the_mixed_workload() {
    let apps = vec![
        suite::wifi_tx(WifiParams { symbols: 4 }),
        suite::pulse_doppler(RadarParams { pulses: 4 }),
    ];
    let mut cfg = tiny_cfg(0);
    cfg.population = 16;
    cfg.generations = 7; // 128 evaluations
    cfg.objectives = vec![Objective::Latency, Objective::Energy];
    cfg.sim.injection_rate_per_ms = 3.0;
    cfg.sim.max_jobs = 30;
    cfg.sim.warmup_jobs = 3;
    let mut e = DseEngine::new(Platform::table2_soc(), cfg).unwrap();
    e.run(&apps, None, |_| {}).unwrap();

    let front = e.archive().entries();
    assert!(
        front.len() >= 5,
        "expected a non-trivial Pareto front, got {} designs",
        front.len()
    );
    // The front spans a real trade-off: the latency-best and
    // energy-best designs differ.
    let best = e.archive().best_per_objective();
    let lat_winner = front
        .iter()
        .find(|p| p.objectives[0] == best[0])
        .unwrap();
    let energy_winner = front
        .iter()
        .find(|p| p.objectives[1] == best[1])
        .unwrap();
    assert_ne!(
        lat_winner.genome, energy_winner.genome,
        "degenerate front: one design wins every objective"
    );
    // The proxy is computed and finite every generation.  (It is
    // normalized to the archive's own bounding box, so it is not
    // monotone across generations — only well-defined.)
    for h in e.history() {
        assert!(h.hypervolume.is_finite() && h.hypervolume >= 0.0);
        assert!(h.front_size >= 1);
    }
}
