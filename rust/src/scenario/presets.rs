//! Named scenario presets: ready-made dynamic conditions for the
//! Table-2 evaluation SoC.
//!
//! * [`bursty_wifi`] — a quiet link that bursts to near-saturation and
//!   back (injection-rate ramps), the Figure-3 x-axis made dynamic.
//! * [`thermal_soak`] — ambient temperature soak test: 25 → 45 → 60 °C
//!   and back, stressing leakage and any thermal-throttle policy.
//! * [`pe_failure`] — all four FFT accelerators fail mid-run and return
//!   later; FFT-heavy tasks must fall back to the cores.
//! * [`budget_throttle`] — an SoC power budget appears, tightens, and is
//!   lifted (DTPM power-cap policy driven from the timeline).
//! * [`scheduler_shootout`] — scheduler hot-swap etf → heft → met-lb →
//!   etf under steady load, comparing policies inside one run.
//!
//! PE ids in `pe-failure` refer to the Table-2 preset layout (0-3 A15,
//! 4-7 A7, 8-9 ACC_SCR, 10-13 ACC_FFT).

use super::{Action, Scenario};

/// All preset names, in listing order.
pub fn names() -> &'static [&'static str] {
    &[
        "bursty-wifi",
        "thermal-soak",
        "pe-failure",
        "budget-throttle",
        "scheduler-shootout",
    ]
}

/// Look up a preset by name.
pub fn by_name(name: &str) -> Option<Scenario> {
    match name {
        "bursty-wifi" => Some(bursty_wifi()),
        "thermal-soak" => Some(thermal_soak()),
        "pe-failure" => Some(pe_failure()),
        "budget-throttle" => Some(budget_throttle()),
        "scheduler-shootout" => Some(scheduler_shootout()),
        _ => None,
    }
}

/// All presets (listing / export helpers).
pub fn all() -> Vec<Scenario> {
    names().iter().map(|n| by_name(n).unwrap()).collect()
}

/// Quiet link, then a burst to near-saturation, then quiet again.
pub fn bursty_wifi() -> Scenario {
    Scenario::new(
        "bursty-wifi",
        "injection rate 1/ms, ramp to 8/ms burst at 100 ms, back to \
         1/ms at 250 ms, second smaller burst at 350 ms",
    )
    .event(0.0, Action::SetRate { per_ms: 1.0 })
    .event(100_000.0, Action::RampRate { to_per_ms: 8.0, over_us: 50_000.0 })
    .event(250_000.0, Action::SetRate { per_ms: 1.0 })
    .event(350_000.0, Action::RampRate { to_per_ms: 6.0, over_us: 50_000.0 })
}

/// Ambient soak: 25 °C baseline, 45 °C, 60 °C, then back to 25 °C.
pub fn thermal_soak() -> Scenario {
    Scenario::new(
        "thermal-soak",
        "ambient temperature steps 25 -> 45 -> 60 -> 25 C; leakage and \
         throttle policies feel the environment change",
    )
    .event(50_000.0, Action::SetAmbient { t_c: 45.0 })
    .event(150_000.0, Action::SetAmbient { t_c: 60.0 })
    .event(300_000.0, Action::SetAmbient { t_c: 25.0 })
}

/// All four FFT accelerators fail at 50 ms, return at 150 ms.
pub fn pe_failure() -> Scenario {
    let mut s = Scenario::new(
        "pe-failure",
        "FFT accelerators (PEs 10-13 on the Table-2 SoC) fail at 50 ms \
         and hotplug back at 150 ms; FFT tasks fall back to the cores",
    );
    for pe in 10..14 {
        s = s.event(50_000.0, Action::PeFail { pe });
    }
    for pe in 10..14 {
        s = s.event(150_000.0, Action::PeRestore { pe });
    }
    s
}

/// A power budget appears at 50 ms, tightens at 150 ms, lifts at 300 ms.
pub fn budget_throttle() -> Scenario {
    Scenario::new(
        "budget-throttle",
        "SoC power cap 6 W at 50 ms, tightened to 3.5 W at 150 ms, \
         removed at 300 ms (drives the DTPM power-cap policy)",
    )
    .event(50_000.0, Action::SetPowerCap { watts: Some(6.0) })
    .event(150_000.0, Action::SetPowerCap { watts: Some(3.5) })
    .event(300_000.0, Action::SetPowerCap { watts: None })
}

/// Scheduler hot-swap under steady load.
pub fn scheduler_shootout() -> Scenario {
    Scenario::new(
        "scheduler-shootout",
        "hot-swap the scheduler etf -> heft -> met-lb -> etf every \
         100 ms under steady load; per-phase stats compare the policies",
    )
    .event(100_000.0, Action::SetScheduler { name: "heft".into() })
    .event(200_000.0, Action::SetScheduler { name: "met-lb".into() })
    .event(300_000.0, Action::SetScheduler { name: "etf".into() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Platform;

    #[test]
    fn all_presets_validate() {
        let p = Platform::table2_soc();
        for s in all() {
            s.validate().unwrap_or_else(|e| panic!("{}: {e}", s.name));
            s.validate_for(&p, 1)
                .unwrap_or_else(|e| panic!("{}: {e}", s.name));
            assert!(!s.description.is_empty());
            assert!(!s.events.is_empty());
        }
        assert_eq!(all().len(), names().len());
    }

    #[test]
    fn presets_roundtrip_json() {
        for s in all() {
            let back =
                Scenario::from_json(&s.to_json()).unwrap();
            assert_eq!(s, back);
        }
    }

    #[test]
    fn pe_failure_targets_fft_engines() {
        let p = Platform::table2_soc();
        let s = pe_failure();
        for ev in &s.events {
            if let Action::PeFail { pe } | Action::PeRestore { pe } =
                &ev.action
            {
                assert_eq!(p.classes[p.pes[*pe].class].name, "ACC_FFT");
            }
        }
    }
}
