"""Pure-jnp oracles for the Pallas kernels (correctness ground truth).

These are the straight-line definitions of the math, with no Pallas, no
blocking, no fusion tricks.  pytest (python/tests/) asserts the kernels
match these to float32 tolerance across shape/value sweeps (hypothesis).
"""

from __future__ import annotations

import jax.numpy as jnp


def dtpm_step_ref(t, a, b, pd, v, k1, k2, pe_node):
    """Reference batched DTPM thermal/power step. See thermal.dtpm_step."""
    t_pe = t @ pe_node.T
    p_leak = k1 * v * jnp.exp(k2 * t_pe)
    p_tot = pd + p_leak
    t_next = t @ a.T + p_tot @ b.T
    return t_next, p_leak, p_tot


def etf_matrix_ref(avail, ready, exec_):
    """Reference ETF finish-time matrix. See etf.etf_matrix."""
    fin = jnp.maximum(avail, ready) + exec_
    best = jnp.min(fin, axis=1, keepdims=True)
    j = fin.shape[1]
    idx = jnp.broadcast_to(jnp.arange(j, dtype=jnp.float32), fin.shape)
    masked = jnp.where(fin <= best, idx, jnp.float32(j))
    best_pe = jnp.min(masked, axis=1, keepdims=True)
    return fin, best_pe, best
