//! The scheduler-robustness tournament: every registered scheduler ×
//! N generated scenarios through the pooled engine, scored on
//! worst-case behaviour, with every run interrogated by the invariant
//! oracles and every violation shrunk to a minimized, replayable repro.
//!
//! ## Determinism
//!
//! The grid is laid out in canonical (scheduler-major, case-minor)
//! order, permuted largest-first through
//! [`crate::coordinator::size_ordered_indices`] for the pooled
//! fan-out (a heterogeneous grid scheduled index-ordered would idle
//! the pool behind whichever big cell lands last), then scattered back
//! to canonical order before anything observable happens: cell
//! scoring, standings, telemetry emission, and repro writing all walk
//! the canonical order.  The serialized [`TournamentReport`] and the
//! telemetry stream are therefore byte-identical for any thread count
//! (`rust/tests/fuzz_props.rs` pins this).
//!
//! ## Failure minimization
//!
//! A violated cell is re-run through greedy event deletion
//! ([`crate::scenario::Scenario::without_event`]): each event is
//! dropped if the candidate still validates and still triggers the
//! original oracle; passes repeat until a fixpoint.  The minimized
//! scenario, the exact sim config fields, and the final verdict are
//! written as a [`Repro`] JSON file that [`replay`] re-executes
//! bit-identically.

use std::path::{Path, PathBuf};

use crate::app::AppGraph;
use crate::config::SimConfig;
use crate::coordinator::{
    parallel_map_pooled_outcomes, quarantine_guard, size_ordered_indices,
    FailPolicy, PointOutcome,
};
use crate::faultpoint;
use crate::platform::Platform;
use crate::scenario::Scenario;
use crate::sim::{SimSetup, SimWorker};
use crate::stats::{
    CellScore, FailureReport, SchedStanding, TournamentReport,
};
use crate::store::{point_key, PointEntry, StoreCtx};
use crate::telemetry::{config_hash, emit_global, Counters, Event};
use crate::util::json::Json;
use crate::{Error, Result};

use super::gen::{self, FuzzConfig};
use super::oracle::{self, Violation};

/// Oracle name of artificially injected violations (the shrinker test
/// hook — see [`TournamentOpts::inject_label`]).
pub const INJECTED_ORACLE: &str = "injected";

/// Tournament options beyond the generator's [`FuzzConfig`].
#[derive(Debug, Clone)]
pub struct TournamentOpts {
    /// Scheduler roster; defaults to every registered scheduler
    /// constructible in this environment
    /// ([`crate::sched::available_names`]).
    pub schedulers: Vec<String>,
    pub threads: usize,
    /// Where minimized repro JSON files go; `None` skips writing (the
    /// minimized scenarios still shrink and land in the report).
    pub repro_dir: Option<PathBuf>,
    /// Test hook: flag an artificial violation on every cell whose
    /// scenario contains an event whose label starts with this prefix
    /// (e.g. `"rate="` or `"pe"`).  Exercises the shrink + repro
    /// pipeline without needing a real simulator bug.
    pub inject_label: Option<String>,
    /// Experiment store: violation-free cells are served from the
    /// on-disk point cache (kind `fuzz`) instead of re-simulating, and
    /// fresh clean cells are recorded back.  Violated cells are never
    /// cached — a rerun re-examines them from scratch.
    pub store: Option<StoreCtx>,
}

impl Default for TournamentOpts {
    fn default() -> TournamentOpts {
        TournamentOpts {
            schedulers: crate::sched::available_names()
                .iter()
                .map(|s| s.to_string())
                .collect(),
            threads: crate::util::default_threads(),
            repro_dir: None,
            inject_label: None,
            store: None,
        }
    }
}

/// The exact simulation config of one tournament cell — also the
/// contract [`Repro`] replays against, so everything that shapes the
/// run is derived from recorded fields only.
fn case_config(
    sched: &str,
    scenario: &Scenario,
    sim_seed: u64,
    jobs: usize,
    rate_per_ms: f64,
) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.scheduler = sched.to_string();
    cfg.seed = sim_seed;
    cfg.max_jobs = jobs;
    cfg.warmup_jobs = 0; // oracles reason about every job
    cfg.injection_rate_per_ms = rate_per_ms;
    cfg.capture_traces = true; // energy == ∫power needs the trace
    cfg.scenario = Some(scenario.clone());
    cfg
}

/// Per-case simulation seed: every scheduler sees the same arrival
/// stream for case `i`, distinct cases decorrelate.
fn case_seed(fuzz: &FuzzConfig, case: usize) -> u64 {
    fuzz.seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case as u64 + 1)
}

fn base_rate(fuzz: &FuzzConfig) -> f64 {
    0.5 * (fuzz.rate_min_per_ms + fuzz.rate_max_per_ms)
}

/// Relative cost weight of a scheduler *build* (per-cell worker resets
/// reconstruct the policy — ROADMAP item 1): solver-backed policies
/// dwarf the listed heuristics.
fn sched_cost_weight(name: &str) -> u64 {
    match name {
        "ilp" | "table" => 64,
        "etf-xla" => 32,
        "il" => 16,
        "heft" => 8,
        _ => 4,
    }
}

/// Expected size of one cell, the sort key for the largest-first
/// fan-out schedule: scenario timeline length plus the scheduler's
/// build weight.
pub(crate) fn cell_cost(sched: &str, scenario: &Scenario) -> u64 {
    sched_cost_weight(sched) + scenario.events.len() as u64
}

/// Fault-injection / quarantine label of one cell — the string the
/// [`crate::faultpoint::sites::SWEEP_POINT`] site matches against and
/// the `point_failed` event carries.
fn cell_label(sched: &str, scenario: &Scenario) -> String {
    format!("{sched}@{}", scenario.name)
}

fn check_cell(
    report: &crate::stats::SimReport,
    cfg: &SimConfig,
    scenario: &Scenario,
    inject_label: Option<&str>,
) -> Vec<Violation> {
    let mut v = oracle::check(report, cfg);
    if let Some(prefix) = inject_label {
        let labels: Vec<String> =
            scenario.events.iter().map(|e| e.action.label()).collect();
        if faultpoint::prefix_hit(
            prefix,
            labels.iter().map(String::as_str),
        ) {
            v.push(Violation {
                oracle: INJECTED_ORACLE.to_string(),
                detail: format!(
                    "scenario contains an event labelled '{prefix}*'"
                ),
            });
        }
    }
    v
}

/// Run the tournament: generate `fuzz.cases` scenarios, execute every
/// `opts.schedulers` policy over each through pooled workers, oracle
/// every report, shrink and persist any violation, and rank the
/// roster.  Returns the report plus the aggregated deterministic
/// counters (for the caller's `run_finished` event).  Any failing
/// cell aborts the whole tournament; see
/// [`run_tournament_with_policy`] for quarantine semantics.
pub fn run_tournament(
    platform: &Platform,
    apps: &[AppGraph],
    fuzz: &FuzzConfig,
    opts: &TournamentOpts,
) -> Result<(TournamentReport, Counters)> {
    run_tournament_with_policy(
        platform,
        apps,
        fuzz,
        opts,
        &FailPolicy::Abort,
    )
    .map(|(report, counters, _)| (report, counters))
}

/// [`run_tournament`] with an explicit [`FailPolicy`].  Under
/// [`FailPolicy::Quarantine`], a cell whose simulation panics, trips
/// the step-budget watchdog, or errors is dropped from the grid:
/// standings rank only surviving cells, the quarantined cell is never
/// written to the store, and the failure lands in the returned
/// [`FailureReport`] plus one deterministic `point_failed` telemetry
/// event.  Cell labels are `"{scheduler}@{scenario}"` (the
/// [`crate::faultpoint::sites::SWEEP_POINT`] site fires on them).
pub fn run_tournament_with_policy(
    platform: &Platform,
    apps: &[AppGraph],
    fuzz: &FuzzConfig,
    opts: &TournamentOpts,
    policy: &FailPolicy,
) -> Result<(TournamentReport, Counters, FailureReport)> {
    fuzz.validate()?;
    if opts.schedulers.is_empty() {
        return Err(Error::Config(
            "tournament: empty scheduler roster".into(),
        ));
    }
    let scenarios = gen::generate_all(fuzz, platform, apps.len())?;
    let base = SimConfig::default();
    let setup = SimSetup::new(platform, apps, &base)?;
    let rate = base_rate(fuzz);

    // Canonical cell order: scheduler-major, case-minor.
    let cells: Vec<(usize, usize)> = (0..opts.schedulers.len())
        .flat_map(|s| (0..scenarios.len()).map(move |c| (s, c)))
        .collect();

    // Experiment store: resolve every cell's content-addressed key in
    // canonical order (the key covers the exact cell config plus the
    // verdict-shaping knobs the config omits: deadline and the
    // injection hook), record them on the manifest, and serve
    // previously-computed violation-free cells from the point cache.
    let mut slots: Vec<Option<(CellScore, Counters)>> = Vec::new();
    slots.resize_with(cells.len(), || None);
    let mut keys: Vec<(String, String)> = Vec::new();
    if let Some(ctx) = &opts.store {
        for &(s, c) in &cells {
            let cfg = case_config(
                &opts.schedulers[s],
                &scenarios[c],
                case_seed(fuzz, c),
                fuzz.jobs,
                rate,
            );
            let ch = config_hash(&format!(
                "fuzz:{}:{}:{:?}",
                cfg.to_json().to_string(),
                fuzz.deadline_us,
                opts.inject_label,
            ));
            let key = point_key(&ch, &ctx.workload_digest);
            keys.push((ch, key));
        }
        let all: Vec<String> =
            keys.iter().map(|(_, k)| k.clone()).collect();
        ctx.store.record_points(&all);
        for (i, (_, key)) in keys.iter().enumerate() {
            if let Some(e) = ctx.store.lookup(key, "fuzz") {
                if let Ok(score) = CellScore::from_json(&e.result) {
                    slots[i] = Some((score, e.counters));
                }
            }
        }
    }
    let fresh: Vec<(usize, (usize, usize))> = cells
        .iter()
        .enumerate()
        .filter(|&(i, _)| slots[i].is_none())
        .map(|(i, &sc)| (i, sc))
        .collect();

    // ROADMAP housekeeping: the pooled fan-out is index-ordered, so a
    // heterogeneous grid must be sorted by expected size at the call
    // site — largest cells first, results scattered back afterwards.
    let order = size_ordered_indices(&fresh, |&(_, (s, c))| {
        cell_cost(&opts.schedulers[s], &scenarios[c])
    });
    let ordered: Vec<(usize, (usize, usize))> =
        order.iter().map(|&i| fresh[i]).collect();

    let permuted = parallel_map_pooled_outcomes(
        &ordered,
        opts.threads,
        || None::<SimWorker>,
        |slot, _, &(_, (s, c))| {
            let sched = &opts.schedulers[s];
            let scenario = &scenarios[c];
            faultpoint::fire_panic(
                faultpoint::sites::SWEEP_POINT,
                &cell_label(sched, scenario),
            );
            let cfg = case_config(
                sched,
                scenario,
                case_seed(fuzz, c),
                fuzz.jobs,
                rate,
            );
            let worker = match SimWorker::obtain(slot, &setup, &cfg) {
                Ok(w) => w,
                Err(e) => return PointOutcome::Error(e),
            };
            let report = worker.run(&setup);
            if report.timed_out {
                return PointOutcome::TimedOut {
                    steps: report.watchdog_steps,
                };
            }
            let cell_counters = Counters::from_report(report);
            let summary = report.latency_summary();
            let deadline_misses = report
                .job_latencies_us
                .iter()
                .filter(|&&l| l > fuzz.deadline_us)
                .count();
            let fallback_rate = if report.sched_decisions > 0 {
                report.sched_fallbacks as f64
                    / report.sched_decisions as f64
            } else {
                0.0
            };
            let violations = check_cell(
                report,
                &cfg,
                scenario,
                opts.inject_label.as_deref(),
            );
            let score = CellScore {
                scheduler: sched.clone(),
                case_idx: c,
                scenario: scenario.name.clone(),
                events: scenario.events.len(),
                mean_us: summary.mean,
                p95_us: summary.p95,
                p99_us: summary.p99,
                max_us: summary.max,
                deadline_misses,
                energy_j: report.total_energy_j,
                fallback_rate,
                violations: violations
                    .into_iter()
                    .map(|v| (v.oracle, v.detail))
                    .collect(),
            };
            PointOutcome::Ok((score, cell_counters))
        },
    );

    // Scatter back to canonical slot order, then triage fresh cells
    // in canonical order: failures either abort the tournament or
    // land in the quarantine report, depending on policy.
    let mut outcome_slots: Vec<Option<PointOutcome<(CellScore, Counters)>>> =
        Vec::new();
    outcome_slots.resize_with(cells.len(), || None);
    for (k, r) in permuted.into_iter().enumerate() {
        outcome_slots[ordered[k].0] = Some(r);
    }
    let mut errs = Vec::new();
    let mut failures = FailureReport::new(cells.len());
    for &(i, (s, c)) in &fresh {
        let label =
            cell_label(&opts.schedulers[s], &scenarios[c]);
        let out = match outcome_slots[i].take() {
            Some(o) => o,
            None => PointOutcome::Error(Error::Internal(format!(
                "tournament cell {i} not scattered back"
            ))),
        };
        match out {
            PointOutcome::Ok(pair) => slots[i] = Some(pair),
            failure => {
                let kind = failure.failure_kind().unwrap_or("error");
                let detail = failure.failure_detail();
                if policy.is_quarantine() {
                    failures.record(i, label, kind, detail);
                } else {
                    errs.push(format!("{label}: {detail}"));
                }
            }
        }
    }
    if !errs.is_empty() {
        return Err(Error::Sim(format!(
            "tournament cells failed: {}",
            errs.join("; ")
        )));
    }
    quarantine_guard(policy, &failures)?;

    // Record fresh violation-free cells back into the store (serial,
    // canonical order) before consuming the slots.  Quarantined cells
    // have no slot and are never cached.
    if let Some(ctx) = &opts.store {
        for &(i, _) in &fresh {
            let Some((score, cc)) = slots[i].as_ref() else {
                continue;
            };
            if score.violations.is_empty() {
                ctx.store.put_point(&PointEntry {
                    kind: "fuzz".into(),
                    key: keys[i].1.clone(),
                    config_hash: keys[i].0.clone(),
                    workload_digest: ctx.workload_digest.clone(),
                    result: score.to_json(),
                    counters: cc.clone(),
                })?;
            }
        }
    }

    // Canonical-order merge, mixing cached and fresh cells: the
    // aggregate counters and the score list come out byte-identical
    // for any thread count and any cache state.  Quarantined cells
    // are dropped; an unresolved *healthy* slot is an internal
    // invariant breach, not a user error.
    let mut counters = Counters::new();
    let mut cell_scores: Vec<CellScore> =
        Vec::with_capacity(cells.len());
    for (i, s) in slots.into_iter().enumerate() {
        match s {
            Some((score, cc)) => {
                counters.merge(&cc);
                cell_scores.push(score);
            }
            None if failures.failed.iter().any(|f| f.index == i) => {}
            None => {
                return Err(Error::Internal(format!(
                    "tournament cell {i} neither resolved nor \
                     quarantined"
                )))
            }
        }
    }

    // Shrink + persist every violated cell, in canonical order.
    let mut repros = Vec::new();
    if let Some(dir) = &opts.repro_dir {
        let mut slot: Option<SimWorker> = None;
        for cell in &cell_scores {
            if cell.violations.is_empty() {
                continue;
            }
            std::fs::create_dir_all(dir)?;
            let scenario = &scenarios[cell.case_idx];
            let repro = shrink_and_describe(
                &setup,
                &mut slot,
                fuzz,
                &cell.scheduler,
                cell.case_idx,
                scenario,
                &cell.violations[0].0,
                opts.inject_label.as_deref(),
            )?;
            let path = dir.join(format!(
                "repro_{}_c{}.json",
                cell.scheduler, cell.case_idx
            ));
            repro.save(&path)?;
            repros.push(path.to_string_lossy().into_owned());
        }
    }

    let standings = rank(&opts.schedulers, &cell_scores);
    let violations: usize =
        cell_scores.iter().map(|c| c.violations.len()).sum();

    for cell in &cell_scores {
        let ev = cell.clone();
        emit_global(|| Event::FuzzCase {
            scheduler: ev.scheduler,
            case: ev.case_idx,
            scenario: ev.scenario,
            max_latency_us: ev.max_us,
            violations: ev.violations.len(),
        });
    }
    // Quarantined cells, post-collection in canonical order, from the
    // calling thread: deterministic for any thread count.
    for p in &failures.failed {
        let (label, kind, detail) =
            (p.label.clone(), p.kind.clone(), p.detail.clone());
        emit_global(|| Event::PointFailed {
            what: "fuzz".to_string(),
            label,
            kind,
            detail,
        });
    }
    let best = standings
        .first()
        .map(|s| s.scheduler.clone())
        .unwrap_or_default();
    emit_global(|| Event::TournamentSummary {
        cases: fuzz.cases,
        schedulers: opts.schedulers.len(),
        cells: cell_scores.len(),
        violations,
        best,
    });

    let report = TournamentReport {
        fuzz_seed: fuzz.seed,
        cases: fuzz.cases,
        jobs: fuzz.jobs,
        schedulers: opts.schedulers.clone(),
        cells: cell_scores,
        standings,
        violations,
        repros,
    };
    Ok((report, counters, failures))
}

/// Rank the roster: per-metric ascending ranks (1 + number of strictly
/// better schedulers) summed into `rank_score`; standings sorted by
/// violations first (a policy that broke an invariant can't win), then
/// rank score, then name.
fn rank(schedulers: &[String], cells: &[CellScore]) -> Vec<SchedStanding> {
    let mut rows: Vec<SchedStanding> = schedulers
        .iter()
        .map(|name| {
            let mine: Vec<&CellScore> =
                cells.iter().filter(|c| &c.scheduler == name).collect();
            let n = mine.len().max(1) as f64;
            let mean = |f: &dyn Fn(&CellScore) -> f64| {
                mine.iter().map(|c| f(*c)).sum::<f64>() / n
            };
            SchedStanding {
                scheduler: name.clone(),
                worst_max_us: mine
                    .iter()
                    .map(|c| c.max_us)
                    .fold(0.0, f64::max),
                mean_p95_us: mean(&|c| c.p95_us),
                mean_p99_us: mean(&|c| c.p99_us),
                deadline_misses: mine
                    .iter()
                    .map(|c| c.deadline_misses)
                    .sum(),
                energy_j: mine.iter().map(|c| c.energy_j).sum(),
                fallback_rate: mean(&|c| c.fallback_rate),
                violations: mine
                    .iter()
                    .map(|c| c.violations.len())
                    .sum(),
                rank_score: 0.0,
            }
        })
        .collect();
    let metrics: [&dyn Fn(&SchedStanding) -> f64; 6] = [
        &|s| s.worst_max_us,
        &|s| s.mean_p95_us,
        &|s| s.mean_p99_us,
        &|s| s.deadline_misses as f64,
        &|s| s.energy_j,
        &|s| s.fallback_rate,
    ];
    for metric in metrics {
        let values: Vec<f64> = rows.iter().map(|r| metric(r)).collect();
        for (i, row) in rows.iter_mut().enumerate() {
            let better =
                values.iter().filter(|&&v| v < values[i]).count();
            row.rank_score += (better + 1) as f64;
        }
    }
    rows.sort_by(|a, b| {
        a.violations
            .cmp(&b.violations)
            .then(a.rank_score.total_cmp(&b.rank_score))
            .then(a.scheduler.cmp(&b.scheduler))
    });
    rows
}

// ---------------------------------------------------------------------------
// Shrinking + replayable repros
// ---------------------------------------------------------------------------

/// Per-channel sample budget for the trace embedded in a [`Repro`]:
/// repro files are meant to be small, pasteable artifacts, so keep the
/// picture coarse (the full-resolution run is one `replay` away).
const REPRO_TRACE_BUDGET: usize = 128;

/// A minimized, replayable failure: the shrunk scenario plus every
/// config field [`case_config`] derives a run from, and the verdict the
/// minimized run produced.  [`replay`] re-executes it and must land on
/// a bit-identical verdict.
#[derive(Debug, Clone, PartialEq)]
pub struct Repro {
    pub scheduler: String,
    pub case_idx: usize,
    pub fuzz_seed: u64,
    pub sim_seed: u64,
    pub jobs: usize,
    pub rate_per_ms: f64,
    pub inject_label: Option<String>,
    /// The oracle the shrinker preserved.
    pub oracle: String,
    /// Full verdict of the minimized scenario.
    pub violations: Vec<(String, String)>,
    pub scenario: Scenario,
    /// Downsampled probe trace of the minimized failing run, so
    /// `fuzz replay` can render what the simulator was doing when the
    /// oracle tripped.  Absent in repros written before the probe
    /// subsystem existed.
    pub trace: Option<crate::probe::TraceSeries>,
}

impl Repro {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", Json::Str("ds3r-fuzz-repro".into()))
            .set("scheduler", Json::Str(self.scheduler.clone()))
            .set("case", Json::Num(self.case_idx as f64))
            .set("fuzz_seed", crate::util::json::u64_to_json(self.fuzz_seed))
            .set("sim_seed", crate::util::json::u64_to_json(self.sim_seed))
            .set("jobs", Json::Num(self.jobs as f64))
            .set("rate_per_ms", Json::Num(self.rate_per_ms))
            .set(
                "inject",
                match &self.inject_label {
                    Some(l) => Json::Str(l.clone()),
                    None => Json::Null,
                },
            )
            .set("oracle", Json::Str(self.oracle.clone()))
            .set(
                "violations",
                Json::Arr(
                    self.violations
                        .iter()
                        .map(|(o, d)| {
                            let mut v = Json::obj();
                            v.set("oracle", Json::Str(o.clone()))
                                .set("detail", Json::Str(d.clone()));
                            v
                        })
                        .collect(),
                ),
            )
            .set("scenario", self.scenario.to_json())
            .set(
                "trace",
                match &self.trace {
                    Some(t) => t.to_json(),
                    None => Json::Null,
                },
            );
        j
    }

    pub fn from_json(j: &Json) -> Result<Repro> {
        if j.get("kind").and_then(Json::as_str) != Some("ds3r-fuzz-repro")
        {
            return Err(Error::Config(
                "not a ds3r-fuzz-repro file".into(),
            ));
        }
        Ok(Repro {
            scheduler: j.req_str("scheduler")?.to_string(),
            case_idx: j.req_f64("case")? as usize,
            fuzz_seed: j.req_f64("fuzz_seed")? as u64,
            sim_seed: j.req_f64("sim_seed")? as u64,
            jobs: j.req_f64("jobs")? as usize,
            rate_per_ms: j.req_f64("rate_per_ms")?,
            inject_label: j
                .get("inject")
                .and_then(Json::as_str)
                .map(str::to_string),
            oracle: j.req_str("oracle")?.to_string(),
            violations: j
                .req_arr("violations")?
                .iter()
                .map(|v| {
                    Ok((
                        v.req_str("oracle")?.to_string(),
                        v.req_str("detail")?.to_string(),
                    ))
                })
                .collect::<Result<Vec<_>>>()?,
            scenario: Scenario::from_json(
                j.get("scenario").ok_or_else(|| {
                    Error::Config("repro missing 'scenario'".into())
                })?,
            )?,
            trace: match j.get("trace") {
                Some(Json::Null) | None => None,
                Some(t) => Some(crate::probe::TraceSeries::from_json(t)?),
            },
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Repro> {
        Repro::from_json(&Json::parse_file(path)?)
    }
}

#[allow(clippy::too_many_arguments)]
fn run_case_violations(
    setup: &SimSetup,
    slot: &mut Option<SimWorker>,
    sched: &str,
    scenario: &Scenario,
    sim_seed: u64,
    jobs: usize,
    rate: f64,
    inject_label: Option<&str>,
) -> Result<Vec<Violation>> {
    run_case_violations_probed(
        setup,
        slot,
        sched,
        scenario,
        sim_seed,
        jobs,
        rate,
        inject_label,
        None,
    )
    .map(|(v, _)| v)
}

/// [`run_case_violations`] plus an optional probe: when `probe` is
/// given the run records a bounded trace (util / temperature / power /
/// queue depth) which is returned alongside the verdict.  Probing does
/// not perturb the verdict — the recorder only observes.
#[allow(clippy::too_many_arguments)]
fn run_case_violations_probed(
    setup: &SimSetup,
    slot: &mut Option<SimWorker>,
    sched: &str,
    scenario: &Scenario,
    sim_seed: u64,
    jobs: usize,
    rate: f64,
    inject_label: Option<&str>,
    probe: Option<&crate::probe::ProbeConfig>,
) -> Result<(Vec<Violation>, Option<crate::probe::TraceSeries>)> {
    let cfg = case_config(sched, scenario, sim_seed, jobs, rate);
    let worker = SimWorker::obtain(slot, setup, &cfg)?;
    if let Some(pc) = probe {
        worker.attach_probe(pc.clone());
    }
    let report = worker.run(setup);
    let violations = check_cell(report, &cfg, scenario, inject_label);
    let trace = worker.take_probe_trace();
    Ok((violations, trace))
}

/// Greedy event-deletion shrink: repeatedly drop any event whose
/// removal keeps the scenario valid and the `target` oracle violated,
/// until a pass removes nothing.  Returns the minimized [`Repro`]
/// carrying the minimized run's full verdict.
#[allow(clippy::too_many_arguments)]
fn shrink_and_describe(
    setup: &SimSetup,
    slot: &mut Option<SimWorker>,
    fuzz: &FuzzConfig,
    sched: &str,
    case_idx: usize,
    scenario: &Scenario,
    target: &str,
    inject_label: Option<&str>,
) -> Result<Repro> {
    let sim_seed = case_seed(fuzz, case_idx);
    let rate = base_rate(fuzz);
    let platform = setup.platform();
    let n_apps = setup.apps().len();
    let mut cur = scenario.clone();
    loop {
        let mut changed = false;
        let mut i = 0;
        while i < cur.events.len() {
            let cand = cur.without_event(i);
            let still_fails = cand.validate().is_ok()
                && cand.validate_for(platform, n_apps).is_ok()
                && run_case_violations(
                    setup,
                    slot,
                    sched,
                    &cand,
                    sim_seed,
                    fuzz.jobs,
                    rate,
                    inject_label,
                )?
                .iter()
                .any(|v| v.oracle == target);
            if still_fails {
                cur = cand;
                changed = true;
            } else {
                i += 1;
            }
        }
        if !changed {
            break;
        }
    }
    // Re-run the minimized scenario once more with a small probe
    // attached so the repro carries a picture of the failing run.
    let (verdict, trace) = run_case_violations_probed(
        setup,
        slot,
        sched,
        &cur,
        sim_seed,
        fuzz.jobs,
        rate,
        inject_label,
        Some(&crate::probe::ProbeConfig::with_budget(REPRO_TRACE_BUDGET)),
    )?;
    Ok(Repro {
        scheduler: sched.to_string(),
        case_idx,
        fuzz_seed: fuzz.seed,
        sim_seed,
        jobs: fuzz.jobs,
        rate_per_ms: rate,
        inject_label: inject_label.map(str::to_string),
        oracle: target.to_string(),
        violations: verdict
            .into_iter()
            .map(|v| (v.oracle, v.detail))
            .collect(),
        scenario: cur,
        trace,
    })
}

/// Re-execute a repro exactly as the tournament did and return the
/// fresh verdict — bit-identical to `repro.violations` when the
/// simulator still misbehaves the same way (the property
/// `rust/tests/fuzz_props.rs` pins), empty if the bug has been fixed.
pub fn replay(
    repro: &Repro,
    platform: &Platform,
    apps: &[AppGraph],
) -> Result<Vec<Violation>> {
    repro.scenario.validate()?;
    repro.scenario.validate_for(platform, apps.len())?;
    let base = SimConfig::default();
    let setup = SimSetup::new(platform, apps, &base)?;
    let mut slot = None;
    run_case_violations(
        &setup,
        &mut slot,
        &repro.scheduler,
        &repro.scenario,
        repro.sim_seed,
        repro.jobs,
        repro.rate_per_ms,
        repro.inject_label.as_deref(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::suite::{self, WifiParams};

    fn tiny_fuzz() -> FuzzConfig {
        let mut f = FuzzConfig::default();
        f.cases = 2;
        f.jobs = 12;
        f.min_events = 3;
        f.max_events = 6;
        f.horizon_us = 30_000.0;
        f
    }

    fn workload() -> Vec<AppGraph> {
        vec![suite::wifi_tx(WifiParams { symbols: 2 })]
    }

    #[test]
    fn tiny_tournament_runs_clean_and_ranks_all_schedulers() {
        let p = Platform::table2_soc();
        let apps = workload();
        let fuzz = tiny_fuzz();
        let opts = TournamentOpts {
            schedulers: vec!["etf".into(), "rr".into(), "met".into()],
            threads: 2,
            repro_dir: None,
            inject_label: None,
            store: None,
        };
        let (report, counters) =
            run_tournament(&p, &apps, &fuzz, &opts).unwrap();
        assert_eq!(report.cells.len(), 6);
        assert_eq!(report.standings.len(), 3);
        assert_eq!(report.violations, 0, "{:?}", report.cells);
        assert_eq!(counters.get("runs"), 6);
        // Canonical order: scheduler-major, case-minor.
        let order: Vec<(String, usize)> = report
            .cells
            .iter()
            .map(|c| (c.scheduler.clone(), c.case_idx))
            .collect();
        assert_eq!(
            order,
            vec![
                ("etf".into(), 0),
                ("etf".into(), 1),
                ("rr".into(), 0),
                ("rr".into(), 1),
                ("met".into(), 0),
                ("met".into(), 1),
            ]
        );
        // JSON round-trip.
        let j = report.to_json().to_string();
        let back =
            TournamentReport::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, report);
    }

    #[test]
    fn fanout_schedule_is_largest_first() {
        // The permutation the tournament feeds the pool must be
        // non-increasing in expected cell size (the ROADMAP
        // housekeeping contract), for a deliberately heterogeneous
        // scheduler × scenario grid.
        let scheds = ["table".to_string(), "rr".to_string()];
        let scenarios = [
            Scenario::new("small", ""),
            Scenario::new("big", "")
                .event(0.0, crate::scenario::Action::SetRate { per_ms: 1.0 })
                .event(1.0, crate::scenario::Action::SetAmbient { t_c: 30.0 })
                .event(2.0, crate::scenario::Action::SetAmbient { t_c: 35.0 }),
        ];
        let cells: Vec<(usize, usize)> = (0..scheds.len())
            .flat_map(|s| (0..scenarios.len()).map(move |c| (s, c)))
            .collect();
        let order = size_ordered_indices(&cells, |&(s, c)| {
            cell_cost(&scheds[s], &scenarios[c])
        });
        let costs: Vec<u64> = order
            .iter()
            .map(|&i| {
                let (s, c) = cells[i];
                cell_cost(&scheds[s], &scenarios[c])
            })
            .collect();
        for w in costs.windows(2) {
            assert!(w[0] >= w[1], "schedule not largest-first: {costs:?}");
        }
        // The solver-backed scheduler's cells lead the schedule.
        assert_eq!(cells[order[0]].0, 0, "table cells must go first");
    }

    #[test]
    fn warm_store_reproduces_report_and_counters() {
        let p = Platform::table2_soc();
        let apps = workload();
        let fuzz = tiny_fuzz();
        let dir = std::env::temp_dir().join("ds3r_fuzz_store_test");
        let _ = std::fs::remove_dir_all(&dir);
        let store = crate::store::ExperimentStore::open(&dir).unwrap();
        let ctx = StoreCtx {
            store: store.clone(),
            workload_digest: "wd".into(),
        };
        let mk = |threads| TournamentOpts {
            schedulers: vec!["etf".into(), "rr".into()],
            threads,
            repro_dir: None,
            inject_label: None,
            store: Some(ctx.clone()),
        };
        let (r1, c1) = run_tournament(&p, &apps, &fuzz, &mk(1)).unwrap();
        assert_eq!(r1.violations, 0, "{:?}", r1.cells);
        let hits_cold = store.session_hits();
        // Second run — different thread count, warm cache — must serve
        // every cell from the store and land on identical bytes.
        let (r2, c2) = run_tournament(&p, &apps, &fuzz, &mk(8)).unwrap();
        assert_eq!(
            store.session_hits() - hits_cold,
            r1.cells.len() as u64,
            "warm rerun must hit the cache for every cell"
        );
        assert_eq!(r1, r2);
        assert_eq!(
            c1.to_json().to_string(),
            c2.to_json().to_string(),
            "aggregate counters must merge back byte-identically"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn quarantine_drops_panicked_cells_and_never_caches_them() {
        let p = Platform::table2_soc();
        let apps = workload();
        let mut fuzz = tiny_fuzz();
        // Unique seed → unique scenario names ("fuzz-s777-c*"), so the
        // armed prefix cannot touch concurrently running tests.
        fuzz.seed = 777;
        let dir = std::env::temp_dir().join("ds3r_fuzz_quarantine_test");
        let _ = std::fs::remove_dir_all(&dir);
        let store = crate::store::ExperimentStore::open(&dir).unwrap();
        let ctx = StoreCtx {
            store: store.clone(),
            workload_digest: "wd".into(),
        };
        let opts = TournamentOpts {
            schedulers: vec!["etf".into(), "rr".into()],
            threads: 2,
            repro_dir: None,
            inject_label: None,
            store: Some(ctx),
        };
        let _g = faultpoint::Armed::new(
            faultpoint::sites::SWEEP_POINT,
            "etf@fuzz-s777",
            faultpoint::Fault::Panic,
        );
        // Abort policy: the injected panic fails the whole run.
        let err =
            run_tournament(&p, &apps, &fuzz, &opts).unwrap_err();
        assert!(
            err.to_string().contains("etf@fuzz-s777"),
            "abort error must name the failing cell: {err}"
        );
        // Quarantine policy: rr survives, etf cells are dropped and
        // recorded.
        let quarantine =
            FailPolicy::Quarantine { max_failures: None };
        let (report, counters, failures) = run_tournament_with_policy(
            &p, &apps, &fuzz, &opts, &quarantine,
        )
        .unwrap();
        assert_eq!(report.cells.len(), 2, "{:?}", report.cells);
        assert!(report.cells.iter().all(|c| c.scheduler == "rr"));
        assert_eq!(failures.quarantined(), 2);
        assert!(failures.failed.iter().all(|f| f.kind == "panic"));
        assert_eq!(counters.get("runs"), 2);
        // A warm rerun serves the healthy cells from the store and
        // quarantines the failing ones again — failed cells were
        // never cached.
        let (r2, c2, f2) = run_tournament_with_policy(
            &p, &apps, &fuzz, &opts, &quarantine,
        )
        .unwrap();
        assert_eq!(r2, report);
        assert_eq!(
            c2.to_json().to_string(),
            counters.to_json().to_string()
        );
        assert_eq!(f2.quarantined(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_violation_shrinks_to_minimal_repro_and_replays() {
        let p = Platform::table2_soc();
        let apps = workload();
        let mut fuzz = tiny_fuzz();
        fuzz.cases = 1;
        let dir = std::env::temp_dir().join("ds3r_fuzz_shrink_test");
        let _ = std::fs::remove_dir_all(&dir);
        let opts = TournamentOpts {
            schedulers: vec!["etf".into()],
            threads: 1,
            repro_dir: Some(dir.clone()),
            // Every generated scenario opens with a SetRate event, so
            // every cell trips the hook and must shrink to exactly it.
            inject_label: Some("rate=".into()),
            store: None,
        };
        let (report, _) = run_tournament(&p, &apps, &fuzz, &opts).unwrap();
        assert_eq!(report.violations, 1);
        assert_eq!(report.repros.len(), 1);
        let repro = Repro::load(Path::new(&report.repros[0])).unwrap();
        assert_eq!(repro.oracle, INJECTED_ORACLE);
        assert_eq!(
            repro.scenario.events.len(),
            1,
            "greedy deletion must strip every event except the trigger: \
             {:?}",
            repro.scenario.events
        );
        assert!(repro.scenario.events[0]
            .action
            .label()
            .starts_with("rate="));
        // Replay reproduces the recorded verdict bit-identically.
        let fresh = replay(&repro, &p, &apps).unwrap();
        let fresh: Vec<(String, String)> = fresh
            .into_iter()
            .map(|v| (v.oracle, v.detail))
            .collect();
        assert_eq!(fresh, repro.violations);
        // Repro JSON round-trips.
        let j = repro.to_json().to_string();
        let back = Repro::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, repro);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
