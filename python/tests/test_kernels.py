"""Kernel-vs-oracle correctness: the CORE L1 signal.

Each Pallas kernel is checked against the pure-jnp reference in
kernels/ref.py, both on the fixed AOT shapes and under hypothesis-driven
value sweeps (shapes are fixed by the AOT contract; values, scales, and
padding patterns are swept).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import etf, ref, thermal

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")


def make_thermal_inputs(rng, t_scale=50.0, p_scale=3.0):
    K, N, P = thermal.K, thermal.N, thermal.P
    t = jnp.asarray(rng.uniform(0, t_scale, (K, N)), jnp.float32)
    # Discretized stable system matrix: diagonally dominant, spectral
    # radius < 1 (I - dt*G/C form).
    a = np.eye(N) * 0.95 + rng.uniform(0, 0.05 / N, (N, N))
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(rng.uniform(0, 0.1, (N, P)), jnp.float32)
    pd = jnp.asarray(rng.uniform(0, p_scale, (K, P)), jnp.float32)
    v = jnp.asarray(rng.uniform(0.9, 1.3, (K, P)), jnp.float32)
    k1 = jnp.asarray(rng.uniform(0.01, 0.1, (1, P)), jnp.float32)
    k2 = jnp.asarray(rng.uniform(0.005, 0.02, (1, P)), jnp.float32)
    pe_node = np.zeros((P, N), np.float32)
    for p in range(P):
        pe_node[p, rng.integers(0, N)] = 1.0
    return t, a, b, pd, v, k1, k2, jnp.asarray(pe_node)


class TestThermalKernel:
    def test_matches_ref_fixed_seed(self):
        rng = np.random.default_rng(0)
        args = make_thermal_inputs(rng)
        got = thermal.dtpm_step(*args)
        want = ref.dtpm_step_ref(*args)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)

    def test_output_shapes(self):
        rng = np.random.default_rng(1)
        t_next, p_leak, p_tot = thermal.dtpm_step(*make_thermal_inputs(rng))
        assert t_next.shape == (thermal.K, thermal.N)
        assert p_leak.shape == (thermal.K, thermal.P)
        assert p_tot.shape == (thermal.K, thermal.P)

    def test_zero_power_decays(self):
        """With zero power and contraction A, temperatures must not grow."""
        rng = np.random.default_rng(2)
        t, a, b, _, v, k1, k2, pe_node = make_thermal_inputs(rng)
        zero = jnp.zeros((thermal.K, thermal.P), jnp.float32)
        t_next, p_leak, p_tot = thermal.dtpm_step(
            t, a, b, zero, v, jnp.zeros_like(k1), k2, pe_node)
        assert np.all(np.asarray(p_leak) == 0)
        assert np.all(np.asarray(p_tot) == 0)
        assert float(jnp.max(t_next)) <= float(jnp.max(t)) * 1.01

    def test_leakage_monotone_in_temperature(self):
        """Leakage must increase with temperature (exp model)."""
        rng = np.random.default_rng(3)
        t, a, b, pd, v, k1, k2, pe_node = make_thermal_inputs(rng)
        _, leak_cold, _ = thermal.dtpm_step(
            jnp.zeros_like(t), a, b, pd, v, k1, k2, pe_node)
        _, leak_hot, _ = thermal.dtpm_step(
            jnp.full_like(t, 80.0), a, b, pd, v, k1, k2, pe_node)
        assert np.all(np.asarray(leak_hot) >= np.asarray(leak_cold))

    @hypothesis.given(seed=st.integers(0, 2**31 - 1),
                      t_scale=st.floats(0.0, 100.0),
                      p_scale=st.floats(0.0, 10.0))
    def test_matches_ref_hypothesis(self, seed, t_scale, p_scale):
        rng = np.random.default_rng(seed)
        args = make_thermal_inputs(rng, t_scale, p_scale)
        got = thermal.dtpm_step(*args)
        want = ref.dtpm_step_ref(*args)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)


def make_etf_inputs(rng, n_valid_tasks=None, n_valid_pes=None):
    I, J = etf.I, etf.J
    nv_i = I if n_valid_tasks is None else n_valid_tasks
    nv_j = J if n_valid_pes is None else n_valid_pes
    avail = rng.uniform(0, 1e4, (1, J)).astype(np.float32)
    ready = rng.uniform(0, 1e4, (I, J)).astype(np.float32)
    exe = rng.uniform(1, 500, (I, J)).astype(np.float32)
    # Pad unused rows/cols the way rust does: +inf exec.
    exe[nv_i:, :] = np.inf
    exe[:, nv_j:] = np.inf
    return jnp.asarray(avail), jnp.asarray(ready), jnp.asarray(exe)


class TestEtfKernel:
    def test_matches_ref_fixed_seed(self):
        rng = np.random.default_rng(0)
        args = make_etf_inputs(rng)
        got = etf.etf_matrix(*args)
        want = ref.etf_matrix_ref(*args)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-6)

    def test_argmin_matches_numpy(self):
        rng = np.random.default_rng(7)
        avail, ready, exe = make_etf_inputs(rng, n_valid_tasks=20,
                                            n_valid_pes=14)
        fin, best_pe, best_fin = etf.etf_matrix(avail, ready, exe)
        fin_np = np.maximum(np.asarray(avail), np.asarray(ready)) \
            + np.asarray(exe)
        # Valid region only (padded rows are all-inf).
        np.testing.assert_array_equal(
            np.asarray(best_pe)[:20, 0].astype(int),
            np.argmin(fin_np[:20], axis=1))
        np.testing.assert_allclose(
            np.asarray(best_fin)[:20, 0], np.min(fin_np[:20], axis=1))

    def test_padded_pes_never_selected(self):
        rng = np.random.default_rng(11)
        avail, ready, exe = make_etf_inputs(rng, n_valid_pes=14)
        _, best_pe, _ = etf.etf_matrix(avail, ready, exe)
        assert np.all(np.asarray(best_pe)[:, 0] < 14)

    def test_tie_break_lowest_index(self):
        I, J = etf.I, etf.J
        avail = jnp.zeros((1, J), jnp.float32)
        ready = jnp.zeros((I, J), jnp.float32)
        exe = jnp.ones((I, J), jnp.float32)  # all finish times equal
        _, best_pe, _ = etf.etf_matrix(avail, ready, exe)
        assert np.all(np.asarray(best_pe) == 0)

    @hypothesis.given(seed=st.integers(0, 2**31 - 1),
                      nv_i=st.integers(1, 64), nv_j=st.integers(1, 16))
    def test_matches_ref_hypothesis(self, seed, nv_i, nv_j):
        rng = np.random.default_rng(seed)
        args = make_etf_inputs(rng, nv_i, nv_j)
        got = etf.etf_matrix(*args)
        want = ref.etf_matrix_ref(*args)
        for g, w in zip(got, want):
            g, w = np.asarray(g), np.asarray(w)
            mask = np.isfinite(w)
            np.testing.assert_allclose(g[mask], w[mask], rtol=1e-5)
            assert np.array_equal(np.isinf(g), np.isinf(w))
