//! In-simulation time-series probes: bounded, deterministic traces of
//! power, temperature, utilization, and scheduler activity.
//!
//! The paper's pitch is that DS3 makes *temperature and power
//! evaluation over seconds-to-minutes of workload* tractable; the
//! scalar aggregates in [`crate::stats::SimReport`] collapse exactly
//! the trajectories that argument rests on.  A [`ProbeRecorder`]
//! attaches to a [`crate::sim::SimWorker`] and samples
//!
//! * per-PE utilization / effective frequency / availability,
//!   ready-queue depth, and cumulative scheduler invocations at every
//!   DTPM epoch boundary, and
//! * per-thermal-node temperature and SoC power at every integrated
//!   epoch (riding `account_epoch`, the one accounting point shared by
//!   the lazy flush lane, the eager lane, and the device lane — so a
//!   probed lazy run records **bit-identical** samples to an eager
//!   one),
//!
//! plus phase markers from scenario timelines.
//!
//! ## Determinism contract
//!
//! A trace is a pure function of (config, seed): no wall-clock field
//! enters [`TraceSeries`], sampling happens at simulated-time points
//! that exist identically on every lane, and downsampling depends only
//! on the sample *count*.  A fixed-seed run therefore serializes to a
//! byte-identical artifact across thread counts and reruns
//! (`rust/tests/integration_probe.rs`).
//!
//! ## Bounded memory: stride-doubling downsampling
//!
//! Each channel holds at most `budget` kept samples.  A
//! [`ProbeSeries`] keeps every raw sample whose index is a multiple of
//! its current `stride` (initially 1); when the kept buffer reaches
//! the budget it drops every other kept sample and doubles the stride.
//! A minute-long simulation thus records a uniformly-spaced sketch at
//! half-to-full budget resolution, for any run length, allocation-free
//! after saturation.  [`ProbeSeries::finish`] re-appends the final raw
//! sample if the stride dropped it, so both endpoints always survive.
//!
//! Rendering (`ds3r trace`) and diffing live here too — as pure
//! string builders; only `cli.rs` prints.

use crate::util::json::{u64_from_json, u64_to_json, Json};
use crate::{Error, Result};

/// Artifact kind tag (`"kind"` field of the JSON artifact).
pub const TRACE_KIND: &str = "ds3r-trace";
/// Bump when the trace JSON layout changes incompatibly.
pub const TRACE_SCHEMA_VERSION: u64 = 1;
/// Default per-channel sample budget.
pub const DEFAULT_BUDGET: usize = 512;

// ---------------------------------------------------------------------------
// Probe configuration
// ---------------------------------------------------------------------------

/// Configuration for one probe attach.
#[derive(Debug, Clone)]
pub struct ProbeConfig {
    /// Maximum kept samples per channel (>= 2; the downsampler needs
    /// room for both endpoints).
    pub budget: usize,
}

impl Default for ProbeConfig {
    fn default() -> Self {
        ProbeConfig { budget: DEFAULT_BUDGET }
    }
}

impl ProbeConfig {
    pub fn with_budget(budget: usize) -> ProbeConfig {
        ProbeConfig { budget: budget.max(2) }
    }
}

// ---------------------------------------------------------------------------
// ProbeSeries: one bounded channel
// ---------------------------------------------------------------------------

/// One bounded (t, v) series with deterministic stride-doubling
/// downsampling.  Kept samples are exactly the raw samples whose index
/// is `0 (mod stride)`, plus (after [`ProbeSeries::finish`]) the final
/// raw sample.
#[derive(Debug, Clone)]
pub struct ProbeSeries {
    budget: usize,
    stride: u64,
    /// Raw samples pushed (kept or not).
    count: u64,
    t_us: Vec<f64>,
    v: Vec<f64>,
    /// Most recent raw sample — the endpoint candidate for `finish`.
    last: Option<(f64, f64)>,
}

impl ProbeSeries {
    pub fn new(budget: usize) -> ProbeSeries {
        ProbeSeries {
            budget: budget.max(2),
            stride: 1,
            count: 0,
            t_us: Vec::new(),
            v: Vec::new(),
            last: None,
        }
    }

    /// Record one raw sample.  O(1) amortized; never exceeds the
    /// budget.
    pub fn push(&mut self, t_us: f64, v: f64) {
        if self.count % self.stride == 0 {
            if self.t_us.len() == self.budget {
                self.compact();
            }
            // `compact` doubled the stride; the current index may no
            // longer be a keeper.
            if self.count % self.stride == 0 {
                self.t_us.push(t_us);
                self.v.push(v);
            }
        }
        self.count += 1;
        self.last = Some((t_us, v));
    }

    /// Drop every other kept sample and double the stride.  Kept slot
    /// `i` holds raw index `i * stride`, so retaining even slots
    /// retains exactly the raw indices `0 (mod 2 * stride)`.
    fn compact(&mut self) {
        let mut w = 0;
        for r in (0..self.t_us.len()).step_by(2) {
            self.t_us[w] = self.t_us[r];
            self.v[w] = self.v[r];
            w += 1;
        }
        self.t_us.truncate(w);
        self.v.truncate(w);
        self.stride *= 2;
    }

    /// Seal the series: if the stride dropped the final raw sample,
    /// append it (compacting once more if the buffer is full), so the
    /// trace always preserves both endpoints.
    pub fn finish(&mut self) {
        if let Some((t, v)) = self.last {
            if self.count > 0 && (self.count - 1) % self.stride != 0 {
                if self.t_us.len() == self.budget {
                    self.compact();
                }
                self.t_us.push(t);
                self.v.push(v);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.t_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.t_us.is_empty()
    }

    /// Raw samples observed (kept + downsampled away).
    pub fn raw_count(&self) -> u64 {
        self.count
    }

    pub fn stride(&self) -> u64 {
        self.stride
    }

    pub fn times_us(&self) -> &[f64] {
        &self.t_us
    }

    pub fn values(&self) -> &[f64] {
        &self.v
    }

    fn into_channel(self, name: String, unit: &str) -> TraceChannel {
        TraceChannel {
            name,
            unit: unit.to_string(),
            raw_count: self.count,
            stride: self.stride,
            t_us: self.t_us,
            v: self.v,
        }
    }
}

// ---------------------------------------------------------------------------
// ProbeRecorder: the in-simulation sampler
// ---------------------------------------------------------------------------

/// A phase boundary from a scenario timeline, in simulated time.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseMark {
    pub t_us: f64,
    pub label: String,
}

/// The live sampler a [`crate::sim::SimWorker`] carries while probed.
/// Cheap to construct, allocation-free after channel buffers saturate;
/// the worker holds it as `Option<Box<ProbeRecorder>>` so the unprobed
/// hot path pays one branch per hook.
#[derive(Debug)]
pub struct ProbeRecorder {
    cfg: ProbeConfig,
    n_pes: usize,
    n_nodes: usize,
    // Epoch-boundary channels (sampled at DTPM epoch ends, identical
    // on the lazy and eager lanes).
    pe_util: Vec<ProbeSeries>,
    pe_mhz: Vec<ProbeSeries>,
    pe_avail: Vec<ProbeSeries>,
    ready_depth: ProbeSeries,
    sched_invocations: ProbeSeries,
    // Integration channels (sampled in `account_epoch`; the cursor
    // reconstructs epoch-end times during a deferred batch replay).
    node_temp: Vec<ProbeSeries>,
    power_w: ProbeSeries,
    cursor_us: f64,
    markers: Vec<PhaseMark>,
}

impl ProbeRecorder {
    pub fn new(
        cfg: ProbeConfig,
        n_pes: usize,
        n_nodes: usize,
    ) -> ProbeRecorder {
        let b = cfg.budget.max(2);
        ProbeRecorder {
            cfg: ProbeConfig { budget: b },
            n_pes,
            n_nodes,
            pe_util: (0..n_pes).map(|_| ProbeSeries::new(b)).collect(),
            pe_mhz: (0..n_pes).map(|_| ProbeSeries::new(b)).collect(),
            pe_avail: (0..n_pes).map(|_| ProbeSeries::new(b)).collect(),
            ready_depth: ProbeSeries::new(b),
            sched_invocations: ProbeSeries::new(b),
            node_temp: (0..n_nodes).map(|_| ProbeSeries::new(b)).collect(),
            power_w: ProbeSeries::new(b),
            cursor_us: 0.0,
            markers: Vec::new(),
        }
    }

    /// Sample the epoch-boundary channels at simulated time `t_us`.
    /// Per-PE frequency is reconstructed from the cluster cache
    /// (`mhz = cluster_mhz[pe_cluster[pe]]`) to stay allocation-free.
    #[allow(clippy::too_many_arguments)]
    pub fn sample_epoch(
        &mut self,
        t_us: f64,
        util: &[f64],
        avail: &[bool],
        cluster_mhz: &[f64],
        pe_cluster: &[usize],
        ready_depth: usize,
        sched_invocations: u64,
    ) {
        for pe in 0..self.n_pes {
            self.pe_util[pe].push(t_us, util.get(pe).copied().unwrap_or(0.0));
            self.pe_mhz[pe].push(
                t_us,
                pe_cluster
                    .get(pe)
                    .and_then(|&c| cluster_mhz.get(c))
                    .copied()
                    .unwrap_or(0.0),
            );
            self.pe_avail[pe].push(
                t_us,
                if avail.get(pe).copied().unwrap_or(false) { 1.0 } else { 0.0 },
            );
        }
        self.ready_depth.push(t_us, ready_depth as f64);
        self.sched_invocations.push(t_us, sched_invocations as f64);
    }

    /// Sample the integration channels for one accounted epoch of
    /// length `dt_us`.  Epochs tile simulated time from 0 and are
    /// replayed in order by the lazy flush, so the cumulative cursor
    /// equals the true epoch-end time on every lane.
    pub fn sample_thermal(
        &mut self,
        dt_us: f64,
        theta: &[f64],
        t_ambient_c: f64,
        power_w: f64,
    ) {
        self.cursor_us += dt_us;
        let t = self.cursor_us;
        for n in 0..self.n_nodes {
            self.node_temp[n]
                .push(t, theta.get(n).copied().unwrap_or(0.0) + t_ambient_c);
        }
        self.power_w.push(t, power_w);
    }

    /// Record a phase boundary (scenario timeline marker).
    pub fn phase_marker(&mut self, t_us: f64, label: &str) {
        self.markers.push(PhaseMark { t_us, label: label.to_string() });
    }

    /// Rewrite the label of the most recent marker — scenario
    /// timelines may relabel a phase that begins at the same
    /// timestamp instead of opening a new one.
    pub fn relabel_last_marker(&mut self, label: &str) {
        if let Some(m) = self.markers.last_mut() {
            m.label = label.to_string();
        }
    }

    /// Seal every channel and convert into the serializable artifact.
    pub fn into_trace(
        mut self,
        scheduler: &str,
        scenario: &str,
        seed: u64,
    ) -> TraceSeries {
        let mut channels = Vec::new();
        for (i, mut s) in self.pe_util.drain(..).enumerate() {
            s.finish();
            channels.push(s.into_channel(format!("pe{i}.util"), "frac"));
        }
        for (i, mut s) in self.pe_mhz.drain(..).enumerate() {
            s.finish();
            channels.push(s.into_channel(format!("pe{i}.mhz"), "MHz"));
        }
        for (i, mut s) in self.pe_avail.drain(..).enumerate() {
            s.finish();
            channels.push(s.into_channel(format!("pe{i}.avail"), "bool"));
        }
        for (i, mut s) in self.node_temp.drain(..).enumerate() {
            s.finish();
            channels.push(s.into_channel(format!("node{i}.temp_c"), "C"));
        }
        let mut s = std::mem::replace(&mut self.power_w, ProbeSeries::new(2));
        s.finish();
        channels.push(s.into_channel("soc.power_w".into(), "W"));
        let mut s =
            std::mem::replace(&mut self.ready_depth, ProbeSeries::new(2));
        s.finish();
        channels.push(s.into_channel("sched.ready_depth".into(), "tasks"));
        let mut s = std::mem::replace(
            &mut self.sched_invocations,
            ProbeSeries::new(2),
        );
        s.finish();
        channels
            .push(s.into_channel("sched.invocations".into(), "count"));
        TraceSeries {
            schema_version: TRACE_SCHEMA_VERSION,
            scheduler: scheduler.to_string(),
            scenario: scenario.to_string(),
            seed,
            n_pes: self.n_pes,
            n_nodes: self.n_nodes,
            budget: self.cfg.budget,
            channels,
            markers: std::mem::take(&mut self.markers),
        }
    }
}

// ---------------------------------------------------------------------------
// TraceSeries: the serialized artifact
// ---------------------------------------------------------------------------

/// One sealed, serializable trace channel.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceChannel {
    pub name: String,
    pub unit: String,
    /// Raw samples observed before downsampling.
    pub raw_count: u64,
    /// Final keep-stride (1 = nothing was downsampled away).
    pub stride: u64,
    pub t_us: Vec<f64>,
    pub v: Vec<f64>,
}

impl TraceChannel {
    fn minmax(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &x in &self.v {
            if x.is_finite() {
                lo = lo.min(x);
                hi = hi.max(x);
            }
        }
        if !lo.is_finite() {
            (0.0, 0.0)
        } else {
            (lo, hi)
        }
    }

    fn mean(&self) -> f64 {
        if self.v.is_empty() {
            return 0.0;
        }
        self.v.iter().sum::<f64>() / self.v.len() as f64
    }
}

/// The schema-versioned trace artifact a probed run emits — a pure
/// function of (config, seed); see the module docs for the
/// determinism contract.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSeries {
    pub schema_version: u64,
    pub scheduler: String,
    /// Scenario name (empty for static runs).
    pub scenario: String,
    pub seed: u64,
    pub n_pes: usize,
    pub n_nodes: usize,
    /// Per-channel sample budget the recorder enforced.
    pub budget: usize,
    pub channels: Vec<TraceChannel>,
    pub markers: Vec<PhaseMark>,
}

impl TraceSeries {
    pub fn channel(&self, name: &str) -> Option<&TraceChannel> {
        self.channels.iter().find(|c| c.name == name)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", Json::Str(TRACE_KIND.into()))
            .set("schema_version", u64_to_json(self.schema_version))
            .set("scheduler", Json::Str(self.scheduler.clone()))
            .set("scenario", Json::Str(self.scenario.clone()))
            .set("seed", u64_to_json(self.seed))
            .set("n_pes", Json::Num(self.n_pes as f64))
            .set("n_nodes", Json::Num(self.n_nodes as f64))
            .set("budget", Json::Num(self.budget as f64));
        let mut chans = Vec::with_capacity(self.channels.len());
        for c in &self.channels {
            let mut cj = Json::obj();
            cj.set("name", Json::Str(c.name.clone()))
                .set("unit", Json::Str(c.unit.clone()))
                .set("raw_count", u64_to_json(c.raw_count))
                .set("stride", u64_to_json(c.stride))
                .set(
                    "t_us",
                    Json::Arr(c.t_us.iter().map(|&x| Json::Num(x)).collect()),
                )
                .set(
                    "v",
                    Json::Arr(c.v.iter().map(|&x| Json::Num(x)).collect()),
                );
            chans.push(cj);
        }
        j.set("channels", Json::Arr(chans));
        let mut marks = Vec::with_capacity(self.markers.len());
        for m in &self.markers {
            let mut mj = Json::obj();
            mj.set("t_us", Json::Num(m.t_us))
                .set("label", Json::Str(m.label.clone()));
            marks.push(mj);
        }
        j.set("markers", Json::Arr(marks));
        j
    }

    pub fn from_json(j: &Json) -> Result<TraceSeries> {
        let kind = j.req_str("kind")?;
        if kind != TRACE_KIND {
            return Err(Error::Json(format!(
                "not a trace artifact: kind '{kind}' (expected '{TRACE_KIND}')"
            )));
        }
        let schema_version = j
            .get("schema_version")
            .and_then(u64_from_json)
            .ok_or_else(|| {
                Error::Json("trace: missing schema_version".into())
            })?;
        if schema_version > TRACE_SCHEMA_VERSION {
            return Err(Error::Json(format!(
                "trace schema v{schema_version} is newer than supported \
                 v{TRACE_SCHEMA_VERSION}"
            )));
        }
        let mut channels = Vec::new();
        for cj in j.req_arr("channels")? {
            channels.push(TraceChannel {
                name: cj.req_str("name")?.to_string(),
                unit: cj.req_str("unit")?.to_string(),
                raw_count: cj
                    .get("raw_count")
                    .and_then(u64_from_json)
                    .unwrap_or(0),
                stride: cj.get("stride").and_then(u64_from_json).unwrap_or(1),
                t_us: cj
                    .get("t_us")
                    .ok_or_else(|| Error::Json("trace: missing t_us".into()))?
                    .f64_vec()?,
                v: cj
                    .get("v")
                    .ok_or_else(|| Error::Json("trace: missing v".into()))?
                    .f64_vec()?,
            });
        }
        let mut markers = Vec::new();
        if let Some(arr) = j.get("markers").and_then(|m| m.as_arr()) {
            for mj in arr {
                markers.push(PhaseMark {
                    t_us: mj.req_f64("t_us")?,
                    label: mj.req_str("label")?.to_string(),
                });
            }
        }
        Ok(TraceSeries {
            schema_version,
            scheduler: j.req_str("scheduler")?.to_string(),
            scenario: j.req_str("scenario")?.to_string(),
            seed: j.get("seed").and_then(u64_from_json).unwrap_or(0),
            n_pes: j.req_f64("n_pes")? as usize,
            n_nodes: j.req_f64("n_nodes")? as usize,
            budget: j.req_f64("budget")? as usize,
            channels,
            markers,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<TraceSeries> {
        TraceSeries::from_json(&Json::parse_file(path)?)
    }
}

/// Artifact kind tag of a multi-trace bundle (one scenario sweep).
pub const TRACE_SET_KIND: &str = "ds3r-trace-set";

/// Serialize one-or-many traces: a single trace stays a plain
/// [`TRACE_KIND`] artifact; several bundle into a [`TRACE_SET_KIND`]
/// with the traces in input (canonical) order.
pub fn traces_to_json(traces: &[TraceSeries]) -> Json {
    if traces.len() == 1 {
        return traces[0].to_json();
    }
    let mut j = Json::obj();
    j.set("kind", Json::Str(TRACE_SET_KIND.into()))
        .set("schema_version", u64_to_json(TRACE_SCHEMA_VERSION))
        .set(
            "traces",
            Json::Arr(traces.iter().map(|t| t.to_json()).collect()),
        );
    j
}

/// Parse either artifact shape back into a list of traces.
pub fn traces_from_json(j: &Json) -> Result<Vec<TraceSeries>> {
    match j.req_str("kind")? {
        TRACE_KIND => Ok(vec![TraceSeries::from_json(j)?]),
        TRACE_SET_KIND => j
            .req_arr("traces")?
            .iter()
            .map(TraceSeries::from_json)
            .collect(),
        other => Err(Error::Json(format!(
            "not a trace artifact: kind '{other}'"
        ))),
    }
}

// ---------------------------------------------------------------------------
// Rendering & diffing (pure string builders; cli.rs prints)
// ---------------------------------------------------------------------------

const SPARK_RAMP: &[u8] = b" .:-=+*#%@";

/// Resample `values` to `width` columns and render each as one ASCII
/// ramp character scaled to [lo, hi].
pub fn sparkline(values: &[f64], lo: f64, hi: f64, width: usize) -> String {
    if values.is_empty() || width == 0 {
        return String::new();
    }
    let span = if hi > lo { hi - lo } else { 1.0 };
    let mut out = String::with_capacity(width);
    let cols = width.min(values.len());
    for c in 0..cols {
        // Bucket [c] covers an equal slice of the samples; render its
        // max so narrow spikes stay visible.
        let a = c * values.len() / cols;
        let b = (((c + 1) * values.len()) / cols).max(a + 1);
        let mut m = f64::NEG_INFINITY;
        for &x in &values[a..b] {
            if x.is_finite() {
                m = m.max(x);
            }
        }
        if !m.is_finite() {
            out.push(' ');
            continue;
        }
        let frac = ((m - lo) / span).clamp(0.0, 1.0);
        let idx = (frac * (SPARK_RAMP.len() - 1) as f64).round() as usize;
        out.push(SPARK_RAMP[idx.min(SPARK_RAMP.len() - 1)] as char);
    }
    out
}

/// Render a trace as the `ds3r trace show` report: metadata, a channel
/// summary table, per-PE utilization heat rows, the thermal/power
/// envelopes, and phase markers.
pub fn render(trace: &TraceSeries, width: usize) -> String {
    let width = width.max(16);
    let mut s = String::new();
    s.push_str(&format!(
        "trace v{}: scheduler={} scenario={} seed={} pes={} nodes={} \
         budget={}\n",
        trace.schema_version,
        trace.scheduler,
        if trace.scenario.is_empty() { "-" } else { &trace.scenario },
        trace.seed,
        trace.n_pes,
        trace.n_nodes,
        trace.budget
    ));
    let span = trace
        .channels
        .iter()
        .flat_map(|c| c.t_us.last().copied())
        .fold(0.0_f64, f64::max);
    s.push_str(&format!("  span: {:.1} ms simulated\n", span / 1000.0));

    if !trace.markers.is_empty() {
        s.push_str("  phases:\n");
        for m in &trace.markers {
            s.push_str(&format!(
                "    {:>10.1} ms  {}\n",
                m.t_us / 1000.0,
                m.label
            ));
        }
    }

    // Heat rows: one sparkline per PE utilization channel, shared
    // [0, 1] scale so rows are comparable.
    let util: Vec<&TraceChannel> = (0..trace.n_pes)
        .filter_map(|i| trace.channel(&format!("pe{i}.util")))
        .collect();
    if !util.is_empty() {
        s.push_str("  utilization (0..1 per PE):\n");
        for (i, c) in util.iter().enumerate() {
            s.push_str(&format!(
                "    pe{:<3} |{}| mean={:.2}\n",
                i,
                sparkline(&c.v, 0.0, 1.0, width),
                c.mean()
            ));
        }
    }

    // Thermal envelope: hottest node trace, own scale.
    let temps: Vec<&TraceChannel> = (0..trace.n_nodes)
        .filter_map(|i| trace.channel(&format!("node{i}.temp_c")))
        .collect();
    if !temps.is_empty() {
        let (lo, hi) = temps.iter().fold(
            (f64::INFINITY, f64::NEG_INFINITY),
            |(lo, hi), c| {
                let (a, b) = c.minmax();
                (lo.min(a), hi.max(b))
            },
        );
        s.push_str(&format!(
            "  temperature ({lo:.1}..{hi:.1} C per node):\n"
        ));
        for (i, c) in temps.iter().enumerate() {
            let (_, peak) = c.minmax();
            s.push_str(&format!(
                "    node{:<2}|{}| peak={:.1} C\n",
                i,
                sparkline(&c.v, lo, hi, width),
                peak
            ));
        }
    }

    if let Some(p) = trace.channel("soc.power_w") {
        let (lo, hi) = p.minmax();
        s.push_str(&format!(
            "  power ({:.2}..{:.2} W):\n    soc   |{}| mean={:.2} W\n",
            lo,
            hi,
            sparkline(&p.v, lo, hi, width),
            p.mean()
        ));
    }
    if let Some(r) = trace.channel("sched.ready_depth") {
        let (lo, hi) = r.minmax();
        s.push_str(&format!(
            "  ready queue (0..{:.0} tasks):\n    ready |{}| mean={:.1}\n",
            hi,
            sparkline(&r.v, lo, hi, width),
            r.mean()
        ));
    }

    s.push_str("  channels:\n");
    let rows: Vec<Vec<String>> = trace
        .channels
        .iter()
        .map(|c| {
            let (lo, hi) = c.minmax();
            vec![
                c.name.clone(),
                c.unit.clone(),
                format!("{}", c.t_us.len()),
                format!("{}", c.raw_count),
                format!("{}", c.stride),
                format!("{lo:.3}"),
                format!("{:.3}", c.mean()),
                format!("{hi:.3}"),
            ]
        })
        .collect();
    for line in crate::util::plot::ascii_table(
        &["channel", "unit", "kept", "raw", "stride", "min", "mean", "max"],
        &rows,
    )
    .lines()
    {
        s.push_str("  ");
        s.push_str(line);
        s.push('\n');
    }
    s
}

/// Compare two traces; returns the human report and the number of
/// differing channels (0 = byte-equivalent payloads).
pub fn diff(a: &TraceSeries, b: &TraceSeries) -> (String, usize) {
    let mut s = String::new();
    let mut differing = 0;
    if a.scheduler != b.scheduler
        || a.scenario != b.scenario
        || a.seed != b.seed
    {
        s.push_str(&format!(
            "  meta: a=({}, {}, seed {})  b=({}, {}, seed {})\n",
            a.scheduler, a.scenario, a.seed, b.scheduler, b.scenario, b.seed
        ));
    }
    let names: Vec<&str> = {
        let mut n: Vec<&str> =
            a.channels.iter().map(|c| c.name.as_str()).collect();
        for c in &b.channels {
            if !n.contains(&c.name.as_str()) {
                n.push(c.name.as_str());
            }
        }
        n
    };
    for name in names {
        match (a.channel(name), b.channel(name)) {
            (Some(ca), Some(cb)) => {
                if ca.t_us == cb.t_us && ca.v == cb.v {
                    continue;
                }
                differing += 1;
                let n = ca.v.len().min(cb.v.len());
                let mut max_dv = 0.0_f64;
                let mut first = None;
                for i in 0..n {
                    let dv = (ca.v[i] - cb.v[i]).abs();
                    if (dv > 0.0 || ca.t_us[i] != cb.t_us[i])
                        && first.is_none()
                    {
                        first = Some(i);
                    }
                    max_dv = max_dv.max(dv);
                }
                if ca.v.len() != cb.v.len() && first.is_none() {
                    first = Some(n);
                }
                s.push_str(&format!(
                    "  {name}: {} vs {} samples, max |dv|={max_dv:.6}, \
                     first divergence at #{}\n",
                    ca.v.len(),
                    cb.v.len(),
                    first.unwrap_or(0)
                ));
            }
            (Some(_), None) => {
                differing += 1;
                s.push_str(&format!("  {name}: only in first trace\n"));
            }
            (None, Some(_)) => {
                differing += 1;
                s.push_str(&format!("  {name}: only in second trace\n"));
            }
            (None, None) => {}
        }
    }
    if a.markers != b.markers {
        s.push_str(&format!(
            "  markers differ: {} vs {}\n",
            a.markers.len(),
            b.markers.len()
        ));
    }
    let header = if differing == 0 && a.markers == b.markers {
        "traces identical\n".to_string()
    } else {
        format!("traces differ in {differing} channel(s)\n")
    };
    (header + &s, differing)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_keeps_everything_under_budget() {
        let mut s = ProbeSeries::new(16);
        for i in 0..10 {
            s.push(i as f64, (i * 2) as f64);
        }
        s.finish();
        assert_eq!(s.len(), 10);
        assert_eq!(s.stride(), 1);
        assert_eq!(s.times_us()[9], 9.0);
    }

    #[test]
    fn series_downsamples_within_budget_and_keeps_endpoints() {
        for n in [1usize, 2, 7, 16, 17, 100, 1000, 4097] {
            for budget in [2usize, 3, 8, 64] {
                let mut s = ProbeSeries::new(budget);
                for i in 0..n {
                    s.push(i as f64, (i as f64).sin());
                }
                s.finish();
                assert!(s.len() <= budget, "n={n} budget={budget}");
                assert!(s.len() >= 1.min(n));
                // Monotonic timestamps.
                for w in s.times_us().windows(2) {
                    assert!(w[0] < w[1], "n={n} budget={budget}");
                }
                // Endpoints preserved.
                assert_eq!(s.times_us()[0], 0.0);
                assert_eq!(
                    *s.times_us().last().unwrap(),
                    (n - 1) as f64,
                    "n={n} budget={budget}"
                );
                assert_eq!(s.raw_count(), n as u64);
            }
        }
    }

    #[test]
    fn series_kept_samples_are_stride_multiples() {
        let mut s = ProbeSeries::new(8);
        for i in 0..100 {
            s.push(i as f64, i as f64);
        }
        // Before finish, every kept index is a stride multiple.
        let stride = s.stride() as usize;
        for (k, &t) in s.times_us().iter().enumerate() {
            assert_eq!(t as usize, k * stride);
        }
    }

    #[test]
    fn recorder_roundtrips_through_json() {
        let mut p = ProbeRecorder::new(ProbeConfig::with_budget(8), 2, 3);
        let cluster_mhz = [1000.0, 2000.0];
        let pe_cluster = [0usize, 1];
        for e in 0..20 {
            let t = (e + 1) as f64 * 100.0;
            p.sample_epoch(
                t,
                &[0.5, 0.25],
                &[true, e % 2 == 0],
                &cluster_mhz,
                &pe_cluster,
                e,
                e as u64,
            );
            p.sample_thermal(100.0, &[1.0, 2.0, 3.0], 25.0, 4.5);
        }
        p.phase_marker(0.0, "baseline");
        p.phase_marker(1000.0, "soak");
        let tr = p.into_trace("etf", "thermal-soak", 42);
        assert_eq!(tr.channels.len(), 2 * 3 + 3 + 2);
        let j = tr.to_json().to_string();
        let back = TraceSeries::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, tr);
        assert_eq!(back.to_json().to_string(), j);
    }

    #[test]
    fn thermal_cursor_tracks_epoch_ends() {
        let mut p = ProbeRecorder::new(ProbeConfig::with_budget(64), 1, 1);
        p.sample_thermal(100.0, &[1.0], 25.0, 1.0);
        p.sample_thermal(250.0, &[2.0], 25.0, 1.0);
        p.sample_thermal(50.0, &[3.0], 25.0, 1.0);
        let tr = p.into_trace("etf", "", 1);
        let c = tr.channel("node0.temp_c").unwrap();
        assert_eq!(c.t_us, vec![100.0, 350.0, 400.0]);
        assert_eq!(c.v, vec![26.0, 27.0, 28.0]);
    }

    #[test]
    fn render_and_diff_are_nonempty_and_consistent() {
        let mut p = ProbeRecorder::new(ProbeConfig::with_budget(8), 1, 1);
        let cm = [1000.0];
        let pc = [0usize];
        for e in 0..5 {
            let t = (e + 1) as f64;
            p.sample_epoch(t, &[0.5], &[true], &cm, &pc, 1, e as u64);
            p.sample_thermal(1.0, &[1.0], 25.0, 2.0);
        }
        let tr = p.into_trace("etf", "", 7);
        let r = render(&tr, 40);
        assert!(r.contains("pe0"));
        assert!(r.contains("soc.power_w"));
        let (d, n) = diff(&tr, &tr);
        assert_eq!(n, 0);
        assert!(d.contains("identical"));
        let mut other = tr.clone();
        other.channels[0].v[0] += 1.0;
        let (d, n) = diff(&tr, &other);
        assert_eq!(n, 1);
        assert!(d.contains("differ"));
    }

    #[test]
    fn sparkline_is_width_bounded() {
        let v: Vec<f64> = (0..100).map(|i| i as f64).collect();
        assert_eq!(sparkline(&v, 0.0, 99.0, 20).len(), 20);
        assert_eq!(sparkline(&[1.0], 0.0, 1.0, 20), "@");
        assert_eq!(sparkline(&[], 0.0, 1.0, 20), "");
    }
}
