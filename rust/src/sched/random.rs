//! Random scheduler: uniform choice over supporting PEs.
//!
//! A sanity baseline for the plug-and-play interface — any scheduler
//! worth its name must beat it.  Deterministic given the seed.

use super::{Assignment, ReadyTask, SchedContext, Scheduler};
use crate::rng::Rng;

pub struct RandomSched {
    rng: Rng,
    decisions: u64,
}

impl RandomSched {
    pub fn new(seed: u64) -> RandomSched {
        RandomSched { rng: Rng::new(seed ^ 0x5EED_5C4E_D01E_0001), decisions: 0 }
    }
}

impl Scheduler for RandomSched {
    fn name(&self) -> &str {
        "random"
    }

    fn schedule(
        &mut self,
        ready: &[ReadyTask],
        ctx: &dyn SchedContext,
    ) -> Vec<Assignment> {
        let mut out = Vec::with_capacity(ready.len());
        let mut supported = Vec::new();
        for rt in ready {
            supported.clear();
            for pe in ctx.pes() {
                if pe.available && ctx.exec_us(rt, pe.id).is_some() {
                    supported.push(pe.id);
                }
            }
            if supported.is_empty() {
                continue;
            }
            let pick =
                supported[self.rng.below(supported.len() as u64) as usize];
            out.push(Assignment { job: rt.job, task: rt.task, pe: pick });
            self.decisions += 1;
        }
        out
    }

    fn report(&self) -> Vec<String> {
        vec![format!("random: {} decisions", self.decisions)]
    }

    fn decision_counts(&self) -> (u64, u64) {
        (self.decisions, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::{rt, MockCtx};

    #[test]
    fn only_assigns_supported_pes() {
        let mut ctx = MockCtx::uniform(4, 0.0);
        ctx.set_exec(0, 0, 1, 5.0);
        ctx.set_exec(0, 0, 3, 5.0);
        let mut s = RandomSched::new(7);
        for _ in 0..50 {
            let a = s.schedule(&[rt(0, 0)], &ctx);
            assert!(a[0].pe == 1 || a[0].pe == 3);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut ctx = MockCtx::uniform(4, 0.0);
        for t in 0..20 {
            for p in 0..4 {
                ctx.set_exec(0, t, p, 5.0);
            }
        }
        let tasks: Vec<_> = (0..20).map(|t| rt(0, t)).collect();
        let run = |seed| {
            let mut s = RandomSched::new(seed);
            s.schedule(&tasks, &ctx)
                .iter()
                .map(|a| a.pe)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(1));
        assert_ne!(run(1), run(2));
    }

    #[test]
    fn covers_all_pes_eventually() {
        let mut ctx = MockCtx::uniform(4, 0.0);
        for t in 0..200 {
            for p in 0..4 {
                ctx.set_exec(0, t, p, 5.0);
            }
        }
        let tasks: Vec<_> = (0..200).map(|t| rt(0, t)).collect();
        let mut s = RandomSched::new(3);
        let a = s.schedule(&tasks, &ctx);
        let used: std::collections::BTreeSet<_> =
            a.iter().map(|x| x.pe).collect();
        assert_eq!(used.len(), 4);
    }
}
