"""AOT lowering: JAX model -> HLO *text* artifacts for the rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the HLO text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Emits:
  dtpm_step.hlo.txt   the batched power/thermal epoch update
  etf_matrix.hlo.txt  the ETF finish-time matrix
  manifest.json       shapes + sha256 of each artifact (rust sanity-checks
                      at load time so a stale artifact fails loudly)
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.etf import I, J
from compile.kernels.thermal import K, N, P


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_dtpm_step() -> str:
    args = (
        f32(K, N),            # t
        f32(N, N),            # a
        f32(N, P),            # b
        f32(K, P),            # pd
        f32(K, P),            # v
        f32(1, P),            # k1
        f32(1, P),            # k2
        f32(P, N),            # pe_node
    )
    return to_hlo_text(jax.jit(model.dtpm_step_model).lower(*args))


def lower_etf() -> str:
    args = (f32(1, J), f32(I, J), f32(I, J))
    return to_hlo_text(jax.jit(model.etf_model).lower(*args))


ARTIFACTS = {
    "dtpm_step.hlo.txt": (
        lower_dtpm_step,
        {"K": K, "N": N, "P": P,
         "inputs": ["t[K,N]", "a[N,N]", "b[N,P]", "pd[K,P]", "v[K,P]",
                    "k1[1,P]", "k2[1,P]", "pe_node[P,N]"],
         "outputs": ["t_next[K,N]", "p_leak[K,P]", "p_total[K,P]",
                     "p_sum[K,1]"]},
    ),
    "etf_matrix.hlo.txt": (
        lower_etf,
        {"I": I, "J": J,
         "inputs": ["avail[1,J]", "ready[I,J]", "exec[I,J]"],
         "outputs": ["finish[I,J]", "best_pe[I,1]", "best_finish[I,1]"]},
    ),
}


def write_goldens(out_dir: str) -> None:
    """Deterministic input/output vectors for the rust runtime tests.

    rust/tests/integration_runtime.rs executes the HLO artifacts via the
    xla crate and asserts bit-close agreement with these values, which are
    computed by the pure-jnp oracle (kernels/ref.py) — closing the
    python->HLO->rust loop end to end.
    """
    import numpy as np
    from compile.kernels import ref

    rng = np.random.default_rng(42)

    # --- dtpm_step golden ---
    t = rng.uniform(0, 60, (K, N)).astype(np.float32)
    a = (np.eye(N) * 0.95 + rng.uniform(0, 0.05 / N, (N, N))).astype(
        np.float32)
    b = rng.uniform(0, 0.1, (N, P)).astype(np.float32)
    pd = rng.uniform(0, 3, (K, P)).astype(np.float32)
    v = rng.uniform(0.9, 1.3, (K, P)).astype(np.float32)
    k1 = rng.uniform(0.01, 0.1, (1, P)).astype(np.float32)
    k2 = rng.uniform(0.005, 0.02, (1, P)).astype(np.float32)
    pe_node = np.zeros((P, N), np.float32)
    for p in range(P):
        pe_node[p, rng.integers(0, N)] = 1.0
    t_next, p_leak, p_tot = ref.dtpm_step_ref(t, a, b, pd, v, k1, k2,
                                              pe_node)
    t_next = np.clip(np.asarray(t_next), 0.0, 105.0)
    p_sum = np.asarray(p_tot).sum(axis=1, keepdims=True)
    golden = {
        "inputs": {kk: vv.flatten().tolist() for kk, vv in
                   [("t", t), ("a", a), ("b", b), ("pd", pd), ("v", v),
                    ("k1", k1), ("k2", k2), ("pe_node", pe_node)]},
        "outputs": {"t_next": np.asarray(t_next).flatten().tolist(),
                    "p_leak": np.asarray(p_leak).flatten().tolist(),
                    "p_total": np.asarray(p_tot).flatten().tolist(),
                    "p_sum": p_sum.flatten().tolist()},
    }
    with open(os.path.join(out_dir, "golden_dtpm.json"), "w") as f:
        json.dump(golden, f)

    # --- etf golden ---
    avail = rng.uniform(0, 1e4, (1, J)).astype(np.float32)
    ready = rng.uniform(0, 1e4, (I, J)).astype(np.float32)
    exe = rng.uniform(1, 500, (I, J)).astype(np.float32)
    exe[40:, :] = 1e30  # rust pads with a large finite sentinel, not inf,
    exe[:, 14:] = 1e30  # to keep the JSON portable
    fin, best_pe, best_fin = ref.etf_matrix_ref(avail, ready, exe)
    golden = {
        "inputs": {kk: vv.flatten().tolist() for kk, vv in
                   [("avail", avail), ("ready", ready), ("exec", exe)]},
        "outputs": {"finish": np.asarray(fin).flatten().tolist(),
                    "best_pe": np.asarray(best_pe).flatten().tolist(),
                    "best_finish": np.asarray(best_fin).flatten().tolist()},
    }
    with open(os.path.join(out_dir, "golden_etf.json"), "w") as f:
        json.dump(golden, f)
    print(f"wrote goldens to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, (lower_fn, meta) in ARTIFACTS.items():
        text = lower_fn()
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()
        manifest[name] = dict(meta, sha256=digest, bytes=len(text))
        print(f"wrote {path}: {len(text)} chars sha256={digest[:12]}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")

    write_goldens(args.out_dir)


if __name__ == "__main__":
    main()
