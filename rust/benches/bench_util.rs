//! Tiny shared timing harness for the `harness = false` benches (the
//! offline build has no criterion).  Reports median / mean / min over
//! repeated runs with a measured-overhead warmup.

// Included via `mod bench_util;` by several benches; not every bench
// uses every helper.
#![allow(dead_code)]

use std::time::Instant;

/// Wall-clock statistics of a repeated whole-run measurement.
#[derive(Debug, Clone, Copy)]
pub struct RunStats {
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub runs: usize,
}

/// Median-of-N measurement for long-running closures (whole simulation
/// runs): `warmup` unmeasured runs, then `runs` measured ones.  Returns
/// the last run's output plus the wall-clock stats — single-shot
/// timing of a multi-second simulation is too noisy to gate CI on.
pub fn bench_median<T, F: FnMut() -> T>(
    name: &str,
    warmup: usize,
    runs: usize,
    mut f: F,
) -> (T, RunStats) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let runs = runs.max(1);
    let mut secs = Vec::with_capacity(runs);
    let mut last = None;
    for _ in 0..runs {
        let t0 = Instant::now();
        let out = f();
        secs.push(t0.elapsed().as_secs_f64());
        last = Some(out);
    }
    secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = RunStats {
        median_s: secs[secs.len() / 2],
        min_s: secs[0],
        max_s: secs[secs.len() - 1],
        runs,
    };
    println!(
        "{name:<48} {:>9.3} s median   (min {:.3}, max {:.3}, n={})",
        stats.median_s, stats.min_s, stats.max_s, stats.runs
    );
    (last.unwrap(), stats)
}

/// Time `f` for `iters` iterations, returning ns/iter statistics.
pub fn bench<F: FnMut()>(name: &str, iters: u64, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..(iters / 10).max(1) {
        f();
    }
    let mut samples = Vec::with_capacity(5);
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    println!(
        "{name:<48} {median:>12.1} ns/iter   (min {:.1}, max {:.1}, {iters} iters x5)",
        samples[0],
        samples[samples.len() - 1]
    );
    median
}

/// Build a timing-enabled telemetry handle from `TELEMETRY_OUT`
/// (`-` streams to stderr, anything else is a JSONL file path).
/// Returns a disabled handle when the variable is unset, so callers
/// can `emit` unconditionally.
pub fn telemetry_from_env() -> ds3r::telemetry::Telemetry {
    use ds3r::telemetry::{JsonlSink, Telemetry};
    use std::sync::Arc;
    let Ok(out) = std::env::var("TELEMETRY_OUT") else {
        return Telemetry::disabled();
    };
    let sink = if out == "-" {
        JsonlSink::stderr()
    } else {
        match JsonlSink::create(std::path::Path::new(&out)) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("could not open TELEMETRY_OUT {out}: {e}");
                return Telemetry::disabled();
            }
        }
    };
    // Bench records are wall-clock measurements; a non-timing sink
    // would drop every one of them.
    Telemetry::new(Arc::new(sink.with_timing(true)))
}

/// Time a single long-running closure, printing seconds.
pub fn bench_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let s = t0.elapsed().as_secs_f64();
    println!("{name:<48} {:>12.3} s", s);
    (out, s)
}
