//! Property-based tests: randomized DAGs, platforms, and workloads must
//! uphold the simulator's invariants.  The offline environment has no
//! proptest crate, so this module drives the crate's own deterministic
//! RNG through a shrinking-free but seed-reported property loop — any
//! failure prints the seed to reproduce.

use std::collections::BTreeMap;

use ds3r::app::{AppGraph, TaskSpec};
use ds3r::config::SimConfig;
use ds3r::platform::Platform;
use ds3r::rng::Rng;
use ds3r::sim::Simulation;

/// Generate a random valid DAG over the Table-2 classes.
fn random_dag(rng: &mut Rng, max_tasks: usize) -> AppGraph {
    let n = 2 + rng.below(max_tasks as u64 - 2) as usize;
    let classes: [(&str, f64); 4] = [
        ("A15", 1.0),
        ("A7", 2.5),
        ("ACC_FFT", 0.14),
        ("ACC_SCR", 0.8),
    ];
    let mut tasks = Vec::with_capacity(n);
    for i in 0..n {
        // Random support set: always include a general-purpose class so
        // the task is schedulable on both presets.
        let base = 2.0 + rng.uniform(0.0, 60.0);
        let mut exec_us = BTreeMap::new();
        exec_us.insert("A15".to_string(), base);
        if rng.f64() < 0.8 {
            exec_us.insert("A7".to_string(), base * classes[1].1);
        }
        if rng.f64() < 0.3 {
            exec_us.insert("ACC_FFT".to_string(), base * classes[2].1);
        }
        // Random preds from earlier tasks (guarantees acyclicity).
        let mut preds = Vec::new();
        if i > 0 {
            let k = rng.below(3.min(i as u64) + 1) as usize;
            for _ in 0..k {
                let p = rng.below(i as u64) as usize;
                if !preds.contains(&p) {
                    preds.push(p);
                }
            }
        }
        tasks.push(TaskSpec {
            name: format!("t{i}"),
            exec_us,
            preds,
            out_bytes: rng.below(4096),
        });
    }
    AppGraph::new("random", tasks).expect("generated DAG is valid")
}

fn property_seeds() -> Vec<u64> {
    // 24 random cases per property keeps the suite < a few seconds.
    (0..24).map(|i| 0xD53F00D + i * 7919).collect()
}

#[test]
fn prop_all_jobs_complete_and_latency_bounded_below() {
    for seed in property_seeds() {
        let mut rng = Rng::new(seed);
        let app = random_dag(&mut rng, 24);
        let cp = app.critical_path_us();
        let p = Platform::table2_soc();
        let apps = vec![app];
        let mut cfg = SimConfig::default();
        cfg.seed = seed;
        cfg.max_jobs = 30;
        cfg.warmup_jobs = 0;
        cfg.injection_rate_per_ms = rng.uniform(0.2, 4.0);
        cfg.scheduler = ["met", "etf", "ilp", "heft", "random", "rr"]
            [rng.below(6) as usize]
            .to_string();
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(r.completed_jobs, 30, "seed {seed}: jobs lost");
        for &l in &r.job_latencies_us {
            assert!(
                l >= cp - 1e-6,
                "seed {seed}: latency {l} below critical path {cp}"
            );
        }
    }
}

#[test]
fn prop_determinism_across_reruns() {
    for seed in property_seeds().into_iter().take(8) {
        let mut rng = Rng::new(seed);
        let app = random_dag(&mut rng, 20);
        let p = Platform::table2_soc();
        let apps = vec![app];
        let mut cfg = SimConfig::default();
        cfg.seed = seed;
        cfg.max_jobs = 25;
        cfg.warmup_jobs = 0;
        cfg.injection_rate_per_ms = 2.0;
        let a = Simulation::build(&p, &apps, &cfg).unwrap().run();
        let b = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(
            a.job_latencies_us, b.job_latencies_us,
            "seed {seed}: nondeterministic latencies"
        );
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.total_energy_j, b.total_energy_j);
    }
}

#[test]
fn prop_gantt_no_pe_overlap_random_dags() {
    for seed in property_seeds().into_iter().take(10) {
        let mut rng = Rng::new(seed);
        let app = random_dag(&mut rng, 16);
        let p = Platform::table2_soc();
        let apps = vec![app];
        let mut cfg = SimConfig::default();
        cfg.seed = seed;
        cfg.max_jobs = 20;
        cfg.warmup_jobs = 0;
        cfg.injection_rate_per_ms = 5.0;
        cfg.capture_gantt = true;
        cfg.gantt_limit = usize::MAX >> 1;
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        let mut by_pe: Vec<Vec<(f64, f64)>> = vec![Vec::new(); p.n_pes()];
        for e in &r.gantt {
            by_pe[e.pe].push((e.start_us, e.end_us));
        }
        for windows in &mut by_pe {
            windows.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in windows.windows(2) {
                assert!(
                    w[1].0 >= w[0].1 - 1e-9,
                    "seed {seed}: overlap {w:?}"
                );
            }
        }
    }
}

#[test]
fn prop_energy_nonnegative_and_power_bounded() {
    // No configuration may produce negative energy or a power draw
    // beyond the platform's absolute peak.
    let p = Platform::table2_soc();
    let peak_w: f64 = p
        .pes
        .iter()
        .map(|pe| {
            let c = &p.classes[pe.class];
            let o = c.max_opp();
            c.ceff * o.volt * o.volt * o.freq_mhz
                + c.leak_k1 * o.volt * (c.leak_k2 * 105.0f64).exp()
        })
        .sum();
    for seed in property_seeds().into_iter().take(10) {
        let mut rng = Rng::new(seed);
        let app = random_dag(&mut rng, 20);
        let apps = vec![app];
        let mut cfg = SimConfig::default();
        cfg.seed = seed;
        cfg.max_jobs = 40;
        cfg.warmup_jobs = 0;
        cfg.injection_rate_per_ms = rng.uniform(1.0, 12.0);
        cfg.dtpm.governor =
            ["performance", "ondemand", "powersave"][rng.below(3) as usize]
                .to_string();
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert!(r.total_energy_j >= 0.0, "seed {seed}");
        assert!(
            r.avg_power_w <= peak_w * 1.001,
            "seed {seed}: avg power {} above physical peak {peak_w}",
            r.avg_power_w
        );
    }
}

#[test]
fn prop_ilp_never_worse_than_greedy_and_respects_support() {
    for seed in property_seeds().into_iter().take(12) {
        let mut rng = Rng::new(seed);
        let app = random_dag(&mut rng, 14);
        let p = Platform::table2_soc();
        let s = ds3r::sched::ilp::optimize(&app, &p, 100_000);
        assert_eq!(s.assign.len(), app.len(), "seed {seed}");
        let exec = ds3r::sched::ilp::ExecTable::new(&app, &p);
        for (t, &pe) in s.assign.iter().enumerate() {
            assert!(
                exec.supported(t, pe),
                "seed {seed}: task {t} on unsupported pe {pe}"
            );
        }
        // Sanity: makespan at least the critical path, at most total work
        // on the slowest class (loose upper bound).
        assert!(s.makespan_us >= app.critical_path_us() - 1e-6);
        let upper: f64 = app
            .tasks
            .iter()
            .map(|t| {
                t.exec_us.values().copied().fold(0.0, f64::max)
            })
            .sum::<f64>()
            + 10.0 * app.len() as f64; // NoC slack
        assert!(
            s.makespan_us <= upper,
            "seed {seed}: makespan {} above bound {upper}",
            s.makespan_us
        );
    }
}

#[test]
fn prop_jobgen_arrival_times_sorted_positive() {
    use ds3r::config::ArrivalKind;
    use ds3r::jobgen::JobGen;
    for seed in property_seeds() {
        let mut rng = Rng::new(seed);
        let kind = [
            ArrivalKind::Poisson,
            ArrivalKind::Periodic,
            ArrivalKind::Uniform,
        ][rng.below(3) as usize];
        let rate = rng.uniform(0.1, 20.0);
        let trace =
            JobGen::new(kind, rate, 3, &[], 200, seed).record_trace();
        assert_eq!(trace.len(), 200);
        let mut last = 0.0;
        for a in &trace {
            assert!(a.at_us > last, "seed {seed}: non-increasing");
            assert!(a.app < 3);
            last = a.at_us;
        }
    }
}

#[test]
fn prop_scenario_phases_partition_and_no_job_lost() {
    // Scenario runs (PE fault + hotplug + rate step) on random DAGs:
    // the clock stays monotone (observable through phase/Gantt
    // ordering), no job is lost across the outage, and the reported
    // phases exactly partition the simulated interval.
    use ds3r::scenario::{Action, Scenario};
    for seed in property_seeds().into_iter().take(8) {
        let mut rng = Rng::new(seed);
        let app = random_dag(&mut rng, 16);
        let p = Platform::table2_soc();
        let apps = vec![app];
        let mut cfg = SimConfig::default();
        cfg.seed = seed;
        cfg.max_jobs = 40;
        cfg.warmup_jobs = 0;
        cfg.injection_rate_per_ms = 2.0;
        cfg.capture_gantt = true;
        cfg.gantt_limit = usize::MAX >> 1;
        let victim = rng.below(p.n_pes() as u64) as usize;
        cfg.scenario = Some(
            Scenario::new("prop-fault", "")
                .event(5_000.0, Action::PeFail { pe: victim })
                .event(12_000.0, Action::SetRate { per_ms: 4.0 })
                .event(18_000.0, Action::PeRestore { pe: victim }),
        );
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert_eq!(
            r.completed_jobs, r.injected_jobs,
            "seed {seed}: jobs lost across PE fault/hotplug"
        );
        assert_eq!(r.completed_jobs, 40, "seed {seed}");
        // Phase partition: starts at 0, contiguous, ends at sim end.
        assert!(!r.phases.is_empty(), "seed {seed}: no phases");
        assert_eq!(r.phases[0].start_us, 0.0, "seed {seed}");
        for w in r.phases.windows(2) {
            assert!(
                (w[0].end_us - w[1].start_us).abs() < 1e-9,
                "seed {seed}: phase gap {w:?}"
            );
        }
        let last = r.phases.last().unwrap();
        assert!(
            (last.end_us - r.sim_time_us).abs() < 1e-9,
            "seed {seed}: phases end {} != sim end {}",
            last.end_us,
            r.sim_time_us
        );
        for ph in &r.phases {
            assert!(ph.end_us >= ph.start_us, "seed {seed}: {ph:?}");
        }
        // Clock monotone: every executed task obeys start <= end and
        // fits the simulated interval.
        for e in &r.gantt {
            assert!(e.end_us >= e.start_us, "seed {seed}");
            assert!(e.end_us <= r.sim_time_us + 1e-9, "seed {seed}");
        }
    }
}

#[test]
fn prop_total_energy_equals_power_integral() {
    // Total reported energy must equal the integral of the per-epoch
    // reported power over the simulated interval (trace capture forces
    // eager integration, so every integrated epoch has a trace entry).
    for seed in property_seeds().into_iter().take(6) {
        let mut rng = Rng::new(seed);
        let app = random_dag(&mut rng, 18);
        let p = Platform::table2_soc();
        let apps = vec![app];
        let mut cfg = SimConfig::default();
        cfg.seed = seed;
        cfg.max_jobs = 40;
        cfg.warmup_jobs = 0;
        // Keep the run well past several 10 ms DTPM epochs so the
        // trace is non-empty (energy only integrates at epochs).
        cfg.injection_rate_per_ms = rng.uniform(0.5, 2.0);
        cfg.capture_traces = true;
        cfg.dtpm.governor =
            ["performance", "ondemand", "powersave"][rng.below(3) as usize]
                .to_string();
        let r = Simulation::build(&p, &apps, &cfg).unwrap().run();
        assert!(!r.trace.is_empty(), "seed {seed}");
        let mut integral = 0.0;
        let mut last_t = 0.0;
        for tr in &r.trace {
            integral += tr.power_w * (tr.t_us - last_t) * 1e-6;
            last_t = tr.t_us;
        }
        let tol = 1e-6 * r.total_energy_j.max(1e-9);
        assert!(
            (integral - r.total_energy_j).abs() <= tol,
            "seed {seed}: energy {} != power integral {integral}",
            r.total_energy_j
        );
    }
}

#[test]
fn prop_sweeps_bit_identical_across_thread_counts() {
    // coordinator::run_sweep and run_scenario_sweep must return
    // bit-identical results — values and order — for 1 vs 8 threads.
    use ds3r::app::suite::{self, WifiParams};
    use ds3r::coordinator::{self, fig3_points};
    use ds3r::scenario::{presets, Action, Scenario};

    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
    let mut base = SimConfig::default();
    base.max_jobs = 60;
    base.warmup_jobs = 5;

    let pts = fig3_points(&["etf", "met", "rr"], &[0.5, 2.0, 5.0], 11);
    let serial =
        coordinator::run_sweep(&p, &apps, &base, &pts, 1).unwrap();
    let par = coordinator::run_sweep(&p, &apps, &base, &pts, 8).unwrap();
    assert_eq!(serial.len(), par.len());
    for (a, b) in serial.iter().zip(&par) {
        assert_eq!(a.point.scheduler, b.point.scheduler, "order changed");
        assert_eq!(a.point.rate_per_ms, b.point.rate_per_ms);
        assert_eq!(a.avg_latency_us.to_bits(), b.avg_latency_us.to_bits());
        assert_eq!(a.p95_latency_us.to_bits(), b.p95_latency_us.to_bits());
        assert_eq!(
            a.energy_per_job_mj.to_bits(),
            b.energy_per_job_mj.to_bits()
        );
        assert_eq!(a.avg_power_w.to_bits(), b.avg_power_w.to_bits());
        assert_eq!(a.peak_temp_c.to_bits(), b.peak_temp_c.to_bits());
        assert_eq!(a.completed_jobs, b.completed_jobs);
        assert_eq!(a.injected_jobs, b.injected_jobs);
    }

    let mut sc_base = base.clone();
    sc_base.max_jobs = 80;
    sc_base.injection_rate_per_ms = 2.0;
    let scenarios = vec![
        presets::pe_failure(),
        Scenario::new("quiet", "")
            .event(10_000.0, Action::SetRate { per_ms: 1.0 }),
    ];
    let s1 =
        coordinator::run_scenario_sweep(&p, &apps, &sc_base, &scenarios, 1)
            .unwrap();
    let s8 =
        coordinator::run_scenario_sweep(&p, &apps, &sc_base, &scenarios, 8)
            .unwrap();
    assert_eq!(s1.len(), s8.len());
    for (a, b) in s1.iter().zip(&s8) {
        assert_eq!(a.scenario, b.scenario, "order changed");
        assert_eq!(a.avg_latency_us.to_bits(), b.avg_latency_us.to_bits());
        assert_eq!(
            a.energy_per_job_mj.to_bits(),
            b.energy_per_job_mj.to_bits()
        );
        assert_eq!(a.peak_temp_c.to_bits(), b.peak_temp_c.to_bits());
        assert_eq!(a.completed_jobs, b.completed_jobs);
        assert_eq!(a.phases.len(), b.phases.len());
        for (pa, pb) in a.phases.iter().zip(&b.phases) {
            assert_eq!(pa.label, pb.label);
            assert_eq!(pa.energy_j.to_bits(), pb.energy_j.to_bits());
            assert_eq!(
                pa.avg_latency_us.to_bits(),
                pb.avg_latency_us.to_bits()
            );
        }
    }
}

/// Worker-reuse property: for random DAGs, random configs, a random
/// registered scheduler and a random scenario preset, a reused
/// (reset) `SimWorker` is bit-identical to a fresh build — the
/// behavioural contract behind every pooled grid loop.
#[test]
fn prop_worker_reuse_bit_identical_random_configs() {
    use ds3r::scenario::presets;
    use ds3r::sim::{SimSetup, SimWorker};
    for seed in property_seeds().into_iter().take(10) {
        let mut rng = Rng::new(seed);
        let app = random_dag(&mut rng, 18);
        let p = Platform::table2_soc();
        let apps = vec![app];
        let mut cfg = SimConfig::default();
        cfg.seed = seed;
        cfg.max_jobs = 40;
        cfg.warmup_jobs = 0;
        cfg.injection_rate_per_ms = rng.uniform(0.5, 6.0);
        // "ilp"/"table" and "il" included: the registry is the roster.
        let names = ds3r::sched::builtin_names();
        loop {
            cfg.scheduler =
                names[rng.below(names.len() as u64) as usize].into();
            if cfg.scheduler != "etf-xla" {
                break; // needs on-disk artifacts; skip in properties
            }
        }
        if rng.f64() < 0.5 {
            let all = presets::all();
            cfg.scenario =
                Some(all[rng.below(all.len() as u64) as usize].clone());
        }
        let fresh = Simulation::build(&p, &apps, &cfg)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"))
            .run();
        // Dirty a worker with a different config, then reset into cfg.
        let mut decoy = cfg.clone();
        decoy.scheduler = "rr".into();
        decoy.scenario = None;
        decoy.max_jobs = 15;
        let setup = SimSetup::new(&p, &apps, &cfg).unwrap();
        let mut w = SimWorker::build(&setup, &decoy).unwrap();
        w.run(&setup);
        w.reset(&setup, &cfg).unwrap();
        w.run(&setup);
        let reused = w.take_report();
        assert_eq!(
            reused.job_latencies_us, fresh.job_latencies_us,
            "seed {seed} [{}]: latencies diverged",
            cfg.scheduler
        );
        assert_eq!(
            reused.events_processed, fresh.events_processed,
            "seed {seed} [{}]: event counts diverged",
            cfg.scheduler
        );
        assert_eq!(
            reused.total_energy_j.to_bits(),
            fresh.total_energy_j.to_bits(),
            "seed {seed} [{}]: energy diverged",
            cfg.scheduler
        );
        assert_eq!(
            reused.peak_temp_c.to_bits(),
            fresh.peak_temp_c.to_bits(),
            "seed {seed} [{}]: peak temp diverged",
            cfg.scheduler
        );
        assert_eq!(reused.scenario_events, fresh.scenario_events);
    }
}

#[test]
fn prop_random_dag_json_roundtrip() {
    for seed in property_seeds() {
        let mut rng = Rng::new(seed);
        let app = random_dag(&mut rng, 30);
        let j = app.to_json();
        let back = AppGraph::from_json(&j).unwrap();
        assert_eq!(back.len(), app.len(), "seed {seed}");
        assert_eq!(back.topo_order(), app.topo_order());
        assert!(
            (back.critical_path_us() - app.critical_path_us()).abs()
                < 1e-9
        );
    }
}
