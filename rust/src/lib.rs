//! # DS3R — a simulation framework for domain-specific SoCs
//!
//! Rust reproduction of *"Work-in-Progress: A Simulation Framework for
//! Domain-Specific System-on-Chips"* (Arda et al., CODES/ISSS 2019), the
//! paper that introduced the open-source **DS3** framework.
//!
//! DS3R is a discrete-event simulator for heterogeneous, domain-specific
//! SoCs.  It models:
//!
//! * **Applications** as DAGs of tasks with per-PE execution-time profiles
//!   ([`app`], [`platform`]) — including the paper's five-application
//!   benchmark suite from the wireless-communication and radar domains.
//! * **Job injection** following configurable stochastic processes
//!   ([`jobgen`]).
//! * **Scheduling** through a plug-and-play [`sched::Scheduler`] trait with
//!   the paper's three built-ins (MET, ETF, table/ILP) plus extension
//!   baselines (HEFT, random, round-robin).
//! * **Power, thermal, and DVFS** dynamics ([`power`], [`thermal`],
//!   [`dtpm`]) with Linux-style governors and DTPM policies; the batched
//!   thermal/power epoch update can run through an AOT-compiled
//!   JAX/Pallas artifact via PJRT ([`runtime`]).
//! * **Interconnect** latency with an analytical mesh NoC model ([`noc`]).
//! * **Runtime scenarios** — declarative, time-scripted event timelines
//!   ([`scenario`]): injection-rate ramps, app-mix switches, ambient
//!   temperature steps, PE fault/hotplug, power-budget changes and
//!   scheduler hot-swap, executed by the discrete-event loop alongside
//!   task events, with per-phase statistics in the report.
//! * **Reporting** of schedules (Gantt), latency, throughput, energy and
//!   temperature ([`stats`]), plus a multithreaded design-space sweep
//!   coordinator ([`coordinator`]) that also sweeps scenario files.
//! * **Guided design-space exploration** ([`dse`]): a mutable platform
//!   genome (PE counts, OPP subsets, NoC speed grade, power budget),
//!   NSGA-II-style multi-objective search over latency/energy/peak
//!   temperature with a Pareto-front archive, parallel cached
//!   evaluation, and resumable JSON checkpoints.
//! * **Learned runtime resource management** ([`learn`]): a
//!   dependency-free imitation-learning pipeline — feature extraction
//!   per (ready-task, PE) pair, DAgger-style demonstration collection
//!   from oracle schedulers, a seeded deterministic softmax model, and
//!   the deployable [`learn::IlSched`] (`--sched il`) with an
//!   oracle-fallback guard, hot-swappable mid-run by the scenario
//!   engine.
//! * **Experiment store** ([`store`]): an on-disk, content-addressed
//!   archive of run manifests and per-point results (`--store`),
//!   giving campaigns resumability (warm reruns skip already-computed
//!   points) and a query layer (`ds3r query`) over their provenance.
//!
//! The crate is the Layer-3 coordinator of a three-layer stack; Layers 1-2
//! (Pallas kernels + JAX models) live in `python/compile/` and are only
//! used at build time to produce `artifacts/*.hlo.txt`.  Python is never
//! on the simulation path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use ds3r::prelude::*;
//!
//! let platform = Platform::table2_soc();          // Table 2 of the paper
//! let app = ds3r::app::suite::wifi_tx(Default::default());
//! let mut cfg = SimConfig::default();
//! cfg.scheduler = "etf".into();
//! cfg.injection_rate_per_ms = 3.0;
//! cfg.max_jobs = 1000;
//! let report = Simulation::build(&platform, &[app], &cfg).unwrap().run();
//! println!("avg job latency = {:.1} us", report.avg_job_latency_us());
//! ```

pub mod app;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dse;
pub mod dtpm;
pub mod faultpoint;
pub mod fuzz;
pub mod jobgen;
pub mod learn;
pub mod noc;
pub mod platform;
pub mod power;
pub mod probe;
pub mod rng;
pub mod runtime;
pub mod scenario;
pub mod sched;
pub mod sim;
pub mod stats;
pub mod store;
pub mod telemetry;
pub mod thermal;
pub mod util;

/// Common imports for downstream users.
pub mod prelude {
    pub use crate::app::{AppGraph, TaskSpec};
    pub use crate::config::SimConfig;
    pub use crate::dse::{DseConfig, DseEngine};
    pub use crate::learn::{IlSched, LearnConfig, SoftmaxModel};
    pub use crate::platform::{PeType, Platform};
    pub use crate::scenario::Scenario;
    pub use crate::sched::Scheduler;
    pub use crate::sim::{SimReport, SimSetup, SimWorker, Simulation};
}

/// Crate-wide error type (hand-rolled: the offline build has no
/// `thiserror`).
#[derive(Debug)]
pub enum Error {
    Config(String),
    Platform(String),
    App(String),
    Sched(String),
    Sim(String),
    Runtime(String),
    Json(String),
    Io(std::io::Error),
    /// A broken internal invariant (e.g. a fan-out slot left unfilled).
    /// Unlike the other variants this never blames user input; it is
    /// returned instead of panicking so a campaign can quarantine the
    /// point and keep going.
    Internal(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Platform(m) => write!(f, "platform error: {m}"),
            Error::App(m) => write!(f, "application graph error: {m}"),
            Error::Sched(m) => write!(f, "scheduler error: {m}"),
            Error::Sim(m) => write!(f, "simulation error: {m}"),
            Error::Runtime(m) => write!(f, "runtime (PJRT) error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Internal(m) => write!(f, "internal error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;
