//! The collect → train → eval driver.
//!
//! * [`collect_round`] — run the simulation grid (`seeds ×
//!   rates`) with a recording [`Collector`] wrapped around the oracle,
//!   fanned out over reusable per-thread simulation workers via
//!   [`crate::coordinator::parallel_map_pooled`].
//!   Results aggregate in input order, so a parallel collection is
//!   **bit-identical** to a serial one.  Panic containment comes from
//!   the pooled primitive itself: a panicking grid point surfaces as
//!   an ordinary per-point error (and its worker is discarded and
//!   rebuilt), never a process abort — see
//!   [`crate::coordinator::PointOutcome`].
//! * [`train_policy`] — DAgger loop: round 0 clones the oracle's
//!   behaviour; each later round collects under the *current* policy
//!   (oracle labels), aggregates, and retrains on everything so far.
//! * [`evaluate`] — IL vs oracle vs baselines on the same grid: mean
//!   latency, energy per job, guard-fallback counts, and the
//!   decision-agreement fraction.

use std::rc::Rc;

use crate::app::AppGraph;
use crate::coordinator::parallel_map_pooled;
use crate::platform::Platform;
use crate::sched::{self, SchedBuild};
use crate::sim::{SimSetup, SimWorker};
use crate::{Error, Result};

use super::dataset::{Collector, Dataset};
use super::model::{SoftmaxModel, TrainParams};
use super::policy::IlSched;
use super::LearnConfig;

/// The `seeds × rates` simulation grid of a config, in deterministic
/// (seed-major) order.
fn grid(lc: &LearnConfig) -> Vec<(u64, f64)> {
    let mut out =
        Vec::with_capacity(lc.seeds.len() * lc.rates_per_ms.len());
    for &s in &lc.seeds {
        for &r in &lc.rates_per_ms {
            out.push((s, r));
        }
    }
    out
}

/// Run one collection round over the grid.  With `policy = None` the
/// oracle acts (behavioural cloning); with a policy, the policy acts
/// and the oracle labels (DAgger).  Returns the aggregated dataset plus
/// the policy's (decisions, oracle-matches) counters.
pub fn collect_round(
    platform: &Platform,
    apps: &[AppGraph],
    lc: &LearnConfig,
    policy: Option<&SoftmaxModel>,
) -> Result<(Dataset, u64, u64)> {
    run_grid(platform, apps, lc, policy, lc.max_samples_per_run)
}

/// Grid fan-out shared by [`collect_round`] and the agreement pass of
/// [`evaluate`] (which sets `max_samples = 0`: decisions are counted
/// but no demonstrations are stored).
fn run_grid(
    platform: &Platform,
    apps: &[AppGraph],
    lc: &LearnConfig,
    policy: Option<&SoftmaxModel>,
    max_samples: usize,
) -> Result<(Dataset, u64, u64)> {
    let pts = grid(lc);
    let setup = SimSetup::new(platform, apps, &lc.sim)?;
    let setup = &setup;
    let results = parallel_map_pooled(
        &pts,
        lc.eval_threads(),
        || None::<SimWorker>,
        |slot, _, &(seed, rate)| {
            let mut cfg = lc.sim.clone();
            cfg.scheduler = lc.oracle.clone();
            cfg.seed = seed;
            cfg.injection_rate_per_ms = rate;
            let build = SchedBuild {
                platform,
                apps,
                seed,
                artifacts_dir: cfg.artifacts_dir.clone(),
                policy_path: cfg.il_policy.clone(),
            };
            let oracle = sched::create(&lc.oracle, &build)?;
            let (collector, shared) =
                Collector::new(oracle, policy.cloned(), max_samples);
            let worker = SimWorker::obtain_with_scheduler(
                slot,
                setup,
                &cfg,
                Box::new(collector),
            )?;
            worker.run(setup);
            // Drop the worker's scheduler handle so the collector's
            // shared sample buffer has exactly one owner left.
            drop(worker.take_scheduler());
            let c = Rc::try_unwrap(shared)
                .map_err(|_| {
                    Error::Sim("collector leaked its sample buffer".into())
                })?
                .into_inner();
            Ok((c.data, c.policy_decisions, c.policy_matches))
        },
    );
    let mut data = Dataset::default();
    data.oracle = lc.oracle.clone();
    let (mut dec, mut mat) = (0u64, 0u64);
    for (i, r) in results.into_iter().enumerate() {
        let (d, pd, pm) = r.map_err(|e| {
            Error::Sim(format!(
                "collect seed {} rate {}: {e}",
                pts[i].0, pts[i].1
            ))
        })?;
        data.extend(d);
        dec += pd;
        mat += pm;
    }
    Ok((data, dec, mat))
}

/// Summary of a [`train_policy`] run.
#[derive(Debug, Clone)]
pub struct TrainSummary {
    pub rounds: usize,
    /// Aggregated demonstrations the final model was trained on.
    pub samples: usize,
    /// Deployment agreement with the oracle measured during the last
    /// DAgger round (`None` for pure behavioural cloning, `rounds = 1`).
    pub agreement: Option<f64>,
}

/// The DAgger pipeline: collect → train, `lc.rounds` times, aggregating
/// demonstrations across rounds.  Bit-reproducible for a fixed config:
/// collection aggregates in grid order, training is seeded SGD.
pub fn train_policy(
    platform: &Platform,
    apps: &[AppGraph],
    lc: &LearnConfig,
) -> Result<(SoftmaxModel, TrainSummary)> {
    train_policy_with(
        platform,
        apps,
        lc,
        &crate::telemetry::Telemetry::disabled(),
    )
}

/// [`train_policy`] with telemetry: emits one deterministic
/// [`LearnRound`](crate::telemetry::Event::LearnRound) event per
/// DAgger round (round index, aggregated sample count, deployment
/// agreement — `None` on the behavioural-cloning round 0).  Training
/// itself is unchanged and bit-identical to [`train_policy`].
pub fn train_policy_with(
    platform: &Platform,
    apps: &[AppGraph],
    lc: &LearnConfig,
    tel: &crate::telemetry::Telemetry,
) -> Result<(SoftmaxModel, TrainSummary)> {
    lc.validate()?;
    let n_classes = platform.classes.len().max(1);
    let params = TrainParams {
        epochs: lc.epochs,
        learning_rate: lc.learning_rate,
        l2: lc.l2,
        seed: lc.train_seed,
    };
    let (mut agg, _, _) = collect_round(platform, apps, lc, None)?;
    if agg.is_empty() {
        return Err(Error::Sim(
            "collected no demonstrations — raise max_jobs or the \
             injection rates"
                .into(),
        ));
    }
    let mut model = SoftmaxModel::train(
        &agg,
        n_classes,
        &lc.oracle,
        &params,
        lc.guard_ratio,
    );
    tel.emit(|| crate::telemetry::Event::LearnRound {
        round: 0,
        samples: agg.len(),
        agreement: None,
    });
    let mut agreement = None;
    for round in 1..lc.rounds {
        let (d, dec, mat) =
            collect_round(platform, apps, lc, Some(&model))?;
        if dec > 0 {
            agreement = Some(mat as f64 / dec as f64);
        }
        agg.extend(d);
        model = SoftmaxModel::train(
            &agg,
            n_classes,
            &lc.oracle,
            &params,
            lc.guard_ratio,
        );
        tel.emit(|| crate::telemetry::Event::LearnRound {
            round,
            samples: agg.len(),
            agreement,
        });
    }
    Ok((
        model,
        TrainSummary { rounds: lc.rounds, samples: agg.len(), agreement },
    ))
}

/// Aggregated evaluation of one scheduler over the grid.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalRow {
    pub scheduler: String,
    /// Mean over grid points of the per-run mean job latency (µs).
    pub mean_latency_us: f64,
    /// Mean over grid points of energy per completed job (mJ).
    pub energy_per_job_mj: f64,
    pub completed: usize,
    pub injected: usize,
    /// Scheduler decision counters summed over the grid (IL rows).
    pub decisions: u64,
    pub fallbacks: u64,
}

/// Result of [`evaluate`]: one row per scheduler (IL first, then the
/// oracle, then the baselines) plus the decision-agreement fraction.
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub rows: Vec<EvalRow>,
    /// Fraction of deployed-policy decisions matching the oracle's
    /// label on the states the policy visits.
    pub agreement: f64,
    /// Grid points each row aggregates (seeds × rates).
    pub grid_points: usize,
}

impl EvalReport {
    pub fn row(&self, scheduler: &str) -> Option<&EvalRow> {
        self.rows.iter().find(|r| r.scheduler == scheduler)
    }
}

/// Run IL vs its oracle vs the configured baselines on the same
/// `seeds × rates` grid and aggregate per scheduler, in input order —
/// like the collection fan-out, bit-identical across thread counts.
pub fn evaluate(
    platform: &Platform,
    apps: &[AppGraph],
    lc: &LearnConfig,
    model: &SoftmaxModel,
) -> Result<EvalReport> {
    lc.validate()?;
    let mut scheds: Vec<String> = vec!["il".into(), lc.oracle.clone()];
    for b in &lc.baselines {
        if !scheds.contains(b) {
            scheds.push(b.clone());
        }
    }
    let g = grid(lc);
    let mut points: Vec<(String, u64, f64)> =
        Vec::with_capacity(scheds.len() * g.len());
    for s in &scheds {
        for &(seed, rate) in &g {
            points.push((s.clone(), seed, rate));
        }
    }
    let setup = SimSetup::new(platform, apps, &lc.sim)?;
    let setup = &setup;
    let results = parallel_map_pooled(
        &points,
        lc.eval_threads(),
        || None::<SimWorker>,
        |slot, _, p| {
            let (sname, seed, rate) = (&p.0, p.1, p.2);
            let mut cfg = lc.sim.clone();
            cfg.scheduler = sname.clone();
            cfg.seed = seed;
            cfg.injection_rate_per_ms = rate;
            let worker = if sname == "il" {
                // Evaluate the in-memory model, not a disk artifact.
                SimWorker::obtain_with_scheduler(
                    slot,
                    setup,
                    &cfg,
                    Box::new(IlSched::new(model.clone())),
                )?
            } else {
                SimWorker::obtain(slot, setup, &cfg)?
            };
            let report = worker.run(setup);
            Ok((
                report.avg_job_latency_us(),
                report.energy_per_job_mj(),
                report.completed_jobs,
                report.injected_jobs,
                report.sched_decisions,
                report.sched_fallbacks,
            ))
        },
    );
    let mut vals = Vec::with_capacity(points.len());
    for (i, r) in results.into_iter().enumerate() {
        vals.push(r.map_err(|e| {
            Error::Sim(format!(
                "eval {} seed {} rate {}: {e}",
                points[i].0, points[i].1, points[i].2
            ))
        })?);
    }
    let per = g.len();
    let mut rows = Vec::with_capacity(scheds.len());
    for (si, s) in scheds.iter().enumerate() {
        let chunk = &vals[si * per..(si + 1) * per];
        let n = per as f64;
        rows.push(EvalRow {
            scheduler: s.clone(),
            mean_latency_us: chunk.iter().map(|v| v.0).sum::<f64>() / n,
            energy_per_job_mj: chunk.iter().map(|v| v.1).sum::<f64>() / n,
            completed: chunk.iter().map(|v| v.2).sum(),
            injected: chunk.iter().map(|v| v.3).sum(),
            decisions: chunk.iter().map(|v| v.4).sum(),
            fallbacks: chunk.iter().map(|v| v.5).sum(),
        });
    }
    // Decision agreement on the states the deployed policy visits —
    // count-only (max_samples 0): no demonstrations are stored.
    let (_, dec, mat) = run_grid(platform, apps, lc, Some(model), 0)?;
    let agreement = if dec > 0 { mat as f64 / dec as f64 } else { 0.0 };
    Ok(EvalReport { rows, agreement, grid_points: per })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::suite::{self, WifiParams};

    fn tiny_cfg() -> LearnConfig {
        let mut lc = LearnConfig::default();
        lc.seeds = vec![1];
        lc.rates_per_ms = vec![2.0];
        lc.rounds = 1;
        lc.epochs = 2;
        lc.sim.max_jobs = 40;
        lc.sim.warmup_jobs = 4;
        lc.threads = 2;
        lc
    }

    #[test]
    fn tiny_pipeline_trains_and_evaluates() {
        let p = Platform::table2_soc();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        let lc = tiny_cfg();
        let (model, summary) = train_policy(&p, &apps, &lc).unwrap();
        assert!(summary.samples > 0);
        assert!(model.weights.iter().all(|w| w.is_finite()));
        let report = evaluate(&p, &apps, &lc, &model).unwrap();
        // il + etf + random + rr.
        assert_eq!(report.rows.len(), 4);
        assert_eq!(report.rows[0].scheduler, "il");
        for row in &report.rows {
            assert_eq!(
                row.completed, row.injected,
                "{} lost jobs",
                row.scheduler
            );
            assert!(row.mean_latency_us > 0.0, "{}", row.scheduler);
        }
        let il = report.row("il").unwrap();
        assert!(il.decisions > 0, "IL decision counter not wired");
        assert!((0.0..=1.0).contains(&report.agreement));
    }

    #[test]
    fn collection_grid_is_seed_major_and_deterministic() {
        let mut lc = tiny_cfg();
        lc.seeds = vec![3, 5];
        lc.rates_per_ms = vec![1.0, 2.0];
        assert_eq!(
            grid(&lc),
            vec![(3, 1.0), (3, 2.0), (5, 1.0), (5, 2.0)]
        );
    }
}
